//! End-to-end full-stack driver (deliverable (e2e)): the tiny 85M-param
//! Llama-style model, TP-sharded at the layer level, decoded by the rust
//! coordinator with **real NVRAR all-reduces** combining shard partials —
//! and every step cross-checked against the unsharded full-model oracle.
//!
//! This proves all three layers compose: Pallas kernels (L1) inside the
//! JAX graphs (L2), AOT-lowered to HLO, executed through PJRT by the rust
//! coordinator (L3) whose communication hot path is Algorithm 1 itself.
//!
//! Usage: cargo run --release --example e2e_decode -- [--steps 64]
//!        [--algo nvrar|ring|rd-flat|central] [--no-verify]

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::collectives::real::Algo;
use yalis::runtime::tensor::argmax_rows;
use yalis::runtime::tp::TpRuntime;
use yalis::util::cli::Cli;
use yalis::util::rng::Rng;
use yalis::util::stats::fmt_time;

fn main() -> anyhow::Result<()> {
    let mut cli = Cli::new("e2e_decode", "full-stack TP decode with real NVRAR all-reduce");
    cli.opt("artifacts", "artifacts", "artifacts directory");
    cli.opt("steps", "64", "decode steps");
    cli.opt("algo", "nvrar", "all-reduce algorithm (nvrar|ring|rd-flat|central)");
    cli.opt("chunk-words", "256", "NVRAR C_s in f32 words");
    cli.flag("no-verify", "skip the full-model oracle cross-check");
    let args = cli.parse();

    let steps = args.get_usize("steps");
    let verify = !args.get_flag("no-verify");

    // lint: allow(D03) real wall-clock timing of the host runtime
    let t_load = std::time::Instant::now();
    let mut rt = TpRuntime::load(args.get("artifacts"))?;
    rt.algo = match args.get("algo") {
        "nvrar" => Algo::Nvrar,
        "ring" => Algo::Ring,
        "rd-flat" => Algo::RdFlat,
        "central" => Algo::Central,
        other => anyhow::bail!("unknown algo {other}"),
    };
    rt.chunk_words = args.get_usize("chunk-words");
    println!(
        "loaded {} layers x {} TP shards, d={}, vocab={} ({}); load {}",
        rt.dims.n_layers,
        rt.dims.shards,
        rt.dims.d_model,
        rt.dims.vocab,
        rt.algo.name(),
        fmt_time(t_load.elapsed().as_secs_f64())
    );

    // Deterministic synthetic prompt (the AOT shape is fixed: B x prompt).
    let mut rng = Rng::new(42);
    let prompt: Vec<i32> = (0..rt.dims.batch * rt.dims.prompt)
        .map(|_| rng.usize(0, rt.dims.vocab - 1) as i32)
        .collect();

    // lint: allow(D03) real wall-clock timing of the host runtime
    let t_prefill = std::time::Instant::now();
    let logits = rt.prefill(&prompt)?;
    let prefill_secs = t_prefill.elapsed().as_secs_f64();
    println!("prefill ({} tokens/seq): {}", rt.dims.prompt, fmt_time(prefill_secs));

    let b = rt.dims.batch;
    let mut toks = argmax_rows(&logits, b);
    let mut produced: Vec<Vec<i32>> = Vec::new();
    let mut max_err = 0f32;
    // lint: allow(D03) real wall-clock timing of the host runtime
    let t_decode = std::time::Instant::now();
    for step in 0..steps {
        if rt.pos + 1 >= rt.dims.max_seq {
            println!("KV cache full at step {step}");
            break;
        }
        let full = if verify { Some(rt.decode_step_full(&toks)?) } else { None };
        let sharded = rt.decode_step_sharded(&toks)?;
        if let Some(full) = full {
            for (a, b_) in sharded.iter().zip(&full) {
                max_err = max_err.max((a - b_).abs() / (1.0 + b_.abs()));
            }
            assert!(
                max_err < 2e-3,
                "step {step}: sharded logits diverged from oracle (rel err {max_err})"
            );
            // Greedy tokens must agree.
            assert_eq!(argmax_rows(&sharded, b), argmax_rows(&full, b), "token mismatch @ {step}");
        }
        toks = argmax_rows(&sharded, b);
        produced.push(toks.clone());
    }
    let decode_secs = t_decode.elapsed().as_secs_f64();
    let n_steps = produced.len();

    println!("\ndecoded {} steps x {} seqs:", n_steps, b);
    for seq in 0..b {
        let ids: Vec<String> =
            produced.iter().take(16).map(|t| t[seq].to_string()).collect();
        println!("  seq{}: {} ...", seq, ids.join(" "));
    }
    let s = rt.stats;
    println!("\n-- timing --");
    println!("decode total: {} ({} /step)", fmt_time(decode_secs), fmt_time(decode_secs / n_steps.max(1) as f64));
    println!("  pjrt:       {}", fmt_time(s.pjrt));
    println!(
        "  all-reduce: {} ({} ops, {} each, msg = {} f32 = {} B)",
        fmt_time(s.allreduce),
        s.allreduces,
        fmt_time(s.allreduce / s.allreduces.max(1) as f64),
        b * rt.dims.d_model,
        b * rt.dims.d_model * 4,
    );
    println!("  host glue:  {}", fmt_time(s.host));
    if verify {
        println!("oracle cross-check: max relative logit error {max_err:.2e} — OK");
    }
    println!("tokens/s: {:.2}", (n_steps * b) as f64 / decode_secs);
    Ok(())
}
