//! Chunked vs whole-prompt prefill, end to end: drive a long-prompt-heavy
//! trace (tail up to 4x the 8192-token step budget) through the same
//! deployment twice — once with the step budget raised until the longest
//! prompt is admissible as one monolithic prefill step (the only way the
//! pre-chunking engine could serve it), once with bounded chunks at the
//! same budget — and print the TTFT tail, TPOT and preemption comparison.
//! A third row runs the production shape: the default budget with chunks,
//! which whole-prompt admission cannot serve at all.
//!
//! Usage: cargo run --release --example chunked_prefill --
//!        [--prompts 300] [--rate 4] [--conc 64] [--chunk 2048]
//!        [--gpus 16] [--allreduce nvrar]

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::collectives::AllReduceImpl;
use yalis::parallel::ParallelSpec;
use yalis::serving::{fig9_config, serve, ServeReport};
use yalis::trace::TraceSpec;
use yalis::util::cli::Cli;
use yalis::util::tables::Table;

fn main() {
    let mut cli = Cli::new("chunked_prefill", "chunked vs whole-prompt prefill TTFT-tail study");
    cli.opt("prompts", "300", "number of prompts");
    cli.opt("rate", "4", "mean arrival rate (req/s)");
    cli.opt("conc", "64", "max concurrency");
    cli.opt("chunk", "2048", "prefill chunk size (tokens)");
    cli.opt("gpus", "16", "GPU count");
    cli.opt("allreduce", "nvrar", "all-reduce impl (nccl|nccl-ring|nccl-tree|mpi|nvrar)");
    let args = cli.parse();

    let ar = args.get_with("allreduce", AllReduceImpl::by_name);
    let gpus = args.get_usize("gpus");
    let chunk = args.get_usize("chunk");

    let mut spec = TraceSpec::long_prompt();
    spec.num_prompts = args.get_usize("prompts");
    spec.rate = args.get_f64("rate");
    let reqs = spec.generate();
    let longest = reqs.iter().map(|r| r.prompt_len).max().unwrap_or(8192);
    println!(
        "trace: {} prompts, mean in {:.0} tokens, longest {longest} (step budget 8192)",
        reqs.len(),
        reqs.iter().map(|r| r.prompt_len).sum::<usize>() as f64 / reqs.len() as f64,
    );

    let base = fig9_config(ParallelSpec::tp(gpus), ar, args.get_usize("conc"), "perlmutter", gpus);
    let mut t = Table::new(
        &format!("chunked vs whole-prompt prefill ({})", base.deployment_label()),
        &["mode", "budget", "tok/s", "TTFT p50", "TTFT p99", "TPOT p50", "preempts", "lost tokens"],
    );
    let expected: u64 = reqs.iter().map(|r| r.decode_len as u64).sum();
    let mut run = |mode: &str, budget: usize, chunk_tokens: usize| -> ServeReport {
        let mut cfg = base.clone();
        cfg.max_step_tokens = budget;
        cfg.chunk_tokens = chunk_tokens;
        let rep = serve(&cfg, &reqs);
        t.row(&[
            mode.to_string(),
            budget.to_string(),
            format!("{:.1}", rep.output_throughput),
            format!("{:.2}", rep.ttft_p50),
            format!("{:.2}", rep.ttft_p99),
            format!("{:.4}", rep.tpot_p50),
            rep.preemptions.to_string(),
            (expected - rep.total_output_tokens).to_string(),
        ]);
        rep
    };
    // Headroom above the longest prompt so in-flight decodes never force
    // the whole-prompt baseline to split a prompt after all.
    let whole = run("whole-prompt", longest + 64, 0);
    let chunked = run("chunked", longest + 64, chunk);
    run("chunked", 8192, chunk);
    t.print();
    println!(
        "TTFT p99: {:.2}s whole -> {:.2}s chunked ({:+.0}%); TPOT p50 {:+.1}%",
        whole.ttft_p99,
        chunked.ttft_p99,
        (chunked.ttft_p99 / whole.ttft_p99 - 1.0) * 100.0,
        (chunked.tpot_p50 / whole.tpot_p50.max(1e-12) - 1.0) * 100.0,
    );
}
