//! Trace-driven serving (Figure 9/18): run a BurstGPT-style or
//! decode-heavy trace through TP/NCCL, TP/NVRAR and HP deployments and
//! report output throughput.
//!
//! Usage: cargo run --release --example serve_trace --
//!        [--trace burstgpt|decode-heavy] [--prompts 300] [--conc 32,256]

use yalis::collectives::AllReduceImpl;
use yalis::serving::{fig9_config, serve, Deployment};
use yalis::trace::TraceSpec;
use yalis::util::cli::Cli;
use yalis::util::tables::Table;

fn main() {
    let mut cli = Cli::new("serve_trace", "Fig 9/18 trace-driven serving");
    cli.opt("trace", "burstgpt", "trace kind (burstgpt|decode-heavy)");
    cli.opt("prompts", "300", "number of prompts");
    cli.opt("conc", "32,256", "concurrency settings");
    cli.opt("gpus", "16", "GPU count");
    let args = cli.parse();

    let mut spec = match args.get("trace") {
        "burstgpt" => TraceSpec::burstgpt(),
        "decode-heavy" => TraceSpec::decode_heavy(),
        other => panic!("unknown trace '{other}'"),
    };
    spec.num_prompts = args.get_usize("prompts");
    let reqs = spec.generate();
    println!(
        "trace: {} prompts, mean in {:.0} / out {:.0} tokens",
        reqs.len(),
        reqs.iter().map(|r| r.prompt_len).sum::<usize>() as f64 / reqs.len() as f64,
        reqs.iter().map(|r| r.decode_len).sum::<usize>() as f64 / reqs.len() as f64,
    );

    let mut t = Table::new(
        &format!("serving throughput ({} trace)", args.get("trace")),
        &["deployment", "C", "tok/s", "makespan (s)", "mean TTFT (s)", "decode-only"],
    );
    for c in args.get_usize_list("conc") {
        for dep in [
            Deployment::Tp(AllReduceImpl::NcclAuto),
            Deployment::Tp(AllReduceImpl::Nvrar),
            Deployment::Hp,
        ] {
            let cfg = fig9_config(dep, c, "perlmutter", args.get_usize("gpus"));
            let rep = serve(&cfg, &reqs);
            t.row(&[
                dep.label(),
                c.to_string(),
                format!("{:.1}", rep.output_throughput),
                format!("{:.1}", rep.makespan),
                format!("{:.2}", rep.mean_ttft),
                format!("{:.0}%", rep.decode_only_frac * 100.0),
            ]);
        }
    }
    t.print();
}
