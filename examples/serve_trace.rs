//! Trace-driven serving (Figure 9/18): run a BurstGPT-style or
//! decode-heavy trace through a grid of parallelism specs × all-reduce
//! implementations and report output throughput.
//!
//! Usage: cargo run --release --example serve_trace --
//!        [--trace burstgpt|decode-heavy|long-prompt] [--prompts 300]
//!        [--conc 32,256] [--gpus 16] [--specs tp16,tp4-pp4]
//!        [--allreduce nccl,nvrar] [--chunk-tokens 0]

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::collectives::AllReduceImpl;
use yalis::parallel::ParallelSpec;
use yalis::serving::{fig9_config, serve};
use yalis::trace::TraceSpec;
use yalis::util::cli::Cli;
use yalis::util::tables::Table;

fn main() {
    let mut cli = Cli::new("serve_trace", "Fig 9/18 trace-driven serving");
    cli.opt("trace", "burstgpt", "trace kind (burstgpt|decode-heavy|long-prompt)");
    cli.opt("prompts", "300", "number of prompts");
    cli.opt("conc", "32,256", "concurrency settings");
    cli.opt("gpus", "16", "GPU count");
    cli.opt("specs", "tp16,tp4-pp4", "parallelism specs to sweep (e.g. tp16,tp8-pp2)");
    cli.opt("allreduce", "nccl,nvrar", "all-reduce impls to sweep");
    cli.opt("chunk-tokens", "0", "prefill chunk cap (0 = budget-bounded chunks)");
    let args = cli.parse();

    let mut spec = match args.get("trace") {
        "burstgpt" => TraceSpec::burstgpt(),
        "decode-heavy" => TraceSpec::decode_heavy(),
        "long-prompt" => TraceSpec::long_prompt(),
        other => panic!("unknown trace '{other}'"),
    };
    spec.num_prompts = args.get_usize("prompts");
    let reqs = spec.generate();
    println!(
        "trace: {} prompts, mean in {:.0} / out {:.0} tokens",
        reqs.len(),
        reqs.iter().map(|r| r.prompt_len).sum::<usize>() as f64 / reqs.len() as f64,
        reqs.iter().map(|r| r.decode_len).sum::<usize>() as f64 / reqs.len() as f64,
    );

    let gpus = args.get_usize("gpus");
    let topo = yalis::cluster::presets::perlmutter(1).with_gpus(gpus);
    let pspecs: Vec<ParallelSpec> = args.get_list_with("specs", |s| {
        let p = ParallelSpec::by_name(s)?;
        p.validate(&topo)?;
        if p.ep > 1 {
            anyhow::bail!("spec {p} is expert-parallel but this example serves the dense 70B model");
        }
        Ok::<_, anyhow::Error>(p)
    });
    let ars: Vec<AllReduceImpl> = args.get_list_with("allreduce", AllReduceImpl::by_name);

    let mut t = Table::new(
        &format!("serving throughput ({} trace)", args.get("trace")),
        &["deployment", "C", "tok/s", "makespan (s)", "mean TTFT (s)", "decode-only"],
    );
    for c in args.get_usize_list("conc") {
        for &pspec in &pspecs {
            for &ar in &ars {
                let mut cfg = fig9_config(pspec, ar, c, "perlmutter", gpus);
                cfg.chunk_tokens = args.get_usize("chunk-tokens");
                let rep = serve(&cfg, &reqs);
                t.row(&[
                    cfg.deployment_label(),
                    c.to_string(),
                    format!("{:.1}", rep.output_throughput),
                    format!("{:.1}", rep.makespan),
                    format!("{:.2}", rep.mean_ttft),
                    format!("{:.0}%", rep.decode_only_frac * 100.0),
                ]);
            }
        }
    }
    t.print();
}
