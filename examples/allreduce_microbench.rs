//! All-reduce microbenchmark (Figure 6 / 14): NVRAR vs NCCL across message
//! sizes and GPU counts on the simulated interconnects, plus the **real**
//! shared-memory implementations raced on this host for correctness-path
//! wall-clock.
//!
//! Usage: cargo run --release --example allreduce_microbench --
//!        [--machine perlmutter|vista] [--real]

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::collectives::real::{serial_sum, Algo, Harness};
use yalis::coordinator::experiments;
use yalis::util::cli::Cli;
use yalis::util::rng::Rng;
use yalis::util::stats::fmt_time;

fn main() {
    let mut cli = Cli::new("allreduce_microbench", "Fig 6/14 microbenchmark");
    cli.opt("machine", "perlmutter", "machine preset");
    cli.flag("real", "also run the real shmem implementations on this host");
    let args = cli.parse();

    for t in experiments::fig6_microbench(args.get("machine")) {
        t.print();
    }

    if args.get_flag("real") {
        println!("== real shmem all-reduce (this host, 8 PEs, 64K f32) ==");
        let n = 65_536;
        let mut rng = Rng::new(3);
        let inputs: Vec<Vec<f32>> =
            (0..8).map(|_| (0..n).map(|_| rng.f32() - 0.5).collect()).collect();
        let want = serial_sum(&inputs);
        for algo in [Algo::Nvrar, Algo::Ring, Algo::RdFlat, Algo::Central] {
            let h = Harness {
                nodes: 4,
                gpus_per_node: 2,
                n_elems: n,
                chunk_words: 4096,
                algo,
            };
            let h = if algo == Algo::RdFlat {
                Harness { nodes: 8, gpus_per_node: 1, ..h }
            } else {
                h
            };
            // lint: allow(D03) real wall-clock timing of the host all-reduce
            let t0 = std::time::Instant::now();
            let out = h.run_once(|pe| inputs[pe].clone());
            let dt = t0.elapsed().as_secs_f64();
            let ok = out.iter().all(|v| {
                v.iter().zip(&want).all(|(a, b)| (a - b).abs() <= 1e-3 * (1.0 + b.abs()))
            });
            println!("  {:<8} {}  correct={}", algo.name(), fmt_time(dt), ok);
        }
    }
}
