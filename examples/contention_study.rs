//! Shared-interconnect contention study: what concurrent KV traffic does
//! to decode latency when every byte rides the same fabric.
//!
//! A disaggregated fleet generates continuous prefill→decode KV handoffs;
//! with `FleetConfig::contention` those transfers book the same per-node
//! inter-node NICs the decode all-reduces occupy, so TTFT/TPOT inflate and
//! the fleet report carries per-link utilization plus a congestion-delay
//! histogram. The closed-form baseline (contention off) prices the same
//! trace with every transfer pretending it has the interconnect to itself.
//!
//! Usage: cargo run --release --example contention_study --
//!        [--prompts 400] [--rate 10] [--replicas 3] [--prefill 1]
//!        [--conc 32] [--allreduce nvrar] [--drain-at 0]

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::collectives::AllReduceImpl;
use yalis::fleet::{run_fleet, FleetConfig};
use yalis::parallel::ParallelSpec;
use yalis::serving::fig9_config;
use yalis::simnet::CongestionStats;
use yalis::trace::TraceSpec;
use yalis::util::cli::Cli;
use yalis::util::tables::Table;

fn main() {
    let mut cli = Cli::new("contention_study", "shared-fabric contention vs closed-form serving");
    cli.opt("prompts", "400", "trace length");
    cli.opt("rate", "10", "arrival rate (req/s)");
    cli.opt("replicas", "3", "decode/monolithic replicas (70B tp16 each)");
    cli.opt("prefill", "1", "prefill-only replicas (0 = monolithic, no handoff traffic)");
    cli.opt("conc", "32", "per-replica max concurrency");
    cli.opt("allreduce", "nvrar", "per-replica all-reduce (nccl|nccl-ring|nccl-tree|mpi|nvrar)");
    cli.opt("drain-at", "0", "also drain replica 0 at this time (0 = no scripted drain)");
    let args = cli.parse();

    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = args.get_usize("prompts");
    spec.rate = args.get_f64("rate");
    let reqs = spec.generate();
    let ar = args.get_with("allreduce", AllReduceImpl::by_name);
    let base = fig9_config(ParallelSpec::tp(16), ar, args.get_usize("conc"), "perlmutter", 16);
    let build = |contention: bool| {
        let mut cfg = FleetConfig::new(base.clone(), args.get_usize("replicas"))
            .with_contention(contention);
        let prefill = args.get_usize("prefill");
        if prefill > 0 {
            cfg = cfg.disaggregated(prefill);
        }
        let drain = args.get_f64("drain-at");
        if drain > 0.0 {
            cfg = cfg.with_drain_at(drain, 0);
        }
        cfg
    };

    let off = run_fleet(&build(false), &reqs);
    let on = run_fleet(&build(true), &reqs);

    let mut t = Table::new(
        &format!(
            "contention study: {} requests, {} replicas + {} prefill, {}",
            reqs.len(),
            args.get_usize("replicas"),
            args.get_usize("prefill"),
            base.deployment_label()
        ),
        &[
            "fabric", "tok/s", "TTFT p50", "TTFT p99", "TPOT p50", "handoff GB",
            "delayed flows", "delay total (s)", "NIC util",
        ],
    );
    for (name, rep) in [("closed-form (off)", &off), ("shared links (on)", &on)] {
        t.row(&[
            name.to_string(),
            format!("{:.1}", rep.throughput),
            format!("{:.3}", rep.ttft_p50),
            format!("{:.3}", rep.ttft_p99),
            format!("{:.4}", rep.tpot_p50),
            format!("{:.2}", rep.handoff_gb),
            rep.congestion.delayed.to_string(),
            format!("{:.3}", rep.congestion.total_delay),
            format!("{:.1}%", rep.net_util_inter * 100.0),
        ]);
    }
    t.print();

    let mut h = Table::new(
        "congestion delay histogram (shared links)",
        &["bucket", "flows"],
    );
    for (label, count) in CongestionStats::bucket_labels().iter().zip(on.congestion.hist.iter()) {
        h.row(&[label.to_string(), count.to_string()]);
    }
    h.print();

    println!("microbench sweep (migration rate x message size x fabric):\n");
    yalis::coordinator::experiments::sweep_contention(16).print();
}
