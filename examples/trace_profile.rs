//! Simulator-native tracing: run a traced serving simulation, emit the
//! Chrome/Perfetto trace + lifecycle + time-series artifacts, and fold
//! the event stream back into the per-GPU breakdown to show it agrees
//! with the analytic accumulator (the Nsight/Pipit loop of ISSUE 6).
//!
//! Usage: cargo run --release --example trace_profile --
//!        [--spec tp16] [--allreduce nvrar] [--prompts 150] [--conc 64]
//!        [--gpus 16] [--out results/trace_profile]
//!
//! Open the `.trace.json` at <https://ui.perfetto.dev> (drag & drop).

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::collectives::AllReduceImpl;
use yalis::obs::{self, fold, Recorder, RunMeta};
use yalis::parallel::ParallelSpec;
use yalis::serving::{fig9_config, serve};
use yalis::trace::TraceSpec;
use yalis::util::cli::Cli;
use yalis::util::tables::Table;

fn main() {
    let mut cli = Cli::new("trace_profile", "traced serving run + Perfetto artifacts");
    cli.opt("spec", "tp16", "parallelism spec (e.g. tp16, tp4-pp4)");
    cli.opt("allreduce", "nvrar", "all-reduce impl (nccl|nccl-ring|nccl-tree|mpi|nvrar)");
    cli.opt("prompts", "150", "number of BurstGPT prompts");
    cli.opt("conc", "64", "serving concurrency");
    cli.opt("gpus", "16", "GPU count");
    cli.opt("out", "results/trace_profile", "artifact base path");
    let args = cli.parse();

    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = args.get_usize("prompts");
    let reqs = spec.generate();

    let gpus = args.get_usize("gpus");
    let pspec = args.get_with("spec", |s| {
        let p = ParallelSpec::by_name(s)?;
        p.validate(&yalis::cluster::presets::perlmutter(1).with_gpus(gpus))?;
        Ok::<_, anyhow::Error>(p)
    });
    let ar = args.get_with("allreduce", AllReduceImpl::by_name);

    let mut cfg = fig9_config(pspec, ar, args.get_usize("conc"), "perlmutter", gpus);
    let sink = Recorder::sink(RunMeta {
        seed: Some(spec.seed),
        machine: "perlmutter".to_string(),
        ..RunMeta::default()
    });
    cfg.obs = Some(sink.clone());
    let rep = serve(&cfg, &reqs);

    let rec = sink.lock().expect("obs lock poisoned");
    match obs::write_artifacts(args.get("out"), &rec) {
        Ok(paths) => {
            for p in paths {
                println!("-> {p}");
            }
        }
        Err(e) => eprintln!("artifact write failed: {e}"),
    }

    // Close the loop: the trace alone reproduces the analytic breakdown.
    let bd = rep.breakdown.expect("tracing was enabled");
    let folded = fold::fold_breakdowns(&rec);
    let drift = fold::reconcile(&[bd], &folded, rec.makespan());
    let mut t = Table::new(
        &format!("{} traced run: {} spans, {} instants", cfg.deployment_label(), rec.spans().len(), rec.instants().len()),
        &["source", "matmul", "other", "comm", "idle", "total"],
    );
    let mut analytic = vec!["analytic".to_string()];
    analytic.extend(bd.row_cells());
    t.row(&analytic);
    if let Some(f) = folded.get(&cfg.net_scope) {
        let mut cells = vec!["event fold".to_string()];
        cells.extend(f.row_cells());
        t.row(&cells);
    }
    t.print();
    println!("fold-vs-analytic max drift: {drift:.2e} s (contract: < 1e-6)");
    println!(
        "serve: {:.1} tok/s over {:.1}s makespan, TTFT p50 {:.2}s",
        rep.output_throughput, rep.makespan, rep.ttft_p50
    );
}
