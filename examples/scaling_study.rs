//! Strong-scaling study (Figures 1/2/11 from the CLI): sweep engines ×
//! parallelism schemes × GPU counts for a model and print the
//! time-to-completion table.
//!
//! Usage: cargo run --release --example scaling_study -- [--model 70b]
//!        [--csv results/scaling.csv]

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::coordinator::experiments;
use yalis::util::cli::Cli;

fn main() {
    let mut cli = Cli::new("scaling_study", "Figs 1/2/11 strong-scaling sweep");
    cli.opt("model", "70b", "model (70b|405b)");
    cli.opt("csv", "", "also write CSV files with this prefix");
    let args = cli.parse();

    let tables = experiments::fig1_fig2_scaling(args.get("model"));
    for (i, t) in tables.iter().enumerate() {
        t.print();
        if !args.get("csv").is_empty() {
            let path = format!("{}.{}.csv", args.get("csv"), i);
            t.write_csv(&path).expect("csv");
            println!("-> {path}");
        }
    }
}
