//! Multi-turn session serving: drive a `SessionSpec` conversation trace
//! (turns of one chat re-send the growing conversation prefix) through a
//! replica fleet and compare routing policies. Session-affinity routing is
//! prefix-cache-aware — arrivals probe each replica's shared-prefix KV
//! cache and land where their conversation's pages live — so it reports a
//! high cache hit rate and a tighter TTFT than content-blind policies,
//! while single-shot traces (`--turns 1`) show zero hits by construction.
//!
//! Usage: cargo run --release --example session_serve --
//!        [--sessions 200] [--turns 6] [--prefix 1500] [--followup 80]
//!        [--output 150] [--think 30] [--rate 2] [--replicas 3]
//!        [--conc 64] [--allreduce nvrar]
//!        [--policies round-robin,least-tokens,kv-pressure,session-affinity]

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::collectives::AllReduceImpl;
use yalis::fleet::router::RoutePolicy;
use yalis::fleet::{run_fleet, FleetConfig};
use yalis::parallel::ParallelSpec;
use yalis::serving::fig9_config;
use yalis::trace::{resend_fraction, LenDist, SessionSpec};
use yalis::util::cli::Cli;
use yalis::util::tables::Table;

fn main() {
    let mut cli = Cli::new("session_serve", "multi-turn shared-prefix session serving study");
    cli.opt("sessions", "200", "concurrent conversations");
    cli.opt("turns", "6", "request turns per conversation");
    cli.opt("prefix", "1500", "median opening-prompt tokens (the shared prefix seed)");
    cli.opt("followup", "80", "median fresh user tokens per later turn");
    cli.opt("output", "150", "median response tokens per turn");
    cli.opt("think", "30", "mean think time between turns (s)");
    cli.opt("rate", "2", "session arrival rate (sessions/s)");
    cli.opt("seed", "0", "trace seed override (0 = default)");
    cli.opt("replicas", "3", "fleet replicas (70B tp16 each)");
    cli.opt("conc", "64", "per-replica max concurrency");
    cli.opt("allreduce", "nvrar", "per-replica all-reduce (nccl|nccl-ring|nccl-tree|mpi|nvrar)");
    cli.opt(
        "policies",
        "least-tokens,session-affinity",
        "routing policies to sweep",
    );
    let args = cli.parse();

    let mut sspec = SessionSpec::standard();
    sspec.sessions = args.get_usize("sessions");
    sspec.turns = args.get_usize("turns");
    sspec.first_prompt.median = args.get_f64("prefix");
    sspec.followup.median = args.get_f64("followup");
    sspec.output.median = args.get_f64("output");
    sspec.think = args.get_f64("think");
    sspec.rate = args.get_f64("rate");
    if args.get_u64("seed") != 0 {
        sspec.seed = args.get_u64("seed");
    }
    // Keep the wide tails reachable when the medians are cranked up.
    sspec.first_prompt = LenDist { max: 32_768, ..sspec.first_prompt };
    let reqs = sspec.generate();
    println!(
        "trace: {} sessions x {} turns = {} requests, resend fraction {:.0}% \
         (the prefix cache's upper bound)",
        sspec.sessions,
        sspec.turns,
        reqs.len(),
        resend_fraction(&reqs) * 100.0,
    );

    let ar = args.get_with("allreduce", AllReduceImpl::by_name);
    let policies: Vec<RoutePolicy> = args.get_list_with("policies", RoutePolicy::by_name);
    let base = fig9_config(
        ParallelSpec::tp(16),
        ar,
        args.get_usize("conc"),
        "perlmutter",
        16,
    );
    let replicas = args.get_usize("replicas");

    let mut t = Table::new(
        &format!(
            "session serving: {replicas}x{} replicas, {} sessions x {} turns",
            base.deployment_label(),
            sspec.sessions,
            sspec.turns
        ),
        &[
            "policy", "tok/s", "goodput", "TTFT p50", "TTFT p99", "TPOT p50", "hit %",
            "saved tok", "SLO %",
        ],
    );
    for &policy in &policies {
        let cfg = FleetConfig::new(base.clone(), replicas).with_policy(policy);
        let rep = run_fleet(&cfg, &reqs);
        t.row(&[
            policy.name().to_string(),
            format!("{:.1}", rep.throughput),
            format!("{:.1}", rep.goodput),
            format!("{:.3}", rep.ttft_p50),
            format!("{:.3}", rep.ttft_p99),
            format!("{:.4}", rep.tpot_p50),
            format!("{:.0}%", rep.cache_hit_rate * 100.0),
            rep.cached_tokens.to_string(),
            format!("{:.0}%", rep.slo_attainment * 100.0),
        ]);
    }
    t.print();
    t.write_csv("results/session_serve.csv").unwrap();
    println!("-> results/session_serve.csv");
}
