//! Fleet serving at scale: drive a BurstGPT-style trace (100k+ requests by
//! default, `--prompts 1000000` for the million-request run) through a
//! multi-replica fleet under every routing policy, monolithic vs
//! disaggregated prefill/decode pools, and report p50/p95/p99 TTFT, TPOT,
//! and SLO goodput per configuration. Deterministic for a fixed `--seed`.
//!
//! Replicas are named by `ParallelSpec` with a count, so heterogeneous
//! fleets are one flag: `--specs tp16:2,tp8:2` mixes TP16 and TP8 replicas
//! (each spec's GPU count is implied by the spec itself) and the
//! cost-aware router loads them in proportion to predicted step time.
//!
//! Usage: cargo run --release --example fleet_serve --
//!        [--trace burstgpt|decode-heavy|long-prompt] [--prompts 100000]
//!        [--rate 40] [--specs tp16:4] [--prefill 1] [--conc 256]
//!        [--allreduce nvrar] [--chunk-tokens 0]
//!        [--policies round-robin,least-tokens,kv-pressure,session-affinity]
//!        [--slo-ttft 5.0] [--slo-tpot 0.2] [--ramp 0] [--autoscale]

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::collectives::AllReduceImpl;
use yalis::fleet::autoscaler::AutoscaleConfig;
use yalis::fleet::metrics::{FleetReport, SloTargets};
use yalis::fleet::router::RoutePolicy;
use yalis::fleet::{run_fleet, FleetConfig};
use yalis::parallel::ParallelSpec;
use yalis::serving::{fig9_config, ServeConfig};
use yalis::trace::{RateShape, TraceSpec};
use yalis::util::cli::Cli;
use yalis::util::tables::Table;

fn main() {
    let mut cli = Cli::new("fleet_serve", "multi-replica SLO-aware fleet serving study");
    cli.opt("trace", "burstgpt", "trace kind (burstgpt|decode-heavy|long-prompt)");
    cli.opt("prompts", "100000", "number of requests");
    cli.opt("rate", "40", "mean arrival rate (req/s) across the fleet");
    cli.opt("seed", "0", "trace seed override (0 = trace default)");
    cli.opt("specs", "tp16:4", "replica specs with counts, e.g. tp16:2,tp8:2");
    cli.opt("prefill", "1", "prefill replicas for the disaggregated rows");
    cli.opt("conc", "256", "per-replica max concurrency");
    cli.opt("chunk-tokens", "0", "per-replica prefill chunk cap (0 = budget-bounded chunks)");
    cli.opt("allreduce", "nvrar", "per-replica all-reduce (nccl|nccl-ring|nccl-tree|mpi|nvrar)");
    cli.opt("policies", "round-robin,least-tokens,kv-pressure,session-affinity", "routing policies to sweep");
    cli.opt("slo-ttft", "5.0", "TTFT SLO target (s)");
    cli.opt("slo-tpot", "0.2", "TPOT SLO target (s)");
    cli.opt("ramp", "0", "rate ramp end-multiplier (0 = flat trace)");
    cli.flag("autoscale", "enable the SLO-driven autoscaler");
    let args = cli.parse();

    let ar = args.get_with("allreduce", AllReduceImpl::by_name);
    let conc = args.get_usize("conc");
    // Expand `tp16:2,tp8:2` into `(spec, count)` entries; each spec's GPU
    // count is its own tp·pp·dp. Validation happens here so an invalid
    // spec prints a usable error instead of panicking in fig9_config.
    let node = yalis::cluster::presets::perlmutter(1);
    let entries: Vec<(ParallelSpec, usize)> = args.get_list_with("specs", |entry| {
        let (name, count) = match entry.split_once(':') {
            Some((n, c)) => (
                n.trim(),
                c.trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad replica count in '{entry}'"))?,
            ),
            None => (entry, 1),
        };
        let spec = ParallelSpec::by_name(name)?;
        if spec.ep > 1 {
            anyhow::bail!("spec {spec} is expert-parallel but this example serves the dense 70B model");
        }
        let gpus = spec.gpus();
        if gpus > node.gpus_per_node && gpus % node.gpus_per_node != 0 {
            anyhow::bail!(
                "spec {spec} needs {gpus} GPUs, not a multiple of {}/node",
                node.gpus_per_node
            );
        }
        spec.validate(&node.with_gpus(gpus))?;
        Ok::<_, anyhow::Error>((spec, count))
    });
    let mut pool: Vec<ServeConfig> = Vec::new();
    let mut pool_label = Vec::new();
    for (spec, count) in entries {
        let mut cfg = fig9_config(spec, ar, conc, "perlmutter", spec.gpus());
        cfg.chunk_tokens = args.get_usize("chunk-tokens");
        pool_label.push(format!("{}x{}", count, cfg.deployment_label()));
        for _ in 0..count {
            pool.push(cfg.clone());
        }
    }
    if pool.is_empty() {
        eprintln!("error: --specs expanded to zero replicas");
        std::process::exit(2);
    }
    let policies: Vec<RoutePolicy> = args.get_list_with("policies", RoutePolicy::by_name);

    let mut spec = match args.get("trace") {
        "burstgpt" => TraceSpec::burstgpt(),
        "decode-heavy" => TraceSpec::decode_heavy(),
        "long-prompt" => TraceSpec::long_prompt(),
        other => {
            eprintln!(
                "error: unknown trace '{other}' (expected burstgpt|decode-heavy|long-prompt)"
            );
            std::process::exit(2);
        }
    };
    spec.num_prompts = args.get_usize("prompts");
    spec.rate = args.get_f64("rate");
    if args.get_u64("seed") != 0 {
        spec.seed = args.get_u64("seed");
    }
    let ramp = args.get_f64("ramp");
    if ramp > 0.0 {
        spec.shape = RateShape::Ramp { from: 1.0, to: ramp };
    }
    let reqs = spec.generate();
    println!(
        "trace: {} requests at ~{:.0} req/s (mean in {:.0} / out {:.0} tokens, {:.0}s span)",
        reqs.len(),
        spec.rate,
        reqs.iter().map(|r| r.prompt_len).sum::<usize>() as f64 / reqs.len() as f64,
        reqs.iter().map(|r| r.decode_len).sum::<usize>() as f64 / reqs.len() as f64,
        reqs.last().map(|r| r.arrival).unwrap_or(0.0),
    );

    let slo = SloTargets { ttft: args.get_f64("slo-ttft"), tpot: args.get_f64("slo-tpot") };
    let prefill = args.get_usize("prefill");

    let mut t = Table::new(
        &format!(
            "fleet serving: {} replicas (70B, {} trace)",
            pool_label.join(" + "),
            args.get("trace"),
        ),
        &[
            "policy", "pools", "tok/s", "goodput", "SLO %", "TTFT p50", "TTFT p95", "TTFT p99",
            "TPOT p50", "TPOT p95", "TPOT p99", "peak rep", "handoff GB", "preempts", "rejects",
        ],
    );
    for &policy in &policies {
        for disagg in [false, true] {
            if disagg && (prefill == 0 || pool.len() <= prefill) {
                continue;
            }
            // Keep total replica count comparable: the disaggregated rows
            // carve the prefill pool out of the same fleet size.
            let mut cfg = if disagg {
                FleetConfig::heterogeneous(pool[prefill..].to_vec())
                    .with_prefill_pool(pool[..prefill].to_vec())
            } else {
                FleetConfig::heterogeneous(pool.clone())
            };
            cfg = cfg.with_policy(policy).with_slo(slo);
            if args.get_flag("autoscale") {
                cfg = cfg.with_autoscale(AutoscaleConfig::default());
            }
            let rep = run_fleet(&cfg, &reqs);
            let pools = if disagg {
                format!("{}D+{}P", pool.len() - prefill, prefill)
            } else {
                format!("{} mono", pool.len())
            };
            t.row(&row_cells(policy, &pools, &rep));
        }
    }
    t.print();
    t.write_csv("results/fleet_serve.csv").unwrap();
    println!("-> results/fleet_serve.csv");
}

fn row_cells(policy: RoutePolicy, pools: &str, r: &FleetReport) -> Vec<String> {
    vec![
        policy.name().to_string(),
        pools.to_string(),
        format!("{:.1}", r.throughput),
        format!("{:.1}", r.goodput),
        format!("{:.1}%", r.slo_attainment * 100.0),
        format!("{:.3}", r.ttft_p50),
        format!("{:.3}", r.ttft_p95),
        format!("{:.3}", r.ttft_p99),
        format!("{:.4}", r.tpot_p50),
        format!("{:.4}", r.tpot_p95),
        format!("{:.4}", r.tpot_p99),
        r.peak_replicas.to_string(),
        format!("{:.1}", r.handoff_gb),
        r.preemptions.to_string(),
        r.rejected.to_string(),
    ]
}
