//! Quickstart: load the AOT artifacts and greedily decode a few tokens
//! with the full (unsharded) model — the smallest possible end-to-end use
//! of the library. Build artifacts first: `make artifacts`.
//!
//! Usage: cargo run --release --example quickstart -- [--steps 32]

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::runtime::tensor::argmax_rows;
use yalis::runtime::tp::TpRuntime;
use yalis::util::cli::Cli;
use yalis::util::rng::Rng;
use yalis::util::stats::fmt_time;

fn main() -> anyhow::Result<()> {
    let mut cli = Cli::new("quickstart", "load artifacts, prefill, decode greedily");
    cli.opt("artifacts", "artifacts", "artifacts directory");
    cli.opt("steps", "32", "decode steps");
    let args = cli.parse();

    let mut rt = TpRuntime::load(args.get("artifacts"))?;
    println!(
        "{}: {} layers, d_model {}, vocab {} (~{:.0}M params)",
        "tiny-llama",
        rt.dims.n_layers,
        rt.dims.d_model,
        rt.dims.vocab,
        85.8,
    );

    // A deterministic synthetic prompt (vocabulary is synthetic ids).
    let mut rng = Rng::new(7);
    let prompt: Vec<i32> = (0..rt.dims.batch * rt.dims.prompt)
        .map(|_| rng.usize(0, rt.dims.vocab - 1) as i32)
        .collect();

    // lint: allow(D03) real wall-clock timing of the host runtime
    let t0 = std::time::Instant::now();
    let mut logits = rt.prefill(&prompt)?;
    println!("prefill: {}", fmt_time(t0.elapsed().as_secs_f64()));

    let steps = args.get_usize("steps");
    let b = rt.dims.batch;
    // lint: allow(D03) real wall-clock timing of the host runtime
    let t1 = std::time::Instant::now();
    let mut tokens_out: Vec<Vec<i32>> = vec![Vec::new(); b];
    for _ in 0..steps {
        if rt.pos + 1 >= rt.dims.max_seq {
            break;
        }
        let toks = argmax_rows(&logits, b);
        for (seq, t) in toks.iter().enumerate() {
            tokens_out[seq].push(*t);
        }
        logits = rt.decode_step_full(&toks)?;
        // decode_step_full is the oracle path; advance pos manually.
        rt.pos += 1;
    }
    let dt = t1.elapsed().as_secs_f64();
    for (seq, toks) in tokens_out.iter().enumerate() {
        let head: Vec<String> = toks.iter().take(12).map(|t| t.to_string()).collect();
        println!("seq{}: {} ...", seq, head.join(" "));
    }
    let n: usize = tokens_out.iter().map(|t| t.len()).sum();
    println!("decoded {} tokens in {} ({:.2} tok/s)", n, fmt_time(dt), n as f64 / dt);
    Ok(())
}
