"""Binary weight export for the rust runtime.

Format ``YWT1`` (little-endian throughout):

    magic   b"YWT1"
    count   u32                      number of tensors
    repeat count times:
      name_len u32, name bytes (utf-8)
      dtype    u8                    0 = f32, 1 = i32
      ndim     u8
      dims     u32 * ndim
      data     raw LE payload (prod(dims) * 4 bytes)

The rust loader is ``rust/src/runtime/weights.rs``; keep the two in sync.
"""

import struct

import numpy as np

MAGIC = b"YWT1"
_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_weights(path: str, tensors: dict) -> None:
    """Write a name -> ndarray mapping in YWT1 format."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.asarray(arr)
            if arr.dtype not in _DTYPES:
                arr = arr.astype(np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(np.ascontiguousarray(arr).tobytes())


def read_weights(path: str) -> dict:
    """Inverse of write_weights (used by tests)."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            dt, nd = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd)) if nd else ()
            n = int(np.prod(dims)) if nd else 1
            dtype = np.float32 if dt == 0 else np.int32
            out[name] = np.frombuffer(f.read(4 * n), dtype).reshape(dims)
    return out
