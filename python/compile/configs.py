"""Model configurations for the AOT (Layer-2) path.

Only the *tiny* config is compiled to artifacts and executed by the rust
runtime; the paper-scale Llama 3.1 / Qwen3 configs live in the rust
``models`` module where they drive the analytic performance model. The tiny
config is a faithful Llama-style architecture (RMSNorm, RoPE, GQA, SwiGLU)
at ~85M parameters so the end-to-end example can actually decode on CPU.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    ffn: int
    max_seq: int          # static KV-cache length baked into the artifacts
    rope_theta: float = 10000.0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        per_layer = (
            self.d_model * self.q_dim          # wq
            + 2 * self.d_model * self.kv_dim   # wk, wv
            + self.q_dim * self.d_model        # wo
            + 3 * self.d_model * self.ffn      # wg, wu, wd
            + 2 * self.d_model                 # norms
        )
        return (
            self.n_layers * per_layer
            + 2 * self.vocab * self.d_model    # embed + lm_head
            + self.d_model                     # final norm
        )

    def validate_tp(self, shards: int) -> None:
        if self.n_heads % shards or self.n_kv_heads % shards or self.ffn % shards:
            raise ValueError(
                f"{self.name}: heads={self.n_heads}/kv={self.n_kv_heads}/"
                f"ffn={self.ffn} not divisible by TP={shards}")


# ~85M parameters; GQA 12 query heads over 4 KV heads like Llama-3-family
# ratios; dims chosen so MXU-shaped 128-tiles divide every GEMM dimension.
TINY = ModelConfig(
    name="tiny-llama-85m",
    vocab=4096,
    d_model=768,
    n_layers=12,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    ffn=2048,
    max_seq=256,
)
