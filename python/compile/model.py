"""Layer-2: Llama-style decoder in JAX, calling the Layer-1 Pallas kernels.

Two execution forms are lowered to HLO artifacts (see ``aot.py``):

1. **Full (unsharded)** — ``prefill_full`` / ``decode_full``: one graph per
   phase over stacked layer weights (lax.scan), used by the quickstart and
   as the numeric oracle for the sharded path.
2. **TP-sharded segments** — ``attn_shard`` / ``mlp_shard`` (+ ``embed_fn``
   / ``head_fn``): shard *s* computes its head / FFN partition up to the
   partial o_proj / down_proj output, exactly the point where Megatron-style
   TP inserts its all-reduce. The all-reduce is deliberately **lifted out of
   the graph**: the rust coordinator sums shard partials with the real NVRAR
   implementation, making the rust binary own the paper's communication hot
   path (message size = B x H, the paper's §3.5 decode regime).

The MLP projections go through the Pallas ``matmul`` kernel so the L1 kernel
lowers into the same HLO module (and its tile quantization is real); the
attention einsums stay in jnp (they are not the paper's focus).

KV caches have a static ``max_seq`` length; decode writes at position ``pos``
via dynamic_update_slice and masks attention to ``<= pos`` — the CUDA-graph
style fixed-shape step the paper's YALIS uses.

Cache layout is ``(B, T, n_kv * head_dim)`` with the KV-head index major in
the last axis, so TP shard *s*'s cache slice is a contiguous range of the
last dimension (the rust coordinator slices prefill caches per shard).
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import matmul


# ---------------------------------------------------------------------------
# Parameter initialisation / sharding
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """Deterministic random init; layer weights stacked on axis 0."""
    k = jax.random.split(key, 12)
    d, q, kv, f, L, V = (cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.ffn,
                         cfg.n_layers, cfg.vocab)

    def w(key, *shape):
        fan_in = shape[-2] if len(shape) > 1 else shape[-1]
        return (jax.random.normal(key, shape, jnp.float32)
                * (1.0 / jnp.sqrt(fan_in)))

    return {
        "embed": w(k[0], V, d),
        "attn_norm": jnp.ones((L, d), jnp.float32),
        "wq": w(k[1], L, d, q),
        "wk": w(k[2], L, d, kv),
        "wv": w(k[3], L, d, kv),
        "wo": w(k[4], L, q, d),
        "mlp_norm": jnp.ones((L, d), jnp.float32),
        "wg": w(k[5], L, d, f),
        "wu": w(k[6], L, d, f),
        "wd": w(k[7], L, f, d),
        "final_norm": jnp.ones((d,), jnp.float32),
        "lm_head": w(k[8], d, V),
    }


def shard_layer_params(params: dict, cfg: ModelConfig, layer: int,
                       shard: int, shards: int) -> dict:
    """Slice layer ``layer``'s weights for TP shard ``shard`` of ``shards``.

    Query heads, KV heads, and FFN columns are partitioned; wo/wd rows are
    partitioned correspondingly so each shard emits a *partial* output whose
    sum over shards equals the full layer output.
    """
    cfg.validate_tp(shards)
    hs, kvs, fs = (cfg.n_heads // shards, cfg.n_kv_heads // shards,
                   cfg.ffn // shards)
    dh = cfg.head_dim
    qa, qb = shard * hs * dh, (shard + 1) * hs * dh
    ka, kb = shard * kvs * dh, (shard + 1) * kvs * dh
    fa, fb = shard * fs, (shard + 1) * fs
    return {
        "attn_norm": params["attn_norm"][layer],
        "wq": params["wq"][layer][:, qa:qb],
        "wk": params["wk"][layer][:, ka:kb],
        "wv": params["wv"][layer][:, ka:kb],
        "wo": params["wo"][layer][qa:qb, :],
        "mlp_norm": params["mlp_norm"][layer],
        "wg": params["wg"][layer][:, fa:fb],
        "wu": params["wu"][layer][:, fa:fb],
        "wd": params["wd"][layer][fa:fb, :],
    }


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def _rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, T, H, dh); positions: (T,)."""
    dh = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (T, dh/2)
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    ro = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return ro.reshape(x.shape)


def _mlp(x2d: jax.Array, wg, wu, wd, use_pallas: bool) -> jax.Array:
    """SwiGLU MLP over flattened (tokens, d) input, via the Pallas kernel.

    Perf pass (§Perf / EXPERIMENTS.md): interpret=True lowers the Pallas
    grid to a serial HLO while-loop, so on the CPU execution path we size
    blocks to cover whole dimensions (grid ≈ 1 — the kernel body becomes a
    single fused dot). On a real TPU the MXU-tile defaults (128³) apply;
    the tiling choice is a BlockSpec parameter, not a kernel rewrite.
    """
    from .kernels.matmul import _pick_block

    def mm_pallas(a, b):
        (m, k), n = a.shape, b.shape[1]
        return matmul(a, b, bm=_pick_whole(m), bn=_pick_whole(n), bk=_pick_whole(k))

    def _pick_whole(dim, cap=2048):
        if dim <= cap:
            return dim
        return _pick_block(dim, cap=cap)

    mm = mm_pallas if use_pallas else (
        lambda a, b: jnp.dot(a, b, preferred_element_type=jnp.float32))
    gate = mm(x2d, wg)
    up = mm(x2d, wu)
    return mm(jax.nn.silu(gate) * up, wd)


def _attention(q, k, v, mask):
    """q: (B,Tq,H,dh); k,v: (B,Tk,KV,dh); mask: (Tq,Tk) bool."""
    b, tq, h, dh = q.shape
    kv = k.shape[2]
    rep = h // kv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(dh))
    scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.reshape(b, tq, h * dh)


# ---------------------------------------------------------------------------
# TP-sharded segments (one graph each; rust composes them per layer)
# ---------------------------------------------------------------------------

def embed_fn(tokens: jax.Array, embed: jax.Array) -> jax.Array:
    """tokens i32(B,) -> hidden f32(B, d)."""
    return embed[tokens]


def attn_shard(cfg: ModelConfig, shards: int, x, norm_w, wq, wk, wv, wo,
               k_cache, v_cache, pos, use_pallas: bool = False):
    """One decode step of shard *s*'s attention partition for one layer.

    x: (B, d) residual-stream input (pre-norm, full — TP replicates it).
    k_cache/v_cache: (B, max_seq, kv_s * dh) this shard's cache slice.
    pos: i32 scalar — index of the token being decoded.

    Returns (partial_out (B, d), k_cache', v_cache'); sum of partial_out
    over shards == the full layer's attention output (pre-residual).
    """
    b, d = x.shape
    dh = cfg.head_dim
    hs = cfg.n_heads // shards
    kvs = cfg.n_kv_heads // shards
    h = rmsnorm(x, norm_w)
    q = (h @ wq).reshape(b, 1, hs, dh)
    kk = (h @ wk).reshape(b, 1, kvs, dh)
    vv = (h @ wv).reshape(b, 1, kvs, dh)
    posv = pos[None].astype(jnp.int32) if pos.ndim == 0 else pos
    q = _rope(q, posv, cfg.rope_theta)
    kk = _rope(kk, posv, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, kk.reshape(b, 1, kvs * dh), (0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, vv.reshape(b, 1, kvs * dh), (0, pos, 0))
    t = cfg.max_seq
    mask = (jnp.arange(t) <= pos)[None, :]                  # (1, T)
    attn = _attention(q,
                      k_cache.reshape(b, t, kvs, dh),
                      v_cache.reshape(b, t, kvs, dh), mask)  # (B,1,hs*dh)
    partial = attn.reshape(b, hs * dh) @ wo
    return partial, k_cache, v_cache


def mlp_shard(cfg: ModelConfig, shards: int, x, norm_w, wg, wu, wd,
              use_pallas: bool = True):
    """Shard *s*'s SwiGLU partition; sum over shards == full MLP output."""
    h = rmsnorm(x, norm_w)
    return _mlp(h, wg, wu, wd, use_pallas)


def head_fn(x, final_norm, lm_head):
    """(B, d) -> logits (B, V)."""
    return rmsnorm(x, final_norm) @ lm_head


# ---------------------------------------------------------------------------
# Full (unsharded) model — scan over stacked layer weights
# ---------------------------------------------------------------------------

def _layer_weights(params):
    return {k: params[k] for k in
            ("attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "wg", "wu", "wd")}


def decode_full(cfg: ModelConfig, params: dict, token, pos, k_caches,
                v_caches, use_pallas: bool = False):
    """One full-model decode step.

    token: i32 (B,); pos: i32 scalar; caches: (L, B, max_seq, kv*dh).
    Returns (logits (B, V), k_caches', v_caches').
    """
    b = token.shape[0]
    dh, kvh, hq, t = cfg.head_dim, cfg.n_kv_heads, cfg.n_heads, cfg.max_seq
    x = embed_fn(token, params["embed"])
    posv = pos[None].astype(jnp.int32)
    mask = (jnp.arange(t) <= pos)[None, :]

    def step(x, layer):
        w, kc, vc = layer
        h = rmsnorm(x, w["attn_norm"])
        q = _rope((h @ w["wq"]).reshape(b, 1, hq, dh), posv, cfg.rope_theta)
        kk = _rope((h @ w["wk"]).reshape(b, 1, kvh, dh), posv, cfg.rope_theta)
        vv = (h @ w["wv"]).reshape(b, 1, kvh, dh)
        kc = jax.lax.dynamic_update_slice(kc, kk.reshape(b, 1, kvh * dh),
                                          (0, pos, 0))
        vc = jax.lax.dynamic_update_slice(vc, vv.reshape(b, 1, kvh * dh),
                                          (0, pos, 0))
        attn = _attention(q, kc.reshape(b, t, kvh, dh),
                          vc.reshape(b, t, kvh, dh), mask)
        x = x + attn.reshape(b, hq * dh) @ w["wo"]
        x = x + _mlp(rmsnorm(x, w["mlp_norm"]), w["wg"], w["wu"], w["wd"],
                     use_pallas)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        step, x, (_layer_weights(params), k_caches, v_caches))
    return head_fn(x, params["final_norm"], params["lm_head"]), k_new, v_new


def prefill_full(cfg: ModelConfig, params: dict, tokens,
                 use_pallas: bool = False):
    """Process a (B, T0) prompt; return last-position logits + padded caches.

    Caches come back as (L, B, max_seq, kv*dh) with rows [0, T0) filled, so
    decode can continue at pos = T0.
    """
    b, t0 = tokens.shape
    dh, kvh, hq, t = cfg.head_dim, cfg.n_kv_heads, cfg.n_heads, cfg.max_seq
    x = params["embed"][tokens]                       # (B, T0, d)
    positions = jnp.arange(t0)
    mask = jnp.tril(jnp.ones((t0, t0), bool))

    def step(x, w):
        h = rmsnorm(x, w["attn_norm"])
        q = _rope((h @ w["wq"]).reshape(b, t0, hq, dh), positions,
                  cfg.rope_theta)
        kk = _rope((h @ w["wk"]).reshape(b, t0, kvh, dh), positions,
                   cfg.rope_theta)
        vv = (h @ w["wv"]).reshape(b, t0, kvh, dh)
        attn = _attention(q, kk, vv, mask)
        x = x + attn @ w["wo"]
        h2 = rmsnorm(x, w["mlp_norm"])
        x = x + _mlp(h2.reshape(b * t0, -1), w["wg"], w["wu"], w["wd"],
                     use_pallas).reshape(b, t0, -1)
        kpad = jnp.zeros((b, t, kvh * dh), jnp.float32)
        kpad = jax.lax.dynamic_update_slice(
            kpad, kk.reshape(b, t0, kvh * dh), (0, 0, 0))
        vpad = jnp.zeros((b, t, kvh * dh), jnp.float32)
        vpad = jax.lax.dynamic_update_slice(
            vpad, vv.reshape(b, t0, kvh * dh), (0, 0, 0))
        return x, (kpad, vpad)

    x, (k_caches, v_caches) = jax.lax.scan(step, x, _layer_weights(params))
    logits = head_fn(x[:, -1, :], params["final_norm"], params["lm_head"])
    return logits, k_caches, v_caches


# ---------------------------------------------------------------------------
# Reference composition of the sharded path (used by tests; rust mirrors it)
# ---------------------------------------------------------------------------

def decode_sharded_reference(cfg: ModelConfig, params: dict, shards: int,
                             token, pos, k_caches, v_caches,
                             use_pallas: bool = False):
    """Python mirror of the rust per-layer shard + all-reduce composition.

    caches: (L, S, B, max_seq, kv_s*dh) per-shard slices. Returns logits and
    updated caches. Must match ``decode_full`` to f32 tolerance — this is
    the contract the rust e2e example asserts via real NVRAR.
    """
    x = embed_fn(token, params["embed"])
    new_k, new_v = [], []
    for layer in range(cfg.n_layers):
        partials, ks, vs = [], [], []
        for s in range(shards):
            w = shard_layer_params(params, cfg, layer, s, shards)
            p, kc, vc = attn_shard(cfg, shards, x, w["attn_norm"], w["wq"],
                                   w["wk"], w["wv"], w["wo"],
                                   k_caches[layer, s], v_caches[layer, s],
                                   pos, use_pallas)
            partials.append(p); ks.append(kc); vs.append(vc)
        x = x + sum(partials)                         # <- the TP all-reduce
        partials = []
        for s in range(shards):
            w = shard_layer_params(params, cfg, layer, s, shards)
            partials.append(mlp_shard(cfg, shards, x, w["mlp_norm"],
                                      w["wg"], w["wu"], w["wd"], use_pallas))
        x = x + sum(partials)                         # <- the TP all-reduce
        new_k.append(jnp.stack(ks)); new_v.append(jnp.stack(vs))
    logits = head_fn(x, params["final_norm"], params["lm_head"])
    return logits, jnp.stack(new_k), jnp.stack(new_v)
