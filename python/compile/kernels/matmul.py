"""Tiled matmul Pallas kernel (Layer 1).

The kernel is written the way a TPU MXU matmul is tiled: a 3-D grid over
(M/bm, N/bn, K/bk); each (i, j) output tile lives in VMEM across the K
sweep and accumulates partial products in f32. ``interpret=True`` lowers it
to plain HLO so the rust CPU-PJRT client can run the surrounding graph.

Block-size selection mirrors CUDA tile quantization (Table 4 of the paper):
an M smaller than the M-tile cannot shrink the tile count, which is exactly
why decode GEMMs (M = batch) do not speed up when M is halved. We pick the
largest hardware-shaped tile that divides each dimension so the same
quantization behaviour is visible in the kernel's grid.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped preferred tile sizes, largest first. 128 matches both the MXU
# systolic array edge and the f32 VMEM-friendly tile used throughout the
# paper's GEMM discussion.
_PREFERRED = (128, 64, 32, 16, 8, 4, 2, 1)


def _pick_block(dim: int, cap: int = 128) -> int:
    """Largest preferred tile <= cap that divides ``dim``."""
    for b in _PREFERRED:
        if b <= cap and dim % b == 0:
            return b
    return 1


def _matmul_kernel(x_ref, y_ref, o_ref, *, k_steps: int):
    """One (i, j, k) grid step: o[i,j] (+)= x[i,k] @ y[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.named_call, name="pallas_matmul")
def matmul(x: jax.Array, y: jax.Array, *, bm: int | None = None,
           bn: int | None = None, bk: int | None = None) -> jax.Array:
    """``x @ y`` via the tiled Pallas kernel.

    Args:
      x: f32[M, K]
      y: f32[K, N]
      bm/bn/bk: optional tile overrides (must divide M/N/K). Defaults pick
        the largest MXU-shaped tile dividing each dim.

    Returns:
      f32[M, N]
    """
    (m, k), (k2, n) = x.shape, y.shape
    if k != k2:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {y.shape}")
    bm = bm or _pick_block(m)
    bn = bn or _pick_block(n)
    bk = bk or _pick_block(k, cap=256)
    # Whole-dimension blocks are always legal (grid extent 1 on that axis).
    if m % bm or n % bn or k % bk:
        raise ValueError(f"tiles ({bm},{bn},{bk}) must divide ({m},{n},{k})")
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
