"""Pure-jnp oracles for the Layer-1 Pallas kernels.

Every kernel in this package has a reference here with identical signature
semantics; pytest/hypothesis sweeps assert allclose (bit-exact for the LL
payload ops, which are pure integer/bit manipulation).
"""

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """f32 matmul oracle."""
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def ll_pack_ref(data: jax.Array, seq: jax.Array) -> jax.Array:
    """Oracle for ll_pack: interleave data bits with the flag word."""
    bits = jax.lax.bitcast_convert_type(data.astype(jnp.float32), jnp.uint32)
    flags = jnp.full_like(bits, seq.astype(jnp.uint32)[0])
    return jnp.stack([bits, flags], axis=-1)


def ll_unpack_reduce_ref(payloads: jax.Array, seq: jax.Array):
    """Oracle for ll_unpack_reduce: flag-validate and sum K peer buffers."""
    p = payloads.astype(jnp.uint32)
    data = jax.lax.bitcast_convert_type(p[:, :, 0], jnp.float32)
    ok = jnp.sum((p[:, :, 1] == seq.astype(jnp.uint32)[0]).astype(jnp.uint32),
                 axis=0)
    return jnp.sum(data, axis=0), ok
