"""LL fused-payload pack / unpack-reduce Pallas kernels (Layer 1).

This is the device-side heart of NVRAR's inter-node recursive-doubling step
(paper §4.2.2): instead of a separate completion signal (put_with_signal +
wait_until, which costs a software fence on Slingshot), every 4 B data word
is fused with a 4 B sequence flag into a single 8 B payload whose delivery
is atomic and ordered. The receiver validates flags and reduces in the same
pass, so reduction can begin the moment a chunk lands.

On TPU there is no warp-level flag spin; what survives the hardware
adaptation is the *payload layout* and the *chunked streaming reduction*:

- ``ll_pack``:   f32[n] data + u32 seq  ->  u32[n, 2] fused payload
  (word 0 = data bits, word 1 = flag; row-major == interleaved in memory,
  i.e. exactly the wire format of the paper's 8 B LL payload).
- ``ll_unpack_reduce``: u32[K, n, 2] payloads from K peers -> (f32[n] sum,
  u32[n] flag-match count). Gridded over chunks of size C_s — the grid is
  the TPU analogue of the paper's B_s thread blocks each walking C_s-byte
  chunks; one chunk of all K peers fits VMEM per grid step.

The rust runtime performs the actual peer exchange (shmem put_nbi); these
kernels define/verify the payload math and let the L2 graph reduce shard
buffers with identical semantics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_chunk(n: int, requested: int) -> int:
    """Largest divisor of ``n`` that is <= requested (>= 1)."""
    c = min(requested, n)
    while n % c:
        c -= 1
    return c


def _pack_kernel(data_ref, seq_ref, out_ref):
    bits = jax.lax.bitcast_convert_type(data_ref[...], jnp.uint32)
    flags = jnp.full_like(bits, seq_ref[0])
    out_ref[...] = jnp.stack([bits, flags], axis=-1)


def ll_pack(data: jax.Array, seq: jax.Array, *, chunk: int = 2048) -> jax.Array:
    """Fuse f32 data words with a u32 sequence flag into 8 B LL payloads.

    Args:
      data: f32[n] message (one recursive-doubling send buffer).
      seq: u32[1] sequence number of this all-reduce operation.
      chunk: requested C_s in elements (clamped to a divisor of n).

    Returns:
      u32[n, 2] payload; [:, 0] = data bits, [:, 1] = seq flag.
    """
    (n,) = data.shape
    c = _pick_chunk(n, chunk)
    return pl.pallas_call(
        _pack_kernel,
        grid=(n // c,),
        in_specs=[
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((c, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 2), jnp.uint32),
        interpret=True,
    )(data.astype(jnp.float32), seq.astype(jnp.uint32))


def _unpack_reduce_kernel(p_ref, seq_ref, out_ref, ok_ref):
    payload = p_ref[...]                      # (K, chunk, 2)
    data = jax.lax.bitcast_convert_type(payload[:, :, 0], jnp.float32)
    flags = payload[:, :, 1]
    out_ref[...] = jnp.sum(data, axis=0)
    ok_ref[...] = jnp.sum((flags == seq_ref[0]).astype(jnp.uint32), axis=0)


def ll_unpack_reduce(payloads: jax.Array, seq: jax.Array, *,
                     chunk: int = 2048) -> tuple[jax.Array, jax.Array]:
    """Validate flags and sum K peer LL-payload buffers chunk-by-chunk.

    Args:
      payloads: u32[K, n, 2] — K peers' fused payload buffers.
      seq: u32[1] expected sequence number.
      chunk: requested C_s in elements (clamped to a divisor of n).

    Returns:
      (f32[n] elementwise sum of the K data vectors,
       u32[n] count of peers whose flag matched ``seq`` — a correct,
       fully-arrived reduction has every entry == K).
    """
    k, n, _ = payloads.shape
    c = _pick_chunk(n, chunk)
    out, ok = pl.pallas_call(
        _unpack_reduce_kernel,
        grid=(n // c,),
        in_specs=[
            pl.BlockSpec((k, c, 2), lambda i: (0, i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        interpret=True,
    )(payloads.astype(jnp.uint32), seq.astype(jnp.uint32))
    return out, ok
