"""Layer-1 Pallas kernels (interpret=True — CPU-PJRT executable HLO).

Two kernels implement the paper's device-side compute:

- ``matmul``: the tiled MXU-shaped matmul used by the L2 model's MLP
  projections. BlockSpec expresses the HBM->VMEM tiling schedule that CUDA
  GEMMs get from thread-block tiling; this is the mechanism behind the
  paper's Table 4 M-tile-floor effect.
- ``ll_reduce``: the NVRAR inter-node reduction step — LL-protocol fused
  (4 B data + 4 B flag) payload pack / flag-check / unpack-sum, gridded over
  chunks (the TPU analogue of the paper's B_s thread blocks x C_s chunks).
"""

from .matmul import matmul
from .ll_reduce import ll_pack, ll_unpack_reduce

__all__ = ["matmul", "ll_pack", "ll_unpack_reduce"]
