"""AOT entry point: lower every Layer-2 graph to HLO *text* artifacts.

Run once at build time (``make artifacts``); the rust binary is then fully
self-contained. HLO text — NOT ``lowered.compile()``/``.serialize()`` — is
the interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids that the crate's xla_extension 0.5.1 rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Artifacts (B = batch, S = TP shards, cfg = configs.TINY):

  prefill_full.hlo.txt   (tokens i32[B,T0], <stacked weights>) -> (logits, kc, vc)
  decode_full.hlo.txt    (token i32[B], pos i32, kc, vc, <stacked weights>)
  embed.hlo.txt          (tokens i32[B], embed) -> x[B,d]
  attn_shard.hlo.txt     per-layer TP attention segment (partial output)
  mlp_shard.hlo.txt      per-layer TP MLP segment (partial output)
  head.hlo.txt           (x, final_norm, lm_head) -> logits
  gemm_<kind>_<var>.hlo.txt   Table-4 GEMMs (base / mhalf / khalf)
  weights.bin            YWT1 tensor bundle (stacked layer weights)
  config.txt             key=value manifest (dims, arg orders, shapes)

Argument order in each artifact == the python function signature order; the
manifest records it so the rust loader can assert agreement.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import TINY
from .export import write_weights

# Static compile-time choices for the tiny end-to-end model.
BATCH = 2          # decode batch (B)
PROMPT = 16        # prefill prompt length (T0)
SHARDS = 2         # TP degree of the sharded artifacts
SEED = 0

# Table-4 GEMM shapes, scaled to CPU (paper: prefill M=32768 N=8192 K=57344,
# decode M=32 N=8192 K=57344). N,K scaled 1/8; prefill M scaled 1/32 to keep
# the bench wall-clock sane; decode M kept exact (it IS the effect: M below
# the tile floor).
GEMMS = {
    "prefill": (1024, 1024, 7168),
    "decode": (32, 1024, 7168),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


STACK_ORDER = ("embed", "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm",
               "wg", "wu", "wd", "final_norm", "lm_head")


def build_artifacts(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cfg = TINY
    cfg.validate_tp(SHARDS)
    b, t0, s = BATCH, PROMPT, SHARDS
    d, v, t = cfg.d_model, cfg.vocab, cfg.max_seq
    kvd, qd, f = cfg.kv_dim, cfg.q_dim, cfg.ffn
    kvs = kvd // s
    hs_dh = qd // s
    fs = f // s

    params = model.init_params(cfg, jax.random.PRNGKey(SEED))
    wspecs = {k: _spec(params[k].shape) for k in STACK_ORDER}

    manifest: dict[str, str] = {
        "model.name": cfg.name, "model.vocab": v, "model.d_model": d,
        "model.n_layers": cfg.n_layers, "model.n_heads": cfg.n_heads,
        "model.n_kv_heads": cfg.n_kv_heads, "model.head_dim": cfg.head_dim,
        "model.ffn": f, "model.max_seq": t, "model.params": cfg.param_count(),
        "aot.batch": b, "aot.prompt": t0, "aot.shards": s, "aot.seed": SEED,
    }

    def emit(name, fn, *specs, args: str):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        manifest[f"artifact.{name}.args"] = args
        print(f"  {name}.hlo.txt  ({len(text)/1e6:.2f} MB text)")

    # ---- full model -----------------------------------------------------
    def prefill(tokens, *stack):
        p = dict(zip(STACK_ORDER, stack))
        return model.prefill_full(cfg, p, tokens, use_pallas=False)

    emit("prefill_full", prefill, _spec((b, t0), jnp.int32),
         *(wspecs[k] for k in STACK_ORDER),
         args="tokens," + ",".join(STACK_ORDER))

    def decode(token, pos, kc, vc, *stack):
        p = dict(zip(STACK_ORDER, stack))
        return model.decode_full(cfg, p, token, pos, kc, vc, use_pallas=False)

    cache_spec = _spec((cfg.n_layers, b, t, kvd))
    emit("decode_full", decode, _spec((b,), jnp.int32),
         _spec((), jnp.int32), cache_spec, cache_spec,
         *(wspecs[k] for k in STACK_ORDER),
         args="token,pos,k_caches,v_caches," + ",".join(STACK_ORDER))

    # ---- TP-sharded segments --------------------------------------------
    emit("embed", model.embed_fn, _spec((b,), jnp.int32), _spec((v, d)),
         args="tokens,embed")

    attn = functools.partial(model.attn_shard, cfg, s, use_pallas=False)

    def attn_seg(x, norm_w, wq, wk, wv, wo, kc, vc, pos):
        return attn(x, norm_w, wq, wk, wv, wo, kc, vc, pos)

    emit("attn_shard", attn_seg, _spec((b, d)), _spec((d,)),
         _spec((d, hs_dh)), _spec((d, kvs)), _spec((d, kvs)),
         _spec((hs_dh, d)), _spec((b, t, kvs)), _spec((b, t, kvs)),
         _spec((), jnp.int32),
         args="x,attn_norm,wq,wk,wv,wo,k_cache,v_cache,pos")

    def mlp_seg(x, norm_w, wg, wu, wd):
        return model.mlp_shard(cfg, s, x, norm_w, wg, wu, wd,
                               use_pallas=True)

    emit("mlp_shard", mlp_seg, _spec((b, d)), _spec((d,)), _spec((d, fs)),
         _spec((d, fs)), _spec((fs, d)),
         args="x,mlp_norm,wg,wu,wd")

    emit("head", model.head_fn, _spec((b, d)), _spec((d,)), _spec((d, v)),
         args="x,final_norm,lm_head")

    # ---- Table-4 GEMMs ---------------------------------------------------
    def gemm(x, y):
        return (jnp.dot(x, y, preferred_element_type=jnp.float32),)

    for kind, (m, n, k) in GEMMS.items():
        for var, (mm, nn, kk) in {
            "base": (m, n, k), "mhalf": (max(m // 2, 1), n, k),
            "khalf": (m, n, k // 2),
        }.items():
            emit(f"gemm_{kind}_{var}", gemm, _spec((mm, kk)), _spec((kk, nn)),
                 args="x,y")
            manifest[f"gemm.{kind}.{var}.mnk"] = f"{mm},{nn},{kk}"

    # ---- weights + manifest ----------------------------------------------
    write_weights(os.path.join(out_dir, "weights.bin"),
                  {k: params[k] for k in STACK_ORDER})
    print(f"  weights.bin  ({cfg.param_count()/1e6:.1f}M params)")

    with open(os.path.join(out_dir, "config.txt"), "w") as fh:
        for k in sorted(manifest):
            fh.write(f"{k}={manifest[k]}\n")
    print("  config.txt")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    print(f"AOT-lowering {TINY.name} to {args.out_dir}")
    build_artifacts(args.out_dir)


if __name__ == "__main__":
    main()
