"""L2 model tests: shapes, KV-cache semantics, TP shard-sum == full model."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.configs import TINY, ModelConfig

jax.config.update("jax_platform_name", "cpu")

# A small config so hypothesis can run many cases.
SMALL = replace(TINY, n_layers=2, max_seq=24, vocab=64, d_model=32,
                n_heads=4, n_kv_heads=2, head_dim=8, ffn=64)


def _params(cfg, seed=0):
    return model.init_params(cfg, jax.random.PRNGKey(seed))


def _prompt(cfg, b, t0, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, t0), 0,
                              cfg.vocab, jnp.int32)


def _shard_caches(cfg, kc, vc, shards):
    kvs = cfg.kv_dim // shards
    def per(c):
        return jnp.stack([
            jnp.stack([c[l][:, :, s * kvs:(s + 1) * kvs]
                       for s in range(shards)])
            for l in range(cfg.n_layers)])
    return per(kc), per(vc)


def test_param_count_matches_init():
    p = _params(SMALL)
    total = sum(int(np.prod(v.shape)) for v in p.values())
    assert total == SMALL.param_count()


def test_prefill_shapes():
    b, t0 = 2, 6
    logits, kc, vc = model.prefill_full(SMALL, _params(SMALL),
                                        _prompt(SMALL, b, t0))
    assert logits.shape == (b, SMALL.vocab)
    assert kc.shape == (SMALL.n_layers, b, SMALL.max_seq, SMALL.kv_dim)
    assert vc.shape == kc.shape
    # cache rows beyond the prompt are untouched zeros
    assert np.asarray(kc[:, :, t0:, :]).max() == 0.0


def test_decode_matches_prefill_extension():
    """prefill(T0+1 tokens) last logits == prefill(T0) + decode(token T0)."""
    cfg = SMALL
    p = _params(cfg)
    b, t0 = 2, 5
    toks = _prompt(cfg, b, t0 + 1)
    want, _, _ = model.prefill_full(cfg, p, toks)
    _, kc, vc = model.prefill_full(cfg, p, toks[:, :t0])
    got, _, _ = model.decode_full(cfg, p, toks[:, t0], jnp.int32(t0), kc, vc)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), t0=st.integers(1, 8), seed=st.integers(0, 99))
def test_sharded_equals_full(b, t0, seed):
    cfg = SMALL
    p = _params(cfg, seed)
    toks = _prompt(cfg, b, t0, seed + 1)
    logits, kc, vc = model.prefill_full(cfg, p, toks)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    want, kfull, vfull = model.decode_full(cfg, p, tok, jnp.int32(t0), kc, vc)
    kcs, vcs = _shard_caches(cfg, kc, vc, 2)
    got, kn, vn = model.decode_sharded_reference(cfg, p, 2, tok,
                                                 jnp.int32(t0), kcs, vcs)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # shard cache slices re-concatenate to the full cache
    for l in range(cfg.n_layers):
        cat = jnp.concatenate([kn[l, s] for s in range(2)], axis=-1)
        np.testing.assert_allclose(kfull[l], cat, rtol=1e-5, atol=1e-5)


def test_sharded_four_way():
    cfg = replace(SMALL, n_kv_heads=4, n_heads=8, ffn=64)
    p = _params(cfg)
    toks = _prompt(cfg, 2, 4)
    logits, kc, vc = model.prefill_full(cfg, p, toks)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    want, _, _ = model.decode_full(cfg, p, tok, jnp.int32(4), kc, vc)
    kcs, vcs = _shard_caches(cfg, kc, vc, 4)
    got, _, _ = model.decode_sharded_reference(cfg, p, 4, tok, jnp.int32(4),
                                               kcs, vcs)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_pallas_mlp_matches_jnp_path():
    cfg = SMALL
    p = _params(cfg)
    toks = _prompt(cfg, 2, 4)
    logits, kc, vc = model.prefill_full(cfg, p, toks)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    a, _, _ = model.decode_full(cfg, p, tok, jnp.int32(4), kc, vc,
                                use_pallas=False)
    b_, _, _ = model.decode_full(cfg, p, tok, jnp.int32(4), kc, vc,
                                 use_pallas=True)
    np.testing.assert_allclose(a, b_, rtol=2e-4, atol=2e-4)


def test_validate_tp_rejects_bad_degree():
    try:
        SMALL.validate_tp(3)
    except ValueError:
        return
    raise AssertionError("TP=3 must be rejected for 4 kv heads")


def test_decode_is_deterministic():
    cfg = SMALL
    p = _params(cfg)
    toks = _prompt(cfg, 1, 3)
    _, kc, vc = model.prefill_full(cfg, p, toks)
    tok = jnp.zeros((1,), jnp.int32)
    a = model.decode_full(cfg, p, tok, jnp.int32(3), kc, vc)[0]
    b = model.decode_full(cfg, p, tok, jnp.int32(3), kc, vc)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rope_positions_matter():
    """Same token at different positions must attend differently."""
    cfg = SMALL
    p = _params(cfg)
    toks = _prompt(cfg, 1, 6)
    _, kc, vc = model.prefill_full(cfg, p, toks)
    tok = jnp.ones((1,), jnp.int32)
    a = model.decode_full(cfg, p, tok, jnp.int32(6), kc, vc)[0]
    b = model.decode_full(cfg, p, tok, jnp.int32(7), kc, vc)[0]
    assert not np.allclose(np.asarray(a), np.asarray(b))
