"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/chunkings; every property asserts
allclose (or bit-exact equality for the integer LL payload ops).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ll_pack, ll_unpack_reduce, matmul
from compile.kernels import ref
from compile.kernels.matmul import _pick_block
from compile.kernels.ll_reduce import _pick_chunk

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             jnp.float32) * scale


# ---------------------------------------------------------------- matmul --

@settings(**SETTINGS)
@given(m=st.integers(1, 96), n=st.integers(1, 96), k=st.integers(1, 96),
       seed=st.integers(0, 2**16))
def test_matmul_matches_ref_any_shape(m, n, k, seed):
    x = _rand(seed, (m, k))
    y = _rand(seed + 1, (k, n))
    got = matmul(x, y)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(bm=st.sampled_from([2, 4, 8]), bn=st.sampled_from([2, 4, 8]),
       bk=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**16))
def test_matmul_tile_override(bm, bn, bk, seed):
    m, n, k = bm * 3, bn * 2, bk * 4
    x = _rand(seed, (m, k))
    y = _rand(seed + 1, (k, n))
    got = matmul(x, y, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-5,
                               atol=1e-5)


def test_matmul_mxu_shaped_tiles():
    """The model-sized GEMM uses true 128-tiles end to end."""
    x = _rand(7, (256, 768))
    y = _rand(8, (768, 2048))
    got = matmul(x, y, bm=128, bn=128, bk=256)
    np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-4,
                               atol=1e-4)


def test_matmul_rejects_bad_tiles():
    x, y = jnp.ones((4, 4)), jnp.ones((4, 4))
    try:
        matmul(x, y, bm=3)
    except ValueError:
        return
    raise AssertionError("expected ValueError for non-dividing tile")


def test_matmul_rejects_shape_mismatch():
    try:
        matmul(jnp.ones((2, 3)), jnp.ones((4, 2)))
    except ValueError:
        return
    raise AssertionError("expected ValueError for mismatched inner dims")


def test_pick_block_prefers_mxu_tiles():
    assert _pick_block(768) == 128
    assert _pick_block(2048) == 128
    assert _pick_block(8) == 8
    assert _pick_block(7) == 1
    assert _pick_block(96) == 32


# ------------------------------------------------------------- ll_reduce --

@settings(**SETTINGS)
@given(n=st.integers(1, 512), seq=st.integers(0, 2**32 - 1),
       chunk=st.integers(1, 128), seed=st.integers(0, 2**16))
def test_ll_pack_bit_exact(n, seq, chunk, seed):
    data = _rand(seed, (n,), scale=10.0)
    s = jnp.array([seq], jnp.uint32)
    got = ll_pack(data, s, chunk=chunk)
    want = ref.ll_pack_ref(data, s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(**SETTINGS)
@given(k=st.integers(1, 8), n=st.integers(1, 256),
       seq=st.integers(0, 2**31), chunk=st.integers(1, 64),
       seed=st.integers(0, 2**16))
def test_ll_unpack_reduce_matches_ref(k, n, seq, chunk, seed):
    bufs = jnp.stack([
        ref.ll_pack_ref(_rand(seed + i, (n,)), jnp.array([seq], jnp.uint32))
        for i in range(k)
    ])
    s = jnp.array([seq], jnp.uint32)
    got_sum, got_ok = ll_unpack_reduce(bufs, s, chunk=chunk)
    want_sum, want_ok = ref.ll_unpack_reduce_ref(bufs, s)
    np.testing.assert_allclose(got_sum, want_sum, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_ok), np.asarray(want_ok))
    assert (np.asarray(got_ok) == k).all()


@settings(**SETTINGS)
@given(k=st.integers(2, 6), n=st.integers(4, 64), seed=st.integers(0, 2**16))
def test_ll_roundtrip_is_sum(k, n, seed):
    """pack -> unpack_reduce over K peers == elementwise sum of the data."""
    datas = [_rand(seed + i, (n,)) for i in range(k)]
    s = jnp.array([42], jnp.uint32)
    bufs = jnp.stack([ll_pack(d, s) for d in datas])
    got, ok = ll_unpack_reduce(bufs, s)
    np.testing.assert_allclose(got, sum(datas), rtol=1e-6, atol=1e-6)
    assert (np.asarray(ok) == k).all()


def test_ll_detects_stale_flag():
    """A buffer written with an old sequence number must show ok < K."""
    n, s_new, s_old = 16, jnp.array([5], jnp.uint32), jnp.array([4], jnp.uint32)
    fresh = ll_pack(jnp.ones((n,)), s_new)
    stale = ll_pack(jnp.ones((n,)), s_old)
    _, ok = ll_unpack_reduce(jnp.stack([fresh, stale]), s_new)
    assert (np.asarray(ok) == 1).all()


def test_ll_pack_preserves_nan_payload_bits():
    """LL pack is a bit move, not an arithmetic op: NaN/Inf bits survive."""
    data = jnp.array([np.nan, np.inf, -np.inf, -0.0], jnp.float32)
    s = jnp.array([1], jnp.uint32)
    p = np.asarray(ll_pack(data, s))
    back = p[:, 0].view(np.float32)
    np.testing.assert_array_equal(back.view(np.uint32),
                                  np.asarray(data).view(np.uint32))


def test_pick_chunk_divides():
    for n in (1, 7, 12, 100, 2048):
        for req in (1, 3, 8, 4096):
            c = _pick_chunk(n, req)
            assert 1 <= c <= max(req, 1) or c == n
            assert n % c == 0
