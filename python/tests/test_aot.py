"""AOT path tests: HLO text emission, manifest, weight round-trip."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.export import read_weights, write_weights

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_emits_parseable_module():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "dot(" in text or "dot " in text


def test_to_hlo_text_pallas_kernel_lowers_to_plain_hlo():
    from compile.kernels import matmul

    spec = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    text = aot.to_hlo_text(jax.jit(lambda x, y: (matmul(x, y),)).lower(spec, spec))
    assert "HloModule" in text
    # interpret=True must not leave an unexecutable custom-call behind
    assert "mosaic" not in text.lower()


def test_weights_roundtrip():
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b.c": np.array([1, -2, 3], dtype=np.int32),
        "scalarish": np.array([2.5], dtype=np.float32),
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.bin")
        write_weights(path, tensors)
        back = read_weights(path)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_built_artifacts_manifest_consistent():
    """If `make artifacts` has run, the manifest must agree with configs."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    cfgf = os.path.join(art, "config.txt")
    if not os.path.exists(cfgf):
        import pytest
        pytest.skip("artifacts not built")
    kv = {}
    for line in open(cfgf):
        k, _, v = line.strip().partition("=")
        kv[k] = v
    from compile.configs import TINY
    assert int(kv["model.d_model"]) == TINY.d_model
    assert int(kv["model.params"]) == TINY.param_count()
    assert kv["artifact.decode_full.args"].startswith("token,pos,")
    for name in ("prefill_full", "decode_full", "embed", "attn_shard",
                 "mlp_shard", "head"):
        path = os.path.join(art, f"{name}.hlo.txt")
        assert os.path.exists(path), f"missing artifact {name}"
        with open(path) as fh:
            assert "HloModule" in fh.read(200)
