//! Model architectures: the paper's evaluation models plus the tiny model
//! the PJRT runtime actually executes.
//!
//! Architecture constants are exact (Llama 3.1 / Qwen3 published configs);
//! they drive the analytic performance model — FLOP counts, bytes moved,
//! KV-cache traffic, and the TP all-reduce message size `B × H × dtype`
//! that §3.5 identifies as the decode-phase communication regime.

/// Dense (or MoE) decoder architecture description.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    /// Bytes per parameter/activation element (bf16 = 2).
    pub dtype_bytes: usize,
    /// MoE structure; `None` for dense models.
    pub moe: Option<MoeConfig>,
}

/// Mixture-of-experts layer structure (Fig 10's Qwen3-235B-A22B).
#[derive(Clone, Copy, Debug)]
pub struct MoeConfig {
    pub n_experts: usize,
    pub active_experts: usize,
    /// Per-expert FFN intermediate size.
    pub expert_ffn: usize,
}

impl ModelConfig {
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Total parameter count (dense weights; MoE counts all experts).
    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let attn = d * self.q_dim() as u64 * 2 // wq, wo
            + d * self.kv_dim() as u64 * 2; // wk, wv
        let mlp = match self.moe {
            None => 3 * d * self.ffn as u64,
            Some(m) => 3 * d * m.expert_ffn as u64 * m.n_experts as u64
                + d * m.n_experts as u64, // router
        };
        self.n_layers as u64 * (attn + mlp + 2 * d)
            + 2 * self.vocab as u64 * d
            + d
    }

    /// Parameters touched per token in decode (active experts only).
    pub fn active_param_count(&self) -> u64 {
        match self.moe {
            None => self.param_count(),
            Some(m) => {
                let d = self.d_model as u64;
                let attn = d * self.q_dim() as u64 * 2 + d * self.kv_dim() as u64 * 2;
                let mlp = 3 * d * m.expert_ffn as u64 * m.active_experts as u64;
                self.n_layers as u64 * (attn + mlp + 2 * d) + 2 * self.vocab as u64 * d + d
            }
        }
    }

    pub fn param_bytes(&self) -> u64 {
        self.param_count() * self.dtype_bytes as u64
    }

    /// Bytes of parameters read per decoded token (what decode bandwidth
    /// actually streams: active experts only for MoE).
    pub fn active_param_bytes(&self) -> u64 {
        self.active_param_count() * self.dtype_bytes as u64
    }

    /// KV-cache bytes per token per layer (both K and V).
    pub fn kv_bytes_per_token_layer(&self) -> u64 {
        2 * self.kv_dim() as u64 * self.dtype_bytes as u64
    }

    /// TP all-reduce message size for a decode step with batch `b` — the
    /// §3.5 quantity B × H × dtype (128 KB for 70B at B=8, bf16).
    pub fn tp_allreduce_bytes(&self, batch: usize) -> u64 {
        (batch * self.d_model * self.dtype_bytes) as u64
    }

    /// Llama 3.1 70B Instruct.
    pub fn llama31_70b() -> Self {
        ModelConfig {
            name: "Llama-3.1-70B",
            vocab: 128_256,
            d_model: 8192,
            n_layers: 80,
            n_heads: 64,
            n_kv_heads: 8,
            head_dim: 128,
            ffn: 28_672,
            dtype_bytes: 2,
            moe: None,
        }
    }

    /// Llama 3.1 405B Instruct.
    pub fn llama31_405b() -> Self {
        ModelConfig {
            name: "Llama-3.1-405B",
            vocab: 128_256,
            d_model: 16_384,
            n_layers: 126,
            n_heads: 128,
            n_kv_heads: 8,
            head_dim: 128,
            ffn: 53_248,
            dtype_bytes: 2,
            moe: None,
        }
    }

    /// Qwen3-235B-A22B (MoE; Fig 10).
    pub fn qwen3_235b_a22b() -> Self {
        ModelConfig {
            name: "Qwen3-235B-A22B",
            vocab: 151_936,
            d_model: 4096,
            n_layers: 94,
            n_heads: 64,
            n_kv_heads: 4,
            head_dim: 128,
            ffn: 12_288,
            dtype_bytes: 2,
            moe: Some(MoeConfig { n_experts: 128, active_experts: 8, expert_ffn: 1536 }),
        }
    }

    /// The ~85M tiny model the PJRT runtime executes (python/compile).
    pub fn tiny() -> Self {
        ModelConfig {
            name: "tiny-llama-85m",
            vocab: 4096,
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            n_kv_heads: 4,
            head_dim: 64,
            ffn: 2048,
            dtype_bytes: 4, // f32 on CPU
            moe: None,
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "70b" | "llama-70b" | "llama31-70b" => Self::llama31_70b(),
            "405b" | "llama-405b" | "llama31-405b" => Self::llama31_405b(),
            "qwen3" | "qwen3-235b" => Self::qwen3_235b_a22b(),
            "tiny" => Self::tiny(),
            other => anyhow::bail!("unknown model '{other}' (expected 70b, 405b, qwen3 or tiny)"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama70b_params_about_70b() {
        let p = ModelConfig::llama31_70b().param_count() as f64;
        assert!((p - 70.0e9).abs() < 3.0e9, "{p}");
    }

    #[test]
    fn llama405b_params_about_405b() {
        let p = ModelConfig::llama31_405b().param_count() as f64;
        assert!((p - 405.0e9).abs() < 10.0e9, "{p}");
    }

    #[test]
    fn qwen_total_vs_active() {
        let m = ModelConfig::qwen3_235b_a22b();
        let total = m.param_count() as f64;
        let active = m.active_param_count() as f64;
        assert!((total - 235.0e9).abs() < 15.0e9, "total {total}");
        assert!((active - 22.0e9).abs() < 4.0e9, "active {active}");
    }

    #[test]
    fn paper_message_size_check() {
        // §3.5: 70B, B=8, H=8192, bf16 -> 128 KB.
        let m = ModelConfig::llama31_70b();
        assert_eq!(m.tp_allreduce_bytes(8), 128 * 1024);
        assert_eq!(m.tp_allreduce_bytes(32), 512 * 1024);
        // 405B: B=8 -> 256 KB; B=32 -> 1 MB (Fig 7's "more favorable").
        let m = ModelConfig::llama31_405b();
        assert_eq!(m.tp_allreduce_bytes(8), 256 * 1024);
        assert_eq!(m.tp_allreduce_bytes(32), 1024 * 1024);
    }

    #[test]
    fn tiny_matches_python_config() {
        let m = ModelConfig::tiny();
        assert_eq!(m.d_model, 768);
        assert_eq!(m.n_layers, 12);
        // Param count must match python/compile/configs.py (~85M).
        let p = m.param_count();
        assert!(p > 80_000_000 && p < 90_000_000, "{p}");
    }

    #[test]
    fn kv_bytes() {
        let m = ModelConfig::llama31_70b();
        // 8 kv heads * 128 dim * 2 (K+V) * 2 bytes = 4096 B.
        assert_eq!(m.kv_bytes_per_token_layer(), 4096);
    }
}
