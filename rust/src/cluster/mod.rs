//! Cluster topology: nodes, GPUs, interconnect parameters.
//!
//! Mirrors the paper's Table 1 testbeds. A GPU is addressed by the pair
//! `(node rank r_n, local rank r_g)` exactly as in Algorithm 1; links are
//! classed intra-node (NVLink) or inter-node (Slingshot-11 / InfiniBand)
//! with independent α (latency) and β (bandwidth) per class — the α-β model
//! of §2.2.

/// A GPU's global identity: `(r_n, r_g)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuId {
    /// Node rank `r_n ∈ [0, N)`.
    pub node: usize,
    /// Local rank within the node `r_g ∈ [0, G)`.
    pub local: usize,
}

/// Link class between two GPUs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkClass {
    /// Same node: NVLink-class.
    Intra,
    /// Different node: scale-out network.
    Inter,
}

/// α-β parameters of one link class.
#[derive(Clone, Copy, Debug)]
pub struct LinkParams {
    /// Latency α in seconds (per message).
    pub alpha: f64,
    /// Bandwidth β in bytes/second.
    pub beta: f64,
}

impl LinkParams {
    /// α + |M|/β transfer time for `bytes`.
    pub fn xfer_time(&self, bytes: u64) -> f64 {
        self.alpha + bytes as f64 / self.beta
    }
}

/// A homogeneous cluster: N nodes × G GPUs.
#[derive(Clone, Copy, Debug)]
pub struct Topology {
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub intra: LinkParams,
    pub inter: LinkParams,
    /// Host-side launch overhead per device kernel (CUDA-graph replay cost
    /// amortises this; engines without graphs pay it per kernel).
    pub kernel_launch: f64,
}

impl Topology {
    pub fn total_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    pub fn gpus(&self) -> impl Iterator<Item = GpuId> + '_ {
        (0..self.nodes)
            .flat_map(move |n| (0..self.gpus_per_node).map(move |g| GpuId { node: n, local: g }))
    }

    /// Flat rank (node-major) of a GPU — NCCL-style rank numbering.
    pub fn flat_rank(&self, id: GpuId) -> usize {
        id.node * self.gpus_per_node + id.local
    }

    pub fn from_flat(&self, rank: usize) -> GpuId {
        GpuId { node: rank / self.gpus_per_node, local: rank % self.gpus_per_node }
    }

    pub fn link_class(&self, a: GpuId, b: GpuId) -> LinkClass {
        if a.node == b.node { LinkClass::Intra } else { LinkClass::Inter }
    }

    pub fn link(&self, a: GpuId, b: GpuId) -> LinkParams {
        match self.link_class(a, b) {
            LinkClass::Intra => self.intra,
            LinkClass::Inter => self.inter,
        }
    }

    /// Carve a topology for `gpus` total GPUs: fills nodes first (the way
    /// Slurm allocates), e.g. 8 GPUs on Perlmutter = 2 full nodes.
    pub fn with_gpus(&self, gpus: usize) -> Topology {
        assert!(gpus >= 1);
        let mut t = *self;
        if gpus <= self.gpus_per_node {
            t.nodes = 1;
            t.gpus_per_node = gpus;
        } else {
            assert!(
                gpus % self.gpus_per_node == 0,
                "{} GPUs not a multiple of {}/node",
                gpus,
                self.gpus_per_node
            );
            t.nodes = gpus / self.gpus_per_node;
        }
        t
    }
}

/// Machine presets calibrated to the paper's Table 1 systems.
///
/// α/β values are the standard published figures for these interconnects
/// (NVLink3 ≈ 2 µs / ~200 GB/s effective per GPU pair; Slingshot-11 ≈ 2 µs
/// HW but ~15 µs effective through NCCL's net transport with ~20 GB/s
/// effective per NIC; InfiniBand NDR ≈ 8 µs / péer 22 GB/s). They are
/// *calibration constants*: EXPERIMENTS.md checks the resulting curves
/// against the paper's reported shapes, not absolute numbers.
pub mod presets {
    use super::*;

    /// NERSC Perlmutter: 4×A100 per node, NVLink-3 intra, Slingshot-11 inter.
    pub fn perlmutter(nodes: usize) -> Topology {
        Topology {
            nodes,
            gpus_per_node: 4,
            intra: LinkParams { alpha: 2.0e-6, beta: 200.0e9 },
            inter: LinkParams { alpha: 15.0e-6, beta: 22.0e9 },
            kernel_launch: 4.0e-6,
        }
    }

    /// TACC Vista: 1×GH200 per node, InfiniBand inter (no intra phase).
    pub fn vista(nodes: usize) -> Topology {
        Topology {
            nodes,
            gpus_per_node: 1,
            intra: LinkParams { alpha: 1.5e-6, beta: 300.0e9 },
            inter: LinkParams { alpha: 8.0e-6, beta: 48.0e9 },
            kernel_launch: 4.0e-6,
        }
    }

    /// A generic 8-GPU/node InfiniBand cluster (DGX-like) for ablations.
    pub fn generic_ib(nodes: usize) -> Topology {
        Topology {
            nodes,
            gpus_per_node: 8,
            intra: LinkParams { alpha: 2.0e-6, beta: 250.0e9 },
            inter: LinkParams { alpha: 10.0e-6, beta: 25.0e9 },
            kernel_launch: 4.0e-6,
        }
    }

    /// Topology for a machine name or bundle file path at `nodes` nodes,
    /// resolved through [`crate::calib::registry`]. Unknown names are an
    /// error, not a panic.
    pub fn by_name(name: &str, nodes: usize) -> anyhow::Result<Topology> {
        Ok(crate::calib::registry::resolve(name)?.topo.topology(nodes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_rank_roundtrip() {
        let t = presets::perlmutter(4);
        for id in t.gpus() {
            assert_eq!(t.from_flat(t.flat_rank(id)), id);
        }
        assert_eq!(t.total_gpus(), 16);
    }

    #[test]
    fn link_classes() {
        let t = presets::perlmutter(2);
        let a = GpuId { node: 0, local: 0 };
        let b = GpuId { node: 0, local: 3 };
        let c = GpuId { node: 1, local: 0 };
        assert_eq!(t.link_class(a, b), LinkClass::Intra);
        assert_eq!(t.link_class(a, c), LinkClass::Inter);
        assert!(t.link(a, c).alpha > t.link(a, b).alpha);
        assert!(t.link(a, c).beta < t.link(a, b).beta);
    }

    #[test]
    fn with_gpus_partial_node() {
        let t = presets::perlmutter(8).with_gpus(2);
        assert_eq!((t.nodes, t.gpus_per_node), (1, 2));
        let t = presets::perlmutter(8).with_gpus(32);
        assert_eq!((t.nodes, t.gpus_per_node), (8, 4));
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn with_gpus_rejects_ragged() {
        presets::perlmutter(8).with_gpus(6);
    }

    #[test]
    fn xfer_time_model() {
        let l = LinkParams { alpha: 1e-6, beta: 1e9 };
        assert!((l.xfer_time(1000) - (1e-6 + 1e-6)).abs() < 1e-12);
    }

    #[test]
    fn vista_is_one_gpu_per_node() {
        assert_eq!(presets::vista(16).total_gpus(), 16);
        assert_eq!(presets::vista(16).gpus_per_node, 1);
    }
}
