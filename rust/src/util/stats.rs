//! Summary statistics for benchmark harnesses and the simulator.

/// Streaming mean/variance (Welford) plus retained samples for percentiles.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
    /// Lazily built sorted view of `samples`, shared by every quantile
    /// read. Samples are append-only, so the cache is valid exactly when
    /// its length matches `samples` — a fleet report asking for p50, p95
    /// and p99 over a 10M-sample summary sorts once, not three times.
    sorted: std::cell::RefCell<Vec<f64>>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let d = x - self.mean;
        self.mean += d / n;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.samples.len() < 2 { 0.0 } else { self.m2 / (self.samples.len() - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, `q` in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        assert!(!self.samples.is_empty(), "percentile of empty summary");
        let mut sorted = self.sorted.borrow_mut();
        if sorted.len() != self.samples.len() {
            sorted.clear();
            sorted.extend_from_slice(&self.samples);
            // total_cmp: a NaN sample must not panic the sort (D02); it
            // sorts last, so finite percentiles stay meaningful.
            sorted.sort_by(f64::total_cmp);
        }
        let v = &*sorted;
        let pos = (q / 100.0) * (v.len() - 1) as f64;
        let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
        if lo == hi { v[lo] } else { v[lo] + (pos - lo as f64) * (v[hi] - v[lo]) }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Half-width of the 95% CI of the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        if self.samples.len() < 2 { return 0.0; }
        1.96 * self.std() / (self.samples.len() as f64).sqrt()
    }
}

/// Pretty time formatting: picks ns/µs/ms/s.
pub fn fmt_time(seconds: f64) -> String {
    let abs = seconds.abs();
    if abs >= 1.0 {
        format!("{seconds:.3} s")
    } else if abs >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if abs >= 1e-6 {
        format!("{:.3} µs", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

/// Pretty byte formatting: KB/MB/GB (powers of 1024, KB as in the paper).
pub fn fmt_bytes(bytes: u64) -> String {
    const K: u64 = 1024;
    if bytes >= K * K * K {
        format!("{:.1} GB", bytes as f64 / (K * K * K) as f64)
    } else if bytes >= K * K {
        format!("{:.1} MB", bytes as f64 / (K * K) as f64)
    } else if bytes >= K {
        format!("{} KB", bytes / K)
    } else {
        format!("{bytes} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_match_formulas() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for x in 1..=100 {
            s.add(x as f64);
        }
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 µs");
        assert_eq!(fmt_bytes(128 * 1024), "128 KB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MB");
    }

    #[test]
    fn percentile_tolerates_nan() {
        // Regression (D02): partial_cmp().unwrap() panicked here on NaN.
        let mut s = Summary::new();
        s.add(1.0);
        s.add(f64::NAN);
        s.add(3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.median(), 3.0); // NaN sorts last under total_cmp
        assert!(s.percentile(100.0).is_nan());
    }

    #[test]
    fn percentile_cache_invalidates_on_growth() {
        // The sorted view is cached between quantile reads; appending a
        // sample must rebuild it, and interleaved add/read sequences must
        // match a fresh clone-and-sort every time.
        let mut s = Summary::new();
        for x in [5.0, 1.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.median(), 5.0);
        assert_eq!(s.percentile(100.0), 9.0);
        s.add(11.0); // cache is stale now
        assert_eq!(s.percentile(100.0), 11.0);
        assert!((s.median() - 7.0).abs() < 1e-12);
        s.add(0.0);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.median(), 5.0);
        // A cloned summary keeps serving correct quantiles independently.
        let mut c = s.clone();
        c.add(100.0);
        assert_eq!(c.percentile(100.0), 100.0);
        assert_eq!(s.percentile(100.0), 11.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let mut small = Summary::new();
        let mut big = Summary::new();
        for i in 0..10 {
            small.add((i % 3) as f64);
        }
        for i in 0..1000 {
            big.add((i % 3) as f64);
        }
        assert!(big.ci95() < small.ci95());
    }
}
