//! Aligned console tables + CSV emission for the paper-figure harnesses.

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

/// A simple column-aligned table that can also dump CSV.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    meta: Vec<(String, String)>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            meta: Vec::new(),
        }
    }

    /// Attach one run-metadata pair (seed, deployment, crate version…).
    /// Rendered as `# key=value` comment lines after the title and ahead
    /// of the CSV header, so every emitted artifact is self-describing.
    /// Re-setting a key overwrites it.
    pub fn meta(&mut self, key: &str, value: &str) -> &mut Self {
        if let Some(kv) = self.meta.iter_mut().find(|(k, _)| k == key) {
            kv.1 = value.to_string();
        } else {
            self.meta.push((key.to_string(), value.to_string()));
        }
        self
    }

    pub fn metadata(&self) -> &[(String, String)] {
        &self.meta
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        for (k, v) in &self.meta {
            out.push_str(&format!("# {k}={v}\n"));
        }
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        for (k, v) in &self.meta {
            out.push_str(&format!("# {k}={v}\n"));
        }
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV next to the bench outputs (results/ is created on demand).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Format a speedup factor the way the paper quotes them ("1.72x").
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "123456".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        let lines: Vec<&str> = r.lines().collect();
        // all data lines share the same width
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["x,y".into(), "p\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"p\"\"q\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new("", &["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn speedup_format() {
        assert_eq!(fmt_speedup(1.7234), "1.72x");
    }

    #[test]
    fn meta_lines_render_after_title_and_lead_the_csv() {
        let mut t = Table::new("demo", &["a"]);
        t.meta("version", "0.1.0").meta("seed", "0xb0257");
        t.meta("version", "0.2.0"); // overwrite, no duplicate
        t.row(&["1".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[0], "== demo ==");
        assert_eq!(lines[1], "# version=0.2.0");
        assert_eq!(lines[2], "# seed=0xb0257");
        let csv = t.to_csv();
        assert!(csv.starts_with("# version=0.2.0\n# seed=0xb0257\na\n"));
        assert_eq!(t.metadata().len(), 2);
    }
}
