//! Deterministic PRNGs and distributions (no external `rand` dependency).
//!
//! [`SplitMix64`] seeds [`Xoshiro256pp`], the main generator. Distributions
//! cover what the workload generators need: uniform ints/floats, standard
//! normal (Box–Muller), exponential, and gamma (Marsaglia–Tsang), the last
//! being what the paper's Table 6 uses for bursty request arrivals
//! ("Burstiness 2.0 (Gamma distribution)").

/// SplitMix64: tiny, full-period seeder (Steele et al.).
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()], gauss_spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        // Lemire-style rejection-free-enough reduction (bias < 2^-64 * span).
        lo + (((self.next_u64() as u128 * (hi - lo) as u128) >> 64) as u64)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.usize(0, i + 1));
        }
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Exponential with mean `mean`.
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Gamma(shape k, scale θ) via Marsaglia–Tsang; supports k < 1 by the
    /// boost trick. Mean = k·θ.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // Gamma(k) = Gamma(k+1) * U^(1/k)
            let g = self.gamma(shape + 1.0, 1.0);
            return g * self.f64().max(1e-300).powf(1.0 / shape) * scale;
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gauss();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v * scale;
            }
        }
    }

    /// Log-normal with underlying N(mu, sigma).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gauss()).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_inclusive_exclusive() {
        let mut r = Rng::new(4);
        let mut seen_lo = false;
        for _ in 0..10_000 {
            let x = r.range(5, 8);
            assert!((5..8).contains(&x));
            seen_lo |= x == 5;
        }
        assert!(seen_lo);
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(6);
        let (k, theta) = (2.0, 1.5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(k, theta)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - k * theta).abs() < 0.05 * k * theta, "mean {mean}");
        assert!((var - k * theta * theta).abs() < 0.1 * k * theta * theta, "var {var}");
    }

    #[test]
    fn gamma_shape_below_one() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| r.gamma(0.5, 2.0)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(8);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(0.1)).sum::<f64>() / n as f64;
        assert!((mean - 0.1).abs() < 0.005, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
