//! Criterion-style wall-clock timing harness (vendored set has no
//! `criterion`). Used by `cargo bench` harnesses (`harness = false`) and the
//! performance pass.
//!
//! Mirrors the paper's microbenchmark methodology (§5): warm-up iterations
//! followed by timed iterations, reporting the mean per-call time.

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use std::time::Instant;

use super::stats::{fmt_time, Summary};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub summary: Summary,
}

impl Measurement {
    pub fn mean(&self) -> f64 {
        self.summary.mean()
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (p50 {:>10}, p99 {:>10}, n={})",
            self.name,
            fmt_time(self.summary.mean()),
            fmt_time(self.summary.median()),
            fmt_time(self.summary.percentile(99.0)),
            self.summary.n(),
        )
    }
}

/// Wall-clock stopwatch for one-shot timings (soak throughput, CI smoke
/// budgets) where the [`Bencher`]'s warmup/repeat machinery is overkill.
/// Lives here so wall-clock reads stay confined to the RealHw-classed
/// bench module — simulator code must never observe real time.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { t0: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

/// Benchmark runner with warmup and an adaptive iteration count.
pub struct Bencher {
    /// Target total measurement time per benchmark.
    pub target_secs: f64,
    /// Number of warm-up calls before timing.
    pub warmup: usize,
    /// Hard cap on timed iterations.
    pub max_iters: usize,
    /// Minimum timed iterations (even if slow).
    pub min_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        // Modest defaults: the full bench suite regenerates every paper
        // figure in one `cargo bench` run, so per-case budgets stay small.
        Bencher { target_secs: 0.5, warmup: 2, max_iters: 1000, min_iters: 3 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { target_secs: 0.2, warmup: 1, max_iters: 200, min_iters: 2 }
    }

    /// Time `f` repeatedly; each sample is one call's wall-clock seconds.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        // Pilot call to size the iteration count.
        let t0 = Instant::now();
        f();
        let pilot = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.target_secs / pilot) as usize)
            .clamp(self.min_iters, self.max_iters);
        let mut summary = Summary::new();
        summary.add(pilot);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            summary.add(t.elapsed().as_secs_f64());
        }
        Measurement { name: name.to_string(), iters: iters + 1, summary }
    }

    /// Time `f` and print the report line immediately.
    pub fn bench<F: FnMut()>(&self, name: &str, f: F) -> Measurement {
        let m = self.run(name, f);
        println!("{}", m.report());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleepy_closure() {
        let b = Bencher { target_secs: 0.02, warmup: 1, max_iters: 10, min_iters: 2 };
        let m = b.run("spin", || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert!(m.mean() >= 0.0015, "mean {} too small", m.mean());
        assert!(m.summary.n() >= 3);
    }

    #[test]
    fn adaptive_iteration_count_bounded() {
        let b = Bencher { target_secs: 0.01, warmup: 0, max_iters: 50, min_iters: 2 };
        let m = b.run("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(m.iters <= 51);
    }
}
