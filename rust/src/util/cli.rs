//! Minimal declarative CLI parser (vendored crate set has no `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! args, and auto-generated `--help`. Used by the `yalis` binary, all
//! examples, and all bench harnesses.

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct Opt {
    name: &'static str,
    // Owned so callers can build help text at runtime (e.g. listing the
    // registered machine bundles).
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument parser.
///
/// ```no_run
/// let mut cli = yalis::util::cli::Cli::new("demo", "example");
/// cli.opt("gpus", "16", "number of GPUs");
/// cli.flag("csv", "emit CSV");
/// let args = cli.parse_from(vec!["--gpus".into(), "32".into()]).unwrap();
/// assert_eq!(args.get_usize("gpus"), 32);
/// assert!(!args.get_flag("csv"));
/// ```
pub struct Cli {
    program: &'static str,
    about: &'static str,
    opts: Vec<Opt>,
}

/// Parsed argument values.
pub struct Args {
    values: BTreeMap<&'static str, String>,
    flags: BTreeMap<&'static str, bool>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Cli { program, about, opts: Vec::new() }
    }

    /// Option with a default value.
    pub fn opt(&mut self, name: &'static str, default: &str, help: &str) -> &mut Self {
        self.opts.push(Opt {
            name,
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Required option (no default).
    pub fn req(&mut self, name: &'static str, help: &str) -> &mut Self {
        self.opts.push(Opt { name, help: help.to_string(), default: None, is_flag: false });
        self
    }

    /// Boolean flag (default false).
    pub fn flag(&mut self, name: &'static str, help: &str) -> &mut Self {
        self.opts.push(Opt { name, help: help.to_string(), default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let kind = if o.is_flag {
                String::new()
            } else if let Some(d) = &o.default {
                format!(" <value> (default {d})")
            } else {
                " <value> (required)".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", o.name, kind, o.help));
        }
        s
    }

    /// Parse `std::env::args()` (skipping argv[0]); exits on `--help`.
    pub fn parse(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        if argv.iter().any(|a| a == "--help" || a == "-h") {
            println!("{}", self.usage());
            std::process::exit(0);
        }
        match self.parse_from(argv) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("error: {e}\n\n{}", self.usage());
                std::process::exit(2);
            }
        }
    }

    pub fn parse_from(&self, argv: Vec<String>) -> Result<Args, String> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name, false);
            } else if let Some(d) = &o.default {
                values.insert(o.name, d.clone());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}"))?;
                if opt.is_flag {
                    if inline.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    flags.insert(opt.name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it.next().ok_or_else(|| format!("--{name} needs a value"))?,
                    };
                    values.insert(opt.name, v);
                }
            } else {
                positional.push(arg);
            }
        }
        for o in &self.opts {
            if !o.is_flag && !values.contains_key(o.name) {
                return Err(format!("missing required option --{}", o.name));
            }
        }
        Ok(Args { values, flags, positional })
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option {name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or_else(|| panic!("flag {name} not declared"))
    }

    /// Parse an option's value with a fallible domain parser (e.g.
    /// `AllReduceImpl::by_name`). A rejected value exits with the parser's
    /// error message — a usable diagnostic, not a panic/backtrace.
    pub fn get_with<T, E: std::fmt::Display>(
        &self,
        name: &str,
        parse: impl FnOnce(&str) -> Result<T, E>,
    ) -> T {
        match parse(self.get(name)) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("error: --{name}: {e}");
                std::process::exit(2);
            }
        }
    }

    /// Comma-separated list parsed element-wise with a fallible domain
    /// parser (the list twin of [`Args::get_with`], e.g. for
    /// `ParallelSpec::by_name` or `RoutePolicy::by_name`). A rejected
    /// element exits with the parser's error message.
    pub fn get_list_with<T, E: std::fmt::Display>(
        &self,
        name: &str,
        parse: impl Fn(&str) -> Result<T, E>,
    ) -> Vec<T> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| match parse(s.trim()) {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("error: --{name}: {e}");
                    std::process::exit(2);
                }
            })
            .collect()
    }

    /// Comma-separated list of integers, e.g. `--gpus 4,8,16`.
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad integer '{s}'")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        let mut c = Cli::new("t", "test");
        c.opt("gpus", "8", "gpu count").flag("csv", "csv out").opt("sizes", "1,2", "list");
        c
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse_from(vec![]).unwrap();
        assert_eq!(a.get_usize("gpus"), 8);
        assert!(!a.get_flag("csv"));
        assert_eq!(a.get_usize_list("sizes"), vec![1, 2]);
    }

    #[test]
    fn overrides_and_inline() {
        let a = cli()
            .parse_from(vec!["--gpus=32".into(), "--csv".into(), "--sizes".into(), "4,8,16".into()])
            .unwrap();
        assert_eq!(a.get_usize("gpus"), 32);
        assert!(a.get_flag("csv"));
        assert_eq!(a.get_usize_list("sizes"), vec![4, 8, 16]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cli().parse_from(vec!["--nope".into()]).is_err());
    }

    #[test]
    fn missing_required_rejected() {
        let mut c = Cli::new("t", "test");
        c.req("model", "model name");
        assert!(c.parse_from(vec![]).is_err());
        assert!(c.parse_from(vec!["--model".into(), "70b".into()]).is_ok());
    }

    #[test]
    fn get_with_accepts_valid_values() {
        let a = cli().parse_from(vec!["--gpus".into(), "12".into()]).unwrap();
        let doubled = a.get_with("gpus", |s| s.parse::<usize>().map(|v| v * 2));
        assert_eq!(doubled, 24);
    }

    #[test]
    fn get_list_with_parses_each_element() {
        let a = cli().parse_from(vec!["--sizes".into(), " 3, 5 ,7".into()]).unwrap();
        let v = a.get_list_with("sizes", |s| s.parse::<usize>());
        assert_eq!(v, vec![3, 5, 7]);
    }

    #[test]
    fn positional_collected() {
        let a = cli().parse_from(vec!["foo".into(), "--gpus".into(), "4".into(), "bar".into()]).unwrap();
        assert_eq!(a.positional, vec!["foo", "bar"]);
    }
}
