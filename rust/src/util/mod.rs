//! From-scratch utility substrates.
//!
//! The build is fully offline against a minimal vendored crate set (no
//! `rand`, `clap`, `criterion`, `proptest`, `serde`), so the facilities a
//! production framework would pull from those crates are implemented here:
//!
//! - [`rng`] — SplitMix64 / xoshiro256++ PRNGs + distributions (uniform,
//!   normal, gamma — the gamma sampler drives the BurstGPT-style bursty
//!   arrival process).
//! - [`stats`] — streaming mean/variance, percentiles, confidence
//!   intervals.
//! - [`cli`] — a small declarative `--flag value` argument parser.
//! - [`prop`] — a property-based-testing harness (randomised cases with
//!   seed reporting on failure) standing in for `proptest`.
//! - [`tables`] — aligned console tables + CSV emission for the bench
//!   harnesses that regenerate the paper's tables and figures.
//! - [`bench`] — a criterion-style timing harness (warmup, adaptive
//!   iteration counts, mean/p50/p99).

pub mod bench;
pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tables;
