//! Property-based testing harness (vendored crate set has no `proptest`).
//!
//! [`check`] runs a property over `cases` randomised inputs drawn through a
//! [`Gen`]; on failure it panics with the failing case index and the seed so
//! the case can be replayed exactly. No shrinking — failures print the
//! generated values instead (callers format their inputs in the property's
//! panic message).
//!
//! ```no_run
//! use yalis::util::prop::{check, Gen};
//! check("addition commutes", 200, |g: &mut Gen| {
//!     let (a, b) = (g.i64(-100, 100), g.i64(-100, 100));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    pub case: usize,
    pub seed: u64,
}

impl Gen {
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range(lo, hi + 1)
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.rng.range(0, (hi - lo + 1) as u64) as i64
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    /// Power of two in `[2^lo_exp, 2^hi_exp]`.
    pub fn pow2(&mut self, lo_exp: u32, hi_exp: u32) -> usize {
        1usize << self.u64(lo_exp as u64, hi_exp as u64)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize(0, xs.len() - 1);
        &xs[i]
    }

    /// Vector of f32 data (the usual all-reduce message payload).
    pub fn f32_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Environment knob: `YALIS_PROP_SEED` replays a failure; `YALIS_PROP_CASES`
/// scales case counts up/down.
fn base_seed() -> u64 {
    std::env::var("YALIS_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC0FFEE)
}

fn scaled_cases(cases: usize) -> usize {
    match std::env::var("YALIS_PROP_CASES").ok().and_then(|s| s.parse::<f64>().ok()) {
        Some(f) => ((cases as f64 * f) as usize).max(1),
        None => cases,
    }
}

/// Run `property` over `cases` randomised inputs.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut property: F) {
    let base = base_seed();
    for case in 0..scaled_cases(cases) {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::new(seed), case, seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| property(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (replay with \
                 YALIS_PROP_SEED={base} YALIS_PROP_CASES=1):\n{msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("sum symmetric", 50, |g| {
            let a = g.i64(-1000, 1000);
            let b = g.i64(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failing_case() {
        check("always fails", 10, |g| {
            assert!(g.usize(0, 10) > 100, "value too small");
        });
    }

    #[test]
    fn gen_ranges_inclusive() {
        check("ranges", 200, |g| {
            let x = g.usize(3, 7);
            assert!((3..=7).contains(&x));
            let p = g.pow2(2, 5);
            assert!(p.is_power_of_two() && (4..=32).contains(&p));
        });
    }

    #[test]
    fn deterministic_cases() {
        let mut first: Vec<u64> = Vec::new();
        check("collect", 5, |g| first.push(g.u64(0, u64::MAX - 1)));
        let mut second: Vec<u64> = Vec::new();
        check("collect", 5, |g| second.push(g.u64(0, u64::MAX - 1)));
        assert_eq!(first, second);
    }
}
