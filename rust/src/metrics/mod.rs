//! Time-breakdown accounting — the Fig 3 / Fig 8 four-way decomposition.
//!
//! Every engine simulation accumulates per-GPU time into the same four
//! buckets the paper's Nsight+Pipit pipeline produces: *Matmul*, *Other
//! Comp.*, *Comm.*, and *Idle*.

/// Per-GPU time breakdown (seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Breakdown {
    pub matmul: f64,
    pub other_comp: f64,
    pub comm: f64,
    pub idle: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.matmul + self.other_comp + self.comm + self.idle
    }

    pub fn add(&mut self, o: &Breakdown) {
        self.matmul += o.matmul;
        self.other_comp += o.other_comp;
        self.comm += o.comm;
        self.idle += o.idle;
    }

    pub fn scale(&self, f: f64) -> Breakdown {
        Breakdown {
            matmul: self.matmul * f,
            other_comp: self.other_comp * f,
            comm: self.comm * f,
            idle: self.idle * f,
        }
    }

    /// Fill `idle` so the breakdown sums to `wall` (never negative).
    pub fn with_idle_to(mut self, wall: f64) -> Breakdown {
        let busy = self.matmul + self.other_comp + self.comm;
        self.idle = (wall - busy).max(0.0);
        self
    }

    /// Percentages of total, in bucket order (Fig 3's stacked bars).
    pub fn fractions(&self) -> [f64; 4] {
        let t = self.total();
        if t == 0.0 {
            return [0.0; 4];
        }
        [self.matmul / t, self.other_comp / t, self.comm / t, self.idle / t]
    }

    pub fn row_cells(&self) -> Vec<String> {
        [self.matmul, self.other_comp, self.comm, self.idle, self.total()]
            .iter()
            .map(|s| format!("{:.3}", s))
            .collect()
    }
}

/// A labelled span recorder for phase-wise timing (Fig 8's per-phase bars).
#[derive(Clone, Debug, Default)]
pub struct Spans {
    spans: Vec<(String, f64)>,
}

impl Spans {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, name: &str, secs: f64) {
        self.spans.push((name.to_string(), secs));
    }

    /// Total seconds across spans whose name matches `name`.
    pub fn total(&self, name: &str) -> f64 {
        self.spans.iter().filter(|(n, _)| n == name).map(|(_, s)| s).sum()
    }

    pub fn grand_total(&self) -> f64 {
        self.spans.iter().map(|(_, s)| s).sum()
    }

    pub fn names(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for (n, _) in &self.spans {
            if !out.contains(n) {
                out.push(n.clone());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_sums() {
        let mut b = Breakdown { matmul: 1.0, other_comp: 0.5, comm: 0.25, idle: 0.25 };
        assert_eq!(b.total(), 2.0);
        b.add(&Breakdown { matmul: 1.0, ..Default::default() });
        assert_eq!(b.matmul, 2.0);
        let f = b.fractions();
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn idle_fill_never_negative() {
        let b = Breakdown { matmul: 2.0, other_comp: 1.0, comm: 1.0, idle: 0.0 };
        assert_eq!(b.with_idle_to(5.0).idle, 1.0);
        assert_eq!(b.with_idle_to(1.0).idle, 0.0);
    }

    #[test]
    fn idle_fill_overwrites_preexisting_idle_and_clamps_busy_overrun() {
        // with_idle_to is a *fill*, not an add: stale idle is replaced.
        let b = Breakdown { matmul: 1.0, other_comp: 0.0, comm: 0.0, idle: 99.0 };
        assert_eq!(b.with_idle_to(4.0).idle, 3.0);
        // Busy exceeding the wall clamps to exactly zero (no negative
        // slot, and no NaN from e.g. fp-noise overruns).
        let over = Breakdown { matmul: 3.0, other_comp: 2.0, comm: 1.0, idle: 0.5 };
        let filled = over.with_idle_to(5.0);
        assert_eq!(filled.idle, 0.0);
        assert_eq!(filled.total(), 6.0); // busy buckets untouched
        // Zero wall, zero busy: a degenerate but valid all-zero result.
        let z = Breakdown::default().with_idle_to(0.0);
        assert_eq!(z.total(), 0.0);
    }

    #[test]
    fn fractions_of_empty_breakdown_are_zero_not_nan() {
        let f = Breakdown::default().fractions();
        assert_eq!(f, [0.0; 4]);
        for x in f {
            assert!(x.is_finite());
        }
    }

    #[test]
    fn scale_is_linear_per_bucket() {
        let b = Breakdown { matmul: 1.0, other_comp: 0.5, comm: 0.25, idle: 0.25 };
        let s = b.scale(4.0);
        assert_eq!(s.total(), 8.0);
        assert_eq!(s.matmul, 4.0);
        assert_eq!(b.scale(0.0).total(), 0.0);
    }

    #[test]
    fn spans_aggregate_by_name() {
        let mut s = Spans::new();
        s.record("comm", 1.0);
        s.record("matmul", 2.0);
        s.record("comm", 0.5);
        assert_eq!(s.total("comm"), 1.5);
        assert_eq!(s.grand_total(), 3.5);
        assert_eq!(s.names(), vec!["comm".to_string(), "matmul".to_string()]);
    }
}
