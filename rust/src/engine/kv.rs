//! Paged KV-cache manager — a real block allocator with PagedAttention's
//! invariants.
//!
//! The serving stack admits a request only if its KV pages fit; decode
//! steps append tokens and allocate pages on block-boundary crossings;
//! completion frees the pages. Invariants (property-tested):
//!
//! 1. a physical page is owned by at most one sequence at a time,
//! 2. allocated + free == total, always,
//! 3. a sequence's page count == ceil(tokens / page_size).

use std::collections::BTreeMap;

/// Sequence identifier.
pub type SeqId = u64;

/// Errors from the allocator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvError {
    OutOfPages,
    UnknownSeq,
    SeqExists,
}

/// A paged KV-cache block allocator.
#[derive(Clone, Debug)]
pub struct PagedKv {
    page_tokens: usize,
    free: Vec<u32>,
    seqs: BTreeMap<SeqId, SeqAlloc>,
    total_pages: usize,
}

#[derive(Clone, Debug)]
struct SeqAlloc {
    pages: Vec<u32>,
    tokens: usize,
}

impl PagedKv {
    pub fn new(total_pages: usize, page_tokens: usize) -> Self {
        assert!(page_tokens > 0 && total_pages > 0);
        PagedKv {
            page_tokens,
            free: (0..total_pages as u32).rev().collect(),
            seqs: BTreeMap::new(),
            total_pages,
        }
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages - self.free.len()
    }

    pub fn pages_needed(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Can a sequence of `tokens` be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_needed(tokens.max(1)) <= self.free.len()
    }

    /// Admit a new sequence holding `tokens` (its prompt, or the first
    /// chunk of it under chunked prefill). Allocates ceil(tokens/page)
    /// pages atomically (all or nothing).
    pub fn admit(&mut self, id: SeqId, tokens: usize) -> Result<(), KvError> {
        if self.seqs.contains_key(&id) {
            return Err(KvError::SeqExists);
        }
        let need = self.pages_needed(tokens.max(1));
        if need > self.free.len() {
            return Err(KvError::OutOfPages);
        }
        let pages = self.free.split_off(self.free.len() - need);
        self.seqs.insert(id, SeqAlloc { pages, tokens: tokens.max(1) });
        Ok(())
    }

    /// Pages the allocator owns in total.
    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// Grow an admitted sequence by `tokens` prompt tokens (the next
    /// prefill chunk): allocates the extra pages atomically (all or
    /// nothing). The partial-prompt twin of [`PagedKv::admit`].
    pub fn extend(&mut self, id: SeqId, tokens: usize) -> Result<(), KvError> {
        let s = self.seqs.get(&id).ok_or(KvError::UnknownSeq)?;
        let need = (s.tokens + tokens).div_ceil(self.page_tokens) - s.pages.len();
        if need > self.free.len() {
            return Err(KvError::OutOfPages);
        }
        let pages = self.free.split_off(self.free.len() - need);
        let s = self.seqs.get_mut(&id).expect("checked above");
        s.pages.extend(pages);
        s.tokens += tokens;
        Ok(())
    }

    /// Most tokens [`PagedKv::extend`] could append to `id` right now:
    /// the slack in its last page plus every free page.
    pub fn extend_capacity(&self, id: SeqId) -> usize {
        let Some(s) = self.seqs.get(&id) else { return 0 };
        let slack = s.pages.len() * self.page_tokens - s.tokens;
        slack + self.free.len() * self.page_tokens
    }

    /// Most tokens [`PagedKv::admit`] could grant a new sequence right now.
    pub fn admit_capacity(&self) -> usize {
        self.free.len() * self.page_tokens
    }

    /// Append one decoded token; allocates a page at block boundaries.
    pub fn append_token(&mut self, id: SeqId) -> Result<(), KvError> {
        // Two-phase to satisfy the borrow checker AND keep atomicity:
        // check first, then mutate.
        let need_page = {
            let s = self.seqs.get(&id).ok_or(KvError::UnknownSeq)?;
            s.tokens % self.page_tokens == 0
        };
        if need_page && self.free.is_empty() {
            return Err(KvError::OutOfPages);
        }
        let page = if need_page { self.free.pop() } else { None };
        let s = self.seqs.get_mut(&id).expect("checked above");
        if let Some(p) = page {
            s.pages.push(p);
        }
        s.tokens += 1;
        Ok(())
    }

    /// Release a finished sequence's pages.
    pub fn release(&mut self, id: SeqId) -> Result<(), KvError> {
        let s = self.seqs.remove(&id).ok_or(KvError::UnknownSeq)?;
        self.free.extend(s.pages);
        Ok(())
    }

    pub fn seq_tokens(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.tokens)
    }

    pub fn seq_pages(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.pages.len())
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Check invariants (used by property tests).
    pub fn check_invariants(&self) {
        let mut seen = std::collections::BTreeSet::new();
        for p in &self.free {
            assert!(seen.insert(*p), "page {p} duplicated in free list");
        }
        for (id, s) in &self.seqs {
            assert_eq!(
                s.pages.len(),
                s.tokens.div_ceil(self.page_tokens),
                "seq {id}: page count mismatch"
            );
            for p in &s.pages {
                assert!(seen.insert(*p), "page {p} double-owned (seq {id})");
            }
        }
        assert_eq!(seen.len(), self.total_pages, "page conservation violated");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    #[test]
    fn admit_append_release_cycle() {
        let mut kv = PagedKv::new(10, 16);
        kv.admit(1, 20).unwrap(); // 2 pages
        assert_eq!(kv.seq_pages(1), Some(2));
        assert_eq!(kv.used_pages(), 2);
        for _ in 0..12 {
            kv.append_token(1).unwrap();
        }
        assert_eq!(kv.seq_tokens(1), Some(32));
        assert_eq!(kv.seq_pages(1), Some(2));
        kv.append_token(1).unwrap(); // crosses boundary -> 3rd page
        assert_eq!(kv.seq_pages(1), Some(3));
        kv.release(1).unwrap();
        assert_eq!(kv.free_pages(), 10);
        kv.check_invariants();
    }

    #[test]
    fn admission_is_atomic() {
        let mut kv = PagedKv::new(3, 16);
        kv.admit(1, 17).unwrap(); // 2 pages
        assert_eq!(kv.admit(2, 30), Err(KvError::OutOfPages));
        assert_eq!(kv.free_pages(), 1); // nothing leaked
        kv.check_invariants();
    }

    #[test]
    fn duplicate_and_unknown_ids() {
        let mut kv = PagedKv::new(4, 8);
        kv.admit(1, 8).unwrap();
        assert_eq!(kv.admit(1, 8), Err(KvError::SeqExists));
        assert_eq!(kv.release(9), Err(KvError::UnknownSeq));
        assert_eq!(kv.append_token(9), Err(KvError::UnknownSeq));
    }

    #[test]
    fn out_of_pages_on_append_keeps_state() {
        let mut kv = PagedKv::new(1, 2);
        kv.admit(1, 2).unwrap();
        assert_eq!(kv.append_token(1), Err(KvError::OutOfPages));
        assert_eq!(kv.seq_tokens(1), Some(2)); // token not counted
        kv.check_invariants();
    }

    #[test]
    fn extend_grows_a_sequence_chunk_by_chunk() {
        let mut kv = PagedKv::new(8, 16);
        kv.admit(1, 10).unwrap(); // 1 page, 6 tokens of slack
        assert_eq!(kv.extend_capacity(1), 6 + 7 * 16);
        kv.extend(1, 6).unwrap(); // fills the page, no new allocation
        assert_eq!(kv.seq_pages(1), Some(1));
        kv.extend(1, 33).unwrap(); // 49 tokens -> 4 pages
        assert_eq!((kv.seq_tokens(1), kv.seq_pages(1)), (Some(49), Some(4)));
        kv.check_invariants();
    }

    #[test]
    fn extend_is_atomic_and_checks_ids() {
        let mut kv = PagedKv::new(3, 16);
        kv.admit(1, 16).unwrap();
        assert_eq!(kv.extend(9, 1), Err(KvError::UnknownSeq));
        assert_eq!(kv.extend(1, 100), Err(KvError::OutOfPages));
        assert_eq!((kv.seq_tokens(1), kv.free_pages()), (Some(16), 2)); // nothing leaked
        assert_eq!(kv.extend_capacity(1), 2 * 16);
        assert_eq!(kv.extend_capacity(9), 0);
        kv.check_invariants();
    }

    #[test]
    fn admit_capacity_tracks_free_pages() {
        let mut kv = PagedKv::new(4, 8);
        assert_eq!(kv.admit_capacity(), 32);
        kv.admit(1, 17).unwrap(); // 3 pages
        assert_eq!(kv.admit_capacity(), 8);
        assert_eq!(kv.total_pages(), 4);
    }

    #[test]
    fn property_no_double_booking_under_random_ops() {
        check("paged kv invariants", 30, |g: &mut Gen| {
            let pages = g.usize(1, 64);
            let page_tokens = g.usize(1, 32);
            let mut kv = PagedKv::new(pages, page_tokens);
            let mut live: Vec<SeqId> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize(10, 200) {
                match g.usize(0, 3) {
                    0 => {
                        let toks = g.usize(1, 100);
                        if kv.admit(next_id, toks).is_ok() {
                            live.push(next_id);
                        }
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let id = live[g.usize(0, live.len() - 1)];
                        let _ = kv.append_token(id);
                    }
                    2 if !live.is_empty() => {
                        let i = g.usize(0, live.len() - 1);
                        let id = live.swap_remove(i);
                        kv.release(id).unwrap();
                    }
                    3 if !live.is_empty() => {
                        let id = live[g.usize(0, live.len() - 1)];
                        let _ = kv.extend(id, g.usize(1, 50));
                    }
                    _ => {}
                }
                kv.check_invariants();
            }
        });
    }
}
