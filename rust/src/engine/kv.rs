//! Paged KV-cache manager — a **refcounted, content-addressed shared-page
//! allocator** with a radix-style prefix index (PagedAttention block
//! allocation + RadixAttention-style prefix caching).
//!
//! A prompt's content is identified by the conversation it belongs to: a
//! [`SessionId`] names a token stream, and block `b` of that stream is the
//! page key `(session, b)`. Admission matches a request's prompt against
//! cached page-aligned prefixes of its session ([`PagedKv::admit_prefix`]),
//! *shares* the hit pages by bumping their refcount, and charges only the
//! uncached suffix to the prefill state machine; the partially-filled tail
//! page of a hit is recomputed into a private copy (a COW fork — shared
//! pages are immutable full blocks, so decode never writes into one).
//! Completion promotes a sequence's full pages into the prefix index
//! ([`PagedKv::release_cached`]); unreferenced cached pages form an LRU
//! pool that is evicted on demand, so caching never costs capacity.
//!
//! Invariants (property-tested):
//!
//! 1. a page's refcount equals the number of live sequences holding it,
//! 2. every page is in exactly one of {free list, referenced, cached-idle},
//!    so `used + free == total` always (free counts cached-idle pages:
//!    they are reclaimable at zero cost),
//! 3. no page is ever freed or evicted while referenced,
//! 4. a sequence's page count == ceil(tokens / page_size), shared prefix
//!    included.

use std::collections::{BTreeMap, BTreeSet};

/// Sequence identifier.
pub type SeqId = u64;

/// Conversation identity of a prompt's token stream. Two requests share
/// cached prefix pages iff they carry the same session id (turn k+1 of a
/// chat re-sends turn k's whole context). Requests without sharing use a
/// unique id per request (see `Request::solo_session`).
pub type SessionId = u64;

/// Session ids with the high bit set are **solo**: single-shot content no
/// other request will ever re-send. Solo sequences are never indexed or
/// matched, so zero-sharing workloads keep the exclusive allocator's
/// behavior exactly — plain free-list pops, no eviction churn, clean
/// stats.
pub fn is_solo(session: SessionId) -> bool {
    session & (1 << 63) != 0
}

/// Errors from the allocator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvError {
    OutOfPages,
    UnknownSeq,
    SeqExists,
}

/// Cumulative prefix-cache counters (monotonic over the allocator's life).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvStats {
    /// Prompt tokens admitted through [`PagedKv::admit_prefix`] (the
    /// hit-rate denominator; re-prefills after preemption count again).
    pub prompt_tokens: u64,
    /// Prompt tokens served by sharing cached pages instead of recompute.
    pub hit_tokens: u64,
    /// Cached-idle pages reclaimed under allocation pressure (LRU).
    pub evictions: u64,
    /// Admissions whose cached prefix ended mid-page (or was capped at
    /// `prompt_len - 1`): the tail is copied, not shared.
    pub cow_forks: u64,
    /// Full pages promoted into the prefix index at completion.
    pub promotions: u64,
}

/// A paged KV-cache block allocator with refcounted shared pages.
#[derive(Clone, Debug)]
pub struct PagedKv {
    page_tokens: usize,
    total_pages: usize,
    free: Vec<u32>,
    /// Live-sequence references per page.
    refcount: Vec<u32>,
    /// Prefix-index key a page is registered under, if any.
    key_of: Vec<Option<(SessionId, u32)>>,
    /// The radix-style prefix index: `(session, block#) -> page`.
    index: BTreeMap<(SessionId, u32), u32>,
    /// Cached pages no live sequence references, in LRU order
    /// `(idle-tick, page)` — the eviction pool.
    evictable: BTreeSet<(u64, u32)>,
    /// Tick at which a page last became unreferenced (locates its
    /// `evictable` entry when it is re-pinned).
    idle_since: Vec<u64>,
    tick: u64,
    seqs: BTreeMap<SeqId, SeqAlloc>,
    stats: KvStats,
}

#[derive(Clone, Debug)]
struct SeqAlloc {
    pages: Vec<u32>,
    tokens: usize,
    /// Content identity for promotion at completion; `None` for sequences
    /// admitted without one (e.g. KV received over the wire).
    session: Option<SessionId>,
}

impl PagedKv {
    pub fn new(total_pages: usize, page_tokens: usize) -> Self {
        assert!(page_tokens > 0 && total_pages > 0);
        PagedKv {
            page_tokens,
            total_pages,
            free: (0..total_pages as u32).rev().collect(),
            refcount: vec![0; total_pages],
            key_of: vec![None; total_pages],
            index: BTreeMap::new(),
            evictable: BTreeSet::new(),
            idle_since: vec![0; total_pages],
            tick: 0,
            seqs: BTreeMap::new(),
            stats: KvStats::default(),
        }
    }

    /// Pages allocatable right now: the free list plus every cached page
    /// no live sequence references (evictable at zero cost).
    pub fn free_pages(&self) -> usize {
        self.free.len() + self.evictable.len()
    }

    /// Pages referenced by live sequences.
    pub fn used_pages(&self) -> usize {
        self.total_pages - self.free_pages()
    }

    /// Cached-idle pages (prefix-cache contents the LRU can evict).
    pub fn cached_pages(&self) -> usize {
        self.evictable.len()
    }

    /// Pages the allocator owns in total.
    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn pages_needed(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Can a sequence of `tokens` be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_needed(tokens.max(1)) <= self.free_pages()
    }

    /// Cumulative prefix-cache counters.
    pub fn stats(&self) -> KvStats {
        self.stats
    }

    // -- page lifecycle -------------------------------------------------

    /// Take one allocatable page, evicting the LRU cached-idle page if the
    /// free list is empty. `None` only when every page is referenced.
    fn acquire(&mut self) -> Option<u32> {
        if let Some(p) = self.free.pop() {
            return Some(p);
        }
        let &(t, p) = self.evictable.iter().next()?;
        self.evictable.remove(&(t, p));
        let key = self.key_of[p as usize].take().expect("evictable page is indexed");
        self.index.remove(&key);
        self.stats.evictions += 1;
        Some(p)
    }

    /// Reference a page (pulling it out of the eviction pool if cached).
    fn pin(&mut self, p: u32) {
        let i = p as usize;
        if self.refcount[i] == 0 && self.key_of[i].is_some() {
            let was = self.evictable.remove(&(self.idle_since[i], p));
            debug_assert!(was, "unreferenced cached page must be evictable");
        }
        self.refcount[i] += 1;
    }

    /// Drop one reference; an unreferenced page returns to the eviction
    /// pool if it is still indexed, else to the free list.
    fn unpin(&mut self, p: u32) {
        let i = p as usize;
        debug_assert!(self.refcount[i] > 0, "unpin of unreferenced page");
        self.refcount[i] -= 1;
        if self.refcount[i] == 0 {
            if self.key_of[i].is_some() {
                self.tick += 1;
                self.idle_since[i] = self.tick;
                self.evictable.insert((self.tick, p));
            } else {
                self.free.push(p);
            }
        }
    }

    // -- admission ------------------------------------------------------

    /// Admit a new sequence holding `tokens` with no content identity
    /// (e.g. KV received over the wire from a prefill replica): its pages
    /// are private — never shared, never promoted. Atomic (all or
    /// nothing).
    pub fn admit(&mut self, id: SeqId, tokens: usize) -> Result<(), KvError> {
        if self.seqs.contains_key(&id) {
            return Err(KvError::SeqExists);
        }
        let tokens = tokens.max(1);
        let need = self.pages_needed(tokens);
        if need > self.free_pages() {
            return Err(KvError::OutOfPages);
        }
        let mut pages = Vec::with_capacity(need);
        for _ in 0..need {
            let p = self.acquire().expect("capacity checked");
            self.pin(p);
            pages.push(p);
        }
        self.seqs.insert(id, SeqAlloc { pages, tokens, session: None });
        Ok(())
    }

    /// Longest cached page-aligned prefix of `session`'s stream a
    /// `prompt_len`-token prompt could share, in tokens. Capped one token
    /// short of the prompt: at least one suffix token must run through the
    /// model to produce the first logits.
    pub fn lookup_prefix(&self, session: SessionId, prompt_len: usize) -> usize {
        if is_solo(session) {
            return 0;
        }
        let max_pages = prompt_len.saturating_sub(1) / self.page_tokens;
        let mut hits = 0usize;
        while hits < max_pages && self.index.contains_key(&(session, hits as u32)) {
            hits += 1;
        }
        hits * self.page_tokens
    }

    /// One index walk answering both admission questions at once:
    /// `(cached_tokens, suffix_capacity_tokens)` — what
    /// [`PagedKv::admit_prefix`] would share, and the most uncached suffix
    /// tokens it could materialize right now. The capacity is tighter
    /// than [`PagedKv::admit_capacity`]: the admission pins the cached
    /// hit pages first, so hit pages currently sitting idle in the
    /// eviction pool are *not* allocatable suffix room — counting them
    /// (the naive bound) would overshoot and fail the admission's own
    /// capacity check under pressure.
    pub fn probe_prefix(&self, session: SessionId, prompt_len: usize) -> (usize, usize) {
        if is_solo(session) {
            return (0, self.admit_capacity());
        }
        let max_pages = prompt_len.saturating_sub(1) / self.page_tokens;
        let mut hits = 0usize;
        let mut idle_hits = 0usize;
        while hits < max_pages {
            match self.index.get(&(session, hits as u32)) {
                Some(&p) => {
                    if self.refcount[p as usize] == 0 {
                        idle_hits += 1;
                    }
                    hits += 1;
                }
                None => break,
            }
        }
        (hits * self.page_tokens, (self.free_pages() - idle_hits) * self.page_tokens)
    }

    /// Admit a new sequence whose prompt is `session`'s stream: the cached
    /// page-aligned prefix is **shared** (refcounts bumped — no recompute,
    /// no new pages), and only `chunk` uncached suffix tokens are
    /// materialized now (the first prefill chunk; the batcher extends the
    /// rest chunk by chunk). Returns the cached token count actually
    /// reused. Atomic: on `OutOfPages` nothing is pinned or allocated.
    pub fn admit_prefix(
        &mut self,
        id: SeqId,
        session: SessionId,
        prompt_len: usize,
        chunk: usize,
    ) -> Result<usize, KvError> {
        if self.seqs.contains_key(&id) {
            return Err(KvError::SeqExists);
        }
        let chunk = chunk.max(1);
        let max_pages =
            if is_solo(session) { 0 } else { prompt_len.saturating_sub(1) / self.page_tokens };
        let mut pages: Vec<u32> = Vec::new();
        while pages.len() < max_pages {
            match self.index.get(&(session, pages.len() as u32)) {
                Some(&p) => pages.push(p),
                None => break,
            }
        }
        // Pin the hits before sizing the suffix allocation so eviction
        // cannot reclaim them from under this admission.
        for &p in &pages {
            self.pin(p);
        }
        let cached = pages.len() * self.page_tokens;
        let tokens = cached + chunk;
        let need = self.pages_needed(tokens) - pages.len();
        if need > self.free_pages() {
            for &p in pages.iter().rev() {
                self.unpin(p);
            }
            return Err(KvError::OutOfPages);
        }
        // A cached continuation that ends mid-page (or was capped at
        // `prompt_len - 1`) cannot be shared at page granularity: the tail
        // page is recomputed into a private copy — a COW fork.
        if self.index.contains_key(&(session, pages.len() as u32)) {
            self.stats.cow_forks += 1;
        }
        for _ in 0..need {
            let p = self.acquire().expect("capacity checked");
            self.pin(p);
            pages.push(p);
        }
        self.stats.prompt_tokens += prompt_len as u64;
        self.stats.hit_tokens += cached as u64;
        self.seqs.insert(id, SeqAlloc { pages, tokens, session: Some(session) });
        Ok(cached)
    }

    // -- growth ---------------------------------------------------------

    /// Grow an admitted sequence by `tokens` prompt tokens (the next
    /// prefill chunk): allocates the extra pages atomically (all or
    /// nothing). The partial-prompt twin of [`PagedKv::admit_prefix`].
    pub fn extend(&mut self, id: SeqId, tokens: usize) -> Result<(), KvError> {
        let (cur_tokens, cur_pages) = {
            let s = self.seqs.get(&id).ok_or(KvError::UnknownSeq)?;
            (s.tokens, s.pages.len())
        };
        let need = (cur_tokens + tokens).div_ceil(self.page_tokens) - cur_pages;
        if need > self.free_pages() {
            return Err(KvError::OutOfPages);
        }
        let mut fresh = Vec::with_capacity(need);
        for _ in 0..need {
            let p = self.acquire().expect("capacity checked");
            self.pin(p);
            fresh.push(p);
        }
        let s = self.seqs.get_mut(&id).expect("checked above");
        s.pages.extend(fresh);
        s.tokens += tokens;
        Ok(())
    }

    /// Most tokens [`PagedKv::extend`] could append to `id` right now:
    /// the slack in its last page plus every allocatable page.
    pub fn extend_capacity(&self, id: SeqId) -> usize {
        let Some(s) = self.seqs.get(&id) else { return 0 };
        let slack = s.pages.len() * self.page_tokens - s.tokens;
        slack + self.free_pages() * self.page_tokens
    }

    /// Most tokens a *private* admission ([`PagedKv::admit`]) could
    /// materialize now. Prefix-aware admissions must use the tighter
    /// [`PagedKv::probe_prefix`] capacity instead: this bound counts idle
    /// cached hit pages the shared admission would pin, not allocate.
    pub fn admit_capacity(&self) -> usize {
        self.free_pages() * self.page_tokens
    }

    /// Append one decoded token; allocates a page at block boundaries
    /// (evicting the LRU cached-idle page under pressure). Decode always
    /// writes into a private page: shared pages are full blocks, and the
    /// tail of a shared admission is a COW copy.
    pub fn append_token(&mut self, id: SeqId) -> Result<(), KvError> {
        let need_page = {
            let s = self.seqs.get(&id).ok_or(KvError::UnknownSeq)?;
            s.tokens % self.page_tokens == 0
        };
        let page = if need_page {
            match self.acquire() {
                Some(p) => {
                    self.pin(p);
                    Some(p)
                }
                None => return Err(KvError::OutOfPages),
            }
        } else {
            None
        };
        let s = self.seqs.get_mut(&id).expect("checked above");
        if let Some(p) = page {
            s.pages.push(p);
        }
        s.tokens += 1;
        Ok(())
    }

    // -- release --------------------------------------------------------

    /// Release a sequence's references **without** caching its content
    /// (preemption / cancellation: the tokens will be re-produced, so the
    /// pages hold no trusted stream content to advertise).
    pub fn release(&mut self, id: SeqId) -> Result<(), KvError> {
        let s = self.seqs.remove(&id).ok_or(KvError::UnknownSeq)?;
        for p in s.pages {
            self.unpin(p);
        }
        Ok(())
    }

    /// Release a **completed** sequence, promoting its full pages into the
    /// prefix index under `(session, block#)` keys so future turns of the
    /// conversation can share them (decoded tokens are part of the stream:
    /// turn k+1's prompt re-sends turn k's response). Partial tail pages,
    /// sessionless sequences, and blocks whose key is already cached are
    /// simply unreferenced.
    pub fn release_cached(&mut self, id: SeqId) -> Result<(), KvError> {
        let s = self.seqs.remove(&id).ok_or(KvError::UnknownSeq)?;
        if let Some(session) = s.session.filter(|&sid| !is_solo(sid)) {
            let full = s.tokens / self.page_tokens;
            for (b, &p) in s.pages.iter().enumerate().take(full) {
                let key = (session, b as u32);
                let i = p as usize;
                if self.key_of[i].is_none() && !self.index.contains_key(&key) {
                    self.index.insert(key, p);
                    self.key_of[i] = Some(key);
                    self.stats.promotions += 1;
                }
            }
        }
        for p in s.pages {
            self.unpin(p);
        }
        Ok(())
    }

    // -- introspection --------------------------------------------------

    pub fn seq_tokens(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.tokens)
    }

    pub fn seq_pages(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.pages.len())
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Check invariants (used by property tests).
    pub fn check_invariants(&self) {
        let mut refs = vec![0u32; self.total_pages];
        for (id, s) in &self.seqs {
            assert_eq!(
                s.pages.len(),
                s.tokens.div_ceil(self.page_tokens),
                "seq {id}: page count mismatch"
            );
            for &p in &s.pages {
                refs[p as usize] += 1;
            }
        }
        let mut pooled = BTreeSet::new();
        for p in &self.free {
            assert_eq!(refs[*p as usize], 0, "page {p} freed while referenced");
            assert!(self.key_of[*p as usize].is_none(), "free page {p} still indexed");
            assert!(pooled.insert(*p), "page {p} duplicated in free list");
        }
        for &(t, p) in &self.evictable {
            assert_eq!(refs[p as usize], 0, "page {p} evictable while referenced");
            assert_eq!(self.idle_since[p as usize], t, "evictable tick mismatch for page {p}");
            assert!(self.key_of[p as usize].is_some(), "evictable page {p} not indexed");
            assert!(pooled.insert(p), "page {p} in two pools");
        }
        for (p, &rc) in self.refcount.iter().enumerate() {
            assert_eq!(rc, refs[p], "page {p}: refcount {rc} != {} live references", refs[p]);
            if rc > 0 {
                assert!(pooled.insert(p as u32), "page {p} pooled while referenced");
            }
        }
        assert_eq!(pooled.len(), self.total_pages, "page conservation violated");
        for (key, &p) in &self.index {
            assert_eq!(self.key_of[p as usize], Some(*key), "index/key_of disagree on page {p}");
        }
        assert_eq!(
            self.index.len(),
            self.key_of.iter().filter(|k| k.is_some()).count(),
            "orphaned key_of entries"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    #[test]
    fn admit_append_release_cycle() {
        let mut kv = PagedKv::new(10, 16);
        kv.admit(1, 20).unwrap(); // 2 pages
        assert_eq!(kv.seq_pages(1), Some(2));
        assert_eq!(kv.used_pages(), 2);
        for _ in 0..12 {
            kv.append_token(1).unwrap();
        }
        assert_eq!(kv.seq_tokens(1), Some(32));
        assert_eq!(kv.seq_pages(1), Some(2));
        kv.append_token(1).unwrap(); // crosses boundary -> 3rd page
        assert_eq!(kv.seq_pages(1), Some(3));
        kv.release(1).unwrap();
        assert_eq!(kv.free_pages(), 10);
        kv.check_invariants();
    }

    #[test]
    fn admission_is_atomic() {
        let mut kv = PagedKv::new(3, 16);
        kv.admit(1, 17).unwrap(); // 2 pages
        assert_eq!(kv.admit(2, 30), Err(KvError::OutOfPages));
        assert_eq!(kv.free_pages(), 1); // nothing leaked
        kv.check_invariants();
    }

    #[test]
    fn duplicate_and_unknown_ids() {
        let mut kv = PagedKv::new(4, 8);
        kv.admit(1, 8).unwrap();
        assert_eq!(kv.admit(1, 8), Err(KvError::SeqExists));
        assert_eq!(kv.admit_prefix(1, 9, 8, 8), Err(KvError::SeqExists));
        assert_eq!(kv.release(9), Err(KvError::UnknownSeq));
        assert_eq!(kv.release_cached(9), Err(KvError::UnknownSeq));
        assert_eq!(kv.append_token(9), Err(KvError::UnknownSeq));
    }

    #[test]
    fn out_of_pages_on_append_keeps_state() {
        let mut kv = PagedKv::new(1, 2);
        kv.admit(1, 2).unwrap();
        assert_eq!(kv.append_token(1), Err(KvError::OutOfPages));
        assert_eq!(kv.seq_tokens(1), Some(2)); // token not counted
        kv.check_invariants();
    }

    #[test]
    fn extend_grows_a_sequence_chunk_by_chunk() {
        let mut kv = PagedKv::new(8, 16);
        kv.admit(1, 10).unwrap(); // 1 page, 6 tokens of slack
        assert_eq!(kv.extend_capacity(1), 6 + 7 * 16);
        kv.extend(1, 6).unwrap(); // fills the page, no new allocation
        assert_eq!(kv.seq_pages(1), Some(1));
        kv.extend(1, 33).unwrap(); // 49 tokens -> 4 pages
        assert_eq!((kv.seq_tokens(1), kv.seq_pages(1)), (Some(49), Some(4)));
        kv.check_invariants();
    }

    #[test]
    fn extend_is_atomic_and_checks_ids() {
        let mut kv = PagedKv::new(3, 16);
        kv.admit(1, 16).unwrap();
        assert_eq!(kv.extend(9, 1), Err(KvError::UnknownSeq));
        assert_eq!(kv.extend(1, 100), Err(KvError::OutOfPages));
        assert_eq!((kv.seq_tokens(1), kv.free_pages()), (Some(16), 2)); // nothing leaked
        assert_eq!(kv.extend_capacity(1), 2 * 16);
        assert_eq!(kv.extend_capacity(9), 0);
        kv.check_invariants();
    }

    #[test]
    fn admit_capacity_tracks_free_pages() {
        let mut kv = PagedKv::new(4, 8);
        assert_eq!(kv.admit_capacity(), 32);
        kv.admit(1, 17).unwrap(); // 3 pages
        assert_eq!(kv.admit_capacity(), 8);
        assert_eq!(kv.total_pages(), 4);
    }

    #[test]
    fn completion_promotes_full_pages_and_next_turn_shares_them() {
        let mut kv = PagedKv::new(16, 16);
        // Turn 1 of session 7: 30-token prompt + 4 decoded tokens = 34
        // tokens = 2 full pages + a partial.
        assert_eq!(kv.admit_prefix(1, 7, 30, 30).unwrap(), 0);
        for _ in 0..4 {
            kv.append_token(1).unwrap();
        }
        kv.release_cached(1).unwrap();
        assert_eq!(kv.cached_pages(), 2, "two full pages promoted, partial freed");
        assert_eq!(kv.stats().promotions, 2);
        assert_eq!(kv.used_pages(), 0);
        // Turn 2 re-sends the whole 34-token context + 30 fresh tokens.
        assert_eq!(kv.lookup_prefix(7, 64), 32);
        let cached = kv.admit_prefix(2, 7, 64, 32).unwrap();
        assert_eq!(cached, 32, "both full pages shared");
        assert_eq!(kv.seq_tokens(2), Some(64));
        assert_eq!(kv.seq_pages(2), Some(4)); // 2 shared + 2 private
        assert_eq!(kv.stats().hit_tokens, 32);
        // The COW fork: block 2's cached copy did not exist, so no fork
        // counted here; a third fork over the same prefix shares again.
        let cached = kv.admit_prefix(3, 7, 40, 7).unwrap();
        assert_eq!(cached, 32);
        assert_eq!(kv.used_pages(), 2 + 2 + 1, "shared pages counted once");
        kv.check_invariants();
        kv.release(2).unwrap();
        kv.release(3).unwrap();
        assert_eq!(kv.used_pages(), 0);
        kv.check_invariants();
    }

    #[test]
    fn lookup_is_capped_one_token_short_of_the_prompt() {
        let mut kv = PagedKv::new(8, 16);
        kv.admit_prefix(1, 3, 32, 32).unwrap();
        kv.release_cached(1).unwrap(); // blocks 0 and 1 cached
        // A 32-token prompt fully covered by cache must still recompute
        // its last token: only block 0 is shareable.
        assert_eq!(kv.lookup_prefix(3, 32), 16);
        assert_eq!(kv.lookup_prefix(3, 33), 32);
        assert_eq!(kv.lookup_prefix(3, 16), 0);
        assert_eq!(kv.lookup_prefix(99, 64), 0);
        // The capped admission counts a COW fork: block 1 was cached but
        // the tail must be recomputed privately.
        let cached = kv.admit_prefix(2, 3, 32, 16).unwrap();
        assert_eq!(cached, 16);
        assert_eq!(kv.stats().cow_forks, 1);
        kv.check_invariants();
    }

    #[test]
    fn lru_eviction_reclaims_cached_pages_under_pressure() {
        let mut kv = PagedKv::new(4, 16);
        // Session 1 caches 2 pages, session 2 caches 1 (younger).
        kv.admit_prefix(1, 1, 33, 33).unwrap(); // 3 pages, 2 full
        kv.release_cached(1).unwrap();
        kv.admit_prefix(2, 2, 17, 17).unwrap(); // 2 pages, 1 full
        kv.release_cached(2).unwrap();
        assert_eq!(kv.cached_pages(), 3);
        assert_eq!(kv.free_pages(), 4);
        // A 4-page private admission must evict all three cached pages.
        kv.admit(3, 64).unwrap();
        assert_eq!(kv.stats().evictions, 3);
        assert_eq!(kv.lookup_prefix(1, 1000), 0, "session 1 evicted");
        assert_eq!(kv.lookup_prefix(2, 1000), 0, "session 2 evicted");
        kv.check_invariants();
        kv.release(3).unwrap();
        // LRU order: pin session 1's surviving... all evicted; re-prime and
        // check the oldest goes first.
        kv.admit_prefix(4, 1, 17, 17).unwrap();
        kv.release_cached(4).unwrap(); // session 1 block 0 cached (older)
        kv.admit_prefix(5, 2, 17, 17).unwrap();
        kv.release_cached(5).unwrap(); // session 2 block 0 cached (younger)
        kv.admit(6, 48).unwrap(); // needs 3 pages: 2 free + one eviction
        assert_eq!(kv.lookup_prefix(1, 1000), 0, "older entry evicted first");
        assert_eq!(kv.lookup_prefix(2, 17), 16, "younger entry survives");
        kv.check_invariants();
    }

    #[test]
    fn shared_pages_are_never_freed_while_referenced() {
        let mut kv = PagedKv::new(4, 16);
        kv.admit_prefix(1, 5, 17, 17).unwrap();
        kv.release_cached(1).unwrap(); // block 0 cached
        let cached = kv.admit_prefix(2, 5, 32, 16).unwrap();
        assert_eq!(cached, 16);
        // The shared page is pinned: filling the rest of the allocator
        // cannot evict it.
        kv.admit(3, 32).unwrap(); // takes the remaining 2 pages
        assert_eq!(kv.admit(4, 16), Err(KvError::OutOfPages));
        assert_eq!(kv.lookup_prefix(5, 17), 16, "pinned page still indexed");
        kv.check_invariants();
        // Releasing the sharer returns it to the cache, not the free list.
        kv.release(2).unwrap();
        assert_eq!(kv.cached_pages(), 1);
        kv.admit(4, 32).unwrap(); // 2 pages: drains the free list + evicts it
        assert_eq!(kv.lookup_prefix(5, 17), 0);
        kv.check_invariants();
    }

    #[test]
    fn probe_prefix_capacity_excludes_idle_hit_pages() {
        // 8 pages: 4 cached hits of session 7 (idle), 3 pinned privately,
        // 1 free. The naive admit_capacity counts the hits as allocatable
        // (5 pages), but an admit_prefix for session 7 pins them first —
        // only 1 page of suffix room actually exists.
        let mut kv = PagedKv::new(8, 16);
        kv.admit_prefix(1, 7, 64, 64).unwrap();
        kv.release_cached(1).unwrap(); // 4 full pages cached
        kv.admit(2, 48).unwrap(); // 3 private pages pinned
        assert_eq!(kv.admit_capacity(), 5 * 16);
        assert_eq!(kv.probe_prefix(7, 96), (64, 16));
        // Unrelated sessions see the full pool (their hits are empty).
        assert_eq!(kv.probe_prefix(99, 96), (0, 5 * 16));
        // A chunk within the tight bound admits; the naive bound fails
        // (this admission needs 2 pages with only 1 allocatable).
        assert_eq!(kv.admit_prefix(3, 7, 96, 32), Err(KvError::OutOfPages));
        let cached = kv.admit_prefix(3, 7, 96, 16).unwrap();
        assert_eq!(cached, 64);
        kv.check_invariants();
    }

    #[test]
    fn solo_sessions_never_index_or_evict() {
        // The zero-sharing fast path: solo completions promote nothing, so
        // single-shot workloads keep plain free-list behavior (no eviction
        // churn, clean stats).
        let mut kv = PagedKv::new(8, 16);
        let solo = (1 << 63) | 42u64;
        assert!(is_solo(solo));
        kv.admit_prefix(1, solo, 64, 64).unwrap();
        kv.release_cached(1).unwrap();
        assert_eq!(kv.cached_pages(), 0, "solo pages go straight to the free list");
        assert_eq!(kv.stats().promotions, 0);
        assert_eq!(kv.lookup_prefix(solo, 64), 0);
        assert_eq!(kv.probe_prefix(solo, 64), (0, kv.admit_capacity()));
        kv.admit(2, 8 * 16).unwrap(); // whole pool, zero evictions
        assert_eq!(kv.stats().evictions, 0);
        kv.check_invariants();
    }

    #[test]
    fn preempt_release_does_not_promote() {
        let mut kv = PagedKv::new(8, 16);
        kv.admit_prefix(1, 9, 40, 40).unwrap();
        kv.release(1).unwrap(); // preemption path
        assert_eq!(kv.cached_pages(), 0);
        assert_eq!(kv.stats().promotions, 0);
        assert_eq!(kv.lookup_prefix(9, 40), 0);
        assert_eq!(kv.free_pages(), 8);
        kv.check_invariants();
    }

    #[test]
    fn admit_prefix_is_atomic_under_pressure() {
        let mut kv = PagedKv::new(3, 16);
        kv.admit_prefix(1, 4, 17, 17).unwrap();
        kv.release_cached(1).unwrap(); // block 0 cached, 3 allocatable
        kv.admit(2, 33).unwrap(); // 3 pages: evicts the cached block too
        // Hit would have been 0 pages now; a too-big chunk fails cleanly.
        assert_eq!(kv.admit_prefix(3, 4, 64, 48), Err(KvError::OutOfPages));
        assert_eq!(kv.free_pages(), 0);
        assert_eq!(kv.active_seqs(), 1);
        kv.check_invariants();
    }

    #[test]
    fn property_shared_allocator_invariants_under_random_ops() {
        check("refcounted paged kv invariants", 30, |g: &mut Gen| {
            let pages = g.usize(1, 64);
            let page_tokens = g.usize(1, 32);
            let mut kv = PagedKv::new(pages, page_tokens);
            let mut live: Vec<SeqId> = Vec::new();
            let mut expect_tokens: std::collections::BTreeMap<SeqId, usize> =
                std::collections::BTreeMap::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize(10, 200) {
                match g.usize(0, 5) {
                    // Shared-prefix admission from a small session pool
                    // (collisions likely) or a unique session.
                    0 => {
                        let session =
                            if g.bool() { g.u64(0, 3) } else { (1 << 62) + next_id };
                        let prompt = g.usize(1, 100);
                        let chunk = g.usize(1, prompt);
                        if let Ok(cached) = kv.admit_prefix(next_id, session, prompt, chunk) {
                            assert!(cached < prompt, "at least one token recomputed");
                            assert_eq!(cached % page_tokens, 0, "hits are page-aligned");
                            live.push(next_id);
                            expect_tokens.insert(next_id, cached + chunk.max(1));
                        }
                        next_id += 1;
                    }
                    // Private admission (the handoff path).
                    1 => {
                        let toks = g.usize(1, 80);
                        if kv.admit(next_id, toks).is_ok() {
                            live.push(next_id);
                            expect_tokens.insert(next_id, toks);
                        }
                        next_id += 1;
                    }
                    2 if !live.is_empty() => {
                        let id = live[g.usize(0, live.len() - 1)];
                        if kv.append_token(id).is_ok() {
                            *expect_tokens.get_mut(&id).unwrap() += 1;
                        }
                    }
                    3 if !live.is_empty() => {
                        let id = live[g.usize(0, live.len() - 1)];
                        let grow = g.usize(1, 50);
                        if kv.extend(id, grow).is_ok() {
                            *expect_tokens.get_mut(&id).unwrap() += grow;
                        }
                    }
                    // Completion: promote into the cache.
                    4 if !live.is_empty() => {
                        let i = g.usize(0, live.len() - 1);
                        let id = live.swap_remove(i);
                        kv.release_cached(id).unwrap();
                        expect_tokens.remove(&id);
                    }
                    // Preemption: free without promoting.
                    5 if !live.is_empty() => {
                        let i = g.usize(0, live.len() - 1);
                        let id = live.swap_remove(i);
                        kv.release(id).unwrap();
                        expect_tokens.remove(&id);
                    }
                    _ => {}
                }
                // Token conservation: the allocator's view of every live
                // sequence matches the operations applied to it.
                for (id, toks) in &expect_tokens {
                    assert_eq!(kv.seq_tokens(*id), Some(*toks), "seq {id} token drift");
                }
                let s = kv.stats();
                assert!(s.hit_tokens <= s.prompt_tokens, "hits exceed admitted prompts");
                kv.check_invariants();
            }
            for id in live {
                kv.release_cached(id).unwrap();
            }
            assert_eq!(kv.used_pages(), 0, "no pages leaked");
            kv.check_invariants();
        });
    }
}
