//! Engine personas: the scheduling/overhead parameter sets that
//! differentiate YALIS, vLLM (V0/V1), and SGLang in the paper's Figs 1–2.
//!
//! The paper attributes inter-engine differences to (a) host scheduling
//! overhead per engine step, (b) CUDA-graph usage (kernel-launch
//! amortization), (c) kernel quality (compute efficiency), and (d) the
//! micro-batching policy used for pipeline parallelism. A persona is
//! exactly that parameter vector, layered on the shared simulator.

/// One engine's behavioural parameters.
#[derive(Clone, Copy, Debug)]
pub struct Persona {
    pub name: &'static str,
    /// Host/scheduler overhead added to every engine step (s).
    pub step_overhead: f64,
    /// Multiplier (≤ 1.03) on raw kernel efficiency: kernel quality.
    pub compute_efficiency: f64,
    /// Extra host latency per PP stage hand-off (Ray/NCCL p2p setup).
    pub p2p_overhead: f64,
    /// Micro-batch policy: micro-batches as a function of stage count.
    pub microbatch_factor: usize,
}

impl Persona {
    /// Micro-batches used for a `stages`-deep pipeline.
    pub fn microbatches(&self, stages: usize) -> usize {
        (self.microbatch_factor * stages).max(1)
    }

    /// YALIS: Torch-Compile kernels + CUDA graphs; minimal Slurm-friendly
    /// scheduler. (§3.1)
    pub fn yalis() -> Self {
        Persona {
            name: "YALIS",
            step_overhead: 1.0e-3,
            compute_efficiency: 0.97,
            p2p_overhead: 30.0e-6,
            microbatch_factor: 1,
        }
    }

    /// vLLM V1 (TP evaluations, v0.11.0): highly-tuned kernels, modest
    /// scheduler cost per step.
    pub fn vllm_v1() -> Self {
        Persona {
            name: "vLLM",
            step_overhead: 1.2e-3,
            compute_efficiency: 1.0,
            p2p_overhead: 30.0e-6,
            microbatch_factor: 1,
        }
    }

    /// vLLM V0 (HP evaluations, v0.10.0): Ray-based PP with heavier stage
    /// hand-offs and scheduler (the paper's Fig 11 shows it scaling worst).
    pub fn vllm_v0() -> Self {
        Persona {
            name: "vLLM-V0",
            step_overhead: 2.5e-3,
            compute_efficiency: 1.0,
            p2p_overhead: 250.0e-6,
            microbatch_factor: 2,
        }
    }

    /// SGLang (v0.5.1): comparable kernels; PP closer to TP than vLLM V0.
    pub fn sglang() -> Self {
        Persona {
            name: "SGLang",
            step_overhead: 1.5e-3,
            compute_efficiency: 0.99,
            p2p_overhead: 80.0e-6,
            microbatch_factor: 2,
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "yalis" => Self::yalis(),
            "vllm" | "vllm-v1" => Self::vllm_v1(),
            "vllm-v0" => Self::vllm_v0(),
            "sglang" => Self::sglang(),
            other => anyhow::bail!(
                "unknown persona '{other}' (expected yalis, vllm, vllm-v0 or sglang)"
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn personas_distinct() {
        let y = Persona::yalis();
        let v0 = Persona::vllm_v0();
        assert!(v0.p2p_overhead > y.p2p_overhead);
        assert!(v0.step_overhead > y.step_overhead);
    }

    #[test]
    fn microbatch_policy() {
        assert_eq!(Persona::yalis().microbatches(4), 4);
        assert_eq!(Persona::vllm_v0().microbatches(4), 8);
        assert_eq!(Persona::yalis().microbatches(0), 1);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Persona::by_name("YALIS").unwrap().name, "YALIS");
        assert_eq!(Persona::by_name("vllm-v0").unwrap().name, "vLLM-V0");
        let err = Persona::by_name("triton").unwrap_err().to_string();
        assert!(err.contains("sglang"), "{err}");
    }
}
