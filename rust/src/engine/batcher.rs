//! Continuous-batching scheduler (Orca/Sarathi-style) — the real admission
//! / step construction logic the serving simulator drives.
//!
//! Each engine step builds a batch from (a) running sequences needing one
//! decode token each, (b) in-flight **prefill chunks** of partially
//! prefilled prompts, and (c) waiting prompts admitted under three caps:
//! max concurrency, a per-step token budget, and KV-page availability.
//!
//! Prefill is **chunked**: a prompt longer than the per-step token budget
//! (or the configured `chunk_tokens` slice) is admitted in bounded slices
//! over successive steps, with KV pages allocated incrementally per chunk
//! — so a long prompt can never head-of-line-block the queue, and the
//! paper's §5.2.3 behaviour (mixed prefill/decode batches at low
//! concurrency, decode-only batches at high concurrency) still emerges
//! from exactly these rules. A sequence whose decode hits KV exhaustion is
//! **preempted** (pages released, re-queued to re-prefill its context),
//! never silently truncated: output tokens are conserved.
//!
//! Admission is **prefix-cache-aware**: a request's prompt is matched
//! against its session's cached page-aligned prefix
//! ([`PagedKv::lookup_prefix`]); hit pages are *shared* (refcounted), the
//! prefill state machine starts at `done = cached`, and only the uncached
//! suffix tokens ever become GEMM rows — while each chunk's `ctx` still
//! covers the full attended context, cached prefix included.

use super::kv::{KvError, PagedKv, SeqId, SessionId};
use std::collections::VecDeque;

/// One client request.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: SeqId,
    pub prompt_len: usize,
    pub decode_len: usize,
    pub arrival: f64,
    /// Conversation the prompt's content belongs to (prefix-cache
    /// identity): turns of one chat share it, unrelated requests use
    /// [`Request::solo_session`].
    pub session: SessionId,
}

impl Request {
    /// A session id no other request shares — the zero-sharing default
    /// for single-shot workloads. The high bit marks it solo (see
    /// [`crate::engine::kv::is_solo`]): the allocator never indexes or
    /// matches solo content, so these workloads keep the exclusive
    /// allocator's behavior exactly.
    pub fn solo_session(id: SeqId) -> SessionId {
        (1 << 63) | id
    }
}

/// One prefill chunk row of a step: `tokens` new prompt tokens fed to the
/// GEMMs, attending a `ctx`-token prefix. Cost models price the chunk's
/// GEMM rows against its *full* attended context, not just the chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefillChunk {
    pub id: SeqId,
    /// New prompt tokens processed this step (the GEMM rows).
    pub tokens: usize,
    /// Prompt tokens attended once this chunk completes (prefix + chunk).
    pub ctx: usize,
    /// This chunk finishes the prompt: its completion produces the
    /// sequence's first output token (TTFT fires here).
    pub last: bool,
}

/// What one engine step will execute.
#[derive(Clone, Debug, Default)]
pub struct StepBatch {
    /// Prefill chunk rows this step (whole prompts are a single chunk
    /// with `last = true`).
    pub prefills: Vec<PrefillChunk>,
    /// Sequences decoding one token this step.
    pub decodes: Vec<SeqId>,
    /// KV context length (prompt + tokens decoded so far) of each decode
    /// row, aligned with `decodes`. Read from the paged allocator when the
    /// step is built, so attention cost scales with real KV growth instead
    /// of a hardcoded mean.
    pub decode_ctx: Vec<usize>,
}

impl StepBatch {
    pub fn is_empty(&self) -> bool {
        self.prefills.is_empty() && self.decodes.is_empty()
    }

    /// Total token rows fed to the GEMMs this step.
    pub fn token_rows(&self) -> usize {
        self.prefills.iter().map(|c| c.tokens).sum::<usize>() + self.decodes.len()
    }

    /// Batch rows for the attention/all-reduce message (B of B×H).
    pub fn batch_rows(&self) -> usize {
        self.token_rows()
    }

    /// Sequences participating in this step (prefill chunks + decodes).
    pub fn seqs(&self) -> usize {
        self.prefills.len() + self.decodes.len()
    }

    /// Mean KV context length the attention kernels read this step:
    /// prefill chunks contribute their full attended prefix, decodes
    /// their current context. Computed and returned in f64 so a batch of
    /// many short contexts plus one long one is not truncated down a
    /// whole token bucket. Never below 1 (an empty batch reports 1).
    pub fn mean_ctx(&self) -> f64 {
        let n = self.seqs();
        if n == 0 {
            return 1.0;
        }
        let total = self.prefills.iter().map(|c| c.ctx).sum::<usize>()
            + self.decode_ctx.iter().sum::<usize>();
        (total as f64 / n as f64).max(1.0)
    }
}

/// What [`Batcher::complete_step`] did: produced tokens and any sequences
/// preempted (KV exhaustion) back to the waiting queue this step.
#[derive(Clone, Debug, Default)]
pub struct StepOutcome {
    /// Output tokens produced by this step: one per successful decode row
    /// plus one per completed (last-chunk) prefill.
    pub new_tokens: usize,
    /// Decoding sequences whose KV append failed: their pending token was
    /// discarded and they were re-queued to re-prefill their context, so
    /// the token total is conserved (they will re-produce it).
    pub preempted: Vec<SeqId>,
}

#[derive(Clone, Copy, Debug)]
struct Running {
    id: SeqId,
    remaining_decode: usize,
    session: SessionId,
}

/// One in-flight decode sequence torn out of a draining engine for KV
/// migration: its accumulated context (`ctx` tokens of KV) ships to a
/// peer, where `remaining_decode` output tokens are still to be produced.
#[derive(Clone, Copy, Debug)]
pub struct MigratedSeq {
    pub id: SeqId,
    pub ctx: usize,
    pub remaining_decode: usize,
    pub session: SessionId,
}

/// Everything a draining engine sheds via [`Batcher::drain_for_migration`].
#[derive(Clone, Debug)]
pub struct DrainedWork {
    /// Not-yet-admitted requests: re-route them, nothing to transfer.
    pub waiting: Vec<Request>,
    /// Partially-prefilled prompts: pages released, restarted elsewhere
    /// (counted as preemptions — their chunks are recomputed).
    pub restarts: Vec<Request>,
    /// Running decodes whose KV migrates to a peer.
    pub migrations: Vec<MigratedSeq>,
}

/// A sequence between waiting and running: admitted, `done` of `total`
/// prompt tokens prefilled. `decode_tokens` output tokens remain to be
/// produced once the prefill completes (the last chunk produces the
/// first of them) — carried here rather than looked up, so a preempted
/// sequence resumes with its *remaining* decode, not the original.
#[derive(Clone, Copy, Debug)]
struct Prefilling {
    id: SeqId,
    total: usize,
    done: usize,
    decode_tokens: usize,
    session: SessionId,
}

/// The continuous batcher.
#[derive(Clone, Debug)]
pub struct Batcher {
    pub max_concurrency: usize,
    /// Token budget per step (vLLM's max_num_batched_tokens analogue).
    pub max_step_tokens: usize,
    /// Per-sequence prefill chunk cap (0 = bounded only by the step
    /// budget and KV availability — Sarathi's "no chunking knob" mode).
    pub chunk_tokens: usize,
    waiting: VecDeque<Request>,
    prefilling: Vec<Prefilling>,
    running: Vec<Running>,
    finished: Vec<SeqId>,
    rejected: Vec<SeqId>,
    preemptions: u64,
    /// Retired [`StepBatch`]es returned via [`Batcher::recycle`]: the next
    /// [`Batcher::next_step`] reuses their vectors instead of growing
    /// fresh ones. At soak scale (~tens of millions of steps fleet-wide)
    /// the per-step `Vec` churn of the old path was a top allocation site.
    spare_steps: Vec<StepBatch>,
    /// Sorted decode-id scratch for [`Batcher::complete_step`] (replaces a
    /// per-step `BTreeSet` allocation; membership via binary search).
    decoded_scratch: Vec<SeqId>,
    /// Double buffer for the surviving-running compaction in
    /// [`Batcher::complete_step`]: swapped with `running` each step so
    /// neither vector is ever reallocated in steady state.
    still_scratch: Vec<Running>,
}

impl Batcher {
    pub fn new(max_concurrency: usize, max_step_tokens: usize) -> Self {
        Batcher {
            max_concurrency,
            max_step_tokens,
            chunk_tokens: 0,
            waiting: VecDeque::new(),
            prefilling: Vec::new(),
            running: Vec::new(),
            finished: Vec::new(),
            rejected: Vec::new(),
            preemptions: 0,
            spare_steps: Vec::new(),
            decoded_scratch: Vec::new(),
            still_scratch: Vec::new(),
        }
    }

    /// Return a completed step's buffers to the pool so the next
    /// [`Batcher::next_step`] builds into them instead of allocating.
    /// Purely an allocator optimization: recycling (or not) never changes
    /// what the next step contains.
    pub fn recycle(&mut self, mut step: StepBatch) {
        step.prefills.clear();
        step.decodes.clear();
        step.decode_ctx.clear();
        // One spare covers the serve/fleet loops' step-at-a-time cadence;
        // a small cap keeps a burst of returns from pinning memory.
        if self.spare_steps.len() < 4 {
            self.spare_steps.push(step);
        }
    }

    /// Cap prefill chunks at `tokens` per sequence per step (0 = uncapped).
    pub fn with_chunk_tokens(mut self, tokens: usize) -> Self {
        self.chunk_tokens = tokens;
        self
    }

    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn prefilling_len(&self) -> usize {
        self.prefilling.len()
    }

    pub fn idle(&self) -> bool {
        self.waiting.is_empty() && self.prefilling.is_empty() && self.running.is_empty()
    }

    /// Preemptions so far (decode KV exhaustion + stuck-prefill victims).
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Drain the list of sequences that finished since the last call.
    pub fn take_finished(&mut self) -> Vec<SeqId> {
        std::mem::take(&mut self.finished)
    }

    /// Drain the sequences rejected at admission since the last call: a
    /// request whose *lifetime* KV footprint (prompt + decode context)
    /// exceeds the whole allocator can never complete — admitting it
    /// would preempt-loop forever, so it is dropped with a trace.
    pub fn take_rejected(&mut self) -> Vec<SeqId> {
        std::mem::take(&mut self.rejected)
    }

    fn chunk_cap(&self) -> usize {
        if self.chunk_tokens == 0 {
            usize::MAX
        } else {
            self.chunk_tokens
        }
    }

    /// Build the next step: one decode row per running sequence, then the
    /// next chunk of every in-flight prefill, then admit waiting prompts
    /// (FCFS) under the caps — chunked, so admission can never stall on a
    /// prompt longer than the step budget. If nothing is schedulable but
    /// prefills are in flight (KV fully committed), the youngest prefill
    /// is preempted to guarantee progress.
    pub fn next_step(&mut self, kv: &mut PagedKv) -> StepBatch {
        let mut step = self.spare_steps.pop().unwrap_or_default();
        loop {
            step.prefills.clear();
            step.decodes.clear();
            step.decode_ctx.clear();
            let mut budget = self.max_step_tokens;

            // Decodes first: running sequences are never starved.
            for r in &self.running {
                if budget == 0 {
                    break;
                }
                step.decodes.push(r.id);
                step.decode_ctx.push(kv.seq_tokens(r.id).unwrap_or(1));
                budget -= 1;
            }

            // Continue in-flight prefills (admission order): they already
            // hold KV pages, so they outrank new admissions.
            let cap = self.chunk_cap();
            for p in &self.prefilling {
                if budget == 0 {
                    break;
                }
                let chunk = (p.total - p.done).min(cap).min(budget).min(kv.extend_capacity(p.id));
                if chunk == 0 {
                    continue; // KV-blocked; decodes/preemption will free pages
                }
                kv.extend(p.id, chunk).expect("extend_capacity checked");
                step.prefills.push(PrefillChunk {
                    id: p.id,
                    tokens: chunk,
                    ctx: p.done + chunk,
                    last: p.done + chunk == p.total,
                });
                budget -= chunk;
            }

            // Admit new prompts while caps allow (FCFS: a blocked head
            // keeps its place; an *infeasible* head is rejected).
            while let Some(req) = self.waiting.front().copied() {
                if kv.pages_needed(req.prompt_len + req.decode_len.saturating_sub(1))
                    > kv.total_pages()
                {
                    self.rejected.push(req.id);
                    self.waiting.pop_front();
                    continue;
                }
                if self.running.len() + self.prefilling.len() >= self.max_concurrency
                    || budget == 0
                {
                    break;
                }
                // Prefix-cache hit: the cached page-aligned prefix is
                // shared (pinned), not recomputed — only the uncached
                // suffix is charged to the prefill state machine. The
                // probe's suffix capacity excludes idle hit pages (the
                // admission pins them out of the allocatable pool first).
                let (cached, capacity) = kv.probe_prefix(req.session, req.prompt_len);
                let remaining = req.prompt_len - cached;
                let chunk = remaining.min(cap).min(budget).min(capacity);
                if chunk == 0 {
                    break; // no KV room for even one suffix token
                }
                let granted = kv
                    .admit_prefix(req.id, req.session, req.prompt_len, chunk)
                    .expect("probe_prefix capacity checked");
                debug_assert_eq!(granted, cached, "probe/admit prefix drift");
                self.prefilling.push(Prefilling {
                    id: req.id,
                    total: req.prompt_len,
                    done: cached,
                    decode_tokens: req.decode_len,
                    session: req.session,
                });
                step.prefills.push(PrefillChunk {
                    id: req.id,
                    tokens: chunk,
                    ctx: cached + chunk,
                    last: cached + chunk == req.prompt_len,
                });
                budget -= chunk;
                self.waiting.pop_front();
            }

            if !step.is_empty() || self.prefilling.is_empty() {
                return step;
            }
            // Stuck: prefills hold pages but none can extend and nothing
            // else is schedulable. Preempt the youngest (LIFO victim) so
            // the older ones can finish; no output tokens existed yet, so
            // nothing is lost. The loop is safe to retry because an empty
            // step implies this iteration made no KV allocations.
            let victim = self.prefilling.pop().expect("checked non-empty");
            kv.release(victim.id).expect("prefilling seq holds pages");
            self.preemptions += 1;
            self.waiting.push_front(Request {
                id: victim.id,
                prompt_len: victim.total,
                decode_len: victim.decode_tokens,
                arrival: 0.0,
                session: victim.session,
            });
        }
    }

    /// Admit a sequence whose prefill ran elsewhere (disaggregated
    /// prefill/decode serving): its prompt KV pages are allocated here and
    /// the sequence joins the running set directly — no prefill step is
    /// scheduled. The first output token was produced by the remote
    /// prefill, so `decode_len - 1` tokens remain to decode locally.
    pub fn submit_prefilled(&mut self, req: Request, kv: &mut PagedKv) -> Result<(), KvError> {
        kv.admit(req.id, req.prompt_len)?;
        let remaining = req.decode_len.saturating_sub(1);
        if remaining == 0 {
            kv.release(req.id).expect("just admitted");
            self.finished.push(req.id);
        } else {
            self.running.push(Running {
                id: req.id,
                remaining_decode: remaining,
                session: req.session,
            });
        }
        Ok(())
    }

    /// Tear every queued and in-flight sequence out of the batcher so a
    /// draining replica can hand its work to peers: waiting requests move
    /// untouched, partially-prefilled prompts are preempted (pages
    /// released, restarted elsewhere — possibly against *their* prefix
    /// cache), and running decodes release their pages here and migrate
    /// their accumulated KV context. Must not be called with a step in
    /// flight (the caller owns the step lifecycle); leaves the batcher
    /// idle.
    pub fn drain_for_migration(&mut self, kv: &mut PagedKv) -> DrainedWork {
        let waiting: Vec<Request> = std::mem::take(&mut self.waiting).into_iter().collect();
        let mut restarts = Vec::new();
        for p in std::mem::take(&mut self.prefilling) {
            kv.release(p.id).expect("prefilling seq holds pages");
            self.preemptions += 1;
            restarts.push(Request {
                id: p.id,
                prompt_len: p.total,
                decode_len: p.decode_tokens,
                arrival: 0.0,
                session: p.session,
            });
        }
        let mut migrations = Vec::new();
        for r in std::mem::take(&mut self.running) {
            let ctx = kv.seq_tokens(r.id).expect("running seq holds KV");
            kv.release(r.id).expect("running seq holds pages");
            migrations.push(MigratedSeq {
                id: r.id,
                ctx,
                remaining_decode: r.remaining_decode,
                session: r.session,
            });
        }
        DrainedWork { waiting, restarts, migrations }
    }

    /// Account the completion of a step: advance prefill chunks (a last
    /// chunk produces the first output token and moves the sequence to
    /// running), append one KV token per decode row, retire finished
    /// sequences. A decode row whose KV append fails is **preempted**:
    /// pages released, sequence re-queued to re-prefill its accumulated
    /// context with its remaining decode intact — tokens are conserved,
    /// never dropped.
    pub fn complete_step(&mut self, step: &StepBatch, kv: &mut PagedKv) -> StepOutcome {
        let mut outcome = StepOutcome::default();

        for c in &step.prefills {
            let idx = self
                .prefilling
                .iter()
                .position(|p| p.id == c.id)
                .expect("chunk of a known prefilling sequence");
            if c.last {
                let p = self.prefilling.remove(idx);
                debug_assert_eq!(p.done + c.tokens, p.total, "last chunk must finish the prompt");
                outcome.new_tokens += 1; // the prefill's first output token
                let remaining = p.decode_tokens.saturating_sub(1);
                if remaining == 0 {
                    kv.release_cached(p.id).unwrap();
                    self.finished.push(p.id);
                } else {
                    self.running.push(Running {
                        id: p.id,
                        remaining_decode: remaining,
                        session: p.session,
                    });
                }
            } else {
                self.prefilling[idx].done += c.tokens;
            }
        }

        // Decoded sequences: append a token, retire at their decode
        // length. Sorted-scratch binary search keeps this O(B log B) — a
        // `contains` scan per running sequence is quadratic per step,
        // which 100k-request traces turn into minutes of wall-clock —
        // and reusing the scratch vec (vs the old per-step `BTreeSet`)
        // makes the lookup allocation-free too.
        let mut decoded = std::mem::take(&mut self.decoded_scratch);
        decoded.clear();
        decoded.extend_from_slice(&step.decodes);
        decoded.sort_unstable();
        let mut still = std::mem::take(&mut self.still_scratch);
        still.clear();
        let mut requeue = Vec::new();
        for r in &self.running {
            if decoded.binary_search(&r.id).is_err() {
                still.push(*r);
                continue;
            }
            if kv.append_token(r.id).is_err() {
                // KV exhaustion: preempt. The pending token was never
                // stored, so it is re-produced after the re-prefill of
                // the full accumulated context (prompt + outputs so far).
                let ctx = kv.seq_tokens(r.id).expect("running seq holds KV");
                kv.release(r.id).unwrap();
                self.preemptions += 1;
                outcome.preempted.push(r.id);
                requeue.push(Request {
                    id: r.id,
                    prompt_len: ctx + 1,
                    decode_len: r.remaining_decode,
                    arrival: 0.0,
                    session: r.session,
                });
                continue;
            }
            outcome.new_tokens += 1;
            if r.remaining_decode <= 1 {
                // Completion promotes the sequence's full pages into the
                // prefix cache: the conversation's next turn re-sends this
                // whole context.
                kv.release_cached(r.id).unwrap();
                self.finished.push(r.id);
            } else {
                still.push(Running {
                    id: r.id,
                    remaining_decode: r.remaining_decode - 1,
                    session: r.session,
                });
            }
        }
        // Swap rather than assign: last step's running vec becomes next
        // step's still buffer, so neither ever reallocates in steady state.
        self.still_scratch = std::mem::replace(&mut self.running, still);
        self.decoded_scratch = decoded;
        // Preempted sequences re-queue at the front (they are the oldest
        // work), keeping their relative order.
        for rq in requeue.into_iter().rev() {
            self.waiting.push_front(rq);
        }
        outcome
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn req(id: u64, p: usize, d: usize) -> Request {
        Request {
            id,
            prompt_len: p,
            decode_len: d,
            arrival: 0.0,
            session: Request::solo_session(id),
        }
    }

    fn sreq(id: u64, session: u64, p: usize, d: usize) -> Request {
        Request { session, ..req(id, p, d) }
    }

    fn drive(
        reqs: Vec<Request>,
        conc: usize,
        pages: usize,
        budget: usize,
        chunk: usize,
    ) -> (usize, usize) {
        let mut kv = PagedKv::new(pages, 16);
        let mut b = Batcher::new(conc, budget).with_chunk_tokens(chunk);
        for r in &reqs {
            b.submit(*r);
        }
        let mut steps = 0;
        let mut done = 0;
        let mut tokens = 0usize;
        while !b.idle() {
            let step = b.next_step(&mut kv);
            assert!(!step.is_empty(), "live batcher must make progress");
            assert!(
                step.token_rows() <= budget,
                "step exceeded token budget: {} > {budget}",
                step.token_rows()
            );
            tokens += b.complete_step(&step, &mut kv).new_tokens;
            b.recycle(step);
            done += b.take_finished().len();
            steps += 1;
            kv.check_invariants();
            assert!(steps < 1_000_000, "runaway");
        }
        assert_eq!(done, reqs.len());
        assert_eq!(kv.used_pages(), 0);
        let expected: usize = reqs.iter().map(|r| r.decode_len).sum();
        assert_eq!(tokens, expected, "output tokens must be conserved");
        (steps, tokens)
    }

    fn drive_to_completion(reqs: Vec<Request>, conc: usize, pages: usize) -> usize {
        drive(reqs, conc, pages, 8192, 0).0
    }

    #[test]
    fn single_request_steps() {
        // 1 prefill step + (decode_len - 1) decode steps.
        let steps = drive_to_completion(vec![req(1, 10, 5)], 8, 64);
        assert_eq!(steps, 5);
    }

    #[test]
    fn concurrency_cap_respected() {
        let mut kv = PagedKv::new(1024, 16);
        let mut b = Batcher::new(2, 100_000);
        let reqs: Vec<Request> = (0..5).map(|i| req(i, 8, 4)).collect();
        for r in &reqs {
            b.submit(*r);
        }
        let step = b.next_step(&mut kv);
        assert_eq!(step.prefills.len(), 2);
        b.complete_step(&step, &mut kv);
        assert_eq!(b.running_len(), 2);
    }

    #[test]
    fn token_budget_chunks_prefills() {
        // 100-token budget, four 60-token prompts: the first admits whole,
        // the second gets the remaining 40 tokens as a partial chunk.
        let mut kv = PagedKv::new(1024, 16);
        let mut b = Batcher::new(64, 100);
        let reqs: Vec<Request> = (0..4).map(|i| req(i, 60, 2)).collect();
        for r in &reqs {
            b.submit(*r);
        }
        let step = b.next_step(&mut kv);
        assert_eq!(step.prefills.len(), 2);
        assert_eq!(step.token_rows(), 100);
        assert_eq!(
            step.prefills[0],
            PrefillChunk { id: 0, tokens: 60, ctx: 60, last: true }
        );
        assert_eq!(
            step.prefills[1],
            PrefillChunk { id: 1, tokens: 40, ctx: 40, last: false }
        );
    }

    #[test]
    fn long_prompt_is_chunked_across_steps_and_never_stalls() {
        // The bugfix: a prompt 4x the step budget used to be unadmittable
        // (head-of-line stall forever). Now it runs as budget-bounded
        // chunks; TTFT fires at the last chunk.
        let mut kv = PagedKv::new(4096, 16);
        let mut b = Batcher::new(8, 100);
        let reqs = vec![req(0, 400, 3)];
        b.submit(reqs[0]);
        for i in 0..4 {
            let step = b.next_step(&mut kv);
            assert_eq!(step.prefills.len(), 1);
            assert_eq!(step.prefills[0].tokens, 100);
            assert_eq!(step.prefills[0].ctx, 100 * (i + 1));
            assert_eq!(step.prefills[0].last, i == 3);
            assert!(step.decodes.is_empty());
            let out = b.complete_step(&step, &mut kv);
            assert_eq!(out.new_tokens, usize::from(i == 3));
        }
        assert_eq!(b.running_len(), 1);
        assert_eq!(kv.seq_tokens(0), Some(400));
        // Remaining decode proceeds normally.
        let step = b.next_step(&mut kv);
        assert_eq!(step.decodes, vec![0]);
        assert_eq!(step.decode_ctx, vec![400]);
    }

    #[test]
    fn chunked_prefill_interleaves_with_decodes() {
        // A short request decodes while a long prompt's chunks stream:
        // the long prompt no longer blocks the short one's admission.
        let mut kv = PagedKv::new(4096, 16);
        let mut b = Batcher::new(8, 64).with_chunk_tokens(32);
        let reqs = vec![req(0, 128, 4), req(1, 16, 4)];
        b.submit(reqs[0]);
        b.submit(reqs[1]);
        let s1 = b.next_step(&mut kv);
        // Chunk of 0 (32 tokens) + whole prompt of 1 (16 tokens).
        assert_eq!(s1.prefills.len(), 2);
        assert!(!s1.prefills[0].last && s1.prefills[1].last);
        b.complete_step(&s1, &mut kv);
        let s2 = b.next_step(&mut kv);
        assert_eq!(s2.decodes, vec![1], "short request decodes");
        assert_eq!(s2.prefills.len(), 1, "long prompt keeps chunking");
        assert_eq!(s2.prefills[0].ctx, 64);
        b.complete_step(&s2, &mut kv);
    }

    #[test]
    fn mixed_batches_at_low_concurrency() {
        // §5.2.3: with spare concurrency, later steps mix decodes+prefills.
        let mut kv = PagedKv::new(1024, 16);
        let mut b = Batcher::new(4, 100_000);
        let reqs: Vec<Request> = (0..4).map(|i| req(i, 32, 8)).collect();
        b.submit(reqs[0]);
        b.submit(reqs[1]);
        let s1 = b.next_step(&mut kv);
        b.complete_step(&s1, &mut kv);
        b.submit(reqs[2]);
        let s2 = b.next_step(&mut kv);
        assert!(!s2.decodes.is_empty() && !s2.prefills.is_empty(), "mixed batch expected");
        b.complete_step(&s2, &mut kv);
    }

    #[test]
    fn zero_free_kv_pages_blocks_admission_but_not_decodes() {
        // All pages consumed by the running sequence: new prompts must not
        // be admitted, while the running sequence keeps decoding.
        let mut kv = PagedKv::new(2, 16);
        let mut b = Batcher::new(8, 100_000);
        let reqs = vec![req(0, 31, 2), req(1, 8, 2)];
        b.submit(reqs[0]);
        b.submit(reqs[1]);
        let s1 = b.next_step(&mut kv);
        assert_eq!(s1.prefills.len(), 1, "only the 2-page prompt fits");
        assert_eq!(kv.free_pages(), 0);
        b.complete_step(&s1, &mut kv);
        // Zero free pages now: the next step must be decode-only.
        let s2 = b.next_step(&mut kv);
        assert!(s2.prefills.is_empty() && s2.decodes == vec![0]);
        b.complete_step(&s2, &mut kv);
        kv.check_invariants();
    }

    #[test]
    fn decode_kv_exhaustion_preempts_and_conserves_tokens() {
        // One page-pair of KV, a request whose decode crosses the page
        // boundary while another sequence pins the remaining pages: the
        // old code finished it early (silent token loss); now it preempts
        // and every output token is still produced.
        let reqs = vec![req(0, 30, 8), req(1, 30, 8)];
        let mut kv = PagedKv::new(4, 16);
        let mut b = Batcher::new(8, 8192);
        for r in &reqs {
            b.submit(*r);
        }
        let mut tokens = 0;
        let mut done = 0;
        let mut steps = 0;
        while !b.idle() {
            let step = b.next_step(&mut kv);
            assert!(!step.is_empty());
            tokens += b.complete_step(&step, &mut kv).new_tokens;
            done += b.take_finished().len();
            kv.check_invariants();
            steps += 1;
            assert!(steps < 10_000, "runaway");
        }
        assert_eq!(done, 2);
        assert_eq!(tokens, 16, "all decode tokens produced despite preemption");
        assert!(b.preemptions() > 0, "KV pressure must have preempted");
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn infeasible_request_is_rejected_not_stalled() {
        // Lifetime footprint (prompt + decode context) exceeds the whole
        // allocator: admitting would preempt-loop forever, so reject.
        let mut kv = PagedKv::new(2, 16);
        let mut b = Batcher::new(8, 8192);
        b.submit(req(7, 30, 20)); // 49-token context > 32
        b.submit(req(8, 8, 2));
        let step = b.next_step(&mut kv);
        assert_eq!(b.take_rejected(), vec![7]);
        assert_eq!(step.prefills.len(), 1, "queue keeps moving past the reject");
        assert_eq!(step.prefills[0].id, 8);
        b.complete_step(&step, &mut kv);
    }

    #[test]
    fn concurrency_cap_one_serializes_requests() {
        // C=1: requests run strictly one at a time, so total step count is
        // the sum of per-request step counts (1 prefill + d-1 decodes).
        let reqs: Vec<Request> = (0..3).map(|i| req(i, 8, 3 + i as usize)).collect();
        let expected: usize = reqs.iter().map(|r| r.decode_len).sum();
        let steps = drive_to_completion(reqs, 1, 64);
        assert_eq!(steps, expected);
    }

    #[test]
    fn submit_prefilled_joins_running_without_prefill_step() {
        let mut kv = PagedKv::new(64, 16);
        let mut b = Batcher::new(8, 8192);
        b.submit_prefilled(req(7, 40, 5), &mut kv).unwrap();
        assert_eq!(b.running_len(), 1);
        assert_eq!(kv.seq_pages(7), Some(3)); // ceil(40/16)
        let mut done = 0;
        let mut steps = 0;
        while !b.idle() {
            let step = b.next_step(&mut kv);
            assert!(step.prefills.is_empty(), "prefill ran remotely");
            b.complete_step(&step, &mut kv);
            done += b.take_finished().len();
            steps += 1;
        }
        // 4 local decode steps (the 5th token's prefill happened remotely).
        assert_eq!((steps, done), (4, 1));
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn submit_prefilled_single_token_finishes_immediately() {
        let mut kv = PagedKv::new(8, 16);
        let mut b = Batcher::new(8, 8192);
        b.submit_prefilled(req(3, 10, 1), &mut kv).unwrap();
        assert_eq!(b.running_len(), 0);
        assert_eq!(b.take_finished(), vec![3]);
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn submit_prefilled_out_of_pages_leaves_state_clean() {
        let mut kv = PagedKv::new(2, 16);
        let mut b = Batcher::new(8, 8192);
        assert_eq!(
            b.submit_prefilled(req(1, 100, 8), &mut kv),
            Err(crate::engine::kv::KvError::OutOfPages)
        );
        assert_eq!(b.running_len(), 0);
        assert_eq!(kv.free_pages(), 2);
        kv.check_invariants();
    }

    #[test]
    fn step_batches_carry_real_context_lengths() {
        let mut kv = PagedKv::new(64, 16);
        let mut b = Batcher::new(8, 8192);
        b.submit(req(0, 40, 4));
        let s1 = b.next_step(&mut kv); // prefill step
        assert!(s1.decode_ctx.is_empty());
        assert_eq!(s1.mean_ctx(), 40.0);
        b.complete_step(&s1, &mut kv);
        let s2 = b.next_step(&mut kv); // first decode reads the prompt KV
        assert_eq!(s2.decode_ctx, vec![40]);
        b.complete_step(&s2, &mut kv);
        let s3 = b.next_step(&mut kv); // context grew by the decoded token
        assert_eq!(s3.decode_ctx, vec![41]);
        assert_eq!(s3.mean_ctx(), 41.0);
        b.complete_step(&s3, &mut kv);
    }

    #[test]
    fn mean_ctx_does_not_truncate_mixed_batches() {
        // Many short + one long context: integer division used to eat a
        // whole token bucket; f64 keeps the fraction.
        let step = StepBatch {
            prefills: vec![],
            decodes: (0..4u64).collect(),
            decode_ctx: vec![10, 10, 10, 8191],
        };
        assert!((step.mean_ctx() - 8221.0 / 4.0).abs() < 1e-9);
    }

    #[test]
    fn recycling_steps_does_not_change_the_schedule() {
        // Step-buffer pooling is an allocator concern only: the exact
        // per-step contents must be identical with and without it.
        let reqs: Vec<Request> = (0..12u64)
            .map(|i| req(i, 20 + (i as usize * 7) % 50, 1 + (i as usize % 6)))
            .collect();
        let run = |recycle: bool| {
            let mut kv = PagedKv::new(96, 16);
            let mut b = Batcher::new(6, 64).with_chunk_tokens(24);
            for r in &reqs {
                b.submit(*r);
            }
            let mut log: Vec<(Vec<PrefillChunk>, Vec<SeqId>, Vec<usize>)> = Vec::new();
            let mut steps = 0;
            while !b.idle() {
                let step = b.next_step(&mut kv);
                b.complete_step(&step, &mut kv);
                log.push((step.prefills.clone(), step.decodes.clone(), step.decode_ctx.clone()));
                if recycle {
                    b.recycle(step);
                }
                steps += 1;
                assert!(steps < 100_000, "runaway");
            }
            log
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn property_all_requests_complete() {
        check("batcher completes everything", 20, |g: &mut Gen| {
            let n = g.usize(1, 30);
            let reqs: Vec<Request> = (0..n as u64)
                .map(|i| req(i, g.usize(1, 64), g.usize(1, 20)))
                .collect();
            let conc = g.usize(1, 16);
            let pages = g.usize(8, 256);
            drive_to_completion(reqs, conc, pages);
        });
    }

    #[test]
    fn shared_prefix_admission_skips_cached_tokens() {
        let mut kv = PagedKv::new(64, 16);
        let mut b = Batcher::new(8, 8192);
        // Turn 1 of session 7: 64-token prompt, 2 output tokens.
        b.submit(sreq(0, 7, 64, 2));
        while !b.idle() {
            let step = b.next_step(&mut kv);
            b.complete_step(&step, &mut kv);
        }
        assert_eq!(b.take_finished(), vec![0]);
        assert!(kv.cached_pages() > 0, "completion must promote pages");
        // Turn 2 re-sends the 66-token context + 14 fresh tokens: exactly
        // four full pages (64 tokens) are cached and shared, so the
        // prefill runs as a single 16-row chunk attending all 80 tokens.
        b.submit(sreq(1, 7, 80, 3));
        let step = b.next_step(&mut kv);
        assert_eq!(
            step.prefills,
            vec![PrefillChunk { id: 1, tokens: 16, ctx: 80, last: true }]
        );
        assert_eq!(step.token_rows(), 16, "cached tokens are not GEMM rows");
        b.complete_step(&step, &mut kv);
        assert_eq!(kv.seq_tokens(1), Some(80), "attention still sees the full context");
        let s = kv.stats();
        assert_eq!(s.hit_tokens, 64);
        // An unrelated request shares nothing.
        b.submit(req(2, 80, 1));
        let step = b.next_step(&mut kv);
        let row = step.prefills.iter().find(|c| c.id == 2).unwrap();
        assert_eq!((row.tokens, row.ctx), (80, 80));
        b.complete_step(&step, &mut kv);
        while !b.idle() {
            let step = b.next_step(&mut kv);
            b.complete_step(&step, &mut kv);
        }
        assert_eq!(kv.used_pages(), 0);
        kv.check_invariants();
    }

    #[test]
    fn fully_cached_prompt_still_computes_one_chunk() {
        let mut kv = PagedKv::new(64, 16);
        let mut b = Batcher::new(8, 8192);
        b.submit(sreq(0, 3, 32, 1)); // completes with exactly 32+...
        while !b.idle() {
            let step = b.next_step(&mut kv);
            b.complete_step(&step, &mut kv);
        }
        // A turn that re-sends exactly the cached 32 tokens: the hit is
        // capped one token short, so one suffix token still runs.
        b.submit(sreq(1, 3, 32, 1));
        let step = b.next_step(&mut kv);
        assert_eq!(
            step.prefills,
            vec![PrefillChunk { id: 1, tokens: 16, ctx: 32, last: true }]
        );
        b.complete_step(&step, &mut kv);
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn prefix_admission_under_kv_pressure_shrinks_the_chunk_not_panics() {
        // Regression: the suffix chunk used to be capped by the naive
        // admit_capacity, which counts the idle cached hit pages the
        // admission itself is about to pin — under KV pressure the
        // capacity re-check inside admit_prefix then failed and the
        // `expect` aborted. The chunk must instead shrink to the real
        // suffix room and the prompt continue chunk by chunk.
        let mut kv = PagedKv::new(8, 16);
        let mut b = Batcher::new(8, 8192);
        let mut tokens = 0usize;
        // Turn 1 of session 7 caches a 64-token prefix (4 of 8 pages).
        b.submit(sreq(0, 7, 64, 1));
        while !b.idle() {
            let step = b.next_step(&mut kv);
            tokens += b.complete_step(&step, &mut kv).new_tokens;
        }
        // A live private sequence pins 3 more pages: 1 page truly free.
        b.submit(req(1, 48, 8));
        let s = b.next_step(&mut kv);
        tokens += b.complete_step(&s, &mut kv).new_tokens;
        assert_eq!(b.running_len(), 1);
        // Turn 2 re-sends 96 tokens: 64 cached + 32 suffix, but only one
        // page of suffix room exists right now.
        b.submit(sreq(2, 7, 96, 1));
        let s = b.next_step(&mut kv);
        let row = s.prefills.iter().find(|c| c.id == 2).expect("admitted, not panicked");
        assert_eq!((row.tokens, row.ctx, row.last), (16, 80, false));
        tokens += b.complete_step(&s, &mut kv).new_tokens;
        // Everything still completes and conserves tokens (the pinned
        // decode may preempt and re-produce under this pressure).
        let mut steps = 0;
        while !b.idle() {
            let step = b.next_step(&mut kv);
            assert!(!step.is_empty());
            tokens += b.complete_step(&step, &mut kv).new_tokens;
            kv.check_invariants();
            steps += 1;
            assert!(steps < 10_000, "runaway");
        }
        assert_eq!(tokens, 1 + 8 + 1, "all output tokens produced");
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn drain_for_migration_empties_the_batcher_and_conserves_kv() {
        let mut kv = PagedKv::new(64, 16);
        let mut b = Batcher::new(2, 100).with_chunk_tokens(32);
        b.submit(req(0, 16, 8)); // will be running
        b.submit(req(1, 200, 4)); // will be mid-prefill
        b.submit(req(2, 64, 2)); // stays waiting (concurrency cap)
        let s1 = b.next_step(&mut kv);
        b.complete_step(&s1, &mut kv);
        let s2 = b.next_step(&mut kv);
        b.complete_step(&s2, &mut kv);
        assert_eq!(b.running_len(), 1);
        assert_eq!(b.prefilling_len(), 1);
        let work = b.drain_for_migration(&mut kv);
        assert!(b.idle(), "drained batcher must be empty");
        assert_eq!(kv.used_pages(), 0, "every page released");
        assert_eq!(work.migrations.len(), 1);
        let m = work.migrations[0];
        assert_eq!(m.id, 0);
        // Migration ships exactly the *stored* KV: prompt (16) plus the one
        // decode that was appended. (The newest produced token is never in
        // KV until the next append — unlike preemption, nothing was
        // discarded, so there is no +1 to re-produce.)
        assert_eq!(m.ctx, 16 + 1, "stored context migrates");
        assert_eq!(m.remaining_decode, 8 - 2, "two tokens already produced");
        assert_eq!(work.restarts.len(), 1);
        assert_eq!((work.restarts[0].id, work.restarts[0].prompt_len), (1, 200));
        assert_eq!(work.waiting.len(), 1);
        assert_eq!(work.waiting[0].id, 2);
        assert!(b.preemptions() >= 1, "restarted prefills count as preemptions");
        kv.check_invariants();
    }

    #[test]
    fn property_session_turns_share_and_conserve() {
        // Multi-turn sessions through the full batcher loop: output tokens
        // are conserved regardless of sharing, and at least some admission
        // hits the cache when turns extend one another.
        check("session turns conserve tokens", 15, |g: &mut Gen| {
            let sessions = g.usize(1, 4);
            let turns = g.usize(2, 4);
            let mut reqs = Vec::new();
            let mut id = 0u64;
            for s in 0..sessions as u64 {
                let mut context = 0usize;
                for _ in 0..turns {
                    let fresh = g.usize(1, 40);
                    let out = g.usize(1, 8);
                    reqs.push(sreq(id, s, context + fresh, out));
                    context += fresh + out;
                    id += 1;
                }
            }
            // Interleave sessions round-robin (ids stay dense per submit
            // order is irrelevant to the batcher).
            let mut kv = PagedKv::new(g.usize(64, 256), g.usize(4, 16));
            let mut b = Batcher::new(g.usize(2, 8), g.usize(32, 128));
            for r in &reqs {
                b.submit(*r);
            }
            let mut tokens = 0usize;
            let mut steps = 0;
            while !b.idle() {
                let step = b.next_step(&mut kv);
                assert!(!step.is_empty(), "live batcher must make progress");
                tokens += b.complete_step(&step, &mut kv).new_tokens;
                b.take_finished();
                kv.check_invariants();
                steps += 1;
                assert!(steps < 1_000_000, "runaway");
            }
            let expected: usize = reqs.iter().map(|r| r.decode_len).sum();
            assert_eq!(tokens, expected, "output tokens conserved with sharing");
            assert_eq!(kv.used_pages(), 0);
        });
    }

    #[test]
    fn property_chunked_prefill_conserves_and_respects_budget() {
        // For any chunk size and budget, chunked prefill conserves output
        // tokens, never exceeds the per-step budget, is deterministic,
        // and leaks no KV pages (drive asserts all four).
        check("chunked prefill conserves tokens", 20, |g: &mut Gen| {
            let n = g.usize(1, 20);
            let budget = g.usize(16, 256);
            let chunk = if g.bool() { 0 } else { g.usize(1, 128) };
            // Prompts up to 4x the step budget: the old admission path
            // would stall on these forever.
            let reqs: Vec<Request> = (0..n as u64)
                .map(|i| req(i, g.usize(1, 4 * budget), g.usize(1, 16)))
                .collect();
            let conc = g.usize(1, 12);
            let pages = g.usize(80, 320); // >= ceil((4*256+16)/16)
            let a = drive(reqs.clone(), conc, pages, budget, chunk);
            let b = drive(reqs, conc, pages, budget, chunk);
            assert_eq!(a, b, "chunked serving must be deterministic");
        });
    }
}
