//! Continuous-batching scheduler (Orca-style) — the real admission / step
//! construction logic the serving simulator drives.
//!
//! Each engine step builds a batch from (a) running sequences needing one
//! decode token each and (b) waiting prompts admitted under three caps:
//! max concurrency, a per-step token budget (prefill chunks count their
//! full prompt), and KV-page availability. The paper's §5.2.3 behaviour —
//! mixed prefill/decode batches at low concurrency, decode-only batches at
//! high concurrency — emerges from exactly these rules.

use super::kv::{KvError, PagedKv, SeqId};
use std::collections::VecDeque;

/// One client request.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: SeqId,
    pub prompt_len: usize,
    pub decode_len: usize,
    pub arrival: f64,
}

/// What one engine step will execute.
#[derive(Clone, Debug, Default)]
pub struct StepBatch {
    /// Sequences doing their prefill this step (id, prompt tokens).
    pub prefills: Vec<(SeqId, usize)>,
    /// Sequences decoding one token this step.
    pub decodes: Vec<SeqId>,
    /// KV context length (prompt + tokens decoded so far) of each decode
    /// row, aligned with `decodes`. Read from the paged allocator when the
    /// step is built, so attention cost scales with real KV growth instead
    /// of a hardcoded mean.
    pub decode_ctx: Vec<usize>,
}

impl StepBatch {
    pub fn is_empty(&self) -> bool {
        self.prefills.is_empty() && self.decodes.is_empty()
    }

    /// Total token rows fed to the GEMMs this step.
    pub fn token_rows(&self) -> usize {
        self.prefills.iter().map(|(_, t)| *t).sum::<usize>() + self.decodes.len()
    }

    /// Batch rows for the attention/all-reduce message (B of B×H).
    pub fn batch_rows(&self) -> usize {
        self.token_rows()
    }

    /// Mean KV context length the attention kernels read this step:
    /// prefills contribute their prompt, decodes their current context.
    /// Never 0 (an empty batch reports 1).
    pub fn mean_ctx(&self) -> usize {
        let n = self.prefills.len() + self.decodes.len();
        if n == 0 {
            return 1;
        }
        let total: usize = self.prefills.iter().map(|(_, t)| *t).sum::<usize>()
            + self.decode_ctx.iter().sum::<usize>();
        (total / n).max(1)
    }
}

#[derive(Clone, Copy, Debug)]
struct Running {
    id: SeqId,
    remaining_decode: usize,
}

/// The continuous batcher.
#[derive(Clone, Debug)]
pub struct Batcher {
    pub max_concurrency: usize,
    /// Token budget per step (vLLM's max_num_batched_tokens analogue).
    pub max_step_tokens: usize,
    waiting: VecDeque<Request>,
    running: Vec<Running>,
    finished: Vec<SeqId>,
}

impl Batcher {
    pub fn new(max_concurrency: usize, max_step_tokens: usize) -> Self {
        Batcher {
            max_concurrency,
            max_step_tokens,
            waiting: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    /// Drain the list of sequences that finished since the last call.
    pub fn take_finished(&mut self) -> Vec<SeqId> {
        std::mem::take(&mut self.finished)
    }

    /// Build the next step: admit waiting prompts (FCFS) under the caps,
    /// then add one decode token for every running sequence.
    pub fn next_step(&mut self, kv: &mut PagedKv) -> StepBatch {
        let mut step = StepBatch::default();
        let mut budget = self.max_step_tokens;

        // Decodes first: running sequences are never starved.
        for r in &self.running {
            if budget == 0 {
                break;
            }
            step.decodes.push(r.id);
            step.decode_ctx.push(kv.seq_tokens(r.id).unwrap_or(1));
            budget -= 1;
        }

        // Admit new prompts while caps allow.
        while let Some(req) = self.waiting.front().copied() {
            if self.running.len() + step.prefills.len() >= self.max_concurrency
                || req.prompt_len > budget
                || !kv.can_admit(req.prompt_len)
            {
                break;
            }
            kv.admit(req.id, req.prompt_len).expect("can_admit checked");
            step.prefills.push((req.id, req.prompt_len));
            budget -= req.prompt_len;
            self.waiting.pop_front();
        }
        step
    }

    /// Admit a sequence whose prefill ran elsewhere (disaggregated
    /// prefill/decode serving): its prompt KV pages are allocated here and
    /// the sequence joins the running set directly — no prefill step is
    /// scheduled. The first output token was produced by the remote
    /// prefill, so `decode_len - 1` tokens remain to decode locally.
    pub fn submit_prefilled(&mut self, req: Request, kv: &mut PagedKv) -> Result<(), KvError> {
        kv.admit(req.id, req.prompt_len)?;
        let remaining = req.decode_len.saturating_sub(1);
        if remaining == 0 {
            kv.release(req.id).expect("just admitted");
            self.finished.push(req.id);
        } else {
            self.running.push(Running { id: req.id, remaining_decode: remaining });
        }
        Ok(())
    }

    /// Account the completion of a step: append KV tokens, retire finished
    /// sequences, move prefilled sequences into the running set.
    pub fn complete_step(&mut self, step: &StepBatch, kv: &mut PagedKv, reqs: &[Request]) {
        self.complete_step_by(step, kv, |id| {
            *reqs.iter().find(|r| r.id == id).expect("request known")
        })
    }

    /// [`Self::complete_step`] with a caller-supplied request lookup. The
    /// fleet layer routes by dense request index, so its lookup is O(1)
    /// where the slice search above is O(n) — the difference between a
    /// 100k-request trace finishing and quadratic blow-up.
    pub fn complete_step_by<F>(&mut self, step: &StepBatch, kv: &mut PagedKv, lookup: F)
    where
        F: Fn(SeqId) -> Request,
    {
        // Prefilled sequences start decoding (their first token was
        // produced by the prefill itself).
        for (id, _) in &step.prefills {
            let req = lookup(*id);
            let remaining = req.decode_len.saturating_sub(1);
            if remaining == 0 {
                kv.release(*id).unwrap();
                self.finished.push(*id);
            } else {
                self.running.push(Running { id: *id, remaining_decode: remaining });
            }
        }
        // Decoded sequences: append a token, retire at their decode length.
        // Set lookup: the O(B) `contains` scan per running sequence is
        // quadratic per step, which the fleet's 100k-request traces turn
        // into minutes of wall-clock.
        let decoded: std::collections::BTreeSet<SeqId> = step.decodes.iter().copied().collect();
        let mut still = Vec::with_capacity(self.running.len());
        for r in &self.running {
            if !decoded.contains(&r.id) {
                still.push(*r);
                continue;
            }
            if kv.append_token(r.id).is_err() {
                // KV exhaustion: finish the sequence early (real engines
                // would preempt; completion keeps the simulation total).
                kv.release(r.id).unwrap();
                self.finished.push(r.id);
                continue;
            }
            if r.remaining_decode <= 1 {
                kv.release(r.id).unwrap();
                self.finished.push(r.id);
            } else {
                still.push(Running { id: r.id, remaining_decode: r.remaining_decode - 1 });
            }
        }
        self.running = still;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn req(id: u64, p: usize, d: usize) -> Request {
        Request { id, prompt_len: p, decode_len: d, arrival: 0.0 }
    }

    fn drive_to_completion(reqs: Vec<Request>, conc: usize, pages: usize) -> usize {
        let mut kv = PagedKv::new(pages, 16);
        let mut b = Batcher::new(conc, 8192);
        for r in &reqs {
            b.submit(*r);
        }
        let mut steps = 0;
        let mut done = 0;
        while !b.idle() {
            let step = b.next_step(&mut kv);
            assert!(!step.is_empty(), "live batcher must make progress");
            b.complete_step(&step, &mut kv, &reqs);
            done += b.take_finished().len();
            steps += 1;
            kv.check_invariants();
            assert!(steps < 1_000_000, "runaway");
        }
        assert_eq!(done, reqs.len());
        assert_eq!(kv.used_pages(), 0);
        steps
    }

    #[test]
    fn single_request_steps() {
        // 1 prefill step + (decode_len - 1) decode steps.
        let steps = drive_to_completion(vec![req(1, 10, 5)], 8, 64);
        assert_eq!(steps, 5);
    }

    #[test]
    fn concurrency_cap_respected() {
        let mut kv = PagedKv::new(1024, 16);
        let mut b = Batcher::new(2, 100_000);
        let reqs: Vec<Request> = (0..5).map(|i| req(i, 8, 4)).collect();
        for r in &reqs {
            b.submit(*r);
        }
        let step = b.next_step(&mut kv);
        assert_eq!(step.prefills.len(), 2);
        b.complete_step(&step, &mut kv, &reqs);
        assert_eq!(b.running_len(), 2);
    }

    #[test]
    fn token_budget_limits_prefills() {
        let mut kv = PagedKv::new(1024, 16);
        let mut b = Batcher::new(64, 100);
        let reqs: Vec<Request> = (0..4).map(|i| req(i, 60, 2)).collect();
        for r in &reqs {
            b.submit(*r);
        }
        let step = b.next_step(&mut kv);
        assert_eq!(step.prefills.len(), 1, "only one 60-token prompt fits in 100");
    }

    #[test]
    fn mixed_batches_at_low_concurrency() {
        // §5.2.3: with spare concurrency, later steps mix decodes+prefills.
        let mut kv = PagedKv::new(1024, 16);
        let mut b = Batcher::new(4, 100_000);
        let reqs: Vec<Request> = (0..4).map(|i| req(i, 32, 8)).collect();
        b.submit(reqs[0]);
        b.submit(reqs[1]);
        let s1 = b.next_step(&mut kv);
        b.complete_step(&s1, &mut kv, &reqs);
        b.submit(reqs[2]);
        let s2 = b.next_step(&mut kv);
        assert!(!s2.decodes.is_empty() && !s2.prefills.is_empty(), "mixed batch expected");
        b.complete_step(&s2, &mut kv, &reqs);
    }

    #[test]
    fn zero_free_kv_pages_blocks_admission_but_not_decodes() {
        // All pages consumed by the running sequence: new prompts must not
        // be admitted, while the running sequence keeps decoding.
        let mut kv = PagedKv::new(2, 16);
        let mut b = Batcher::new(8, 100_000);
        let reqs = vec![req(0, 32, 4), req(1, 8, 2)];
        b.submit(reqs[0]);
        b.submit(reqs[1]);
        let s1 = b.next_step(&mut kv);
        assert_eq!(s1.prefills.len(), 1, "only the 2-page prompt fits");
        assert_eq!(kv.free_pages(), 0);
        b.complete_step(&s1, &mut kv, &reqs);
        // Zero free pages now: the next step must be decode-only.
        let s2 = b.next_step(&mut kv);
        assert!(s2.prefills.is_empty() && s2.decodes == vec![0]);
        b.complete_step(&s2, &mut kv, &reqs);
        kv.check_invariants();
    }

    #[test]
    fn concurrency_cap_one_serializes_requests() {
        // C=1: requests run strictly one at a time, so total step count is
        // the sum of per-request step counts (1 prefill + d-1 decodes).
        let reqs: Vec<Request> = (0..3).map(|i| req(i, 8, 3 + i as usize)).collect();
        let expected: usize = reqs.iter().map(|r| r.decode_len).sum();
        let steps = drive_to_completion(reqs, 1, 64);
        assert_eq!(steps, expected);
    }

    #[test]
    fn submit_prefilled_joins_running_without_prefill_step() {
        let mut kv = PagedKv::new(64, 16);
        let mut b = Batcher::new(8, 8192);
        let reqs = vec![req(7, 40, 5)];
        b.submit_prefilled(reqs[0], &mut kv).unwrap();
        assert_eq!(b.running_len(), 1);
        assert_eq!(kv.seq_pages(7), Some(3)); // ceil(40/16)
        let mut done = 0;
        let mut steps = 0;
        while !b.idle() {
            let step = b.next_step(&mut kv);
            assert!(step.prefills.is_empty(), "prefill ran remotely");
            b.complete_step(&step, &mut kv, &reqs);
            done += b.take_finished().len();
            steps += 1;
        }
        // 4 local decode steps (the 5th token's prefill happened remotely).
        assert_eq!((steps, done), (4, 1));
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn submit_prefilled_single_token_finishes_immediately() {
        let mut kv = PagedKv::new(8, 16);
        let mut b = Batcher::new(8, 8192);
        b.submit_prefilled(req(3, 10, 1), &mut kv).unwrap();
        assert_eq!(b.running_len(), 0);
        assert_eq!(b.take_finished(), vec![3]);
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn submit_prefilled_out_of_pages_leaves_state_clean() {
        let mut kv = PagedKv::new(2, 16);
        let mut b = Batcher::new(8, 8192);
        assert_eq!(b.submit_prefilled(req(1, 100, 8), &mut kv), Err(crate::engine::kv::KvError::OutOfPages));
        assert_eq!(b.running_len(), 0);
        assert_eq!(kv.free_pages(), 2);
        kv.check_invariants();
    }

    #[test]
    fn step_batches_carry_real_context_lengths() {
        let mut kv = PagedKv::new(64, 16);
        let mut b = Batcher::new(8, 8192);
        let reqs = vec![req(0, 40, 4)];
        b.submit(reqs[0]);
        let s1 = b.next_step(&mut kv); // prefill step
        assert!(s1.decode_ctx.is_empty());
        assert_eq!(s1.mean_ctx(), 40);
        b.complete_step(&s1, &mut kv, &reqs);
        let s2 = b.next_step(&mut kv); // first decode reads the prompt KV
        assert_eq!(s2.decode_ctx, vec![40]);
        b.complete_step(&s2, &mut kv, &reqs);
        let s3 = b.next_step(&mut kv); // context grew by the decoded token
        assert_eq!(s3.decode_ctx, vec![41]);
        assert_eq!(s3.mean_ctx(), 41);
        b.complete_step(&s3, &mut kv, &reqs);
    }

    #[test]
    fn property_all_requests_complete() {
        check("batcher completes everything", 20, |g: &mut Gen| {
            let n = g.usize(1, 30);
            let reqs: Vec<Request> = (0..n as u64)
                .map(|i| req(i, g.usize(1, 64), g.usize(1, 20)))
                .collect();
            let conc = g.usize(1, 16);
            let pages = g.usize(8, 256);
            drive_to_completion(reqs, conc, pages);
        });
    }
}
