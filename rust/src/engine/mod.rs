//! The YALIS-style inference engine (Layer 3's modelling half).
//!
//! Simulates batched inference of a [`crate::models::ModelConfig`] on a
//! [`crate::cluster::Topology`] under a parallelism [`Plan`] (TP / hybrid
//! TP+PP), an engine [`persona::Persona`], and a chosen all-reduce
//! implementation — producing end-to-end batch latency plus the Fig 3/8
//! per-GPU breakdown. The decode hot loop mirrors the real runtime
//! (`crate::runtime`) step for step; the simulation is what lets us run the
//! paper's 70B/405B × 128-GPU sweeps on this machine.
//!
//! Submodules:
//! - [`persona`] — engine personas (YALIS, vLLM V0/V1, SGLang) as
//!   scheduling/overhead parameter sets.
//! - [`kv`] — a real paged KV-cache manager (block allocator) with the
//!   invariants vLLM's PagedAttention allocator maintains.
//! - [`batcher`] — a real continuous-batching scheduler used by the
//!   serving stack.

pub mod batcher;
pub mod kv;
pub mod persona;

use crate::cluster::Topology;
use crate::collectives::sim::{allreduce, CommConfig};
use crate::collectives::AllReduceImpl;
use crate::metrics::Breakdown;
use crate::models::ModelConfig;
use crate::perfmodel::{self, GpuSpec};
use persona::Persona;

/// A batched-inference workload (paper Table 2).
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub prompt_len: usize,
    pub decode_len: usize,
    pub num_prompts: usize,
}

impl Workload {
    /// Table 2 "Prefill-heavy": 2363 prompt / 128 decode.
    pub fn prefill_heavy(num_prompts: usize) -> Self {
        Workload { prompt_len: 2363, decode_len: 128, num_prompts }
    }

    /// Table 2 "Decode-heavy": 1426 prompt / 3072 decode.
    pub fn decode_heavy(num_prompts: usize) -> Self {
        Workload { prompt_len: 1426, decode_len: 3072, num_prompts }
    }

    pub fn total_seq(&self) -> usize {
        self.prompt_len + self.decode_len
    }
}

/// Model-parallel plan: `tp × pp` GPUs (Table 3's two schemes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Plan {
    pub tp: usize,
    pub pp: usize,
}

impl Plan {
    pub fn tensor(gpus: usize) -> Self {
        Plan { tp: gpus, pp: 1 }
    }

    /// Hybrid: TP within a node, PP across nodes (Table 3).
    pub fn hybrid(topo: &Topology, gpus: usize) -> Self {
        let tp = topo.gpus_per_node.min(gpus);
        Plan { tp, pp: gpus / tp }
    }

    pub fn gpus(&self) -> usize {
        self.tp * self.pp
    }
}

/// Result of simulating one batch to completion.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// End-to-end batch latency (the Figs 1/2 Y-axis).
    pub total: f64,
    pub prefill: f64,
    pub decode: f64,
    /// Per-GPU average breakdown (Fig 3 / Fig 8 buckets).
    pub breakdown: Breakdown,
    /// Communication time attributable to all-reduce (TP) / P2P (PP).
    pub comm: f64,
    /// Deployment did not fit GPU memory (missing points in Figs 1/2).
    pub oom: bool,
}

impl RunReport {
    fn oom() -> Self {
        RunReport {
            total: f64::NAN,
            prefill: f64::NAN,
            decode: f64::NAN,
            breakdown: Breakdown::default(),
            comm: f64::NAN,
            oom: true,
        }
    }
}

/// Full engine description for one simulated deployment.
#[derive(Clone, Debug)]
pub struct Engine {
    pub model: ModelConfig,
    pub topo: Topology,
    pub gpu: GpuSpec,
    pub comm: CommConfig,
    pub plan: Plan,
    pub persona: Persona,
    pub allreduce: AllReduceImpl,
}

impl Engine {
    /// Simulate one batched-inference run (prefill + full decode).
    pub fn run_batch(&self, w: &Workload) -> RunReport {
        assert_eq!(self.plan.gpus(), self.topo.total_gpus(), "plan/topology mismatch");
        if !perfmodel::fits_memory(
            &self.gpu,
            &self.model,
            self.plan.tp,
            self.plan.pp,
            w.num_prompts,
            w.total_seq(),
        ) {
            return RunReport::oom();
        }
        if self.plan.pp == 1 {
            self.run_tp(w)
        } else {
            self.run_hybrid(w)
        }
    }

    /// Topology seen by one TP group (for HP: the intra-node slice).
    fn tp_topo(&self) -> Topology {
        self.topo.with_gpus(self.plan.tp)
    }

    /// Time of one all-reduce of `bytes`, given `gap` seconds of compute
    /// since the previous collective (hides NVRAR's deferred sync).
    fn ar(&self, topo: &Topology, bytes: u64, gap: f64) -> f64 {
        if topo.total_gpus() <= 1 {
            return 0.0;
        }
        allreduce(self.allreduce, topo, &self.comm, bytes, gap).total
    }

    // ------------------------------------------------------------------
    // Pure tensor parallelism
    // ------------------------------------------------------------------

    fn run_tp(&self, w: &Workload) -> RunReport {
        let tp = self.plan.tp;
        let topo = self.tp_topo();
        let b = w.num_prompts;
        let l = self.model.n_layers;
        let eff = self.persona.compute_efficiency;

        // ---- prefill: all prompt tokens in parallel.
        let m_tokens = b * w.prompt_len;
        let lt_p =
            perfmodel::layer_times(&self.gpu, &self.model, tp, m_tokens, w.prompt_len as f64, b);
        let ar_bytes_p = (m_tokens * self.model.d_model * self.model.dtype_bytes) as u64;
        let gap_p = lt_p.total() / 2.0;
        let ar_p = self.ar(&topo, ar_bytes_p, gap_p);
        let prefill_compute = l as f64 * lt_p.total() / eff;
        let prefill_comm = l as f64 * 2.0 * ar_p;
        let prefill =
            prefill_compute + prefill_comm + self.persona.step_overhead + self.head_time(b);

        // ---- decode: token by token; KV grows — use the mean KV length.
        let kv_mean = (w.prompt_len + w.decode_len / 2) as f64;
        let lt_d = perfmodel::layer_times(&self.gpu, &self.model, tp, b, kv_mean, b);
        let ar_bytes_d = self.model.tp_allreduce_bytes(b);
        let gap_d = lt_d.total() / 2.0;
        let ar_d = self.ar(&topo, ar_bytes_d, gap_d);
        let step_compute = l as f64 * lt_d.total() / eff;
        let step_comm = l as f64 * 2.0 * ar_d;
        let step = step_compute + step_comm + self.persona.step_overhead + self.head_time(b);
        let decode = step * w.decode_len as f64;

        let total = prefill + decode;
        let matmul = (l as f64 * lt_p.matmul / eff)
            + (l as f64 * lt_d.matmul / eff) * w.decode_len as f64;
        let other = (l as f64 * lt_p.other / eff)
            + (l as f64 * lt_d.other / eff) * w.decode_len as f64
            + self.head_time(b) * (1.0 + w.decode_len as f64);
        let comm = prefill_comm + step_comm * w.decode_len as f64;
        let breakdown =
            Breakdown { matmul, other_comp: other, comm, idle: 0.0 }.with_idle_to(total);
        RunReport { total, prefill, decode, breakdown, comm, oom: false }
    }

    /// LM-head + sampling time (runs on every GPU under TP).
    fn head_time(&self, batch: usize) -> f64 {
        perfmodel::gemm_time(
            &self.gpu,
            batch,
            self.model.vocab / self.plan.tp,
            self.model.d_model,
            self.model.dtype_bytes,
        )
    }

    // ------------------------------------------------------------------
    // Hybrid: TP intra-node × PP across nodes
    // ------------------------------------------------------------------

    fn run_hybrid(&self, w: &Workload) -> RunReport {
        let tp = self.plan.tp;
        let stages = self.plan.pp;
        let topo_tp = self.tp_topo();
        let b = w.num_prompts;
        let eff = self.persona.compute_efficiency;
        let layers_per_stage = self.model.n_layers.div_ceil(stages);
        // Micro-batching: split the batch into m micro-batches (persona
        // policy), floor 1 prompt per micro-batch.
        let m = self.persona.microbatches(stages).min(b).max(1);
        let mb = b.div_ceil(m);

        // P2P activation transfer between stages (inter-node).
        let p2p = |rows: usize| -> f64 {
            let bytes = (rows * self.model.d_model * self.model.dtype_bytes) as u64;
            self.topo.inter.xfer_time(bytes) + self.persona.p2p_overhead
        };

        // ---- prefill: micro-batches pipeline through stages.
        let rows_p = mb * w.prompt_len;
        let lt_p =
            perfmodel::layer_times(&self.gpu, &self.model, tp, rows_p, w.prompt_len as f64, mb);
        let ar_p = self.ar(&topo_tp, (rows_p * self.model.d_model * self.model.dtype_bytes) as u64, lt_p.total() / 2.0);
        let stage_p = layers_per_stage as f64 * (lt_p.total() / eff + 2.0 * ar_p) + p2p(rows_p);
        // Pipeline fill-drain: (m + S - 1) stage slots.
        let prefill = (m + stages - 1) as f64 * stage_p
            + self.persona.step_overhead * m as f64
            + self.head_time_pp(mb);

        // ---- decode: each token round, every micro-batch crosses all
        // stages; micro-batch j's next token waits for its previous one.
        let kv_mean = (w.prompt_len + w.decode_len / 2) as f64;
        let lt_d = perfmodel::layer_times(&self.gpu, &self.model, tp, mb, kv_mean, mb);
        let ar_d = self.ar(&topo_tp, self.model.tp_allreduce_bytes(mb), lt_d.total() / 2.0);
        let stage_d = layers_per_stage as f64 * (lt_d.total() / eff + 2.0 * ar_d) + p2p(mb);
        let round = (m + stages - 1) as f64 * stage_d
            + self.persona.step_overhead
            + self.head_time_pp(mb);
        let decode = round * w.decode_len as f64;

        let total = prefill + decode;
        // Per-GPU busy time: each GPU serves m micro-batch stage-slots per
        // (m + S - 1)-slot round; the remainder is pipeline bubble (idle).
        let matmul = layers_per_stage as f64
            * (lt_p.matmul / eff * m as f64
                + lt_d.matmul / eff * (m * w.decode_len) as f64);
        let other = layers_per_stage as f64
            * (lt_p.other / eff * m as f64 + lt_d.other / eff * (m * w.decode_len) as f64);
        let comm_tp = layers_per_stage as f64
            * 2.0
            * (ar_p * m as f64 + ar_d * (m * w.decode_len) as f64);
        let comm_pp = p2p(rows_p) * m as f64 + p2p(mb) * (m * w.decode_len) as f64;
        let comm = comm_tp + comm_pp;
        let breakdown =
            Breakdown { matmul, other_comp: other, comm, idle: 0.0 }.with_idle_to(total);
        RunReport { total, prefill, decode, breakdown, comm, oom: false }
    }

    fn head_time_pp(&self, batch: usize) -> f64 {
        perfmodel::gemm_time(
            &self.gpu,
            batch,
            self.model.vocab / self.plan.tp,
            self.model.d_model,
            self.model.dtype_bytes,
        )
    }
}

/// Engine over an already-resolved calibration bundle — topology, GPU
/// roofline and comm constants all come from the *same* bundle.
pub fn engine_for_bundle(
    bundle: &crate::calib::MachineBundle,
    model: ModelConfig,
    gpus: usize,
    plan_kind: &str,
    persona: Persona,
    ar: AllReduceImpl,
) -> Engine {
    let topo = bundle.topo.topology(1).with_gpus(gpus);
    let plan = match plan_kind {
        "tp" => Plan::tensor(gpus),
        "hp" => Plan::hybrid(&topo, gpus),
        other => panic!("unknown plan '{other}'"),
    };
    Engine { model, topo, gpu: bundle.gpu, comm: bundle.comm, plan, persona, allreduce: ar }
}

/// Convenience constructor for the Perlmutter/Vista sweeps. Panics on an
/// unknown machine (sweep drivers hard-code known names); CLI paths
/// validate the name via [`crate::calib::registry::resolve`] first.
pub fn engine_for(
    machine: &str,
    model: ModelConfig,
    gpus: usize,
    plan_kind: &str,
    persona: Persona,
    ar: AllReduceImpl,
) -> Engine {
    let bundle =
        crate::calib::registry::resolve(machine).unwrap_or_else(|e| panic!("{e}"));
    engine_for_bundle(&bundle, model, gpus, plan_kind, persona, ar)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelConfig;

    fn eng(gpus: usize, plan: &str, ar: AllReduceImpl) -> Engine {
        engine_for("perlmutter", ModelConfig::llama31_70b(), gpus, plan, Persona::yalis(), ar)
    }

    #[test]
    fn tp_decode_message_size_is_paper_value() {
        let e = eng(16, "tp", AllReduceImpl::NcclAuto);
        assert_eq!(e.model.tp_allreduce_bytes(8), 128 * 1024);
    }

    #[test]
    fn observation1_tp_beats_hp_decode_heavy() {
        let w = Workload::decode_heavy(8);
        let tp = eng(16, "tp", AllReduceImpl::NcclAuto).run_batch(&w);
        let hp = eng(16, "hp", AllReduceImpl::NcclAuto).run_batch(&w);
        assert!(!tp.oom && !hp.oom);
        assert!(tp.total < hp.total, "TP {} should beat HP {}", tp.total, hp.total);
    }

    #[test]
    fn observation1_hp_competitive_prefill_heavy() {
        let w = Workload::prefill_heavy(32);
        let tp = eng(16, "tp", AllReduceImpl::NcclAuto).run_batch(&w);
        let hp = eng(16, "hp", AllReduceImpl::NcclAuto).run_batch(&w);
        // HP avoids the huge prefill all-reduces; it should win or tie.
        assert!(hp.total < 1.1 * tp.total, "HP {} vs TP {}", hp.total, tp.total);
    }

    #[test]
    fn tp_poor_strong_scaling_decode() {
        // Observation 1: beyond ~16 GPUs latency flattens or rises.
        let w = Workload::decode_heavy(8);
        let t8 = eng(8, "tp", AllReduceImpl::NcclAuto).run_batch(&w).total;
        let t32 = eng(32, "tp", AllReduceImpl::NcclAuto).run_batch(&w).total;
        assert!(t32 > 0.5 * t8, "strong scaling should be poor: {t8} -> {t32}");
    }

    #[test]
    fn comm_fraction_grows_with_tp_gpus() {
        let w = Workload::decode_heavy(8);
        let r8 = eng(8, "tp", AllReduceImpl::NcclAuto).run_batch(&w);
        let r16 = eng(16, "tp", AllReduceImpl::NcclAuto).run_batch(&w);
        // Fig 3 right: comm time increases ~1.6x from 8 to 16 GPUs.
        assert!(r16.comm > 1.2 * r8.comm, "{} -> {}", r8.comm, r16.comm);
    }

    #[test]
    fn nvrar_speeds_up_decode_heavy_tp() {
        let w = Workload::decode_heavy(32);
        let nccl = eng(32, "tp", AllReduceImpl::NcclAuto).run_batch(&w);
        let nvrar = eng(32, "tp", AllReduceImpl::Nvrar).run_batch(&w);
        let speedup = nccl.total / nvrar.total;
        assert!(speedup > 1.1, "NVRAR speedup {speedup}");
    }

    #[test]
    fn oom_for_single_gpu_70b() {
        let e = eng(1, "tp", AllReduceImpl::NcclAuto);
        assert!(e.run_batch(&Workload::decode_heavy(8)).oom);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let w = Workload::decode_heavy(8);
        let r = eng(16, "tp", AllReduceImpl::NcclAuto).run_batch(&w);
        assert!((r.breakdown.total() - r.total).abs() / r.total < 1e-6);
    }

    #[test]
    fn hybrid_plan_shape() {
        let topo = crate::cluster::presets::perlmutter(4);
        let p = Plan::hybrid(&topo, 16);
        assert_eq!((p.tp, p.pp), (4, 4));
    }

    #[test]
    fn pp_decode_does_not_scale() {
        // Observation 2: PP fails to cut decode time (tile floor + bubbles).
        let w = Workload::decode_heavy(8);
        let hp8 = eng(8, "hp", AllReduceImpl::NcclAuto).run_batch(&w).total;
        let hp32 = eng(32, "hp", AllReduceImpl::NcclAuto).run_batch(&w).total;
        assert!(hp32 > 0.8 * hp8, "PP decode should not scale: {hp8} -> {hp32}");
    }
}
