//! # yalis — multi-node LLM inference study + NVRAR all-reduce (reproduction)
//!
//! Reproduction of *"LLM Inference Beyond a Single Node: From Bottlenecks to
//! Mitigations with Fast All-Reduce Communication"* (Singhania et al.) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the coordinator: the YALIS-style inference
//!   engine ([`engine`]: continuous batcher over a refcounted
//!   shared-prefix paged KV cache), the composable parallelism/cost API
//!   ([`parallel`]: `ParallelSpec` + `StepCost` — one vocabulary for pure
//!   TP, hybrid TP×PP×DP, and MoE EP deployments), the single-replica
//!   serving stack ([`serving`]), the multi-replica SLO-aware serving
//!   fleet ([`fleet`]: cost-aware + prefix-cache-aware router,
//!   disaggregated prefill/decode pools, KV migration on drain, dual-pool
//!   autoscaler with NVRAR re-tuning, heterogeneous replica specs), the
//!   cluster / network simulation substrate ([`simnet`], [`cluster`] —
//!   including the shared-interconnect fair-share fabric
//!   [`simnet::Interconnect`] that makes link contention between
//!   collectives and KV transfers a first-class simulated resource), the
//!   collective algorithms ([`collectives`]) including the paper's NVRAR
//!   (an event-level simulation, a flow-level shared-fabric path
//!   [`collectives::flows`], and a **real** shared-memory implementation
//!   over the [`shmem`] PGAS substrate), the calibration subsystem
//!   ([`calib`]: versioned machine bundles, the `yalis validate`
//!   paper-claim harness, and `yalis fit` α/β fitting from measured
//!   CSVs), the determinism-invariant static-analysis pass ([`lint`]:
//!   `yalis lint`, a ratcheted source-level gate on the hazards that
//!   silently break the simulator's bit-for-bit guarantees), and the
//!   PJRT [`runtime`] that executes AOT-compiled model artifacts.
//! - **Layer 2** — JAX model graphs (`python/compile/model.py`), lowered
//!   once to HLO text in `artifacts/`.
//! - **Layer 1** — Pallas kernels (`python/compile/kernels/`), lowered into
//!   the same HLO.
//!
//! Python never runs at inference time: the `yalis` binary and every
//! example/bench are self-contained once `make artifacts` has run.

pub mod calib;
pub mod cluster;
pub mod collectives;
pub mod coordinator;
pub mod engine;
pub mod fleet;
pub mod lint;
pub mod metrics;
pub mod models;
pub mod moe;
pub mod obs;
pub mod parallel;
pub mod perfmodel;
pub mod runtime;
pub mod serving;
pub mod shmem;
pub mod simnet;
pub mod trace;
pub mod util;
