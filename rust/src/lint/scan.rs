//! Source scanner for `yalis lint` — see [`crate::lint`] for the rule
//! catalog and the ratchet workflow.
//!
//! A hand-rolled two-pass line/token scanner (the vendored crate set has
//! no `syn` and no regex):
//!
//! 1. **Strip pass** ([`strip`]) — walks the file once, character by
//!    character, classifying every char as code, line comment, block
//!    comment, or literal content. Emits, per line, the *code* text
//!    (string/char-literal contents blanked, comments dropped) and the
//!    *line-comment* text (for waiver parsing). Handles nested `/* */`
//!    blocks, raw strings, byte strings, char literals vs. lifetimes,
//!    escaped-newline string continuations, and multi-line literals.
//! 2. **Rule pass** ([`scan_source`]) — walks the stripped lines in
//!    order, tracking brace depth, `#[cfg(test)]` regions and pending
//!    waivers, and records a [`Hit`] for every rule pattern that matches
//!    in an applicable scope.
//!
//! Deliberately line-oriented: a comparator chain split across lines
//! evades D02. The rules target the idioms as actually written —
//! rustfmt keeps comparator closures on one line — and the ratchet
//! means an evasion is at worst status quo, never a lost guarantee.

use super::RULES;

/// One stripped source line.
#[derive(Clone, Debug, Default)]
pub struct StrippedLine {
    /// Code chars only: comments removed, literal contents blanked
    /// (string/char delimiters kept so the text stays token-shaped).
    pub code: String,
    /// Concatenated `//` line-comment text, delimiter removed.
    pub comment: String,
}

enum St {
    Normal,
    Line,
    Block(u32),
    Str,
    RawStr(u32),
}

/// Strip pass: split source text into per-line (code, comment) pairs.
/// Line count always equals the source's `lines()` count, so hit line
/// numbers map 1:1 onto the raw file.
pub fn strip(text: &str) -> Vec<StrippedLine> {
    let cs: Vec<char> = text.chars().collect();
    let n = cs.len();
    let mut out = Vec::new();
    let mut cur = StrippedLine::default();
    let mut st = St::Normal;
    let mut prev_ident = false;
    let mut i = 0usize;
    while i < n {
        if cs[i] == '\n' {
            out.push(std::mem::take(&mut cur));
            if matches!(st, St::Line) {
                st = St::Normal;
            }
            prev_ident = false;
            i += 1;
            continue;
        }
        let c = cs[i];
        let next = cs.get(i + 1).copied();
        match st {
            St::Normal => {
                if c == '/' && next == Some('/') {
                    st = St::Line;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(1);
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    st = St::Str;
                    prev_ident = false;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_ident {
                    // Maybe r"…", r#"…"#, b"…", br"…": jump to just past
                    // the opening quote; otherwise an ordinary ident char.
                    if let Some((hashes, after, raw)) = raw_or_byte_open(&cs, i) {
                        cur.code.push('"');
                        st = if raw { St::RawStr(hashes) } else { St::Str };
                        prev_ident = false;
                        i = after;
                    } else {
                        cur.code.push(c);
                        prev_ident = true;
                        i += 1;
                    }
                } else if c == '\'' {
                    i = consume_quote(&cs, i, &mut cur.code);
                    prev_ident = false;
                } else {
                    cur.code.push(c);
                    prev_ident = c.is_alphanumeric() || c == '_';
                    i += 1;
                }
            }
            St::Line => {
                cur.comment.push(c);
                i += 1;
            }
            St::Block(d) => {
                if c == '*' && next == Some('/') {
                    st = if d == 1 { St::Normal } else { St::Block(d - 1) };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(d + 1);
                    i += 2;
                } else {
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    // Escaped newline continues the string on the next
                    // line; let the top-of-loop newline handling see it
                    // so line numbers stay aligned.
                    i += if next == Some('\n') { 1 } else { 2 };
                } else if c == '"' {
                    cur.code.push('"');
                    st = St::Normal;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' {
                    let mut k = 0u32;
                    while cs.get(i + 1 + k as usize) == Some(&'#') && k < h {
                        k += 1;
                    }
                    if k >= h {
                        cur.code.push('"');
                        st = St::Normal;
                        i += 1 + h as usize;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    out.push(cur);
    out
}

/// If position `i` (at `r` or `b`) opens a raw/byte string literal,
/// return (hash count, index just past the opening quote, is_raw).
fn raw_or_byte_open(cs: &[char], i: usize) -> Option<(u32, usize, bool)> {
    let mut j = i;
    if cs.get(j) == Some(&'b') {
        j += 1;
    }
    let raw = cs.get(j) == Some(&'r');
    if raw {
        j += 1;
    }
    let mut hashes = 0u32;
    while raw && cs.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    // A bare `r` / `b` identifier is not an opener; a plain `"` after a
    // lone `b` is a byte string, after `r`(+hashes) a raw string.
    if cs.get(j) == Some(&'"') && (raw || cs.get(i) == Some(&'b')) {
        Some((hashes, j + 1, raw))
    } else {
        None
    }
}

/// Consume a `'`-introduced token: a char literal (`'x'`, `'\n'`,
/// `'\x41'`, `'\u{1F600}'`, `'{'` …) with contents blanked, or a
/// lifetime quote kept as-is. Returns the next index.
fn consume_quote(cs: &[char], i: usize, code: &mut String) -> usize {
    let next = cs.get(i + 1).copied();
    if next == Some('\\') {
        code.push('\'');
        code.push('\'');
        let mut j = i + 2;
        match cs.get(j) {
            Some('x') => j += 3,
            Some('u') => {
                j += 1;
                if cs.get(j) == Some(&'{') {
                    while j < cs.len() && cs[j] != '}' {
                        j += 1;
                    }
                }
                j += 1;
            }
            Some(_) => j += 1,
            None => {}
        }
        if cs.get(j) == Some(&'\'') {
            j += 1;
        }
        return j;
    }
    if let (Some(ch), Some('\'')) = (next, cs.get(i + 2).copied()) {
        if ch != '\'' {
            code.push('\'');
            code.push('\'');
            return i + 3;
        }
    }
    // Lifetime (or stray quote): keep the quote, consume one char.
    code.push('\'');
    i + 1
}

/// Which scanning scope a file belongs to, decided from its repo-relative
/// path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FileClass {
    /// Simulated library code under `rust/src` — the full rule set.
    Sim,
    /// Real-hardware modules (`runtime/`, `shmem/`, `util/bench.rs`):
    /// wall-clock reads are their job, so D03 (and D01 — they hold host
    /// state, not simulated decisions) do not apply.
    RealHw,
    /// Tests, benches and examples: P01 exempt (panics are assertions
    /// there), determinism rules still on.
    TestLike,
}

/// Classify a repo-relative path (forward slashes).
pub fn classify(rel: &str) -> FileClass {
    if rel.starts_with("rust/src/") {
        if rel.starts_with("rust/src/runtime/")
            || rel.starts_with("rust/src/shmem/")
            || rel == "rust/src/util/bench.rs"
        {
            FileClass::RealHw
        } else {
            FileClass::Sim
        }
    } else {
        FileClass::TestLike
    }
}

/// Does `rule` apply in this (file class, cfg(test) region) scope?
pub fn applies(rule: &str, class: FileClass, in_test: bool) -> bool {
    match rule {
        "D01" => class == FileClass::Sim && !in_test,
        "D02" => true,
        "D03" => class != FileClass::RealHw,
        "D04" => true,
        "P01" => class != FileClass::TestLike && !in_test,
        _ => false,
    }
}

/// Does the stripped code line contain `rule`'s pattern?
pub fn pattern_hit(rule: &str, code: &str) -> bool {
    match rule {
        "D01" => code.contains("HashMap") || code.contains("HashSet"),
        "D02" => {
            code.contains(".partial_cmp(")
                && (code.contains(".unwrap()")
                    || code.contains(".expect(")
                    || code.contains("sort_by")
                    || code.contains("min_by")
                    || code.contains("max_by"))
        }
        "D03" => code.contains("Instant::now") || code.contains("SystemTime"),
        "D04" => code.contains("thread_rng") || code.contains("rand::random"),
        "P01" => {
            code.contains(".unwrap()")
                || code.contains(".expect(")
                || code.contains("panic!")
                || code.contains("f64::NAN")
        }
        _ => false,
    }
}

#[derive(Clone, Debug)]
struct Waiver {
    rules: Vec<String>,
    line: usize,
}

/// Parse a waiver from a line's comment text. `None`: no waiver on this
/// line. `Some(Err)`: the comment *claims* to be a waiver (leads with
/// `lint:`) but does not parse — always a hard error, never baselined.
fn parse_waiver(comment: &str, line: usize) -> Option<Result<Waiver, String>> {
    let t = comment.trim_start();
    let rest = t.strip_prefix("lint:")?;
    let rest = rest.trim_start();
    let rest = match rest.strip_prefix("allow(") {
        Some(r) => r,
        None => return Some(Err("malformed waiver: expected `lint: allow(RULE) reason`".into())),
    };
    let close = match rest.find(')') {
        Some(p) => p,
        None => return Some(Err("malformed waiver: missing `)`".into())),
    };
    let ids: Vec<String> = rest[..close].split(',').map(|s| s.trim().to_string()).collect();
    for id in &ids {
        if !RULES.iter().any(|r| r.id == id) {
            return Some(Err(format!("waiver names unknown rule `{id}`")));
        }
    }
    let reason = rest[close + 1..].trim();
    if reason.is_empty() {
        return Some(Err("waiver needs a reason: `lint: allow(RULE) <why>`".into()));
    }
    Some(Ok(Waiver { rules: ids, line }))
}

/// One rule match.
#[derive(Clone, Debug)]
pub struct Hit {
    pub rule: &'static str,
    /// 1-based source line.
    pub line: usize,
    /// The raw source line, trimmed, for diagnostics.
    pub excerpt: String,
    /// Covered by an inline `lint: allow` waiver.
    pub waived: bool,
}

/// A waiver that failed to parse (always fails the run).
#[derive(Clone, Debug)]
pub struct WaiverErr {
    pub line: usize,
    pub msg: String,
}

/// Scan result for one file.
#[derive(Clone, Debug)]
pub struct FileScan {
    pub path: String,
    pub hits: Vec<Hit>,
    pub waiver_errors: Vec<WaiverErr>,
    /// Lines that declared a waiver which matched no violation.
    pub unused_waivers: Vec<usize>,
}

/// Rule pass: scan one file's source text.
pub fn scan_source(rel_path: &str, text: &str) -> FileScan {
    let class = classify(rel_path);
    let stripped = strip(text);
    let raw_lines: Vec<&str> = text.lines().collect();
    let mut hits = Vec::new();
    let mut waiver_errors = Vec::new();
    let mut unused_waivers = Vec::new();
    let mut depth: i64 = 0;
    let mut test_stack: Vec<i64> = Vec::new();
    let mut pending_cfg = false;
    let mut pending_waivers: Vec<Waiver> = Vec::new();

    for (idx, line) in stripped.iter().enumerate() {
        let lineno = idx + 1;
        let mut line_waivers: Vec<Waiver> = Vec::new();
        match parse_waiver(&line.comment, lineno) {
            Some(Ok(w)) => line_waivers.push(w),
            Some(Err(msg)) => waiver_errors.push(WaiverErr { line: lineno, msg }),
            None => {}
        }
        if line.code.trim().is_empty() {
            // Comment-only / blank line: a waiver here covers the next
            // code line (pending survives further blank lines).
            pending_waivers.append(&mut line_waivers);
            continue;
        }
        let mut waivers = std::mem::take(&mut pending_waivers);
        waivers.append(&mut line_waivers);

        if line.code.contains("#[cfg(test)]") {
            pending_cfg = true;
        }
        let test_at_start = !test_stack.is_empty();
        for c in line.code.chars() {
            match c {
                '{' => {
                    if pending_cfg {
                        test_stack.push(depth);
                        pending_cfg = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if test_stack.last() == Some(&depth) {
                        test_stack.pop();
                    }
                }
                // `#[cfg(test)] use x;` / `mod tests;` gate no block.
                ';' => pending_cfg = false,
                _ => {}
            }
        }
        let in_test = test_at_start || !test_stack.is_empty() || pending_cfg;

        let raw = raw_lines.get(idx).map(|s| s.trim()).unwrap_or_default();
        let mut used = vec![false; waivers.len()];
        for rule in RULES.iter() {
            if !applies(rule.id, class, in_test) || !pattern_hit(rule.id, &line.code) {
                continue;
            }
            let widx = waivers.iter().position(|w| w.rules.iter().any(|r| r == rule.id));
            if let Some(wi) = widx {
                used[wi] = true;
            }
            hits.push(Hit {
                rule: rule.id,
                line: lineno,
                excerpt: raw.to_string(),
                waived: widx.is_some(),
            });
        }
        for (wi, w) in waivers.iter().enumerate() {
            if !used[wi] {
                unused_waivers.push(w.line);
            }
        }
    }
    for w in &pending_waivers {
        unused_waivers.push(w.line);
    }
    FileScan { path: rel_path.to_string(), hits, waiver_errors, unused_waivers }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(text: &str) -> Vec<String> {
        strip(text).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strip_blanks_strings_and_comments() {
        let c = codes("let x = \"HashMap\"; // HashMap in comment\nuse std::fmt;");
        assert_eq!(c.len(), 2);
        assert!(!c[0].contains("HashMap"), "{:?}", c[0]);
        assert!(c[0].contains("let x = "));
        assert_eq!(c[1], "use std::fmt;");
    }

    #[test]
    fn strip_handles_block_comments_and_nesting() {
        let c = codes("a /* x /* y */ z */ b\n/* open\nstill comment */ after");
        assert_eq!(c[0].replace(' ', ""), "ab");
        assert_eq!(c[1], "");
        assert_eq!(c[2].trim(), "after");
    }

    #[test]
    fn strip_handles_raw_and_byte_strings() {
        let c = codes("let j = r#\"{\"panic!\": 1}\"#; let b = b\"panic!\";");
        assert!(!c[0].contains("panic!"), "{:?}", c[0]);
        // Braces inside the raw string must not reach the code text.
        assert!(!c[0].contains('{'), "{:?}", c[0]);
    }

    #[test]
    fn strip_handles_char_literals_and_lifetimes() {
        let c = codes("fn f<'a>(x: &'a str) -> char { '{' }");
        // The char-literal brace is blanked; the real braces survive.
        let opens = c[0].matches('{').count();
        let closes = c[0].matches('}').count();
        assert_eq!(opens, 1);
        assert_eq!(closes, 1);
        let c = codes(r"let e = '\n'; let q = '\''; let u = '\u{8}'; let h = '\x41';");
        assert!(!c[0].contains('n') || !c[0].contains("\\"), "{:?}", c[0]);
        assert!(!c[0].contains('{'), "{:?}", c[0]);
    }

    #[test]
    fn strip_keeps_line_count_with_continued_strings() {
        let text = "let s = \"a\\\n    b\";\nlet t = 1;";
        let c = codes(text);
        assert_eq!(c.len(), text.lines().count());
        assert_eq!(c[2].trim(), "let t = 1;");
    }

    #[test]
    fn strip_collects_comment_text() {
        let l = strip("x(); // lint: allow(P01) because\n");
        assert!(l[0].comment.trim_start().starts_with("lint:"), "{:?}", l[0].comment);
    }

    #[test]
    fn classify_scopes() {
        assert_eq!(classify("rust/src/simnet/mod.rs"), FileClass::Sim);
        assert_eq!(classify("rust/src/runtime/tp.rs"), FileClass::RealHw);
        assert_eq!(classify("rust/src/shmem/mod.rs"), FileClass::RealHw);
        assert_eq!(classify("rust/src/util/bench.rs"), FileClass::RealHw);
        assert_eq!(classify("rust/src/util/stats.rs"), FileClass::Sim);
        assert_eq!(classify("rust/tests/integration_fleet.rs"), FileClass::TestLike);
        assert_eq!(classify("examples/quickstart.rs"), FileClass::TestLike);
        assert_eq!(classify("rust/benches/sweep_chunk.rs"), FileClass::TestLike);
    }

    fn hit_rules(path: &str, src: &str) -> Vec<(&'static str, usize, bool)> {
        scan_source(path, src).hits.iter().map(|h| (h.rule, h.line, h.waived)).collect()
    }

    #[test]
    fn d01_hits_in_sim_misses_in_tests_and_realhw() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(hit_rules("rust/src/fleet/mod.rs", src), vec![("D01", 1, false)]);
        assert_eq!(hit_rules("rust/src/runtime/tp.rs", src), vec![]);
        assert_eq!(hit_rules("rust/tests/x.rs", src), vec![]);
        let test_src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashSet;\n}\n";
        assert_eq!(hit_rules("rust/src/fleet/mod.rs", test_src), vec![]);
    }

    #[test]
    fn d02_hits_comparator_idioms_everywhere() {
        let unwrap = "v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        let min_by = "xs.iter().min_by(|a, b| a.t.partial_cmp(&b.t).unwrap());\n";
        let fallback = "v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));\n";
        let fixed = "v.sort_by(|a, b| a.total_cmp(b));\n";
        assert!(hit_rules("rust/src/util/stats.rs", unwrap).iter().any(|h| h.0 == "D02"));
        assert!(hit_rules("rust/tests/t.rs", unwrap).iter().any(|h| h.0 == "D02"));
        assert!(hit_rules("rust/src/x.rs", min_by).iter().any(|h| h.0 == "D02"));
        // NaN-tolerant but order-unstable: still flagged.
        assert!(hit_rules("rust/src/x.rs", fallback).iter().any(|h| h.0 == "D02"));
        assert!(hit_rules("rust/src/x.rs", fixed).is_empty());
        // Defining PartialOrd is not a comparator call.
        let def = "fn partial_cmp(&self, other: &Self) -> Option<Ordering> {\n";
        assert!(hit_rules("rust/src/x.rs", def).is_empty());
    }

    #[test]
    fn d03_hits_outside_realhw_only() {
        let src = "let t0 = std::time::Instant::now();\n";
        assert!(hit_rules("rust/src/fleet/mod.rs", src).iter().any(|h| h.0 == "D03"));
        assert!(hit_rules("examples/quickstart.rs", src).iter().any(|h| h.0 == "D03"));
        assert!(hit_rules("rust/src/runtime/tp.rs", src).is_empty());
        assert!(hit_rules("rust/src/util/bench.rs", src).is_empty());
        let sys = "let now = std::time::SystemTime::now();\n";
        assert!(hit_rules("rust/src/obs/mod.rs", sys).iter().any(|h| h.0 == "D03"));
    }

    #[test]
    fn d04_hits_ambient_randomness() {
        assert!(hit_rules("rust/src/trace/mod.rs", "let r = rand::random::<f64>();\n")
            .iter()
            .any(|h| h.0 == "D04"));
        assert!(hit_rules("rust/tests/t.rs", "let mut rng = thread_rng();\n")
            .iter()
            .any(|h| h.0 == "D04"));
        assert!(hit_rules("rust/src/trace/mod.rs", "let mut rng = Rng::seeded(7);\n").is_empty());
    }

    #[test]
    fn p01_lib_only_and_cfg_test_exempt() {
        let src = "let v = m.get(&k).unwrap();\n";
        assert_eq!(hit_rules("rust/src/engine/kv.rs", src), vec![("P01", 1, false)]);
        // Real-hardware modules are still library code for P01.
        assert_eq!(hit_rules("rust/src/runtime/tp.rs", src), vec![("P01", 1, false)]);
        assert!(hit_rules("rust/tests/t.rs", src).is_empty());
        assert!(hit_rules("rust/benches/b.rs", src).is_empty());
        let test_src = "#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn lib() { y.expect(\"boom\"); }\n";
        assert_eq!(hit_rules("rust/src/engine/kv.rs", test_src), vec![("P01", 5, false)]);
        // unwrap_or & friends are fine; so is expect_err-free code.
        assert!(hit_rules("rust/src/x.rs", "let v = o.unwrap_or_default();\n").is_empty());
        assert!(hit_rules("rust/src/x.rs", "let v = o.unwrap_or(3);\n").is_empty());
        // NaN sentinel.
        assert!(hit_rules("rust/src/x.rs", "let v = f64::NAN;\n").iter().any(|h| h.0 == "P01"));
    }

    #[test]
    fn cfg_test_on_single_item_without_block_is_cancelled_by_semicolon() {
        let src = "#[cfg(test)]\nuse crate::util::prop;\nfn lib() { x.unwrap(); }\n";
        let hits = hit_rules("rust/src/x.rs", src);
        assert_eq!(hits, vec![("P01", 3, false)]);
    }

    #[test]
    fn waiver_on_same_line_and_preceding_line() {
        let same = "let v = x.unwrap(); // lint: allow(P01) init-time config, cannot fail\n";
        assert_eq!(hit_rules("rust/src/x.rs", same), vec![("P01", 1, true)]);
        let prev = "// lint: allow(P01) init-time config, cannot fail\nlet v = x.unwrap();\n";
        assert_eq!(hit_rules("rust/src/x.rs", prev), vec![("P01", 2, true)]);
        // Pending waiver survives an intervening blank/comment line.
        let gap = "// lint: allow(P01) init-time config\n\n// explains more\nlet v = x.unwrap();\n";
        assert_eq!(hit_rules("rust/src/x.rs", gap), vec![("P01", 4, true)]);
    }

    #[test]
    fn waiver_multi_rule_and_scope_is_one_line() {
        let src = "a.sort_by(|x, y| x.partial_cmp(y).unwrap()); // lint: allow(D02,P01) fixture exercising the unsafe idiom\nb.unwrap();\n";
        let hits = hit_rules("rust/src/x.rs", src);
        assert_eq!(hits[0], ("D02", 1, true));
        assert_eq!(hits[1], ("P01", 1, true));
        // The waiver does not leak to line 2.
        assert_eq!(hits[2], ("P01", 2, false));
    }

    #[test]
    fn waiver_grammar_errors_are_hard_errors() {
        let bad = [
            "x(); // lint: allowed(P01) typo\n",
            "x(); // lint: allow(P01\n",
            "x(); // lint: allow(D99) no such rule\n",
            "x(); // lint: allow(P01)\n", // missing reason
        ];
        for src in bad {
            let s = scan_source("rust/src/x.rs", src);
            assert_eq!(s.waiver_errors.len(), 1, "{src:?}");
        }
        // Prose mentioning lint waivers is not a waiver.
        let prose = "// the linter accepts lint waivers via allow(...)\nx();\n";
        assert!(scan_source("rust/src/x.rs", prose).waiver_errors.is_empty());
    }

    #[test]
    fn unused_waivers_are_reported_not_fatal() {
        let src = "// lint: allow(D03) no wall clock here after all\nlet x = 1;\n";
        let s = scan_source("rust/src/x.rs", src);
        assert!(s.waiver_errors.is_empty());
        assert_eq!(s.unused_waivers, vec![1]);
        // A waiver dangling at EOF is unused too.
        let eof = "let x = 1;\n// lint: allow(D03) dangling\n";
        assert_eq!(scan_source("rust/src/x.rs", eof).unused_waivers, vec![2]);
    }

    #[test]
    fn patterns_inside_strings_do_not_fire() {
        let src = "let msg = \"call .unwrap() on HashMap at Instant::now\";\n";
        assert!(hit_rules("rust/src/x.rs", src).is_empty());
    }
}
