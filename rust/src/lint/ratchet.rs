//! Ratcheted debt baseline for `yalis lint`.
//!
//! `lint/baseline.json` records, per file and per rule, how many
//! *unwaived* violations existed when the linter landed. The contract is
//! one-directional: a count above its baseline entry fails the run (new
//! debt), a count below it is written back automatically so the ceiling
//! only ever comes down ("auto-tighten"). Files and rules at zero are
//! dropped from the file entirely. Never hand-raise an entry — fix the
//! code or waive the line with a reason instead.
//!
//! The format is the repo's no-serde JSON (parsed with
//! [`crate::obs::json`], emitted by hand, keys sorted) so diffs are
//! stable and reviewable.

use crate::obs::chrome::esc;
use crate::obs::json as oj;
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::Path;

/// file → rule id → unwaived violation count.
pub type Counts = BTreeMap<String, BTreeMap<String, u64>>;

/// Load a baseline. A missing file is an empty baseline (every
/// violation is new debt), so a repo without one still gets gated.
pub fn load(path: &Path) -> anyhow::Result<Counts> {
    if !path.exists() {
        return Ok(Counts::new());
    }
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading lint baseline {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing lint baseline {}", path.display()))
}

/// Parse baseline JSON text.
pub fn parse(text: &str) -> anyhow::Result<Counts> {
    let v = oj::parse(text).map_err(|e| anyhow::anyhow!("bad JSON: {e}"))?;
    let schema = v.get("schema").and_then(|s| s.as_f64());
    if schema != Some(1.0) {
        bail!("unsupported baseline schema {schema:?} (expected 1)");
    }
    let files = match v.get("counts") {
        Some(oj::Value::Obj(files)) => files,
        _ => bail!("missing \"counts\" object"),
    };
    let mut out = Counts::new();
    for (file, rules) in files {
        let rules = match rules {
            oj::Value::Obj(rs) => rs,
            _ => bail!("counts[{file}] must be an object"),
        };
        for (rule, n) in rules {
            let n = match n.as_f64() {
                Some(x) if x >= 0.0 => x as u64,
                _ => bail!("counts[{file}][{rule}] must be a non-negative number"),
            };
            if n > 0 {
                out.entry(file.clone()).or_default().insert(rule.clone(), n);
            }
        }
    }
    Ok(out)
}

/// Render baseline JSON: sorted, one file per line, diff-friendly.
/// Zero-count entries are dropped.
pub fn render(counts: &Counts) -> String {
    let mut s = String::from("{\n  \"schema\": 1,\n");
    s.push_str(
        "  \"note\": \"ratchet: counts may only decrease; `yalis lint` \
         auto-tightens on improvement — never hand-raise an entry\",\n",
    );
    s.push_str("  \"counts\": {\n");
    let files: Vec<String> = counts
        .iter()
        .filter(|(_, rules)| rules.values().any(|n| *n > 0))
        .map(|(file, rules)| {
            let inner: Vec<String> = rules
                .iter()
                .filter(|(_, n)| **n > 0)
                .map(|(rule, n)| format!("\"{}\": {}", esc(rule), n))
                .collect();
            format!("    \"{}\": {{ {} }}", esc(file), inner.join(", "))
        })
        .collect();
    s.push_str(&files.join(",\n"));
    s.push_str("\n  }\n}\n");
    s
}

/// Write the baseline (creating parent directories).
pub fn save(path: &Path, counts: &Counts) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    std::fs::write(path, render(counts))
        .with_context(|| format!("writing lint baseline {}", path.display()))
}

/// Outcome of ratcheting current counts against the baseline.
#[derive(Clone, Debug, Default)]
pub struct RatchetResult {
    /// (file, rule, current, baseline): current exceeds baseline — new
    /// debt, fails the run.
    pub exceeded: Vec<(String, String, u64, u64)>,
    /// (file, rule, baseline, current): improved — the baseline can and
    /// will be tightened to `current`.
    pub tightened: Vec<(String, String, u64, u64)>,
    /// Violations fully covered by the baseline.
    pub baselined: u64,
}

/// Compare `current` unwaived counts against `baseline`.
pub fn compare(current: &Counts, baseline: &Counts) -> RatchetResult {
    let mut r = RatchetResult::default();
    for (file, rules) in current {
        for (rule, &c) in rules {
            if c == 0 {
                continue;
            }
            let b = baseline.get(file).and_then(|rs| rs.get(rule)).copied().unwrap_or(0);
            if c > b {
                r.exceeded.push((file.clone(), rule.clone(), c, b));
            } else {
                r.baselined += c;
                if c < b {
                    r.tightened.push((file.clone(), rule.clone(), b, c));
                }
            }
        }
    }
    // Baseline entries the current scan no longer reaches at all
    // (debt fully paid, or the file was deleted) tighten to zero.
    for (file, rules) in baseline {
        for (rule, &b) in rules {
            let c = current.get(file).and_then(|rs| rs.get(rule)).copied().unwrap_or(0);
            if c == 0 && b > 0 {
                r.tightened.push((file.clone(), rule.clone(), b, 0));
            }
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(entries: &[(&str, &str, u64)]) -> Counts {
        let mut c = Counts::new();
        for (f, r, n) in entries {
            c.entry(f.to_string()).or_default().insert(r.to_string(), *n);
        }
        c
    }

    #[test]
    fn round_trips_through_render_and_parse() {
        let c = counts(&[
            ("rust/src/engine/kv.rs", "P01", 12),
            ("rust/src/engine/kv.rs", "D02", 1),
            ("examples/quickstart.rs", "D03", 3),
        ]);
        let text = render(&c);
        let back = parse(&text).unwrap();
        assert_eq!(back, c);
        // Sorted and stable: rendering the parse reproduces the text.
        assert_eq!(render(&back), text);
    }

    #[test]
    fn zero_entries_are_dropped() {
        let c = counts(&[("a.rs", "P01", 0), ("b.rs", "D01", 2)]);
        let text = render(&c);
        assert!(!text.contains("a.rs"));
        let back = parse(&text).unwrap();
        assert!(!back.contains_key("a.rs"));
        assert_eq!(back["b.rs"]["D01"], 2);
    }

    #[test]
    fn rejects_bad_schema_and_shapes() {
        assert!(parse("{}").is_err());
        assert!(parse("{\"schema\": 2, \"counts\": {}}").is_err());
        assert!(parse("{\"schema\": 1}").is_err());
        assert!(parse("{\"schema\": 1, \"counts\": {\"a.rs\": 3}}").is_err());
        assert!(parse("{\"schema\": 1, \"counts\": {\"a.rs\": {\"P01\": -1}}}").is_err());
        assert!(parse("{\"schema\": 1, \"counts\": {}}").unwrap().is_empty());
    }

    #[test]
    fn compare_flags_increase_and_tightens_decrease() {
        let base = counts(&[("a.rs", "P01", 3), ("b.rs", "D02", 2), ("gone.rs", "P01", 4)]);
        let cur = counts(&[("a.rs", "P01", 5), ("b.rs", "D02", 1)]);
        let r = compare(&cur, &base);
        assert_eq!(r.exceeded, vec![("a.rs".into(), "P01".into(), 5, 3)]);
        assert_eq!(r.baselined, 1);
        let mut t = r.tightened.clone();
        t.sort();
        assert_eq!(
            t,
            vec![
                ("b.rs".into(), "D02".into(), 2, 1),
                ("gone.rs".into(), "P01".into(), 4, 0),
            ]
        );
    }

    #[test]
    fn unbaselined_violation_is_new_debt() {
        let r = compare(&counts(&[("new.rs", "D04", 1)]), &Counts::new());
        assert_eq!(r.exceeded, vec![("new.rs".into(), "D04".into(), 1, 0)]);
    }

    #[test]
    fn equal_counts_are_quiet() {
        let c = counts(&[("a.rs", "P01", 3)]);
        let r = compare(&c, &c);
        assert!(r.exceeded.is_empty() && r.tightened.is_empty());
        assert_eq!(r.baselined, 3);
    }
}
