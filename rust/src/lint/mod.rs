//! simlint — determinism-invariant static analysis for the simulator
//! (`yalis lint`).
//!
//! The repo's headline guarantees are determinism claims: traced runs
//! bit-for-bit equal to untraced, contention-off equal to pre-contention
//! numbers, idle-fabric α-β parity within 1e-9. Spot-check tests pin
//! those end to end, but the hazards that silently break them — NaN
//! orderings, wall-clock reads, iteration-order-dependent containers,
//! ambient RNG, panics in library paths — reappear with every PR. This
//! module is the machine-checked invariant layer: a dependency-free
//! source scanner ([`scan`]) enforcing a small rule catalog ([`RULES`]),
//! with inline waivers (`// lint: allow(RULE) reason`) and a committed
//! per-file ratcheted debt baseline ([`ratchet`], `lint/baseline.json`)
//! so pre-existing debt is frozen and can only shrink.
//!
//! Rule catalog (see DESIGN.md "Static analysis & determinism
//! invariants" for the rationale of each):
//!
//! | id  | pattern | protects |
//! |-----|---------|----------|
//! | D01 | `HashMap`/`HashSet` in simulation modules | iteration-order determinism |
//! | D02 | `partial_cmp` comparators (`unwrap`/`sort_by`/`min_by`/`max_by`) | NaN-total ordering |
//! | D03 | `Instant::now`/`SystemTime` outside real-hardware modules | simulated-time purity |
//! | D04 | `thread_rng`/`rand::random` | all randomness flows from the seed |
//! | P01 | `unwrap`/`expect`/`panic!`/`f64::NAN` in library code | panic-free library paths |
//!
//! `yalis lint` exits non-zero on any new (unwaived, above-baseline)
//! violation or malformed waiver; `--json` emits a machine-readable
//! report for CI.

// This module is a CLI surface: diagnostics and the summary table print
// to stdout by design.
#![allow(clippy::print_stdout)]

pub mod ratchet;
pub mod scan;

use crate::obs::chrome::esc;
use crate::util::tables::Table;
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lint rule: stable id, what it matches, which guarantee it guards.
pub struct Rule {
    pub id: &'static str,
    pub summary: &'static str,
    pub protects: &'static str,
}

/// The rule catalog. Ids are stable (they appear in waivers and in the
/// committed baseline); add new rules at the end.
pub const RULES: [Rule; 5] = [
    Rule {
        id: "D01",
        summary: "HashMap/HashSet in simulation code",
        protects: "iteration order feeds simulated decisions; BTreeMap/Vec keep runs bit-for-bit",
    },
    Rule {
        id: "D02",
        summary: "NaN-unsafe float comparator (partial_cmp in sort/min/max or unwrapped)",
        protects: "a NaN must surface as a value bug, not a panic or heap-shape-dependent order",
    },
    Rule {
        id: "D03",
        summary: "wall-clock read (Instant::now/SystemTime) in simulated paths",
        protects: "simulated time derives from the event queue; wall-clock makes runs machine-bound",
    },
    Rule {
        id: "D04",
        summary: "ambient randomness (thread_rng/rand::random)",
        protects: "all stochastic choice flows from the run seed so reruns reproduce exactly",
    },
    Rule {
        id: "P01",
        summary: "panic path (unwrap/expect/panic!/f64::NAN) in library code",
        protects: "library paths return Result; a panic kills a fleet run halfway through",
    },
];

/// Directories scanned, relative to the repo root. Missing ones are
/// skipped (`rust/examples` exists for layouts that keep examples under
/// the package; this repo keeps them at the workspace root).
pub const ROOTS: [&str; 5] = ["rust/src", "rust/tests", "rust/benches", "rust/examples", "examples"];

/// Default ratchet baseline path, relative to the repo root.
pub const DEFAULT_BASELINE: &str = "lint/baseline.json";

/// A (file, rule) group whose unwaived count exceeds its baseline.
#[derive(Clone, Debug)]
pub struct DebtGroup {
    pub file: String,
    pub rule: &'static str,
    pub count: u64,
    pub baseline: u64,
    /// All unwaived hits of the rule in the file (line, excerpt) — the
    /// scanner cannot know which individual lines are the new ones.
    pub hits: Vec<(usize, String)>,
}

/// Aggregated result of a lint run.
#[derive(Default)]
pub struct Report {
    pub files_scanned: usize,
    pub new_debt: Vec<DebtGroup>,
    pub waiver_errors: Vec<(String, usize, String)>,
    pub unused_waivers: Vec<(String, usize)>,
    /// (file, rule, old, new) baseline entries that will tighten.
    pub tightened: Vec<(String, String, u64, u64)>,
    pub baselined: u64,
    pub waived: u64,
    /// Current unwaived counts (what an auto-tightened baseline holds).
    pub counts: ratchet::Counts,
    /// Per-rule (baselined, waived, new) tallies for the summary table.
    pub per_rule: BTreeMap<&'static str, (u64, u64, u64)>,
}

impl Report {
    /// A run passes iff there is no new debt and every waiver parses.
    pub fn ok(&self) -> bool {
        self.new_debt.is_empty() && self.waiver_errors.is_empty()
    }
}

/// Recursively collect `.rs` files under the scan roots, sorted by
/// repo-relative path so runs are deterministic.
pub fn collect_files(root: &Path) -> anyhow::Result<Vec<(String, PathBuf)>> {
    fn walk(dir: &Path, root: &Path, out: &mut Vec<(String, PathBuf)>) -> anyhow::Result<()> {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("reading {}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, root, out)?;
            } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
                let rel = p
                    .strip_prefix(root)
                    .unwrap_or(&p)
                    .to_string_lossy()
                    .replace('\\', "/");
                out.push((rel, p));
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    for r in ROOTS {
        let dir = root.join(r);
        if dir.is_dir() {
            walk(&dir, root, &mut out)?;
        }
    }
    out.sort();
    Ok(out)
}

/// Run the scanner over the repo at `root` and ratchet against the
/// baseline at `baseline_path` (not written here — see [`run_cli`]).
pub fn run(root: &Path, baseline_path: &Path) -> anyhow::Result<Report> {
    let files = collect_files(root)?;
    if files.is_empty() {
        bail!("no .rs files found under {} (scan roots: {})", root.display(), ROOTS.join(", "));
    }
    let mut report = Report { files_scanned: files.len(), ..Report::default() };
    for r in RULES.iter() {
        report.per_rule.insert(r.id, (0, 0, 0));
    }
    // (file, rule) → unwaived hits.
    let mut groups: BTreeMap<(String, &'static str), Vec<(usize, String)>> = BTreeMap::new();
    for (rel, path) in &files {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let fs = scan::scan_source(rel, &text);
        for e in fs.waiver_errors {
            report.waiver_errors.push((rel.clone(), e.line, e.msg));
        }
        for l in fs.unused_waivers {
            report.unused_waivers.push((rel.clone(), l));
        }
        for h in fs.hits {
            if h.waived {
                report.waived += 1;
                if let Some(t) = report.per_rule.get_mut(h.rule) {
                    t.1 += 1;
                }
            } else {
                groups.entry((rel.clone(), h.rule)).or_default().push((h.line, h.excerpt));
            }
        }
    }
    for ((file, rule), hits) in &groups {
        report
            .counts
            .entry(file.clone())
            .or_default()
            .insert(rule.to_string(), hits.len() as u64);
    }
    let baseline = ratchet::load(baseline_path)?;
    let rr = ratchet::compare(&report.counts, &baseline);
    report.baselined = rr.baselined;
    report.tightened = rr.tightened;
    for (file, rule, c, b) in rr.exceeded {
        let rule_id = RULES.iter().find(|r| r.id == rule.as_str()).map(|r| r.id).unwrap_or("?");
        let hits = groups.get(&(file.clone(), rule_id)).cloned().unwrap_or_default();
        if let Some(t) = report.per_rule.get_mut(rule_id) {
            t.2 += c - b;
        }
        report.new_debt.push(DebtGroup { file, rule: rule_id, count: c, baseline: b, hits });
    }
    // Everything unwaived and not exceeded is baselined debt.
    for ((file, rule), hits) in &groups {
        let exceeded = report.new_debt.iter().any(|d| d.file == *file && d.rule == *rule);
        if !exceeded {
            if let Some(t) = report.per_rule.get_mut(*rule) {
                t.0 += hits.len() as u64;
            }
        }
    }
    Ok(report)
}

/// Render the per-rule summary table.
pub fn summary_table(report: &Report) -> Table {
    let mut t = Table::new("simlint summary", &["rule", "checks", "baselined", "waived", "new"]);
    t.meta("files_scanned", &report.files_scanned.to_string());
    for r in RULES.iter() {
        let (b, w, n) = report.per_rule.get(r.id).copied().unwrap_or((0, 0, 0));
        t.row(&[
            r.id.to_string(),
            r.summary.to_string(),
            b.to_string(),
            w.to_string(),
            n.to_string(),
        ]);
    }
    t
}

/// Render the machine-readable JSON report (no serde — hand-emitted,
/// validated by [`crate::obs::json`] in tests).
pub fn report_json(report: &Report) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"schema\": 1,\n");
    s.push_str(&format!("  \"files_scanned\": {},\n", report.files_scanned));
    s.push_str(&format!("  \"ok\": {},\n", report.ok()));
    let new_total: u64 = report.new_debt.iter().map(|d| d.count - d.baseline).sum();
    s.push_str(&format!("  \"new\": {new_total},\n"));
    s.push_str(&format!("  \"baselined\": {},\n", report.baselined));
    s.push_str(&format!("  \"waived\": {},\n", report.waived));
    s.push_str(&format!("  \"tightened\": {},\n", report.tightened.len()));
    let werrs: Vec<String> = report
        .waiver_errors
        .iter()
        .map(|(f, l, m)| {
            format!("    {{ \"file\": \"{}\", \"line\": {l}, \"msg\": \"{}\" }}", esc(f), esc(m))
        })
        .collect();
    s.push_str(&format!("  \"waiver_errors\": [\n{}\n  ],\n", werrs.join(",\n")));
    let debts: Vec<String> = report
        .new_debt
        .iter()
        .map(|d| {
            let lines: Vec<String> = d.hits.iter().map(|(l, _)| l.to_string()).collect();
            format!(
                "    {{ \"file\": \"{}\", \"rule\": \"{}\", \"count\": {}, \"baseline\": {}, \"lines\": [{}] }}",
                esc(&d.file),
                d.rule,
                d.count,
                d.baseline,
                lines.join(", ")
            )
        })
        .collect();
    s.push_str(&format!("  \"new_debt\": [\n{}\n  ]\n", debts.join(",\n")));
    s.push_str("}\n");
    // Hand-emitted arrays with no members would render a blank line;
    // normalize to strict JSON either way.
    s.replace("[\n\n  ]", "[]")
}

/// CLI entry for `yalis lint`. Returns `Ok(true)` when the repo is
/// clean (exit 0), `Ok(false)` on new debt or waiver errors (exit 1);
/// IO/parse failures bubble as `Err` (exit 2).
pub fn run_cli(root: &str, baseline: &str, json: bool, out: &str) -> anyhow::Result<bool> {
    let root_path = Path::new(root);
    if !root_path.join("rust/src").is_dir() {
        bail!("--root {root}: rust/src not found (run from the repo root or pass --root)");
    }
    let baseline_path = if Path::new(baseline).is_absolute() {
        PathBuf::from(baseline)
    } else {
        root_path.join(baseline)
    };
    let report = run(root_path, &baseline_path)?;

    let json_text = report_json(&report);
    if json {
        println!("{json_text}");
    } else {
        for (file, line, msg) in &report.waiver_errors {
            println!("{file}:{line}: [waiver] {msg}");
        }
        for d in &report.new_debt {
            println!(
                "{}: [{}] {} unwaived (baseline {}) — new debt:",
                d.file, d.rule, d.count, d.baseline
            );
            for (line, excerpt) in &d.hits {
                println!("  {}:{}: {}", d.file, line, excerpt);
            }
        }
        for (file, line) in &report.unused_waivers {
            println!("{file}:{line}: note: waiver matches no violation (stale?)");
        }
        summary_table(&report).print();
    }
    if !out.is_empty() {
        let out_path = Path::new(out);
        if let Some(dir) = out_path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        std::fs::write(out_path, &json_text).with_context(|| format!("writing {out}"))?;
        if !json {
            println!("-> {out}");
        }
    }
    if report.ok() && !report.tightened.is_empty() {
        ratchet::save(&baseline_path, &report.counts)?;
        eprintln!(
            "lint: ratchet tightened {} entr{} in {} — commit the updated baseline",
            report.tightened.len(),
            if report.tightened.len() == 1 { "y" } else { "ies" },
            baseline_path.display()
        );
    }
    if report.ok() {
        eprintln!(
            "lint: clean — {} files, {} baselined, {} waived",
            report.files_scanned, report.baselined, report.waived
        );
    } else {
        eprintln!(
            "lint: FAILED — {} new-debt group(s), {} waiver error(s); fix the code, \
             waive with `// lint: allow(RULE) reason`, or (never) hand-raise the baseline",
            report.new_debt.len(),
            report.waiver_errors.len()
        );
    }
    Ok(report.ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(counts: &[(&str, &'static str, u64, u64)]) -> Report {
        // (file, rule, current, baseline) — synthesize a report the way
        // `run` would classify it.
        let mut r = Report::default();
        for rule in RULES.iter() {
            r.per_rule.insert(rule.id, (0, 0, 0));
        }
        for (file, rule, c, b) in counts {
            r.counts.entry(file.to_string()).or_default().insert(rule.to_string(), *c);
            if c > b {
                let hits = (1..=*c as usize).map(|i| (i, format!("line {i}"))).collect();
                r.new_debt.push(DebtGroup {
                    file: file.to_string(),
                    rule: *rule,
                    count: *c,
                    baseline: *b,
                    hits,
                });
            } else {
                r.baselined += c;
            }
        }
        r
    }

    #[test]
    fn json_report_parses_and_carries_verdict() {
        let r = report_with(&[("a.rs", "P01", 3, 1), ("b.rs", "D02", 1, 1)]);
        let v = crate::obs::json::parse(&report_json(&r)).unwrap();
        assert_eq!(v.get("ok"), Some(&crate::obs::json::Value::Bool(false)));
        assert_eq!(v.get("new").and_then(|x| x.as_f64()), Some(2.0));
        assert_eq!(v.get("baselined").and_then(|x| x.as_f64()), Some(1.0));
        let debt = v.get("new_debt").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(debt.len(), 1);
        assert_eq!(debt[0].get("file").and_then(|x| x.as_str()), Some("a.rs"));
        assert_eq!(debt[0].get("lines").and_then(|x| x.as_arr()).map(|a| a.len()), Some(3));
    }

    #[test]
    fn json_report_empty_arrays_are_strict_json() {
        let r = report_with(&[("a.rs", "P01", 1, 1)]);
        let v = crate::obs::json::parse(&report_json(&r)).unwrap();
        assert_eq!(v.get("ok"), Some(&crate::obs::json::Value::Bool(true)));
        assert_eq!(v.get("new_debt").and_then(|x| x.as_arr()).map(|a| a.len()), Some(0));
        assert_eq!(v.get("waiver_errors").and_then(|x| x.as_arr()).map(|a| a.len()), Some(0));
    }

    #[test]
    fn summary_table_has_one_row_per_rule() {
        let r = report_with(&[]);
        let t = summary_table(&r);
        assert_eq!(t.rows().len(), RULES.len());
    }

    #[test]
    fn rule_ids_are_unique_and_stable() {
        let mut ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n);
        assert_eq!(ids, vec!["D01", "D02", "D03", "D04", "P01"]);
    }
}
