//! In-process PGAS substrate — the "NVSHMEM" the real collectives run on.
//!
//! Each GPU of the paper's cluster becomes a **PE** (processing element)
//! running on its own thread. Every PE owns a *symmetric heap* of 64-bit
//! words; remote PEs write into it with one-sided [`Pe::put_nbi`] and the
//! owner observes arrival by polling flag bits — exactly the LL-protocol
//! discipline of the paper's §4.2.2: each heap word fuses 4 B of data with
//! a 4 B flag, so delivery of a word is atomic and ordered *by construction*
//! (a single atomic store), and no separate signaling op is needed.
//!
//! Correspondence to the NVSHMEM API used by NVRAR (Algorithm 1):
//!
//! | paper / NVSHMEM                  | here                              |
//! |----------------------------------|-----------------------------------|
//! | symmetric heap                   | per-PE `Vec<AtomicU64>`           |
//! | `put_nbi` (block-cooperative)    | [`Pe::put_nbi`] (Release stores)  |
//! | LL fused 8 B payload             | [`ll_word`] / [`ll_split`]        |
//! | `wait_until(flag == seq)`        | [`Pe::wait_ll`] (Acquire spins)   |
//! | sequence-number atomics (§4.2.3) | [`Pe::announce_seq`] / [`Pe::wait_peer_seq`] |
//! | `quiet` / `fence`                | [`Pe::quiet`] (SeqCst fence)      |
//! | `barrier_all`                    | [`Pe::barrier_all`]               |
//!
//! Races are confined to atomics by design; there is no `unsafe` here.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Barrier;

/// Pack an LL word: high 32 bits = flag (sequence number), low = data bits.
#[inline]
pub fn ll_word(data_bits: u32, flag: u32) -> u64 {
    ((flag as u64) << 32) | data_bits as u64
}

/// Split an LL word into `(data_bits, flag)`.
#[inline]
pub fn ll_split(word: u64) -> (u32, u32) {
    (word as u32, (word >> 32) as u32)
}

/// The shared world: `n_pes` symmetric heaps + synchronization state.
pub struct World {
    n_pes: usize,
    heaps: Vec<Vec<AtomicU64>>,
    seqs: Vec<AtomicU64>,
    barrier: Barrier,
}

impl World {
    /// Create a world of `n_pes` PEs, each owning `heap_words` 64-bit words.
    pub fn new(n_pes: usize, heap_words: usize) -> Self {
        assert!(n_pes >= 1);
        let heaps = (0..n_pes)
            .map(|_| (0..heap_words).map(|_| AtomicU64::new(0)).collect())
            .collect();
        let seqs = (0..n_pes).map(|_| AtomicU64::new(0)).collect();
        World { n_pes, heaps, seqs, barrier: Barrier::new(n_pes) }
    }

    pub fn n_pes(&self) -> usize {
        self.n_pes
    }

    pub fn heap_words(&self) -> usize {
        self.heaps[0].len()
    }

    /// Run `f(pe)` on one thread per PE and wait for all to finish.
    pub fn run<F>(&self, f: F)
    where
        F: Fn(Pe<'_>) + Sync,
    {
        std::thread::scope(|s| {
            for id in 0..self.n_pes {
                let world = &self;
                let f = &f;
                s.spawn(move || f(Pe { id, world }));
            }
        });
    }

    /// Read a heap word after a run (test/verification convenience).
    pub fn peek(&self, pe: usize, off: usize) -> u64 {
        self.heaps[pe][off].load(Ordering::Acquire)
    }
}

/// A PE's handle: its identity plus one-sided access to every heap.
pub struct Pe<'w> {
    pub id: usize,
    world: &'w World,
}

impl<'w> Pe<'w> {
    pub fn n_pes(&self) -> usize {
        self.world.n_pes
    }

    /// One-sided non-blocking put: store `words` into `peer`'s heap at
    /// `dst_off`. Each word is a single Release store — the LL guarantee
    /// that a data word and its flag arrive together.
    pub fn put_nbi(&self, peer: usize, dst_off: usize, words: &[u64]) {
        let heap = &self.world.heaps[peer];
        for (i, &w) in words.iter().enumerate() {
            heap[dst_off + i].store(w, Ordering::Release);
        }
    }

    /// One-sided put of an f32 slice as LL words (data bits fused with
    /// `flag`), packing on the fly — the zero-allocation hot path the
    /// collectives use (perf pass: the naive pack-into-Vec-then-put costs
    /// one heap allocation + an extra pass per chunk).
    pub fn put_f32_ll(&self, peer: usize, dst_off: usize, data: &[f32], flag: u32) {
        let heap = &self.world.heaps[peer];
        let flag_hi = (flag as u64) << 32;
        for (i, &v) in data.iter().enumerate() {
            heap[dst_off + i].store(flag_hi | v.to_bits() as u64, Ordering::Release);
        }
    }

    /// Store one word into our own heap.
    pub fn store_local(&self, off: usize, word: u64) {
        self.world.heaps[self.id][off].store(word, Ordering::Release);
    }

    /// Read one word from our own heap.
    pub fn load_local(&self, off: usize) -> u64 {
        self.world.heaps[self.id][off].load(Ordering::Acquire)
    }

    /// Spin until our heap word at `off` carries flag `flag`, then return
    /// its data bits. The LL-protocol receive: flag and data in one load.
    ///
    /// Perf pass: on oversubscribed hosts (more PEs than cores — always
    /// true here) burning a long spin quantum starves the very sender we
    /// wait on; after a short inline spin we yield on every miss. On real
    /// hardware (PE-per-core) the inline spin is the common path.
    pub fn wait_ll(&self, off: usize, flag: u32) -> u32 {
        let cell = &self.world.heaps[self.id][off];
        // Fast path + short spin.
        for _ in 0..16 {
            let w = cell.load(Ordering::Acquire);
            let (data, f) = ll_split(w);
            if f == flag {
                return data;
            }
            std::hint::spin_loop();
        }
        loop {
            std::thread::yield_now();
            let w = cell.load(Ordering::Acquire);
            let (data, f) = ll_split(w);
            if f == flag {
                return data;
            }
        }
    }

    /// Publish that this PE has reached sequence number `seq` (§4.2.3).
    pub fn announce_seq(&self, seq: u64) {
        self.world.seqs[self.id].store(seq, Ordering::Release);
    }

    /// Wait until `peer` has reached at least `seq`. Peer-wise — not a
    /// global barrier — exactly Algorithm 1 lines 4–6.
    pub fn wait_peer_seq(&self, peer: usize, seq: u64) {
        let cell = &self.world.seqs[peer];
        for _ in 0..16 {
            if cell.load(Ordering::Acquire) >= seq {
                return;
            }
            std::hint::spin_loop();
        }
        while cell.load(Ordering::Acquire) < seq {
            std::thread::yield_now();
        }
    }

    /// Ensure our prior puts are globally visible (they already are —
    /// atomic stores — but callers keep the call sites for fidelity).
    pub fn quiet(&self) {
        std::sync::atomic::fence(Ordering::SeqCst);
    }

    /// Full-world barrier (used only at world setup/teardown; the
    /// collectives themselves synchronize peer-wise).
    pub fn barrier_all(&self) {
        self.world.barrier.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ll_word_roundtrip() {
        let w = ll_word(0xDEADBEEF, 42);
        assert_eq!(ll_split(w), (0xDEADBEEF, 42));
        let w = ll_word(f32::to_bits(-1.5), u32::MAX);
        let (bits, flag) = ll_split(w);
        assert_eq!(f32::from_bits(bits), -1.5);
        assert_eq!(flag, u32::MAX);
    }

    #[test]
    fn put_then_wait_delivers() {
        let world = World::new(2, 16);
        world.run(|pe| {
            if pe.id == 0 {
                let words: Vec<u64> =
                    (0..8).map(|i| ll_word(i as u32 * 3, 7)).collect();
                pe.put_nbi(1, 4, &words);
            } else {
                for i in 0..8 {
                    let data = pe.wait_ll(4 + i, 7);
                    assert_eq!(data, i as u32 * 3);
                }
            }
        });
    }

    #[test]
    fn stale_flag_not_accepted() {
        let world = World::new(2, 4);
        world.run(|pe| {
            if pe.id == 0 {
                // Old op's payload (flag 1), then the real one (flag 2).
                pe.put_nbi(1, 0, &[ll_word(111, 1)]);
                pe.put_nbi(1, 0, &[ll_word(222, 2)]);
            } else {
                // Receiver waits for flag 2 and must never observe 111.
                assert_eq!(pe.wait_ll(0, 2), 222);
            }
        });
    }

    #[test]
    fn seq_announce_wait() {
        let world = World::new(3, 1);
        world.run(|pe| {
            for round in 1..=5u64 {
                pe.announce_seq(round);
                for peer in 0..pe.n_pes() {
                    pe.wait_peer_seq(peer, round);
                }
                // All peers at >= round here; write and read something.
                pe.store_local(0, ll_word(round as u32, round as u32));
            }
        });
        for pe in 0..3 {
            assert_eq!(ll_split(world.peek(pe, 0)).1, 5);
        }
    }

    #[test]
    fn all_pairs_exchange() {
        // Every PE puts its id into every peer's slot; all arrive.
        let n = 8;
        let world = World::new(n, n);
        world.run(|pe| {
            for peer in 0..n {
                pe.put_nbi(peer, pe.id, &[ll_word(pe.id as u32, 1)]);
            }
            for src in 0..n {
                assert_eq!(pe.wait_ll(src, 1), src as u32);
            }
        });
    }
}
