//! `yalis` — CLI entry point for the paper-reproduction experiment suite.
//!
//! Run `yalis --help` for subcommands; each regenerates one of the paper's
//! tables or figures (see DESIGN.md's per-experiment index).

fn main() {
    yalis::coordinator::main();
}
