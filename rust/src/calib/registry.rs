//! Built-in machine bundles and the one resolution path for `--machine`.
//!
//! Everything that needs per-machine constants — `CommConfig::for_machine`,
//! `GpuSpec::for_machine`, `cluster::presets::by_name`, the CLI — resolves
//! through [`resolve`], so a name always yields one *coherent* bundle: a
//! deployment can never pair perlmutter's α/β with vista's roofline. A
//! `--machine` value that is not a built-in name is treated as a path to a
//! bundle JSON file.

use super::bundle::{MachineBundle, TopoSpec};
use crate::collectives::sim::CommConfig;
use crate::perfmodel::GpuSpec;
use anyhow::{bail, Result};

/// Built-in bundle names, in help/display order.
pub fn names() -> &'static [&'static str] {
    &["perlmutter", "vista", "generic_ib"]
}

/// Comma-ish list of built-in names for error/help strings:
/// `"perlmutter, vista or generic_ib"`.
pub fn names_for_help() -> String {
    let ns = names();
    match ns {
        [] => String::new(),
        [only] => (*only).to_string(),
        [init @ .., last] => format!("{} or {last}", init.join(", ")),
    }
}

fn builtin(name: &str) -> Option<MachineBundle> {
    // Topology shapes are taken from the cluster presets at one node; the
    // node count is a per-experiment parameter, not a machine constant.
    let b = match name {
        "perlmutter" => MachineBundle {
            name: "perlmutter".to_string(),
            version: 1,
            comm: CommConfig::perlmutter(),
            gpu: GpuSpec::a100(),
            topo: TopoSpec::of(&crate::cluster::presets::perlmutter(1)),
        },
        "vista" => MachineBundle {
            name: "vista".to_string(),
            version: 1,
            comm: CommConfig::vista(),
            gpu: GpuSpec::gh200(),
            topo: TopoSpec::of(&crate::cluster::presets::vista(1)),
        },
        "generic_ib" => MachineBundle {
            name: "generic_ib".to_string(),
            version: 1,
            comm: CommConfig::generic_ib(),
            gpu: GpuSpec::a100(),
            topo: TopoSpec::of(&crate::cluster::presets::generic_ib(1)),
        },
        _ => return None,
    };
    Some(b)
}

/// Resolve a `--machine` value: a built-in bundle name, or a path to a
/// bundle JSON file (anything containing a path separator or ending in
/// `.json`, or simply a file that exists).
pub fn resolve(spec: &str) -> Result<MachineBundle> {
    if let Some(b) = builtin(spec) {
        return Ok(b);
    }
    let looks_like_path =
        spec.contains('/') || spec.contains('\\') || spec.ends_with(".json");
    if looks_like_path || std::path::Path::new(spec).is_file() {
        return MachineBundle::load(spec);
    }
    bail!(
        "unknown machine '{spec}' (expected {}, or a path to a bundle JSON file)",
        names_for_help()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_and_match_legacy_constants() {
        let p = resolve("perlmutter").unwrap();
        assert_eq!(p.label(), "perlmutter@1");
        assert_eq!(p.comm.reduce_bw, CommConfig::perlmutter().reduce_bw);
        assert_eq!(p.gpu.name, "A100-80GB");
        assert_eq!(p.topo.gpus_per_node, 4);

        let v = resolve("vista").unwrap();
        assert_eq!(v.comm.proxy_overhead, CommConfig::vista().proxy_overhead);
        assert_eq!(v.gpu.name, "GH200-96GB");
        assert_eq!(v.topo.gpus_per_node, 1);

        let g = resolve("generic_ib").unwrap();
        assert_eq!(g.comm.proxy_overhead, CommConfig::generic_ib().proxy_overhead);
        assert_eq!(g.topo.gpus_per_node, 8);
    }

    #[test]
    fn unknown_name_lists_valid_names() {
        let err = resolve("summit").unwrap_err().to_string();
        assert!(err.contains("unknown machine 'summit'"), "{err}");
        for n in names() {
            assert!(err.contains(n), "missing {n} in: {err}");
        }
    }

    #[test]
    fn pathlike_spec_reports_file_error_not_unknown_name() {
        let err = resolve("/no/such/bundle.json").unwrap_err().to_string();
        assert!(!err.contains("unknown machine"), "{err}");
    }

    #[test]
    fn bundle_file_resolves_via_machine_spec() {
        let dir = std::env::temp_dir().join("yalis_calib_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("site.json");
        let mut b = resolve("generic_ib").unwrap();
        b.name = "site_cluster".to_string();
        b.version = 3;
        b.save(path.to_str().unwrap()).unwrap();
        let loaded = resolve(path.to_str().unwrap()).unwrap();
        assert_eq!(loaded.label(), "site_cluster@3");
        assert_eq!(loaded.comm.sync_cost, b.comm.sync_cost);
    }
}
