//! The paper-claims validation harness behind `yalis validate`.
//!
//! Each [`Claim`] re-derives one quantitative claim of the paper from the
//! current simulation stack — NVRAR-vs-NCCL speedup per message size per
//! fabric (Fig 6), the 405B end-to-end decode-heavy speedup (Fig 7), the
//! Eq 1–6 closed-form parity — and checks the observed value against a
//! declared band. The harness exists so six PRs of model growth cannot
//! silently walk the simulator off the paper while tier-1 unit tests keep
//! passing: CI runs `yalis validate` and fails on any out-of-band claim.
//!
//! Bands are deliberately wider than the paper's point values: they bound
//! the *shape* of the reproduction (see DESIGN.md), leaving headroom for
//! calibration refits without letting a sign flip or an order-of-magnitude
//! drift through.

use super::bundle::MachineBundle;
use super::registry;
use crate::collectives::{sim, AllReduceImpl};
use crate::engine::persona::Persona;
use crate::engine::{engine_for_bundle, Workload};
use crate::models::ModelConfig;
use crate::util::tables::Table;
use anyhow::{bail, Result};

/// An inclusive `[lo, hi]` acceptance band for an observed ratio.
#[derive(Clone, Copy, Debug)]
pub struct Band {
    lo: f64,
    hi: f64,
}

impl Band {
    pub fn new(lo: f64, hi: f64) -> Result<Self> {
        if !(lo.is_finite() && hi.is_finite()) {
            bail!("band bounds must be finite (got [{lo}, {hi}])");
        }
        if lo > hi {
            bail!("inverted band: lo {lo} > hi {hi}");
        }
        Ok(Band { lo, hi })
    }

    /// Inclusive on both edges: a value exactly on a bound passes.
    pub fn contains(&self, v: f64) -> bool {
        v.is_finite() && v >= self.lo && v <= self.hi
    }

    pub fn lo(&self) -> f64 {
        self.lo
    }

    pub fn hi(&self) -> f64 {
        self.hi
    }
}

impl std::fmt::Display for Band {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{:.2}, {:.2}]", self.lo, self.hi)
    }
}

/// One registered claim: an observable computed from a bundle, plus the
/// band it must land in.
pub struct Claim {
    /// Stable identifier (`fig6/perlmutter/512KB`, `fig7/e2e/32gpu`, ...).
    pub id: String,
    /// Which built-in bundle this claim is calibrated against.
    pub machine: &'static str,
    /// Human description for the pass/fail table.
    pub what: String,
    pub band: Band,
    /// The observable: evaluated against the claim's built-in bundle, or
    /// against an override bundle passed to `yalis validate --bundle`.
    pub eval: Box<dyn Fn(&MachineBundle) -> f64>,
}

fn band(lo: f64, hi: f64) -> Band {
    Band::new(lo, hi).expect("registered claim bands are well-formed")
}

/// Fig 6 observable: NCCL-auto over NVRAR latency at `kb` KiB under
/// interleaved compute (gap hides the sequence-number sync, Appendix B).
fn hot_speedup(b: &MachineBundle, nodes: usize, kb: u64) -> f64 {
    let topo = b.topo.topology(nodes);
    let bytes = kb * 1024;
    sim::nccl_auto(&topo, &b.comm, bytes).total / sim::nvrar(&topo, &b.comm, bytes, 1.0).total
}

/// Fig 7 observable: 405B decode-heavy end-to-end batch latency ratio,
/// NCCL-auto over NVRAR, TP across `gpus`.
fn e2e_405b_speedup(b: &MachineBundle, gpus: usize) -> f64 {
    let w = Workload::decode_heavy(32);
    let nccl = engine_for_bundle(
        b,
        ModelConfig::llama31_405b(),
        gpus,
        "tp",
        Persona::yalis(),
        AllReduceImpl::NcclAuto,
    )
    .run_batch(&w);
    let nvrar = engine_for_bundle(
        b,
        ModelConfig::llama31_405b(),
        gpus,
        "tp",
        Persona::yalis(),
        AllReduceImpl::Nvrar,
    )
    .run_batch(&w);
    nccl.total / nvrar.total
}

/// Fig 13 observable: hidden-vs-serial NVRAR latency ratio at the paper's
/// 128 KiB / 16-GPU operating point — interleaved compute hides the
/// deferred sequence-number sync, so the hot call must be a real but
/// bounded fraction cheaper than the cold one (Appendix B).
fn fig13_hidden_vs_serial(b: &MachineBundle) -> f64 {
    let topo = b.topo.topology(4);
    let bytes = 128 * 1024;
    sim::nvrar(&topo, &b.comm, bytes, 1.0).total / sim::nvrar(&topo, &b.comm, bytes, 0.0).total
}

/// Fig 13 step-level observable: the fraction of a tp16/NVRAR decode
/// step's collective time the cost layer hides at full overlap. The
/// compute-cap makes this land strictly inside (0, 1): a 32-row decode
/// layer has less GEMM time than its serial all-reduce pair, so even
/// `uniform(1.0)` cannot hide everything.
fn fig13_step_hidden_frac(b: &MachineBundle) -> f64 {
    let cfg = crate::serving::fig9_config_bundle(
        crate::parallel::ParallelSpec::tp(16),
        AllReduceImpl::Nvrar,
        32,
        b,
        16,
    )
    .with_overlap(crate::parallel::OverlapSpec::uniform(1.0));
    let step = crate::engine::batcher::StepBatch {
        prefills: vec![],
        decodes: (0..32u64).collect(),
        decode_ctx: vec![1024; 32],
    };
    let c = cfg.step_comm(&step);
    c.hidden / (c.hidden + c.exposed).max(1e-30)
}

/// Eq 6 parity observable: event-level NVRAR sim over the closed form with
/// chunking and implementation overheads disabled (the same zeroing as the
/// pinned `sim_vs_closed_form_agreement` test).
fn eq6_parity(b: &MachineBundle, kb: u64) -> f64 {
    let topo = b.topo.topology(8);
    let mut c = b.comm;
    c.block_count = 1;
    c.chunk_bytes = u64::MAX;
    c.put_overhead = 0.0;
    c.nvshmem_overhead = 0.0;
    c.sync_cost = 0.0;
    c.launch_overhead = 0.0;
    c.reduce_bw = f64::INFINITY;
    let bytes = kb * 1024;
    sim::nvrar(&topo, &c, bytes, 0.0).total / crate::collectives::model::nvrar(&topo, bytes, c.eta)
}

/// The registered claim suite. Band centers were computed from the built-in
/// bundles at registration time; widths allow recalibration headroom.
pub fn claims() -> Vec<Claim> {
    let mut out = Vec::new();
    let mut fig6 = |machine: &'static str, nodes: usize, kb: u64, lo: f64, hi: f64| {
        out.push(Claim {
            id: format!("fig6/{machine}/{kb}KB"),
            machine,
            what: format!("NVRAR vs NCCL speedup, {kb} KiB, {nodes} nodes, hot"),
            band: band(lo, hi),
            eval: Box::new(move |b| hot_speedup(b, nodes, kb)),
        });
    };
    // Perlmutter (Slingshot-11), 8 nodes = 32 GPUs. Observed at v1:
    // 1.26 / 1.35 / 1.50 / 1.55 / 1.35.
    fig6("perlmutter", 8, 128, 1.05, 1.50);
    fig6("perlmutter", 8, 256, 1.10, 1.60);
    fig6("perlmutter", 8, 512, 1.20, 1.80);
    fig6("perlmutter", 8, 1024, 1.25, 1.85);
    fig6("perlmutter", 8, 2048, 1.05, 1.65);
    // Vista (InfiniBand), 16 nodes = 16 GPUs. Observed at v1:
    // 3.91 / 3.52 / 2.52 / 1.59 / 1.11 — the larger IB-side wins of Fig 6.
    fig6("vista", 16, 128, 3.10, 4.70);
    fig6("vista", 16, 256, 2.80, 4.20);
    fig6("vista", 16, 512, 2.00, 3.10);
    fig6("vista", 16, 1024, 1.30, 1.95);
    fig6("vista", 16, 2048, 0.95, 1.35);
    // Generic IB (8 GPUs/node), 8 nodes = 64 GPUs. Observed at v1:
    // 1.72 / 1.98 / 2.18.
    fig6("generic_ib", 8, 128, 1.40, 2.10);
    fig6("generic_ib", 8, 512, 1.60, 2.40);
    fig6("generic_ib", 8, 2048, 1.75, 2.65);
    for gpus in [32usize, 64] {
        out.push(Claim {
            id: format!("fig7/e2e-405b/{gpus}gpu"),
            machine: "perlmutter",
            what: format!("405B decode-heavy e2e speedup, TP {gpus} GPUs"),
            band: band(1.05, 2.0),
            eval: Box::new(move |b| e2e_405b_speedup(b, gpus)),
        });
    }
    out.push(Claim {
        id: "eq6/parity/128KB".to_string(),
        machine: "perlmutter",
        what: "NVRAR sim / Eq 6 closed form, overheads zeroed".to_string(),
        band: band(0.90, 1.30),
        eval: Box::new(|b| eq6_parity(b, 128)),
    });
    // Fig 13 (sync hiding): observed at v10 — 0.793 for the kernel-level
    // hot/cold ratio, 0.437 for the step-level hidden fraction.
    out.push(Claim {
        id: "fig13/hidden-vs-serial/128KB".to_string(),
        machine: "perlmutter",
        what: "NVRAR hot / cold latency, 128 KiB, 16 GPUs".to_string(),
        band: band(0.70, 0.90),
        eval: Box::new(fig13_hidden_vs_serial),
    });
    out.push(Claim {
        id: "fig13/step-hidden-frac/tp16".to_string(),
        machine: "perlmutter",
        what: "hidden share of tp16/NVRAR decode-step comm at overlap 1.0".to_string(),
        band: band(0.25, 0.65),
        eval: Box::new(fig13_step_hidden_frac),
    });
    out
}

/// Run the claim suite and render the pass/fail table.
///
/// With `override_bundle`, only claims registered for the same machine
/// *name* run, evaluated against the override — this is how a fitted or
/// site-local bundle is checked. Without it, every claim runs against its
/// own built-in bundle. Returns `(table, all_passed)`.
pub fn run(override_bundle: Option<&MachineBundle>) -> Result<(Table, bool)> {
    let suite = claims();
    let mut t = Table::new(
        "yalis validate — paper-claim bands",
        &["claim", "machine", "what", "observed", "band", "verdict"],
    );
    if let Some(b) = override_bundle {
        t.meta("bundle", &b.label());
    }
    let mut ran = 0usize;
    let mut all_pass = true;
    for c in suite {
        let bundle = match override_bundle {
            Some(b) => {
                if b.name != c.machine {
                    continue;
                }
                b.clone()
            }
            None => registry::resolve(c.machine)?,
        };
        ran += 1;
        let observed = (c.eval)(&bundle);
        let pass = c.band.contains(observed);
        all_pass &= pass;
        t.row(&[
            c.id.clone(),
            bundle.label(),
            c.what.clone(),
            if observed.is_finite() { format!("{observed:.3}") } else { observed.to_string() },
            c.band.to_string(),
            if pass { "pass".to_string() } else { "FAIL".to_string() },
        ]);
    }
    if ran == 0 {
        if let Some(b) = override_bundle {
            bail!(
                "no claims registered for machine '{}' (claims exist for {})",
                b.name,
                registry::names_for_help()
            );
        }
    }
    Ok((t, all_pass))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn band_edges_are_inclusive() {
        let b = Band::new(1.0, 2.0).unwrap();
        assert!(b.contains(1.0));
        assert!(b.contains(2.0));
        assert!(b.contains(1.5));
        assert!(!b.contains(1.0 - 1e-9));
        assert!(!b.contains(2.0 + 1e-9));
        assert!(!b.contains(f64::NAN));
        assert!(!b.contains(f64::INFINITY));
        // degenerate point band is legal
        assert!(Band::new(1.0, 1.0).unwrap().contains(1.0));
    }

    #[test]
    fn inverted_or_nan_bands_rejected() {
        assert!(Band::new(2.0, 1.0).is_err());
        assert!(Band::new(f64::NAN, 1.0).is_err());
        assert!(Band::new(0.0, f64::INFINITY).is_err());
    }

    #[test]
    fn builtin_bundles_pass_all_claims() {
        let (table, ok) = run(None).unwrap();
        assert!(ok, "claim drift:\n{}", table.render());
        assert_eq!(table.rows().len(), claims().len());
    }

    #[test]
    fn perturbed_bundle_fails_validation() {
        // A 5 ms per-put NVSHMEM overhead makes NVRAR uncompetitive; every
        // perlmutter speedup claim must leave its band.
        let mut b = registry::resolve("perlmutter").unwrap();
        b.comm.nvshmem_overhead = 5.0e-3;
        let (table, ok) = run(Some(&b)).unwrap();
        assert!(!ok, "perturbation not detected:\n{}", table.render());
    }

    #[test]
    fn override_bundle_runs_only_its_machines_claims() {
        let b = registry::resolve("vista").unwrap();
        let (table, ok) = run(Some(&b)).unwrap();
        assert!(ok);
        assert!(table.rows().len() < claims().len());
        for row in table.rows() {
            assert_eq!(row[1], "vista@1");
        }
    }

    #[test]
    fn unknown_override_machine_is_an_error() {
        let mut b = registry::resolve("vista").unwrap();
        b.name = "frontier".to_string();
        let err = run(Some(&b)).unwrap_err().to_string();
        assert!(err.contains("no claims registered"), "{err}");
    }
}
