//! `yalis fit`: least-squares calibration of link α/β (and optionally the
//! GPU roofline efficiency) from measured-latency CSVs.
//!
//! The input is the shape the sweeps emit and the real `shmem` all-reduce
//! path can produce: `bytes,gpus,impl,seconds`. Each row is mapped through
//! the closed-form models (Eqs 1–6) to a linear combination of
//! `θ = [α_intra, 1/β_intra, α_inter, 1/β_inter]`, and θ is solved by
//! column-scaled normal equations (Gaussian elimination with partial
//! pivoting — 4 unknowns, so the normal-equation conditioning is fine once
//! columns are scaled to O(1)). Columns with no signal in the data (e.g.
//! no multi-GPU-per-node rows ⇒ no intra terms) keep the base bundle's
//! values. The output is a new bundle with `version = base + 1` plus a
//! per-row residual report, closing the loop: measure → fit → bundle →
//! validate.

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use super::bundle::MachineBundle;
use crate::collectives::model::log2_steps;
use crate::perfmodel::GpuSpec;
use crate::util::tables::Table;
use anyhow::{bail, Context, Result};

/// One measured all-reduce latency sample.
#[derive(Clone, Debug)]
pub struct FitRow {
    pub bytes: u64,
    pub gpus: usize,
    pub imp: String,
    pub secs: f64,
}

/// Parse a `bytes,gpus,impl,seconds` CSV. `#` comments, blank lines and a
/// leading header row are skipped.
pub fn parse_csv(text: &str) -> Result<Vec<FitRow>> {
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cells: Vec<&str> = line.split(',').map(str::trim).collect();
        if cells.len() != 4 {
            bail!("line {}: expected 4 columns bytes,gpus,impl,seconds", ln + 1);
        }
        if cells[0].parse::<u64>().is_err() && cells[0].eq_ignore_ascii_case("bytes") {
            continue; // header row
        }
        let bytes: u64 =
            cells[0].parse().with_context(|| format!("line {}: bad bytes '{}'", ln + 1, cells[0]))?;
        let gpus: usize =
            cells[1].parse().with_context(|| format!("line {}: bad gpus '{}'", ln + 1, cells[1]))?;
        let secs: f64 = cells[3]
            .parse()
            .with_context(|| format!("line {}: bad seconds '{}'", ln + 1, cells[3]))?;
        if !(secs.is_finite() && secs > 0.0) {
            bail!("line {}: seconds must be positive ({secs})", ln + 1);
        }
        if bytes == 0 || gpus == 0 {
            bail!("line {}: bytes and gpus must be >= 1", ln + 1);
        }
        out.push(FitRow { bytes, gpus, imp: cells[2].to_string(), secs });
    }
    if out.is_empty() {
        bail!("no data rows in fit CSV");
    }
    Ok(out)
}

/// Coefficients of θ for one sample under the matching closed-form model
/// (Eqs 1–6): `t ≈ c·θ` with `θ = [α_i, 1/β_i, α_e, 1/β_e]`.
fn coeffs(imp: &str, nodes: usize, g: usize, bytes: u64, eta: f64) -> Result<[f64; 4]> {
    let n = nodes as f64;
    let p = (nodes * g) as f64;
    let m = bytes as f64;
    Ok(match imp {
        // Eq 1: ring charges every hop at inter α/β at scale.
        "ring" | "nccl-ring" => [0.0, 0.0, 2.0 * (p - 1.0), 2.0 * ((p - 1.0) / p) * m],
        // Eq 2: intra chain + inter tree depth.
        "tree" | "nccl-tree" => {
            [2.0 * (g as f64 - 1.0), 0.0, 2.0 * log2_steps(n), 2.0 * ((n - 1.0) / n) * m]
        }
        // Eq 3: flat recursive doubling, full message per step.
        "mpi" | "rd" => {
            let s = log2_steps(p);
            [0.0, 0.0, s, s * m]
        }
        // Eqs 4–6: RS + AG intra, recursive doubling on η-inflated
        // node-local shards inter.
        "nvrar" => {
            let gf = g as f64;
            [
                2.0 * (gf - 1.0),
                2.0 * ((gf - 1.0) / gf) * m,
                log2_steps(n),
                ((n - 1.0) / n) * eta * m / gf,
            ]
        }
        other => bail!(
            "unknown impl '{other}' in fit CSV (expected ring, tree, mpi/rd or nvrar)"
        ),
    })
}

/// Least squares for `A·θ ≈ y` over the active (non-zero) columns of `A`.
/// Returns per-column `Some(θ_k)` or `None` for columns with no signal.
fn solve_lstsq(a: &[[f64; 4]], y: &[f64]) -> Result<[Option<f64>; 4]> {
    let active: Vec<usize> =
        (0..4).filter(|&k| a.iter().any(|r| r[k] != 0.0)).collect();
    let m = active.len();
    if m == 0 {
        bail!("fit data exercises no model terms");
    }
    if a.len() < m {
        bail!("{} rows cannot determine {m} parameters", a.len());
    }
    // Scale each active column to O(1) so the normal equations stay
    // well-conditioned despite α ~ 1e-6 coefficients next to M/β ~ 1e6.
    let scale: Vec<f64> = active
        .iter()
        .map(|&k| a.iter().map(|r| r[k].abs()).fold(0.0f64, f64::max))
        .collect();
    let mut ata = vec![vec![0.0f64; m]; m];
    let mut aty = vec![0.0f64; m];
    for (row, &obs) in a.iter().zip(y) {
        let sr: Vec<f64> = active.iter().zip(&scale).map(|(&k, s)| row[k] / s).collect();
        for i in 0..m {
            aty[i] += sr[i] * obs;
            for j in 0..m {
                ata[i][j] += sr[i] * sr[j];
            }
        }
    }
    // Gaussian elimination with partial pivoting on [AtA | Aty].
    let mut aug: Vec<Vec<f64>> =
        (0..m).map(|i| ata[i].iter().copied().chain([aty[i]]).collect()).collect();
    for col in 0..m {
        let piv = (col..m)
            .max_by(|&r1, &r2| aug[r1][col].abs().total_cmp(&aug[r2][col].abs()))
            .unwrap();
        if aug[piv][col].abs() < 1e-300 {
            bail!("singular fit system (degenerate sample set)");
        }
        aug.swap(col, piv);
        for r in 0..m {
            if r != col {
                let f = aug[r][col] / aug[col][col];
                for cc in col..=m {
                    aug[r][cc] -= f * aug[col][cc];
                }
            }
        }
    }
    let mut theta = [None; 4];
    for (j, (&k, s)) in active.iter().zip(&scale).enumerate() {
        theta[k] = Some(aug[j][m] / aug[j][j] / s);
    }
    Ok(theta)
}

/// Outcome of an α/β fit.
pub struct FitReport {
    /// The fitted bundle (base constants with fitted link params spliced
    /// in, `version = base.version + 1`).
    pub bundle: MachineBundle,
    /// Per-row residuals (`impl, gpus, bytes, observed, predicted, rel err`).
    pub residuals: Table,
    /// Root-mean-square relative residual across all rows.
    pub rms: f64,
    /// Which of `[α_intra, β_intra, α_inter, β_inter]` the data determined.
    pub fitted: [bool; 4],
}

/// Fit link α/β from measured rows against `base`'s topology shape.
pub fn fit_alpha_beta(base: &MachineBundle, rows: &[FitRow]) -> Result<FitReport> {
    let mut a = Vec::with_capacity(rows.len());
    let mut y = Vec::with_capacity(rows.len());
    for r in rows {
        let t = base.topo.topology_for_gpus(r.gpus).with_context(|| {
            format!("row {} GPUs does not fit {}'s topology", r.gpus, base.name)
        })?;
        a.push(coeffs(&r.imp, t.nodes, t.gpus_per_node, r.bytes, base.comm.eta)?);
        y.push(r.secs);
    }
    let theta = solve_lstsq(&a, &y)?;
    for (name, v) in ["alpha_intra", "inv_beta_intra", "alpha_inter", "inv_beta_inter"]
        .iter()
        .zip(&theta)
    {
        if let Some(v) = v {
            if !(v.is_finite() && *v > 0.0) {
                bail!("fitted {name} is non-physical ({v}); check the input data");
            }
        }
    }

    let mut bundle = base.clone();
    bundle.version = base.version + 1;
    if let Some(v) = theta[0] {
        bundle.topo.intra.alpha = v;
    }
    if let Some(v) = theta[1] {
        bundle.topo.intra.beta = 1.0 / v;
    }
    if let Some(v) = theta[2] {
        bundle.topo.inter.alpha = v;
    }
    if let Some(v) = theta[3] {
        bundle.topo.inter.beta = 1.0 / v;
    }
    bundle.validate()?;

    let mut residuals = Table::new(
        "yalis fit — residuals",
        &["impl", "gpus", "bytes", "observed_s", "predicted_s", "rel_err"],
    );
    residuals.meta("bundle", &bundle.label());
    let mut sq = 0.0;
    for (r, row) in rows.iter().zip(&a) {
        let pred: f64 = row
            .iter()
            .zip(&theta)
            .map(|(c, t)| c * t.unwrap_or(0.0))
            .sum();
        let rel = (pred - r.secs) / r.secs;
        sq += rel * rel;
        residuals.row(&[
            r.imp.clone(),
            r.gpus.to_string(),
            r.bytes.to_string(),
            format!("{:.3e}", r.secs),
            format!("{pred:.3e}"),
            format!("{:+.4}", rel),
        ]);
    }
    let rms = (sq / rows.len() as f64).sqrt();
    residuals.meta("rms_rel_residual", &format!("{rms:.4e}"));
    Ok(FitReport { bundle, residuals, rms, fitted: theta.map(|t| t.is_some()) })
}

/// One measured GEMM sample: `m,n,k,dtype_bytes,seconds`.
#[derive(Clone, Debug)]
pub struct GemmRow {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub dtype: usize,
    pub secs: f64,
}

/// Parse a `m,n,k,dtype_bytes,seconds` CSV (comments/header as above).
pub fn parse_gemm_csv(text: &str) -> Result<Vec<GemmRow>> {
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cells: Vec<&str> = line.split(',').map(str::trim).collect();
        if cells.len() != 5 {
            bail!("line {}: expected 5 columns m,n,k,dtype_bytes,seconds", ln + 1);
        }
        if cells[0].parse::<usize>().is_err() && cells[0].eq_ignore_ascii_case("m") {
            continue;
        }
        let p = |i: usize| -> Result<usize> {
            cells[i].parse().with_context(|| format!("line {}: bad '{}'", ln + 1, cells[i]))
        };
        let secs: f64 = cells[4]
            .parse()
            .with_context(|| format!("line {}: bad seconds '{}'", ln + 1, cells[4]))?;
        if !(secs.is_finite() && secs > 0.0) {
            bail!("line {}: seconds must be positive", ln + 1);
        }
        out.push(GemmRow { m: p(0)?, n: p(1)?, k: p(2)?, dtype: p(3)?, secs });
    }
    if out.is_empty() {
        bail!("no data rows in GEMM CSV");
    }
    Ok(out)
}

/// Fit the roofline `mxu_efficiency` from measured GEMM times. Only
/// clearly compute-bound samples vote (memory time and kernel floor both
/// < 70% of the observation); returns `None` if no sample qualifies.
pub fn fit_mxu_efficiency(gpu: &GpuSpec, rows: &[GemmRow]) -> Option<f64> {
    let mut num = 0.0;
    let mut den = 0.0;
    for r in rows {
        let mq = r.m.div_ceil(gpu.tile_m) * gpu.tile_m;
        let nq = r.n.div_ceil(gpu.tile_n) * gpu.tile_n;
        // Time at 100% efficiency; observed ≈ c / eff for compute-bound rows.
        let c = 2.0 * mq as f64 * nq as f64 * r.k as f64 / gpu.flops;
        let mem = ((r.m * r.k + r.k * r.n + r.m * r.n) * r.dtype) as f64 / gpu.mem_bw;
        if mem < 0.7 * r.secs && gpu.kernel_floor < 0.7 * r.secs {
            num += c * r.secs;
            den += c * c;
        }
    }
    if den == 0.0 {
        return None;
    }
    let eff = den / num; // slope = 1/eff minimizing Σ(c/eff − t)²
    (eff > 0.0).then(|| eff.min(1.0))
}

/// The `yalis fit` driver: parse CSVs, fit, print residuals, save the new
/// bundle to `out`.
pub fn run_fit(base: &MachineBundle, fit_csv: &str, gemm_csv: &str, out: &str) -> Result<()> {
    let text =
        std::fs::read_to_string(fit_csv).with_context(|| format!("reading {fit_csv}"))?;
    let rows = parse_csv(&text).with_context(|| format!("parsing {fit_csv}"))?;
    let mut report = fit_alpha_beta(base, &rows)?;
    if !gemm_csv.is_empty() {
        let gtext = std::fs::read_to_string(gemm_csv)
            .with_context(|| format!("reading {gemm_csv}"))?;
        let grows = parse_gemm_csv(&gtext).with_context(|| format!("parsing {gemm_csv}"))?;
        match fit_mxu_efficiency(&report.bundle.gpu, &grows) {
            Some(eff) => {
                println!(
                    "fitted mxu_efficiency {:.4} from {} GEMM samples (was {:.4})",
                    eff,
                    grows.len(),
                    report.bundle.gpu.mxu_efficiency
                );
                report.bundle.gpu.mxu_efficiency = eff;
            }
            None => println!(
                "GEMM CSV has no clearly compute-bound samples; keeping mxu_efficiency {:.4}",
                report.bundle.gpu.mxu_efficiency
            ),
        }
    }
    report.residuals.print();
    let names = ["alpha_intra", "beta_intra", "alpha_inter", "beta_inter"];
    let fitted: Vec<&str> =
        names.iter().zip(report.fitted).filter(|(_, f)| *f).map(|(n, _)| *n).collect();
    let kept: Vec<&str> =
        names.iter().zip(report.fitted).filter(|(_, f)| !*f).map(|(n, _)| *n).collect();
    println!(
        "fitted {{{}}} over {} rows, rms relative residual {:.3e}",
        fitted.join(", "),
        rows.len(),
        report.rms
    );
    if !kept.is_empty() {
        println!("no signal for {{{}}}; kept base values", kept.join(", "));
    }
    let t = &report.bundle.topo;
    println!(
        "intra α {:.3e}s β {:.3e}B/s | inter α {:.3e}s β {:.3e}B/s",
        t.intra.alpha, t.intra.beta, t.inter.alpha, t.inter.beta
    );
    report.bundle.save(out)?;
    println!("wrote {} ({})", out, report.bundle.label());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::registry;

    fn synth_rows(b: &MachineBundle, noise: bool) -> Vec<FitRow> {
        // Deterministic multiplicative "noise" from a Weyl sequence — tests
        // must not depend on RNG state.
        let mut rows = Vec::new();
        let mut i: u64 = 0;
        for imp in ["nvrar", "tree", "mpi", "ring"] {
            for gpus in [8usize, 16, 32, 64] {
                for bytes in [131072u64, 524288, 2097152] {
                    let t = b.topo.topology_for_gpus(gpus).unwrap();
                    let c =
                        coeffs(imp, t.nodes, t.gpus_per_node, bytes, b.comm.eta).unwrap();
                    let th = [
                        b.topo.intra.alpha,
                        1.0 / b.topo.intra.beta,
                        b.topo.inter.alpha,
                        1.0 / b.topo.inter.beta,
                    ];
                    let mut secs: f64 = c.iter().zip(th).map(|(c, t)| c * t).sum();
                    if noise {
                        let u = ((i.wrapping_mul(2654435761) % 1000) as f64) / 1000.0;
                        secs *= 1.0 + 0.02 * (u - 0.5);
                    }
                    i += 1;
                    rows.push(FitRow { bytes, gpus, imp: imp.to_string(), secs });
                }
            }
        }
        rows
    }

    #[test]
    fn exact_data_recovers_alpha_beta_exactly() {
        let b = registry::resolve("perlmutter").unwrap();
        let rows = synth_rows(&b, false);
        let rep = fit_alpha_beta(&b, &rows).unwrap();
        assert!(rep.rms < 1e-9, "rms {}", rep.rms);
        assert_eq!(rep.fitted, [true; 4]);
        let t = &rep.bundle.topo;
        for (got, want) in [
            (t.intra.alpha, b.topo.intra.alpha),
            (t.intra.beta, b.topo.intra.beta),
            (t.inter.alpha, b.topo.inter.alpha),
            (t.inter.beta, b.topo.inter.beta),
        ] {
            assert!((got - want).abs() / want < 1e-9, "{got} vs {want}");
        }
        assert_eq!(rep.bundle.version, b.version + 1);
    }

    #[test]
    fn noisy_data_recovers_different_truth_within_tolerance() {
        // Ground truth deliberately far from the perlmutter base, with 2%
        // multiplicative noise: recovery must land within 3%.
        let mut truth = registry::resolve("perlmutter").unwrap();
        truth.topo.intra.alpha = 3.0e-6;
        truth.topo.intra.beta = 150.0e9;
        truth.topo.inter.alpha = 12.0e-6;
        truth.topo.inter.beta = 30.0e9;
        let rows = synth_rows(&truth, true);
        let base = registry::resolve("perlmutter").unwrap();
        let rep = fit_alpha_beta(&base, &rows).unwrap();
        let t = &rep.bundle.topo;
        for (got, want) in [
            (t.intra.alpha, 3.0e-6),
            (t.intra.beta, 150.0e9),
            (t.inter.alpha, 12.0e-6),
            (t.inter.beta, 30.0e9),
        ] {
            let rel = (got - want).abs() / want;
            assert!(rel < 0.03, "{got} vs {want} (rel {rel})");
        }
    }

    #[test]
    fn single_gpu_per_node_data_leaves_intra_untouched() {
        // Vista-shaped data (g = 1 everywhere) has no intra-link signal:
        // the intra columns must stay at base values, inter must fit.
        let b = registry::resolve("vista").unwrap();
        let mut rows = Vec::new();
        for imp in ["nvrar", "mpi", "ring"] {
            for gpus in [8usize, 16] {
                for bytes in [131072u64, 1048576] {
                    let c = coeffs(imp, gpus, 1, bytes, b.comm.eta).unwrap();
                    let secs = c[2] * 8.0e-6 + c[3] / 48.0e9;
                    rows.push(FitRow { bytes, gpus, imp: imp.to_string(), secs });
                }
            }
        }
        let rep = fit_alpha_beta(&b, &rows).unwrap();
        assert_eq!(rep.fitted, [false, false, true, true]);
        assert_eq!(rep.bundle.topo.intra.alpha, b.topo.intra.alpha);
        assert_eq!(rep.bundle.topo.intra.beta, b.topo.intra.beta);
        assert!((rep.bundle.topo.inter.alpha - 8.0e-6).abs() / 8.0e-6 < 1e-9);
        assert!((rep.bundle.topo.inter.beta - 48.0e9).abs() / 48.0e9 < 1e-9);
    }

    #[test]
    fn csv_parsing_and_rejection() {
        let rows = parse_csv(
            "# comment\nbytes,gpus,impl,seconds\n131072, 8, nvrar, 1.5e-4\n\n262144,16,ring,2e-4\n",
        )
        .unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].imp, "nvrar");
        assert_eq!(rows[1].gpus, 16);
        assert!(parse_csv("bytes,gpus,impl,seconds\n").is_err());
        assert!(parse_csv("1,2,ring\n").is_err());
        assert!(parse_csv("1024,8,ring,-1.0\n").is_err());
        let b = registry::resolve("perlmutter").unwrap();
        let bad = vec![FitRow { bytes: 1024, gpus: 8, imp: "warp".into(), secs: 1e-4 }];
        let err = fit_alpha_beta(&b, &bad).unwrap_err().to_string();
        assert!(err.contains("unknown impl 'warp'"), "{err}");
    }

    #[test]
    fn ragged_gpu_count_is_a_row_error() {
        let b = registry::resolve("perlmutter").unwrap();
        let bad = vec![FitRow { bytes: 1024, gpus: 6, imp: "ring".into(), secs: 1e-4 }];
        assert!(fit_alpha_beta(&b, &bad).is_err());
    }

    #[test]
    fn mxu_efficiency_recovered_from_compute_bound_gemms() {
        let gpu = GpuSpec::a100();
        let truth = 0.62;
        let mut rows = Vec::new();
        for (m, n, k) in [(4096usize, 4096usize, 4096usize), (8192, 4096, 8192)] {
            let mq = m.div_ceil(gpu.tile_m) * gpu.tile_m;
            let nq = n.div_ceil(gpu.tile_n) * gpu.tile_n;
            let secs = 2.0 * mq as f64 * nq as f64 * k as f64 / (gpu.flops * truth);
            rows.push(GemmRow { m, n, k, dtype: 2, secs });
        }
        // A decode-shaped memory-bound row (KN weight stream dominates)
        // must be filtered out — its tile-quantized compute time is far
        // from the truth and would skew the slope if it voted.
        let membound = GemmRow { m: 1, n: 8192, k: 8192, dtype: 2, secs: 7.0e-5 };
        rows.push(membound.clone());
        let eff = fit_mxu_efficiency(&gpu, &rows).unwrap();
        assert!((eff - truth).abs() < 1e-6, "{eff}");
        // All-memory-bound input: no votes.
        assert!(fit_mxu_efficiency(&gpu, &[membound]).is_none());
    }
}
