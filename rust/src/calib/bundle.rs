//! The serializable machine bundle: one versioned artifact holding *all*
//! of a machine's calibration constants.
//!
//! A bundle couples the communication-stack tunables ([`CommConfig`]), the
//! GPU roofline ([`GpuSpec`]) and the link topology shape ([`TopoSpec`])
//! under one name+version, so a deployment can never pair one machine's
//! α/β with another's roofline. Bundles serialize to a small flat-ish JSON
//! document read back by the no-serde [`crate::obs::json`] parser — the
//! same self-contained style as the benchsuite metric files.

use crate::cluster::{LinkParams, Topology};
use crate::collectives::sim::CommConfig;
use crate::obs::json::{self, Value};
use crate::perfmodel::GpuSpec;
use anyhow::{bail, Context, Result};

/// Bundle file schema version (the `"schema"` field).
pub const SCHEMA: u32 = 1;

/// The topology *shape* of a machine — everything in [`Topology`] except
/// the node count, which is chosen per experiment.
#[derive(Clone, Copy, Debug)]
pub struct TopoSpec {
    pub gpus_per_node: usize,
    pub intra: LinkParams,
    pub inter: LinkParams,
    /// Host-side kernel launch overhead (see [`Topology::kernel_launch`]).
    pub kernel_launch: f64,
}

impl TopoSpec {
    /// The shape of an existing topology (drops the node count).
    pub fn of(t: &Topology) -> Self {
        TopoSpec {
            gpus_per_node: t.gpus_per_node,
            intra: t.intra,
            inter: t.inter,
            kernel_launch: t.kernel_launch,
        }
    }

    /// Instantiate at `nodes` nodes.
    pub fn topology(&self, nodes: usize) -> Topology {
        Topology {
            nodes,
            gpus_per_node: self.gpus_per_node,
            intra: self.intra,
            inter: self.inter,
            kernel_launch: self.kernel_launch,
        }
    }

    /// Instantiate for a total GPU count, filling nodes first (the
    /// fallible twin of [`Topology::with_gpus`] for data-driven callers
    /// like `yalis fit`, where a ragged count is a row error, not a bug).
    pub fn topology_for_gpus(&self, gpus: usize) -> Result<Topology> {
        if gpus == 0 {
            bail!("gpu count must be >= 1");
        }
        if gpus > self.gpus_per_node && gpus % self.gpus_per_node != 0 {
            bail!("{gpus} GPUs is not a multiple of {}/node", self.gpus_per_node);
        }
        Ok(self.topology(1).with_gpus(gpus))
    }
}

/// A named, versioned calibration bundle — the single source of truth for
/// a machine's constants.
#[derive(Clone, Debug)]
pub struct MachineBundle {
    /// Machine name (`perlmutter`, `vista`, ... or a site-local name).
    pub name: String,
    /// Calibration version; `yalis fit` bumps this when emitting.
    pub version: u32,
    pub comm: CommConfig,
    pub gpu: GpuSpec,
    pub topo: TopoSpec,
}

impl MachineBundle {
    /// `name@version` — stamped into run metadata so every table, CSV and
    /// trace records which calibration produced it.
    pub fn label(&self) -> String {
        format!("{}@{}", self.name, self.version)
    }

    /// Serialize to the bundle JSON document.
    pub fn to_json(&self) -> String {
        let c = &self.comm;
        let g = &self.gpu;
        let t = &self.topo;
        format!(
            "{{\n  \"schema\": {SCHEMA},\n  \"name\": \"{}\",\n  \"version\": {},\n  \
             \"comm\": {{\n    \"eta\": {},\n    \"block_count\": {},\n    \
             \"chunk_bytes\": {},\n    \"reduce_bw\": {},\n    \"launch_overhead\": {},\n    \
             \"proxy_overhead\": {},\n    \"nvshmem_overhead\": {},\n    \
             \"put_overhead\": {},\n    \"sync_cost\": {},\n    \"ll_bw_penalty\": {},\n    \
             \"ll_alpha_factor\": {},\n    \"mpi_host_overhead\": {}\n  }},\n  \
             \"gpu\": {{\n    \"name\": \"{}\",\n    \"flops\": {},\n    \"mem_bw\": {},\n    \
             \"mem_bytes\": {},\n    \"tile_m\": {},\n    \"tile_n\": {},\n    \
             \"kernel_floor\": {},\n    \"mxu_efficiency\": {}\n  }},\n  \
             \"topo\": {{\n    \"gpus_per_node\": {},\n    \"intra_alpha\": {},\n    \
             \"intra_beta\": {},\n    \"inter_alpha\": {},\n    \"inter_beta\": {},\n    \
             \"kernel_launch\": {}\n  }}\n}}\n",
            self.name,
            self.version,
            c.eta,
            c.block_count,
            c.chunk_bytes,
            c.reduce_bw,
            c.launch_overhead,
            c.proxy_overhead,
            c.nvshmem_overhead,
            c.put_overhead,
            c.sync_cost,
            c.ll_bw_penalty,
            c.ll_alpha_factor,
            c.mpi_host_overhead,
            g.name,
            g.flops,
            g.mem_bw,
            g.mem_bytes,
            g.tile_m,
            g.tile_n,
            g.kernel_floor,
            g.mxu_efficiency,
            t.gpus_per_node,
            t.intra.alpha,
            t.intra.beta,
            t.inter.alpha,
            t.inter.beta,
            t.kernel_launch,
        )
    }

    /// Parse a bundle document (the inverse of [`Self::to_json`]).
    pub fn from_json(text: &str) -> Result<Self> {
        let doc = json::parse(text).map_err(|e| anyhow::anyhow!("bundle JSON: {e}"))?;
        let schema = num(&doc, "schema")? as u32;
        if schema != SCHEMA {
            bail!("unsupported bundle schema {schema} (this build reads schema {SCHEMA})");
        }
        let name = string(&doc, "name")?;
        let version = uint(&doc, "version")? as u32;
        let c = section(&doc, "comm")?;
        let comm = CommConfig {
            eta: num(c, "eta")?,
            block_count: uint(c, "block_count")? as usize,
            chunk_bytes: uint(c, "chunk_bytes")?,
            reduce_bw: num(c, "reduce_bw")?,
            launch_overhead: num(c, "launch_overhead")?,
            proxy_overhead: num(c, "proxy_overhead")?,
            nvshmem_overhead: num(c, "nvshmem_overhead")?,
            put_overhead: num(c, "put_overhead")?,
            sync_cost: num(c, "sync_cost")?,
            ll_bw_penalty: num(c, "ll_bw_penalty")?,
            ll_alpha_factor: num(c, "ll_alpha_factor")?,
            mpi_host_overhead: num(c, "mpi_host_overhead")?,
        };
        let g = section(&doc, "gpu")?;
        let gpu = GpuSpec {
            // GpuSpec is Copy with a &'static name; a loaded bundle's name
            // is leaked once per load — bounded, since bundles are read a
            // handful of times per process, not in any loop.
            name: Box::leak(string(g, "name")?.into_boxed_str()),
            flops: num(g, "flops")?,
            mem_bw: num(g, "mem_bw")?,
            mem_bytes: uint(g, "mem_bytes")?,
            tile_m: uint(g, "tile_m")? as usize,
            tile_n: uint(g, "tile_n")? as usize,
            kernel_floor: num(g, "kernel_floor")?,
            mxu_efficiency: num(g, "mxu_efficiency")?,
        };
        let t = section(&doc, "topo")?;
        let topo = TopoSpec {
            gpus_per_node: uint(t, "gpus_per_node")? as usize,
            intra: LinkParams { alpha: num(t, "intra_alpha")?, beta: num(t, "intra_beta")? },
            inter: LinkParams { alpha: num(t, "inter_alpha")?, beta: num(t, "inter_beta")? },
            kernel_launch: num(t, "kernel_launch")?,
        };
        let bundle = MachineBundle { name, version, comm, gpu, topo };
        bundle.validate()?;
        Ok(bundle)
    }

    /// Load from a bundle file.
    pub fn load(path: &str) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading bundle {path}"))?;
        Self::from_json(&text).with_context(|| format!("parsing bundle {path}"))
    }

    /// Write to a bundle file (creating parent directories).
    pub fn save(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        }
        std::fs::write(path, self.to_json()).with_context(|| format!("writing bundle {path}"))
    }

    /// Physical-sanity checks applied to every loaded bundle, so a typo'd
    /// constant fails at load time with a named field, not as NaNs deep in
    /// a simulation.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("bundle name must be non-empty");
        }
        for (field, v) in [
            ("comm.eta", self.comm.eta),
            ("comm.ll_bw_penalty", self.comm.ll_bw_penalty),
            ("comm.ll_alpha_factor", self.comm.ll_alpha_factor),
        ] {
            if !(v.is_finite() && v > 0.0) {
                bail!("{field} must be positive and finite (got {v})");
            }
        }
        if self.comm.eta < 1.0 {
            bail!("comm.eta must be >= 1 (LL payloads never shrink the message; got {})", self.comm.eta);
        }
        for (field, v) in [
            ("comm.reduce_bw", self.comm.reduce_bw),
            ("gpu.flops", self.gpu.flops),
            ("gpu.mem_bw", self.gpu.mem_bw),
            ("topo.intra_beta", self.topo.intra.beta),
            ("topo.inter_beta", self.topo.inter.beta),
        ] {
            if !(v.is_finite() && v > 0.0) {
                bail!("{field} must be a positive bandwidth (got {v})");
            }
        }
        for (field, v) in [
            ("comm.launch_overhead", self.comm.launch_overhead),
            ("comm.proxy_overhead", self.comm.proxy_overhead),
            ("comm.nvshmem_overhead", self.comm.nvshmem_overhead),
            ("comm.put_overhead", self.comm.put_overhead),
            ("comm.sync_cost", self.comm.sync_cost),
            ("comm.mpi_host_overhead", self.comm.mpi_host_overhead),
            ("gpu.kernel_floor", self.gpu.kernel_floor),
            ("topo.intra_alpha", self.topo.intra.alpha),
            ("topo.inter_alpha", self.topo.inter.alpha),
            ("topo.kernel_launch", self.topo.kernel_launch),
        ] {
            if !(v.is_finite() && v >= 0.0) {
                bail!("{field} must be a non-negative time (got {v})");
            }
        }
        if self.comm.block_count == 0 || self.comm.chunk_bytes == 0 {
            bail!("comm.block_count and comm.chunk_bytes must be >= 1");
        }
        if self.topo.gpus_per_node == 0 {
            bail!("topo.gpus_per_node must be >= 1");
        }
        if self.gpu.tile_m == 0 || self.gpu.tile_n == 0 {
            bail!("gpu.tile_m and gpu.tile_n must be >= 1");
        }
        if !(self.gpu.mxu_efficiency > 0.0 && self.gpu.mxu_efficiency <= 1.0) {
            bail!(
                "gpu.mxu_efficiency must be in (0, 1] (got {})",
                self.gpu.mxu_efficiency
            );
        }
        Ok(())
    }
}

fn section<'a>(doc: &'a Value, key: &str) -> Result<&'a Value> {
    match doc.get(key) {
        Some(v @ Value::Obj(_)) => Ok(v),
        Some(_) => bail!("bundle field '{key}' must be an object"),
        None => bail!("bundle is missing the '{key}' section"),
    }
}

fn num(obj: &Value, key: &str) -> Result<f64> {
    obj.get(key)
        .and_then(Value::as_f64)
        .with_context(|| format!("bundle is missing numeric field '{key}'"))
}

fn string(obj: &Value, key: &str) -> Result<String> {
    obj.get(key)
        .and_then(Value::as_str)
        .map(str::to_string)
        .with_context(|| format!("bundle is missing string field '{key}'"))
}

fn uint(obj: &Value, key: &str) -> Result<u64> {
    let v = num(obj, key)?;
    if v < 0.0 || v.fract() != 0.0 || v > (1u64 << 53) as f64 {
        bail!("bundle field '{key}' must be a non-negative integer (got {v})");
    }
    Ok(v as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::registry;

    fn fields(b: &MachineBundle) -> Vec<f64> {
        vec![
            b.comm.eta,
            b.comm.block_count as f64,
            b.comm.chunk_bytes as f64,
            b.comm.reduce_bw,
            b.comm.launch_overhead,
            b.comm.proxy_overhead,
            b.comm.nvshmem_overhead,
            b.comm.put_overhead,
            b.comm.sync_cost,
            b.comm.ll_bw_penalty,
            b.comm.ll_alpha_factor,
            b.comm.mpi_host_overhead,
            b.gpu.flops,
            b.gpu.mem_bw,
            b.gpu.mem_bytes as f64,
            b.gpu.tile_m as f64,
            b.gpu.tile_n as f64,
            b.gpu.kernel_floor,
            b.gpu.mxu_efficiency,
            b.topo.gpus_per_node as f64,
            b.topo.intra.alpha,
            b.topo.intra.beta,
            b.topo.inter.alpha,
            b.topo.inter.beta,
            b.topo.kernel_launch,
        ]
    }

    #[test]
    fn json_round_trip_is_exact() {
        // f64 Display emits the shortest round-tripping decimal, so every
        // constant must survive write -> parse bit-for-bit.
        for name in registry::names() {
            let b = registry::resolve(name).unwrap();
            let back = MachineBundle::from_json(&b.to_json()).unwrap();
            assert_eq!(b.name, back.name);
            assert_eq!(b.version, back.version);
            assert_eq!(b.gpu.name, back.gpu.name);
            assert_eq!(fields(&b), fields(&back), "{name}");
        }
    }

    #[test]
    fn missing_field_is_a_named_error() {
        let b = registry::resolve("perlmutter").unwrap();
        let broken = b.to_json().replace("\"eta\"", "\"eta_typo\"");
        let err = MachineBundle::from_json(&broken).unwrap_err().to_string();
        assert!(err.contains("eta"), "{err}");
        let err = MachineBundle::from_json("{ not json").unwrap_err().to_string();
        assert!(err.contains("JSON"), "{err}");
    }

    #[test]
    fn insane_constants_rejected_by_field_name() {
        let mut b = registry::resolve("perlmutter").unwrap();
        b.topo.inter.beta = 0.0;
        let err = MachineBundle::from_json(&b.to_json()).unwrap_err().to_string();
        assert!(err.contains("inter_beta") || err.contains("inter.beta"), "{err}");
        let mut b = registry::resolve("perlmutter").unwrap();
        b.comm.eta = 0.5;
        assert!(b.validate().unwrap_err().to_string().contains("eta"));
        let mut b = registry::resolve("perlmutter").unwrap();
        b.gpu.mxu_efficiency = 1.5;
        assert!(b.validate().unwrap_err().to_string().contains("mxu_efficiency"));
    }

    #[test]
    fn wrong_schema_rejected() {
        let b = registry::resolve("vista").unwrap();
        let future = b.to_json().replacen("\"schema\": 1", "\"schema\": 99", 1);
        let err = MachineBundle::from_json(&future).unwrap_err().to_string();
        assert!(err.contains("schema 99"), "{err}");
    }

    #[test]
    fn topology_for_gpus_fills_nodes_first() {
        let b = registry::resolve("perlmutter").unwrap();
        let t = b.topo.topology_for_gpus(2).unwrap();
        assert_eq!((t.nodes, t.gpus_per_node), (1, 2));
        let t = b.topo.topology_for_gpus(32).unwrap();
        assert_eq!((t.nodes, t.gpus_per_node), (8, 4));
        assert!(b.topo.topology_for_gpus(6).is_err());
        assert!(b.topo.topology_for_gpus(0).is_err());
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("yalis_calib_bundle_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("perlmutter_copy.json");
        let b = registry::resolve("perlmutter").unwrap();
        b.save(path.to_str().unwrap()).unwrap();
        let back = MachineBundle::load(path.to_str().unwrap()).unwrap();
        assert_eq!(fields(&b), fields(&back));
        assert_eq!(back.label(), "perlmutter@1");
        assert!(MachineBundle::load(dir.join("nope.json").to_str().unwrap()).is_err());
    }
}
