//! Calibration subsystem: machine bundles, paper-claim validation, α/β
//! fitting.
//!
//! The paper's reproduction rests on calibration constants — link α/β,
//! comm-stack overheads, the GPU roofline. This module makes them a
//! first-class, *versioned* artifact and closes the loop around them:
//!
//! 1. **Bundles** ([`bundle`]): a [`MachineBundle`] couples one machine's
//!    [`crate::collectives::sim::CommConfig`],
//!    [`crate::perfmodel::GpuSpec`] and topology shape under a
//!    `name@version` label, serialized as self-contained JSON. The
//!    [`registry`] ships `perlmutter`, `vista` and `generic_ib` as
//!    built-ins and also loads bundle files, so `--machine` takes either a
//!    name or a path.
//! 2. **Validation** ([`claims`]): `yalis validate` re-derives the paper's
//!    quantitative claims (Fig 6 speedup bands per fabric, the Fig 7 405B
//!    e2e speedup, Eq 1–6 parity) from the current stack and fails on
//!    drift.
//! 3. **Fitting** ([`fit`]): `yalis fit` least-squares-fits α/β (and
//!    optionally roofline efficiency) from measured CSVs, emitting a
//!    version-bumped bundle that feeds straight back into validation.
//!
//! measure → `fit` → bundle → `validate` — the loop Kundu et al. argue an
//! analytical model needs to stay trustworthy.

pub mod bundle;
pub mod claims;
pub mod fit;
pub mod registry;

pub use bundle::{MachineBundle, TopoSpec};

/// The machine assumed when `--machine` is not given (the paper's primary
/// testbed). The *only* place this default is spelled.
pub const DEFAULT_MACHINE: &str = "perlmutter";

/// `name@version` label of the default machine's bundle, for run metadata.
pub fn default_label() -> String {
    registry::resolve(DEFAULT_MACHINE)
        .expect("default machine is a built-in bundle")
        .label()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_machine_is_a_builtin() {
        assert!(registry::names().contains(&DEFAULT_MACHINE));
        assert_eq!(default_label(), "perlmutter@1");
    }
}
