//! Chrome trace-event JSON emission (the `{base}.trace.json` artifact).
//!
//! The format is the Trace Event Format both `chrome://tracing` and
//! Perfetto load: a `traceEvents` array of complete spans (`ph: "X"`,
//! microsecond `ts`/`dur`) and instants (`ph: "i"`), plus `ph: "M"`
//! metadata events naming the processes and threads. Tracks map as:
//!
//! - pid 1 "replicas" — one tid per serving replica,
//! - pid 2 "fabric links" — one tid per (scope, link-class),
//! - pid 3 "control" — router/autoscaler/drain decisions.
//!
//! Events are sorted by (pid, tid, ts) so per-track timestamps are
//! monotone — pinned by `tests/integration_obs.rs` and the CI
//! trace-smoke job. Hand-emitted (the vendored crate set has no serde);
//! the inverse parser for validation lives in [`crate::obs::json`].

use super::{ArgV, Recorder, Track};
use crate::simnet::LinkKind;

/// (pid, tid) a track renders under.
fn track_ids(t: Track) -> (u64, u64) {
    match t {
        Track::Replica(r) => (1, r as u64),
        Track::Link { scope, kind } => {
            (2, 2 * scope as u64 + if kind == LinkKind::Intra { 0 } else { 1 })
        }
        Track::Control => (3, 0),
    }
}

fn track_name(t: Track) -> String {
    match t {
        Track::Replica(r) => format!("replica {r}"),
        Track::Link { scope, kind } => format!(
            "scope {scope} {}",
            if kind == LinkKind::Intra { "intra (NVLink)" } else { "inter (NIC)" }
        ),
        Track::Control => "decisions".to_string(),
    }
}

/// Escape a string for a JSON string literal.
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn args_json(args: &[(&'static str, ArgV)]) -> String {
    let mut s = String::from("{");
    for (i, (k, v)) in args.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("\"{}\":", esc(k)));
        match v {
            ArgV::F(x) => s.push_str(&format!("{x:.9}")),
            ArgV::U(u) => s.push_str(&format!("{u}")),
            ArgV::S(t) => s.push_str(&format!("\"{}\"", esc(t))),
        }
    }
    s.push('}');
    s
}

/// Render the whole recorder as a Chrome trace JSON document.
pub fn to_chrome_json(rec: &Recorder) -> String {
    // One row per event, keyed for the (pid, tid, ts) sort. Instants sort
    // after spans starting at the same instant (stable marker placement).
    struct Row {
        pid: u64,
        tid: u64,
        ts: f64,
        order: u8,
        body: String,
    }
    let mut rows: Vec<Row> = Vec::with_capacity(rec.spans().len() + rec.instants().len());
    let mut tracks: Vec<Track> = Vec::new();
    let mut see = |t: Track, tracks: &mut Vec<Track>| {
        if !tracks.contains(&t) {
            tracks.push(t);
        }
    };
    for sp in rec.spans() {
        see(sp.track, &mut tracks);
        let (pid, tid) = track_ids(sp.track);
        rows.push(Row {
            pid,
            tid,
            ts: sp.start,
            order: 0,
            body: format!(
                "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":{pid},\
                 \"tid\":{tid},\"args\":{}}}",
                esc(&sp.name),
                sp.start * 1e6,
                sp.dur * 1e6,
                args_json(&sp.args)
            ),
        });
    }
    for iv in rec.instants() {
        see(iv.track, &mut tracks);
        let (pid, tid) = track_ids(iv.track);
        rows.push(Row {
            pid,
            tid,
            ts: iv.at,
            order: 1,
            body: format!(
                "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{:.3},\"pid\":{pid},\
                 \"tid\":{tid},\"args\":{}}}",
                esc(&iv.name),
                iv.at * 1e6,
                args_json(&iv.args)
            ),
        });
    }
    rows.sort_by(|a, b| {
        (a.pid, a.tid)
            .cmp(&(b.pid, b.tid))
            .then(a.ts.total_cmp(&b.ts))
            .then(a.order.cmp(&b.order))
    });

    let mut out = String::from("{\n\"displayTimeUnit\":\"ms\",\n\"metadata\":{");
    for (i, (k, v)) in rec.meta.pairs().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{}\":\"{}\"", esc(k), esc(v)));
    }
    out.push_str(&format!(",\"makespan_s\":\"{:.6}\"", rec.makespan()));
    out.push_str("},\n\"traceEvents\":[\n");
    // Process/thread naming metadata first.
    let mut bodies: Vec<String> = Vec::new();
    for (pid, name) in [(1u64, "replicas"), (2, "fabric links"), (3, "control")] {
        bodies.push(format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
             \"args\":{{\"name\":\"{name}\"}}}}"
        ));
    }
    tracks.sort();
    for t in tracks {
        let (pid, tid) = track_ids(t);
        bodies.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
             \"args\":{{\"name\":\"{}\"}}}}",
            esc(&track_name(t))
        ));
    }
    bodies.extend(rows.into_iter().map(|r| r.body));
    out.push_str(&bodies.join(",\n"));
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{json, RunMeta};

    fn sample() -> Recorder {
        let mut r = Recorder::new(RunMeta { label: "tp16/NVRAR".into(), ..Default::default() });
        r.span(Track::Replica(0), "step", 0.0, 0.5, vec![("rows", ArgV::U(8))]);
        r.span(Track::Replica(0), "step", 0.5, 0.25, vec![("matmul", ArgV::F(0.125))]);
        r.span(
            Track::Link { scope: 0, kind: LinkKind::Inter },
            "nvrar.rd-inter",
            0.1,
            0.05,
            vec![("bytes", ArgV::F(1e6))],
        );
        r.instant(Track::Control, "route", 0.0, vec![("req", ArgV::U(1))]);
        r.set_makespan(0.75);
        r
    }

    #[test]
    fn emitted_trace_parses_as_json_with_expected_structure() {
        let text = to_chrome_json(&sample());
        let v = json::parse(&text).expect("trace must be valid JSON");
        let evs = v.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // 3 process_name + 3 thread_name + 3 spans + 1 instant.
        assert_eq!(evs.len(), 10);
        let meta = v.get("metadata").unwrap();
        assert_eq!(meta.get("deployment").and_then(|d| d.as_str()), Some("tp16/NVRAR"));
        // Every non-metadata event carries numeric ts and pid/tid.
        for e in evs {
            let ph = e.get("ph").and_then(|p| p.as_str()).unwrap();
            if ph != "M" {
                assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
            }
        }
    }

    #[test]
    fn per_track_timestamps_are_monotone() {
        let text = to_chrome_json(&sample());
        let v = json::parse(&text).unwrap();
        let mut last: std::collections::BTreeMap<(u64, u64), f64> = Default::default();
        for e in v.get("traceEvents").and_then(|e| e.as_arr()).unwrap() {
            if e.get("ph").and_then(|p| p.as_str()) == Some("M") {
                continue;
            }
            let key = (
                e.get("pid").and_then(|p| p.as_f64()).unwrap() as u64,
                e.get("tid").and_then(|p| p.as_f64()).unwrap() as u64,
            );
            let ts = e.get("ts").and_then(|t| t.as_f64()).unwrap();
            let prev = last.insert(key, ts).unwrap_or(f64::NEG_INFINITY);
            assert!(ts >= prev, "track {key:?} went backwards: {prev} -> {ts}");
        }
    }

    #[test]
    fn escaping_handles_quotes_and_control_chars() {
        assert_eq!(esc("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(esc("\u{1}"), "\\u0001");
    }
}
