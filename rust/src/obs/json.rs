//! Minimal recursive-descent JSON parser (the vendored crate set has no
//! serde). The forward direction is hand-emitted in [`crate::obs::chrome`]
//! and `coordinator::benchsuite`; this is the inverse, used by the
//! integration tests and the CI trace-smoke job to validate that emitted
//! traces actually parse. General-purpose enough for any well-formed
//! document (nesting, escapes), deliberately small.

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object member lookup (None on non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct P<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> P<'a> {
    fn ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let tok = std::str::from_utf8(&self.s[start..self.i]).unwrap();
        tok.parse::<f64>().map(Value::Num).map_err(|_| format!("bad number '{tok}'"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .s
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.s[self.i..])
                        .map_err(|_| "invalid UTF-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                other => return Err(format!("expected ',' or ']', got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.push((key, val));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }
}

/// Parse one JSON document; trailing non-whitespace is an error.
pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = P { s: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.s.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_documents() {
        let v = parse(r#"{"a":[1,2.5,-3e-2],"b":{"c":"x","d":true},"e":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1], Value::Num(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().get("d"), Some(&Value::Bool(true)));
        assert_eq!(v.get("e"), Some(&Value::Null));
    }

    #[test]
    fn string_escapes_round_trip() {
        let v = parse(r#"{"k":"a\"b\\c\ndA"}"#).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("{} garbage").is_err());
        assert!(parse("[1 2]").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(vec![]));
    }
}
