//! The Pipit-style fold: re-derive per-replica Matmul / Other-Comp /
//! Comm / Idle [`Breakdown`]s from the event stream alone, and reconcile
//! them against the analytically accumulated ones.
//!
//! Each `step` span a replica track carries was stamped with its own
//! four-bucket decomposition (plus any fabric queueing delay, folded
//! into Comm at record time). The fold sums the busy buckets across a
//! track's spans and attributes everything else up to the run's makespan
//! as Idle — exactly what Pipit does to an Nsight trace (paper Figs 3,
//! 8). Since the serving loops accumulate the *same* per-step breakdowns
//! analytically, the two paths must agree: any drift means the recorder
//! dropped or double-counted an event, or the cost model's decomposition
//! stopped summing to its own step time. `tests/integration_obs.rs`
//! pins the agreement to 1e-6 on serve and fleet runs.

use super::{arg_f64, Recorder, Track};
use crate::metrics::Breakdown;
use std::collections::BTreeMap;

/// Per-replica breakdowns derived purely from the event stream. A
/// replica's Idle is its span-stamped idle (pipeline bubbles) plus the
/// gap between its total busy time and the run's makespan.
pub fn fold_breakdowns(rec: &Recorder) -> BTreeMap<usize, Breakdown> {
    let mut out: BTreeMap<usize, Breakdown> = BTreeMap::new();
    let mut span_total: BTreeMap<usize, f64> = BTreeMap::new();
    for sp in rec.spans() {
        let Track::Replica(r) = sp.track else { continue };
        if sp.name != "step" {
            continue;
        }
        let b = out.entry(r).or_default();
        b.matmul += arg_f64(&sp.args, "matmul");
        b.other_comp += arg_f64(&sp.args, "other");
        b.comm += arg_f64(&sp.args, "comm");
        b.idle += arg_f64(&sp.args, "idle");
        *span_total.entry(r).or_default() += sp.dur;
    }
    for (r, b) in out.iter_mut() {
        b.idle += (rec.makespan() - span_total[r]).max(0.0);
    }
    out
}

/// Per-replica exposed/hidden collective seconds and booked fabric
/// gigabytes, folded from the event stream ([`fold_comm`]). `exposed` is
/// the step spans' Comm bucket (closed-form exposed comm plus any fabric
/// queueing delay); `hidden` and `booked_gb` come from the spans'
/// overlap-era `hidden`/`booked` args (0 for traces recorded with
/// overlap off).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommAgg {
    pub exposed: f64,
    pub hidden: f64,
    pub booked_gb: f64,
}

/// Sum each replica track's exposed/hidden/booked collective accounting
/// from its `step` spans — the event-stream view the serving loops'
/// analytic accumulators ([`crate::serving::ServeReport::comm_exposed`]
/// et al.) must reconcile with.
pub fn fold_comm(rec: &Recorder) -> BTreeMap<usize, CommAgg> {
    let mut out: BTreeMap<usize, CommAgg> = BTreeMap::new();
    for sp in rec.spans() {
        let Track::Replica(r) = sp.track else { continue };
        if sp.name != "step" {
            continue;
        }
        let c = out.entry(r).or_default();
        c.exposed += arg_f64(&sp.args, "comm");
        c.hidden += arg_f64(&sp.args, "hidden");
        c.booked_gb += arg_f64(&sp.args, "booked") / 1e9;
    }
    out
}

/// Max absolute difference between analytic per-replica comm accounting
/// (`analytic[r]` for replica `r`) and the event-derived one. A replica
/// with no recorded steps folds to all-zero. Folded tracks the analytic
/// side never produced are infinite drift, like [`reconcile`].
pub fn reconcile_comm(analytic: &[CommAgg], folded: &BTreeMap<usize, CommAgg>) -> f64 {
    let mut worst = 0.0f64;
    for (r, a) in analytic.iter().enumerate() {
        let f = folded.get(&r).copied().unwrap_or_default();
        for d in [a.exposed - f.exposed, a.hidden - f.hidden, a.booked_gb - f.booked_gb] {
            worst = worst.max(d.abs());
        }
    }
    for r in folded.keys() {
        if *r >= analytic.len() {
            worst = f64::INFINITY;
        }
    }
    worst
}

/// Max absolute per-bucket difference between the analytic breakdowns
/// (`analytic[r]` for replica `r`) and the event-derived ones. A replica
/// with no recorded steps folds to pure idle over the makespan.
pub fn reconcile(
    analytic: &[Breakdown],
    folded: &BTreeMap<usize, Breakdown>,
    makespan: f64,
) -> f64 {
    let mut worst = 0.0f64;
    for (r, a) in analytic.iter().enumerate() {
        let idle_only = Breakdown { idle: makespan, ..Default::default() };
        let f = folded.get(&r).copied().unwrap_or(idle_only);
        for d in [
            a.matmul - f.matmul,
            a.other_comp - f.other_comp,
            a.comm - f.comm,
            a.idle - f.idle,
        ] {
            worst = worst.max(d.abs());
        }
    }
    // Folded tracks the analytic side never produced also count.
    for r in folded.keys() {
        if *r >= analytic.len() {
            worst = f64::INFINITY;
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{ArgV, RunMeta};

    fn step_args(m: f64, o: f64, c: f64, i: f64) -> Vec<(&'static str, ArgV)> {
        vec![
            ("matmul", ArgV::F(m)),
            ("other", ArgV::F(o)),
            ("comm", ArgV::F(c)),
            ("idle", ArgV::F(i)),
        ]
    }

    #[test]
    fn fold_sums_buckets_and_attributes_gap_idle() {
        let mut r = Recorder::new(RunMeta::default());
        r.span(Track::Replica(0), "step", 0.0, 1.0, step_args(0.4, 0.3, 0.3, 0.0));
        r.span(Track::Replica(0), "step", 2.0, 1.0, step_args(0.5, 0.2, 0.2, 0.1));
        r.set_makespan(4.0);
        let folded = fold_breakdowns(&r);
        let b = folded[&0];
        assert!((b.matmul - 0.9).abs() < 1e-12);
        assert!((b.other_comp - 0.5).abs() < 1e-12);
        assert!((b.comm - 0.5).abs() < 1e-12);
        // 0.1 span-stamped + (4.0 − 2.0 span seconds) gap.
        assert!((b.idle - 2.1).abs() < 1e-12);
    }

    #[test]
    fn reconcile_matches_identical_breakdowns_and_flags_drift() {
        let mut r = Recorder::new(RunMeta::default());
        r.span(Track::Replica(0), "step", 0.0, 1.0, step_args(0.4, 0.3, 0.3, 0.0));
        r.set_makespan(1.0);
        let folded = fold_breakdowns(&r);
        let analytic = vec![Breakdown { matmul: 0.4, other_comp: 0.3, comm: 0.3, idle: 0.0 }];
        assert!(reconcile(&analytic, &folded, 1.0) < 1e-12);
        let drifted = vec![Breakdown { matmul: 0.5, other_comp: 0.3, comm: 0.3, idle: 0.0 }];
        assert!((reconcile(&drifted, &folded, 1.0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn replica_with_no_steps_folds_to_pure_idle() {
        let r = Recorder::new(RunMeta::default());
        let folded = fold_breakdowns(&r);
        let analytic = vec![Breakdown { idle: 3.0, ..Default::default() }];
        assert!(reconcile(&analytic, &folded, 3.0) < 1e-12);
    }

    #[test]
    fn fold_comm_sums_overlap_args_and_reconciles() {
        let mut r = Recorder::new(RunMeta::default());
        let mut args = step_args(0.4, 0.3, 0.3, 0.0);
        args.push(("hidden", ArgV::F(0.2)));
        args.push(("booked", ArgV::F(5.0e8)));
        r.span(Track::Replica(0), "step", 0.0, 1.0, args.clone());
        r.span(Track::Replica(0), "step", 2.0, 1.0, args);
        r.set_makespan(4.0);
        let folded = fold_comm(&r);
        let c = folded[&0];
        assert!((c.exposed - 0.6).abs() < 1e-12);
        assert!((c.hidden - 0.4).abs() < 1e-12);
        assert!((c.booked_gb - 1.0).abs() < 1e-12);
        let analytic = vec![CommAgg { exposed: 0.6, hidden: 0.4, booked_gb: 1.0 }];
        assert!(reconcile_comm(&analytic, &folded) < 1e-12);
        let drifted = vec![CommAgg { exposed: 0.6, hidden: 0.5, booked_gb: 1.0 }];
        assert!((reconcile_comm(&drifted, &folded) - 0.1).abs() < 1e-12);
        // Pre-overlap traces (no hidden/booked args) fold to zero.
        let mut r2 = Recorder::new(RunMeta::default());
        r2.span(Track::Replica(0), "step", 0.0, 1.0, step_args(0.4, 0.3, 0.3, 0.0));
        let c2 = fold_comm(&r2)[&0];
        assert_eq!((c2.hidden, c2.booked_gb), (0.0, 0.0));
        assert!(reconcile_comm(&[], &fold_comm(&r)).is_infinite());
    }

    #[test]
    fn unknown_folded_replica_is_infinite_drift() {
        let mut r = Recorder::new(RunMeta::default());
        r.span(Track::Replica(5), "step", 0.0, 1.0, step_args(1.0, 0.0, 0.0, 0.0));
        r.set_makespan(1.0);
        let folded = fold_breakdowns(&r);
        assert!(reconcile(&[], &folded, 1.0).is_infinite());
    }
}
