//! Per-request lifecycle records folded from the event stream (the
//! `{base}.lifecycle.csv` artifact).
//!
//! The serving loops emit request-keyed instants — `arrival`, `chunk`
//! (one per scheduled prefill chunk, with the attended context), `preempt`,
//! `first_token`, `finish` — and this fold groups them into one row per
//! request: admission latency (arrival → first chunk scheduled), chunk
//! count, preemptions, prefix-cache hit tokens (first chunk's
//! `ctx − tokens`, the cached prefix the batcher skipped), TTFT and TPOT.

use super::{arg_f64, Recorder};
use crate::util::tables::Table;
use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
struct Life {
    arrival: Option<f64>,
    first_chunk: Option<f64>,
    hit_tok: u64,
    chunks: u64,
    preempts: u64,
    first_token: Option<f64>,
    finish: Option<f64>,
    out_tokens: u64,
}

/// Fold the recorder's instants into one [`Table`] row per request,
/// ordered by request id. Requests still in flight at the end of the
/// trace (no `finish`) render with an empty finish column.
pub fn lifecycle_table(rec: &Recorder) -> Table {
    let mut lives: BTreeMap<u64, Life> = BTreeMap::new();
    for iv in rec.instants() {
        let req = arg_f64(&iv.args, "req") as u64;
        match iv.name.as_str() {
            "arrival" => lives.entry(req).or_default().arrival = Some(iv.at),
            "chunk" => {
                let l = lives.entry(req).or_default();
                l.chunks += 1;
                if l.first_chunk.is_none() {
                    l.first_chunk = Some(iv.at);
                    let tokens = arg_f64(&iv.args, "tokens");
                    let ctx = arg_f64(&iv.args, "ctx");
                    l.hit_tok = (ctx - tokens).max(0.0) as u64;
                }
            }
            "preempt" => lives.entry(req).or_default().preempts += 1,
            "first_token" => {
                let l = lives.entry(req).or_default();
                if l.first_token.is_none() {
                    l.first_token = Some(iv.at);
                }
            }
            "finish" => {
                let l = lives.entry(req).or_default();
                l.finish = Some(iv.at);
                l.out_tokens = arg_f64(&iv.args, "out") as u64;
            }
            _ => {}
        }
    }
    let mut t = Table::new(
        "request lifecycle",
        &[
            "req", "arrival_s", "admit_s", "chunks", "preempts", "hit_tok", "ttft_s", "tpot_s",
            "out_tok", "finish_s",
        ],
    );
    for (k, v) in rec.meta.pairs() {
        t.meta(&k, &v);
    }
    let f = |x: Option<f64>| x.map(|v| format!("{v:.6}")).unwrap_or_default();
    for (req, l) in &lives {
        let arrival = l.arrival.unwrap_or(0.0);
        let admit = l.first_chunk.map(|c| c - arrival);
        let ttft = l.first_token.map(|ft| ft - arrival);
        let tpot = match (l.first_token, l.finish) {
            (Some(ft), Some(fin)) if l.out_tokens > 1 => {
                Some((fin - ft) / (l.out_tokens - 1) as f64)
            }
            (Some(_), Some(_)) => Some(0.0),
            _ => None,
        };
        t.row(&[
            req.to_string(),
            format!("{arrival:.6}"),
            f(admit),
            l.chunks.to_string(),
            l.preempts.to_string(),
            l.hit_tok.to_string(),
            f(ttft),
            f(tpot),
            l.out_tokens.to_string(),
            f(l.finish),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{ArgV, RunMeta, Track};

    #[test]
    fn folds_one_request_end_to_end() {
        let mut r = Recorder::new(RunMeta::default());
        let t = Track::Replica(0);
        let u = |x: u64| ArgV::U(x);
        r.instant(t, "arrival", 1.0, vec![("req", u(7))]);
        // First chunk attends 640 tokens but only computes 512: 128 cached.
        r.instant(t, "chunk", 1.5, vec![("req", u(7)), ("tokens", u(512)), ("ctx", u(640))]);
        r.instant(t, "chunk", 2.0, vec![("req", u(7)), ("tokens", u(256)), ("ctx", u(896))]);
        r.instant(t, "first_token", 2.5, vec![("req", u(7))]);
        r.instant(t, "preempt", 3.0, vec![("req", u(7))]);
        r.instant(t, "finish", 4.5, vec![("req", u(7)), ("out", u(5))]);
        let table = lifecycle_table(&r);
        assert_eq!(table.rows().len(), 1);
        let row = &table.rows()[0];
        assert_eq!(row[0], "7");
        assert_eq!(row[1], "1.000000"); // arrival
        assert_eq!(row[2], "0.500000"); // admit latency
        assert_eq!(row[3], "2"); // chunks
        assert_eq!(row[4], "1"); // preempts
        assert_eq!(row[5], "128"); // hit tokens from the FIRST chunk only
        assert_eq!(row[6], "1.500000"); // ttft
        assert_eq!(row[7], "0.500000"); // tpot = (4.5-2.5)/(5-1)
        assert_eq!(row[8], "5");
    }

    #[test]
    fn unfinished_request_has_empty_finish_cells() {
        let mut r = Recorder::new(RunMeta::default());
        r.instant(Track::Replica(0), "arrival", 0.0, vec![("req", ArgV::U(1))]);
        let table = lifecycle_table(&r);
        let row = &table.rows()[0];
        assert_eq!(row[6], ""); // no ttft
        assert_eq!(row[9], ""); // no finish
    }
}
