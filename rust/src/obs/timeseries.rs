//! Fixed-window time-series folded from the event stream (the
//! `{base}.timeline.csv` artifact): how goodput, batch occupancy, KV
//! utilization, and fabric activity move over sim-time — the view the
//! end-of-run aggregates flatten away (diurnal ramps, drain dips,
//! migration bursts).

use super::{arg_f64, Recorder, Track};
use crate::simnet::LinkKind;
use crate::util::tables::Table;

/// Overlap of `[s, e)` with window `[w0, w1)`.
fn overlap(s: f64, e: f64, w0: f64, w1: f64) -> f64 {
    (e.min(w1) - s.max(w0)).max(0.0)
}

/// Fraction of `[w0, w1)` covered by the union of `intervals`.
fn union_frac(intervals: &[(f64, f64)], w0: f64, w1: f64) -> f64 {
    let mut clipped: Vec<(f64, f64)> = intervals
        .iter()
        .filter_map(|&(s, e)| {
            let (a, b) = (s.max(w0), e.min(w1));
            (b > a).then_some((a, b))
        })
        .collect();
    clipped.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut covered = 0.0;
    let mut cursor = w0;
    for (s, e) in clipped {
        let s = s.max(cursor);
        if e > s {
            covered += e - s;
            cursor = e;
        }
    }
    covered / (w1 - w0).max(1e-12)
}

/// Fold the recorder into one row per `window`-second bucket over
/// `[0, makespan]`:
///
/// - `out_tok_per_s` — decoded tokens per second (`toks` instants),
/// - `running` — mean sequences in flight (step spans' `seqs` weighted
///   by their overlap with the window),
/// - `kv_frac` — mean KV-page occupancy across `kv` gauge samples in the
///   window (previous sample held when a window has none),
/// - `busy_intra` / `busy_inter` — fraction of the window in which at
///   least one flow occupied a link of that class (union over the link
///   tracks' spans).
pub fn timeseries_table(rec: &Recorder, window: f64) -> Table {
    let window = window.max(1e-9);
    let horizon = rec.makespan().max(window);
    let n_win = (horizon / window).ceil() as usize;

    // Pre-split events once.
    let mut step_spans: Vec<(f64, f64, f64)> = Vec::new(); // (start, end, seqs)
    let mut intra: Vec<(f64, f64)> = Vec::new();
    let mut inter: Vec<(f64, f64)> = Vec::new();
    for sp in rec.spans() {
        match sp.track {
            Track::Replica(_) if sp.name == "step" => {
                step_spans.push((sp.start, sp.start + sp.dur, arg_f64(&sp.args, "seqs")));
            }
            Track::Link { kind, .. } => {
                let iv = (sp.start, sp.start + sp.dur);
                if kind == LinkKind::Intra {
                    intra.push(iv);
                } else {
                    inter.push(iv);
                }
            }
            _ => {}
        }
    }
    let mut toks: Vec<(f64, f64)> = Vec::new(); // (at, tokens)
    let mut kv: Vec<(f64, f64)> = Vec::new(); // (at, frac)
    for iv in rec.instants() {
        match iv.name.as_str() {
            "toks" => toks.push((iv.at, arg_f64(&iv.args, "n"))),
            "kv" => kv.push((iv.at, arg_f64(&iv.args, "frac"))),
            _ => {}
        }
    }
    kv.sort_by(|a, b| a.0.total_cmp(&b.0));

    let mut t = Table::new(
        "timeline",
        &["t0_s", "out_tok_per_s", "running", "kv_frac", "busy_intra", "busy_inter"],
    );
    for (k, v) in rec.meta.pairs() {
        t.meta(&k, &v);
    }
    let mut last_kv = 0.0;
    for w in 0..n_win {
        let (w0, w1) = (w as f64 * window, (w as f64 + 1.0) * window);
        let out: f64 = toks.iter().filter(|(at, _)| *at >= w0 && *at < w1).map(|(_, n)| n).sum();
        let running: f64 = step_spans
            .iter()
            .map(|&(s, e, seqs)| seqs * overlap(s, e, w0, w1))
            .sum::<f64>()
            / window;
        let samples: Vec<f64> =
            kv.iter().filter(|(at, _)| *at >= w0 && *at < w1).map(|(_, f)| *f).collect();
        let kv_frac = if samples.is_empty() {
            last_kv
        } else {
            let mean = samples.iter().sum::<f64>() / samples.len() as f64;
            last_kv = *samples.last().unwrap();
            mean
        };
        t.row(&[
            format!("{w0:.3}"),
            format!("{:.2}", out / window),
            format!("{running:.2}"),
            format!("{kv_frac:.4}"),
            format!("{:.4}", union_frac(&intra, w0, w1)),
            format!("{:.4}", union_frac(&inter, w0, w1)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{ArgV, RunMeta};

    #[test]
    fn union_frac_merges_overlaps() {
        let iv = [(0.0, 0.5), (0.25, 0.75), (2.0, 3.0)];
        assert!((union_frac(&iv, 0.0, 1.0) - 0.75).abs() < 1e-12);
        assert_eq!(union_frac(&iv, 1.0, 2.0), 0.0);
        assert_eq!(union_frac(&[], 0.0, 1.0), 0.0);
    }

    #[test]
    fn windows_partition_the_run() {
        let mut r = Recorder::new(RunMeta::default());
        // 10 tokens at t=0.5, 20 at t=1.5; one step span covering [0, 2)
        // with 4 seqs; KV gauge sampled once per second.
        r.span(
            Track::Replica(0),
            "step",
            0.0,
            2.0,
            vec![("seqs", ArgV::F(4.0))],
        );
        r.instant(Track::Replica(0), "toks", 0.5, vec![("n", ArgV::U(10))]);
        r.instant(Track::Replica(0), "toks", 1.5, vec![("n", ArgV::U(20))]);
        r.instant(Track::Replica(0), "kv", 0.5, vec![("frac", ArgV::F(0.25))]);
        r.span(
            Track::Link { scope: 0, kind: LinkKind::Inter },
            "xfer",
            0.0,
            0.5,
            vec![],
        );
        r.set_makespan(2.0);
        let t = timeseries_table(&r, 1.0);
        assert_eq!(t.rows().len(), 2);
        let r0 = &t.rows()[0];
        let r1 = &t.rows()[1];
        assert_eq!(r0[1], "10.00");
        assert_eq!(r1[1], "20.00");
        assert_eq!(r0[2], "4.00");
        assert_eq!(r0[3], "0.2500");
        // Window 1 has no KV sample: previous value held.
        assert_eq!(r1[3], "0.2500");
        assert_eq!(r0[5], "0.5000"); // NIC busy half of window 0
        assert_eq!(r1[5], "0.0000");
    }
}
