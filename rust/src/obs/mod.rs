//! Simulator-native tracing: a Nsight/Pipit-style event timeline for
//! every run.
//!
//! The source paper's bottleneck analysis (Figs 3, 8) comes from Nsight
//! Systems traces folded with Pipit into per-GPU Matmul / Other-Comp /
//! Comm / Idle buckets. This module is the simulation's analogue: a
//! structured event [`Recorder`] the serving loop, the fleet simulation,
//! and the collective flow models all feed, from which three artifacts
//! are derived:
//!
//! 1. **Chrome trace-event JSON** ([`chrome`]) — loadable in Perfetto;
//!    tracks are replicas (step spans with per-bucket args), fabric links
//!    (per-phase collective spans, KV transfers), and a control track
//!    (router/autoscaler decisions).
//! 2. **Per-request lifecycle CSV** ([`lifecycle`]) — admission latency,
//!    prefill chunks, preemptions, prefix-cache hit tokens, TTFT/TPOT.
//! 3. **Windowed time-series CSV** ([`timeseries`]) — goodput, batch
//!    occupancy, KV utilization, per-kind link activity over sim-time.
//!
//! [`fold`] closes the loop: it re-derives the four-bucket
//! [`crate::metrics::Breakdown`] per replica from the event stream alone
//! and reconciles it against the analytically accumulated one — turning
//! the tracer into a correctness check on the cost model itself
//! (asserted to 1e-6 in `tests/integration_obs.rs`).
//!
//! Tracing is **zero-cost when disabled**: every hook sits behind an
//! `Option<ObsSink>` that defaults to `None`, and the recording path
//! never feeds back into any simulated quantity — reports with tracing
//! off are bit-for-bit identical to a build without this module.

pub mod chrome;
pub mod fold;
pub mod json;
pub mod lifecycle;
pub mod timeseries;

use crate::simnet::LinkKind;
use std::sync::{Arc, Mutex};

/// Shared handle every instrumented layer holds; cheap to clone.
pub type ObsSink = Arc<Mutex<Recorder>>;

/// Where an event lives in the timeline. One `Replica` track per serving
/// replica (a TP group acting as one logical GPU), one `Link` track per
/// (scope, link-class) slice of the shared fabric, and a `Control` track
/// for fleet-level decisions (routing, scaling, drains).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Track {
    Replica(usize),
    Link { scope: usize, kind: LinkKind },
    Control,
}

/// One span/instant argument value.
#[derive(Clone, Debug, PartialEq)]
pub enum ArgV {
    F(f64),
    U(u64),
    S(String),
}

/// A duration event (`ph: "X"` in the Chrome trace).
#[derive(Clone, Debug)]
pub struct SpanEv {
    pub track: Track,
    pub name: String,
    /// Start time, sim seconds.
    pub start: f64,
    /// Duration, sim seconds.
    pub dur: f64,
    pub args: Vec<(&'static str, ArgV)>,
}

/// A point event (`ph: "i"`).
#[derive(Clone, Debug)]
pub struct InstantEv {
    pub track: Track,
    pub name: String,
    pub at: f64,
    pub args: Vec<(&'static str, ArgV)>,
}

/// Run-identifying metadata stamped into every artifact so traces are
/// self-describing and reproducible (the satellite of ISSUE 6).
#[derive(Clone, Debug)]
pub struct RunMeta {
    /// Workload seed (None for seedless runs).
    pub seed: Option<u64>,
    /// Deployment label, e.g. `tp16/NVRAR`.
    pub label: String,
    pub model: String,
    pub machine: String,
    /// Crate version the artifact was produced by.
    pub version: &'static str,
}

impl Default for RunMeta {
    fn default() -> Self {
        RunMeta {
            seed: None,
            label: String::new(),
            model: String::new(),
            machine: String::new(),
            version: env!("CARGO_PKG_VERSION"),
        }
    }
}

impl RunMeta {
    /// Key/value pairs for CSV headers and the trace's metadata object.
    pub fn pairs(&self) -> Vec<(String, String)> {
        let mut out = vec![("version".to_string(), self.version.to_string())];
        if let Some(s) = self.seed {
            out.push(("seed".to_string(), format!("{s:#x}")));
        }
        for (k, v) in
            [("deployment", &self.label), ("model", &self.model), ("machine", &self.machine)]
        {
            if !v.is_empty() {
                out.push((k.to_string(), v.clone()));
            }
        }
        out
    }
}

/// The event store one run accumulates. Owned behind an [`ObsSink`];
/// locked briefly per event (the simulations are single-threaded, the
/// mutex only exists so the sink can be shared through `Arc` clones in
/// configs).
#[derive(Debug, Default)]
pub struct Recorder {
    pub meta: RunMeta,
    spans: Vec<SpanEv>,
    instants: Vec<InstantEv>,
    makespan: f64,
}

impl Recorder {
    pub fn new(meta: RunMeta) -> Self {
        Recorder { meta, ..Default::default() }
    }

    /// Convenience: a fresh shared sink.
    pub fn sink(meta: RunMeta) -> ObsSink {
        Arc::new(Mutex::new(Recorder::new(meta)))
    }

    pub fn span(
        &mut self,
        track: Track,
        name: &str,
        start: f64,
        dur: f64,
        args: Vec<(&'static str, ArgV)>,
    ) {
        self.spans.push(SpanEv { track, name: name.to_string(), start, dur: dur.max(0.0), args });
    }

    pub fn instant(&mut self, track: Track, name: &str, at: f64, args: Vec<(&'static str, ArgV)>) {
        self.instants.push(InstantEv { track, name: name.to_string(), at, args });
    }

    /// Declare the run's horizon (monotone max) — the fold uses it to
    /// attribute trailing idle time.
    pub fn set_makespan(&mut self, t: f64) {
        self.makespan = self.makespan.max(t);
    }

    pub fn makespan(&self) -> f64 {
        self.makespan
    }

    pub fn spans(&self) -> &[SpanEv] {
        &self.spans
    }

    pub fn instants(&self) -> &[InstantEv] {
        &self.instants
    }
}

/// Look up a span/instant argument by key.
pub fn arg<'a>(args: &'a [(&'static str, ArgV)], key: &str) -> Option<&'a ArgV> {
    args.iter().find(|(k, _)| *k == key).map(|(_, v)| v)
}

/// Numeric argument lookup (F or U), defaulting to 0.
pub fn arg_f64(args: &[(&'static str, ArgV)], key: &str) -> f64 {
    match arg(args, key) {
        Some(ArgV::F(x)) => *x,
        Some(ArgV::U(u)) => *u as f64,
        _ => 0.0,
    }
}

/// Write the three artifacts for a finished run: `{base}.trace.json`
/// (Chrome trace), `{base}.lifecycle.csv`, `{base}.timeline.csv`.
/// Returns the written paths.
pub fn write_artifacts(base: &str, rec: &Recorder) -> std::io::Result<Vec<String>> {
    if let Some(dir) = std::path::Path::new(base).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let trace = format!("{base}.trace.json");
    std::fs::write(&trace, chrome::to_chrome_json(rec))?;
    let life = format!("{base}.lifecycle.csv");
    std::fs::write(&life, lifecycle::lifecycle_table(rec).to_csv())?;
    let tl = format!("{base}.timeline.csv");
    let window = (rec.makespan() / 20.0).max(1e-3);
    std::fs::write(&tl, timeseries::timeseries_table(rec, window).to_csv())?;
    Ok(vec![trace, life, tl])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_accumulates_and_makespan_is_monotone() {
        let mut r = Recorder::new(RunMeta::default());
        r.span(Track::Replica(0), "step", 0.0, 1.0, vec![("rows", ArgV::U(4))]);
        r.instant(Track::Control, "route", 0.5, vec![("req", ArgV::U(7))]);
        r.set_makespan(2.0);
        r.set_makespan(1.0);
        assert_eq!(r.spans().len(), 1);
        assert_eq!(r.instants().len(), 1);
        assert_eq!(r.makespan(), 2.0);
        assert_eq!(arg_f64(&r.spans()[0].args, "rows"), 4.0);
        assert_eq!(arg_f64(&r.spans()[0].args, "nope"), 0.0);
    }

    #[test]
    fn negative_durations_clamp_to_zero() {
        let mut r = Recorder::new(RunMeta::default());
        r.span(Track::Replica(0), "step", 1.0, -0.25, vec![]);
        assert_eq!(r.spans()[0].dur, 0.0);
    }

    #[test]
    fn meta_pairs_include_version_and_skip_empty() {
        let m = RunMeta {
            seed: Some(0xB0257),
            label: "tp16/NVRAR".into(),
            model: "70b".into(),
            machine: String::new(),
            version: "9.9.9",
        };
        let pairs = m.pairs();
        assert!(pairs.contains(&("version".to_string(), "9.9.9".to_string())));
        assert!(pairs.contains(&("seed".to_string(), "0xb0257".to_string())));
        assert!(pairs.contains(&("deployment".to_string(), "tp16/NVRAR".to_string())));
        assert!(!pairs.iter().any(|(k, _)| k == "machine"));
    }

    #[test]
    fn write_artifacts_emits_all_three_files() {
        let mut r = Recorder::new(RunMeta::default());
        r.span(Track::Replica(0), "step", 0.0, 0.5, vec![]);
        r.set_makespan(0.5);
        let dir = std::env::temp_dir().join("yalis_obs_test");
        let base = dir.join("run").to_str().unwrap().to_string();
        let paths = write_artifacts(&base, &r).unwrap();
        assert_eq!(paths.len(), 3);
        for p in &paths {
            assert!(std::fs::metadata(p).unwrap().len() > 0, "{p} empty");
        }
    }
}
