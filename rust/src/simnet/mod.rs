//! Discrete-event simulation substrate.
//!
//! Three pieces:
//!
//! - [`EventQueue`]: a time-ordered event heap with stable FIFO tie-breaking.
//!   Callers own the state machine and `match` on their payload type — no
//!   trait-object callbacks, so simulations stay plain, testable Rust. Used
//!   by the serving simulator (request arrivals / step completions) and the
//!   engine-level pipeline simulation.
//! - [`Server`]: a FIFO resource (a NIC, a link, a GPU's compute stream,
//!   a pipeline stage). `book(ready, dur)` returns the `[start, end)`
//!   occupancy interval respecting both the caller's readiness and the
//!   resource's queue — the building block for α-β link contention in the
//!   collective simulations.
//! - [`Interconnect`]: a **shared fabric** of per-node links (intra-node
//!   NVLink, inter-node NIC) with fair-share bandwidth occupancy. Every
//!   byte a simulation moves — collective phases, KV handoffs, drain
//!   migrations — books onto a [`LinkId`], and concurrent flows on the
//!   same link slow each other down ([`Interconnect::book`]). With an idle
//!   link a booking completes in exactly `bytes/β` seconds, which is what
//!   keeps the contention path bit-compatible with the closed-form α-β
//!   models when nothing else is on the fabric.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, BTreeMap};

/// One scheduled event.
struct Entry<T> {
    at: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap: earliest time first, then insertion order.
        // `total_cmp` (not `partial_cmp` + a silent Equal fallback) makes
        // the order *total*: a NaN timestamp can no longer collapse into a
        // heap-shape-dependent tie, so equal-time pops are always stable
        // FIFO — the property the contention results depend on.
        other.at.total_cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

/// Min-time event queue; popping advances the simulation clock.
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    now: f64,
    seq: u64,
    popped: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, popped: 0 }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.popped
    }

    /// Schedule `payload` at absolute time `at` (must not be in the past).
    pub fn push(&mut self, at: f64, payload: T) {
        debug_assert!(at >= self.now - 1e-12, "event at {at} < now {}", self.now);
        self.heap.push(Entry { at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` `delay` seconds from now.
    pub fn push_in(&mut self, delay: f64, payload: T) {
        let at = self.now + delay;
        self.push(at, payload);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let e = self.heap.pop()?;
        debug_assert!(e.at >= self.now - 1e-12, "time went backwards");
        self.now = self.now.max(e.at);
        self.popped += 1;
        Some((self.now, e.payload))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// A FIFO resource with a single service lane (link, NIC, compute stream).
#[derive(Clone, Copy, Debug, Default)]
pub struct Server {
    next_free: f64,
    busy_total: f64,
}

impl Server {
    pub fn new() -> Self {
        Self::default()
    }

    /// Book `dur` seconds of service no earlier than `ready`.
    /// Returns the `(start, end)` interval granted.
    pub fn book(&mut self, ready: f64, dur: f64) -> (f64, f64) {
        debug_assert!(dur >= 0.0);
        let start = ready.max(self.next_free);
        let end = start + dur;
        self.next_free = end;
        self.busy_total += dur;
        (start, end)
    }

    /// When the resource next becomes idle.
    pub fn next_free(&self) -> f64 {
        self.next_free
    }

    /// Total busy time booked — used for utilization/idle accounting.
    pub fn busy_total(&self) -> f64 {
        self.busy_total
    }
}

// ---------------------------------------------------------------------
// Shared interconnect: per-link fair-share bandwidth occupancy
// ---------------------------------------------------------------------

/// Link class of a fabric link.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LinkKind {
    /// Intra-node (NVLink-class).
    Intra,
    /// Inter-node (scale-out NIC).
    Inter,
}

/// One directedless link of the shared fabric: a `scope` (one replica's /
/// one TP group's slice of the cluster), a node rank within that scope,
/// and the link class. Transfers between scopes book the source's and the
/// target's inter-node links; a collective books every node of its scope.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LinkId {
    pub scope: usize,
    pub node: usize,
    pub kind: LinkKind,
}

/// Outcome of one fabric booking.
#[derive(Clone, Copy, Debug)]
pub struct Flow {
    /// When the last byte has moved.
    pub end: f64,
    /// Idle-link transfer seconds (`bytes/β`).
    pub ideal: f64,
    /// Queueing delay beyond `ideal` caused by concurrent flows
    /// (exactly 0.0 when the link was uncontended for the whole transfer).
    pub delay: f64,
}

/// Congestion accounting across every booking of a fabric: how many flows
/// were delayed, by how much, and a decade histogram of the delays.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CongestionStats {
    /// All bookings (delayed or not).
    pub bookings: u64,
    /// Bookings that finished later than their idle-link time.
    pub delayed: u64,
    /// Total delay seconds across delayed bookings.
    pub total_delay: f64,
    /// Largest single delay.
    pub max_delay: f64,
    /// Delay histogram, decade buckets: `<1µs, <10µs, <100µs, <1ms,
    /// <10ms, <100ms, ≥100ms` (see [`CongestionStats::BUCKETS`]).
    pub hist: [u64; 7],
}

impl CongestionStats {
    /// Upper bounds (seconds) of the histogram buckets; the last bucket is
    /// unbounded.
    pub const BUCKETS: [f64; 6] = [1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1];

    /// Human labels matching [`CongestionStats::hist`].
    pub fn bucket_labels() -> [&'static str; 7] {
        ["<1us", "<10us", "<100us", "<1ms", "<10ms", "<100ms", ">=100ms"]
    }

    fn record(&mut self, delay: f64) {
        self.bookings += 1;
        if delay <= 0.0 {
            return;
        }
        self.delayed += 1;
        self.total_delay += delay;
        self.max_delay = self.max_delay.max(delay);
        let idx = Self::BUCKETS.iter().position(|&b| delay < b).unwrap_or(6);
        self.hist[idx] += 1;
    }

    /// Mean delay over delayed bookings (0 when none).
    pub fn mean_delay(&self) -> f64 {
        if self.delayed == 0 {
            0.0
        } else {
            self.total_delay / self.delayed as f64
        }
    }
}

/// One link's occupancy state.
#[derive(Clone, Debug)]
struct Link {
    /// Bandwidth β in bytes/second.
    beta: f64,
    /// Booked `[start, end)` intervals; intervals ending before the
    /// fabric's [`Interconnect::advance`] watermark are pruned lazily.
    active: Vec<(f64, f64)>,
    /// Total idle-equivalent busy seconds (Σ bytes/β) — utilization.
    busy_ideal: f64,
    /// Total bytes carried.
    bytes: f64,
}

/// Shared-fabric bandwidth tracker with **fair-share progress**: a new
/// flow's instantaneous rate at time `τ` is `β / (1 + k(τ))` where `k(τ)`
/// is the number of previously-booked flows overlapping `τ`. Booked flows'
/// completion times are immutable (the newcomer pays for the sharing),
/// which keeps every booking O(overlapping flows), deterministic, and
/// *monotone*: adding traffic can only push later bookings out, never pull
/// them in. On an idle link the rate is exactly β, so the booking
/// completes in exactly `bytes/β` seconds with `delay == 0.0` — the
/// closed-form α-β parity guarantee the integration tests pin.
///
/// Bookings may arrive in any time order (experiments pre-book background
/// traffic across the whole horizon, then simulations book flows from
/// t = 0); nothing is forgotten until the owner declares time progress
/// via [`Interconnect::advance`], which is what keeps per-link state
/// bounded over long runs.
#[derive(Clone, Debug)]
pub struct Interconnect {
    links: BTreeMap<LinkId, Link>,
    stats: CongestionStats,
    /// No future booking will be ready before this time; intervals ending
    /// at or before it are unreachable and pruned lazily.
    watermark: f64,
    /// Sweep-event scratch reused across [`Interconnect::book`] calls —
    /// a fleet books every step's collective bytes here, so the per-call
    /// `Vec` of the old path was allocator churn on the hot loop.
    sweep: Vec<(f64, i32)>,
}

impl Default for Interconnect {
    fn default() -> Self {
        Interconnect {
            links: BTreeMap::new(),
            stats: CongestionStats::default(),
            watermark: f64::NEG_INFINITY,
            sweep: Vec::new(),
        }
    }
}

impl Interconnect {
    pub fn new() -> Self {
        Self::default()
    }

    /// Promise that no future booking will be ready before `t` (monotone —
    /// earlier values are ignored). Simulations call this with their event
    /// clock so finished intervals can be pruned; pre-booking background
    /// traffic before a run simply never advances.
    pub fn advance(&mut self, t: f64) {
        self.watermark = self.watermark.max(t);
    }

    /// Declare one link (idempotent; re-adding keeps existing occupancy).
    pub fn add_link(&mut self, id: LinkId, beta: f64) {
        assert!(beta > 0.0, "link {id:?} needs positive bandwidth");
        self.links
            .entry(id)
            .or_insert(Link { beta, active: Vec::new(), busy_ideal: 0.0, bytes: 0.0 });
    }

    /// Declare one scope's links: an intra-node and an inter-node link per
    /// node rank — the fabric slice one replica (or one standalone
    /// topology) occupies.
    pub fn add_scope(&mut self, scope: usize, nodes: usize, intra_beta: f64, inter_beta: f64) {
        for node in 0..nodes.max(1) {
            self.add_link(LinkId { scope, node, kind: LinkKind::Intra }, intra_beta);
            self.add_link(LinkId { scope, node, kind: LinkKind::Inter }, inter_beta);
        }
    }

    /// Move `bytes` over `id` starting no earlier than `ready`, sharing
    /// bandwidth fairly with every already-booked overlapping flow
    /// (whether booked for the past, the present, or the future).
    /// Panics on an undeclared link — a wiring bug, not a load condition.
    pub fn book(&mut self, id: LinkId, ready: f64, bytes: f64) -> Flow {
        let cut = self.watermark;
        let link = self
            .links
            .get_mut(&id)
            .unwrap_or_else(|| panic!("booking on undeclared link {id:?}"));
        debug_assert!(bytes >= 0.0 && ready.is_finite());
        let ideal = bytes / link.beta;
        if bytes <= 0.0 {
            self.stats.record(0.0);
            return Flow { end: ready, ideal: 0.0, delay: 0.0 };
        }
        // Lazily drop intervals no future booking can reach. NOT keyed to
        // this booking's `ready`: a later call may legitimately book at an
        // earlier time (pre-booked background traffic), and must still see
        // every interval it overlaps.
        link.active.retain(|&(_, e)| e > cut);
        // Sweep the load profile: +1 at each overlap start, -1 at each
        // end; intervals fully before `ready` cannot overlap this flow.
        let mut events = std::mem::take(&mut self.sweep);
        events.clear();
        for &(s, e) in &link.active {
            if e <= ready {
                continue;
            }
            events.push((s.max(ready), 1));
            events.push((e, -1));
        }
        events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut t = ready;
        let mut k: i32 = 0;
        let mut remaining = bytes;
        let mut contended = false;
        let mut i = 0;
        while remaining > 0.0 {
            while i < events.len() && events[i].0 <= t {
                k += events[i].1;
                i += 1;
            }
            if k > 0 {
                contended = true;
            }
            let rate = link.beta / (1.0 + k as f64);
            let next = if i < events.len() { events[i].0 } else { f64::INFINITY };
            let span = next - t;
            if span * rate >= remaining {
                t += remaining / rate;
                remaining = 0.0;
            } else {
                remaining -= span * rate;
                t = next;
            }
        }
        // Uncontended bookings complete in exactly bytes/β: force the
        // arithmetic so `delay` is a true 0.0, not floating-point dust —
        // a contention-enabled-but-idle fabric reproduces the standalone
        // α-β numbers bit for bit.
        let end = if contended { t } else { ready + ideal };
        let delay = if contended { (end - ready - ideal).max(0.0) } else { 0.0 };
        link.active.push((ready, end));
        link.busy_ideal += ideal;
        link.bytes += bytes;
        self.sweep = events;
        self.stats.record(delay);
        Flow { end, ideal, delay }
    }

    /// Mean utilization of every declared link of `kind` over `[0,
    /// horizon]`: idle-equivalent busy seconds / (links × horizon),
    /// capped at 1.0 — traffic booked beyond the horizon (pre-booked
    /// background outlasting a short run) would otherwise over-count.
    pub fn utilization(&self, kind: LinkKind, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            return 0.0;
        }
        let (busy, n) = self
            .links
            .iter()
            .filter(|(id, _)| id.kind == kind)
            .fold((0.0, 0usize), |(b, n), (_, l)| (b + l.busy_ideal, n + 1));
        if n == 0 {
            0.0
        } else {
            (busy / (n as f64 * horizon)).min(1.0)
        }
    }

    /// Total bytes carried by links of `kind`.
    pub fn bytes_carried(&self, kind: LinkKind) -> f64 {
        self.links.iter().filter(|(id, _)| id.kind == kind).map(|(_, l)| l.bytes).sum()
    }

    /// Fabric-wide congestion accounting.
    pub fn stats(&self) -> &CongestionStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn congestion_histogram_bucket_boundaries() {
        let mut s = CongestionStats::default();
        // Non-positive delays count the booking but touch nothing else.
        s.record(0.0);
        s.record(-1e-9);
        assert_eq!(s.bookings, 2);
        assert_eq!(s.delayed, 0);
        assert_eq!(s.hist, [0; 7]);
        // Buckets are half-open [prev, bound): an exact bound belongs to
        // the NEXT bucket (strict `<` in record).
        s.record(1e-6);
        assert_eq!(s.hist, [0, 1, 0, 0, 0, 0, 0], "1µs is the 2nd bucket's floor");
        s.record(1e-6 - 1e-12);
        assert_eq!(s.hist[0], 1, "just under 1µs lands in <1µs");
        for (i, b) in CongestionStats::BUCKETS.iter().enumerate() {
            let mut t = CongestionStats::default();
            t.record(*b);
            let expect = (i + 1).min(6);
            assert_eq!(t.hist[expect], 1, "bound {b} -> bucket {expect}");
        }
        // At and beyond the last bound: the unbounded tail bucket.
        s.record(1e-1);
        s.record(7.5);
        assert_eq!(s.hist[6], 2);
        // Aggregates line up with what was recorded.
        assert_eq!(s.delayed, 4);
        assert_eq!(s.max_delay, 7.5);
        assert!((s.mean_delay() - (1e-6 + (1e-6 - 1e-12) + 1e-1 + 7.5) / 4.0).abs() < 1e-12);
        assert_eq!(s.hist.iter().sum::<u64>(), s.delayed);
        assert_eq!(CongestionStats::bucket_labels().len(), s.hist.len());
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_tie_break() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_monotonic() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.push(1.0, ());
        let mut last = 0.0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
            if t < 2.0 {
                q.push_in(0.5, ());
            }
        }
        // events: 1.0, then chained 1.5 and 2.0, then the original 5.0
        assert_eq!(q.processed(), 4);
    }

    #[test]
    fn server_fifo_queueing() {
        let mut s = Server::new();
        let (a0, a1) = s.book(0.0, 2.0);
        assert_eq!((a0, a1), (0.0, 2.0));
        // Request ready earlier than the server is free: queues.
        let (b0, b1) = s.book(1.0, 1.0);
        assert_eq!((b0, b1), (2.0, 3.0));
        // Request ready after the server frees: starts at readiness.
        let (c0, c1) = s.book(10.0, 0.5);
        assert_eq!((c0, c1), (10.0, 10.5));
        assert!((s.busy_total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn many_events_throughput_shape() {
        // Simulator invariant: N scheduled events all get processed.
        let mut q = EventQueue::new();
        for i in 0..10_000 {
            q.push((i % 97) as f64, i);
        }
        let mut n = 0;
        while q.pop().is_some() {
            n += 1;
        }
        assert_eq!(n, 10_000);
    }

    #[test]
    fn property_equal_timestamps_pop_in_stable_fifo_order() {
        // The contention results are only reproducible if simultaneous
        // events (a migration landing and a step completing at the same
        // instant) always pop in insertion order. Draw times from a small
        // discrete set so ties are dense, and check the pop order is the
        // stable sort of the push order.
        use crate::util::prop::{check, Gen};
        check("event queue ties are FIFO", 60, |g: &mut Gen| {
            let n = g.usize(2, 200);
            let mut q = EventQueue::new();
            let mut pushed: Vec<(f64, usize)> = Vec::with_capacity(n);
            for i in 0..n {
                let at = g.usize(0, 4) as f64 * 0.25;
                q.push(at, i);
                pushed.push((at, i));
            }
            let mut expect = pushed.clone();
            expect.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let popped: Vec<(f64, usize)> =
                std::iter::from_fn(|| q.pop()).collect();
            assert_eq!(popped, expect, "pop order must be stable FIFO per timestamp");
        });
    }

    #[test]
    fn event_queue_holds_order_at_a_million_events() {
        // Soak-scale regression: the heap must keep exact time order and
        // FIFO tie-breaking at 1M events (a 10M-request fleet run pops
        // tens of millions) with no O(n) behavior creeping in. Pushes and
        // pops interleave like a real simulation: the clock only moves
        // forward, and quantized offsets make equal-time ties dense.
        let mut rng = crate::util::rng::Rng::new(0x50AC);
        let mut q = EventQueue::new();
        const N: u64 = 1_000_000;
        let mut pushed = 0u64;
        let mut seq = 0u64;
        let mut last = (f64::NEG_INFINITY, 0u64);
        while pushed < N || !q.is_empty() {
            while pushed < N && (q.len() < 64 || rng.bool(0.6)) {
                let at = q.now() + rng.range(0, 8) as f64 * 0.125;
                q.push(at, seq);
                seq += 1;
                pushed += 1;
            }
            let (t, id) = q.pop().expect("queue non-empty");
            assert!(t >= last.0, "time went backwards");
            if t == last.0 {
                // Every push gets a larger id, so stable FIFO means
                // consecutive equal-time pops strictly increase.
                assert!(id > last.1, "equal-time pops must be FIFO");
            }
            last = (t, id);
        }
        assert_eq!(q.processed(), N);
        assert_eq!(q.len(), 0);
    }

    // -- Interconnect ---------------------------------------------------

    fn one_link() -> (Interconnect, LinkId) {
        let mut net = Interconnect::new();
        let id = LinkId { scope: 0, node: 0, kind: LinkKind::Inter };
        net.add_link(id, 1e9); // 1 GB/s
        (net, id)
    }

    #[test]
    fn idle_link_booking_is_exact_alpha_beta_with_zero_delay() {
        let (mut net, id) = one_link();
        let f = net.book(id, 2.0, 1e9);
        assert_eq!(f.end, 3.0);
        assert_eq!(f.delay, 0.0);
        assert_eq!(f.ideal, 1.0);
        // Non-overlapping follow-up is also idle.
        let g = net.book(id, 10.0, 5e8);
        assert_eq!(g.end, 10.5);
        assert_eq!(g.delay, 0.0);
        assert_eq!(net.stats().delayed, 0);
        assert_eq!(net.stats().bookings, 2);
    }

    #[test]
    fn overlapping_flows_fair_share_the_link() {
        let (mut net, id) = one_link();
        // Flow A occupies [0, 1).
        net.book(id, 0.0, 1e9);
        // Flow B starts at 0 too: shares β/2 while A is present (its whole
        // first second), then finishes alone: 1e9 bytes = 0.5e9 in [0,1)
        // at rate 0.5 GB/s, remaining 0.5e9 at 1 GB/s -> end 1.5.
        let b = net.book(id, 0.0, 1e9);
        assert!((b.end - 1.5).abs() < 1e-12, "end {}", b.end);
        assert!((b.delay - 0.5).abs() < 1e-12, "delay {}", b.delay);
        assert_eq!(net.stats().delayed, 1);
        assert_eq!(net.stats().hist[6], 1, "0.5s delay lands in the top bucket");
    }

    #[test]
    fn future_bookings_slow_flows_that_overlap_them() {
        let (mut net, id) = one_link();
        // A transfer parked in the future (a phase-2 booking made earlier
        // in the step) still counts against flows that overlap it.
        net.book(id, 1.0, 1e9); // occupies [1, 2)
        let f = net.book(id, 0.5, 1e9);
        // [0.5, 1): 0.5e9 moved alone; remaining 0.5e9 at half rate -> 1s.
        assert!((f.end - 2.0).abs() < 1e-12, "end {}", f.end);
        assert!((f.delay - 0.5).abs() < 1e-12);
    }

    #[test]
    fn monotone_adding_background_never_speeds_a_booking() {
        use crate::util::prop::{check, Gen};
        check("fair-share booking is monotone in load", 40, |g: &mut Gen| {
            let n_bg = g.usize(0, 6);
            let bg: Vec<(f64, f64)> = (0..n_bg)
                .map(|_| (g.f64(0.0, 2.0), g.f64(1e6, 2e9)))
                .collect();
            let ready = g.f64(0.0, 2.0);
            let bytes = g.f64(1e6, 1e9);
            let mut last = 0.0;
            for take in 0..=n_bg {
                let (mut net, id) = one_link();
                // Background in any time order relative to the measured
                // flow — bookings are order-independent w.r.t. `ready`.
                let mut slice: Vec<_> = bg[..take].to_vec();
                slice.sort_by(|a, b| a.0.total_cmp(&b.0));
                for &(t, b) in &slice {
                    net.book(id, t, b);
                }
                let f = net.book(id, ready, bytes);
                assert!(
                    f.end >= last - 1e-12,
                    "more background made the flow finish earlier: {} < {last}",
                    f.end
                );
                last = last.max(f.end);
            }
        });
    }

    #[test]
    fn pre_booked_background_is_not_forgotten_by_earlier_bookings() {
        // Regression: experiments pre-book background transfers across the
        // whole horizon, then simulate flows from t = 0. Booking at a time
        // earlier than already-booked intervals must still see ALL of them
        // (a prune keyed to the caller's ready-time used to erase every
        // predecessor); only an explicit advance() retires history.
        let (mut net, id) = one_link();
        net.book(id, 0.0, 1e9); // [0, 1)
        net.book(id, 1.0, 1e9); // [1, 2) — used to prune [0, 1)
        net.book(id, 2.0, 1e9); // [2, 3) — used to prune [1, 2)
        // A flow from t = 0 spanning all three: β/2 over [0, 3) moves
        // 1.5e9, the remaining 1.5e9 alone -> end 4.5.
        let f = net.book(id, 0.0, 3e9);
        assert!((f.end - 4.5).abs() < 1e-12, "end {}", f.end);
        assert!((f.delay - 1.5).abs() < 1e-12, "delay {}", f.delay);
        // advance() is what retires history: once the clock passes them,
        // a fresh booking pays nothing.
        net.advance(10.0);
        let g = net.book(id, 10.0, 1e9);
        assert_eq!(g.delay, 0.0);
    }

    #[test]
    fn scope_registration_and_utilization() {
        let mut net = Interconnect::new();
        net.add_scope(3, 2, 200e9, 20e9);
        let nic = LinkId { scope: 3, node: 1, kind: LinkKind::Inter };
        let f = net.book(nic, 0.0, 20e9); // 1 second of NIC time
        assert_eq!(f.delay, 0.0);
        // 2 inter links, one busy for 1s over a 2s horizon -> 25%.
        assert!((net.utilization(LinkKind::Inter, 2.0) - 0.25).abs() < 1e-12);
        assert_eq!(net.utilization(LinkKind::Intra, 2.0), 0.0);
        assert_eq!(net.bytes_carried(LinkKind::Inter), 20e9);
        // Re-adding a scope keeps occupancy (idempotent).
        net.add_scope(3, 2, 200e9, 20e9);
        assert_eq!(net.bytes_carried(LinkKind::Inter), 20e9);
    }

    #[test]
    #[should_panic(expected = "undeclared link")]
    fn booking_undeclared_link_is_a_wiring_bug() {
        let mut net = Interconnect::new();
        net.book(LinkId { scope: 9, node: 0, kind: LinkKind::Intra }, 0.0, 1.0);
    }

    #[test]
    fn zero_byte_booking_is_free() {
        let (mut net, id) = one_link();
        let f = net.book(id, 1.0, 0.0);
        assert_eq!((f.end, f.ideal, f.delay), (1.0, 0.0, 0.0));
    }
}
