//! Discrete-event simulation substrate.
//!
//! Two pieces:
//!
//! - [`EventQueue`]: a time-ordered event heap with stable FIFO tie-breaking.
//!   Callers own the state machine and `match` on their payload type — no
//!   trait-object callbacks, so simulations stay plain, testable Rust. Used
//!   by the serving simulator (request arrivals / step completions) and the
//!   engine-level pipeline simulation.
//! - [`Server`]: a FIFO resource (a NIC, a link, a GPU's compute stream,
//!   a pipeline stage). `book(ready, dur)` returns the `[start, end)`
//!   occupancy interval respecting both the caller's readiness and the
//!   resource's queue — the building block for α-β link contention in the
//!   collective simulations.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled event.
struct Entry<T> {
    at: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap: earliest time first, then insertion order.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Min-time event queue; popping advances the simulation clock.
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    now: f64,
    seq: u64,
    popped: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, popped: 0 }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.popped
    }

    /// Schedule `payload` at absolute time `at` (must not be in the past).
    pub fn push(&mut self, at: f64, payload: T) {
        debug_assert!(at >= self.now - 1e-12, "event at {at} < now {}", self.now);
        self.heap.push(Entry { at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` `delay` seconds from now.
    pub fn push_in(&mut self, delay: f64, payload: T) {
        let at = self.now + delay;
        self.push(at, payload);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let e = self.heap.pop()?;
        debug_assert!(e.at >= self.now - 1e-12, "time went backwards");
        self.now = self.now.max(e.at);
        self.popped += 1;
        Some((self.now, e.payload))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// A FIFO resource with a single service lane (link, NIC, compute stream).
#[derive(Clone, Copy, Debug, Default)]
pub struct Server {
    next_free: f64,
    busy_total: f64,
}

impl Server {
    pub fn new() -> Self {
        Self::default()
    }

    /// Book `dur` seconds of service no earlier than `ready`.
    /// Returns the `(start, end)` interval granted.
    pub fn book(&mut self, ready: f64, dur: f64) -> (f64, f64) {
        debug_assert!(dur >= 0.0);
        let start = ready.max(self.next_free);
        let end = start + dur;
        self.next_free = end;
        self.busy_total += dur;
        (start, end)
    }

    /// When the resource next becomes idle.
    pub fn next_free(&self) -> f64 {
        self.next_free
    }

    /// Total busy time booked — used for utilization/idle accounting.
    pub fn busy_total(&self) -> f64 {
        self.busy_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_tie_break() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_monotonic() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.push(1.0, ());
        let mut last = 0.0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
            if t < 2.0 {
                q.push_in(0.5, ());
            }
        }
        // events: 1.0, then chained 1.5 and 2.0, then the original 5.0
        assert_eq!(q.processed(), 4);
    }

    #[test]
    fn server_fifo_queueing() {
        let mut s = Server::new();
        let (a0, a1) = s.book(0.0, 2.0);
        assert_eq!((a0, a1), (0.0, 2.0));
        // Request ready earlier than the server is free: queues.
        let (b0, b1) = s.book(1.0, 1.0);
        assert_eq!((b0, b1), (2.0, 3.0));
        // Request ready after the server frees: starts at readiness.
        let (c0, c1) = s.book(10.0, 0.5);
        assert_eq!((c0, c1), (10.0, 10.5));
        assert!((s.busy_total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn many_events_throughput_shape() {
        // Simulator invariant: N scheduled events all get processed.
        let mut q = EventQueue::new();
        for i in 0..10_000 {
            q.push((i % 97) as f64, i);
        }
        let mut n = 0;
        while q.pop().is_some() {
            n += 1;
        }
        assert_eq!(n, 10_000);
    }
}
