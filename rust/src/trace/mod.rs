//! Workload traces: BurstGPT-style arrival/length generation (Table 6,
//! Fig 17) and the synthetic decode-heavy trace (Appendix C.4.3).
//!
//! Arrivals follow the vLLM benchmark convention the paper uses: a target
//! request rate with Gamma-distributed inter-arrival gaps; *burstiness* 2.0
//! means the Gamma shape is `1/2` (coefficient of variation² = 2 — burstier
//! than Poisson), keeping the configured mean rate.

use crate::engine::batcher::Request;
use crate::util::rng::Rng;

/// Trace generation spec.
#[derive(Clone, Copy, Debug)]
pub struct TraceSpec {
    pub num_prompts: usize,
    /// Mean request rate (requests/second) — Table 6: 10 req/s.
    pub rate: f64,
    /// Burstiness (Gamma CV²); 1.0 = Poisson, 2.0 = Table 6.
    pub burstiness: f64,
    /// Time-varying multiplier on `rate` (fleet-autoscaling stimulus).
    pub shape: RateShape,
    /// Input-length distribution.
    pub input: LenDist,
    /// Output-length distribution.
    pub output: LenDist,
    pub seed: u64,
}

/// A time-varying request-rate multiplier. Real serving traffic is not
/// stationary — BurstGPT-style production traces ramp and follow diurnal
/// cycles — and a fleet autoscaler needs exactly that non-stationarity to
/// have anything to react to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RateShape {
    /// Constant configured rate (the paper's Table 6 setting).
    Flat,
    /// Linear ramp of the multiplier from `from` to `to` across the trace
    /// (by request index, so the shape is independent of the base rate).
    Ramp { from: f64, to: f64 },
    /// Diurnal-style sinusoid in *time*: `1 + amplitude·sin(2πt/period)`.
    Diurnal { period: f64, amplitude: f64 },
}

impl RateShape {
    /// Multiplier at trace progress `frac ∈ [0, 1]` and absolute time `t`.
    /// Clamped away from zero so inter-arrival gaps stay finite.
    pub fn multiplier(&self, frac: f64, t: f64) -> f64 {
        let m = match *self {
            RateShape::Flat => 1.0,
            RateShape::Ramp { from, to } => from + (to - from) * frac,
            RateShape::Diurnal { period, amplitude } => {
                1.0 + amplitude * (std::f64::consts::TAU * t / period.max(1e-9)).sin()
            }
        };
        m.max(0.05)
    }
}

/// A token-length distribution (log-normal, truncated).
#[derive(Clone, Copy, Debug)]
pub struct LenDist {
    /// Median length (exp of the underlying normal's mean).
    pub median: f64,
    /// Log-space sigma.
    pub sigma: f64,
    pub min: usize,
    pub max: usize,
}

impl LenDist {
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let v = rng.lognormal(self.median.ln(), self.sigma);
        (v.round() as usize).clamp(self.min, self.max)
    }

    /// Mean of the truncated log-normal, estimated by quick sampling.
    pub fn approx_mean(&self, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let n = 4000;
        (0..n).map(|_| self.sample(&mut rng) as f64).sum::<f64>() / n as f64
    }
}

impl TraceSpec {
    /// The paper's BurstGPT sample (Table 6 / Fig 17): 1,000 prompts,
    /// 10 req/s, burstiness 2.0; mixed short/long prompts with shorter
    /// outputs (Fig 17's distribution shape).
    pub fn burstgpt() -> Self {
        TraceSpec {
            num_prompts: 1000,
            rate: 10.0,
            burstiness: 2.0,
            shape: RateShape::Flat,
            input: LenDist { median: 550.0, sigma: 0.9, min: 16, max: 8192 },
            output: LenDist { median: 260.0, sigma: 0.5, min: 8, max: 1024 },
            seed: 0xB0257,
        }
    }

    /// Appendix C.4.3: randomly generated decode-heavy trace with mean
    /// input/output lengths of 1024 and 4096.
    pub fn decode_heavy() -> Self {
        TraceSpec {
            num_prompts: 1000,
            rate: 10.0,
            burstiness: 2.0,
            shape: RateShape::Flat,
            input: LenDist { median: 950.0, sigma: 0.4, min: 64, max: 4096 },
            output: LenDist { median: 3900.0, sigma: 0.3, min: 256, max: 8192 },
            seed: 0xDEC0DE,
        }
    }

    /// Long-prompt-heavy trace (chunked-prefill stimulus): a wide
    /// log-normal whose tail reaches 4x the default 8192-token step
    /// budget, with short-to-moderate outputs — the workload where
    /// whole-prompt admission either stalls or blocks every decode behind
    /// multi-10k-token prefill steps.
    pub fn long_prompt() -> Self {
        TraceSpec {
            num_prompts: 1000,
            rate: 4.0,
            burstiness: 2.0,
            shape: RateShape::Flat,
            input: LenDist { median: 3000.0, sigma: 1.1, min: 64, max: 32_768 },
            output: LenDist { median: 120.0, sigma: 0.6, min: 8, max: 1024 },
            seed: 0x10F6,
        }
    }

    /// Generate the request list (sorted by arrival time).
    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::new(self.seed);
        let shape = 1.0 / self.burstiness;
        let scale = (1.0 / self.rate) / shape; // keep the configured mean
        let denom = (self.num_prompts.max(2) - 1) as f64;
        let mut t = 0.0;
        let mut out = Vec::with_capacity(self.num_prompts);
        for id in 0..self.num_prompts as u64 {
            // Instantaneous rate = rate · multiplier: the sampled gap (mean
            // 1/rate) shrinks where the multiplier is high.
            let frac = id as f64 / denom;
            t += rng.gamma(shape, scale) / self.shape.multiplier(frac, t);
            out.push(Request {
                id,
                prompt_len: self.input.sample(&mut rng),
                decode_len: self.output.sample(&mut rng),
                arrival: t,
            });
        }
        out
    }

    /// Summary histogram of lengths (Fig 17 regeneration).
    pub fn length_histogram(&self, buckets: &[usize]) -> (Vec<usize>, Vec<usize>) {
        let reqs = self.generate();
        let mut hin = vec![0usize; buckets.len() + 1];
        let mut hout = vec![0usize; buckets.len() + 1];
        for r in &reqs {
            hin[bucket_of(r.prompt_len, buckets)] += 1;
            hout[bucket_of(r.decode_len, buckets)] += 1;
        }
        (hin, hout)
    }
}

fn bucket_of(v: usize, buckets: &[usize]) -> usize {
    buckets.iter().position(|&b| v <= b).unwrap_or(buckets.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_rate_matches_spec() {
        let spec = TraceSpec::burstgpt();
        let reqs = spec.generate();
        assert_eq!(reqs.len(), 1000);
        let span = reqs.last().unwrap().arrival - reqs[0].arrival;
        let rate = (reqs.len() - 1) as f64 / span;
        assert!((rate - 10.0).abs() < 1.5, "rate {rate}");
    }

    #[test]
    fn burstiness_raises_variance() {
        let mut poisson = TraceSpec::burstgpt();
        poisson.burstiness = 1.0;
        let bursty = TraceSpec::burstgpt();
        let cv2 = |reqs: &[Request]| {
            let gaps: Vec<f64> =
                reqs.windows(2).map(|w| w[1].arrival - w[0].arrival).collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let v = gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>() / gaps.len() as f64;
            v / (m * m)
        };
        let c_poisson = cv2(&poisson.generate());
        let c_bursty = cv2(&bursty.generate());
        assert!(c_bursty > 1.4 * c_poisson, "{c_poisson} vs {c_bursty}");
        assert!((c_bursty - 2.0).abs() < 0.6, "bursty CV² {c_bursty}");
    }

    #[test]
    fn arrivals_sorted_and_lengths_bounded() {
        let spec = TraceSpec::decode_heavy();
        let reqs = spec.generate();
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        for r in &reqs {
            assert!((64..=4096).contains(&r.prompt_len));
            assert!((256..=8192).contains(&r.decode_len));
        }
    }

    #[test]
    fn decode_heavy_means_match_appendix() {
        // C.4.3: mean input 1024, output 4096 (tolerances: sampled).
        let spec = TraceSpec::decode_heavy();
        let reqs = spec.generate();
        let mi = reqs.iter().map(|r| r.prompt_len).sum::<usize>() as f64 / reqs.len() as f64;
        let mo = reqs.iter().map(|r| r.decode_len).sum::<usize>() as f64 / reqs.len() as f64;
        assert!((mi - 1024.0).abs() < 150.0, "mean input {mi}");
        assert!((mo - 4096.0).abs() < 500.0, "mean output {mo}");
    }

    #[test]
    fn long_prompt_trace_reaches_past_the_step_budget() {
        let reqs = TraceSpec::long_prompt().generate();
        let longest = reqs.iter().map(|r| r.prompt_len).max().unwrap();
        assert!(longest > 8192, "tail must exceed the default step budget: {longest}");
        assert!(longest <= 32_768);
        assert!(reqs.iter().filter(|r| r.prompt_len > 8192).count() >= 10);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TraceSpec::burstgpt().generate();
        let b = TraceSpec::burstgpt().generate();
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.arrival == y.arrival
            && x.prompt_len == y.prompt_len
            && x.decode_len == y.decode_len));
    }

    #[test]
    fn lendist_sample_respects_truncation_bounds() {
        // A wide sigma pushes raw samples far outside [min, max]; every
        // returned length must still be clamped into the bounds.
        let d = LenDist { median: 500.0, sigma: 3.0, min: 32, max: 900 };
        let mut rng = Rng::new(99);
        let mut saw_min = false;
        let mut saw_max = false;
        for _ in 0..5000 {
            let v = d.sample(&mut rng);
            assert!((32..=900).contains(&v), "sample {v} out of bounds");
            saw_min |= v == 32;
            saw_max |= v == 900;
        }
        // With sigma 3 both tails must actually be hit (clamping active).
        assert!(saw_min && saw_max);
        // Degenerate distribution: min == max pins every sample.
        let pin = LenDist { median: 10.0, sigma: 1.0, min: 7, max: 7 };
        assert_eq!(pin.sample(&mut rng), 7);
    }

    #[test]
    fn ramp_shape_compresses_late_arrivals() {
        let mut flat = TraceSpec::burstgpt();
        flat.shape = RateShape::Flat;
        let mut ramp = TraceSpec::burstgpt();
        ramp.shape = RateShape::Ramp { from: 0.5, to: 4.0 };
        let half_span = |reqs: &[Request]| {
            let mid = reqs.len() / 2;
            let first = reqs[mid - 1].arrival - reqs[0].arrival;
            let second = reqs[reqs.len() - 1].arrival - reqs[mid].arrival;
            (first, second)
        };
        let (rf, rs) = half_span(&ramp.generate());
        assert!(rs < rf * 0.5, "late half should be much denser: {rf} vs {rs}");
        let (ff, fs) = half_span(&flat.generate());
        assert!(fs > ff * 0.5, "flat trace stays roughly uniform: {ff} vs {fs}");
    }

    #[test]
    fn diurnal_multiplier_oscillates_and_stays_positive() {
        let s = RateShape::Diurnal { period: 100.0, amplitude: 0.99 };
        let hi = s.multiplier(0.0, 25.0); // sin peak
        let lo = s.multiplier(0.0, 75.0); // sin trough
        assert!(hi > 1.9 && lo < 0.1);
        assert!(lo >= 0.05, "clamped away from zero");
        // Extreme amplitude never produces a non-positive multiplier.
        let s = RateShape::Diurnal { period: 10.0, amplitude: 5.0 };
        for i in 0..100 {
            assert!(s.multiplier(0.0, i as f64 * 0.1) >= 0.05);
        }
        assert_eq!(RateShape::Flat.multiplier(0.3, 42.0), 1.0);
    }

    #[test]
    fn histogram_covers_all() {
        let spec = TraceSpec::burstgpt();
        let (hin, hout) = spec.length_histogram(&[128, 512, 2048]);
        assert_eq!(hin.iter().sum::<usize>(), 1000);
        assert_eq!(hout.iter().sum::<usize>(), 1000);
    }
}
