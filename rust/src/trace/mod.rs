//! Workload traces: BurstGPT-style arrival/length generation (Table 6,
//! Fig 17) and the synthetic decode-heavy trace (Appendix C.4.3).
//!
//! Arrivals follow the vLLM benchmark convention the paper uses: a target
//! request rate with Gamma-distributed inter-arrival gaps; *burstiness* 2.0
//! means the Gamma shape is `1/2` (coefficient of variation² = 2 — burstier
//! than Poisson), keeping the configured mean rate.

use crate::engine::batcher::Request;
use crate::util::rng::Rng;

/// Trace generation spec.
#[derive(Clone, Copy, Debug)]
pub struct TraceSpec {
    pub num_prompts: usize,
    /// Mean request rate (requests/second) — Table 6: 10 req/s.
    pub rate: f64,
    /// Burstiness (Gamma CV²); 1.0 = Poisson, 2.0 = Table 6.
    pub burstiness: f64,
    /// Time-varying multiplier on `rate` (fleet-autoscaling stimulus).
    pub shape: RateShape,
    /// Input-length distribution.
    pub input: LenDist,
    /// Output-length distribution.
    pub output: LenDist,
    pub seed: u64,
}

/// A time-varying request-rate multiplier. Real serving traffic is not
/// stationary — BurstGPT-style production traces ramp and follow diurnal
/// cycles — and a fleet autoscaler needs exactly that non-stationarity to
/// have anything to react to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RateShape {
    /// Constant configured rate (the paper's Table 6 setting).
    Flat,
    /// Linear ramp of the multiplier from `from` to `to` across the trace
    /// (by request index, so the shape is independent of the base rate).
    Ramp { from: f64, to: f64 },
    /// Diurnal-style sinusoid in *time*: `1 + amplitude·sin(2πt/period)`.
    Diurnal { period: f64, amplitude: f64 },
}

impl RateShape {
    /// Multiplier at trace progress `frac ∈ [0, 1]` and absolute time `t`.
    /// Clamped away from zero so inter-arrival gaps stay finite.
    pub fn multiplier(&self, frac: f64, t: f64) -> f64 {
        let m = match *self {
            RateShape::Flat => 1.0,
            RateShape::Ramp { from, to } => from + (to - from) * frac,
            RateShape::Diurnal { period, amplitude } => {
                1.0 + amplitude * (std::f64::consts::TAU * t / period.max(1e-9)).sin()
            }
        };
        m.max(0.05)
    }
}

/// A token-length distribution (log-normal, truncated).
#[derive(Clone, Copy, Debug)]
pub struct LenDist {
    /// Median length (exp of the underlying normal's mean).
    pub median: f64,
    /// Log-space sigma.
    pub sigma: f64,
    pub min: usize,
    pub max: usize,
}

impl LenDist {
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let v = rng.lognormal(self.median.ln(), self.sigma);
        (v.round() as usize).clamp(self.min, self.max)
    }

    /// Mean of the truncated log-normal, estimated by quick sampling.
    pub fn approx_mean(&self, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let n = 4000;
        (0..n).map(|_| self.sample(&mut rng) as f64).sum::<f64>() / n as f64
    }
}

impl TraceSpec {
    /// The paper's BurstGPT sample (Table 6 / Fig 17): 1,000 prompts,
    /// 10 req/s, burstiness 2.0; mixed short/long prompts with shorter
    /// outputs (Fig 17's distribution shape).
    pub fn burstgpt() -> Self {
        TraceSpec {
            num_prompts: 1000,
            rate: 10.0,
            burstiness: 2.0,
            shape: RateShape::Flat,
            input: LenDist { median: 550.0, sigma: 0.9, min: 16, max: 8192 },
            output: LenDist { median: 260.0, sigma: 0.5, min: 8, max: 1024 },
            seed: 0xB0257,
        }
    }

    /// Appendix C.4.3: randomly generated decode-heavy trace with mean
    /// input/output lengths of 1024 and 4096.
    pub fn decode_heavy() -> Self {
        TraceSpec {
            num_prompts: 1000,
            rate: 10.0,
            burstiness: 2.0,
            shape: RateShape::Flat,
            input: LenDist { median: 950.0, sigma: 0.4, min: 64, max: 4096 },
            output: LenDist { median: 3900.0, sigma: 0.3, min: 256, max: 8192 },
            seed: 0xDEC0DE,
        }
    }

    /// Long-prompt-heavy trace (chunked-prefill stimulus): a wide
    /// log-normal whose tail reaches 4x the default 8192-token step
    /// budget, with short-to-moderate outputs — the workload where
    /// whole-prompt admission either stalls or blocks every decode behind
    /// multi-10k-token prefill steps.
    pub fn long_prompt() -> Self {
        TraceSpec {
            num_prompts: 1000,
            rate: 4.0,
            burstiness: 2.0,
            shape: RateShape::Flat,
            input: LenDist { median: 3000.0, sigma: 1.1, min: 64, max: 32_768 },
            output: LenDist { median: 120.0, sigma: 0.6, min: 8, max: 1024 },
            seed: 0x10F6,
        }
    }

    /// Overlay a diurnal rate cycle sized to the trace's expected span:
    /// `cycles` full sinusoid periods across the `num_prompts / rate`
    /// seconds the trace covers at its mean rate.
    pub fn with_diurnal_cycles(mut self, cycles: f64, amplitude: f64) -> Self {
        let span = self.num_prompts as f64 / self.rate.max(1e-9);
        self.shape = RateShape::Diurnal { period: span / cycles.max(1e-9), amplitude };
        self
    }

    /// Million-request soak workload (the `yalis soak` reference trace):
    /// chat-shaped lengths — short-to-moderate prompts, light outputs — at
    /// a fleet-scale arrival rate with a diurnal swing whose peaks push
    /// past a ~120-replica pool's capacity and whose troughs let it drain.
    pub fn soak(num_prompts: usize) -> Self {
        TraceSpec {
            num_prompts,
            rate: 600.0,
            burstiness: 2.0,
            shape: RateShape::Flat,
            input: LenDist { median: 700.0, sigma: 0.8, min: 32, max: 4096 },
            output: LenDist { median: 150.0, sigma: 0.5, min: 8, max: 512 },
            seed: 0x50AC,
        }
        .with_diurnal_cycles(2.0, 0.6)
    }

    /// Generate the request list (sorted by arrival time).
    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::new(self.seed);
        let shape = 1.0 / self.burstiness;
        let scale = (1.0 / self.rate) / shape; // keep the configured mean
        let denom = (self.num_prompts.max(2) - 1) as f64;
        let mut t = 0.0;
        let mut out = Vec::with_capacity(self.num_prompts);
        for id in 0..self.num_prompts as u64 {
            // Instantaneous rate = rate · multiplier: the sampled gap (mean
            // 1/rate) shrinks where the multiplier is high.
            let frac = id as f64 / denom;
            t += rng.gamma(shape, scale) / self.shape.multiplier(frac, t);
            out.push(Request {
                id,
                prompt_len: self.input.sample(&mut rng),
                decode_len: self.output.sample(&mut rng),
                arrival: t,
                // Single-shot prompts share nothing: each gets a session
                // of its own, so the prefix cache stays cold.
                session: Request::solo_session(id),
            });
        }
        out
    }

    /// Summary histogram of lengths (Fig 17 regeneration).
    pub fn length_histogram(&self, buckets: &[usize]) -> (Vec<usize>, Vec<usize>) {
        let reqs = self.generate();
        let mut hin = vec![0usize; buckets.len() + 1];
        let mut hout = vec![0usize; buckets.len() + 1];
        for r in &reqs {
            hin[bucket_of(r.prompt_len, buckets)] += 1;
            hout[bucket_of(r.decode_len, buckets)] += 1;
        }
        (hin, hout)
    }
}

fn bucket_of(v: usize, buckets: &[usize]) -> usize {
    buckets.iter().position(|&b| v <= b).unwrap_or(buckets.len())
}

/// Multi-turn conversation workload: `sessions` independent chats, each
/// running `turns` request turns. Turn k's prompt **is the whole
/// conversation so far** — turn k-1's prompt, its response, and fresh
/// `followup` user tokens — so consecutive turns of one session share a
/// growing page-aligned prefix. This is the workload where the
/// shared-prefix KV cache and session-affinity routing have something to
/// win; on `TraceSpec`'s single-shot traces every hit rate is zero by
/// construction.
#[derive(Clone, Copy, Debug)]
pub struct SessionSpec {
    /// Concurrent conversations.
    pub sessions: usize,
    /// Request turns per conversation.
    pub turns: usize,
    /// Opening prompt (system prompt + first user message) — the shared
    /// prefix every later turn of the session re-sends.
    pub first_prompt: LenDist,
    /// Fresh user tokens appended per later turn.
    pub followup: LenDist,
    /// Response length per turn.
    pub output: LenDist,
    /// Session arrival rate (sessions/s), Gamma inter-arrivals.
    pub rate: f64,
    /// Burstiness of session arrivals (Gamma CV²; 1.0 = Poisson).
    pub burstiness: f64,
    /// Mean think time between consecutive turns of a session
    /// (exponential). Set well above a turn's service time so the next
    /// turn usually arrives after the previous completed — i.e. after its
    /// pages were promoted into the prefix cache.
    pub think: f64,
    pub seed: u64,
}

impl SessionSpec {
    /// A chat-assistant-shaped default: ~1.5k-token openings, short
    /// follow-ups, six turns, 30 s of think time.
    pub fn standard() -> Self {
        SessionSpec {
            sessions: 100,
            turns: 6,
            first_prompt: LenDist { median: 1500.0, sigma: 0.6, min: 64, max: 8192 },
            followup: LenDist { median: 80.0, sigma: 0.6, min: 8, max: 512 },
            output: LenDist { median: 150.0, sigma: 0.5, min: 8, max: 512 },
            rate: 2.0,
            burstiness: 2.0,
            think: 30.0,
            seed: 0x5E55,
        }
    }

    /// Generate the multi-turn trace: globally sorted by arrival with
    /// dense ids 0..n (the fleet's indexing contract), each request
    /// carrying its session id.
    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::new(self.seed);
        let shape = 1.0 / self.burstiness;
        let scale = (1.0 / self.rate.max(1e-9)) / shape;
        let mut out = Vec::with_capacity(self.sessions * self.turns);
        let mut start = 0.0f64;
        for s in 0..self.sessions as u64 {
            start += rng.gamma(shape, scale);
            let mut t = start;
            let mut context = 0usize; // conversation tokens so far
            for turn in 0..self.turns {
                let fresh = if turn == 0 {
                    self.first_prompt.sample(&mut rng)
                } else {
                    self.followup.sample(&mut rng)
                };
                let prompt_len = context + fresh;
                let decode_len = self.output.sample(&mut rng);
                out.push(Request { id: 0, prompt_len, decode_len, arrival: t, session: s });
                context = prompt_len + decode_len;
                t += rng.exp(self.think);
            }
        }
        out.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for (i, r) in out.iter_mut().enumerate() {
            r.id = i as u64;
        }
        out
    }

}

/// Fraction of all prompt tokens in `reqs` (any generator's output, in
/// arrival order) that are conversation re-sends — the upper bound on
/// what prefix caching can save on the trace.
pub fn resend_fraction(reqs: &[Request]) -> f64 {
    let mut last_ctx: std::collections::BTreeMap<u64, usize> = std::collections::BTreeMap::new();
    let mut total = 0usize;
    let mut resend = 0usize;
    for r in reqs {
        total += r.prompt_len;
        resend += last_ctx.get(&r.session).copied().unwrap_or(0).min(r.prompt_len);
        last_ctx.insert(r.session, r.prompt_len + r.decode_len);
    }
    if total == 0 {
        0.0
    } else {
        resend as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_rate_matches_spec() {
        let spec = TraceSpec::burstgpt();
        let reqs = spec.generate();
        assert_eq!(reqs.len(), 1000);
        let span = reqs.last().unwrap().arrival - reqs[0].arrival;
        let rate = (reqs.len() - 1) as f64 / span;
        assert!((rate - 10.0).abs() < 1.5, "rate {rate}");
    }

    #[test]
    fn burstiness_raises_variance() {
        let mut poisson = TraceSpec::burstgpt();
        poisson.burstiness = 1.0;
        let bursty = TraceSpec::burstgpt();
        let cv2 = |reqs: &[Request]| {
            let gaps: Vec<f64> =
                reqs.windows(2).map(|w| w[1].arrival - w[0].arrival).collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let v = gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>() / gaps.len() as f64;
            v / (m * m)
        };
        let c_poisson = cv2(&poisson.generate());
        let c_bursty = cv2(&bursty.generate());
        assert!(c_bursty > 1.4 * c_poisson, "{c_poisson} vs {c_bursty}");
        assert!((c_bursty - 2.0).abs() < 0.6, "bursty CV² {c_bursty}");
    }

    #[test]
    fn arrivals_sorted_and_lengths_bounded() {
        let spec = TraceSpec::decode_heavy();
        let reqs = spec.generate();
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        for r in &reqs {
            assert!((64..=4096).contains(&r.prompt_len));
            assert!((256..=8192).contains(&r.decode_len));
        }
    }

    #[test]
    fn decode_heavy_means_match_appendix() {
        // C.4.3: mean input 1024, output 4096 (tolerances: sampled).
        let spec = TraceSpec::decode_heavy();
        let reqs = spec.generate();
        let mi = reqs.iter().map(|r| r.prompt_len).sum::<usize>() as f64 / reqs.len() as f64;
        let mo = reqs.iter().map(|r| r.decode_len).sum::<usize>() as f64 / reqs.len() as f64;
        assert!((mi - 1024.0).abs() < 150.0, "mean input {mi}");
        assert!((mo - 4096.0).abs() < 500.0, "mean output {mo}");
    }

    #[test]
    fn long_prompt_trace_reaches_past_the_step_budget() {
        let reqs = TraceSpec::long_prompt().generate();
        let longest = reqs.iter().map(|r| r.prompt_len).max().unwrap();
        assert!(longest > 8192, "tail must exceed the default step budget: {longest}");
        assert!(longest <= 32_768);
        assert!(reqs.iter().filter(|r| r.prompt_len > 8192).count() >= 10);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TraceSpec::burstgpt().generate();
        let b = TraceSpec::burstgpt().generate();
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.arrival == y.arrival
            && x.prompt_len == y.prompt_len
            && x.decode_len == y.decode_len));
    }

    #[test]
    fn lendist_sample_respects_truncation_bounds() {
        // A wide sigma pushes raw samples far outside [min, max]; every
        // returned length must still be clamped into the bounds.
        let d = LenDist { median: 500.0, sigma: 3.0, min: 32, max: 900 };
        let mut rng = Rng::new(99);
        let mut saw_min = false;
        let mut saw_max = false;
        for _ in 0..5000 {
            let v = d.sample(&mut rng);
            assert!((32..=900).contains(&v), "sample {v} out of bounds");
            saw_min |= v == 32;
            saw_max |= v == 900;
        }
        // With sigma 3 both tails must actually be hit (clamping active).
        assert!(saw_min && saw_max);
        // Degenerate distribution: min == max pins every sample.
        let pin = LenDist { median: 10.0, sigma: 1.0, min: 7, max: 7 };
        assert_eq!(pin.sample(&mut rng), 7);
    }

    #[test]
    fn ramp_shape_compresses_late_arrivals() {
        let mut flat = TraceSpec::burstgpt();
        flat.shape = RateShape::Flat;
        let mut ramp = TraceSpec::burstgpt();
        ramp.shape = RateShape::Ramp { from: 0.5, to: 4.0 };
        let half_span = |reqs: &[Request]| {
            let mid = reqs.len() / 2;
            let first = reqs[mid - 1].arrival - reqs[0].arrival;
            let second = reqs[reqs.len() - 1].arrival - reqs[mid].arrival;
            (first, second)
        };
        let (rf, rs) = half_span(&ramp.generate());
        assert!(rs < rf * 0.5, "late half should be much denser: {rf} vs {rs}");
        let (ff, fs) = half_span(&flat.generate());
        assert!(fs > ff * 0.5, "flat trace stays roughly uniform: {ff} vs {fs}");
    }

    #[test]
    fn diurnal_multiplier_oscillates_and_stays_positive() {
        let s = RateShape::Diurnal { period: 100.0, amplitude: 0.99 };
        let hi = s.multiplier(0.0, 25.0); // sin peak
        let lo = s.multiplier(0.0, 75.0); // sin trough
        assert!(hi > 1.9 && lo < 0.1);
        assert!(lo >= 0.05, "clamped away from zero");
        // Extreme amplitude never produces a non-positive multiplier.
        let s = RateShape::Diurnal { period: 10.0, amplitude: 5.0 };
        for i in 0..100 {
            assert!(s.multiplier(0.0, i as f64 * 0.1) >= 0.05);
        }
        assert_eq!(RateShape::Flat.multiplier(0.3, 42.0), 1.0);
    }

    #[test]
    fn session_trace_prompts_grow_within_a_session() {
        let mut spec = SessionSpec::standard();
        spec.sessions = 12;
        spec.turns = 5;
        let reqs = spec.generate();
        assert_eq!(reqs.len(), 12 * 5);
        // Dense ids in arrival order, arrivals sorted.
        for (i, w) in reqs.windows(2).enumerate() {
            assert!(w[1].arrival >= w[0].arrival);
            assert_eq!(w[0].id, i as u64);
        }
        // Per session: turn k's prompt strictly extends turn k-1's whole
        // context (prompt + response), in arrival order.
        for s in 0..12u64 {
            let turns: Vec<&Request> = reqs.iter().filter(|r| r.session == s).collect();
            assert_eq!(turns.len(), 5);
            for w in turns.windows(2) {
                assert!(w[1].arrival > w[0].arrival, "turns arrive in order");
                // The next prompt re-sends the whole prior context plus at
                // least the followup distribution's minimum fresh tokens.
                assert!(
                    w[1].prompt_len >= w[0].prompt_len + w[0].decode_len + 8,
                    "prompt must be the growing conversation: {} then {}",
                    w[0].prompt_len,
                    w[1].prompt_len
                );
            }
        }
        // The workload has something for a prefix cache to win.
        assert!(resend_fraction(&reqs) > 0.5, "{}", resend_fraction(&reqs));
        // Solo single-shot traces have nothing to re-send.
        assert_eq!(resend_fraction(&TraceSpec::burstgpt().generate()), 0.0);
    }

    #[test]
    fn session_trace_deterministic_and_solo_sessions_distinct() {
        let spec = SessionSpec::standard();
        let a = spec.generate();
        let b = spec.generate();
        assert!(a.iter().zip(&b).all(|(x, y)| {
            x.arrival == y.arrival
                && x.prompt_len == y.prompt_len
                && x.decode_len == y.decode_len
                && x.session == y.session
        }));
        // Solo sessions from TraceSpec never collide with chat sessions.
        let solo = TraceSpec::burstgpt().generate();
        for r in solo.iter().take(50) {
            assert_eq!(r.session, Request::solo_session(r.id));
            assert!(r.session >= (1 << 63));
        }
        assert!(a.iter().all(|r| r.session < (1 << 63)));
    }

    #[test]
    fn soak_trace_is_diurnal_and_scales_with_requests() {
        let spec = TraceSpec::soak(20_000);
        let reqs = spec.generate();
        assert_eq!(reqs.len(), 20_000);
        match spec.shape {
            RateShape::Diurnal { period, amplitude } => {
                // Two full cycles across the expected span at the mean rate.
                let span = 20_000.0 / spec.rate;
                assert!((period - span / 2.0).abs() < 1e-9, "period {period}");
                assert!(amplitude > 0.0);
            }
            other => panic!("soak trace must be diurnal, got {other:?}"),
        }
        // The swing must actually modulate density: the busiest tenth of
        // the trace is much denser than the quietest tenth.
        let n = reqs.len() / 10;
        let window_span = |i: usize| reqs[i + n - 1].arrival - reqs[i].arrival;
        let mut fastest = f64::INFINITY;
        let mut slowest = 0.0f64;
        for i in (0..reqs.len() - n).step_by(n) {
            let s = window_span(i);
            fastest = fastest.min(s);
            slowest = slowest.max(s);
        }
        assert!(slowest > 2.0 * fastest, "diurnal swing: {fastest} vs {slowest}");
        // Soak lengths stay light so 10M-request runs fit the budget.
        assert!(reqs.iter().all(|r| r.decode_len <= 512));
    }

    #[test]
    fn histogram_covers_all() {
        let spec = TraceSpec::burstgpt();
        let (hin, hout) = spec.length_histogram(&[128, 512, 2048]);
        assert_eq!(hin.iter().sum::<usize>(), 1000);
        assert_eq!(hout.iter().sum::<usize>(), 1000);
    }
}
