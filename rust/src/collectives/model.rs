//! Closed-form α-β communication models — the paper's Equations 1–6.
//!
//! Notation (§2.2): N nodes × G GPUs; intra-node (α_intra, β_intra),
//! inter-node (α_inter, β_inter); message |M| bytes.
//!
//! These are used (a) directly, to validate the event-level simulation in
//! the latency regime (integration tests assert sim ≈ model when chunking
//! and contention are disabled), and (b) to reproduce the §4.3 analysis.

use crate::cluster::Topology;

/// Exchange rounds of a recursive-doubling / binary-tree collective over
/// `n` participants: `ceil(log2 n)`. Non-power-of-two counts pay **whole**
/// rounds (N=6 runs 3 steps, not log2(6) ≈ 2.58 — the fractional-step bug
/// this replaces), matching the dissemination-style handling real
/// implementations use for ragged participant counts.
pub(crate) fn log2_steps(n: f64) -> f64 {
    n.log2().ceil().max(0.0)
}

/// Eq. (1) — NCCL Ring all-reduce: reduce-scatter + all-gather over a flat
/// ring; inter-node links dominate.
///
/// `T_ring = 2(NG-1)·α_inter + 2·((NG-1)/NG)·(|M|/β_inter)`
pub fn ring(t: &Topology, bytes: u64) -> f64 {
    let p = t.total_gpus() as f64;
    2.0 * (p - 1.0) * t.inter.alpha + 2.0 * ((p - 1.0) / p) * (bytes as f64 / t.inter.beta)
}

/// Eq. (2) — NCCL Tree all-reduce: reduce + broadcast over a double binary
/// tree inter-node and a chain intra-node.
///
/// `T_tree ≈ 2(G-1)·α_intra + 2·log2(N)·α_inter + 2·((N-1)/N)·(|M|/β_inter)`
pub fn tree(t: &Topology, bytes: u64) -> f64 {
    let (n, g) = (t.nodes as f64, t.gpus_per_node as f64);
    2.0 * (g - 1.0) * t.intra.alpha
        + 2.0 * log2_steps(n) * t.inter.alpha
        + 2.0 * ((n - 1.0) / n) * (bytes as f64 / t.inter.beta)
}

/// Flat recursive-doubling all-reduce (Thakur & Gropp) — the algorithm the
/// paper attributes MPI's small-message advantage to (§3.5): log2(P) steps,
/// each exchanging the full message with the XOR peer.
pub fn recursive_doubling_flat(t: &Topology, bytes: u64) -> f64 {
    let p = t.total_gpus() as f64;
    let steps = log2_steps(p);
    steps * (t.inter.alpha + bytes as f64 / t.inter.beta)
}

/// Eq. (3) — NVRAR phase 1: intra-node ring reduce-scatter.
///
/// `T_RS = (G-1)·α_intra + ((G-1)/G)·(|M|/β_intra)`
pub fn nvrar_reduce_scatter(t: &Topology, bytes: u64) -> f64 {
    let g = t.gpus_per_node as f64;
    (g - 1.0) * t.intra.alpha + ((g - 1.0) / g) * (bytes as f64 / t.intra.beta)
}

/// Eq. (4) — NVRAR phase 2: inter-node recursive doubling on |M|/G bytes,
/// with LL payload inflation 1 < η ≤ 2.
///
/// `T_RD = log2(N)·α_inter + ((N-1)/N)·(η|M| / (G·β_inter))`
pub fn nvrar_recursive_doubling(t: &Topology, bytes: u64, eta: f64) -> f64 {
    let (n, g) = (t.nodes as f64, t.gpus_per_node as f64);
    log2_steps(n) * t.inter.alpha + ((n - 1.0) / n) * (eta * bytes as f64 / (g * t.inter.beta))
}

/// Eq. (5) — NVRAR phase 3: intra-node ring all-gather (same cost as RS).
pub fn nvrar_all_gather(t: &Topology, bytes: u64) -> f64 {
    nvrar_reduce_scatter(t, bytes)
}

/// Eq. (6) — total NVRAR time: RS + RD + AG.
///
/// `T = 2(G-1)·α_intra + log2(N)·α_inter
///      + (|M|/G)·[2(G-1)/β_intra + (N-1)η/(N·β_inter)]`
pub fn nvrar(t: &Topology, bytes: u64, eta: f64) -> f64 {
    nvrar_reduce_scatter(t, bytes) + nvrar_recursive_doubling(t, bytes, eta)
        + nvrar_all_gather(t, bytes)
}

/// Latency (α-only) coefficients — used in §4.3's scaling argument:
/// Ring is linear in N·G; Tree pays 2·log2(N) inter hops; NVRAR pays
/// log2(N).
pub fn latency_terms(t: &Topology) -> (f64, f64, f64) {
    let (n, g) = (t.nodes as f64, t.gpus_per_node as f64);
    let ring = 2.0 * (n * g - 1.0) * t.inter.alpha;
    let tree = 2.0 * (g - 1.0) * t.intra.alpha + 2.0 * log2_steps(n) * t.inter.alpha;
    let nvrar = 2.0 * (g - 1.0) * t.intra.alpha + log2_steps(n) * t.inter.alpha;
    (ring, tree, nvrar)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;

    fn topo() -> Topology {
        presets::perlmutter(8) // 32 GPUs
    }

    #[test]
    fn ring_linear_tree_log_in_nodes() {
        let bytes = 256 * 1024;
        let t4 = presets::perlmutter(4);
        let t16 = presets::perlmutter(16);
        // Ring latency term grows ~4x from 4->16 nodes; tree only +2 hops.
        let ring_ratio = ring(&t16, bytes) / ring(&t4, bytes);
        let tree_ratio = tree(&t16, bytes) / tree(&t4, bytes);
        assert!(ring_ratio > 3.0, "ring ratio {ring_ratio}");
        assert!(tree_ratio < 2.0, "tree ratio {tree_ratio}");
    }

    #[test]
    fn nvrar_beats_tree_latency_coefficient() {
        // §4.3: same log scaling, lower inter-node coefficient.
        let (_, t_tree, t_nvrar) = latency_terms(&topo());
        assert!(t_nvrar < t_tree);
    }

    #[test]
    fn nvrar_total_is_sum_of_phases() {
        let t = topo();
        let b = 1024 * 1024;
        let total = nvrar(&t, b, 2.0);
        let sum = nvrar_reduce_scatter(&t, b)
            + nvrar_recursive_doubling(&t, b, 2.0)
            + nvrar_all_gather(&t, b);
        assert!((total - sum).abs() < 1e-15);
    }

    #[test]
    fn eta_inflates_only_bandwidth_term() {
        let t = topo();
        let b = 4 * 1024 * 1024;
        let lo = nvrar(&t, b, 1.0);
        let hi = nvrar(&t, b, 2.0);
        assert!(hi > lo);
        // Difference is exactly the extra bandwidth term.
        let expected =
            ((t.nodes as f64 - 1.0) / t.nodes as f64) * (b as f64 / (t.gpus_per_node as f64 * t.inter.beta));
        assert!((hi - lo - expected).abs() / expected < 1e-9);
    }

    #[test]
    fn non_power_of_two_node_counts_pay_whole_exchange_rounds() {
        // The fractional-step bug: N=6 used to pay log2(6) ≈ 2.58 inter
        // hops. Recursive doubling and trees run whole rounds: N=6 → 3,
        // N=12 → 4. Pin the closed forms exactly.
        let bytes = 256 * 1024u64;
        for (nodes, steps) in [(6usize, 3.0f64), (12, 4.0)] {
            let t = presets::perlmutter(nodes);
            let n = nodes as f64;
            let g = t.gpus_per_node as f64;
            let tree_expected = 2.0 * (g - 1.0) * t.intra.alpha
                + 2.0 * steps * t.inter.alpha
                + 2.0 * ((n - 1.0) / n) * (bytes as f64 / t.inter.beta);
            assert!(
                (tree(&t, bytes) - tree_expected).abs() < 1e-15,
                "tree N={nodes}"
            );
            let rd_expected = steps * t.inter.alpha
                + ((n - 1.0) / n) * (2.0 * bytes as f64 / (g * t.inter.beta));
            assert!(
                (nvrar_recursive_doubling(&t, bytes, 2.0) - rd_expected).abs() < 1e-15,
                "nvrar RD N={nodes}"
            );
        }
        // Flat RD counts GPUs: 6 nodes × 4 GPUs = 24 → ceil(log2 24) = 5.
        let t6 = presets::perlmutter(6);
        let rd_flat_expected =
            5.0 * (t6.inter.alpha + bytes as f64 / t6.inter.beta);
        assert!((recursive_doubling_flat(&t6, bytes) - rd_flat_expected).abs() < 1e-15);
        // Monotonic in whole steps: N=6 pays the same latency rounds as
        // N=8, strictly more than N=4.
        let a4 = latency_terms(&presets::perlmutter(4)).2;
        let a6 = latency_terms(&presets::perlmutter(6)).2;
        let a8 = latency_terms(&presets::perlmutter(8)).2;
        assert!(a6 > a4);
        assert!((a6 - a8).abs() < 1e-15);
    }

    #[test]
    fn rd_flat_matches_tree_shape_but_single_exchange() {
        // For G=1 (Vista-like), tree ≈ 2·log2(N)·α + bw, RD ≈ log2(N)·α + bw:
        // RD's latency term is half the tree's.
        let t = presets::vista(16);
        let small = 1024; // latency dominated
        assert!(recursive_doubling_flat(&t, small) < tree(&t, small));
    }

    #[test]
    fn large_messages_favor_ring_bandwidth() {
        // Ring's bandwidth term ~ |M|; tree's ~ |M| too but ring wins at
        // scale on pure-bandwidth when α negligible... verify crossover
        // exists: at tiny messages tree < ring; ring latency term explodes.
        let t = topo();
        assert!(tree(&t, 1024) < ring(&t, 1024));
    }

    #[test]
    fn vista_nvrar_has_no_intra_cost() {
        let t = presets::vista(8);
        let b = 512 * 1024;
        let total = nvrar(&t, b, 2.0);
        let rd_only = nvrar_recursive_doubling(&t, b, 2.0);
        assert!((total - rd_only).abs() < 1e-15);
    }
}
