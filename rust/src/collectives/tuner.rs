//! NVRAR hyperparameter auto-tuner — the paper's stated future work
//! ("We leave heuristic-based hyperparameter tuning to future work",
//! Appendix C.1).
//!
//! Table 5 shows NVRAR's latency is sensitive to the thread-block count
//! B_s and chunk size C_s, and the best setting depends on message size
//! and node count. [`tune`] grid-searches the event-level simulation once
//! per (topology, message size) and [`TunedTable`] caches the result per
//! size bucket so an engine can pick tuned parameters per all-reduce call
//! at zero cost on the hot path.

use super::sim::{nvrar, CommConfig};
use crate::cluster::Topology;

/// Search space: powers of two around the paper's Table 5 values.
const BLOCK_CANDIDATES: [usize; 5] = [4, 8, 16, 32, 64];
const CHUNK_CANDIDATES: [u64; 6] =
    [4 * 1024, 8 * 1024, 16 * 1024, 32 * 1024, 64 * 1024, 128 * 1024];

/// One tuned configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Tuned {
    pub block_count: usize,
    pub chunk_bytes: u64,
    /// Predicted all-reduce time with these parameters (s).
    pub predicted: f64,
}

/// Grid-search B_s × C_s for one (topology, message size).
pub fn tune(topo: &Topology, base: &CommConfig, bytes: u64) -> Tuned {
    let mut best = Tuned { block_count: base.block_count, chunk_bytes: base.chunk_bytes, predicted: f64::INFINITY };
    for &bs in &BLOCK_CANDIDATES {
        for &cs in &CHUNK_CANDIDATES {
            let mut c = *base;
            c.block_count = bs;
            c.chunk_bytes = cs;
            let t = nvrar(topo, &c, bytes, 0.0).total;
            if t < best.predicted {
                best = Tuned { block_count: bs, chunk_bytes: cs, predicted: t };
            }
        }
    }
    best
}

/// Pre-tuned table over power-of-two size buckets (the engine integration:
/// tune once per deployment, look up per call).
#[derive(Clone, Debug)]
pub struct TunedTable {
    /// (max message bytes of bucket, tuned params).
    buckets: Vec<(u64, Tuned)>,
}

impl TunedTable {
    /// Tune buckets from 32 KB to 8 MB for a deployment.
    pub fn build(topo: &Topology, base: &CommConfig) -> Self {
        let mut buckets = Vec::new();
        let mut size = 32 * 1024u64;
        while size <= 8 * 1024 * 1024 {
            buckets.push((size, tune(topo, base, size)));
            size *= 2;
        }
        TunedTable { buckets }
    }

    /// Tuned parameters for a message of `bytes` (clamps to the largest
    /// bucket above 8 MB).
    pub fn lookup(&self, bytes: u64) -> Tuned {
        for (cap, t) in &self.buckets {
            if bytes <= *cap {
                return *t;
            }
        }
        self.buckets.last().expect("non-empty").1
    }

    /// Apply the tuned parameters for `bytes` onto a CommConfig.
    pub fn apply(&self, base: &CommConfig, bytes: u64) -> CommConfig {
        let t = self.lookup(bytes);
        let mut c = *base;
        c.block_count = t.block_count;
        c.chunk_bytes = t.chunk_bytes;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;

    #[test]
    fn tuned_never_worse_than_default() {
        let topo = presets::perlmutter(4);
        let base = CommConfig::perlmutter();
        for kb in [64u64, 256, 1024, 4096] {
            let bytes = kb * 1024;
            let default_t = nvrar(&topo, &base, bytes, 0.0).total;
            let tuned = tune(&topo, &base, bytes);
            assert!(
                tuned.predicted <= default_t * (1.0 + 1e-9),
                "{kb}KB: tuned {} vs default {default_t}",
                tuned.predicted
            );
        }
    }

    #[test]
    fn tuned_params_in_search_space() {
        let topo = presets::vista(8);
        let t = tune(&topo, &CommConfig::vista(), 512 * 1024);
        assert!(BLOCK_CANDIDATES.contains(&t.block_count));
        assert!(CHUNK_CANDIDATES.contains(&t.chunk_bytes));
        assert!(t.predicted.is_finite() && t.predicted > 0.0);
    }

    #[test]
    fn table_lookup_monotone_buckets() {
        let topo = presets::perlmutter(8);
        let base = CommConfig::perlmutter();
        let table = TunedTable::build(&topo, &base);
        // Lookup picks the right bucket and clamps above the top.
        let small = table.lookup(40 * 1024);
        let big = table.lookup(64 * 1024 * 1024);
        assert_eq!(big, table.buckets.last().unwrap().1);
        assert!(small.predicted <= big.predicted);
    }

    #[test]
    fn apply_improves_sim_time() {
        let topo = presets::perlmutter(8);
        let base = CommConfig::perlmutter();
        let table = TunedTable::build(&topo, &base);
        for kb in [128u64, 1024] {
            let bytes = kb * 1024;
            let tuned_cfg = table.apply(&base, bytes);
            let t_tuned = nvrar(&topo, &tuned_cfg, bytes, 0.0).total;
            let t_base = nvrar(&topo, &base, bytes, 0.0).total;
            assert!(t_tuned <= t_base * (1.0 + 1e-9));
        }
    }

    #[test]
    fn large_messages_prefer_larger_chunks() {
        // The Table 5 intuition: per-put overhead penalizes tiny chunks on
        // big messages.
        let topo = presets::perlmutter(4);
        let t_big = tune(&topo, &CommConfig::perlmutter(), 4 * 1024 * 1024);
        assert!(t_big.chunk_bytes >= 16 * 1024, "got {}", t_big.chunk_bytes);
    }
}
