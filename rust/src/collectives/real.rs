//! **Real** all-reduce implementations over the [`crate::shmem`] PGAS
//! substrate — Algorithm 1 of the paper, executed by one thread per PE,
//! bitwise-verifiable against a serial sum.
//!
//! [`Algo::Nvrar`] follows Algorithm 1 step by step:
//!
//! 1. *intra-node ring reduce-scatter* (the paper delegates this phase to
//!    NCCL's host API; we run it on the same LL substrate),
//! 2. *inter-node recursive doubling*: `log2(N)` steps; at step `ℓ`,
//!    GPU `(r_n, r_g)` exchanges its segment with `(r_n ⊕ 2^ℓ, r_g)` using
//!    chunked non-blocking puts of fused 8 B (data, flag) payloads
//!    (§4.2.1–4.2.2) into **per-step receive buffers**, reducing each chunk
//!    as it lands,
//! 3. *intra-node ring all-gather*.
//!
//! Sequence numbers (§4.2.3): every all-reduce round carries `seq`; each PE
//! announces its `seq` and waits — peer-wise, not globally — for every PE
//! it will *put into* to have reached the same round before sending. This
//! is what makes buffer reuse across back-to-back all-reduces safe, and the
//! property tests hammer exactly that.
//!
//! Baselines ([`Algo::Ring`], [`Algo::RdFlat`], [`Algo::Central`]) share the
//! substrate so the hot-path bench compares algorithms, not plumbing.

use crate::shmem::{Pe, World};
use std::sync::Mutex;

/// Which real algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Algorithm 1: hierarchical RS → recursive doubling → AG.
    Nvrar,
    /// Flat ring reduce-scatter + all-gather over all P PEs (NCCL Ring).
    Ring,
    /// Flat recursive doubling over all P PEs (MPI-style).
    RdFlat,
    /// Binary-tree reduce + broadcast (NCCL Tree's skeleton).
    Tree,
    /// Rabenseifner: recursive-halving reduce-scatter + recursive-doubling
    /// all-gather — the bandwidth-optimal log-latency baseline
    /// (Thakur & Gropp).
    Rabenseifner,
    /// Naive: PE 0 gathers, reduces, broadcasts (correctness yardstick).
    Central,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Nvrar => "nvrar",
            Algo::Ring => "ring",
            Algo::RdFlat => "rd-flat",
            Algo::Tree => "tree",
            Algo::Rabenseifner => "rabenseifner",
            Algo::Central => "central",
        }
    }

    pub fn all() -> [Algo; 6] {
        [Algo::Nvrar, Algo::Ring, Algo::RdFlat, Algo::Tree, Algo::Rabenseifner, Algo::Central]
    }
}

/// Harness for running `rounds` back-to-back all-reduces on an N×G world.
#[derive(Clone, Copy, Debug)]
pub struct Harness {
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub n_elems: usize,
    /// C_s in words (f32 elements per chunked put).
    pub chunk_words: usize,
    pub algo: Algo,
}

impl Harness {
    pub fn pes(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    fn padded(&self) -> usize {
        let p = self.pes().max(1);
        self.n_elems.div_ceil(p.max(1)).max(1) * p
    }

    /// Heap words needed per PE for the chosen algorithm.
    fn heap_words(&self) -> usize {
        let p = self.pes();
        let n_pad = self.padded();
        match self.algo {
            Algo::Nvrar => {
                let g = self.gpus_per_node;
                let seg = n_pad / g;
                let rd_steps = log2(self.nodes);
                (2 * g.saturating_sub(1) + rd_steps) * seg + 1
            }
            Algo::Ring => 2 * p.saturating_sub(1) * (n_pad / p) + 1,
            Algo::RdFlat => log2(p) * n_pad + 1,
            // Tree: two child slots for the reduce + one broadcast slot.
            Algo::Tree => 3 * n_pad + 1,
            // Rabenseifner: a full-width buffer PER halving step (the
            // nested windows are written by different peers, so a fast
            // peer's step ℓ+1 put must not share words with a slow
            // receiver's unread step ℓ data) + one all-gather region.
            Algo::Rabenseifner => (log2(p) + 1) * n_pad + 2,
            Algo::Central => (p + 1) * n_pad + 1,
        }
    }

    /// Run `rounds` consecutive all-reduces. `input(pe, round)` supplies
    /// each PE's contribution; returns `out[round][pe]` result vectors.
    ///
    /// Every PE's result for a round must equal the elementwise sum of all
    /// PEs' inputs for that round (tests assert this for every algorithm).
    pub fn run_rounds<F>(&self, rounds: usize, input: F) -> Vec<Vec<Vec<f32>>>
    where
        F: Fn(usize, usize) -> Vec<f32> + Sync,
    {
        let p = self.pes();
        assert!(p >= 1);
        if matches!(self.algo, Algo::Nvrar | Algo::RdFlat) {
            assert!(self.nodes.is_power_of_two(), "recursive doubling needs power-of-two nodes");
        }
        if matches!(self.algo, Algo::RdFlat | Algo::Rabenseifner) {
            assert!(p.is_power_of_two(), "{:?} needs power-of-two PEs", self.algo);
        }
        let world = World::new(p, self.heap_words());
        let results: Vec<Vec<Mutex<Vec<f32>>>> = (0..rounds)
            .map(|_| (0..p).map(|_| Mutex::new(Vec::new())).collect())
            .collect();

        world.run(|pe| {
            for round in 0..rounds {
                let seq = (round + 1) as u64;
                let mut x = input(pe.id, round);
                assert_eq!(x.len(), self.n_elems, "input length mismatch");
                x.resize(self.padded(), 0.0);
                match self.algo {
                    Algo::Nvrar => self.nvrar_once(&pe, seq, &mut x),
                    Algo::Ring => self.ring_once(&pe, seq, &mut x),
                    Algo::RdFlat => self.rd_flat_once(&pe, seq, &mut x),
                    Algo::Tree => self.tree_once(&pe, seq, &mut x),
                    Algo::Rabenseifner => self.rabenseifner_once(&pe, seq, &mut x),
                    Algo::Central => self.central_once(&pe, seq, &mut x),
                }
                x.truncate(self.n_elems);
                *results[round][pe.id].lock().unwrap() = x;
            }
        });

        results
            .into_iter()
            .map(|row| row.into_iter().map(|m| m.into_inner().unwrap()).collect())
            .collect()
    }

    /// Convenience: one round.
    pub fn run_once<F>(&self, input: F) -> Vec<Vec<f32>>
    where
        F: Fn(usize) -> Vec<f32> + Sync,
    {
        self.run_rounds(1, |pe, _| input(pe)).remove(0)
    }

    // ---------------------------------------------------------------------
    // NVRAR — Algorithm 1
    // ---------------------------------------------------------------------

    fn nvrar_once(&self, pe: &Pe<'_>, seq: u64, x: &mut [f32]) {
        let g = self.gpus_per_node;
        let n = self.nodes;
        let (rn, rg) = (pe.id / g, pe.id % g);
        let n_pad = x.len();
        let seg = n_pad / g;
        let rd_steps = log2(n);
        // Heap layout per PE: [rs_recv (G-1)·seg][rd_recv steps·seg][ag_recv (G-1)·seg]
        let rs_off = 0;
        let rd_off = rs_off + g.saturating_sub(1) * seg;
        let ag_off = rd_off + rd_steps * seg;

        // --- sequence sync (Alg. 1 lines 3–6): peer-wise, before any put.
        pe.announce_seq(seq);
        if g > 1 {
            pe.wait_peer_seq(rn * g + (rg + 1) % g, seq); // ring right neighbour
        }
        for l in 0..rd_steps {
            pe.wait_peer_seq((rn ^ (1 << l)) * g + rg, seq);
        }

        // --- Phase 1: intra-node ring reduce-scatter (Alg. 1 line 2).
        if g > 1 {
            let right = rn * g + (rg + 1) % g;
            for s in 0..g - 1 {
                let send_chunk = (rg + g - s) % g;
                let recv_chunk = (rg + g - s - 1) % g;
                put_f32(pe, right, rs_off + s * seg, &x[send_chunk * seg..(send_chunk + 1) * seg], seq as u32);
                wait_add_f32(pe, rs_off + s * seg, &mut x[recv_chunk * seg..(recv_chunk + 1) * seg], seq as u32);
            }
        }
        let owned = (rg + 1) % g;

        // --- Phase 2: inter-node recursive doubling (Alg. 1 RD_inter).
        if n > 1 {
            // m: this PE's reduced segment (whole message when G == 1).
            let mut m: Vec<f32> = x[owned * seg..(owned + 1) * seg].to_vec();
            let cw = self.chunk_words.max(1);
            for l in 0..rd_steps {
                let peer = (rn ^ (1 << l)) * g + rg;
                // Non-blocking chunked sends (lines 16–18): issue all puts.
                let mut off = 0;
                while off < seg {
                    let end = (off + cw).min(seg);
                    put_f32(pe, peer, rd_off + l * seg + off, &m[off..end], seq as u32);
                    off = end;
                }
                // Receive + reduce chunk-by-chunk (lines 19–20).
                wait_add_f32(pe, rd_off + l * seg, &mut m, seq as u32);
            }
            x[owned * seg..(owned + 1) * seg].copy_from_slice(&m);
        }

        // --- Phase 3: intra-node ring all-gather (Alg. 1 line 11).
        if g > 1 {
            let right = rn * g + (rg + 1) % g;
            for s in 0..g - 1 {
                let send_seg = (rg + 1 + g - s) % g;
                let recv_seg = (rg + g - s) % g;
                put_f32(pe, right, ag_off + s * seg, &x[send_seg * seg..(send_seg + 1) * seg], seq as u32);
                wait_copy_f32(pe, ag_off + s * seg, &mut x[recv_seg * seg..(recv_seg + 1) * seg], seq as u32);
            }
        }
    }

    // ---------------------------------------------------------------------
    // Baselines
    // ---------------------------------------------------------------------

    /// Flat ring: reduce-scatter + all-gather over all P PEs (what NCCL
    /// Ring does, minus topology-aware ordering).
    fn ring_once(&self, pe: &Pe<'_>, seq: u64, x: &mut [f32]) {
        let p = self.pes();
        if p == 1 {
            return;
        }
        let n_pad = x.len();
        let seg = n_pad / p;
        let rs_off = 0;
        let ag_off = (p - 1) * seg;
        let me = pe.id;
        let right = (me + 1) % p;

        pe.announce_seq(seq);
        pe.wait_peer_seq(right, seq);

        for s in 0..p - 1 {
            let send_chunk = (me + p - s) % p;
            let recv_chunk = (me + p - s - 1) % p;
            put_f32(pe, right, rs_off + s * seg, &x[send_chunk * seg..(send_chunk + 1) * seg], seq as u32);
            wait_add_f32(pe, rs_off + s * seg, &mut x[recv_chunk * seg..(recv_chunk + 1) * seg], seq as u32);
        }
        for s in 0..p - 1 {
            let send_seg = (me + 1 + p - s) % p;
            let recv_seg = (me + p - s) % p;
            put_f32(pe, right, ag_off + s * seg, &x[send_seg * seg..(send_seg + 1) * seg], seq as u32);
            wait_copy_f32(pe, ag_off + s * seg, &mut x[recv_seg * seg..(recv_seg + 1) * seg], seq as u32);
        }
    }

    /// Flat recursive doubling: log2(P) full-message pairwise exchanges.
    fn rd_flat_once(&self, pe: &Pe<'_>, seq: u64, x: &mut [f32]) {
        let p = self.pes();
        let n_pad = x.len();
        let steps = log2(p);
        pe.announce_seq(seq);
        for l in 0..steps {
            pe.wait_peer_seq(pe.id ^ (1 << l), seq);
        }
        let cw = self.chunk_words.max(1);
        for l in 0..steps {
            let peer = pe.id ^ (1 << l);
            let mut off = 0;
            while off < n_pad {
                let end = (off + cw).min(n_pad);
                put_f32(pe, peer, l * n_pad + off, &x[off..end], seq as u32);
                off = end;
            }
            wait_add_f32(pe, l * n_pad, x, seq as u32);
        }
    }

    /// Binary-tree reduce to PE 0, then tree broadcast — the skeleton of
    /// NCCL's Tree algorithm (single tree; NCCL runs two interleaved).
    /// Works for any PE count.
    fn tree_once(&self, pe: &Pe<'_>, seq: u64, x: &mut [f32]) {
        let p = self.pes();
        if p == 1 {
            return;
        }
        let n_pad = x.len();
        let me = pe.id;
        let parent = (me.wrapping_sub(1)) / 2;
        let (c0, c1) = (2 * me + 1, 2 * me + 2);
        // Heap layout: child slot 0 [0, n), child slot 1 [n, 2n),
        // broadcast slot [2n, 3n).
        pe.announce_seq(seq);
        // Everyone we put into must have reached this round.
        if me != 0 {
            pe.wait_peer_seq(parent, seq);
        }
        if c0 < p {
            pe.wait_peer_seq(c0, seq);
        }
        if c1 < p {
            pe.wait_peer_seq(c1, seq);
        }
        // Reduce up: wait for children, add, send to parent.
        if c0 < p {
            wait_add_f32(pe, 0, x, seq as u32);
        }
        if c1 < p {
            wait_add_f32(pe, n_pad, x, seq as u32);
        }
        if me != 0 {
            let slot = if me % 2 == 1 { 0 } else { n_pad };
            put_f32(pe, parent, slot, x, seq as u32);
            // Broadcast down: wait for the result from the parent.
            wait_copy_f32(pe, 2 * n_pad, x, seq as u32);
        }
        for c in [c0, c1] {
            if c < p {
                put_f32(pe, c, 2 * n_pad, x, seq as u32);
            }
        }
    }

    /// Rabenseifner's all-reduce: recursive-halving reduce-scatter, then
    /// recursive-doubling all-gather. Bandwidth-optimal (2·(P-1)/P·|M|)
    /// with log2(P) latency — the canonical large-message algorithm the
    /// small-message-optimal flat RD trades against.
    fn rabenseifner_once(&self, pe: &Pe<'_>, seq: u64, x: &mut [f32]) {
        let p = self.pes();
        if p == 1 {
            return;
        }
        let n_pad = x.len();
        let steps = log2(p);
        let me = pe.id;
        // Heap layout: one full-width RS buffer per step at [ℓ·n, (ℓ+1)·n)
        // — steps are served by DIFFERENT peers, so sharing the nested
        // window across steps would let a fast peer's step ℓ+1 put clobber
        // a slow receiver's unread step ℓ words (a deadlock the property
        // tests caught). AG recv at [steps·n, (steps+1)·n): each word is
        // written exactly once per round, so one region suffices.
        pe.announce_seq(seq);
        for l in 0..steps {
            pe.wait_peer_seq(me ^ (1 << l), seq);
        }
        // Recursive halving: at step ℓ the active window halves; we keep
        // the half containing our rank and send the other half into the
        // peer's step-ℓ buffer.
        let (mut lo, mut hi) = (0usize, n_pad); // our live window in elements
        for l in 0..steps {
            let peer = me ^ (1 << l);
            let mid = lo + (hi - lo) / 2;
            let keep_low = me & (1 << l) == 0;
            let (send_a, send_b, keep_a, keep_b) = if keep_low {
                (mid, hi, lo, mid)
            } else {
                (lo, mid, mid, hi)
            };
            put_f32(pe, peer, l * n_pad + send_a, &x[send_a..send_b], seq as u32);
            wait_add_f32(pe, l * n_pad + keep_a, &mut x[keep_a..keep_b], seq as u32);
            lo = keep_a;
            hi = keep_b;
        }
        // x[lo..hi] now holds this rank's fully-reduced segment.
        // Recursive doubling all-gather: windows merge back, reversed.
        let ag = steps * n_pad;
        for l in (0..steps).rev() {
            let peer = me ^ (1 << l);
            let span = hi - lo;
            let keep_low = me & (1 << l) == 0;
            let (peer_lo, peer_hi) = if keep_low { (hi, hi + span) } else { (lo - span, lo) };
            put_f32(pe, peer, ag + lo, &x[lo..hi], seq as u32);
            wait_copy_f32(pe, ag + peer_lo, &mut x[peer_lo..peer_hi], seq as u32);
            lo = lo.min(peer_lo);
            hi = hi.max(peer_hi);
        }
        debug_assert!(lo == 0 && hi == n_pad);
    }

    /// PE 0 gathers every buffer, reduces serially, broadcasts the result.
    fn central_once(&self, pe: &Pe<'_>, seq: u64, x: &mut [f32]) {
        let p = self.pes();
        if p == 1 {
            return;
        }
        let n_pad = x.len();
        // Layout on PE 0: p slots of n_pad; result slot at p*n_pad on all.
        pe.announce_seq(seq);
        pe.wait_peer_seq(0, seq);
        put_f32(pe, 0, pe.id * n_pad, x, seq as u32);
        if pe.id == 0 {
            let mut acc = vec![0.0f32; n_pad];
            for src in 0..p {
                wait_add_f32(pe, src * n_pad, &mut acc, seq as u32);
            }
            for peer in 1..p {
                pe.wait_peer_seq(peer, seq);
                put_f32(pe, peer, p * n_pad, &acc, seq as u32);
            }
            x.copy_from_slice(&acc);
        } else {
            wait_copy_f32(pe, p * n_pad, x, seq as u32);
        }
    }
}

fn log2(x: usize) -> usize {
    assert!(x.is_power_of_two(), "{x} not a power of two");
    x.trailing_zeros() as usize
}

/// Put a f32 slice as LL words (data bits fused with `flag`).
/// Delegates to the zero-allocation packing put (perf pass: the original
/// pack-into-`Vec<u64>`-then-`put_nbi` allocated per chunk on the hot path).
#[inline]
fn put_f32(pe: &Pe<'_>, peer: usize, dst_off: usize, data: &[f32], flag: u32) {
    pe.put_f32_ll(peer, dst_off, data, flag);
}

/// Wait for `dst.len()` LL words at `off` carrying `flag`; add into `dst`.
///
/// Perf pass: senders write chunks in order with Release stores, so
/// acquiring the *last* word of a chunk happens-after every earlier store
/// of that chunk — one spin per chunk instead of one per word, then a bulk
/// read of the chunk body (each word's flag still validated; LL semantics
/// are preserved, just amortized).
fn wait_add_f32(pe: &Pe<'_>, off: usize, dst: &mut [f32], flag: u32) {
    wait_chunks(pe, off, dst, flag, |d, v| *d += v);
}

/// Wait for LL words and overwrite `dst`.
fn wait_copy_f32(pe: &Pe<'_>, off: usize, dst: &mut [f32], flag: u32) {
    wait_chunks(pe, off, dst, flag, |d, v| *d = v);
}

/// Chunk-tail waiting strategy shared by add/copy receives.
const RECV_CHUNK: usize = 512;

fn wait_chunks(pe: &Pe<'_>, off: usize, dst: &mut [f32], flag: u32, mut apply: impl FnMut(&mut f32, f32)) {
    let n = dst.len();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + RECV_CHUNK).min(n);
        // Spin once on the chunk tail; earlier words are then visible.
        let tail_bits = pe.wait_ll(off + hi - 1, flag);
        for i in lo..hi - 1 {
            // Already-arrived words: a failed flag check here would mean a
            // memory-ordering bug; wait_ll degrades to a spin, not an error.
            let bits = pe.wait_ll(off + i, flag);
            apply(&mut dst[i], f32::from_bits(bits));
        }
        apply(&mut dst[hi - 1], f32::from_bits(tail_bits));
        lo = hi;
    }
}

/// Serial oracle: elementwise sum of all inputs.
pub fn serial_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
    let n = inputs[0].len();
    let mut out = vec![0.0f32; n];
    for x in inputs {
        for (o, v) in out.iter_mut().zip(x) {
            *o += v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn run_and_check(algo: Algo, nodes: usize, g: usize, n_elems: usize, chunk: usize, seed: u64) {
        let h = Harness { nodes, gpus_per_node: g, n_elems, chunk_words: chunk, algo };
        let p = h.pes();
        let inputs: Vec<Vec<f32>> = (0..p)
            .map(|pe| {
                let mut r = crate::util::rng::Rng::new(seed + pe as u64);
                (0..n_elems).map(|_| r.f32() * 2.0 - 1.0).collect()
            })
            .collect();
        let want = serial_sum(&inputs);
        let got = h.run_once(|pe| inputs[pe].clone());
        for (pe, out) in got.iter().enumerate() {
            for (i, (a, b)) in out.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "{} N={nodes} G={g} n={n_elems}: pe {pe} elem {i}: {a} != {b}",
                    algo.name()
                );
            }
        }
    }

    #[test]
    fn nvrar_2x2_basic() {
        run_and_check(Algo::Nvrar, 2, 2, 64, 8, 1);
    }

    #[test]
    fn nvrar_4x2() {
        run_and_check(Algo::Nvrar, 4, 2, 100, 16, 2);
    }

    #[test]
    fn nvrar_vista_shape_g1() {
        run_and_check(Algo::Nvrar, 8, 1, 33, 4, 3);
    }

    #[test]
    fn nvrar_single_node() {
        run_and_check(Algo::Nvrar, 1, 4, 40, 8, 4);
    }

    #[test]
    fn ring_and_rd_and_central() {
        run_and_check(Algo::Ring, 2, 3, 50, 8, 5); // ring works for any P
        run_and_check(Algo::RdFlat, 4, 2, 50, 8, 6);
        run_and_check(Algo::Central, 2, 2, 50, 8, 7);
    }

    #[test]
    fn tree_various_worlds() {
        run_and_check(Algo::Tree, 2, 2, 64, 8, 8);
        run_and_check(Algo::Tree, 3, 2, 40, 8, 9); // non-pow2 PE count
        run_and_check(Algo::Tree, 1, 7, 33, 8, 10);
    }

    #[test]
    fn rabenseifner_pow2_worlds() {
        run_and_check(Algo::Rabenseifner, 2, 2, 64, 8, 11);
        run_and_check(Algo::Rabenseifner, 4, 2, 100, 8, 12);
        run_and_check(Algo::Rabenseifner, 8, 1, 128, 8, 13);
        run_and_check(Algo::Rabenseifner, 2, 1, 5, 8, 14); // n < P padding
    }

    #[test]
    fn rabenseifner_back_to_back_rounds() {
        // Per-step flags + seq gating: nested RS buffers must not leak
        // across steps or rounds.
        let h = Harness { nodes: 4, gpus_per_node: 1, n_elems: 32, chunk_words: 8, algo: Algo::Rabenseifner };
        let out = h.run_rounds(5, |pe, round| {
            (0..32).map(|i| (pe * 100 + round * 7 + i) as f32).collect()
        });
        for round in 0..5 {
            let inputs: Vec<Vec<f32>> = (0..4)
                .map(|pe| (0..32).map(|i| (pe * 100 + round * 7 + i) as f32).collect())
                .collect();
            let want = serial_sum(&inputs);
            for pe in 0..4 {
                assert_eq!(out[round][pe], want, "round {round} pe {pe}");
            }
        }
    }

    #[test]
    fn back_to_back_rounds_reuse_buffers_safely() {
        // The §4.2.3 sequence-number property: consecutive all-reduces with
        // the same buffers must not mix rounds.
        let h = Harness { nodes: 2, gpus_per_node: 2, n_elems: 32, chunk_words: 4, algo: Algo::Nvrar };
        let rounds = 6;
        let out = h.run_rounds(rounds, |pe, round| {
            (0..32).map(|i| (pe * 1000 + round * 10 + i) as f32).collect()
        });
        for round in 0..rounds {
            let inputs: Vec<Vec<f32>> = (0..4)
                .map(|pe| (0..32).map(|i| (pe * 1000 + round * 10 + i) as f32).collect())
                .collect();
            let want = serial_sum(&inputs);
            for pe in 0..4 {
                assert_eq!(out[round][pe], want, "round {round} pe {pe}");
            }
        }
    }

    #[test]
    fn property_all_algos_equal_serial_sum() {
        check("real all-reduce == serial sum", 14, |g: &mut Gen| {
            let algo = *g.pick(&Algo::all());
            let nodes = g.pow2(0, 3); // 1..8 nodes
            let gpn = match algo {
                Algo::RdFlat | Algo::Rabenseifner => g.pow2(0, 2),
                _ => g.usize(1, 4),
            };
            if nodes * gpn > 24 {
                return; // keep thread counts sane on 1 core
            }
            let n_elems = g.usize(1, 200);
            let chunk = g.usize(1, 64);
            let seed = g.u64(0, 1 << 30);
            run_and_check(algo, nodes, gpn, n_elems, chunk, seed);
        });
    }

    #[test]
    fn property_rounds_with_varying_lengths_chunks() {
        check("nvrar rounds safe", 6, |g: &mut Gen| {
            let nodes = g.pow2(1, 2);
            let gpn = g.usize(1, 3);
            let n_elems = g.usize(3, 120);
            let chunk = g.usize(1, 32);
            let h = Harness { nodes, gpus_per_node: gpn, n_elems, chunk_words: chunk, algo: Algo::Nvrar };
            let p = h.pes();
            let rounds = 3;
            let out = h.run_rounds(rounds, |pe, round| {
                (0..n_elems).map(|i| ((pe + 1) * (round + 2) + i) as f32 * 0.5).collect()
            });
            for round in 0..rounds {
                let inputs: Vec<Vec<f32>> = (0..p)
                    .map(|pe| (0..n_elems).map(|i| ((pe + 1) * (round + 2) + i) as f32 * 0.5).collect())
                    .collect();
                let want = serial_sum(&inputs);
                for pe in 0..p {
                    for (a, b) in out[round][pe].iter().zip(&want) {
                        assert!((a - b).abs() <= 1e-3, "mismatch");
                    }
                }
            }
        });
    }

    #[test]
    fn nan_inputs_propagate_bitwise() {
        // LL words are bit moves; a NaN contribution must surface as NaN.
        let h = Harness { nodes: 2, gpus_per_node: 1, n_elems: 4, chunk_words: 2, algo: Algo::Nvrar };
        let out = h.run_once(|pe| {
            if pe == 0 { vec![f32::NAN, 1.0, 2.0, 3.0] } else { vec![1.0; 4] }
        });
        assert!(out[0][0].is_nan() && out[1][0].is_nan());
        assert_eq!(out[0][1], 2.0);
    }
}
