//! Event-level all-reduce simulations over the α-β cluster model.
//!
//! Where the closed forms (Eqs 1–6) stop, these simulations model what the
//! paper measures: chunk pipelining (B_s thread blocks × C_s-byte chunks),
//! per-put injection overheads, LL payload inflation η, per-phase kernel
//! launches, NCCL's protocol (LL vs Simple) and algorithm (Ring vs Tree)
//! selection, host-proxy costs of NCCL/MPI versus GPU-initiated NVSHMEM
//! RMA, and NVRAR's deferred sequence-number synchronization (hidden by
//! interleaved compute — Appendix B / Fig 13).

use crate::cluster::Topology;
use crate::simnet::Server;

/// Tunables of the communication stack (per machine; see [`CommConfig::perlmutter`]).
#[derive(Clone, Copy, Debug)]
pub struct CommConfig {
    /// LL fused-payload inflation factor (1 < η ≤ 2); paper §4.3.
    pub eta: f64,
    /// NVRAR thread-block count B_s (concurrent chunk lanes).
    pub block_count: usize,
    /// NVRAR chunk size C_s in bytes.
    pub chunk_bytes: u64,
    /// GPU-local reduction bandwidth (bytes/s of *reduced output*; HBM-bound).
    pub reduce_bw: f64,
    /// Host kernel-launch overhead per launched kernel/phase.
    pub launch_overhead: f64,
    /// Extra per-hop latency of host-proxied transports (NCCL net/MPI).
    pub proxy_overhead: f64,
    /// Extra per-hop latency of GPU-initiated NVSHMEM RMA.
    pub nvshmem_overhead: f64,
    /// Per-put injection overhead (each put_nbi chunk pays this on the NIC).
    pub put_overhead: f64,
    /// Cost of NVRAR's sequence-number peer sync when *not* hidden by
    /// interleaved compute (§4.2.3, Fig 13).
    pub sync_cost: f64,
    /// NCCL LL protocol: bandwidth divides by this (8 B carries 4 B data).
    pub ll_bw_penalty: f64,
    /// NCCL LL protocol: latency multiplier (< 1; LL path skips syncs).
    pub ll_alpha_factor: f64,
    /// MPI per-call host overhead (no CUDA-graph capture; §4 intro).
    pub mpi_host_overhead: f64,
}

impl CommConfig {
    /// Slingshot-11 stack (Perlmutter). NVSHMEM's libfabric path has high
    /// per-put costs (the paper's §4.2.2 motivation for fused payloads).
    ///
    /// η = 1.25: the paper's 1 < η < 2 — the tuned kernel packs flags per
    /// cache line (LL128-style), not per 8 B word. (The *real* shmem
    /// implementation in `collectives::real` keeps word-granular flags,
    /// i.e. η = 2; it optimizes correctness clarity, not wire efficiency.)
    pub fn perlmutter() -> Self {
        CommConfig {
            eta: 1.25,
            block_count: 32,
            chunk_bytes: 32 * 1024,
            reduce_bw: 600.0e9,
            launch_overhead: 4.0e-6,
            proxy_overhead: 5.0e-6,
            nvshmem_overhead: 1.0e-6,
            put_overhead: 0.3e-6,
            sync_cost: 18.0e-6,
            ll_bw_penalty: 2.0,
            ll_alpha_factor: 0.6,
            mpi_host_overhead: 12.0e-6,
        }
    }

    /// InfiniBand stack (Vista). GPU-initiated RMA is very efficient on IB
    /// verbs; NCCL's proxy thread costs relatively more (drives the larger
    /// Vista speedups in Fig 6 right / Fig 14).
    /// NCCL's IB transport progresses through a host proxy thread whose
    /// per-hop cost dominates small messages, and its LL protocol's flag
    /// traffic crosses PCIe — while NVSHMEM IBGDA issues NIC doorbells from
    /// the GPU directly. This asymmetry is what gives Vista its larger
    /// NVRAR speedups (Fig 6 right / Fig 14).
    pub fn vista() -> Self {
        CommConfig {
            eta: 1.25,
            block_count: 32,
            chunk_bytes: 32 * 1024,
            reduce_bw: 900.0e9,
            launch_overhead: 4.0e-6,
            proxy_overhead: 25.0e-6,
            nvshmem_overhead: 0.5e-6,
            put_overhead: 0.1e-6,
            sync_cost: 10.0e-6,
            ll_bw_penalty: 3.0,
            ll_alpha_factor: 0.6,
            mpi_host_overhead: 10.0e-6,
        }
    }

    /// A generic InfiniBand GPU cluster (8 GPUs/node, DGX-like): per-hop
    /// costs between the Slingshot libfabric stack and Vista's tuned IBGDA
    /// path. The reference point for porting to unprofiled IB sites before
    /// `yalis fit` replaces the guesses with measured constants.
    pub fn generic_ib() -> Self {
        CommConfig {
            eta: 1.25,
            block_count: 32,
            chunk_bytes: 32 * 1024,
            reduce_bw: 600.0e9,
            launch_overhead: 4.0e-6,
            proxy_overhead: 15.0e-6,
            nvshmem_overhead: 0.8e-6,
            put_overhead: 0.2e-6,
            sync_cost: 14.0e-6,
            ll_bw_penalty: 2.0,
            ll_alpha_factor: 0.6,
            mpi_host_overhead: 11.0e-6,
        }
    }

    /// Comm constants for a machine name or bundle file path, resolved
    /// through [`crate::calib::registry`] (which also guarantees the
    /// matching [`crate::perfmodel::GpuSpec`] and topology come from the
    /// same bundle). Unknown names are an error, not a silent fallback.
    pub fn for_machine(name: &str) -> anyhow::Result<Self> {
        Ok(crate::calib::registry::resolve(name)?.comm)
    }
}

/// Result of one simulated all-reduce.
#[derive(Clone, Debug)]
pub struct Timing {
    pub total: f64,
    /// (phase name, seconds) — Fig 8 / Fig 13 breakdowns.
    pub phases: Vec<(&'static str, f64)>,
    /// Which algorithm/protocol was actually used (NCCL auto-selection).
    pub algo: &'static str,
}

impl Timing {
    fn new(algo: &'static str) -> Self {
        Timing { total: 0.0, phases: Vec::new(), algo }
    }

    fn phase(mut self, name: &'static str, secs: f64) -> Self {
        self.total += secs;
        self.phases.push((name, secs));
        self
    }

    pub fn phase_secs(&self, name: &str) -> f64 {
        self.phases.iter().filter(|(n, _)| *n == name).map(|(_, s)| s).sum()
    }
}

/// NCCL protocol choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Proto {
    Ll,
    Simple,
}

fn inter_alpha(t: &Topology, c: &CommConfig, proto: Proto) -> f64 {
    let a = t.inter.alpha + c.proxy_overhead;
    match proto {
        Proto::Ll => a * c.ll_alpha_factor,
        Proto::Simple => a,
    }
}

fn inter_beta(t: &Topology, c: &CommConfig, proto: Proto) -> f64 {
    match proto {
        Proto::Ll => t.inter.beta / c.ll_bw_penalty,
        Proto::Simple => t.inter.beta,
    }
}

/// NCCL Ring all-reduce (flat, node-major ring). Every one of the
/// 2(P-1) steps moves |M|/P bytes and is gated by the inter-node hop.
pub fn nccl_ring(t: &Topology, c: &CommConfig, bytes: u64, proto: Proto) -> Timing {
    let p = t.total_gpus() as f64;
    if t.total_gpus() == 1 {
        return Timing::new("ring").phase("launch", c.launch_overhead);
    }
    let chunk = bytes as f64 / p;
    let steps = 2.0 * (p - 1.0);
    let (a_ie, b_ie) = (inter_alpha(t, c, proto), inter_beta(t, c, proto));
    // On a node-major ring only 1/G of the hops cross nodes, but every
    // synchronous ring step is gated by its slowest active hop, which is
    // inter-node whenever N > 1.
    let step_time = if t.nodes > 1 {
        a_ie + chunk / b_ie
    } else {
        t.intra.alpha + chunk / t.intra.beta
    };
    Timing::new(if proto == Proto::Ll { "ring/LL" } else { "ring" })
        .phase("launch", c.launch_overhead)
        .phase("ring-steps", steps * step_time)
}

/// Pipelined chain: `hops` sequential (α, β) hops carrying `bytes` split
/// into `chunk`-byte pieces. T = Σα + Σ(c/β_i) + (Q-1)·max_i(c/β_i).
fn pipelined_chain(hops: &[(f64, f64)], bytes: u64, chunk: u64) -> f64 {
    if hops.is_empty() || bytes == 0 {
        return 0.0;
    }
    let chunk = chunk.max(1).min(bytes);
    let q = bytes.div_ceil(chunk) as f64;
    let c = bytes as f64 / q; // equalized chunk size
    let sum_alpha: f64 = hops.iter().map(|(a, _)| a).sum();
    let sum_ser: f64 = hops.iter().map(|(_, b)| c / b).sum();
    let bottleneck = hops.iter().map(|(_, b)| c / b).fold(0.0, f64::max);
    sum_alpha + sum_ser + (q - 1.0) * bottleneck
}

/// NCCL Tree all-reduce: intra-node chain + double-binary-tree inter-node
/// reduce, then the mirrored broadcast. Chunk-pipelined along the chain.
pub fn nccl_tree(t: &Topology, c: &CommConfig, bytes: u64, proto: Proto) -> Timing {
    let (a_ie, b_ie) = (inter_alpha(t, c, proto), inter_beta(t, c, proto));
    let mut up: Vec<(f64, f64)> = Vec::new();
    // Intra-node chain: G-1 hops on NVLink.
    for _ in 1..t.gpus_per_node {
        up.push((t.intra.alpha, t.intra.beta));
    }
    // Inter-node binary-tree depth: log2(N) hops. The double binary tree
    // halves per-tree traffic; model as bandwidth ×2 on inter hops.
    let depth = (t.nodes as f64).log2().ceil() as usize;
    for _ in 0..depth {
        up.push((a_ie, b_ie * 2.0));
    }
    let pipe_chunk = c.chunk_bytes.max(4096);
    let reduce = pipelined_chain(&up, bytes, pipe_chunk);
    let bcast = reduce; // mirrored down-phase
    Timing::new(if proto == Proto::Ll { "tree/LL" } else { "tree" })
        .phase("launch", c.launch_overhead)
        .phase("tree-reduce", reduce)
        .phase("tree-bcast", bcast)
}

/// NCCL with automatic algorithm+protocol selection (what `NcclAuto` runs):
/// the cheapest of {ring, tree} × {LL, Simple}, mirroring NCCL's tuner.
pub fn nccl_auto(t: &Topology, c: &CommConfig, bytes: u64) -> Timing {
    let candidates = [
        nccl_ring(t, c, bytes, Proto::Ll),
        nccl_ring(t, c, bytes, Proto::Simple),
        nccl_tree(t, c, bytes, Proto::Ll),
        nccl_tree(t, c, bytes, Proto::Simple),
    ];
    candidates
        .into_iter()
        // total_cmp (D02): a NaN timing must not panic the tuner; NaN
        // compares greatest, so it simply never wins the min.
        .min_by(|a, b| a.total.total_cmp(&b.total))
        // lint: allow(P01) fixed four-candidate array is never empty
        .unwrap()
}

/// GPU-aware MPI all-reduce: flat recursive doubling (Thakur-Gropp), host-
/// driven (no CUDA graphs ⇒ per-call host overhead — §4 intro).
pub fn mpi_rd(t: &Topology, c: &CommConfig, bytes: u64) -> Timing {
    let p = t.total_gpus();
    assert!(p.is_power_of_two(), "recursive doubling needs a power-of-two rank count");
    let steps = p.trailing_zeros() as usize;
    let mut total = 0.0;
    for step in 0..steps {
        // First log2(G) exchange rounds stay intra-node under node-major
        // rank order XOR peering.
        let intra = (1usize << step) < t.gpus_per_node;
        let (a, b) = if intra {
            (t.intra.alpha + c.proxy_overhead, t.intra.beta)
        } else {
            (t.inter.alpha + c.proxy_overhead, t.inter.beta)
        };
        total += a + bytes as f64 / b;
    }
    Timing::new("mpi-rd").phase("host", c.mpi_host_overhead).phase("rd-steps", total)
}

/// NVRAR (Algorithm 1), event-level: intra RS → chunked inter-node RD with
/// LL payloads and per-step buffers → intra AG. `gap_compute` is the GPU
/// compute time elapsed since the previous collective, which hides the
/// deferred sequence-number sync (§4.2.3; Fig 13's "w/ matmul" case).
pub fn nvrar(t: &Topology, c: &CommConfig, bytes: u64, gap_compute: f64) -> Timing {
    let g = t.gpus_per_node as f64;
    let n = t.nodes;
    let mut timing = Timing::new("nvrar");

    // Host-side: one launch per phase (RS + RD kernel + AG); single-GPU
    // nodes skip the intra phases entirely (Vista: one launch — §5.1).
    let launches = if t.gpus_per_node > 1 { 3.0 } else { 1.0 };
    timing = timing.phase("launch", launches * c.launch_overhead);

    // Deferred peer sync: pay only what interleaved compute didn't hide.
    timing = timing.phase("sync", (c.sync_cost - gap_compute).max(0.0));

    // Phase 1: intra-node ring reduce-scatter (NCCL under the hood).
    if t.gpus_per_node > 1 {
        let rs = (g - 1.0) * t.intra.alpha + ((g - 1.0) / g) * (bytes as f64 / t.intra.beta);
        timing = timing.phase("reduce-scatter", rs);
    }

    // Phase 2: inter-node recursive doubling on |M|/G bytes, η-inflated,
    // B_s lanes × C_s chunks, per-chunk put overhead, reduction overlapped.
    if n > 1 {
        assert!(n.is_power_of_two(), "NVRAR inter-node phase needs power-of-two node count");
        let steps = n.trailing_zeros() as usize;
        let msg = (bytes as f64 / g * c.eta).ceil() as u64;
        let alpha = t.inter.alpha + c.nvshmem_overhead;
        let lane_bytes = msg.div_ceil(c.block_count as u64).max(1);
        let q = lane_bytes.div_ceil(c.chunk_bytes).max(1) as usize;
        let chunk = lane_bytes as f64 / q as f64;

        // One GPU's timeline; peers are symmetric. The NIC serializes all
        // lanes' puts; each lane's reduce depends on its chunk arrival.
        let mut nic = Server::new();
        let mut reduce_srv = Server::new();
        // ready[lane][chunk] = when this chunk's data is ready to send.
        let mut ready = vec![vec![0.0f64; q]; c.block_count];
        let mut phase_end: f64 = 0.0;
        for _step in 0..steps {
            let mut next_ready = vec![vec![0.0f64; q]; c.block_count];
            for ci in 0..q {
                for lane in 0..c.block_count {
                    let ser = chunk / t.inter.beta + c.put_overhead;
                    let (_s, sent) = nic.book(ready[lane][ci], ser);
                    let arrive = sent + alpha;
                    // LL reduction begins on arrival (warp-level flag spin).
                    let rtime = chunk / c.reduce_bw;
                    let (_rs, rdone) = reduce_srv.book(arrive, rtime);
                    next_ready[lane][ci] = rdone;
                    phase_end = phase_end.max(rdone);
                }
            }
            ready = next_ready;
        }
        timing = timing.phase("recursive-doubling", phase_end);
    }

    // Phase 3: intra-node all-gather.
    if t.gpus_per_node > 1 {
        let ag = (g - 1.0) * t.intra.alpha + ((g - 1.0) / g) * (bytes as f64 / t.intra.beta);
        timing = timing.phase("all-gather", ag);
    }
    timing
}

/// Above this size the NVRAR integration falls back to NCCL — the same
/// size gating vLLM's custom all-reduce uses; the paper notes NVRAR
/// "primarily benefits small messages (128 KB–4 MB)", and prefill-phase
/// all-reduces (tens of MB) are bandwidth-bound where the LL η-inflation
/// loses.
pub const NVRAR_FALLBACK_BYTES: u64 = 4 * 1024 * 1024;

/// Dispatch by implementation choice. `gap_compute` only affects NVRAR.
/// The `Nvrar` arm models the engine *integration*: size-gated between the
/// NVRAR kernel and NCCL (see [`NVRAR_FALLBACK_BYTES`]).
pub fn allreduce(
    which: super::AllReduceImpl,
    t: &Topology,
    c: &CommConfig,
    bytes: u64,
    gap_compute: f64,
) -> Timing {
    use super::AllReduceImpl::*;
    match which {
        NcclAuto => nccl_auto(t, c, bytes),
        NcclRing => {
            let ll = nccl_ring(t, c, bytes, Proto::Ll);
            let simple = nccl_ring(t, c, bytes, Proto::Simple);
            if ll.total < simple.total { ll } else { simple }
        }
        NcclTree => {
            let ll = nccl_tree(t, c, bytes, Proto::Ll);
            let simple = nccl_tree(t, c, bytes, Proto::Simple);
            if ll.total < simple.total { ll } else { simple }
        }
        Mpi => mpi_rd(t, c, bytes),
        Nvrar => {
            if bytes > NVRAR_FALLBACK_BYTES {
                nccl_auto(t, c, bytes)
            } else {
                nvrar(t, c, bytes, gap_compute)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::collectives::model;

    #[test]
    fn nccl_selects_tree_for_small_multinode() {
        let t = presets::perlmutter(8);
        let c = CommConfig::perlmutter();
        let pick = nccl_auto(&t, &c, 128 * 1024);
        assert!(pick.algo.starts_with("tree"), "picked {}", pick.algo);
    }

    #[test]
    fn nccl_selects_ring_for_large_single_node() {
        let t = presets::perlmutter(1);
        let c = CommConfig::perlmutter();
        let pick = nccl_auto(&t, &c, 64 * 1024 * 1024);
        assert!(pick.algo.starts_with("ring"), "picked {}", pick.algo);
    }

    #[test]
    fn nvrar_sim_tracks_closed_form_in_latency_regime() {
        // With chunking trivial and overheads zeroed, the event-level RD
        // phase must agree with Eq. 4 within a put-overhead margin.
        let t = presets::perlmutter(8);
        let mut c = CommConfig::perlmutter();
        c.block_count = 1;
        c.chunk_bytes = u64::MAX;
        c.put_overhead = 0.0;
        c.nvshmem_overhead = 0.0;
        c.sync_cost = 0.0;
        c.launch_overhead = 0.0;
        c.reduce_bw = f64::INFINITY;
        let bytes = 512 * 1024;
        let sim = nvrar(&t, &c, bytes, 0.0);
        let rd_sim = sim.phase_secs("recursive-doubling");
        let rd_model = model::nvrar_recursive_doubling(&t, bytes, c.eta);
        // Model uses (N-1)/N bandwidth credit; sim sends full msg per step:
        // allow 2x slack but demand the same order.
        assert!(
            rd_sim > 0.5 * rd_model && rd_sim < 3.0 * rd_model,
            "sim {rd_sim} vs model {rd_model}"
        );
        let rs = sim.phase_secs("reduce-scatter");
        let rs_model = model::nvrar_reduce_scatter(&t, bytes);
        assert!((rs - rs_model).abs() < 1e-9);
    }

    #[test]
    fn nvrar_scales_logarithmically() {
        let c = CommConfig::perlmutter();
        let bytes = 256 * 1024;
        let t2 = nvrar(&presets::perlmutter(2), &c, bytes, 0.0).total;
        let t4 = nvrar(&presets::perlmutter(4), &c, bytes, 0.0).total;
        let t16 = nvrar(&presets::perlmutter(16), &c, bytes, 0.0).total;
        // Each node doubling adds ~one RD step: deltas roughly equal.
        let d1 = t4 - t2;
        let d2 = (t16 - t4) / 2.0;
        assert!(d1 > 0.0 && d2 > 0.0);
        assert!(d2 < 2.5 * d1, "not log-shaped: {d1} then {d2}");
    }

    #[test]
    fn ring_scales_linearly() {
        let c = CommConfig::perlmutter();
        let bytes = 256 * 1024;
        let t4 = nccl_ring(&presets::perlmutter(4), &c, bytes, Proto::Simple).total;
        let t16 = nccl_ring(&presets::perlmutter(16), &c, bytes, Proto::Simple).total;
        assert!(t16 / t4 > 3.0, "ratio {}", t16 / t4);
    }

    #[test]
    fn gap_compute_hides_sync() {
        let t = presets::perlmutter(4);
        let c = CommConfig::perlmutter();
        let bytes = 128 * 1024;
        let cold = nvrar(&t, &c, bytes, 0.0);
        let hot = nvrar(&t, &c, bytes, 1.0); // plenty of interleaved compute
        assert!(cold.total > hot.total);
        assert!((cold.total - hot.total - c.sync_cost).abs() < 1e-9);
    }

    #[test]
    fn vista_single_gpu_nodes_skip_intra_phases() {
        let t = presets::vista(8);
        let c = CommConfig::vista();
        let timing = nvrar(&t, &c, 512 * 1024, 0.0);
        assert_eq!(timing.phase_secs("reduce-scatter"), 0.0);
        assert_eq!(timing.phase_secs("all-gather"), 0.0);
        assert!(timing.phase_secs("recursive-doubling") > 0.0);
    }

    #[test]
    fn chunking_hyperparams_matter() {
        // Table 5: performance is sensitive to C_s; degenerate chunking
        // (tiny chunks => per-put overhead dominates) must be slower.
        let t = presets::perlmutter(4);
        let mut good = CommConfig::perlmutter();
        good.chunk_bytes = 32 * 1024;
        let mut bad = good;
        bad.chunk_bytes = 512;
        let bytes = 1024 * 1024;
        let tg = nvrar(&t, &good, bytes, 0.0).total;
        let tb = nvrar(&t, &bad, bytes, 0.0).total;
        assert!(tb > tg, "tiny chunks {tb} should beat.. err, lose to {tg}");
    }

    #[test]
    fn pipelined_chain_limits() {
        // Single chunk: plain store-and-forward sum.
        let hops = [(1e-6, 1e9), (2e-6, 2e9)];
        let t1 = pipelined_chain(&hops, 1000, u64::MAX);
        assert!((t1 - (3e-6 + 1e-6 + 0.5e-6)).abs() < 1e-12);
        // Many chunks: bottleneck-dominated, strictly faster than
        // unpipelined transfer of the whole message per hop.
        let big = 10_000_000;
        let pipelined = pipelined_chain(&hops, big, 10_000);
        let store_fwd = pipelined_chain(&hops, big, u64::MAX);
        assert!(pipelined < store_fwd);
    }

    #[test]
    fn mpi_beats_nccl_multinode_small_but_not_intra() {
        // Fig 4's observation: NCCL faster within a node; MPI competitive
        // across nodes for 512 KB–1 MB.
        let c = CommConfig::perlmutter();
        let intra = presets::perlmutter(1);
        assert!(nccl_auto(&intra, &c, 512 * 1024).total < mpi_rd(&intra, &c, 512 * 1024).total);
    }
}
