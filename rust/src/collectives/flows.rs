//! Flow-level all-reduce path over the shared [`crate::simnet::Interconnect`].
//!
//! Where [`super::sim`] models the *internals* of one collective (chunk
//! pipelining, launch/proxy overheads, protocol selection) on a private
//! fabric, this module models the collective's *footprint on a shared
//! fabric*: each phase books its byte volume onto the per-node links it
//! actually occupies, so concurrent traffic — KV handoffs, drain
//! migrations, another step's collective — inflates it, and it inflates
//! them. Phase decomposition mirrors the closed forms (Eqs 1–6,
//! [`super::model`]) exactly:
//!
//! | impl | phases booked |
//! |------|---------------|
//! | Ring (Eq 1) | one inter-node phase: `2(P-1)·α` + `2(P-1)/P·M` bytes |
//! | Tree (Eq 2) | intra latency `2(G-1)·α`; inter `2⌈log2 N⌉·α` + `2(N-1)/N·M` bytes |
//! | MPI RD | inter `⌈log2 P⌉·α` + `⌈log2 P⌉·M` bytes |
//! | NVRAR (Eqs 3–6) | intra RS → inter RD (`η`-inflated `M/G` share) → intra AG, each a distinct booking |
//!
//! **Parity guarantee** (pinned in `tests/integration_contention.rs`): on
//! an idle fabric [`allreduce_flow`] with `count = 1.0` returns
//! `alpha_beta` equal to the matching closed form within 1e-9 and
//! `delay == 0.0`, so enabling the contention layer without concurrent
//! traffic reproduces the standalone numbers.

use crate::cluster::Topology;
// `log2_steps` is shared with `model`, not duplicated: the 1e-9 parity
// contract depends on counting exchange rounds exactly as the closed
// forms do.
use crate::collectives::model::log2_steps;
use crate::collectives::sim::{CommConfig, NVRAR_FALLBACK_BYTES};
use crate::collectives::{model, AllReduceImpl};
use crate::obs::{ArgV, ObsSink, Track};
use crate::simnet::{Interconnect, LinkId, LinkKind};

/// One fabric call: the per-collective message size, how many back-to-back
/// collectives to aggregate into the booking (one engine step runs
/// `2·layers` of them; aggregating keeps the fabric cheap to simulate),
/// which link scope to book on, and the fabric start time.
#[derive(Clone, Copy, Debug)]
pub struct FlowSpec {
    /// Per-collective message bytes |M| (also drives algorithm selection).
    pub bytes: u64,
    /// Collectives aggregated into this booking (> 0; may be fractional
    /// when a step cost caps its booked volume at its wire-time budget).
    pub count: f64,
    /// Link scope (a replica's / TP group's slice of the fabric).
    pub scope: usize,
    /// Fabric time the first phase may start.
    pub at: f64,
}

/// Outcome of routing one collective's bytes through the shared fabric.
#[derive(Clone, Copy, Debug)]
pub struct FlowTiming {
    /// Pure per-collective α-β seconds — the matching closed form on an
    /// idle fabric, independent of `count`.
    pub alpha_beta: f64,
    /// Aggregate queueing delay from link contention (0.0 when idle).
    pub delay: f64,
    /// Fabric time when the last phase's bytes have moved.
    pub end: f64,
}

impl FlowTiming {
    /// Per-collective wall-clock seconds under the observed contention.
    pub fn total(&self) -> f64 {
        self.alpha_beta + self.delay
    }
}

/// One sequential phase of a collective on the fabric: `latency` α-seconds
/// plus `bytes` booked on every node link of `kind` in the scope (the
/// phases of one collective run on all of its nodes' links symmetrically;
/// a phase completes when its slowest link does). `name` is the phase's
/// label in the event timeline (`"{algo}.{name}"` spans on link tracks).
struct Phase {
    name: &'static str,
    kind: LinkKind,
    latency: f64,
    bytes: f64,
}

fn run_phases(
    phases: &[Phase],
    algo: &'static str,
    t: &Topology,
    s: FlowSpec,
    net: &mut Interconnect,
    obs: Option<&ObsSink>,
) -> FlowTiming {
    let mut cursor = s.at;
    let mut alpha_beta = 0.0;
    let mut delay = 0.0;
    let count = if s.count > 0.0 { s.count } else { 1.0 };
    for p in phases {
        let phase_start = cursor;
        let mut ideal = 0.0;
        let mut phase_delay = 0.0;
        if p.bytes > 0.0 {
            let mut phase_end = cursor;
            for node in 0..t.nodes.max(1) {
                let f = net.book(
                    LinkId { scope: s.scope, node, kind: p.kind },
                    cursor,
                    count * p.bytes,
                );
                ideal = f.ideal;
                phase_end = phase_end.max(f.end);
            }
            phase_delay = phase_end - cursor - ideal;
            delay += phase_delay;
            cursor = phase_end;
        }
        // `alpha_beta` reports the per-collective closed form: latency is
        // per-call already, the booked bandwidth term is aggregate.
        alpha_beta += p.latency + ideal / count;
        cursor += p.latency;
        if let Some(sink) = obs {
            sink.lock().unwrap().span(
                Track::Link { scope: s.scope, kind: p.kind },
                &format!("{algo}.{}", p.name),
                phase_start,
                cursor - phase_start,
                vec![
                    ("bytes", ArgV::U((count * p.bytes) as u64)),
                    ("count", ArgV::F(count)),
                    ("delay", ArgV::F(phase_delay)),
                ],
            );
        }
    }
    FlowTiming { alpha_beta, delay, end: cursor }
}

/// Book one (or `count` aggregated) all-reduce(s) through the shared
/// fabric. Algorithm selection (NCCL auto's ring-vs-tree pick, NVRAR's
/// NCCL fallback above [`NVRAR_FALLBACK_BYTES`]) uses the per-call
/// `spec.bytes`, mirroring [`super::sim::allreduce`].
pub fn allreduce_flow(
    which: AllReduceImpl,
    t: &Topology,
    c: &CommConfig,
    spec: FlowSpec,
    net: &mut Interconnect,
) -> FlowTiming {
    allreduce_flow_obs(which, t, c, spec, net, None)
}

/// [`allreduce_flow`] with an optional event sink: each booked phase is
/// also recorded as a span on its link track (name `"{algo}.{phase}"`,
/// args `bytes`/`count`/`delay`). Passing `None` is exactly
/// [`allreduce_flow`] — no recording, identical timing.
pub fn allreduce_flow_obs(
    which: AllReduceImpl,
    t: &Topology,
    c: &CommConfig,
    spec: FlowSpec,
    net: &mut Interconnect,
    obs: Option<&ObsSink>,
) -> FlowTiming {
    use AllReduceImpl::*;
    match which {
        NcclRing => ring_flow(t, spec, net, obs),
        NcclTree => tree_flow(t, spec, net, obs),
        NcclAuto => {
            // Pick by the closed forms, then book only the winner.
            if model::ring(t, spec.bytes) <= model::tree(t, spec.bytes) {
                ring_flow(t, spec, net, obs)
            } else {
                tree_flow(t, spec, net, obs)
            }
        }
        Mpi => rd_flat_flow(t, spec, net, obs),
        Nvrar => {
            if spec.bytes > NVRAR_FALLBACK_BYTES {
                allreduce_flow_obs(NcclAuto, t, c, spec, net, obs)
            } else {
                nvrar_flow(t, c, spec, net, obs)
            }
        }
    }
}

/// Eq. (1): flat ring, gated by the inter-node hops.
fn ring_flow(
    t: &Topology,
    s: FlowSpec,
    net: &mut Interconnect,
    obs: Option<&ObsSink>,
) -> FlowTiming {
    let p = t.total_gpus() as f64;
    let phases = [Phase {
        name: "hops",
        kind: LinkKind::Inter,
        latency: 2.0 * (p - 1.0) * t.inter.alpha,
        bytes: 2.0 * ((p - 1.0) / p) * s.bytes as f64,
    }];
    run_phases(&phases, "ring", t, s, net, obs)
}

/// Eq. (2): intra chain (latency-only in the closed form) + inter tree.
fn tree_flow(
    t: &Topology,
    s: FlowSpec,
    net: &mut Interconnect,
    obs: Option<&ObsSink>,
) -> FlowTiming {
    let (n, g) = (t.nodes as f64, t.gpus_per_node as f64);
    let phases = [
        Phase {
            name: "chain",
            kind: LinkKind::Intra,
            latency: 2.0 * (g - 1.0) * t.intra.alpha,
            bytes: 0.0,
        },
        Phase {
            name: "tree",
            kind: LinkKind::Inter,
            latency: 2.0 * log2_steps(n) * t.inter.alpha,
            bytes: 2.0 * ((n - 1.0) / n) * s.bytes as f64,
        },
    ];
    run_phases(&phases, "tree", t, s, net, obs)
}

/// Flat recursive doubling: ⌈log2 P⌉ full-message inter exchanges.
fn rd_flat_flow(
    t: &Topology,
    s: FlowSpec,
    net: &mut Interconnect,
    obs: Option<&ObsSink>,
) -> FlowTiming {
    let steps = log2_steps(t.total_gpus() as f64);
    let phases = [Phase {
        name: "rd",
        kind: LinkKind::Inter,
        latency: steps * t.inter.alpha,
        bytes: steps * s.bytes as f64,
    }];
    run_phases(&phases, "mpi", t, s, net, obs)
}

/// Eqs. (3)–(6): NVRAR's three phases as three distinct link bookings.
fn nvrar_flow(
    t: &Topology,
    c: &CommConfig,
    s: FlowSpec,
    net: &mut Interconnect,
    obs: Option<&ObsSink>,
) -> FlowTiming {
    let (n, g) = (t.nodes as f64, t.gpus_per_node as f64);
    let ring_bytes = ((g - 1.0) / g) * s.bytes as f64; // per intra ring phase
    let rd_bytes = if t.nodes > 1 {
        ((n - 1.0) / n) * (c.eta * s.bytes as f64 / g)
    } else {
        0.0
    };
    let phases = [
        Phase {
            name: "rs-intra",
            kind: LinkKind::Intra,
            latency: (g - 1.0) * t.intra.alpha,
            bytes: ring_bytes,
        },
        Phase {
            name: "rd-inter",
            kind: LinkKind::Inter,
            latency: log2_steps(n) * t.inter.alpha,
            bytes: rd_bytes,
        },
        Phase {
            name: "ag-intra",
            kind: LinkKind::Intra,
            latency: (g - 1.0) * t.intra.alpha,
            bytes: ring_bytes,
        },
    ];
    run_phases(&phases, "nvrar", t, s, net, obs)
}

/// Closed-form per-collective α-β seconds for `which` — the idle-fabric
/// `alpha_beta` an [`allreduce_flow`] booking reports — without touching
/// any fabric. Step costs use it to cap the volume they book at their
/// step's wire-time capacity (a step cannot occupy more link-seconds than
/// its own duration).
pub fn alpha_beta_time(which: AllReduceImpl, t: &Topology, c: &CommConfig, bytes: u64) -> f64 {
    use AllReduceImpl::*;
    match which {
        NcclRing => model::ring(t, bytes),
        NcclTree => model::tree(t, bytes),
        NcclAuto => model::ring(t, bytes).min(model::tree(t, bytes)),
        Mpi => model::recursive_doubling_flat(t, bytes),
        Nvrar => {
            if bytes > NVRAR_FALLBACK_BYTES {
                alpha_beta_time(NcclAuto, t, c, bytes)
            } else {
                model::nvrar(t, bytes, c.eta)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;

    fn fabric_for(t: &Topology) -> Interconnect {
        let mut net = Interconnect::new();
        net.add_scope(0, t.nodes, t.intra.beta, t.inter.beta);
        net
    }

    fn spec(bytes: u64) -> FlowSpec {
        FlowSpec { bytes, count: 1.0, scope: 0, at: 0.0 }
    }

    #[test]
    fn idle_fabric_matches_closed_forms() {
        let c = CommConfig::perlmutter();
        for nodes in [1usize, 2, 4, 8] {
            let t = presets::perlmutter(nodes);
            for kb in [128u64, 512, 2048] {
                let bytes = kb * 1024;
                let mut net = fabric_for(&t);
                let ring = ring_flow(&t, spec(bytes), &mut net, None);
                assert!((ring.alpha_beta - model::ring(&t, bytes)).abs() < 1e-9);
                assert_eq!(ring.delay, 0.0);
                let mut net = fabric_for(&t);
                let tree = tree_flow(&t, spec(bytes), &mut net, None);
                assert!((tree.alpha_beta - model::tree(&t, bytes)).abs() < 1e-9);
                let mut net = fabric_for(&t);
                let rd = rd_flat_flow(&t, spec(bytes), &mut net, None);
                assert!((rd.alpha_beta - model::recursive_doubling_flat(&t, bytes)).abs() < 1e-9);
                let mut net = fabric_for(&t);
                let nv = nvrar_flow(&t, &c, spec(bytes), &mut net, None);
                assert!(
                    (nv.alpha_beta - model::nvrar(&t, bytes, c.eta)).abs() < 1e-9,
                    "N={nodes} {kb}KB: {} vs {}",
                    nv.alpha_beta,
                    model::nvrar(&t, bytes, c.eta)
                );
                assert_eq!(nv.delay, 0.0);
            }
        }
    }

    #[test]
    fn auto_picks_the_cheaper_closed_form() {
        let t = presets::perlmutter(8);
        let c = CommConfig::perlmutter();
        let mut net = fabric_for(&t);
        let small = allreduce_flow(AllReduceImpl::NcclAuto, &t, &c, spec(64 * 1024), &mut net);
        let expect = model::ring(&t, 64 * 1024).min(model::tree(&t, 64 * 1024));
        assert!((small.alpha_beta - expect).abs() < 1e-9);
    }

    #[test]
    fn nvrar_falls_back_to_nccl_above_the_size_gate() {
        let t = presets::perlmutter(4);
        let c = CommConfig::perlmutter();
        let big = NVRAR_FALLBACK_BYTES + 1;
        let mut net = fabric_for(&t);
        let nv = allreduce_flow(AllReduceImpl::Nvrar, &t, &c, spec(big), &mut net);
        let mut net = fabric_for(&t);
        let auto = allreduce_flow(AllReduceImpl::NcclAuto, &t, &c, spec(big), &mut net);
        assert_eq!(nv.alpha_beta, auto.alpha_beta);
    }

    #[test]
    fn concurrent_transfer_inflates_only_the_contended_run() {
        let t = presets::perlmutter(4);
        let c = CommConfig::perlmutter();
        let bytes = 512 * 1024;
        let mut idle = fabric_for(&t);
        let base = nvrar_flow(&t, &c, spec(bytes), &mut idle, None);
        // A drain-migration-sized transfer parked on the node-0 NIC.
        let mut busy = fabric_for(&t);
        busy.book(
            LinkId { scope: 0, node: 0, kind: LinkKind::Inter },
            0.0,
            256.0 * 1024.0 * 1024.0,
        );
        let contended = nvrar_flow(&t, &c, spec(bytes), &mut busy, None);
        assert_eq!(contended.alpha_beta, base.alpha_beta, "α-β part is load-independent");
        assert!(contended.delay > 0.0, "sharing the NIC must delay the RD phase");
        assert!(contended.total() > base.total());
    }

    #[test]
    fn count_aggregates_volume_but_not_alpha_beta() {
        let t = presets::perlmutter(4);
        let c = CommConfig::perlmutter();
        let bytes = 256 * 1024;
        let mut net = fabric_for(&t);
        let one = nvrar_flow(&t, &c, spec(bytes), &mut net, None);
        let mut net = fabric_for(&t);
        let many =
            nvrar_flow(&t, &c, FlowSpec { count: 160.0, ..spec(bytes) }, &mut net, None);
        assert!((one.alpha_beta - many.alpha_beta).abs() < 1e-12);
        assert_eq!(many.delay, 0.0, "an idle fabric never delays, whatever the volume");
        let heavy = net.bytes_carried(LinkKind::Inter);
        let mut net = fabric_for(&t);
        nvrar_flow(&t, &c, spec(bytes), &mut net, None);
        let light = net.bytes_carried(LinkKind::Inter);
        assert!((heavy / light - 160.0).abs() < 1e-9);
    }

    #[test]
    fn vista_single_gpu_nodes_book_no_intra_bytes() {
        let t = presets::vista(8);
        let c = CommConfig::vista();
        let mut net = fabric_for(&t);
        let f = nvrar_flow(&t, &c, spec(512 * 1024), &mut net, None);
        assert_eq!(net.bytes_carried(LinkKind::Intra), 0.0);
        assert!((f.alpha_beta - model::nvrar(&t, 512 * 1024, c.eta)).abs() < 1e-9);
    }

    #[test]
    fn obs_records_one_span_per_phase_without_changing_timing() {
        use crate::obs::{arg_f64, Recorder, RunMeta, Track};
        let t = presets::perlmutter(4);
        let c = CommConfig::perlmutter();
        let bytes = 512 * 1024;
        let mut net = fabric_for(&t);
        let silent = nvrar_flow(&t, &c, spec(bytes), &mut net, None);
        let sink = Recorder::sink(RunMeta::default());
        let mut net = fabric_for(&t);
        let traced = nvrar_flow(&t, &c, spec(bytes), &mut net, Some(&sink));
        assert_eq!(silent.alpha_beta.to_bits(), traced.alpha_beta.to_bits());
        assert_eq!(silent.end.to_bits(), traced.end.to_bits());
        let rec = sink.lock().unwrap();
        let names: Vec<&str> = rec.spans().iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["nvrar.rs-intra", "nvrar.rd-inter", "nvrar.ag-intra"]);
        // Phases land on the right link class and carry their booked bytes.
        assert_eq!(rec.spans()[0].track, Track::Link { scope: 0, kind: LinkKind::Intra });
        assert_eq!(rec.spans()[1].track, Track::Link { scope: 0, kind: LinkKind::Inter });
        assert!(arg_f64(&rec.spans()[0].args, "bytes") > 0.0);
        assert_eq!(arg_f64(&rec.spans()[1].args, "delay"), 0.0);
        // Spans tile the collective: last span ends at the flow's end.
        let last = rec.spans().last().unwrap();
        assert!((last.start + last.dur - traced.end).abs() < 1e-12);
    }
}
