//! All-reduce algorithms: closed-form α-β models, event-level simulations,
//! and **real** shared-memory implementations.
//!
//! - [`model`] — the paper's Equations 1–6 (Ring, Tree, recursive doubling,
//!   NVRAR's three phases) as closed forms.
//! - [`sim`] — event-level simulations over [`crate::simnet`], modelling
//!   what the closed forms cannot: chunk pipelining (B_s × C_s), LL payload
//!   inflation η, per-phase kernel launches, NCCL protocol/algorithm
//!   selection, and NVRAR's deferred sequence-number synchronization.
//! - [`real`] — Algorithm 1 and the baselines implemented for real over the
//!   [`crate::shmem`] PGAS substrate (one thread per PE): bitwise-verifiable
//!   all-reduces with fused 8-byte data+flag payloads.
//! - [`tuner`] — B_s × C_s auto-tuning (the paper's Appendix C.1 future
//!   work), cached per message-size bucket.
//! - [`flows`] — the same closed forms as **flows on a shared fabric**
//!   ([`crate::simnet::Interconnect`]): each phase books its bytes on the
//!   per-node links it occupies, so concurrent KV handoffs / drain
//!   migrations inflate the collective (and vice versa), while an idle
//!   fabric reproduces the closed-form numbers exactly.

pub mod flows;
pub mod model;
pub mod real;
pub mod sim;
pub mod tuner;

/// Which all-reduce implementation an engine uses (paper §5 comparisons).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllReduceImpl {
    /// NCCL with automatic algorithm selection (Ring vs Tree).
    NcclAuto,
    /// NCCL pinned to Ring (Appendix C.3.2).
    NcclRing,
    /// NCCL pinned to Tree (Appendix C.3.2).
    NcclTree,
    /// GPU-aware MPI (recursive doubling, §3.5 / Fig 4).
    Mpi,
    /// The paper's NVSHMEM hierarchical recursive-doubling all-reduce.
    Nvrar,
}

impl AllReduceImpl {
    pub fn name(&self) -> &'static str {
        match self {
            AllReduceImpl::NcclAuto => "NCCL",
            AllReduceImpl::NcclRing => "NCCL(Ring)",
            AllReduceImpl::NcclTree => "NCCL(Tree)",
            AllReduceImpl::Mpi => "MPI",
            AllReduceImpl::Nvrar => "NVRAR",
        }
    }

    /// Every selectable implementation (sweep order of the benches).
    pub fn all() -> [AllReduceImpl; 5] {
        [
            AllReduceImpl::NcclAuto,
            AllReduceImpl::NcclRing,
            AllReduceImpl::NcclTree,
            AllReduceImpl::Mpi,
            AllReduceImpl::Nvrar,
        ]
    }

    /// Parse a CLI name. Unknown names are an error, not a panic, so a bad
    /// `--allreduce` flag produces a usable message.
    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "nccl" => AllReduceImpl::NcclAuto,
            "nccl-ring" => AllReduceImpl::NcclRing,
            "nccl-tree" => AllReduceImpl::NcclTree,
            "mpi" => AllReduceImpl::Mpi,
            "nvrar" => AllReduceImpl::Nvrar,
            other => anyhow::bail!(
                "unknown all-reduce impl '{other}' (expected nccl, nccl-ring, nccl-tree, mpi or nvrar)"
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_parses_known_impls() {
        assert_eq!(AllReduceImpl::by_name("nvrar").unwrap(), AllReduceImpl::Nvrar);
        assert_eq!(AllReduceImpl::by_name("NCCL").unwrap(), AllReduceImpl::NcclAuto);
        assert_eq!(AllReduceImpl::by_name("nccl-tree").unwrap(), AllReduceImpl::NcclTree);
        assert_eq!(AllReduceImpl::by_name("nccl-ring").unwrap(), AllReduceImpl::NcclRing);
        assert_eq!(AllReduceImpl::by_name("mpi").unwrap(), AllReduceImpl::Mpi);
    }

    #[test]
    fn by_name_rejects_unknown_with_usable_message() {
        let err = AllReduceImpl::by_name("gloo").unwrap_err().to_string();
        assert!(err.contains("gloo") && err.contains("nvrar"), "{err}");
    }
}
