//! YWT1 weight-bundle loader (inverse of `python/compile/export.py`).
//!
//! Format (little-endian): magic `YWT1`, u32 count, then per tensor:
//! u32 name_len, name, u8 dtype (0=f32, 1=i32), u8 ndim, u32 dims[],
//! raw data. The rust side only needs f32 tensors.

use super::tensor::HostTensor;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::io::Read;

/// Load every f32 tensor from a YWT1 bundle.
pub fn load_weights(path: &str) -> Result<BTreeMap<String, HostTensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    parse_weights(&bytes)
}

pub fn parse_weights(bytes: &[u8]) -> Result<BTreeMap<String, HostTensor>> {
    let mut r = std::io::Cursor::new(bytes);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("magic")?;
    ensure!(&magic == b"YWT1", "bad magic {magic:?}");
    let count = read_u32(&mut r)? as usize;
    let mut out = BTreeMap::new();
    for i in 0..count {
        let nlen = read_u32(&mut r)? as usize;
        ensure!(nlen < 4096, "tensor {i}: absurd name length {nlen}");
        let mut name = vec![0u8; nlen];
        r.read_exact(&mut name).context("name")?;
        let name = String::from_utf8(name).context("utf8 name")?;
        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr).context("dtype/ndim")?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut r)? as usize);
        }
        let numel: usize = dims.iter().product();
        let mut raw = vec![0u8; numel * 4];
        r.read_exact(&mut raw).with_context(|| format!("data of {name}"))?;
        match dtype {
            0 => {
                let data: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                out.insert(name, HostTensor::new(dims, data)?);
            }
            1 => {
                // i32 tensors are not used by the runtime; store as f32.
                let data: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32)
                    .collect();
                out.insert(name, HostTensor::new(dims, data)?);
            }
            other => bail!("tensor {name}: unknown dtype {other}"),
        }
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(tensors: &[(&str, &[usize], &[f32])]) -> Vec<u8> {
        let mut out = b"YWT1".to_vec();
        out.extend((tensors.len() as u32).to_le_bytes());
        for (name, dims, data) in tensors {
            out.extend((name.len() as u32).to_le_bytes());
            out.extend(name.as_bytes());
            out.push(0u8);
            out.push(dims.len() as u8);
            for d in *dims {
                out.extend((*d as u32).to_le_bytes());
            }
            for v in *data {
                out.extend(v.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn roundtrip() {
        let bytes =
            encode(&[("a", &[2, 2], &[1.0, 2.0, 3.0, 4.0]), ("b.c", &[3], &[5.0, 6.0, 7.0])]);
        let w = parse_weights(&bytes).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w["a"].dims, vec![2, 2]);
        assert_eq!(w["b.c"].data, vec![5.0, 6.0, 7.0]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_weights(b"NOPE\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut bytes = encode(&[("a", &[2, 2], &[1.0, 2.0, 3.0, 4.0])]);
        bytes.truncate(bytes.len() - 3);
        assert!(parse_weights(&bytes).is_err());
    }
}
