//! PJRT runtime: load the AOT-compiled HLO artifacts and run them from
//! rust — Python is never on this path.
//!
//! - [`Runtime`] wraps `xla::PjRtClient::cpu()`; [`Exe`] wraps one
//!   compiled executable (`HloModuleProto::from_text_file` → compile).
//! - [`weights`] loads the YWT1 tensor bundle written by
//!   `python/compile/export.py`.
//! - [`manifest`] parses `artifacts/config.txt` (dims + argument orders).
//! - [`tensor`] is a minimal host-side f32 tensor with the slicing the TP
//!   weight partitioner needs.
//! - [`tp`] is the tensor-parallel coordinator: the per-layer
//!   attn-shard / all-reduce / mlp-shard / all-reduce decode loop, with the
//!   all-reduce performed by the **real NVRAR implementation** over shmem
//!   PEs ([`crate::collectives::real`]) — the paper's Algorithm 1 sits in
//!   the real hot path of a real model.

pub mod manifest;
pub mod tensor;
pub mod tp;
pub mod weights;

use anyhow::{Context, Result};

/// A PJRT client (CPU platform).
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A device buffer plus the host literal that backs its (asynchronous)
/// upload. `BufferFromHostLiteral` on the TFRT CPU client copies lazily;
/// dropping the literal before the copy completes reads freed memory.
/// Keeping the literal alive for the buffer's lifetime makes the upload
/// safe with zero extra copies (PJRT sequences executions after the
/// transfer via the buffer's definition event).
pub struct DeviceBuf {
    pub buf: xla::PjRtBuffer,
    _keepalive: xla::Literal,
}

impl std::ops::Deref for DeviceBuf {
    type Target = xla::PjRtBuffer;
    fn deref(&self) -> &xla::PjRtBuffer {
        &self.buf
    }
}

/// One compiled HLO executable.
pub struct Exe {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: xla::PjRtClient::cpu().context("PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one `artifacts/<name>.hlo.txt` module.
    pub fn load(&self, dir: &str, name: &str) -> Result<Exe> {
        let path = format!("{dir}/{name}.hlo.txt");
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        Ok(Exe { name: name.to_string(), exe })
    }

    /// Upload a host literal to a device buffer (weights, caches): the
    /// literal is retained inside the returned [`DeviceBuf`] so the async
    /// transfer can never outlive its source.
    pub fn upload(&self, lit: xla::Literal) -> Result<DeviceBuf> {
        let buf = self.client.buffer_from_host_literal(None, &lit)?;
        Ok(DeviceBuf { buf, _keepalive: lit })
    }
}

impl Exe {
    /// Execute with literal arguments; the artifacts are lowered with
    /// `return_tuple=True`, so the single output buffer is a tuple —
    /// download it and split into per-output host literals.
    pub fn run_lits(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute::<xla::Literal>(args)?;
        untuple(out)
    }

    /// Execute with device-buffer arguments (no host copies on inputs).
    pub fn run_bufs(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute_b::<&xla::PjRtBuffer>(args)?;
        untuple(out)
    }
}

fn untuple(mut out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
    let mut row = out.pop().context("no output row")?;
    let buf = row.pop().context("empty output row")?;
    let lit = buf.to_literal_sync()?;
    Ok(lit.to_tuple()?)
}

/// Build an f32 literal from data + dims.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_f32: {dims:?} vs {} elems", data.len());
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)?)
}

/// Build an i32 literal from data + dims.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_i32: {dims:?} vs {} elems", data.len());
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)?)
}

/// Scalar i32 literal (decode position).
pub fn lit_scalar_i32(v: i32) -> Result<xla::Literal> {
    lit_i32(&[v], &[])
}

/// Literal to host f32 vector.
pub fn to_host_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
