//! `artifacts/config.txt` parser: the key=value manifest `aot.py` writes
//! (model dims, AOT batch/shard choices, per-artifact argument orders).

use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    kv: BTreeMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Self> {
        let path = format!("{dir}/config.txt");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
        Ok(Self::parse(&text))
    }

    pub fn parse(text: &str) -> Self {
        let mut kv = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        Manifest { kv }
    }

    pub fn get(&self, key: &str) -> Result<&str> {
        self.kv.get(key).map(|s| s.as_str()).with_context(|| format!("manifest key '{key}'"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?.parse().with_context(|| format!("manifest key '{key}' not an integer"))
    }

    /// The argument-name order of an artifact (sanity check vs the caller).
    pub fn artifact_args(&self, name: &str) -> Result<Vec<String>> {
        Ok(self
            .get(&format!("artifact.{name}.args"))?
            .split(',')
            .map(|s| s.trim().to_string())
            .collect())
    }

    /// Tiny-model dimensions as (vocab, d_model, layers, heads, kv_heads,
    /// head_dim, ffn, max_seq).
    pub fn model_dims(&self) -> Result<ModelDims> {
        Ok(ModelDims {
            vocab: self.get_usize("model.vocab")?,
            d_model: self.get_usize("model.d_model")?,
            n_layers: self.get_usize("model.n_layers")?,
            n_heads: self.get_usize("model.n_heads")?,
            n_kv_heads: self.get_usize("model.n_kv_heads")?,
            head_dim: self.get_usize("model.head_dim")?,
            ffn: self.get_usize("model.ffn")?,
            max_seq: self.get_usize("model.max_seq")?,
            batch: self.get_usize("aot.batch")?,
            prompt: self.get_usize("aot.prompt")?,
            shards: self.get_usize("aot.shards")?,
        })
    }
}

/// Static dims of the AOT-compiled tiny model.
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub batch: usize,
    pub prompt: usize,
    pub shards: usize,
}

impl ModelDims {
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
model.vocab=4096
model.d_model=768
model.n_layers=12
model.n_heads=12
model.n_kv_heads=4
model.head_dim=64
model.ffn=2048
model.max_seq=256
aot.batch=2
aot.prompt=16
aot.shards=2
artifact.decode_full.args=token,pos,k_caches,v_caches,embed
";

    #[test]
    fn parses_dims_and_args() {
        let m = Manifest::parse(SAMPLE);
        let d = m.model_dims().unwrap();
        assert_eq!(d.d_model, 768);
        assert_eq!(d.q_dim(), 768);
        assert_eq!(d.kv_dim(), 256);
        assert_eq!(
            m.artifact_args("decode_full").unwrap()[..2],
            ["token".to_string(), "pos".to_string()]
        );
    }

    #[test]
    fn missing_key_errors() {
        let m = Manifest::parse("a=1");
        assert!(m.get("b").is_err());
        assert!(m.get_usize("a").is_ok());
    }

    #[test]
    fn ignores_comments_blank() {
        let m = Manifest::parse("# comment\n\nx=7\n");
        assert_eq!(m.get_usize("x").unwrap(), 7);
    }
}
