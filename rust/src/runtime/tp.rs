//! The tensor-parallel runtime coordinator — the end-to-end hot path.
//!
//! Mirrors the paper's TP execution structure exactly: every decode step,
//! every layer runs its attention shard and MLP shard per TP rank, and the
//! partial outputs are combined by an **all-reduce owned by the rust
//! coordinator** — performed by the real NVRAR implementation (Algorithm 1
//! over shmem PEs), or any baseline algorithm, at the paper's §3.5 message
//! granularity (B × H floats, twice per layer).
//!
//! Weights are uploaded to device buffers once at load; KV caches come back
//! from each step's output tuple and are re-uploaded for the next step (the
//! CPU-PJRT client keeps root tuples whole, so a host round-trip per step
//! is unavoidable — measured and reported in `TpStats`).

use super::manifest::{Manifest, ModelDims};
use super::tensor::{argmax_rows, HostTensor};
use super::weights::load_weights;
use super::{lit_f32, lit_i32, lit_scalar_i32, to_host_f32, DeviceBuf, Exe, Runtime};
use crate::collectives::real::{Algo, Harness};
use anyhow::{ensure, Context, Result};
use std::time::Instant;

/// Cumulative timing stats of the coordinator loop.
#[derive(Clone, Copy, Debug, Default)]
pub struct TpStats {
    /// Seconds inside PJRT executions (incl. output tuple download).
    pub pjrt: f64,
    /// Seconds inside the real all-reduce (including PE thread spin-up).
    pub allreduce: f64,
    /// Seconds of host-side glue (slicing, residual adds, uploads).
    pub host: f64,
    /// Decode steps executed.
    pub steps: u64,
    /// All-reduce operations performed.
    pub allreduces: u64,
}

/// Per-(layer, shard) uploaded weight buffers.
struct ShardBufs {
    attn: Vec<DeviceBuf>, // norm, wq, wk, wv, wo
    mlp: Vec<DeviceBuf>,  // norm, wg, wu, wd
}

/// The TP coordinator over the AOT artifacts.
pub struct TpRuntime {
    pub dims: ModelDims,
    rt: Runtime,
    embed_exe: Exe,
    attn_exe: Exe,
    mlp_exe: Exe,
    head_exe: Exe,
    prefill_exe: Exe,
    decode_exe: Exe,
    /// Stacked full-model weights in artifact argument order.
    full_w: Vec<DeviceBuf>,
    embed_w: DeviceBuf,
    final_norm_w: DeviceBuf,
    lm_head_w: DeviceBuf,
    shard_w: Vec<Vec<ShardBufs>>, // [layer][shard]
    /// Sharded KV-cache device buffers: [layer][shard] -> (k, v).
    caches: Vec<Vec<Option<(DeviceBuf, DeviceBuf)>>>,
    /// Full-model caches for the oracle path.
    full_caches: Option<(DeviceBuf, DeviceBuf)>,
    pub pos: usize,
    /// All-reduce algorithm for shard combination.
    pub algo: Algo,
    /// C_s in f32 words for the real NVRAR chunked puts.
    pub chunk_words: usize,
    pub stats: TpStats,
}

impl TpRuntime {
    /// Load artifacts + weights from `dir` (usually "artifacts").
    pub fn load(dir: &str) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let dims = manifest.model_dims()?;
        ensure!(dims.shards.is_power_of_two(), "TP shard count must be a power of two");
        let rt = Runtime::cpu()?;
        let weights = load_weights(&format!("{dir}/weights.bin"))?;

        // Sanity: artifact arg orders match what this coordinator feeds.
        ensure!(
            manifest.artifact_args("attn_shard")?
                == ["x", "attn_norm", "wq", "wk", "wv", "wo", "k_cache", "v_cache", "pos"],
            "attn_shard argument order drifted"
        );
        ensure!(
            manifest.artifact_args("mlp_shard")? == ["x", "mlp_norm", "wg", "wu", "wd"],
            "mlp_shard argument order drifted"
        );

        let stack_order = [
            "embed", "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "wg", "wu", "wd",
            "final_norm", "lm_head",
        ];
        let mut full_w = Vec::new();
        for name in stack_order {
            let t = weights.get(name).with_context(|| format!("weight {name}"))?;
            full_w.push(rt.upload(lit_f32(&t.data, &t.dims)?)?);
        }

        // Per-layer, per-shard slices (mirrors python shard_layer_params).
        let s = dims.shards;
        let (hs_dh, kvs_dh, fs) = (dims.q_dim() / s, dims.kv_dim() / s, dims.ffn / s);
        let mut shard_w = Vec::with_capacity(dims.n_layers);
        for l in 0..dims.n_layers {
            let mut per_shard = Vec::with_capacity(s);
            for sh in 0..s {
                let (qa, qb) = (sh * hs_dh, (sh + 1) * hs_dh);
                let (ka, kb) = (sh * kvs_dh, (sh + 1) * kvs_dh);
                let (fa, fb) = (sh * fs, (sh + 1) * fs);
                let up = |t: &HostTensor| -> Result<DeviceBuf> {
                    rt.upload(lit_f32(&t.data, &t.dims)?)
                };
                let attn = vec![
                    up(&weights["attn_norm"].index0(l))?,
                    up(&weights["wq"].index0(l).cols(qa, qb))?,
                    up(&weights["wk"].index0(l).cols(ka, kb))?,
                    up(&weights["wv"].index0(l).cols(ka, kb))?,
                    up(&weights["wo"].index0(l).rows(qa, qb))?,
                ];
                let mlp = vec![
                    up(&weights["mlp_norm"].index0(l))?,
                    up(&weights["wg"].index0(l).cols(fa, fb))?,
                    up(&weights["wu"].index0(l).cols(fa, fb))?,
                    up(&weights["wd"].index0(l).rows(fa, fb))?,
                ];
                per_shard.push(ShardBufs { attn, mlp });
            }
            shard_w.push(per_shard);
        }

        let embed_w = rt.upload(lit_f32(&weights["embed"].data, &weights["embed"].dims)?)?;
        let final_norm_w =
            rt.upload(lit_f32(&weights["final_norm"].data, &weights["final_norm"].dims)?)?;
        let lm_head_w =
            rt.upload(lit_f32(&weights["lm_head"].data, &weights["lm_head"].dims)?)?;

        let caches = (0..dims.n_layers).map(|_| (0..s).map(|_| None).collect()).collect();

        Ok(TpRuntime {
            embed_exe: rt.load(dir, "embed")?,
            attn_exe: rt.load(dir, "attn_shard")?,
            mlp_exe: rt.load(dir, "mlp_shard")?,
            head_exe: rt.load(dir, "head")?,
            prefill_exe: rt.load(dir, "prefill_full")?,
            decode_exe: rt.load(dir, "decode_full")?,
            rt,
            dims,
            full_w,
            embed_w,
            final_norm_w,
            lm_head_w,
            shard_w,
            caches,
            full_caches: None,
            pos: 0,
            algo: Algo::Nvrar,
            chunk_words: 256,
            stats: TpStats::default(),
        })
    }

    /// Prefill the fixed AOT prompt shape; initialize both the sharded and
    /// the full-model caches. `tokens` is row-major (B, prompt).
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, t0) = (self.dims.batch, self.dims.prompt);
        ensure!(tokens.len() == b * t0, "prefill expects {}x{} tokens", b, t0);
        let t_start = Instant::now();
        let tok_buf = self.rt.upload(lit_i32(tokens, &[b, t0])?)?;
        let mut bufs: Vec<&xla::PjRtBuffer> = vec![&tok_buf.buf];
        for w in &self.full_w {
            bufs.push(&w.buf);
        }
        let out = self.prefill_exe.run_bufs(&bufs)?;
        self.stats.pjrt += t_start.elapsed().as_secs_f64();
        ensure!(out.len() == 3, "prefill_full returns (logits, kc, vc), got {}", out.len());
        let logits = to_host_f32(&out[0])?;
        let kc = to_host_f32(&out[1])?;
        let vc = to_host_f32(&out[2])?;

        let host_start = Instant::now();
        // Slice the (L, B, T, kv·dh) caches per layer per shard and upload.
        let (l, tmax, kvd) = (self.dims.n_layers, self.dims.max_seq, self.dims.kv_dim());
        let s = self.dims.shards;
        let kvs = kvd / s;
        let per_layer = b * tmax * kvd;
        for layer in 0..l {
            let lk = HostTensor::new(
                vec![b, tmax, kvd],
                kc[layer * per_layer..(layer + 1) * per_layer].to_vec(),
            )?;
            let lv = HostTensor::new(
                vec![b, tmax, kvd],
                vc[layer * per_layer..(layer + 1) * per_layer].to_vec(),
            )?;
            for sh in 0..s {
                let ks = lk.last_dim_slice3(sh * kvs, (sh + 1) * kvs);
                let vs = lv.last_dim_slice3(sh * kvs, (sh + 1) * kvs);
                let kb = self.rt.upload(lit_f32(&ks.data, &ks.dims)?)?;
                let vb = self.rt.upload(lit_f32(&vs.data, &vs.dims)?)?;
                self.caches[layer][sh] = Some((kb, vb));
            }
        }
        // Full caches for the oracle path.
        let kc_buf = self.rt.upload(lit_f32(&kc, &[l, b, tmax, kvd])?)?;
        let vc_buf = self.rt.upload(lit_f32(&vc, &[l, b, tmax, kvd])?)?;
        self.full_caches = Some((kc_buf, vc_buf));
        self.pos = t0;
        self.stats.host += host_start.elapsed().as_secs_f64();
        Ok(logits)
    }

    /// All-reduce shard partials with the configured real algorithm.
    fn reduce_partials(&mut self, partials: Vec<Vec<f32>>) -> Vec<f32> {
        let t = Instant::now();
        let n = partials[0].len();
        let h = Harness {
            nodes: self.dims.shards,
            gpus_per_node: 1,
            n_elems: n,
            chunk_words: self.chunk_words,
            algo: self.algo,
        };
        let out = h.run_once(|pe| partials[pe].clone());
        self.stats.allreduce += t.elapsed().as_secs_f64();
        self.stats.allreduces += 1;
        out.into_iter().next().unwrap()
    }

    /// One sharded decode step: returns logits (B, V) and advances pos.
    pub fn decode_step_sharded(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let b = self.dims.batch;
        let d = self.dims.d_model;
        ensure!(tokens.len() == b, "decode expects batch {b}");
        ensure!(self.pos < self.dims.max_seq, "KV cache exhausted at pos {}", self.pos);
        let s = self.dims.shards;

        // Embed.
        let t0 = Instant::now();
        let tok_buf = self.rt.upload(lit_i32(tokens, &[b])?)?;
        let x_out = self.embed_exe.run_bufs(&[&tok_buf.buf, &self.embed_w.buf])?;
        let mut x = to_host_f32(&x_out[0])?;
        let pos_buf = self.rt.upload(lit_scalar_i32(self.pos as i32)?)?;
        self.stats.pjrt += t0.elapsed().as_secs_f64();

        for layer in 0..self.dims.n_layers {
            // --- attention shards.
            let tp = Instant::now();
            let x_buf = self.rt.upload(lit_f32(&x, &[b, d])?)?;
            let mut partials: Vec<Vec<f32>> = Vec::with_capacity(s);
            for sh in 0..s {
                let (kc, vc) = self.caches[layer][sh].take().expect("prefill first");
                let w = &self.shard_w[layer][sh].attn;
                let out = self.attn_exe.run_bufs(&[
                    &x_buf.buf, &w[0].buf, &w[1].buf, &w[2].buf, &w[3].buf, &w[4].buf, &kc.buf,
                    &vc.buf, &pos_buf.buf,
                ])?;
                ensure!(out.len() == 3, "attn_shard returns 3 outputs");
                let mut it = out.into_iter();
                partials.push(to_host_f32(&it.next().unwrap())?);
                let new_k = self.rt.upload(it.next().unwrap())?;
                let new_v = self.rt.upload(it.next().unwrap())?;
                self.caches[layer][sh] = Some((new_k, new_v));
            }
            self.stats.pjrt += tp.elapsed().as_secs_f64();

            // --- TP all-reduce #1 (attention output) + residual.
            let reduced = self.reduce_partials(partials);
            let th = Instant::now();
            for (a, r) in x.iter_mut().zip(&reduced) {
                *a += r;
            }
            self.stats.host += th.elapsed().as_secs_f64();

            // --- MLP shards.
            let tp = Instant::now();
            let x_buf = self.rt.upload(lit_f32(&x, &[b, d])?)?;
            let mut partials: Vec<Vec<f32>> = Vec::with_capacity(s);
            for sh in 0..s {
                let w = &self.shard_w[layer][sh].mlp;
                let out = self
                    .mlp_exe
                    .run_bufs(&[&x_buf.buf, &w[0].buf, &w[1].buf, &w[2].buf, &w[3].buf])?;
                partials.push(to_host_f32(&out[0])?);
            }
            self.stats.pjrt += tp.elapsed().as_secs_f64();

            // --- TP all-reduce #2 (MLP output) + residual.
            let reduced = self.reduce_partials(partials);
            let th = Instant::now();
            for (a, r) in x.iter_mut().zip(&reduced) {
                *a += r;
            }
            self.stats.host += th.elapsed().as_secs_f64();
        }

        // Head.
        let tp = Instant::now();
        let x_buf = self.rt.upload(lit_f32(&x, &[b, d])?)?;
        let out =
            self.head_exe.run_bufs(&[&x_buf.buf, &self.final_norm_w.buf, &self.lm_head_w.buf])?;
        let logits = to_host_f32(&out[0])?;
        self.stats.pjrt += tp.elapsed().as_secs_f64();
        self.pos += 1;
        self.stats.steps += 1;
        Ok(logits)
    }

    /// One full-model (unsharded) decode step — the numeric oracle.
    /// Does NOT advance `pos`; call in lockstep before the sharded step.
    pub fn decode_step_full(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let b = self.dims.batch;
        let (kc, vc) = self.full_caches.take().context("prefill first")?;
        let t0 = Instant::now();
        let tok_buf = self.rt.upload(lit_i32(tokens, &[b])?)?;
        let pos_buf = self.rt.upload(lit_scalar_i32(self.pos as i32)?)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf.buf, &pos_buf.buf, &kc.buf, &vc.buf];
        for w in &self.full_w {
            args.push(&w.buf);
        }
        let out = self.decode_exe.run_bufs(&args)?;
        ensure!(out.len() == 3, "decode_full returns 3 outputs");
        let mut it = out.into_iter();
        let logits = to_host_f32(&it.next().unwrap())?;
        let new_k = self.rt.upload(it.next().unwrap())?;
        let new_v = self.rt.upload(it.next().unwrap())?;
        self.full_caches = Some((new_k, new_v));
        self.stats.pjrt += t0.elapsed().as_secs_f64();
        Ok(logits)
    }

    /// Greedy-decode `steps` tokens with the sharded path; returns the
    /// token ids produced per step (batch-major).
    pub fn generate(&mut self, first_logits: &[f32], steps: usize) -> Result<Vec<Vec<i32>>> {
        let b = self.dims.batch;
        let mut toks = argmax_rows(first_logits, b);
        let mut out = Vec::with_capacity(steps);
        for _ in 0..steps {
            if self.pos + 1 >= self.dims.max_seq {
                break;
            }
            out.push(toks.clone());
            let logits = self.decode_step_sharded(&toks)?;
            toks = argmax_rows(&logits, b);
        }
        Ok(out)
    }
}
