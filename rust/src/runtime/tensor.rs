//! Minimal host-side f32 tensor with the slicing the TP partitioner needs.

use anyhow::{ensure, Result};

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        ensure!(dims.iter().product::<usize>() == data.len(), "shape/data mismatch");
        Ok(HostTensor { dims, data })
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        HostTensor { dims, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Select index `i` along the first dimension (stacked-layer lookup).
    pub fn index0(&self, i: usize) -> HostTensor {
        assert!(self.rank() >= 1 && i < self.dims[0]);
        let stride: usize = self.dims[1..].iter().product();
        HostTensor {
            dims: self.dims[1..].to_vec(),
            data: self.data[i * stride..(i + 1) * stride].to_vec(),
        }
    }

    /// Slice columns `[a, b)` of a 2-D tensor (TP column partition).
    pub fn cols(&self, a: usize, b: usize) -> HostTensor {
        assert!(self.rank() == 2 && a < b && b <= self.dims[1]);
        let (r, c) = (self.dims[0], self.dims[1]);
        let mut data = Vec::with_capacity(r * (b - a));
        for row in 0..r {
            data.extend_from_slice(&self.data[row * c + a..row * c + b]);
        }
        HostTensor { dims: vec![r, b - a], data }
    }

    /// Slice rows `[a, b)` of a 2-D tensor (TP row partition).
    pub fn rows(&self, a: usize, b: usize) -> HostTensor {
        assert!(self.rank() == 2 && a < b && b <= self.dims[0]);
        let c = self.dims[1];
        HostTensor { dims: vec![b - a, c], data: self.data[a * c..b * c].to_vec() }
    }

    /// Slice the last dimension `[a, b)` of a 3-D tensor (per-shard KV
    /// cache slice: (B, T, kv·dh) → (B, T, kv_s·dh), contiguous because
    /// the KV-head index is major in the last axis).
    pub fn last_dim_slice3(&self, a: usize, b: usize) -> HostTensor {
        assert!(self.rank() == 3 && a < b && b <= self.dims[2]);
        let (d0, d1, d2) = (self.dims[0], self.dims[1], self.dims[2]);
        let mut data = Vec::with_capacity(d0 * d1 * (b - a));
        for i in 0..d0 * d1 {
            data.extend_from_slice(&self.data[i * d2 + a..i * d2 + b]);
        }
        HostTensor { dims: vec![d0, d1, b - a], data }
    }

    /// Elementwise add (residual connections in the coordinator loop).
    pub fn add_assign(&mut self, o: &[f32]) {
        assert_eq!(self.data.len(), o.len());
        for (a, b) in self.data.iter_mut().zip(o) {
            *a += b;
        }
    }

    pub fn allclose(&self, o: &HostTensor, tol: f32) -> bool {
        self.dims == o.dims
            && self
                .data
                .iter()
                .zip(&o.data)
                .all(|(a, b)| (a - b).abs() <= tol * (1.0 + b.abs()))
    }
}

/// Row-major argmax over the last dim of a (B, V) logits buffer.
pub fn argmax_rows(logits: &[f32], batch: usize) -> Vec<i32> {
    assert!(batch > 0 && !logits.is_empty() && logits.len() % batch == 0);
    let v = logits.len() / batch;
    (0..batch)
        .map(|b| {
            let row = &logits[b * v..(b + 1) * v];
            // total_cmp (D02): NaN logits must not panic argmax; NaN
            // compares greatest under the IEEE total order, so a NaN row
            // deterministically picks the last NaN index.
            // lint: allow(P01) rows are non-empty (v > 0 asserted above)
            row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0 as i32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(dims: &[usize]) -> HostTensor {
        let n: usize = dims.iter().product();
        HostTensor::new(dims.to_vec(), (0..n).map(|i| i as f32).collect()).unwrap()
    }

    #[test]
    fn argmax_tolerates_nan() {
        // Regression (D02): partial_cmp().unwrap() panicked here on NaN.
        // Under total_cmp, NaN compares greatest, so the NaN index wins
        // deterministically and finite rows are unaffected.
        let r = argmax_rows(&[0.0, f32::NAN, 1.0, 5.0, 2.0, 1.0], 2);
        assert_eq!(r, vec![1, 0]);
    }

    #[test]
    fn index0_picks_layer() {
        let x = t(&[3, 2, 2]);
        let l1 = x.index0(1);
        assert_eq!(l1.dims, vec![2, 2]);
        assert_eq!(l1.data, vec![4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn cols_rows_partition() {
        let x = t(&[2, 4]); // [[0,1,2,3],[4,5,6,7]]
        assert_eq!(x.cols(1, 3).data, vec![1.0, 2.0, 5.0, 6.0]);
        assert_eq!(x.rows(1, 2).data, vec![4.0, 5.0, 6.0, 7.0]);
        // Column halves reassemble the original.
        let l = x.cols(0, 2);
        let r = x.cols(2, 4);
        let mut rebuilt = Vec::new();
        for row in 0..2 {
            rebuilt.extend_from_slice(&l.data[row * 2..row * 2 + 2]);
            rebuilt.extend_from_slice(&r.data[row * 2..row * 2 + 2]);
        }
        assert_eq!(rebuilt, x.data);
    }

    #[test]
    fn last_dim_slice3_contiguous_kv() {
        let x = t(&[2, 2, 4]);
        let s = x.last_dim_slice3(2, 4);
        assert_eq!(s.dims, vec![2, 2, 2]);
        assert_eq!(s.data, vec![2.0, 3.0, 6.0, 7.0, 10.0, 11.0, 14.0, 15.0]);
    }

    #[test]
    fn add_and_allclose() {
        let mut a = t(&[2, 2]);
        a.add_assign(&[1.0; 4]);
        assert_eq!(a.data, vec![1.0, 2.0, 3.0, 4.0]);
        let b = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0000001]).unwrap();
        assert!(a.allclose(&b, 1e-5));
    }

    #[test]
    fn argmax() {
        let logits = vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 2), vec![1, 0]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        t(&[2, 2]).cols(3, 2);
    }
}
