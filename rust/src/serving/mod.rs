//! Trace-driven serving simulation (Figs 9, 10, 18): request router +
//! continuous batching + per-step engine costs, driven by the discrete-
//! event queue.
//!
//! The real scheduling machinery ([`crate::engine::batcher::Batcher`] and
//! [`crate::engine::kv::PagedKv`]) makes the decisions; a
//! [`crate::parallel::StepCost`] model (built from a
//! [`crate::parallel::ParallelSpec`] by [`crate::parallel::cost_for`])
//! supplies step durations. Mixed prefill+decode batches, decode-only
//! batches at high concurrency, and KV-pressure effects all emerge from the
//! real allocator — the paper's §5.2.3 explanation of why NVRAR's gains
//! shrink at C=256 (bigger decode batches ⇒ bigger messages) is reproduced
//! mechanically.

use crate::cluster::Topology;
use crate::collectives::sim::CommConfig;
use crate::collectives::AllReduceImpl;
use crate::engine::batcher::{Batcher, Request, StepBatch};
use crate::engine::kv::PagedKv;
use crate::engine::persona::Persona;
use crate::metrics::Breakdown;
use crate::models::ModelConfig;
use crate::obs::ArgV;
use crate::parallel::{cost_for, CommSplit, OverlapSpec, ParallelSpec, StepCost, StepTiming};
use crate::perfmodel::GpuSpec;
use crate::simnet::{CongestionStats, EventQueue, Interconnect, LinkKind};
use crate::util::stats::Summary;
use std::sync::{Arc, Mutex};

/// Shared-fabric handle: one [`Interconnect`] shared by every replica (and
/// every transfer) of a simulation. `Arc<Mutex<…>>` so cloned
/// [`ServeConfig`]s reference the *same* fabric — the sharing is the
/// point.
pub type Fabric = Arc<Mutex<Interconnect>>;

/// Build a fabric pre-registered with one scope's links for `topo`.
pub fn fabric_for(scope: usize, topo: &Topology) -> Fabric {
    let mut net = Interconnect::new();
    net.add_scope(scope, topo.nodes, topo.intra.beta, topo.inter.beta);
    Arc::new(Mutex::new(net))
}

/// Serving configuration: the machine/model context plus the deployment's
/// [`StepCost`] model. Every replica of a fleet owns one of these, so
/// heterogeneous fleets are just different `ServeConfig`s side by side.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: ModelConfig,
    pub topo: Topology,
    pub gpu: GpuSpec,
    pub comm: CommConfig,
    pub persona: Persona,
    /// Per-step cost model of the deployment (see [`crate::parallel`]).
    pub cost: Arc<dyn StepCost>,
    /// Max request concurrency (the paper's C).
    pub max_concurrency: usize,
    /// Per-step token budget.
    pub max_step_tokens: usize,
    /// Per-sequence prefill chunk cap (0 = chunks bounded only by the
    /// step budget and KV availability). See [`crate::engine::batcher`].
    pub chunk_tokens: usize,
    /// KV pages (per TP group) and tokens per page.
    pub kv_pages: usize,
    pub kv_page_tokens: usize,
    /// Shared interconnect fabric. `None` (the default) prices every
    /// collective/transfer as if it had the fabric to itself — the
    /// closed-form behavior every pre-contention sweep pins. `Some`
    /// routes the step's collective bytes through per-link fair-share
    /// occupancy: step times inflate when the links are busy.
    pub net: Option<Fabric>,
    /// Link scope this deployment's nodes occupy on the fabric (a fleet
    /// assigns one scope per replica; standalone `serve` uses 0).
    pub net_scope: usize,
    /// Communication/computation overlap fractions per collective site.
    /// The default ([`OverlapSpec::none`]) prices everything serially —
    /// bit-for-bit the pre-overlap numbers.
    pub overlap: OverlapSpec,
    /// Event recorder ([`crate::obs`]) — `None` (the default) disables
    /// tracing entirely. Recording never feeds back into any simulated
    /// quantity: reports with tracing off are bit-for-bit identical.
    pub obs: Option<crate::obs::ObsSink>,
}

impl ServeConfig {
    /// Duration of one engine step for `step` under this deployment,
    /// ignoring fabric contention (also the routing-prediction path —
    /// never books bytes).
    pub fn step_time(&self, step: &StepBatch) -> f64 {
        self.cost.step_time(self, step)
    }

    /// Duration of one engine step launched at fabric time `at`: books the
    /// step's collective bytes on the shared fabric and adds the queueing
    /// delay. Identical to [`ServeConfig::step_time`] when `net` is `None`
    /// or the fabric is idle.
    pub fn step_time_at(&self, step: &StepBatch, at: f64) -> f64 {
        self.cost.step_time_at(self, step, at)
    }

    /// Full timing view of [`ServeConfig::step_time_at`]: the duration
    /// plus the exposed/hidden collective split and the bytes booked on
    /// the fabric (see [`StepTiming`]). The serving/fleet hot loops use
    /// this so exposed-vs-hidden accounting costs no extra pass.
    pub fn step_timing_at(&self, step: &StepBatch, at: f64) -> StepTiming {
        self.cost.step_timing_at(self, step, at)
    }

    /// Exposed/hidden decomposition of one step's closed-form collective
    /// time under this config's [`OverlapSpec`] (see [`CommSplit`]).
    pub fn step_comm(&self, step: &StepBatch) -> CommSplit {
        self.cost.step_comm(self, step)
    }

    /// Four-bucket decomposition of [`ServeConfig::step_time`] (same
    /// inputs, buckets summing back to it — see
    /// [`StepCost::step_breakdown`]).
    pub fn step_breakdown(&self, step: &StepBatch) -> Breakdown {
        self.cost.step_breakdown(self, step)
    }

    /// Enable the shared-interconnect contention layer with a fresh
    /// single-scope fabric for this deployment's topology. The fabric is
    /// consumed by one simulation run (callers may pre-book background
    /// traffic on it first — that is the contention experiments' lever).
    pub fn with_contention(mut self) -> Self {
        self.net = Some(fabric_for(0, &self.topo));
        self.net_scope = 0;
        self
    }

    /// Set the communication/computation overlap fractions (builder
    /// style; see [`OverlapSpec`]).
    pub fn with_overlap(mut self, overlap: OverlapSpec) -> Self {
        self.overlap = overlap;
        self
    }

    /// Canonical deployment string (e.g. `tp8-pp2/NVRAR`) for tables/CSVs.
    pub fn deployment_label(&self) -> String {
        self.cost.label()
    }

    /// Effective prefill chunk size: the configured cap, bounded by the
    /// step budget (0 = budget-bounded chunks).
    pub fn effective_chunk(&self) -> usize {
        if self.chunk_tokens == 0 {
            self.max_step_tokens
        } else {
            self.chunk_tokens.min(self.max_step_tokens)
        }
    }

    pub(crate) fn build_batcher(&self) -> Batcher {
        Batcher::new(self.max_concurrency, self.max_step_tokens)
            .with_chunk_tokens(self.chunk_tokens)
    }
}

/// Serving outcome metrics. TTFT is recorded at **last-chunk completion**:
/// under chunked prefill the first output token exists only once the whole
/// prompt has been processed, however many steps that took.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Output tokens per second — the Fig 9/10/18 Y-axis.
    pub output_throughput: f64,
    pub total_output_tokens: u64,
    pub makespan: f64,
    pub steps: u64,
    /// Mean time-to-first-token.
    pub mean_ttft: f64,
    /// TTFT percentiles across completed requests.
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    /// Median time per output token (completion − first token over
    /// produced − 1; single-token requests contribute 0).
    pub tpot_p50: f64,
    /// Fraction of steps that were decode-only (no prefill mixed in).
    pub decode_only_frac: f64,
    /// Sequences preempted (KV exhaustion / stuck prefill) and re-queued.
    /// Preemption re-produces work; it never drops tokens.
    pub preemptions: u64,
    /// Requests rejected at admission because their lifetime KV footprint
    /// exceeds the allocator (they could never complete).
    pub rejected: u64,
    /// Fraction of admitted prompt tokens served from the shared-prefix
    /// KV cache instead of recomputed (0 on workloads without sessions).
    pub cache_hit_rate: f64,
    /// Prompt tokens the prefix cache saved (GEMM rows never priced).
    pub cached_tokens: u64,
    /// Mean utilization of the fabric's intra-node links over the
    /// makespan (0 with contention disabled).
    pub net_util_intra: f64,
    /// Mean utilization of the fabric's inter-node links.
    pub net_util_inter: f64,
    /// Congestion-delay accounting across every fabric booking of the run
    /// (all-zero with contention disabled or an uncontended fabric).
    pub congestion: CongestionStats,
    /// Analytically accumulated Matmul/Other/Comm/Idle over the run
    /// (`Some` only when tracing was enabled; sums to the makespan).
    pub breakdown: Option<Breakdown>,
    /// Exposed collective seconds summed over every step (closed-form
    /// exposed comm plus unabsorbed fabric delay). Only accumulated when
    /// overlap or tracing is on — 0.0 on the fast path, like `breakdown`.
    pub comm_exposed: f64,
    /// Hidden collective seconds summed over every step (priced behind
    /// compute; their bytes still occupied the fabric). 0.0 on the fast
    /// path.
    pub comm_hidden: f64,
    /// Collective gigabytes booked on the shared fabric over the run —
    /// the *full* volume, hidden bytes included (0.0 with `net: None`).
    pub booked_gb: f64,
}

enum Ev {
    Arrival(usize),
    StepDone,
}

/// Run the trace through the deployment; returns serving metrics.
pub fn serve(cfg: &ServeConfig, reqs: &[Request]) -> ServeReport {
    let mut q: EventQueue<Ev> = EventQueue::new();
    for (i, r) in reqs.iter().enumerate() {
        q.push(r.arrival, Ev::Arrival(i));
    }
    let mut kv = PagedKv::new(cfg.kv_pages, cfg.kv_page_tokens);
    let mut batcher = cfg.build_batcher();
    let mut stepping = false;
    let mut current: Option<StepBatch> = None;
    let mut steps = 0u64;
    let mut decode_only = 0u64;
    let mut out_tokens = 0u64;
    let mut rejected = 0u64;
    let mut first_token: Vec<Option<f64>> = vec![None; reqs.len()];
    let mut produced: Vec<u32> = vec![0; reqs.len()];
    let mut ttft = Summary::new();
    let mut tpot = Summary::new();
    let mut last_done = 0.0f64;
    let mut comm_exposed = 0.0f64;
    let mut comm_hidden = 0.0f64;
    let mut booked_bytes = 0.0f64;
    // Tracing state: the replica's event track and the analytically
    // accumulated breakdown the event fold is reconciled against.
    let track = crate::obs::Track::Replica(cfg.net_scope);
    if let Some(sink) = &cfg.obs {
        let mut r = sink.lock().unwrap_or_else(|e| e.into_inner());
        if r.meta.label.is_empty() {
            r.meta.label = cfg.deployment_label();
        }
        if r.meta.model.is_empty() {
            r.meta.model = cfg.model.name.to_string();
        }
    }
    let mut analytic = Breakdown::default();

    while let Some((now, ev)) = q.pop() {
        match ev {
            Ev::Arrival(i) => {
                batcher.submit(reqs[i]);
                if let Some(sink) = &cfg.obs {
                    sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                        track,
                        "arrival",
                        now,
                        vec![
                            ("req", ArgV::U(reqs[i].id)),
                            ("prompt", ArgV::U(reqs[i].prompt_len as u64)),
                            ("decode", ArgV::U(reqs[i].decode_len as u64)),
                        ],
                    );
                }
            }
            Ev::StepDone => {
                stepping = false;
                let Some(step) = current.take() else {
                    debug_assert!(false, "StepDone with no step in flight");
                    continue;
                };
                let outcome = batcher.complete_step(&step, &mut kv);
                out_tokens += outcome.new_tokens as u64;
                // TTFT at last-chunk completion — only the first time (a
                // preempted sequence re-prefills, but its first token
                // already happened).
                for c in &step.prefills {
                    if c.last {
                        let i = c.id as usize;
                        if first_token[i].is_none() {
                            first_token[i] = Some(now);
                            if let Some(sink) = &cfg.obs {
                                sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                                    track,
                                    "first_token",
                                    now,
                                    vec![("req", ArgV::U(c.id))],
                                );
                            }
                        }
                        produced[i] += 1;
                    }
                }
                for id in &step.decodes {
                    produced[*id as usize] += 1;
                }
                for id in &outcome.preempted {
                    // The preempted row's pending token was discarded; it
                    // will be re-produced after the re-prefill.
                    produced[*id as usize] -= 1;
                }
                if let Some(sink) = &cfg.obs {
                    let mut r = sink.lock().unwrap_or_else(|e| e.into_inner());
                    for id in &outcome.preempted {
                        r.instant(track, "preempt", now, vec![("req", ArgV::U(*id))]);
                    }
                    r.instant(
                        track,
                        "toks",
                        now,
                        vec![("n", ArgV::U(outcome.new_tokens as u64))],
                    );
                    let frac = kv.used_pages() as f64 / kv.total_pages().max(1) as f64;
                    r.instant(track, "kv", now, vec![("frac", ArgV::F(frac))]);
                }
                for id in batcher.take_finished() {
                    let i = id as usize;
                    let Some(ft) = first_token[i] else {
                        debug_assert!(false, "finished request has a first token");
                        continue;
                    };
                    ttft.add(ft - reqs[i].arrival);
                    let toks = produced[i].max(1);
                    tpot.add(if toks > 1 { (now - ft) / (toks - 1) as f64 } else { 0.0 });
                    if let Some(sink) = &cfg.obs {
                        sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                            track,
                            "finish",
                            now,
                            vec![("req", ArgV::U(id)), ("out", ArgV::U(produced[i] as u64))],
                        );
                    }
                }
                last_done = now;
                batcher.recycle(step);
            }
        }
        if !stepping {
            if let Some(net) = &cfg.net {
                // Advance the fabric watermark with the event clock so
                // `book` can prune expired intervals; without this a long
                // contention run grows every link's active list without
                // bound. Pruned intervals end at or before `now`, and all
                // future bookings start at or after it, so nothing priced
                // changes.
                net.lock().unwrap_or_else(|e| e.into_inner()).advance(q.now());
            }
            let step = batcher.next_step(&mut kv);
            let rej = batcher.take_rejected();
            rejected += rej.len() as u64;
            if let Some(sink) = &cfg.obs {
                let mut r = sink.lock().unwrap_or_else(|e| e.into_inner());
                for id in &rej {
                    r.instant(track, "reject", now, vec![("req", ArgV::U(*id))]);
                }
            }
            if !step.is_empty() {
                let timing = cfg.step_timing_at(&step, q.now());
                let dur = timing.dur;
                steps += 1;
                comm_exposed += timing.comm_exposed;
                comm_hidden += timing.comm_hidden;
                booked_bytes += timing.booked_bytes;
                if step.prefills.is_empty() {
                    decode_only += 1;
                }
                if let Some(sink) = &cfg.obs {
                    // Per-step four-bucket decomposition; any fabric
                    // queueing delay beyond the closed-form step time is
                    // Comm. The span carries the same buckets the analytic
                    // accumulator sums, so the event fold reconciles
                    // bit-for-bit on the busy buckets.
                    let delay = (dur - timing.base).max(0.0);
                    let mut bd = cfg.step_breakdown(&step);
                    bd.comm += delay;
                    analytic.add(&bd);
                    let mut r = sink.lock().unwrap_or_else(|e| e.into_inner());
                    for c in &step.prefills {
                        r.instant(
                            track,
                            "chunk",
                            q.now(),
                            vec![
                                ("req", ArgV::U(c.id)),
                                ("tokens", ArgV::U(c.tokens as u64)),
                                ("ctx", ArgV::U(c.ctx as u64)),
                                ("last", ArgV::U(c.last as u64)),
                            ],
                        );
                    }
                    r.span(
                        track,
                        "step",
                        q.now(),
                        dur,
                        vec![
                            ("matmul", ArgV::F(bd.matmul)),
                            ("other", ArgV::F(bd.other_comp)),
                            ("comm", ArgV::F(bd.comm)),
                            ("idle", ArgV::F(bd.idle)),
                            ("rows", ArgV::U(step.token_rows() as u64)),
                            ("seqs", ArgV::U(step.seqs() as u64)),
                            ("hidden", ArgV::F(timing.comm_hidden)),
                            ("booked", ArgV::F(timing.booked_bytes)),
                        ],
                    );
                }
                stepping = true;
                q.push_in(dur, Ev::StepDone);
                current = Some(step);
            } else {
                batcher.recycle(step);
            }
        }
    }

    let pct = |s: &Summary, q: f64| if s.n() == 0 { 0.0 } else { s.percentile(q) };
    let kvs = kv.stats();
    let (net_util_intra, net_util_inter, congestion) = match &cfg.net {
        Some(net) => {
            let n = net.lock().unwrap_or_else(|e| e.into_inner());
            (
                n.utilization(LinkKind::Intra, last_done),
                n.utilization(LinkKind::Inter, last_done),
                n.stats().clone(),
            )
        }
        None => (0.0, 0.0, CongestionStats::default()),
    };
    let breakdown = cfg.obs.as_ref().map(|sink| {
        let mut r = sink.lock().unwrap_or_else(|e| e.into_inner());
        r.set_makespan(last_done);
        // Everything the steps did not cover is idle — the same gap the
        // event fold attributes from the recorded spans.
        let mut b = analytic;
        b.idle += (last_done - b.total()).max(0.0);
        b
    });
    ServeReport {
        output_throughput: out_tokens as f64 / last_done.max(1e-9),
        total_output_tokens: out_tokens,
        makespan: last_done,
        steps,
        mean_ttft: if ttft.n() == 0 { 0.0 } else { ttft.mean() },
        ttft_p50: pct(&ttft, 50.0),
        ttft_p99: pct(&ttft, 99.0),
        tpot_p50: pct(&tpot, 50.0),
        decode_only_frac: if steps == 0 { 0.0 } else { decode_only as f64 / steps as f64 },
        preemptions: batcher.preemptions(),
        rejected,
        cache_hit_rate: if kvs.prompt_tokens == 0 {
            0.0
        } else {
            kvs.hit_tokens as f64 / kvs.prompt_tokens as f64
        },
        cached_tokens: kvs.hit_tokens,
        net_util_intra,
        net_util_inter,
        congestion,
        breakdown,
        comm_exposed,
        comm_hidden,
        booked_gb: booked_bytes / 1e9,
    }
}

/// Standard config builder for the Fig 9/18 setups (70B on `machine`).
/// Panics if the machine is unknown or `spec` does not fit the
/// `machine`×`gpus` topology — CLI paths should resolve/`validate` first
/// for a usable error.
pub fn fig9_config(
    spec: ParallelSpec,
    ar: AllReduceImpl,
    concurrency: usize,
    machine: &str,
    gpus: usize,
) -> ServeConfig {
    let bundle = crate::calib::registry::resolve(machine)
        // lint: allow(P01) documented panic contract — CLI paths resolve first
        .unwrap_or_else(|e| panic!("fig9_config: {e}"));
    fig9_config_bundle(spec, ar, concurrency, &bundle, gpus)
}

/// [`fig9_config`] over an already-resolved calibration bundle: topology,
/// roofline and comm constants all come from the same bundle.
pub fn fig9_config_bundle(
    spec: ParallelSpec,
    ar: AllReduceImpl,
    concurrency: usize,
    bundle: &crate::calib::MachineBundle,
    gpus: usize,
) -> ServeConfig {
    let topo = bundle.topo.topology(1).with_gpus(gpus);
    if let Err(e) = spec.validate(&topo) {
        // lint: allow(P01) documented panic contract — CLI paths validate first
        panic!("fig9_config: {e}");
    }
    ServeConfig {
        model: ModelConfig::llama31_70b(),
        topo,
        gpu: bundle.gpu,
        comm: bundle.comm,
        persona: Persona::vllm_v1(),
        cost: cost_for(spec, ar),
        max_concurrency: concurrency,
        max_step_tokens: 8192,
        chunk_tokens: 0,
        kv_pages: 60_000,
        kv_page_tokens: 16,
        net: None,
        net_scope: 0,
        obs: None,
        overlap: OverlapSpec::none(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::HybridTpPp;
    use crate::trace::TraceSpec;
    use crate::util::prop::{check, Gen};

    fn small_trace(n: usize) -> Vec<Request> {
        let mut spec = TraceSpec::burstgpt();
        spec.num_prompts = n;
        spec.generate()
    }

    fn tp16(ar: AllReduceImpl, concurrency: usize) -> ServeConfig {
        fig9_config(ParallelSpec::tp(16), ar, concurrency, "perlmutter", 16)
    }

    #[test]
    fn serve_completes_all_requests() {
        let cfg = tp16(AllReduceImpl::NcclAuto, 32);
        let reqs = small_trace(40);
        let rep = serve(&cfg, &reqs);
        let expected: u64 = reqs.iter().map(|r| r.decode_len as u64).sum();
        assert_eq!(rep.total_output_tokens, expected);
        assert!(rep.makespan > 0.0 && rep.output_throughput > 0.0);
    }

    #[test]
    fn nvrar_tp_beats_nccl_tp_throughput() {
        let reqs = small_trace(40);
        let nccl = serve(&tp16(AllReduceImpl::NcclAuto, 32), &reqs);
        let nvrar = serve(&tp16(AllReduceImpl::Nvrar, 32), &reqs);
        let gain = nvrar.output_throughput / nccl.output_throughput;
        assert!(gain > 1.02, "NVRAR throughput gain {gain}");
    }

    #[test]
    fn higher_concurrency_more_decode_only_steps() {
        // §5.2.3: at higher C, prefills finish earlier -> decode-only
        // batches dominate.
        let reqs = small_trace(60);
        let lo = serve(&tp16(AllReduceImpl::NcclAuto, 4), &reqs);
        let hi = serve(&tp16(AllReduceImpl::NcclAuto, 64), &reqs);
        assert!(
            hi.decode_only_frac >= lo.decode_only_frac * 0.95,
            "lo {} hi {}",
            lo.decode_only_frac,
            hi.decode_only_frac
        );
    }

    #[test]
    fn ttft_improves_with_concurrency() {
        let reqs = small_trace(50);
        let lo = serve(&tp16(AllReduceImpl::NcclAuto, 2), &reqs);
        let hi = serve(&tp16(AllReduceImpl::NcclAuto, 64), &reqs);
        assert!(hi.mean_ttft < lo.mean_ttft, "{} vs {}", lo.mean_ttft, hi.mean_ttft);
    }

    #[test]
    fn hybrid_splits_run_including_ones_hp_could_not_express() {
        let reqs = small_trace(20);
        // tp4-pp4 is the old HP shape on Perlmutter-16; tp8-pp2 (TP group
        // spanning two nodes) and tp4-pp2-dp2 were inexpressible before.
        for name in ["tp4-pp4", "tp8-pp2", "tp4-pp2-dp2", "tp2-pp8"] {
            let spec = ParallelSpec::by_name(name).unwrap();
            let cfg = fig9_config(spec, AllReduceImpl::NcclAuto, 32, "perlmutter", 16);
            let rep = serve(&cfg, &reqs);
            assert!(
                rep.output_throughput.is_finite() && rep.output_throughput > 0.0,
                "{name}: {rep:?}"
            );
            let expected: u64 = reqs.iter().map(|r| r.decode_len as u64).sum();
            assert_eq!(rep.total_output_tokens, expected, "{name}");
        }
    }

    #[test]
    fn pure_tp_beats_one_in_flight_pipeline_on_decode() {
        // The paper's headline comparison: TP (K-split keeps scaling decode
        // GEMMs) beats the bubble-dominated hybrid on the same 16 GPUs.
        let reqs = small_trace(30);
        let tp = serve(&tp16(AllReduceImpl::NcclAuto, 32), &reqs);
        let hp = serve(
            &fig9_config(ParallelSpec::tp_pp(4, 4), AllReduceImpl::NcclAuto, 32, "perlmutter", 16),
            &reqs,
        );
        assert!(
            tp.output_throughput > hp.output_throughput,
            "tp16 {} vs tp4-pp4 {}",
            tp.output_throughput,
            hp.output_throughput
        );
    }

    #[test]
    fn micro_batching_helps_prefill_but_not_decode() {
        let base =
            fig9_config(ParallelSpec::tp_pp(4, 4), AllReduceImpl::NcclAuto, 32, "perlmutter", 16);
        let m1 = HybridTpPp::new(ParallelSpec::tp_pp(4, 4), AllReduceImpl::NcclAuto);
        let m4 = m1.with_micro_batches(4);
        let prefill = StepBatch {
            prefills: vec![crate::engine::batcher::PrefillChunk {
                id: 0,
                tokens: 4096,
                ctx: 4096,
                last: true,
            }],
            decodes: vec![],
            decode_ctx: vec![],
        };
        use crate::parallel::StepCost;
        assert!(
            m4.step_time(&base, &prefill) < m1.step_time(&base, &prefill),
            "micro-batching must shrink the prefill pipeline bubble"
        );
        let decode = StepBatch {
            prefills: vec![],
            decodes: (0..32u64).collect(),
            decode_ctx: vec![1024; 32],
        };
        // Observation 2: decode GEMMs sit at the M-tile floor, so slicing
        // the batch re-streams weights without shrinking stage time.
        assert!(
            m4.step_time(&base, &decode) >= m1.step_time(&base, &decode) * 0.99,
            "micro-batching must not help decode"
        );
    }

    #[test]
    fn step_cost_scales_with_real_kv_context() {
        // Satellite of the redesign: the attention roofline reads the
        // batch's actual context lengths, not a hardcoded 1024.
        let cfg = tp16(AllReduceImpl::NcclAuto, 32);
        let short = StepBatch {
            prefills: vec![],
            decodes: (0..32u64).collect(),
            decode_ctx: vec![128; 32],
        };
        let long = StepBatch {
            prefills: vec![],
            decodes: (0..32u64).collect(),
            decode_ctx: vec![8192; 32],
        };
        assert!(
            cfg.step_time(&long) > cfg.step_time(&short),
            "KV growth must slow the step: {} vs {}",
            cfg.step_time(&long),
            cfg.step_time(&short)
        );
    }

    #[test]
    fn serve_terminates_on_prompts_longer_than_the_step_budget() {
        // Regression for the admission bug: a prompt > max_step_tokens
        // used to be unadmittable — `serve` head-of-line-stalled and the
        // request (plus everything queued behind it) was silently dropped.
        let cfg = tp16(AllReduceImpl::NcclAuto, 16);
        assert_eq!(cfg.max_step_tokens, 8192);
        let mut reqs = small_trace(20);
        // Four prompts up to 4x the step budget, interleaved with the rest.
        for (i, len) in [(3usize, 32_768usize), (7, 20_000), (11, 9000), (15, 16_384)] {
            reqs[i].prompt_len = len;
        }
        let rep = serve(&cfg, &reqs);
        let expected: u64 = reqs.iter().map(|r| r.decode_len as u64).sum();
        assert_eq!(rep.total_output_tokens, expected, "zero lost tokens");
        assert_eq!(rep.rejected, 0);
        assert!(rep.ttft_p50 <= rep.ttft_p99);
        assert!(rep.tpot_p50 >= 0.0);
    }

    #[test]
    fn chunking_tightens_ttft_tail_on_long_prompt_trace() {
        // Whole-prompt admission (budget large enough to swallow the
        // longest prompt) runs monolithic multi-10k-token prefill steps
        // that block every decode; bounded chunks interleave, so the TTFT
        // tail of the requests queued behind the monsters tightens while
        // median TPOT stays within noise.
        let mut spec = TraceSpec::long_prompt();
        spec.num_prompts = 80;
        let reqs = spec.generate();
        let mut whole = tp16(AllReduceImpl::NcclAuto, 32);
        whole.max_step_tokens = 40_960; // the longest prompt fits whole
        let mut chunked = whole.clone();
        chunked.chunk_tokens = 2048;
        let w = serve(&whole, &reqs);
        let c = serve(&chunked, &reqs);
        let expected: u64 = reqs.iter().map(|r| r.decode_len as u64).sum();
        assert_eq!(w.total_output_tokens, expected);
        assert_eq!(c.total_output_tokens, expected);
        assert!(
            c.ttft_p99 < w.ttft_p99,
            "chunked TTFT p99 {} must beat whole-prompt {}",
            c.ttft_p99,
            w.ttft_p99
        );
        assert!(
            c.tpot_p50 < w.tpot_p50 * 1.05,
            "chunking must not regress TPOT p50 by >5%: {} vs {}",
            c.tpot_p50,
            w.tpot_p50
        );
    }

    #[test]
    fn unshared_trace_reports_zero_cache_hits_and_unchanged_totals() {
        // The zero-sharing contract of the shared-prefix refactor: on a
        // trace of solo sessions the allocator behaves exactly like the
        // exclusive-ownership one — nothing cached is ever hit, and every
        // pre-refactor total (tokens, steps, determinism) holds.
        let cfg = tp16(AllReduceImpl::NcclAuto, 32);
        let reqs = small_trace(40);
        let a = serve(&cfg, &reqs);
        assert_eq!(a.cache_hit_rate, 0.0);
        assert_eq!(a.cached_tokens, 0);
        let expected: u64 = reqs.iter().map(|r| r.decode_len as u64).sum();
        assert_eq!(a.total_output_tokens, expected);
        let b = serve(&cfg, &reqs);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    }

    #[test]
    fn session_trace_hits_the_prefix_cache_and_tightens_ttft() {
        // Multi-turn sessions: later turns share the growing conversation
        // prefix, so prefill work shrinks and TTFT drops vs the identical
        // trace with sharing stripped (every request a solo session).
        let mut sspec = crate::trace::SessionSpec::standard();
        sspec.sessions = 20;
        sspec.turns = 5;
        let shared = sspec.generate();
        let mut solo = shared.clone();
        for r in &mut solo {
            r.session = crate::engine::batcher::Request::solo_session(r.id);
        }
        let cfg = tp16(AllReduceImpl::NcclAuto, 32);
        let s = serve(&cfg, &shared);
        let u = serve(&cfg, &solo);
        let expected: u64 = shared.iter().map(|r| r.decode_len as u64).sum();
        assert_eq!(s.total_output_tokens, expected, "sharing must not lose tokens");
        assert_eq!(u.total_output_tokens, expected);
        assert!(s.cache_hit_rate > 0.3, "hit rate {}", s.cache_hit_rate);
        assert!(s.cached_tokens > 0);
        assert_eq!(u.cache_hit_rate, 0.0);
        assert!(
            s.ttft_p50 < u.ttft_p50,
            "cached prefills must cut TTFT p50: {} vs {}",
            s.ttft_p50,
            u.ttft_p50
        );
    }

    #[test]
    fn contention_enabled_idle_fabric_reproduces_closed_form_serving() {
        // The parity contract: turning the contention layer ON without any
        // concurrent traffic books every collective byte on the fabric but
        // changes no step time — the report is bit-identical to the
        // closed-form run, and not a single booking is delayed.
        let reqs = small_trace(30);
        let plain = serve(&tp16(AllReduceImpl::Nvrar, 32), &reqs);
        let idle = serve(&tp16(AllReduceImpl::Nvrar, 32).with_contention(), &reqs);
        assert_eq!(plain.makespan.to_bits(), idle.makespan.to_bits());
        assert_eq!(plain.total_output_tokens, idle.total_output_tokens);
        assert_eq!(plain.steps, idle.steps);
        assert!(idle.congestion.bookings > 0, "the fabric must see the traffic");
        assert_eq!(idle.congestion.delayed, 0, "an idle fabric never delays");
        assert_eq!(idle.congestion.total_delay, 0.0);
        assert!(idle.net_util_inter > 0.0, "collective bytes must register on the NICs");
        assert_eq!(plain.congestion.bookings, 0, "disabled layer books nothing");
    }

    #[test]
    fn tracing_is_zero_cost_and_reconciles_with_the_event_fold() {
        use crate::obs::{fold, Recorder, RunMeta};
        let reqs = small_trace(30);
        let plain = serve(&tp16(AllReduceImpl::Nvrar, 32), &reqs);
        assert!(plain.breakdown.is_none(), "tracing off reports no breakdown");
        let sink = Recorder::sink(RunMeta::default());
        let mut cfg = tp16(AllReduceImpl::Nvrar, 32);
        cfg.obs = Some(sink.clone());
        let traced = serve(&cfg, &reqs);
        // Zero-cost contract: recording changes nothing simulated.
        assert_eq!(plain.makespan.to_bits(), traced.makespan.to_bits());
        assert_eq!(plain.total_output_tokens, traced.total_output_tokens);
        assert_eq!(plain.steps, traced.steps);
        let bd = traced.breakdown.expect("tracing on reports a breakdown");
        assert!((bd.total() - traced.makespan).abs() < 1e-6 * traced.makespan);
        let rec = sink.lock().unwrap();
        assert_eq!(rec.meta.label, "tp16/NVRAR");
        assert_eq!(rec.meta.model, "Llama-3.1-70B");
        assert_eq!(rec.spans().len() as u64, traced.steps);
        let folded = fold::fold_breakdowns(&rec);
        let drift = fold::reconcile(&[bd], &folded, rec.makespan());
        assert!(drift < 1e-6, "event fold drifted {drift} from the analytic breakdown");
    }

    #[test]
    fn background_transfers_on_shared_links_inflate_serving() {
        // Concurrent migration-sized transfers on the inter-node NIC slow
        // every decode all-reduce: same trace, strictly longer makespan,
        // counted congestion — and still deterministic.
        use crate::simnet::{LinkId, LinkKind};
        let reqs = small_trace(30);
        let busy_cfg = || {
            let cfg = tp16(AllReduceImpl::Nvrar, 32).with_contention();
            {
                let net = cfg.net.as_ref().expect("contention enabled");
                let mut net = net.lock().unwrap();
                let link = LinkId { scope: 0, node: 0, kind: LinkKind::Inter };
                let mut t = 0.0;
                for _ in 0..1500 {
                    // Back-to-back 256 MB drain-migration-sized flows:
                    // continuous single-flow background occupancy over the
                    // first ~17 s — every step in that window contends.
                    t = net.book(link, t, 256.0 * 1024.0 * 1024.0).end;
                }
            }
            cfg
        };
        let base = serve(&tp16(AllReduceImpl::Nvrar, 32).with_contention(), &reqs);
        let busy = serve(&busy_cfg(), &reqs);
        assert_eq!(base.total_output_tokens, busy.total_output_tokens);
        assert!(busy.congestion.delayed > 0, "shared links must register contention");
        assert!(busy.congestion.total_delay > 0.0);
        assert!(
            busy.makespan > base.makespan,
            "contended fabric must slow serving: {} vs {}",
            busy.makespan,
            base.makespan
        );
        let again = serve(&busy_cfg(), &reqs);
        assert_eq!(busy.makespan.to_bits(), again.makespan.to_bits(), "still deterministic");
    }

    #[test]
    fn property_valid_specs_conserve_tokens_and_are_deterministic() {
        check("parallel specs conserve output tokens", 12, |g: &mut Gen| {
            let gpus = *g.pick(&[4usize, 8, 16]);
            let topo = crate::cluster::presets::perlmutter(1).with_gpus(gpus);
            let specs: Vec<ParallelSpec> = ParallelSpec::enumerate(gpus, false)
                .into_iter()
                .filter(|s| s.validate(&topo).is_ok())
                .collect();
            let spec = *g.pick(&specs);
            let ar = *g.pick(&AllReduceImpl::all());
            let mut tspec = TraceSpec::burstgpt();
            tspec.num_prompts = g.usize(8, 24);
            tspec.seed = g.u64(1, 1 << 20);
            let reqs = tspec.generate();
            let cfg = fig9_config(spec, ar, 16, "perlmutter", gpus);
            let a = serve(&cfg, &reqs);
            let expected: u64 = reqs.iter().map(|r| r.decode_len as u64).sum();
            assert_eq!(a.total_output_tokens, expected, "{spec} lost tokens");
            let b = serve(&cfg, &reqs);
            assert_eq!(a.total_output_tokens, b.total_output_tokens, "{spec}");
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{spec} not deterministic");
        });
    }
}
