//! Trace-driven serving simulation (Figs 9, 10, 18): request router +
//! continuous batching + per-step engine costs, driven by the discrete-
//! event queue.
//!
//! The real scheduling machinery ([`crate::engine::batcher::Batcher`] and
//! [`crate::engine::kv::PagedKv`]) makes the decisions; the α-β/roofline
//! models supply step durations. Mixed prefill+decode batches, decode-only
//! batches at high concurrency, and KV-pressure effects all emerge from the
//! real allocator — the paper's §5.2.3 explanation of why NVRAR's gains
//! shrink at C=256 (bigger decode batches ⇒ bigger messages) is reproduced
//! mechanically.

use crate::cluster::Topology;
use crate::collectives::sim::{allreduce, CommConfig};
use crate::collectives::AllReduceImpl;
use crate::engine::batcher::{Batcher, Request, StepBatch};
use crate::engine::kv::PagedKv;
use crate::engine::persona::Persona;
use crate::models::ModelConfig;
use crate::perfmodel::{self, GpuSpec};
use crate::simnet::EventQueue;

/// Deployment shape for serving.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Deployment {
    /// Pure TP over all GPUs with the given all-reduce implementation.
    Tp(AllReduceImpl),
    /// Hybrid: TP within a node, PP across nodes (NCCL).
    Hp,
}

impl Deployment {
    pub fn label(&self) -> String {
        match self {
            Deployment::Tp(ar) => format!("TP/{}", ar.name()),
            Deployment::Hp => "HP".to_string(),
        }
    }
}

/// Serving configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: ModelConfig,
    pub topo: Topology,
    pub gpu: GpuSpec,
    pub comm: CommConfig,
    pub persona: Persona,
    pub deployment: Deployment,
    /// Max request concurrency (the paper's C).
    pub max_concurrency: usize,
    /// Per-step token budget.
    pub max_step_tokens: usize,
    /// KV pages (per TP group) and tokens per page.
    pub kv_pages: usize,
    pub kv_page_tokens: usize,
}

/// Serving outcome metrics.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Output tokens per second — the Fig 9/10/18 Y-axis.
    pub output_throughput: f64,
    pub total_output_tokens: u64,
    pub makespan: f64,
    pub steps: u64,
    /// Mean time-to-first-token.
    pub mean_ttft: f64,
    /// Fraction of steps that were decode-only (no prefill mixed in).
    pub decode_only_frac: f64,
}

enum Ev {
    Arrival(usize),
    StepDone,
}

/// Run the trace through the deployment; returns serving metrics.
pub fn serve(cfg: &ServeConfig, reqs: &[Request]) -> ServeReport {
    serve_with(cfg, reqs, |c, s| step_time(c, s))
}

/// [`serve`] with a custom step timer (the MoE deployments of Fig 10 plug
/// their own per-step cost model in here).
pub fn serve_with<F>(cfg: &ServeConfig, reqs: &[Request], step_timer: F) -> ServeReport
where
    F: Fn(&ServeConfig, &StepBatch) -> f64,
{
    let mut q: EventQueue<Ev> = EventQueue::new();
    for (i, r) in reqs.iter().enumerate() {
        q.push(r.arrival, Ev::Arrival(i));
    }
    let mut kv = PagedKv::new(cfg.kv_pages, cfg.kv_page_tokens);
    let mut batcher = Batcher::new(cfg.max_concurrency, cfg.max_step_tokens);
    let mut stepping = false;
    let mut current: Option<StepBatch> = None;
    let mut steps = 0u64;
    let mut decode_only = 0u64;
    let mut out_tokens = 0u64;
    let mut first_token: Vec<Option<f64>> = vec![None; reqs.len()];
    let mut last_done = 0.0f64;

    while let Some((now, ev)) = q.pop() {
        match ev {
            Ev::Arrival(i) => {
                batcher.submit(reqs[i]);
            }
            Ev::StepDone => {
                stepping = false;
                let step = current.take().expect("step in flight");
                // Account produced tokens: one per decode + one per prefill
                // (its first output token).
                out_tokens += (step.decodes.len() + step.prefills.len()) as u64;
                for (id, _) in &step.prefills {
                    first_token[*id as usize] = Some(now);
                }
                batcher.complete_step(&step, &mut kv, reqs);
                batcher.take_finished();
                last_done = now;
            }
        }
        if !stepping {
            let step = batcher.next_step(&mut kv);
            if !step.is_empty() {
                let dur = step_timer(cfg, &step);
                steps += 1;
                if step.prefills.is_empty() {
                    decode_only += 1;
                }
                stepping = true;
                q.push_in(dur, Ev::StepDone);
                current = Some(step);
            }
        }
    }

    let ttfts: Vec<f64> = reqs
        .iter()
        .zip(&first_token)
        .filter_map(|(r, ft)| ft.map(|t| t - r.arrival))
        .collect();
    let mean_ttft =
        if ttfts.is_empty() { 0.0 } else { ttfts.iter().sum::<f64>() / ttfts.len() as f64 };
    ServeReport {
        output_throughput: out_tokens as f64 / last_done.max(1e-9),
        total_output_tokens: out_tokens,
        makespan: last_done,
        steps,
        mean_ttft,
        decode_only_frac: if steps == 0 { 0.0 } else { decode_only as f64 / steps as f64 },
    }
}

/// Duration of one engine step for the given batch under the deployment.
pub fn step_time(cfg: &ServeConfig, step: &StepBatch) -> f64 {
    let rows = step.token_rows().max(1);
    let kv_len = 1024; // mean context length during serving
    match cfg.deployment {
        Deployment::Tp(ar) => {
            let tp = cfg.topo.total_gpus();
            let lt =
                perfmodel::layer_times(&cfg.gpu, &cfg.model, tp, rows, kv_len, step.decodes.len().max(1));
            let msg = (rows * cfg.model.d_model * cfg.model.dtype_bytes) as u64;
            let gap = lt.total() / 2.0;
            let ar_t = if tp > 1 {
                allreduce(ar, &cfg.topo, &cfg.comm, msg, gap).total
            } else {
                0.0
            };
            let l = cfg.model.n_layers as f64;
            l * (lt.total() / cfg.persona.compute_efficiency + 2.0 * ar_t)
                + cfg.persona.step_overhead
        }
        Deployment::Hp => {
            // Decode-phase pipeline with ONE batch in flight — what the
            // paper's engines actually did (vLLM PP; Fig 3 shows the
            // resulting idle): a token's step traverses all S stages
            // sequentially, so the full-batch step is S · stage_time(rows)
            // = L · layer(tp_intra, rows) + S · (p2p + stage sync), and
            // (S-1)/S of every GPU-second is pipeline bubble. Micro-batch
            // interleaving cannot win back the weight-streaming: decode
            // GEMMs sit at the M-tile floor (Observation 2), and each
            // micro-batch re-streams the stage's weights.
            let stages = cfg.topo.nodes.max(1);
            let tp = cfg.topo.gpus_per_node;
            let tp_topo = cfg.topo.with_gpus(tp);
            let lt = perfmodel::layer_times(&cfg.gpu, &cfg.model, tp, rows, kv_len, step.decodes.len().max(1));
            let msg = (rows * cfg.model.d_model * cfg.model.dtype_bytes) as u64;
            let ar_t = if tp > 1 {
                allreduce(AllReduceImpl::NcclAuto, &tp_topo, &cfg.comm, msg, lt.total() / 2.0).total
            } else {
                0.0
            };
            let p2p = cfg
                .topo
                .inter
                .xfer_time((rows * cfg.model.d_model * cfg.model.dtype_bytes) as u64)
                + cfg.persona.p2p_overhead;
            cfg.model.n_layers as f64
                * (lt.total() / cfg.persona.compute_efficiency + 2.0 * ar_t)
                + stages as f64 * p2p
                + cfg.persona.step_overhead
        }
    }
}

/// Standard config builder for the Fig 9/18 setups (70B on Perlmutter).
pub fn fig9_config(
    deployment: Deployment,
    concurrency: usize,
    machine: &str,
    gpus: usize,
) -> ServeConfig {
    let topo = crate::cluster::presets::by_name(machine, 1).with_gpus(gpus);
    ServeConfig {
        model: ModelConfig::llama31_70b(),
        topo,
        gpu: GpuSpec::for_machine(machine),
        comm: CommConfig::for_machine(machine),
        persona: Persona::vllm_v1(),
        deployment,
        max_concurrency: concurrency,
        max_step_tokens: 8192,
        kv_pages: 60_000,
        kv_page_tokens: 16,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceSpec;

    fn small_trace(n: usize) -> Vec<Request> {
        let mut spec = TraceSpec::burstgpt();
        spec.num_prompts = n;
        spec.generate()
    }

    #[test]
    fn serve_completes_all_requests() {
        let cfg = fig9_config(Deployment::Tp(AllReduceImpl::NcclAuto), 32, "perlmutter", 16);
        let reqs = small_trace(40);
        let rep = serve(&cfg, &reqs);
        let expected: u64 = reqs.iter().map(|r| r.decode_len as u64).sum();
        assert_eq!(rep.total_output_tokens, expected);
        assert!(rep.makespan > 0.0 && rep.output_throughput > 0.0);
    }

    #[test]
    fn nvrar_tp_beats_nccl_tp_throughput() {
        let reqs = small_trace(40);
        let nccl = serve(
            &fig9_config(Deployment::Tp(AllReduceImpl::NcclAuto), 32, "perlmutter", 16),
            &reqs,
        );
        let nvrar = serve(
            &fig9_config(Deployment::Tp(AllReduceImpl::Nvrar), 32, "perlmutter", 16),
            &reqs,
        );
        let gain = nvrar.output_throughput / nccl.output_throughput;
        assert!(gain > 1.02, "NVRAR throughput gain {gain}");
    }

    #[test]
    fn higher_concurrency_more_decode_only_steps() {
        // §5.2.3: at higher C, prefills finish earlier -> decode-only
        // batches dominate.
        let reqs = small_trace(60);
        let lo = serve(&fig9_config(Deployment::Tp(AllReduceImpl::NcclAuto), 4, "perlmutter", 16), &reqs);
        let hi = serve(&fig9_config(Deployment::Tp(AllReduceImpl::NcclAuto), 64, "perlmutter", 16), &reqs);
        assert!(
            hi.decode_only_frac >= lo.decode_only_frac * 0.95,
            "lo {} hi {}",
            lo.decode_only_frac,
            hi.decode_only_frac
        );
    }

    #[test]
    fn ttft_improves_with_concurrency() {
        let reqs = small_trace(50);
        let lo = serve(&fig9_config(Deployment::Tp(AllReduceImpl::NcclAuto), 2, "perlmutter", 16), &reqs);
        let hi = serve(&fig9_config(Deployment::Tp(AllReduceImpl::NcclAuto), 64, "perlmutter", 16), &reqs);
        assert!(hi.mean_ttft < lo.mean_ttft, "{} vs {}", lo.mean_ttft, hi.mean_ttft);
    }

    #[test]
    fn hp_step_time_finite() {
        let cfg = fig9_config(Deployment::Hp, 32, "perlmutter", 16);
        let reqs = small_trace(20);
        let rep = serve(&cfg, &reqs);
        assert!(rep.output_throughput.is_finite() && rep.output_throughput > 0.0);
    }
}
