//! Composable parallelism specs and per-step cost models — the single
//! vocabulary every layer (serving, MoE, fleet, CLI, benches) uses to
//! describe a deployment.
//!
//! The paper's central comparison is between model-parallel *schemes*
//! (pure TP vs hybrid TP+PP, dense vs MoE EP layouts, §4–§5, Fig 10).
//! [`ParallelSpec`] names a scheme as one `tp × pp × dp (× ep)` tuple,
//! validated against the cluster [`Topology`] (node-boundary-aware
//! placement); the [`StepCost`] trait turns a spec into a per-engine-step
//! duration, with three first-class implementations:
//!
//! - [`DenseTp`] — pure tensor parallelism over every GPU (the paper's
//!   YALIS-style deployment), one all-reduce pair per layer.
//! - [`HybridTpPp`] — any TP×PP(×DP) split with configurable
//!   micro-batching. Micro-batching cannot win back decode time because
//!   decode GEMMs sit at the M-tile floor (Observation 2) — the roofline
//!   in [`crate::perfmodel`] makes that emerge rather than being asserted.
//! - [`crate::moe::MoeCost`] — expert-parallel MoE layers composed with
//!   TP×DP(×PP) attention (Fig 10's deployments).
//!
//! [`cost_for`] dispatches a spec to the right implementation; everything
//! downstream holds an `Arc<dyn StepCost>` inside
//! [`crate::serving::ServeConfig`], so heterogeneous fleets mix replicas
//! with different specs (and GPU counts) freely.

use crate::cluster::{LinkParams, Topology};
use crate::collectives::sim::allreduce;
use crate::collectives::AllReduceImpl;
use crate::engine::batcher::StepBatch;
use crate::metrics::Breakdown;
use crate::perfmodel;
use crate::serving::ServeConfig;
use std::fmt;
use std::sync::Arc;

/// One parallelism layout: `tp · pp · dp` GPUs, with `ep`-way expert
/// parallelism for MoE layers (1 = dense / no EP).
///
/// Canonical string form (round-trips through [`ParallelSpec::by_name`]):
/// `tp16`, `tp8-pp2`, `tp4-pp2-dp2`, `tp8-dp2-ep16` — dimensions equal to
/// 1 are omitted (except `tp`, always printed).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ParallelSpec {
    /// Tensor-parallel degree (K-split of every GEMM; one all-reduce pair
    /// per layer).
    pub tp: usize,
    /// Pipeline stages (1 = no PP).
    pub pp: usize,
    /// Data-parallel replicas of the dense/attention layers.
    pub dp: usize,
    /// Expert-parallel degree of the MoE layers; may exceed `tp·pp` (the
    /// EP group then spans DP replicas) but never `tp·pp·dp`.
    pub ep: usize,
}

impl ParallelSpec {
    /// Pure TP over `n` GPUs.
    pub fn tp(n: usize) -> Self {
        ParallelSpec { tp: n, pp: 1, dp: 1, ep: 1 }
    }

    /// Hybrid TP-within-stage, PP-across-stages.
    pub fn tp_pp(tp: usize, pp: usize) -> Self {
        ParallelSpec { tp, pp, dp: 1, ep: 1 }
    }

    /// MoE layout: TP×DP attention with `ep`-way expert parallelism.
    pub fn moe(tp: usize, dp: usize, ep: usize) -> Self {
        ParallelSpec { tp, pp: 1, dp, ep }
    }

    /// GPUs this spec occupies.
    pub fn gpus(&self) -> usize {
        self.tp * self.pp * self.dp
    }

    /// Canonical name, e.g. `tp8-pp2` (see type-level docs).
    pub fn label(&self) -> String {
        let mut s = format!("tp{}", self.tp);
        if self.pp > 1 {
            s.push_str(&format!("-pp{}", self.pp));
        }
        if self.dp > 1 {
            s.push_str(&format!("-dp{}", self.dp));
        }
        if self.ep > 1 {
            s.push_str(&format!("-ep{}", self.ep));
        }
        s
    }

    /// Parse a spec name: `-`-separated `tp<N>`/`pp<N>`/`dp<N>`/`ep<N>`
    /// parts, `tp` mandatory, the rest defaulting to 1. As a convenience,
    /// `ep` larger than the listed `tp·pp·dp` implies the missing DP
    /// replicas (`tp8-ep16` ⇒ `tp8-dp2-ep16`, the Fig 10 convention);
    /// an *explicit* `dp` is never overridden.
    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        let lower = name.trim().to_ascii_lowercase();
        let mut spec = ParallelSpec { tp: 0, pp: 1, dp: 1, ep: 1 };
        let mut seen = [false; 4]; // tp, pp, dp, ep
        let complain = || {
            anyhow::anyhow!(
                "bad parallel spec '{name}' (expected e.g. tp16, tp8-pp2, tp4-pp2-dp2, tp8-ep16)"
            )
        };
        for part in lower.split('-') {
            if part.len() < 3 || !part.is_char_boundary(2) {
                return Err(complain());
            }
            let (key, digits) = part.split_at(2);
            let n: usize = digits.parse().map_err(|_| complain())?;
            if n == 0 {
                anyhow::bail!("parallel spec '{name}': degree 0 in '{part}'");
            }
            let idx = match key {
                "tp" => 0,
                "pp" => 1,
                "dp" => 2,
                "ep" => 3,
                _ => return Err(complain()),
            };
            if seen[idx] {
                anyhow::bail!("parallel spec '{name}': duplicate '{key}'");
            }
            seen[idx] = true;
            match idx {
                0 => spec.tp = n,
                1 => spec.pp = n,
                2 => spec.dp = n,
                _ => spec.ep = n,
            }
        }
        if !seen[0] {
            anyhow::bail!("parallel spec '{name}': missing mandatory 'tp<N>'");
        }
        if spec.ep > spec.gpus() {
            let group = spec.tp * spec.pp;
            if seen[2] || spec.ep % group != 0 {
                anyhow::bail!(
                    "parallel spec '{name}': ep{} exceeds tp·pp·dp = {}",
                    spec.ep,
                    spec.gpus()
                );
            }
            spec.dp = spec.ep / group;
        }
        Ok(spec)
    }

    /// Validate the spec against a topology: the GPU grid must be fully
    /// used (`tp·pp·dp == gpus`), TP groups must align to node boundaries
    /// (within one node, or spanning whole nodes), and the EP group must
    /// tile the GPU grid.
    pub fn validate(&self, topo: &Topology) -> anyhow::Result<()> {
        if self.tp == 0 || self.pp == 0 || self.dp == 0 || self.ep == 0 {
            anyhow::bail!("parallel spec {self}: degrees must be >= 1");
        }
        let gpus = topo.total_gpus();
        if self.gpus() != gpus {
            anyhow::bail!(
                "parallel spec {self} needs tp·pp·dp = {} GPUs but the topology has {gpus}",
                self.gpus()
            );
        }
        let gpn = topo.gpus_per_node.max(1);
        if self.tp > gpn && self.tp % gpn != 0 {
            anyhow::bail!(
                "parallel spec {self}: tp{} straddles node boundaries ({} GPUs/node)",
                self.tp,
                gpn
            );
        }
        if self.ep > self.gpus() || self.gpus() % self.ep != 0 {
            anyhow::bail!(
                "parallel spec {self}: ep{} must tile the {}-GPU grid",
                self.ep,
                self.gpus()
            );
        }
        Ok(())
    }

    /// Sub-topology one TP group occupies (node-boundary-aware: a TP group
    /// either fits inside a node or spans whole nodes — [`Self::validate`]
    /// rejects anything else), which is what its all-reduce runs over.
    pub fn tp_topology(&self, topo: &Topology) -> Topology {
        topo.with_gpus(self.tp.max(1))
    }

    /// Link a PP stage boundary crosses: intra-node while one DP replica's
    /// whole pipeline (`tp·pp` GPUs) fits in a node, inter-node otherwise.
    pub fn stage_link(&self, topo: &Topology) -> LinkParams {
        if self.tp * self.pp <= topo.gpus_per_node {
            topo.intra
        } else {
            topo.inter
        }
    }

    /// All power-of-two-factored specs for a GPU count (the
    /// `sweep-parallel` grid). With `moe`, each dense layout is augmented
    /// with its EP variants (`ep = gpus` and `ep = tp`, the Fig 10
    /// shapes).
    pub fn enumerate(gpus: usize, moe: bool) -> Vec<ParallelSpec> {
        let mut out = Vec::new();
        let mut push = |s: ParallelSpec| {
            if !out.contains(&s) {
                out.push(s);
            }
        };
        let mut tp = 1;
        while tp <= gpus {
            if gpus % tp == 0 {
                let rest = gpus / tp;
                let mut pp = 1;
                while pp <= rest {
                    if rest % pp == 0 {
                        let dp = rest / pp;
                        let base = ParallelSpec { tp, pp, dp, ep: 1 };
                        push(base);
                        if moe {
                            for ep in [gpus, tp] {
                                if ep > 1 {
                                    push(ParallelSpec { ep, ..base });
                                }
                            }
                        }
                    }
                    pp *= 2;
                }
            }
            tp *= 2;
        }
        out
    }
}

impl fmt::Display for ParallelSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// Communication/computation overlap fractions, one per collective site
/// (paper Fig 13 / Appendix B: the all-reduce of layer *l* hides behind
/// the GEMMs of layer *l+1*). Each fraction is the share of that
/// collective's closed-form time the runtime overlaps with compute; what
/// actually hides is additionally capped by the compute available to hide
/// behind, so `uniform(1.0)` never prices a step below pure compute.
///
/// The default ([`OverlapSpec::none`]) prices everything serially —
/// bit-for-bit the pre-overlap numbers, because every hidden term is then
/// exactly `0.0` and `x - 0.0 == x`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OverlapSpec {
    /// Fraction of each layer's TP all-reduce pair hidden behind the next
    /// layer's GEMMs.
    pub tp_ar: f64,
    /// Fraction of each PP stage-boundary transfer hidden behind the next
    /// micro-batch's compute. Only effective with `micro_batches > 1` —
    /// a single batch has no next slice to hide behind.
    pub pp_p2p: f64,
    /// Fraction of each MoE layer's all-to-all pair hidden behind the
    /// expert GEMMs it interleaves with.
    pub ep_a2a: f64,
}

impl Default for OverlapSpec {
    fn default() -> Self {
        Self::none()
    }
}

impl OverlapSpec {
    /// Serial pricing (the legacy numbers, bit-for-bit).
    pub fn none() -> Self {
        OverlapSpec { tp_ar: 0.0, pp_p2p: 0.0, ep_a2a: 0.0 }
    }

    /// The same fraction at every collective site (clamped to [0, 1]).
    pub fn uniform(f: f64) -> Self {
        let f = f.clamp(0.0, 1.0);
        OverlapSpec { tp_ar: f, pp_p2p: f, ep_a2a: f }
    }

    /// The Fig 13 calibration point: the hideable share of one NVRAR
    /// all-reduce — its deferred-sync phase — at the paper's 128 KiB /
    /// 16-GPU Perlmutter operating point, derived from the same
    /// [`crate::collectives::sim::nvrar`] phase model `fig13_sync_hiding`
    /// tabulates. Only the TP all-reduce site is calibrated by Fig 13;
    /// the PP/EP sites stay serial.
    pub fn fig13() -> Self {
        let topo = crate::cluster::presets::perlmutter(4); // 16 GPUs
        let c = crate::collectives::sim::CommConfig::perlmutter();
        let nv = crate::collectives::sim::nvrar(&topo, &c, 128 * 1024, 0.0);
        let frac = if nv.total > 0.0 {
            (nv.phase_secs("sync") / nv.total).clamp(0.0, 1.0)
        } else {
            0.0
        };
        OverlapSpec { tp_ar: frac, pp_p2p: 0.0, ep_a2a: 0.0 }
    }

    /// Parse a CLI `--overlap` value: `0.7` (uniform), `fig13` (the
    /// calibrated preset), `none`/`off`/empty (serial), or per-site
    /// `tp=0.7,pp=0.5,ep=0.3` (unnamed sites stay 0).
    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        let s = name.trim().to_ascii_lowercase();
        if s.is_empty() || s == "none" || s == "off" {
            return Ok(Self::none());
        }
        if s == "fig13" {
            return Ok(Self::fig13());
        }
        if let Ok(f) = s.parse::<f64>() {
            anyhow::ensure!(
                (0.0..=1.0).contains(&f),
                "overlap fraction {f} outside [0, 1] in '{name}'"
            );
            return Ok(Self::uniform(f));
        }
        let mut out = Self::none();
        for part in s.split(',') {
            let Some((key, val)) = part.split_once('=') else {
                anyhow::bail!(
                    "bad overlap spec '{name}' (expected e.g. 0.7, fig13, tp=0.7,pp=0.5,ep=0.3)"
                );
            };
            let f: f64 = val
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad overlap fraction '{val}' in '{name}'"))?;
            anyhow::ensure!(
                (0.0..=1.0).contains(&f),
                "overlap fraction {f} outside [0, 1] in '{name}'"
            );
            match key.trim() {
                "tp" | "ar" => out.tp_ar = f,
                "pp" | "p2p" => out.pp_p2p = f,
                "ep" | "a2a" => out.ep_a2a = f,
                other => anyhow::bail!("unknown overlap site '{other}' in '{name}' (tp|pp|ep)"),
            }
        }
        Ok(out)
    }

    /// True when every site prices serially (the fast-path test: the cost
    /// layer skips the exposed/hidden split entirely).
    pub fn is_none(&self) -> bool {
        self.tp_ar == 0.0 && self.pp_p2p == 0.0 && self.ep_a2a == 0.0
    }
}

/// Exposed-vs-hidden decomposition of one step's closed-form collective
/// time, plus the compute slack still available to absorb fabric delay.
/// Invariant: `exposed` equals [`StepCost::step_breakdown`]'s Comm bucket
/// (same arithmetic, bit-for-bit), and `exposed + hidden` is the serial
/// collective time.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommSplit {
    /// Collective seconds extending the step (what Fig 3/Fig 8 charts).
    pub exposed: f64,
    /// Collective seconds priced behind compute — absent from the step
    /// time, but their bytes still occupy the fabric.
    pub hidden: f64,
    /// Compute seconds not already hiding a collective — the budget that
    /// can still absorb shared-fabric queueing delay before contention
    /// un-hides communication.
    pub slack: f64,
}

/// One step priced against the shared fabric: what
/// [`StepCost::step_timing_at`] returns so callers can account exposed
/// vs hidden communication without re-deriving the split.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepTiming {
    /// Step duration (s), fabric queueing delay included.
    pub dur: f64,
    /// Private-fabric closed-form [`StepCost::step_time`].
    pub base: f64,
    /// Exposed collective seconds, fabric delay included. Only computed
    /// when overlap or tracing is on (0.0 on the fast path).
    pub comm_exposed: f64,
    /// Hidden collective seconds (closed-form hidden + absorbed delay).
    /// Only computed when overlap or tracing is on (0.0 on the fast path).
    pub comm_hidden: f64,
    /// Collective bytes booked on the shared fabric — the *full* volume,
    /// hidden bytes included (0.0 with no fabric configured).
    pub booked_bytes: f64,
}

/// Per-engine-step cost model of one deployment. Implementations read the
/// machine/model/persona context from the [`ServeConfig`] at call time, so
/// one cost object serves any model the config carries.
pub trait StepCost: fmt::Debug + Send + Sync {
    /// Duration (s) of one engine step executing `step` under `cfg`,
    /// assuming the deployment has the interconnect to itself (the
    /// closed-form/simulated path — also what routing predictions use, so
    /// probing a cost never perturbs the shared fabric).
    fn step_time(&self, cfg: &ServeConfig, step: &StepBatch) -> f64;

    /// Four-bucket (Matmul / Other-Comp / Comm / Idle) decomposition of
    /// [`StepCost::step_time`], per GPU — the paper's Fig 3/Fig 8 view of
    /// one step, and what the tracing layer stamps on every step span.
    ///
    /// Invariant: `step_breakdown(..).total()` equals `step_time(..)` up
    /// to floating-point association dust (NOT bit-for-bit — `step_time`
    /// is deliberately left untouched so its results stay bit-identical
    /// with tracing off; reconciliation is asserted to 1e-6 end-to-end in
    /// `tests/integration_obs.rs`). The default attributes everything to
    /// Other-Comp; real cost models override with their own arithmetic.
    fn step_breakdown(&self, cfg: &ServeConfig, step: &StepBatch) -> Breakdown {
        Breakdown { other_comp: self.step_time(cfg, step), ..Default::default() }
    }

    /// The parallelism layout this cost models.
    fn spec(&self) -> ParallelSpec;

    /// All-reduce implementation used for the TP groups.
    fn ar(&self) -> AllReduceImpl;

    /// Aggregate all-reduce message bytes one step moves through the TP
    /// group — the volume [`StepCost::step_time_at`] books on the shared
    /// fabric. The default is the dense accounting (two all-reduces per
    /// layer on the step's token rows); implementations with different
    /// per-step message math override it.
    fn step_collective_bytes(&self, cfg: &ServeConfig, step: &StepBatch) -> (u64, f64) {
        let msg = (step.token_rows().max(1) * cfg.model.d_model * cfg.model.dtype_bytes) as u64;
        (msg, 2.0 * cfg.model.n_layers as f64)
    }

    /// Exposed/hidden decomposition of this step's closed-form collective
    /// time under `cfg.overlap` (see [`CommSplit`]). The default matches
    /// the default breakdown: all comm exposed, nothing hidden, no slack.
    /// Implementations mirror their own breakdown arithmetic so
    /// `step_comm(..).exposed` equals `step_breakdown(..).comm` exactly.
    fn step_comm(&self, cfg: &ServeConfig, step: &StepBatch) -> CommSplit {
        CommSplit { exposed: self.step_breakdown(cfg, step).comm, hidden: 0.0, slack: 0.0 }
    }

    /// One engine step *launched at fabric time `at`*, priced against the
    /// shared [`crate::simnet::Interconnect`] in [`ServeConfig::net`]: the
    /// private-fabric [`StepCost::step_time`] plus the queueing delay of
    /// booking the step's collective bytes. The *full* collective volume
    /// is booked — hidden bytes still occupy NVLink/NIC links and contend
    /// with KV handoffs and migrations — but the overlapped fraction of
    /// the resulting delay can duck behind the step's remaining compute
    /// slack; once the delay outgrows that slack the excess extends the
    /// step, so contention un-hides communication under load. With no
    /// fabric configured — or an idle one — `dur` is exactly `step_time`
    /// (closed-form parity).
    fn step_timing_at(&self, cfg: &ServeConfig, step: &StepBatch, at: f64) -> StepTiming {
        let base = self.step_time(cfg, step);
        // The split costs a second breakdown-shaped pass; skip it on the
        // hot path nobody reads it on (overlap off, tracing off) so the
        // legacy contention pricing keeps its exact cost profile.
        let split = if cfg.overlap.is_none() && cfg.obs.is_none() {
            None
        } else {
            Some(self.step_comm(cfg, step))
        };
        let comm_exposed = split.map_or(0.0, |s| s.exposed);
        let comm_hidden = split.map_or(0.0, |s| s.hidden);
        let no_fabric =
            StepTiming { dur: base, base, comm_exposed, comm_hidden, booked_bytes: 0.0 };
        let Some(net) = &cfg.net else { return no_fabric };
        let spec = self.spec();
        if spec.tp <= 1 {
            return no_fabric;
        }
        let (msg, count) = self.step_collective_bytes(cfg, step);
        if msg == 0 || count <= 0.0 {
            return no_fabric;
        }
        let tp_topo = spec.tp_topology(&cfg.topo);
        // A step cannot occupy more link-seconds than its own duration:
        // the event-level sim's pipelined collectives beat the α-β closed
        // forms on big messages, so cap the booked volume at the step's
        // wire-time capacity. This keeps back-to-back steps from
        // overlapping their *own* flows — an idle fabric stays exactly
        // idle — while contention from *other* traffic still lands.
        let per = crate::collectives::flows::alpha_beta_time(self.ar(), &tp_topo, &cfg.comm, msg);
        let count = if per > 0.0 {
            count.min(base / per)
        } else {
            count
        };
        if count <= 0.0 {
            return no_fabric;
        }
        let mut net = net.lock().unwrap_or_else(|e| e.into_inner());
        // The engine's clock only moves forward: let the fabric prune
        // intervals that ended before this step (pre-booked background
        // traffic stays intact until the run reaches it).
        net.advance(at);
        // When tracing is on, the flow path also records per-phase spans
        // on the booked link tracks; it never changes the arithmetic.
        let flow = crate::collectives::flows::allreduce_flow_obs(
            self.ar(),
            &tp_topo,
            &cfg.comm,
            crate::collectives::flows::FlowSpec { bytes: msg, count, scope: cfg.net_scope, at },
            &mut net,
            cfg.obs.as_ref(),
        );
        // Only the overlapped fraction of the queueing delay can hide,
        // and never more than the remaining compute slack. At
        // OverlapSpec::none this is exactly 0.0 and `dur` reproduces the
        // legacy `base + delay` bit-for-bit.
        let absorbed = match &split {
            Some(s) => (cfg.overlap.tp_ar * flow.delay).min(s.slack).max(0.0),
            None => 0.0,
        };
        StepTiming {
            dur: base + (flow.delay - absorbed),
            base,
            comm_exposed: comm_exposed + (flow.delay - absorbed),
            comm_hidden: comm_hidden + absorbed,
            booked_bytes: msg as f64 * count,
        }
    }

    /// Duration-only view of [`StepCost::step_timing_at`] (the historical
    /// entry point; serving/fleet hot loops use the full timing).
    fn step_time_at(&self, cfg: &ServeConfig, step: &StepBatch, at: f64) -> f64 {
        self.step_timing_at(cfg, step, at).dur
    }

    /// Canonical deployment string, e.g. `tp8-pp2/NVRAR` — the label every
    /// experiment table and `results/` CSV emits.
    fn label(&self) -> String {
        format!("{}/{}", self.spec(), self.ar().name())
    }
}

/// Build the cost model for a spec: EP ⇒ [`crate::moe::MoeCost`], pure TP
/// ⇒ [`DenseTp`], anything else ⇒ [`HybridTpPp`].
pub fn cost_for(spec: ParallelSpec, ar: AllReduceImpl) -> Arc<dyn StepCost> {
    if spec.ep > 1 {
        Arc::new(crate::moe::MoeCost::new(spec, ar))
    } else if spec.pp == 1 && spec.dp == 1 {
        Arc::new(DenseTp::new(spec.tp, ar))
    } else {
        Arc::new(HybridTpPp::new(spec, ar))
    }
}

/// Pure tensor parallelism over every GPU: each layer pays its GEMMs at
/// `1/tp` K-width plus two all-reduces on the `rows × d_model` activation.
#[derive(Clone, Copy, Debug)]
pub struct DenseTp {
    spec: ParallelSpec,
    ar: AllReduceImpl,
}

impl DenseTp {
    pub fn new(tp: usize, ar: AllReduceImpl) -> Self {
        DenseTp { spec: ParallelSpec::tp(tp), ar }
    }
}

impl StepCost for DenseTp {
    fn step_time(&self, cfg: &ServeConfig, step: &StepBatch) -> f64 {
        let tp = self.spec.tp;
        // GEMM rows are the *chunk* tokens fed this step; the attention
        // context (`mean_ctx`) is each sequence's full attended prefix —
        // a mid-prompt chunk re-reads everything written so far.
        let rows = step.token_rows().max(1);
        let kv_len = step.mean_ctx();
        let lt = perfmodel::layer_times(
            &cfg.gpu,
            &cfg.model,
            tp,
            rows,
            kv_len,
            step.seqs().max(1),
        );
        let msg = (rows * cfg.model.d_model * cfg.model.dtype_bytes) as u64;
        let ar_t = if tp > 1 {
            let tp_topo = self.spec.tp_topology(&cfg.topo);
            allreduce(self.ar, &tp_topo, &cfg.comm, msg, lt.total() / 2.0).total
        } else {
            0.0
        };
        let comp = lt.total() / cfg.persona.compute_efficiency;
        // Overlap: layer l's all-reduce pair ducks behind layer l+1's
        // GEMMs — at most the layer's own compute can hide it.
        let hidden = (cfg.overlap.tp_ar * (2.0 * ar_t)).min(comp).max(0.0);
        cfg.model.n_layers as f64 * (comp + (2.0 * ar_t - hidden)) + cfg.persona.step_overhead
    }

    // Mirrors `step_time` term by term (same inputs, same intermediate
    // values) so the buckets sum back to it; a pure-TP step has no
    // intra-step idle. The Comm bucket is *exposed* comm only — hidden
    // collective time lives in `step_comm`.
    fn step_breakdown(&self, cfg: &ServeConfig, step: &StepBatch) -> Breakdown {
        let tp = self.spec.tp;
        let rows = step.token_rows().max(1);
        let kv_len = step.mean_ctx();
        let lt =
            perfmodel::layer_times(&cfg.gpu, &cfg.model, tp, rows, kv_len, step.seqs().max(1));
        let msg = (rows * cfg.model.d_model * cfg.model.dtype_bytes) as u64;
        let ar_t = if tp > 1 {
            let tp_topo = self.spec.tp_topology(&cfg.topo);
            allreduce(self.ar, &tp_topo, &cfg.comm, msg, lt.total() / 2.0).total
        } else {
            0.0
        };
        let layers = cfg.model.n_layers as f64;
        let eff = cfg.persona.compute_efficiency;
        let comp = lt.total() / eff;
        let hidden = (cfg.overlap.tp_ar * (2.0 * ar_t)).min(comp).max(0.0);
        Breakdown {
            matmul: layers * (lt.matmul / eff),
            other_comp: layers * (lt.other / eff) + cfg.persona.step_overhead,
            comm: layers * (2.0 * ar_t - hidden),
            idle: 0.0,
        }
    }

    // Same preamble as `step_time`/`step_breakdown`, so `exposed` is
    // bit-for-bit the breakdown's Comm bucket.
    fn step_comm(&self, cfg: &ServeConfig, step: &StepBatch) -> CommSplit {
        let tp = self.spec.tp;
        let rows = step.token_rows().max(1);
        let kv_len = step.mean_ctx();
        let lt =
            perfmodel::layer_times(&cfg.gpu, &cfg.model, tp, rows, kv_len, step.seqs().max(1));
        let msg = (rows * cfg.model.d_model * cfg.model.dtype_bytes) as u64;
        let ar_t = if tp > 1 {
            let tp_topo = self.spec.tp_topology(&cfg.topo);
            allreduce(self.ar, &tp_topo, &cfg.comm, msg, lt.total() / 2.0).total
        } else {
            0.0
        };
        let layers = cfg.model.n_layers as f64;
        let comp = lt.total() / cfg.persona.compute_efficiency;
        let hidden = (cfg.overlap.tp_ar * (2.0 * ar_t)).min(comp).max(0.0);
        CommSplit {
            exposed: layers * (2.0 * ar_t - hidden),
            hidden: layers * hidden,
            slack: (layers * (comp - hidden)).max(0.0),
        }
    }

    fn spec(&self) -> ParallelSpec {
        self.spec
    }

    fn ar(&self) -> AllReduceImpl {
        self.ar
    }
}

/// Hybrid TP×PP(×DP): `pp` pipeline stages of `tp`-way TP each, the batch
/// split across `dp` replicas, with `micro_batches` batch slices in flight
/// through the pipeline.
///
/// With `micro_batches = 1` (the default, what the paper's engines ran —
/// vLLM PP, Fig 3's idle) a step traverses all stages sequentially:
/// `T = L·layer + pp·(p2p + overhead)`, leaving `(pp-1)/pp` of every
/// GPU-second as bubble. With `m > 1` the pipeline fills:
/// `T = (pp + m - 1) · stage_time(rows/m)` — which helps prefill (GEMM
/// rows shrink with the slice) but not decode, where the M-tile floor
/// keeps `stage_time` constant and each slice re-streams the stage's
/// weights (Observation 2).
#[derive(Clone, Copy, Debug)]
pub struct HybridTpPp {
    spec: ParallelSpec,
    ar: AllReduceImpl,
    micro_batches: usize,
}

impl HybridTpPp {
    pub fn new(spec: ParallelSpec, ar: AllReduceImpl) -> Self {
        HybridTpPp { spec, ar, micro_batches: 1 }
    }

    /// Configure pipeline micro-batching (clamped to ≥ 1).
    pub fn with_micro_batches(mut self, m: usize) -> Self {
        self.micro_batches = m.max(1);
        self
    }
}

impl StepCost for HybridTpPp {
    fn step_time(&self, cfg: &ServeConfig, step: &StepBatch) -> f64 {
        let s = self.spec;
        let rows_total = step.token_rows().max(1);
        // DP splits the batch; PP does not divide per-token depth.
        let rows = rows_total.div_ceil(s.dp).max(1);
        let m = self.micro_batches.clamp(1, rows);
        let mb_rows = rows.div_ceil(m).max(1);
        let kv_len = step.mean_ctx();
        let batch = step.seqs().max(1).div_ceil(s.dp).max(1);
        let lt = perfmodel::layer_times(&cfg.gpu, &cfg.model, s.tp, mb_rows, kv_len, batch);
        let msg = (mb_rows * cfg.model.d_model * cfg.model.dtype_bytes) as u64;
        let ar_t = if s.tp > 1 {
            let tp_topo = s.tp_topology(&cfg.topo);
            allreduce(self.ar, &tp_topo, &cfg.comm, msg, lt.total() / 2.0).total
        } else {
            0.0
        };
        let layers_per_stage = cfg.model.n_layers.div_ceil(s.pp).max(1);
        let p2p = if s.pp > 1 {
            s.stage_link(&cfg.topo).xfer_time(msg) + cfg.persona.p2p_overhead
        } else {
            0.0
        };
        let lps = layers_per_stage as f64;
        let comp_l = lt.total() / cfg.persona.compute_efficiency;
        // Overlap: per-layer all-reduces duck behind the next layer's
        // GEMMs; with micro-batches in flight (m > 1) a slice's stage
        // boundary transfer ducks behind the next slice's compute —
        // interleaving shrinks the pipeline bubble. The p2p hiding budget
        // is the stage compute not already hiding all-reduces.
        let hidden_ar = (cfg.overlap.tp_ar * (2.0 * ar_t)).min(comp_l).max(0.0);
        let hidden_p2p = if m > 1 {
            (cfg.overlap.pp_p2p * p2p).min((lps * (comp_l - hidden_ar)).max(0.0)).max(0.0)
        } else {
            0.0
        };
        let stage_t = lps * (comp_l + (2.0 * ar_t - hidden_ar)) + (p2p - hidden_p2p);
        (s.pp + m - 1) as f64 * stage_t + cfg.persona.step_overhead
    }

    // Per-GPU view of the pipelined step: each stage is busy for its `m`
    // micro-batches (`m · stage_t`) and sits in fill/drain bubble for the
    // other `(pp − 1) · stage_t` — Fig 3's "Idle" bucket emerging from
    // the schedule. Buckets sum to `(pp + m − 1)·stage_t + overhead`,
    // i.e. `step_time`, up to fp association dust.
    fn step_breakdown(&self, cfg: &ServeConfig, step: &StepBatch) -> Breakdown {
        let s = self.spec;
        let rows_total = step.token_rows().max(1);
        let rows = rows_total.div_ceil(s.dp).max(1);
        let m = self.micro_batches.clamp(1, rows);
        let mb_rows = rows.div_ceil(m).max(1);
        let kv_len = step.mean_ctx();
        let batch = step.seqs().max(1).div_ceil(s.dp).max(1);
        let lt = perfmodel::layer_times(&cfg.gpu, &cfg.model, s.tp, mb_rows, kv_len, batch);
        let msg = (mb_rows * cfg.model.d_model * cfg.model.dtype_bytes) as u64;
        let ar_t = if s.tp > 1 {
            let tp_topo = s.tp_topology(&cfg.topo);
            allreduce(self.ar, &tp_topo, &cfg.comm, msg, lt.total() / 2.0).total
        } else {
            0.0
        };
        let layers_per_stage = cfg.model.n_layers.div_ceil(s.pp).max(1);
        let p2p = if s.pp > 1 {
            s.stage_link(&cfg.topo).xfer_time(msg) + cfg.persona.p2p_overhead
        } else {
            0.0
        };
        let eff = cfg.persona.compute_efficiency;
        let lps = layers_per_stage as f64;
        let comp_l = lt.total() / eff;
        let hidden_ar = (cfg.overlap.tp_ar * (2.0 * ar_t)).min(comp_l).max(0.0);
        let hidden_p2p = if m > 1 {
            (cfg.overlap.pp_p2p * p2p).min((lps * (comp_l - hidden_ar)).max(0.0)).max(0.0)
        } else {
            0.0
        };
        let stage_t = lps * (comp_l + (2.0 * ar_t - hidden_ar)) + (p2p - hidden_p2p);
        let mf = m as f64;
        Breakdown {
            matmul: mf * lps * (lt.matmul / eff),
            other_comp: mf * lps * (lt.other / eff) + cfg.persona.step_overhead,
            comm: mf * (lps * (2.0 * ar_t - hidden_ar) + (p2p - hidden_p2p)),
            idle: (s.pp - 1) as f64 * stage_t,
        }
    }

    // Same preamble as `step_breakdown`, so `exposed` is bit-for-bit the
    // breakdown's Comm bucket.
    fn step_comm(&self, cfg: &ServeConfig, step: &StepBatch) -> CommSplit {
        let s = self.spec;
        let rows_total = step.token_rows().max(1);
        let rows = rows_total.div_ceil(s.dp).max(1);
        let m = self.micro_batches.clamp(1, rows);
        let mb_rows = rows.div_ceil(m).max(1);
        let kv_len = step.mean_ctx();
        let batch = step.seqs().max(1).div_ceil(s.dp).max(1);
        let lt = perfmodel::layer_times(&cfg.gpu, &cfg.model, s.tp, mb_rows, kv_len, batch);
        let msg = (mb_rows * cfg.model.d_model * cfg.model.dtype_bytes) as u64;
        let ar_t = if s.tp > 1 {
            let tp_topo = s.tp_topology(&cfg.topo);
            allreduce(self.ar, &tp_topo, &cfg.comm, msg, lt.total() / 2.0).total
        } else {
            0.0
        };
        let layers_per_stage = cfg.model.n_layers.div_ceil(s.pp).max(1);
        let p2p = if s.pp > 1 {
            s.stage_link(&cfg.topo).xfer_time(msg) + cfg.persona.p2p_overhead
        } else {
            0.0
        };
        let lps = layers_per_stage as f64;
        let comp_l = lt.total() / cfg.persona.compute_efficiency;
        let hidden_ar = (cfg.overlap.tp_ar * (2.0 * ar_t)).min(comp_l).max(0.0);
        let hidden_p2p = if m > 1 {
            (cfg.overlap.pp_p2p * p2p).min((lps * (comp_l - hidden_ar)).max(0.0)).max(0.0)
        } else {
            0.0
        };
        let mf = m as f64;
        let hidden = mf * (lps * hidden_ar + hidden_p2p);
        CommSplit {
            exposed: mf * (lps * (2.0 * ar_t - hidden_ar) + (p2p - hidden_p2p)),
            hidden,
            slack: (mf * lps * comp_l - hidden).max(0.0),
        }
    }

    fn step_collective_bytes(&self, cfg: &ServeConfig, step: &StepBatch) -> (u64, f64) {
        let s = self.spec;
        let rows = step.token_rows().max(1).div_ceil(s.dp).max(1);
        let m = self.micro_batches.clamp(1, rows);
        let mb_rows = rows.div_ceil(m).max(1);
        let msg = (mb_rows * cfg.model.d_model * cfg.model.dtype_bytes) as u64;
        let layers = (cfg.model.n_layers.div_ceil(s.pp).max(1) * s.pp) as f64;
        (msg, 2.0 * layers * m as f64)
    }

    fn spec(&self) -> ParallelSpec {
        self.spec
    }

    fn ar(&self) -> AllReduceImpl {
        self.ar
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;

    #[test]
    fn by_name_parses_the_advertised_forms() {
        assert_eq!(ParallelSpec::by_name("tp16").unwrap(), ParallelSpec::tp(16));
        assert_eq!(ParallelSpec::by_name("tp8-pp2").unwrap(), ParallelSpec::tp_pp(8, 2));
        assert_eq!(
            ParallelSpec::by_name("tp4-pp2-dp2").unwrap(),
            ParallelSpec { tp: 4, pp: 2, dp: 2, ep: 1 }
        );
        // ep beyond tp·pp·dp implies the missing DP replicas (Fig 10).
        assert_eq!(ParallelSpec::by_name("tp8-ep16").unwrap(), ParallelSpec::moe(8, 2, 16));
        assert_eq!(ParallelSpec::by_name("TP16-EP16").unwrap(), ParallelSpec::moe(16, 1, 16));
    }

    #[test]
    fn by_name_round_trips_canonical_labels() {
        for gpus in [4usize, 8, 16, 32] {
            for moe in [false, true] {
                for spec in ParallelSpec::enumerate(gpus, moe) {
                    let back = ParallelSpec::by_name(&spec.label()).unwrap();
                    assert_eq!(back, spec, "round-trip of {}", spec.label());
                }
            }
        }
    }

    #[test]
    fn by_name_rejects_with_usable_errors() {
        for bad in ["", "hp", "tp", "tp0", "xx4", "tp8-tp2", "tp8-qq2", "tp-pp2"] {
            let err = ParallelSpec::by_name(bad).unwrap_err().to_string();
            assert!(err.contains("parallel spec") || err.contains("tp16"), "{bad}: {err}");
        }
        // Explicit dp is never silently overridden by a too-large ep.
        assert!(ParallelSpec::by_name("tp8-dp1-ep16").is_err());
        // ep not a multiple of the tp·pp group cannot be inferred.
        assert!(ParallelSpec::by_name("tp3-ep16").is_err());
    }

    #[test]
    fn validate_checks_gpu_count_and_node_boundaries() {
        let topo16 = presets::perlmutter(4); // 4 nodes × 4 GPUs
        assert!(ParallelSpec::tp(16).validate(&topo16).is_ok());
        assert!(ParallelSpec::tp_pp(8, 2).validate(&topo16).is_ok()); // TP spans 2 whole nodes
        assert!(ParallelSpec::tp_pp(4, 4).validate(&topo16).is_ok());
        assert!(ParallelSpec::moe(8, 2, 16).validate(&topo16).is_ok());
        // Wrong GPU totals.
        assert!(ParallelSpec::tp(8).validate(&topo16).is_err());
        assert!(ParallelSpec::tp_pp(8, 4).validate(&topo16).is_err());
        // ep must tile the grid.
        assert!(ParallelSpec { tp: 16, pp: 1, dp: 1, ep: 3 }.validate(&topo16).is_err());
    }

    #[test]
    fn tp_topology_and_stage_link_are_node_aware() {
        let topo = presets::perlmutter(4);
        // TP4 fits one node: NVLink all-reduce.
        let t4 = ParallelSpec::tp_pp(4, 4).tp_topology(&topo);
        assert_eq!((t4.nodes, t4.gpus_per_node), (1, 4));
        // TP8 spans two nodes.
        let t8 = ParallelSpec::tp_pp(8, 2).tp_topology(&topo);
        assert_eq!((t8.nodes, t8.gpus_per_node), (2, 4));
        // Stage hops cross nodes whenever a replica's pipeline exceeds one.
        let inter = ParallelSpec::tp_pp(4, 4).stage_link(&topo);
        assert_eq!(inter.alpha, topo.inter.alpha);
        let small = presets::perlmutter(1); // 1 node × 4 GPUs
        let intra = ParallelSpec::tp_pp(2, 2).stage_link(&small);
        assert_eq!(intra.alpha, small.intra.alpha);
    }

    #[test]
    fn enumerate_covers_the_full_grid() {
        let dense = ParallelSpec::enumerate(16, false);
        assert!(dense.contains(&ParallelSpec::tp(16)));
        assert!(dense.contains(&ParallelSpec::tp_pp(4, 4)));
        assert!(dense.contains(&ParallelSpec { tp: 4, pp: 2, dp: 2, ep: 1 }));
        assert!(dense.iter().all(|s| s.gpus() == 16 && s.ep == 1));
        let moe = ParallelSpec::enumerate(16, true);
        assert!(moe.contains(&ParallelSpec::moe(16, 1, 16)));
        assert!(moe.contains(&ParallelSpec { tp: 4, pp: 4, dp: 1, ep: 4 }));
        assert!(moe.len() > dense.len());
    }

    #[test]
    fn cost_for_dispatches_by_spec_shape() {
        let d = cost_for(ParallelSpec::tp(16), AllReduceImpl::Nvrar);
        assert_eq!(d.label(), "tp16/NVRAR");
        let h = cost_for(ParallelSpec::tp_pp(8, 2), AllReduceImpl::NcclAuto);
        assert_eq!(h.label(), "tp8-pp2/NCCL");
        let m = cost_for(ParallelSpec::moe(16, 1, 16), AllReduceImpl::Nvrar);
        assert_eq!(m.label(), "tp16-ep16/NVRAR");
    }

    #[test]
    fn step_breakdown_buckets_sum_to_step_time() {
        use crate::engine::batcher::{PrefillChunk, StepBatch};
        let mixed = StepBatch {
            prefills: vec![PrefillChunk { id: 100, tokens: 512, ctx: 640, last: false }],
            decodes: (0..24u64).collect(),
            decode_ctx: vec![1024; 24],
        };
        let decode_only = StepBatch {
            prefills: vec![],
            decodes: (0..32u64).collect(),
            decode_ctx: vec![2048; 32],
        };
        for (spec, ar) in [
            (ParallelSpec::tp(16), AllReduceImpl::Nvrar),
            (ParallelSpec::tp(16), AllReduceImpl::NcclAuto),
            (ParallelSpec::tp_pp(4, 4), AllReduceImpl::NcclAuto),
            (ParallelSpec { tp: 4, pp: 2, dp: 2, ep: 1 }, AllReduceImpl::Nvrar),
        ] {
            let cfg = crate::serving::fig9_config(spec, ar, 32, "perlmutter", 16);
            for step in [&mixed, &decode_only] {
                let t = cfg.step_time(step);
                let bd = cfg.step_breakdown(step);
                assert!(
                    (bd.total() - t).abs() <= 1e-9 * t.max(1.0),
                    "{}: buckets {} vs step {t}",
                    cfg.deployment_label(),
                    bd.total()
                );
                assert!(bd.matmul > 0.0 && bd.comm > 0.0);
                // The pipeline bubble is the only intra-step idle source.
                assert_eq!(bd.idle > 0.0, spec.pp > 1, "{}", cfg.deployment_label());
            }
        }
    }

    #[test]
    fn overlap_spec_by_name_parses_and_validates() {
        assert_eq!(OverlapSpec::by_name("").unwrap(), OverlapSpec::none());
        assert_eq!(OverlapSpec::by_name("off").unwrap(), OverlapSpec::none());
        assert_eq!(OverlapSpec::by_name("none").unwrap(), OverlapSpec::none());
        assert_eq!(OverlapSpec::by_name("0").unwrap(), OverlapSpec::none());
        assert_eq!(OverlapSpec::by_name("0.5").unwrap(), OverlapSpec::uniform(0.5));
        assert_eq!(
            OverlapSpec::by_name("tp=0.7,pp=0.5,ep=0.25").unwrap(),
            OverlapSpec { tp_ar: 0.7, pp_p2p: 0.5, ep_a2a: 0.25 }
        );
        // The Fig 13 preset hides a real, partial fraction of the
        // all-reduce (its deferred-sync share) — never nothing, never all.
        let fig13 = OverlapSpec::by_name("fig13").unwrap();
        assert!(fig13.tp_ar > 0.0 && fig13.tp_ar < 1.0, "{fig13:?}");
        assert_eq!((fig13.pp_p2p, fig13.ep_a2a), (0.0, 0.0));
        assert!(!fig13.is_none());
        assert!(OverlapSpec::none().is_none());
        for bad in ["1.5", "-0.1", "tp=2", "zz=0.5", "tp0.5", "tp=,pp=0.1"] {
            assert!(OverlapSpec::by_name(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn overlap_zero_is_bit_identical_and_overlap_on_still_sums() {
        use crate::engine::batcher::StepBatch;
        let step = StepBatch {
            prefills: vec![],
            decodes: (0..32u64).collect(),
            decode_ctx: vec![2048; 32],
        };
        for spec in [
            ParallelSpec::tp(16),
            ParallelSpec::tp_pp(4, 4),
            ParallelSpec { tp: 4, pp: 2, dp: 2, ep: 1 },
        ] {
            let cfg = crate::serving::fig9_config(spec, AllReduceImpl::Nvrar, 32, "perlmutter", 16);
            let explicit = cfg.clone().with_overlap(OverlapSpec::none());
            // Explicit overlap 0 reproduces the default bit-for-bit.
            assert_eq!(
                cfg.step_time(&step).to_bits(),
                explicit.step_time(&step).to_bits(),
                "{spec}"
            );
            let bd0 = cfg.step_breakdown(&step);
            assert_eq!(bd0, explicit.step_breakdown(&step), "{spec}");

            // Overlap on: buckets still sum to the (smaller) step time,
            // exposed mirrors the Comm bucket, and exposed + hidden is
            // the serial collective time.
            let on = cfg.clone().with_overlap(OverlapSpec::uniform(0.6));
            let t = on.step_time(&step);
            let bd = on.step_breakdown(&step);
            let sc = on.step_comm(&step);
            assert!((bd.total() - t).abs() <= 1e-9 * t.max(1.0), "{spec}: {} vs {t}", bd.total());
            assert_eq!(sc.exposed.to_bits(), bd.comm.to_bits(), "{spec}");
            assert!(sc.hidden > 0.0, "{spec} hides nothing at 0.6");
            assert!(sc.slack >= 0.0, "{spec}");
            assert!(
                (sc.exposed + sc.hidden - bd0.comm).abs() <= 1e-9 * bd0.comm.max(1.0),
                "{spec}: exposed {} + hidden {} vs serial comm {}",
                sc.exposed,
                sc.hidden,
                bd0.comm
            );
            assert!(t < cfg.step_time(&step), "{spec}: overlap must shrink the step");
        }
    }

    #[test]
    fn default_step_breakdown_is_all_other_comp() {
        // A custom StepCost that does not override step_breakdown still
        // satisfies the total() == step_time invariant exactly.
        #[derive(Debug)]
        struct Flat;
        impl StepCost for Flat {
            fn step_time(&self, _: &ServeConfig, _: &StepBatch) -> f64 {
                0.125
            }
            fn spec(&self) -> ParallelSpec {
                ParallelSpec::tp(1)
            }
            fn ar(&self) -> AllReduceImpl {
                AllReduceImpl::NcclAuto
            }
        }
        let cfg = crate::serving::fig9_config(
            ParallelSpec::tp(16),
            AllReduceImpl::Nvrar,
            32,
            "perlmutter",
            16,
        );
        let step = StepBatch { prefills: vec![], decodes: vec![1], decode_ctx: vec![64] };
        let bd = Flat.step_breakdown(&cfg, &step);
        assert_eq!(bd.other_comp, 0.125);
        assert_eq!(bd.total(), Flat.step_time(&cfg, &step));
        assert_eq!((bd.matmul, bd.comm, bd.idle), (0.0, 0.0, 0.0));
    }
}
