//! Coordinator: the experiment registry behind the `yalis` CLI and every
//! `cargo bench` harness.
//!
//! Each function regenerates one of the paper's tables/figures as a
//! [`crate::util::tables::Table`] (printed + optionally CSV'd). The bench
//! harnesses in `rust/benches/` are thin wrappers over these, so the CLI,
//! the benches, and the integration tests all exercise identical code.

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

pub mod benchsuite;
pub mod experiments;

use crate::util::cli::Cli;

/// CLI entry (called by `rust/src/main.rs`).
pub fn main() {
    let mut cli = Cli::new(
        "yalis",
        "multi-node LLM inference study + NVRAR all-reduce (paper reproduction).\n\
         Subcommand = first positional arg: scaling | breakdown | gemm | nccl-vs-mpi |\n\
         micro | hyperparams | e2e | phase | serve | sweep-parallel | sweep-chunk |\n\
         sweep-session | sweep-contention | sweep-overlap | fleet | fleet-hetero |\n\
         soak | moe | sync | variants | traces | profile | bench-suite | bench-check |\n\
         validate | fit | lint | all",
    );
    cli.opt(
        "machine",
        crate::calib::DEFAULT_MACHINE,
        &format!(
            "machine bundle ({}) or path to a bundle JSON file",
            crate::calib::registry::names().join("|")
        ),
    );
    cli.opt("model", "70b", "model (70b|405b|qwen3|tiny)");
    cli.opt("gpus", "16", "GPU count for the `sweep-*` subcommands");
    cli.opt("allreduce", "nvrar", "per-replica all-reduce for `fleet`/`fleet-hetero` (nccl|nccl-ring|nccl-tree|mpi|nvrar)");
    cli.opt("chunk-tokens", "0", "prefill chunk cap for serve/fleet (0 = budget-bounded)");
    cli.opt(
        "overlap",
        "0",
        "comm/compute overlap for serve/fleet/sweep-parallel: fraction 0..1, \
         'fig13' (Fig 13-calibrated TP site), or per-site 'tp=F,pp=F,ep=F'",
    );
    cli.opt("csv-dir", "", "write CSVs into this directory (empty = don't)");
    cli.opt(
        "trace-out",
        "",
        "trace-artifact base path for serve/fleet/sweep-chunk/sweep-session/profile: \
         writes <base>.trace.json (Perfetto), <base>.lifecycle.csv, <base>.timeline.csv \
         (profile defaults to results/profile)",
    );
    cli.flag("json", "`bench-suite`/`lint`: print the report as JSON on stdout");
    cli.opt(
        "out",
        "",
        "`bench-suite`: also write the metrics JSON to this path; \
         `validate`: write the pass/fail table here; \
         `fit`: output bundle path (default results/fitted.json); \
         `lint`: also write the JSON report here",
    );
    cli.opt("root", ".", "`lint`: repository root to scan");
    cli.opt(
        "lint-baseline",
        crate::lint::DEFAULT_BASELINE,
        "`lint`: ratcheted debt baseline (relative to --root); new debt fails, \
         decreases auto-tighten",
    );
    cli.opt("baseline", "bench/baseline.json", "`bench-check`: committed baseline metrics");
    cli.opt("current", "", "`bench-check`: freshly generated metrics to compare");
    cli.opt("tol", "0.10", "`bench-check`: allowed worse-direction fraction per metric");
    cli.opt(
        "requests",
        &experiments::SOAK_REQUESTS.to_string(),
        "`soak`: simulated request count",
    );
    cli.opt(
        "replicas",
        &experiments::SOAK_REPLICAS.to_string(),
        "`soak`: mixed-pool replica count",
    );
    cli.opt("seed", &experiments::SOAK_SEED.to_string(), "`soak`: trace seed");
    cli.opt("bundle", "", "`validate`: check this bundle file instead of the built-ins");
    cli.opt("fit-csv", "", "`fit`: measured latencies (bytes,gpus,impl,seconds CSV)");
    cli.opt("gemm-csv", "", "`fit`: optional measured GEMMs (m,n,k,dtype_bytes,seconds CSV)");
    let args = cli.parse();
    let csv = if args.get("csv-dir").is_empty() { None } else { Some(args.get("csv-dir").to_string()) };
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let machine = args.get("machine");
    let model = args.get("model");
    let trace_out = args.get("trace-out").to_string();
    let trace = if trace_out.is_empty() { None } else { Some(trace_out.as_str()) };

    // The perf-gate subcommands exit directly (bench-check's exit code IS
    // the CI gate); everything below the match prints tables.
    if cmd == "bench-suite" {
        benchsuite::run_suite(args.get_flag("json"), args.get("out"));
        return;
    }
    if cmd == "bench-check" {
        let ok = benchsuite::run_check(
            args.get("baseline"),
            args.get("current"),
            args.get_f64("tol"),
        );
        std::process::exit(if ok { 0 } else { 1 });
    }
    if cmd == "lint" {
        // simlint: the exit code IS the CI gate (0 clean, 1 new debt or
        // bad waiver, 2 usage/IO error).
        match crate::lint::run_cli(
            args.get("root"),
            args.get("lint-baseline"),
            args.get_flag("json"),
            args.get("out"),
        ) {
            Ok(ok) => std::process::exit(if ok { 0 } else { 1 }),
            Err(e) => {
                eprintln!("error: {e:#}");
                std::process::exit(2);
            }
        }
    }
    if cmd == "validate" {
        // Paper-claim harness: exit code IS the drift gate for CI.
        let override_bundle = if args.get("bundle").is_empty() {
            None
        } else {
            Some(args.get_with("bundle", crate::calib::MachineBundle::load))
        };
        match crate::calib::claims::run(override_bundle.as_ref()) {
            Ok((table, ok)) => {
                table.print();
                let out = args.get("out");
                if !out.is_empty() {
                    if let Some(dir) = std::path::Path::new(out).parent() {
                        let _ = std::fs::create_dir_all(dir);
                    }
                    match std::fs::write(out, table.render()) {
                        Ok(()) => println!("-> {out}"),
                        Err(e) => eprintln!("table write failed for {out}: {e}"),
                    }
                }
                if ok {
                    println!("validate: all claims in band");
                } else {
                    eprintln!("validate: CLAIM DRIFT — observed values left their bands");
                }
                std::process::exit(if ok { 0 } else { 1 });
            }
            Err(e) => {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
    }
    if cmd == "fit" {
        let base = args.get_with("machine", crate::calib::registry::resolve);
        if args.get("fit-csv").is_empty() {
            eprintln!("error: fit needs --fit-csv <bytes,gpus,impl,seconds CSV>");
            std::process::exit(2);
        }
        let out = if args.get("out").is_empty() { "results/fitted.json" } else { args.get("out") };
        match crate::calib::fit::run_fit(&base, args.get("fit-csv"), args.get("gemm-csv"), out) {
            Ok(()) => std::process::exit(0),
            Err(e) => {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
    }

    // Validate --machine/--model up front: a bad value exits 2 with the
    // registry's list-the-valid-names message instead of panicking deep in
    // an experiment driver.
    let bundle = args.get_with("machine", crate::calib::registry::resolve);
    let _ = args.get_with("model", crate::models::ModelConfig::by_name);
    // Bad --overlap values exit 2 with by_name's message, like --machine.
    let overlap = args.get_with("overlap", crate::parallel::OverlapSpec::by_name);

    let mut tables = match cmd {
        "scaling" => experiments::fig1_fig2_scaling(model),
        "breakdown" => vec![experiments::fig3_breakdown()],
        "gemm" => vec![experiments::table4_gemm_model()],
        "nccl-vs-mpi" => vec![experiments::fig4_nccl_vs_mpi()],
        "micro" => experiments::fig6_microbench(machine),
        "hyperparams" => vec![experiments::table5_hyperparams()],
        "e2e" => vec![experiments::fig7_e2e_speedup(model, machine)],
        "phase" => vec![experiments::fig8_phase_breakdown()],
        "serve" => vec![experiments::fig9_trace_serving(
            args.get_usize("chunk-tokens"),
            trace,
            overlap,
        )],
        "sweep-parallel" => {
            vec![experiments::sweep_parallel(model, machine, args.get_usize("gpus"), overlap)]
        }
        "sweep-chunk" => {
            vec![experiments::sweep_chunk(model, machine, args.get_usize("gpus"), trace)]
        }
        "sweep-session" => {
            vec![experiments::sweep_session(model, machine, args.get_usize("gpus"), trace)]
        }
        "sweep-contention" => vec![experiments::sweep_contention(args.get_usize("gpus"))],
        "sweep-overlap" => vec![experiments::sweep_overlap(args.get_usize("gpus"))],
        "fleet" => {
            // Bad --allreduce values exit with a usable message, not a panic.
            let ar = args.get_with("allreduce", crate::collectives::AllReduceImpl::by_name);
            vec![experiments::fleet_experiment(
                ar,
                args.get_usize("chunk-tokens"),
                trace,
                overlap,
            )]
        }
        "fleet-hetero" => {
            let ar = args.get_with("allreduce", crate::collectives::AllReduceImpl::by_name);
            vec![experiments::fleet_hetero_experiment(ar)]
        }
        "soak" => {
            match experiments::soak_experiment(
                args.get_usize("requests"),
                args.get_usize("replicas"),
                args.get_u64("seed"),
            ) {
                Ok(t) => vec![t],
                Err(e) => {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        "profile" => experiments::profile_experiment(trace.unwrap_or("results/profile")),
        "moe" => vec![experiments::fig10_moe()],
        "sync" => vec![experiments::fig13_sync_hiding()],
        "variants" => experiments::fig14_fig15_nccl_variants(),
        "traces" => experiments::fig17_fig18_traces(),
        "all" => experiments::all_experiments(),
        other => {
            eprintln!("unknown subcommand '{other}'");
            std::process::exit(2);
        }
    };
    // Run-metadata header: every printed table and every CSV states what
    // produced it (experiments add their own `seed`/`deployment` pairs).
    for t in &mut tables {
        t.meta("version", env!("CARGO_PKG_VERSION"));
        t.meta("command", cmd);
        // name@version: which calibration produced this table.
        t.meta("machine", &bundle.label());
        t.meta("model", model);
    }
    for t in &tables {
        t.print();
        if let Some(dir) = &csv {
            let path = format!("{dir}/{}.csv", slug(t));
            if let Err(e) = t.write_csv(&path) {
                eprintln!("csv write failed: {e}");
            } else {
                println!("-> {path}");
            }
        }
    }
}

fn slug(t: &crate::util::tables::Table) -> String {
    t.render()
        .lines()
        .next()
        .unwrap_or("table")
        .trim_matches(['=', ' '])
        .to_lowercase()
        .replace([' ', '/', '(', ')', ',', ':'], "-")
        .replace("--", "-")
        .trim_matches('-')
        .to_string()
}
