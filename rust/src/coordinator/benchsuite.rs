//! `yalis bench-suite` / `yalis bench-check` — the CI perf-regression
//! gate.
//!
//! The simulation stack is deterministic, so "performance" here means the
//! *modeled* numbers: a cost-model change that silently moves NVRAR
//! latency or fleet goodput by >10% should fail CI, not ship unnoticed.
//! `bench-suite` emits a small flat-JSON metric file; `bench-check`
//! compares it against the committed `bench/baseline.json` with a
//! per-metric direction (lower-better latencies, higher-better
//! throughputs) and a configurable tolerance, exiting non-zero on any
//! worse-direction move beyond it.
//!
//! A baseline containing `"bootstrap": true` disarms the gate (exit 0
//! with a warning): it lets the workflow land before a real baseline has
//! been generated. Arm it with
//! `cargo run --release -- bench-suite --json --out bench/baseline.json`
//! and commit the result.

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use crate::cluster::presets;
use crate::collectives::flows::{allreduce_flow, FlowSpec};
use crate::collectives::sim::{self, CommConfig};
use crate::collectives::AllReduceImpl;
use crate::fleet::{run_fleet, FleetConfig};
use crate::parallel::ParallelSpec;
use crate::serving::{fig9_config, serve};
use crate::simnet::{Interconnect, LinkId, LinkKind};
use crate::trace::TraceSpec;
use crate::util::tables::Table;
use std::collections::BTreeMap;

/// Which direction is a regression for a metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Better {
    /// Smaller is better (latencies): a rise beyond tolerance regresses.
    Lower,
    /// Bigger is better (throughput): a drop beyond tolerance regresses.
    Higher,
    /// A modeling constant: any move beyond tolerance regresses.
    Either,
}

/// One tracked metric.
#[derive(Clone, Debug)]
pub struct Metric {
    pub key: &'static str,
    pub value: f64,
    pub better: Better,
}

/// Static key → direction registry, so `bench-check` can judge a metric
/// file without re-running the simulations that produced it. A unit test
/// pins this to exactly the keys (and directions) [`suite`] emits.
pub fn directions() -> BTreeMap<&'static str, Better> {
    [
        ("nvrar_us_128kb", Better::Lower),
        ("nccl_us_128kb", Better::Lower),
        ("nvrar_us_512kb", Better::Lower),
        ("nccl_us_512kb", Better::Lower),
        ("nvrar_us_2048kb", Better::Lower),
        ("nccl_us_2048kb", Better::Lower),
        ("serve_ttft_p50_ms", Better::Lower),
        ("serve_tpot_p50_ms", Better::Lower),
        ("serve_tok_per_s", Better::Higher),
        ("fleet_goodput_tok_per_s", Better::Higher),
        ("fleet_ttft_p99_ms", Better::Lower),
        ("contention_rd_delay_us", Better::Either),
        ("overlap_exposed_comm_frac", Better::Either),
        ("sim_throughput_rps", Better::Higher),
    ]
    .into()
}

/// Request count of the simulator-throughput reference run (a scaled-down
/// `yalis soak`: mixed fleet, diurnal trace, contention priced). Small
/// enough that debug-build tests stay fast; the full 10M-request target
/// lives in `yalis soak` itself.
pub const SIM_THROUGHPUT_REQUESTS: usize = 20_000;
/// Replica count of the reference run.
pub const SIM_THROUGHPUT_REPLICAS: usize = 16;

/// Compute the tracked metric set. Small and deterministic: one run takes
/// seconds, and two runs of the same build emit identical JSON.
pub fn suite() -> Vec<Metric> {
    let mut out = Vec::new();

    // NVRAR vs NCCL microbench latency, 128 KB – 2 MB on 16 GPUs.
    let topo = presets::perlmutter(4);
    let comm = CommConfig::perlmutter();
    for (kb, nv_key, nccl_key) in [
        (128u64, "nvrar_us_128kb", "nccl_us_128kb"),
        (512, "nvrar_us_512kb", "nccl_us_512kb"),
        (2048, "nvrar_us_2048kb", "nccl_us_2048kb"),
    ] {
        let bytes = kb * 1024;
        out.push(Metric {
            key: nv_key,
            value: sim::nvrar(&topo, &comm, bytes, 0.0).total * 1e6,
            better: Better::Lower,
        });
        out.push(Metric {
            key: nccl_key,
            value: sim::nccl_auto(&topo, &comm, bytes).total * 1e6,
            better: Better::Lower,
        });
    }

    // Single-replica serving on a short BurstGPT trace.
    let mut tspec = TraceSpec::burstgpt();
    tspec.num_prompts = 80;
    let reqs = tspec.generate();
    let cfg =
        fig9_config(ParallelSpec::tp(16), AllReduceImpl::Nvrar, 32, crate::calib::DEFAULT_MACHINE, 16);
    let rep = serve(&cfg, &reqs);
    out.push(Metric { key: "serve_ttft_p50_ms", value: rep.ttft_p50 * 1e3, better: Better::Lower });
    out.push(Metric { key: "serve_tpot_p50_ms", value: rep.tpot_p50 * 1e3, better: Better::Lower });
    out.push(Metric {
        key: "serve_tok_per_s",
        value: rep.output_throughput,
        better: Better::Higher,
    });

    // Fleet goodput on a 3-replica pool.
    let mut fspec = TraceSpec::burstgpt();
    fspec.num_prompts = 150;
    fspec.rate = 12.0;
    let freqs = fspec.generate();
    let base =
        fig9_config(ParallelSpec::tp(16), AllReduceImpl::Nvrar, 64, crate::calib::DEFAULT_MACHINE, 16);
    let frep = run_fleet(&FleetConfig::new(base, 3), &freqs);
    out.push(Metric {
        key: "fleet_goodput_tok_per_s",
        value: frep.goodput,
        better: Better::Higher,
    });
    out.push(Metric {
        key: "fleet_ttft_p99_ms",
        value: frep.ttft_p99 * 1e3,
        better: Better::Lower,
    });

    // Contention model constant: the delay one 256 MB migration inflicts
    // on an overlapping 512 KB NVRAR all-reduce.
    let mut net = Interconnect::new();
    net.add_scope(0, topo.nodes, topo.intra.beta, topo.inter.beta);
    net.book(LinkId { scope: 0, node: 0, kind: LinkKind::Inter }, 0.0, 256.0 * 1024.0 * 1024.0);
    let flow = allreduce_flow(
        AllReduceImpl::Nvrar,
        &topo,
        &comm,
        FlowSpec { bytes: 512 * 1024, count: 1.0, scope: 0, at: 0.0 },
        &mut net,
    );
    out.push(Metric {
        key: "contention_rd_delay_us",
        value: flow.delay * 1e6,
        better: Better::Either,
    });

    // Overlap pricing constant: the share of a half-overlapped tp16/NVRAR
    // decode step's collective time that stays exposed — the Fig 13 knob's
    // step-level effect. A silent move means the overlap math (or the cost
    // model under it) changed without a baseline regeneration.
    let ocfg = fig9_config(
        ParallelSpec::tp(16),
        AllReduceImpl::Nvrar,
        64,
        crate::calib::DEFAULT_MACHINE,
        16,
    )
    .with_overlap(crate::parallel::OverlapSpec::uniform(0.5));
    let ostep = crate::engine::batcher::StepBatch {
        prefills: vec![],
        decodes: (0..64u64).collect(),
        decode_ctx: vec![1024; 64],
    };
    let sc = ocfg.step_comm(&ostep);
    out.push(Metric {
        key: "overlap_exposed_comm_frac",
        value: sc.exposed / (sc.exposed + sc.hidden).max(1e-30),
        better: Better::Either,
    });

    // The simulator's own speed: simulated requests per wall-second on the
    // soak reference run. The only wall-clock metric in the suite — max of
    // two repeats so one scheduler hiccup doesn't trip the 10% gate.
    let mut rps = 0.0f64;
    for _ in 0..2 {
        if let Ok((_rep, wall)) = super::experiments::soak_run(
            SIM_THROUGHPUT_REQUESTS,
            SIM_THROUGHPUT_REPLICAS,
            super::experiments::SOAK_SEED,
        ) {
            rps = rps.max(SIM_THROUGHPUT_REQUESTS as f64 / wall.max(1e-9));
        }
    }
    out.push(Metric { key: "sim_throughput_rps", value: rps, better: Better::Higher });

    out
}

// ---------------------------------------------------------------------
// Flat JSON (the vendored crate set has no serde)
// ---------------------------------------------------------------------

/// A flat-JSON value: numbers for metrics, booleans for flags, strings
/// for the `_meta_*` run-metadata entries (ignored by the gate).
#[derive(Clone, Debug, PartialEq)]
pub enum JsonVal {
    Num(f64),
    Bool(bool),
    Str(String),
}

/// Run-identifying metadata stamped into every metric file so a baseline
/// is self-describing (what build/workload produced it). String-valued,
/// `_meta_`-prefixed: [`check_maps`] only judges numeric entries.
fn meta_pairs() -> Vec<(&'static str, String)> {
    vec![
        ("version", env!("CARGO_PKG_VERSION").to_string()),
        // Bundle name@version: which calibration produced these numbers.
        ("machine", crate::calib::default_label()),
        ("model", "70b".to_string()),
        ("seed", format!("{:#x}", TraceSpec::burstgpt().seed)),
    ]
}

/// Render the metric set as a flat JSON object (sorted by key emission
/// order = suite order; stable across runs).
pub fn to_json(metrics: &[Metric]) -> String {
    let mut s = String::from("{\n  \"schema\": 1");
    for (k, v) in meta_pairs() {
        s.push_str(&format!(",\n  \"_meta_{k}\": \"{v}\""));
    }
    for m in metrics {
        s.push_str(&format!(",\n  \"{}\": {:.6}", m.key, m.value));
    }
    s.push_str("\n}\n");
    s
}

/// Parse a flat JSON object of string keys → number/bool values. Rejects
/// nesting — the metric files are deliberately flat.
pub fn parse_flat(text: &str) -> Result<BTreeMap<String, JsonVal>, String> {
    let chars: Vec<char> = text.chars().collect();
    let mut i = 0usize;
    let mut out = BTreeMap::new();
    fn skip_ws(chars: &[char], i: &mut usize) {
        while *i < chars.len() && chars[*i].is_whitespace() {
            *i += 1;
        }
    }
    skip_ws(&chars, &mut i);
    if chars.get(i) != Some(&'{') {
        return Err("expected '{'".into());
    }
    i += 1;
    loop {
        skip_ws(&chars, &mut i);
        match chars.get(i) {
            Some('}') => {
                i += 1;
                break;
            }
            Some('"') => {}
            other => return Err(format!("expected key or '}}', got {other:?}")),
        }
        i += 1; // opening quote
        let mut key = String::new();
        while i < chars.len() && chars[i] != '"' {
            key.push(chars[i]);
            i += 1;
        }
        if i >= chars.len() {
            return Err(format!("unterminated key '{key}'"));
        }
        i += 1; // closing quote
        skip_ws(&chars, &mut i);
        if chars.get(i) != Some(&':') {
            return Err(format!("expected ':' after key '{key}'"));
        }
        i += 1;
        skip_ws(&chars, &mut i);
        let val = if chars.get(i) == Some(&'"') {
            i += 1; // opening quote (no escape support: meta strings are plain)
            let mut sv = String::new();
            while i < chars.len() && chars[i] != '"' {
                sv.push(chars[i]);
                i += 1;
            }
            if i >= chars.len() {
                return Err(format!("unterminated string value for key '{key}'"));
            }
            i += 1; // closing quote
            JsonVal::Str(sv)
        } else {
            let mut token = String::new();
            while i < chars.len() && !chars[i].is_whitespace() && chars[i] != ',' && chars[i] != '}'
            {
                token.push(chars[i]);
                i += 1;
            }
            match token.as_str() {
                "true" => JsonVal::Bool(true),
                "false" => JsonVal::Bool(false),
                t => JsonVal::Num(
                    t.parse::<f64>().map_err(|_| format!("bad value '{t}' for key '{key}'"))?,
                ),
            }
        };
        out.insert(key, val);
        skip_ws(&chars, &mut i);
        match chars.get(i) {
            Some(',') => {
                i += 1;
                continue;
            }
            Some('}') => {
                i += 1;
                break;
            }
            other => return Err(format!("expected ',' or '}}', got {other:?}")),
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// The gate
// ---------------------------------------------------------------------

/// One comparison outcome.
#[derive(Clone, Debug)]
pub struct Verdict {
    pub key: String,
    pub baseline: f64,
    pub current: Option<f64>,
    /// Signed relative change (current − baseline) / |baseline|.
    pub delta: f64,
    pub regressed: bool,
}

/// Compare `current` against `baseline` (both flat metric maps) with a
/// worse-direction tolerance. `directions` maps known metric keys to
/// their regression direction; unknown keys regress on any move.
pub fn check_maps(
    baseline: &BTreeMap<String, JsonVal>,
    current: &BTreeMap<String, JsonVal>,
    tol: f64,
    directions: &BTreeMap<&str, Better>,
) -> Vec<Verdict> {
    let mut out = Vec::new();
    for (key, val) in baseline {
        if key == "schema" || key == "bootstrap" {
            continue;
        }
        let JsonVal::Num(base) = val else { continue };
        let cur = match current.get(key) {
            Some(JsonVal::Num(c)) => *c,
            _ => {
                // A tracked metric vanished: the suite changed without a
                // baseline regeneration — fail loudly.
                out.push(Verdict {
                    key: key.clone(),
                    baseline: *base,
                    current: None,
                    delta: 0.0,
                    regressed: true,
                });
                continue;
            }
        };
        // A zero baseline has no meaningful relative scale: report any
        // appearance as a loud ±100% so a worse-direction move fails the
        // gate and forces a deliberate baseline regeneration, instead of
        // comparing a raw unit-dependent difference against a fraction.
        let delta = if base.abs() > 1e-9 {
            (cur - base) / base.abs()
        } else if cur.abs() > 1e-9 {
            if cur > 0.0 { 1.0 } else { -1.0 }
        } else {
            0.0
        };
        let regressed = match directions.get(key.as_str()).copied().unwrap_or(Better::Either) {
            Better::Lower => delta > tol,
            Better::Higher => delta < -tol,
            Better::Either => delta.abs() > tol,
        };
        out.push(Verdict {
            key: key.clone(),
            baseline: *base,
            current: Some(cur),
            delta,
            regressed,
        });
    }
    out
}

/// `yalis bench-suite`: compute the metrics, print them (table or JSON),
/// optionally write the JSON to `out`.
pub fn run_suite(json: bool, out: &str) {
    let metrics = suite();
    let rendered = to_json(&metrics);
    if json {
        print!("{rendered}");
    } else {
        let mut t = Table::new("bench-suite metrics", &["metric", "value", "regresses when"]);
        for m in &metrics {
            t.row(&[
                m.key.to_string(),
                format!("{:.3}", m.value),
                match m.better {
                    Better::Lower => "rises",
                    Better::Higher => "drops",
                    Better::Either => "moves",
                }
                .to_string(),
            ]);
        }
        t.print();
    }
    if !out.is_empty() {
        if let Some(dir) = std::path::Path::new(out).parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        match std::fs::write(out, &rendered) {
            Ok(()) => eprintln!("-> {out}"),
            Err(e) => {
                eprintln!("error: writing {out}: {e}");
                std::process::exit(2);
            }
        }
    }
}

/// `yalis bench-check`: true = gate passes. Prints the per-metric table
/// and a verdict line either way.
pub fn run_check(baseline_path: &str, current_path: &str, tol: f64) -> bool {
    let read = |path: &str| -> Result<BTreeMap<String, JsonVal>, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        parse_flat(&text).map_err(|e| format!("parsing {path}: {e}"))
    };
    let baseline = match read(baseline_path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("error: {e}");
            return false;
        }
    };
    if baseline.get("bootstrap") == Some(&JsonVal::Bool(true)) {
        println!(
            "bench-check: baseline {baseline_path} is a bootstrap placeholder — gate \
             disarmed.\nArm it: cargo run --release -- bench-suite --json --out \
             {baseline_path}  (and commit)"
        );
        return true;
    }
    if current_path.is_empty() {
        eprintln!("error: bench-check needs --current <metrics.json>");
        return false;
    }
    let current = match read(current_path) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return false;
        }
    };
    let verdicts = check_maps(&baseline, &current, tol, &directions());
    let mut t = Table::new(
        &format!("bench-check vs {baseline_path} (tolerance {:.0}%)", tol * 100.0),
        &["metric", "baseline", "current", "delta", "verdict"],
    );
    for v in &verdicts {
        t.row(&[
            v.key.clone(),
            format!("{:.3}", v.baseline),
            v.current.map_or("MISSING".to_string(), |c| format!("{c:.3}")),
            format!("{:+.1}%", v.delta * 100.0),
            (if v.regressed { "REGRESSED" } else { "ok" }).to_string(),
        ]);
    }
    t.print();
    let failures: Vec<&Verdict> = verdicts.iter().filter(|v| v.regressed).collect();
    if failures.is_empty() {
        println!("bench-check: {} metrics within tolerance", verdicts.len());
        true
    } else {
        println!("bench-check: {} regression(s):", failures.len());
        for v in failures {
            println!(
                "  {}: {:.3} -> {:?} ({:+.1}%)",
                v.key,
                v.baseline,
                v.current,
                v.delta * 100.0
            );
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_is_deterministic_and_nonempty() {
        let a = suite();
        let b = suite();
        assert!(a.len() >= 10, "suite should track a real metric set");
        // `sim_throughput_rps` is wall-clock by design — everything else
        // must render bit-identically across runs.
        let strip = |text: &str| -> String {
            text.lines().filter(|l| !l.contains("sim_throughput_rps")).collect::<Vec<_>>().join("\n")
        };
        assert_eq!(
            strip(&to_json(&a)),
            strip(&to_json(&b)),
            "two runs must emit identical JSON (wall-clock metric aside)"
        );
        for m in &a {
            assert!(m.value.is_finite() && m.value >= 0.0, "{}: {}", m.key, m.value);
        }
        // The gate's named metrics are present.
        let keys: Vec<&str> = a.iter().map(|m| m.key).collect();
        for k in [
            "nvrar_us_128kb",
            "nccl_us_2048kb",
            "serve_ttft_p50_ms",
            "serve_tpot_p50_ms",
            "fleet_goodput_tok_per_s",
            "sim_throughput_rps",
        ] {
            assert!(keys.contains(&k), "missing {k}");
        }
        // The simulator-throughput reference actually ran and timed.
        let rps = a.iter().find(|m| m.key == "sim_throughput_rps").unwrap();
        assert!(rps.value > 0.0, "soak reference run must complete");
    }

    #[test]
    fn directions_registry_matches_the_suite_exactly() {
        // bench-check judges with the static registry; it must cover
        // every emitted metric with the same direction, nothing more.
        let dirs = directions();
        let metrics = suite();
        assert_eq!(dirs.len(), metrics.len());
        for m in &metrics {
            assert_eq!(dirs.get(m.key), Some(&m.better), "{}", m.key);
        }
    }

    #[test]
    fn json_round_trips() {
        let metrics = vec![
            Metric { key: "a_us", value: 12.5, better: Better::Lower },
            Metric { key: "b_tok", value: 3400.0, better: Better::Higher },
        ];
        let text = to_json(&metrics);
        let map = parse_flat(&text).unwrap();
        assert_eq!(map.get("schema"), Some(&JsonVal::Num(1.0)));
        assert_eq!(map.get("a_us"), Some(&JsonVal::Num(12.5)));
        assert_eq!(map.get("b_tok"), Some(&JsonVal::Num(3400.0)));
        // Run metadata survives the round trip as strings the gate skips.
        assert_eq!(
            map.get("_meta_version"),
            Some(&JsonVal::Str(env!("CARGO_PKG_VERSION").to_string()))
        );
        assert_eq!(map.get("_meta_machine"), Some(&JsonVal::Str("perlmutter@1".to_string())));
        assert!(parse_flat("{ \"bootstrap\": true }").unwrap().get("bootstrap")
            == Some(&JsonVal::Bool(true)));
        assert!(parse_flat("{ \"s\": \"oops").is_err());
        assert!(parse_flat("not json").is_err());
        assert!(parse_flat("{ \"k\": oops }").is_err());
    }

    #[test]
    fn check_maps_directions_and_tolerance() {
        let mut directions = BTreeMap::new();
        directions.insert("lat_us", Better::Lower);
        directions.insert("thr", Better::Higher);
        let base: BTreeMap<String, JsonVal> = [
            ("lat_us".to_string(), JsonVal::Num(100.0)),
            ("thr".to_string(), JsonVal::Num(1000.0)),
            ("schema".to_string(), JsonVal::Num(1.0)),
        ]
        .into();
        // Within tolerance: +5% latency, -5% throughput.
        let ok: BTreeMap<String, JsonVal> = [
            ("lat_us".to_string(), JsonVal::Num(105.0)),
            ("thr".to_string(), JsonVal::Num(950.0)),
        ]
        .into();
        assert!(check_maps(&base, &ok, 0.10, &directions).iter().all(|v| !v.regressed));
        // Latency up 20% regresses; throughput up 20% does not.
        let bad: BTreeMap<String, JsonVal> = [
            ("lat_us".to_string(), JsonVal::Num(120.0)),
            ("thr".to_string(), JsonVal::Num(1200.0)),
        ]
        .into();
        let verdicts = check_maps(&base, &bad, 0.10, &directions);
        assert!(verdicts.iter().find(|v| v.key == "lat_us").unwrap().regressed);
        assert!(!verdicts.iter().find(|v| v.key == "thr").unwrap().regressed);
        // Improvements in the good direction never regress.
        let better: BTreeMap<String, JsonVal> = [
            ("lat_us".to_string(), JsonVal::Num(50.0)),
            ("thr".to_string(), JsonVal::Num(2000.0)),
        ]
        .into();
        assert!(check_maps(&base, &better, 0.10, &directions).iter().all(|v| !v.regressed));
        // A vanished metric fails loudly.
        let missing: BTreeMap<String, JsonVal> =
            [("thr".to_string(), JsonVal::Num(1000.0))].into();
        let verdicts = check_maps(&base, &missing, 0.10, &directions);
        let lat = verdicts.iter().find(|v| v.key == "lat_us").unwrap();
        assert!(lat.regressed && lat.current.is_none());
        // A zero baseline: staying zero is fine; any worse-direction
        // appearance is a loud ±100% regression (no unit guessing).
        let zbase: BTreeMap<String, JsonVal> =
            [("lat_us".to_string(), JsonVal::Num(0.0))].into();
        let still: BTreeMap<String, JsonVal> =
            [("lat_us".to_string(), JsonVal::Num(0.0))].into();
        assert!(check_maps(&zbase, &still, 0.10, &directions).iter().all(|v| !v.regressed));
        let appeared: BTreeMap<String, JsonVal> =
            [("lat_us".to_string(), JsonVal::Num(0.2))].into();
        let v = check_maps(&zbase, &appeared, 0.10, &directions);
        assert!(v[0].regressed && (v[0].delta - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bootstrap_baseline_disarms_the_gate() {
        let dir = std::env::temp_dir().join("yalis_benchsuite_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bootstrap.json");
        std::fs::write(&path, "{ \"bootstrap\": true }\n").unwrap();
        assert!(run_check(path.to_str().unwrap(), "", 0.10));
        // A missing baseline file fails the gate.
        assert!(!run_check(dir.join("nope.json").to_str().unwrap(), "", 0.10));
    }
}
