//! One function per paper table/figure. See DESIGN.md's per-experiment
//! index; EXPERIMENTS.md records paper-vs-measured for each.

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use crate::cluster::presets;
use crate::collectives::flows::{allreduce_flow, FlowSpec};
use crate::collectives::sim::{self, CommConfig};
use crate::collectives::AllReduceImpl;
use crate::engine::persona::Persona;
use crate::engine::{engine_for, Workload};
use crate::fleet::router::RoutePolicy;
use crate::fleet::{run_fleet, FleetConfig};
use crate::metrics::Breakdown;
use crate::models::ModelConfig;
use crate::obs::{self, fold, ObsSink, Recorder, RunMeta};
use crate::parallel::{OverlapSpec, ParallelSpec};
use crate::perfmodel::{gemm_time, GpuSpec};
use crate::serving::{fig9_config, serve};
use crate::trace::{LenDist, SessionSpec, TraceSpec};
use crate::util::tables::{fmt_speedup, Table};

fn fmt_s(x: f64) -> String {
    if x.is_nan() {
        "OOM".to_string()
    } else {
        format!("{x:.2}")
    }
}

fn fmt_us(x: f64) -> String {
    format!("{:.1}", x * 1e6)
}

/// Fresh shared recorder for a traced run (seed + machine known up
/// front; the simulation fills deployment label and model).
fn trace_sink(seed: u64, machine: &str) -> ObsSink {
    // Stamp the bundle's name@version so the trace records which
    // calibration produced it; fall back to the raw string for machines
    // outside the registry (should not happen past CLI validation).
    let label = crate::calib::registry::resolve(machine)
        .map(|b| b.label())
        .unwrap_or_else(|_| machine.to_string());
    Recorder::sink(RunMeta { seed: Some(seed), machine: label, ..RunMeta::default() })
}

/// Flush a finished run's recorder to `{base}.trace.json` /
/// `.lifecycle.csv` / `.timeline.csv`, announcing the written paths.
fn write_trace(base: &str, sink: &ObsSink) {
    let rec = sink.lock().expect("obs lock poisoned");
    match obs::write_artifacts(base, &rec) {
        Ok(paths) => {
            for p in paths {
                println!("-> {p}");
            }
        }
        Err(e) => eprintln!("trace write failed for {base}: {e}"),
    }
}

/// GPU counts for the strong-scaling sweeps (paper §3.2).
pub fn scaling_gpus(model: &str) -> Vec<usize> {
    if model.contains("405") {
        vec![16, 32, 64, 128]
    } else {
        vec![4, 8, 16, 32]
    }
}

/// Figures 1, 2 and 11: strong scaling of engines × parallelism schemes.
pub fn fig1_fig2_scaling(model_name: &str) -> Vec<Table> {
    let model = ModelConfig::by_name(model_name).unwrap_or_else(|e| panic!("{e}"));
    let engines: [(&str, &str, Persona); 5] = [
        ("YALIS (TP)", "tp", Persona::yalis()),
        ("vLLM (TP)", "tp", Persona::vllm_v1()),
        ("SGLang (TP)", "tp", Persona::sglang()),
        ("vLLM (HP)", "hp", Persona::vllm_v0()),
        ("SGLang (HP)", "hp", Persona::sglang()),
    ];
    let workloads = [
        ("prefill-heavy #P=32", Workload::prefill_heavy(32)),
        ("prefill-heavy #P=8", Workload::prefill_heavy(8)),
        ("decode-heavy #P=8", Workload::decode_heavy(8)),
        ("decode-heavy #P=32", Workload::decode_heavy(32)), // Fig 11
    ];
    let mut tables = Vec::new();
    for (wname, w) in workloads {
        let mut t = Table::new(
            &format!("Fig1/2 strong scaling {} {}", model.name, wname),
            &["engine", "4", "8", "16", "32", "64", "128"],
        );
        let gpus = scaling_gpus(model_name);
        for (ename, plan, persona) in engines.iter() {
            let mut cells = vec![ename.to_string()];
            for g in [4usize, 8, 16, 32, 64, 128] {
                if !gpus.contains(&g) {
                    cells.push("-".into());
                    continue;
                }
                let e = engine_for("perlmutter", model.clone(), g, plan, *persona, AllReduceImpl::NcclAuto);
                let r = e.run_batch(&w);
                cells.push(fmt_s(r.total));
            }
            t.row(&cells);
        }
        tables.push(t);
    }
    tables
}

/// Figure 3: per-GPU breakdown of YALIS (TP) and vLLM (HP), 8 vs 16 GPUs.
pub fn fig3_breakdown() -> Table {
    let model = ModelConfig::llama31_70b();
    let mut t = Table::new(
        "Fig3 breakdown 70B (seconds)",
        &["workload", "engine", "gpus", "matmul", "other", "comm", "idle", "total"],
    );
    for (wname, w) in [
        ("prefill-heavy #P=32", Workload::prefill_heavy(32)),
        ("decode-heavy #P=8", Workload::decode_heavy(8)),
    ] {
        for (ename, plan, persona) in [
            ("YALIS (TP)", "tp", Persona::yalis()),
            ("vLLM (HP)", "hp", Persona::vllm_v0()),
        ] {
            for g in [8usize, 16] {
                let e = engine_for("perlmutter", model.clone(), g, plan, persona, AllReduceImpl::NcclAuto);
                let r = e.run_batch(&w);
                let mut cells =
                    vec![wname.to_string(), ename.to_string(), g.to_string()];
                cells.extend(r.breakdown.row_cells());
                t.row(&cells);
            }
        }
    }
    t
}

/// Table 4: Prefill-GEMM / Decode-GEMM with M or K halved (analytic model
/// at the paper's exact A100 shapes).
pub fn table4_gemm_model() -> Table {
    let g = GpuSpec::a100();
    let mut t = Table::new(
        "Table4 GEMM tile quantization (ms, A100 model)",
        &["workload", "baseline (M,N,K)", "HP (M/2,N,K)", "TP (M,N,K/2)"],
    );
    for (name, m, n, k) in
        [("Prefill-GEMM", 32768usize, 8192usize, 57344usize), ("Decode-GEMM", 32, 8192, 57344)]
    {
        let base = gemm_time(&g, m, n, k, 2) * 1e3;
        let mhalf = gemm_time(&g, m / 2, n, k, 2) * 1e3;
        let khalf = gemm_time(&g, m, n, k / 2, 2) * 1e3;
        t.row(&[
            name.to_string(),
            format!("{base:.3}"),
            format!("{mhalf:.3}"),
            format!("{khalf:.3}"),
        ]);
    }
    t
}

/// Figure 4: NCCL vs MPI all-reduce across message sizes and GPU counts.
pub fn fig4_nccl_vs_mpi() -> Table {
    let c = CommConfig::perlmutter();
    let mut t = Table::new(
        "Fig4 NCCL vs MPI all-reduce (us, Perlmutter A100-40GB)",
        &["gpus", "size", "NCCL", "MPI", "NCCL/MPI"],
    );
    for gpus in [4usize, 8, 16, 32, 64] {
        let topo = presets::perlmutter(1).with_gpus(gpus);
        for kb in [32u64, 128, 512, 1024, 4096] {
            let bytes = kb * 1024;
            let nccl = sim::nccl_auto(&topo, &c, bytes).total;
            let mpi = sim::mpi_rd(&topo, &c, bytes).total;
            t.row(&[
                gpus.to_string(),
                format!("{kb} KB"),
                fmt_us(nccl),
                fmt_us(mpi),
                format!("{:.2}", nccl / mpi),
            ]);
        }
    }
    t
}

/// Figure 6 (+ Fig 14 left): NVRAR vs NCCL microbenchmark — scaling curves
/// and the speedup grid. Microbenchmark = back-to-back collectives (no
/// interleaved compute), so NVRAR pays its deferred sync (Appendix B).
pub fn fig6_microbench(machine: &str) -> Vec<Table> {
    let c = CommConfig::for_machine(machine).unwrap_or_else(|e| panic!("{e}"));
    let base = presets::by_name(machine, 1).unwrap_or_else(|e| panic!("{e}"));
    let gpus_list: Vec<usize> = match machine {
        "vista" => vec![2, 4, 8, 16, 32],
        _ => vec![8, 16, 32, 64, 128],
    };

    let mut scaling = Table::new(
        &format!("Fig6-left all-reduce scaling on {machine} (us)"),
        &["gpus", "NVRAR 256KB", "NCCL 256KB", "NVRAR 1024KB", "NCCL 1024KB"],
    );
    for &g in &gpus_list {
        let topo = base.with_gpus(g);
        if topo.nodes > 1 && !topo.nodes.is_power_of_two() {
            continue;
        }
        let row: Vec<String> = [256u64, 1024]
            .iter()
            .flat_map(|kb| {
                let b = kb * 1024;
                vec![
                    fmt_us(sim::nvrar(&topo, &c, b, 0.0).total),
                    fmt_us(sim::nccl_auto(&topo, &c, b).total),
                ]
            })
            .collect();
        let mut cells = vec![g.to_string()];
        cells.extend(row);
        scaling.row(&cells);
    }

    let mut grid = Table::new(
        &format!("Fig6 speedup grid NVRAR vs NCCL on {machine} (microbench, no overlap)"),
        &["size", "g4", "g8", "g16", "g32", "g64", "g128"],
    );
    for kb in [64u64, 128, 256, 512, 1024, 2048] {
        let mut cells = vec![format!("{kb} KB")];
        for g in [4usize, 8, 16, 32, 64, 128] {
            if g < base.gpus_per_node || (machine == "vista" && g > 32) {
                cells.push("-".into());
                continue;
            }
            let topo = base.with_gpus(g);
            if topo.nodes > 1 && !topo.nodes.is_power_of_two() {
                cells.push("-".into());
                continue;
            }
            let b = kb * 1024;
            let nccl = sim::nccl_auto(&topo, &c, b).total;
            let nv = sim::nvrar(&topo, &c, b, 0.0).total;
            cells.push(format!("{:.2}", nccl / nv));
        }
        grid.row(&cells);
    }
    vec![scaling, grid]
}

/// Table 5: B_s × C_s hyperparameter sensitivity (1 MB, 16 GPUs).
pub fn table5_hyperparams() -> Table {
    let topo = presets::perlmutter(4); // 16 GPUs
    let mut t = Table::new(
        "Table5 NVRAR hyperparameters, 1024 KB on 16 GPUs",
        &["B_s", "C_s", "time (ms)"],
    );
    for (bs, cs) in [(32usize, 32768u64), (32, 4096), (8, 16384), (8, 131072)] {
        let mut c = CommConfig::perlmutter();
        c.block_count = bs;
        c.chunk_bytes = cs;
        let secs = sim::nvrar(&topo, &c, 1024 * 1024, 0.0).total;
        t.row(&[bs.to_string(), cs.to_string(), format!("{:.4}", secs * 1e3)]);
    }
    t
}

/// Figures 7 & 16: end-to-end decode-heavy speedup of NVRAR over NCCL.
pub fn fig7_e2e_speedup(model_name: &str, machine: &str) -> Table {
    let model = ModelConfig::by_name(model_name).unwrap_or_else(|e| panic!("{e}"));
    let mut t = Table::new(
        &format!("Fig7/16 e2e decode-heavy NVRAR speedup, {} on {machine}", model.name),
        &["engine", "#P", "gpus", "msg", "NCCL (s)", "NVRAR (s)", "speedup"],
    );
    let gpus_list = if model_name.contains("405") {
        vec![16usize, 32, 64, 128]
    } else if machine == "vista" {
        vec![4usize, 8, 16]
    } else {
        vec![8usize, 16, 32]
    };
    for persona in [Persona::yalis(), Persona::vllm_v1()] {
        for np in [8usize, 32] {
            let w = Workload::decode_heavy(np);
            for &g in &gpus_list {
                let nccl = engine_for(machine, model.clone(), g, "tp", persona, AllReduceImpl::NcclAuto)
                    .run_batch(&w);
                let nvrar = engine_for(machine, model.clone(), g, "tp", persona, AllReduceImpl::Nvrar)
                    .run_batch(&w);
                if nccl.oom || nvrar.oom {
                    continue;
                }
                t.row(&[
                    persona.name.to_string(),
                    np.to_string(),
                    g.to_string(),
                    crate::util::stats::fmt_bytes(model.tp_allreduce_bytes(np)),
                    fmt_s(nccl.total),
                    fmt_s(nvrar.total),
                    fmt_speedup(nccl.total / nvrar.total),
                ]);
            }
        }
    }
    t
}

/// Figure 8: per-phase breakdown of YALIS (TP) with NCCL vs NVRAR.
pub fn fig8_phase_breakdown() -> Table {
    let model = ModelConfig::llama31_70b();
    let mut t = Table::new(
        "Fig8 YALIS(TP) breakdown, 16 GPUs, decode-heavy (s)",
        &["#P", "all-reduce", "matmul", "other", "comm", "idle", "total"],
    );
    for np in [8usize, 32] {
        let w = Workload::decode_heavy(np);
        for ar in [AllReduceImpl::NcclAuto, AllReduceImpl::Nvrar] {
            let e = engine_for("perlmutter", model.clone(), 16, "tp", Persona::yalis(), ar);
            let r = e.run_batch(&w);
            let mut cells = vec![np.to_string(), ar.name().to_string()];
            cells.extend(r.breakdown.row_cells());
            t.row(&cells);
        }
    }
    t
}

/// Figure 9: BurstGPT trace serving throughput (70B, Perlmutter, 16 GPUs).
/// `chunk_tokens` caps prefill chunks (0 = budget-bounded chunks);
/// `trace` writes the tp16/NVRAR run's artifacts under that base path;
/// `overlap` prices comm/compute overlap in every deployment's step cost.
pub fn fig9_trace_serving(
    chunk_tokens: usize,
    trace: Option<&str>,
    overlap: OverlapSpec,
) -> Table {
    serving_table(
        "Fig9 BurstGPT serving 70B/Perlmutter (16 GPUs)",
        TraceSpec::burstgpt(),
        &[32, 256],
        chunk_tokens,
        trace,
        overlap,
    )
}

/// Figure 18: decode-heavy trace serving.
pub fn fig18_decode_trace_serving() -> Table {
    serving_table(
        "Fig18 decode-heavy trace serving 70B/Perlmutter (16 GPUs)",
        TraceSpec::decode_heavy(),
        &[32, 256],
        0,
        None,
        OverlapSpec::none(),
    )
}

fn serving_table(
    title: &str,
    mut spec: TraceSpec,
    concurrencies: &[usize],
    chunk_tokens: usize,
    trace: Option<&str>,
    overlap: OverlapSpec,
) -> Table {
    // Scaled-down trace keeps bench wall-clock sane; rates and shapes keep
    // the paper's Table 6 proportions.
    spec.num_prompts = 200;
    let reqs = spec.generate();
    let mut t = Table::new(title, &["deployment", "C", "tok/s", "decode-only steps", "mean TTFT (s)"]);
    t.meta("seed", &format!("{:#x}", spec.seed));
    let traced_c = concurrencies.last().copied().unwrap_or(0);
    for &c in concurrencies {
        // tp4-pp4 is the old "HP" shape on Perlmutter-16 (TP within a
        // node, PP across) expressed through the one spec vocabulary.
        for (pspec, ar) in [
            (ParallelSpec::tp(16), AllReduceImpl::NcclAuto),
            (ParallelSpec::tp(16), AllReduceImpl::Nvrar),
            (ParallelSpec::tp_pp(4, 4), AllReduceImpl::NcclAuto),
        ] {
            let mut cfg = fig9_config(pspec, ar, c, "perlmutter", 16);
            cfg.chunk_tokens = chunk_tokens;
            cfg.overlap = overlap;
            // Trace exactly one run: the flagship NVRAR deployment at
            // the highest concurrency.
            let sink = trace
                .filter(|_| matches!(ar, AllReduceImpl::Nvrar) && c == traced_c)
                .map(|_| trace_sink(spec.seed, "perlmutter"));
            cfg.obs = sink.clone();
            let rep = serve(&cfg, &reqs);
            if let (Some(base), Some(sink)) = (trace, &sink) {
                write_trace(base, sink);
            }
            t.row(&[
                cfg.deployment_label(),
                c.to_string(),
                format!("{:.1}", rep.output_throughput),
                format!("{:.0}%", rep.decode_only_frac * 100.0),
                format!("{:.2}", rep.mean_ttft),
            ]);
        }
    }
    t
}

/// `yalis sweep-chunk`: chunked vs whole-prompt prefill on the
/// long-prompt-heavy trace. The whole-prompt baseline raises the step
/// budget until the longest prompt is admissible in one monolithic step
/// (the only way the pre-chunking engine could serve it at all); every
/// chunked row keeps the same budget so admission capacity is equal and
/// only the slicing differs. The last row is the production shape: the
/// default 8192-token budget with prompts 4x longer — unservable before
/// chunked prefill existed.
pub fn sweep_chunk(model_name: &str, machine: &str, gpus: usize, trace: Option<&str>) -> Table {
    let model = ModelConfig::by_name(model_name).unwrap_or_else(|e| panic!("{e}"));
    let mut tspec = TraceSpec::long_prompt();
    tspec.num_prompts = 150;
    let reqs = tspec.generate();
    let longest = reqs.iter().map(|r| r.prompt_len).max().unwrap_or(8192);
    // Headroom above the longest prompt so in-flight decodes never force
    // the "whole-prompt" baseline to split a prompt after all.
    let budget = longest + 64;
    let mut t = Table::new(
        &format!(
            "sweep-chunk {} on {machine} x{gpus} GPUs (long-prompt trace, max prompt {longest})",
            model.name
        ),
        &["mode", "budget", "tok/s", "TTFT p50", "TTFT p99", "TPOT p50", "preempts"],
    );
    t.meta("seed", &format!("{:#x}", tspec.seed));
    let rows: Vec<(String, usize, usize)> = std::iter::once(("whole-prompt".to_string(), budget, 0))
        .chain([512usize, 1024, 2048, 4096].into_iter().map(|c| (format!("chunk {c}"), budget, c)))
        .chain(std::iter::once(("chunk 2048".to_string(), 8192, 2048)))
        .collect();
    let last = rows.len() - 1;
    for (i, (mode, budget, chunk)) in rows.into_iter().enumerate() {
        let mut cfg = fig9_config(ParallelSpec::tp(gpus), AllReduceImpl::Nvrar, 64, machine, gpus);
        cfg.model = model.clone();
        cfg.max_step_tokens = budget;
        cfg.chunk_tokens = chunk;
        // Trace the production shape (the final row).
        let sink = trace.filter(|_| i == last).map(|_| trace_sink(tspec.seed, machine));
        cfg.obs = sink.clone();
        let rep = serve(&cfg, &reqs);
        if let (Some(base), Some(sink)) = (trace, &sink) {
            write_trace(base, sink);
        }
        t.row(&[
            mode,
            budget.to_string(),
            format!("{:.1}", rep.output_throughput),
            format!("{:.2}", rep.ttft_p50),
            format!("{:.2}", rep.ttft_p99),
            format!("{:.4}", rep.tpot_p50),
            rep.preemptions.to_string(),
        ]);
    }
    t
}

/// `yalis sweep-session`: multi-turn session serving — turns × shared-
/// prefix length × routing policy on a 3-replica fleet. Session-affinity
/// routing is prefix-cache-aware (expected per-replica hits discount its
/// placement costs), so on conversational workloads it reports a high hit
/// rate and a tighter TTFT than content-blind least-outstanding; with one
/// turn per session there is nothing to share and the policies converge.
pub fn sweep_session(model_name: &str, machine: &str, gpus: usize, trace: Option<&str>) -> Table {
    let model = ModelConfig::by_name(model_name).unwrap_or_else(|e| panic!("{e}"));
    let mut t = Table::new(
        &format!("sweep-session {} on {machine} x{gpus} GPUs, 3 replicas", model.name),
        &["turns", "prefix", "policy", "tok/s", "TTFT p50", "TTFT p99", "hit %", "saved tok"],
    );
    for &turns in &[1usize, 4, 8] {
        for &prefix in &[512usize, 2048] {
            // Comparable request counts across rows: fewer, longer
            // sessions as the turn count grows.
            let mut sspec = SessionSpec::standard();
            sspec.sessions = 240 / turns.max(1);
            sspec.turns = turns;
            sspec.think = 15.0; // enough overlap that blind routing scatters
            sspec.first_prompt =
                LenDist { median: prefix as f64, sigma: 0.4, min: 64, max: 16_384 };
            let reqs = sspec.generate();
            t.meta("seed", &format!("{:#x}", sspec.seed));
            for policy in [RoutePolicy::LeastOutstanding, RoutePolicy::SessionAffinity] {
                let mut base =
                    fig9_config(ParallelSpec::tp(gpus), AllReduceImpl::Nvrar, 64, machine, gpus);
                base.model = model.clone();
                let mut cfg = FleetConfig::new(base, 3).with_policy(policy);
                // Trace the richest grid point: 8 turns, long prefixes,
                // cache-aware routing.
                let sink = trace
                    .filter(|_| {
                        turns == 8 && prefix == 2048 && matches!(policy, RoutePolicy::SessionAffinity)
                    })
                    .map(|_| trace_sink(sspec.seed, machine));
                if let Some(s) = &sink {
                    cfg = cfg.with_obs(s.clone());
                }
                let rep = run_fleet(&cfg, &reqs);
                if let (Some(base), Some(sink)) = (trace, &sink) {
                    write_trace(base, sink);
                }
                t.row(&[
                    turns.to_string(),
                    prefix.to_string(),
                    policy.name().to_string(),
                    format!("{:.1}", rep.throughput),
                    format!("{:.3}", rep.ttft_p50),
                    format!("{:.3}", rep.ttft_p99),
                    format!("{:.0}%", rep.cache_hit_rate * 100.0),
                    rep.cached_tokens.to_string(),
                ]);
            }
        }
    }
    t
}

/// `yalis sweep-contention`: shared-interconnect contention — concurrent
/// drain-migration-sized background transfers × all-reduce message size ×
/// fabric (Slingshot-11 Perlmutter vs InfiniBand Vista). For each cell,
/// a fresh [`crate::simnet::Interconnect`] carries `mig/s` background KV
/// transfers on the node-0 NIC while decode all-reduces sample the fabric
/// across a 1-second horizon; the closed-form α-β models price every cell
/// identically regardless of load — the *inflate* column is exactly the
/// scenario class they cannot represent. Deterministic (no RNG).
pub fn sweep_contention(gpus: usize) -> Table {
    const MIG_BYTES: f64 = 256.0 * 1024.0 * 1024.0; // one migrating context
    const HORIZON: f64 = 1.0;
    const SAMPLES: usize = 200;
    let mut t = Table::new(
        &format!("sweep-contention NVRAR on shared links, {gpus} GPUs (1s horizon)"),
        &["fabric", "msg", "mig/s", "idle us", "mean us", "p99 us", "inflate", "NIC util"],
    );
    for machine in ["perlmutter", "vista"] {
        let topo = presets::by_name(machine, 1).unwrap().with_gpus(gpus);
        if topo.nodes > 1 && !topo.nodes.is_power_of_two() {
            continue;
        }
        let c = CommConfig::for_machine(machine).unwrap();
        for kb in [128u64, 512, 2048] {
            for rate in [0usize, 2, 8, 32] {
                let mut net = crate::simnet::Interconnect::new();
                net.add_scope(0, topo.nodes, topo.intra.beta, topo.inter.beta);
                let nic = crate::simnet::LinkId {
                    scope: 0,
                    node: 0,
                    kind: crate::simnet::LinkKind::Inter,
                };
                for k in 0..rate {
                    let at = HORIZON * (k as f64 + 0.5) / rate as f64;
                    net.book(nic, at, MIG_BYTES);
                }
                let mut s = crate::util::stats::Summary::new();
                let mut idle = 0.0;
                for i in 0..SAMPLES {
                    let at = HORIZON * i as f64 / SAMPLES as f64;
                    let f = allreduce_flow(
                        AllReduceImpl::Nvrar,
                        &topo,
                        &c,
                        FlowSpec { bytes: kb * 1024, count: 1.0, scope: 0, at },
                        &mut net,
                    );
                    idle = f.alpha_beta;
                    s.add(f.total());
                }
                let mean = s.mean();
                t.row(&[
                    machine.to_string(),
                    format!("{kb} KB"),
                    rate.to_string(),
                    fmt_us(idle),
                    fmt_us(mean),
                    fmt_us(s.percentile(99.0)),
                    format!("{:.2}x", mean / idle),
                    format!(
                        "{:.0}%",
                        net.utilization(crate::simnet::LinkKind::Inter, HORIZON) * 100.0
                    ),
                ]);
            }
        }
    }
    t
}

/// `yalis sweep-overlap`: comm/compute overlap sensitivity — for each
/// deployment shape × decode batch size, price one steady-state decode
/// step at overlap fractions 0..1 and report step time plus the
/// exposed/hidden split ([`crate::serving::ServeConfig::step_comm`]).
/// Pure closed-form (no trace, no RNG): the `speedup` column is the
/// step-time ratio against the serial (overlap 0) row, so the table is
/// exactly the knob Fig 13 calibrates — how much of the paper's
/// sync-hiding win survives at each fraction. Deterministic.
pub fn sweep_overlap(gpus: usize) -> Table {
    use crate::engine::batcher::StepBatch;
    let machine = "perlmutter";
    let topo = presets::perlmutter(1).with_gpus(gpus);
    let mut t = Table::new(
        &format!("sweep-overlap 70B decode steps on {machine} x{gpus} GPUs (NVRAR)"),
        &["deployment", "rows", "overlap", "step ms", "exposed ms", "hidden ms", "speedup"],
    );
    let mut specs = vec![ParallelSpec::tp(gpus)];
    if gpus % 2 == 0 {
        specs.push(ParallelSpec::tp_pp(gpus / 2, 2));
    }
    if gpus % 4 == 0 {
        specs.push(ParallelSpec::tp_pp(gpus / 4, 4));
    }
    for pspec in specs {
        if pspec.validate(&topo).is_err() {
            continue;
        }
        for rows in [32usize, 256] {
            let step = StepBatch {
                prefills: vec![],
                decodes: (0..rows as u64).collect(),
                decode_ctx: vec![1024; rows],
            };
            let base = fig9_config(pspec, AllReduceImpl::Nvrar, rows, machine, gpus);
            let serial = base.step_timing_at(&step, 0.0).dur;
            for f in [0.0f64, 0.25, 0.5, 0.75, 1.0] {
                let cfg = base.clone().with_overlap(OverlapSpec::uniform(f));
                let dur = cfg.step_timing_at(&step, 0.0).dur;
                // step_comm always prices the split, fast path or not, so
                // the overlap-0 row still shows its (all-exposed) comm.
                let sc = cfg.step_comm(&step);
                t.row(&[
                    cfg.deployment_label(),
                    rows.to_string(),
                    format!("{f:.2}"),
                    format!("{:.3}", dur * 1e3),
                    format!("{:.3}", sc.exposed * 1e3),
                    format!("{:.3}", sc.hidden * 1e3),
                    fmt_speedup(serial / dur),
                ]);
            }
        }
    }
    t
}

/// Figure 10: Qwen3-235B-A22B MoE deployments on 16 GPUs.
pub fn fig10_moe() -> Table {
    let model = ModelConfig::qwen3_235b_a22b();
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 150;
    let reqs = spec.generate();
    let mut t = Table::new(
        "Fig10 Qwen3-235B-A22B serving on 16 GPUs",
        &["deployment", "C", "tok/s"],
    );
    for &c in &[32usize, 128] {
        for (pspec, ar) in crate::moe::fig10_specs() {
            let mut cfg = fig9_config(pspec, ar, c, "perlmutter", 16);
            cfg.model = model.clone();
            let rep = serve(&cfg, &reqs);
            t.row(&[
                cfg.deployment_label(),
                c.to_string(),
                format!("{:.1}", rep.output_throughput),
            ]);
        }
    }
    t
}

/// `yalis sweep-parallel`: grid-search every valid [`ParallelSpec`] ×
/// all-reduce implementation for a model/machine/GPU count, report
/// throughput and mean TTFT, and mark the Pareto frontier (no other
/// configuration is at least as good on both axes and better on one).
pub fn sweep_parallel(
    model_name: &str,
    machine: &str,
    gpus: usize,
    overlap: OverlapSpec,
) -> Table {
    let model = ModelConfig::by_name(model_name).unwrap_or_else(|e| panic!("{e}"));
    let mut tspec = TraceSpec::burstgpt();
    tspec.num_prompts = 120;
    let reqs = tspec.generate();
    let topo = presets::by_name(machine, 1).unwrap_or_else(|e| panic!("{e}")).with_gpus(gpus);
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for pspec in ParallelSpec::enumerate(gpus, model.moe.is_some()) {
        if pspec.validate(&topo).is_err() {
            continue;
        }
        for ar in [AllReduceImpl::NcclAuto, AllReduceImpl::Nvrar] {
            let mut cfg = fig9_config(pspec, ar, 64, machine, gpus);
            cfg.model = model.clone();
            cfg.overlap = overlap;
            let rep = serve(&cfg, &reqs);
            rows.push((cfg.deployment_label(), rep.output_throughput, rep.mean_ttft));
        }
    }
    let mut t = Table::new(
        &format!("sweep-parallel {} on {machine} x{gpus} GPUs", model.name),
        &["deployment", "tok/s", "mean TTFT (s)", "pareto"],
    );
    for (label, thr, ttft) in &rows {
        let dominated = rows.iter().any(|(l2, t2, f2)| {
            l2 != label && *t2 >= *thr && *f2 <= *ttft && (*t2 > *thr || *f2 < *ttft)
        });
        t.row(&[
            label.clone(),
            format!("{thr:.1}"),
            format!("{ttft:.2}"),
            (if dominated { "" } else { "*" }).to_string(),
        ]);
    }
    t
}

/// Fleet: multi-replica SLO-aware serving — routing policies × pool modes
/// on a scaled BurstGPT trace with the chosen per-replica all-reduce.
/// (Beyond the paper: its serving experiments stop at one replica.)
pub fn fleet_experiment(
    ar: AllReduceImpl,
    chunk_tokens: usize,
    trace: Option<&str>,
    overlap: OverlapSpec,
) -> Table {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 800;
    spec.rate = 12.0;
    let reqs = spec.generate();
    let mut base = fig9_config(ParallelSpec::tp(16), ar, 64, "perlmutter", 16);
    base.chunk_tokens = chunk_tokens;
    base.overlap = overlap;
    let mut t = Table::new(
        &format!("Fleet serving, 4x(70B {}) replicas, BurstGPT x{}", base.deployment_label(), reqs.len()),
        &[
            "policy",
            "pools",
            "tok/s",
            "goodput",
            "TTFT p50",
            "TTFT p99",
            "TPOT p50",
            "SLO %",
            "handoffs",
        ],
    );
    t.meta("seed", &format!("{:#x}", spec.seed));
    let policies = RoutePolicy::all();
    let lastp = policies.len() - 1;
    for (pi, policy) in policies.into_iter().enumerate() {
        for disagg in [false, true] {
            let mut cfg = if disagg {
                FleetConfig::new(base.clone(), 3).with_policy(policy).disaggregated(1)
            } else {
                FleetConfig::new(base.clone(), 4).with_policy(policy)
            };
            // Trace the disaggregated run under the final policy — the
            // richest event stream (handoffs + prefill pool).
            let sink = trace
                .filter(|_| pi == lastp && disagg)
                .map(|_| trace_sink(spec.seed, "perlmutter"));
            if let Some(s) = &sink {
                cfg = cfg.with_obs(s.clone());
            }
            let rep = run_fleet(&cfg, &reqs);
            if let (Some(tbase), Some(sink)) = (trace, &sink) {
                write_trace(tbase, sink);
            }
            t.row(&[
                policy.name().to_string(),
                if disagg { "3D+1P".to_string() } else { "4 mono".to_string() },
                format!("{:.1}", rep.throughput),
                format!("{:.1}", rep.goodput),
                format!("{:.2}", rep.ttft_p50),
                format!("{:.2}", rep.ttft_p99),
                format!("{:.3}", rep.tpot_p50),
                format!("{:.0}%", rep.slo_attainment * 100.0),
                rep.handoffs.to_string(),
            ]);
        }
    }
    t
}

/// Heterogeneous fleet: the same 48-GPU budget spent as 3×TP16 vs
/// 2×TP16 + 2×TP8, under every routing policy. The cost-aware router
/// keeps the mixed fleet competitive by loading each replica in
/// proportion to its predicted step time (the `routed` column shows the
/// per-replica request split).
pub fn fleet_hetero_experiment(ar: AllReduceImpl) -> Table {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 500;
    spec.rate = 10.0;
    let reqs = spec.generate();
    let tp16 = fig9_config(ParallelSpec::tp(16), ar, 64, "perlmutter", 16);
    let tp8 = fig9_config(ParallelSpec::tp(8), ar, 64, "perlmutter", 8);
    let mut t = Table::new(
        &format!(
            "Heterogeneous fleet, 48 GPUs as 3x{} vs 2x{} + 2x{}, BurstGPT x{}",
            tp16.deployment_label(),
            tp16.deployment_label(),
            tp8.deployment_label(),
            reqs.len()
        ),
        &["fleet", "policy", "tok/s", "goodput", "TTFT p99", "SLO %", "routed"],
    );
    for policy in RoutePolicy::all() {
        for (name, pool) in [
            ("3x tp16", vec![tp16.clone(); 3]),
            (
                "2x tp16 + 2x tp8",
                vec![tp16.clone(), tp16.clone(), tp8.clone(), tp8.clone()],
            ),
        ] {
            let cfg = FleetConfig::heterogeneous(pool).with_policy(policy);
            let rep = run_fleet(&cfg, &reqs);
            t.row(&[
                name.to_string(),
                policy.name().to_string(),
                format!("{:.1}", rep.throughput),
                format!("{:.1}", rep.goodput),
                format!("{:.2}", rep.ttft_p99),
                format!("{:.0}%", rep.slo_attainment * 100.0),
                rep.routed.iter().map(|r| r.to_string()).collect::<Vec<_>>().join("/"),
            ]);
        }
    }
    t
}

/// Default request count / replica count for `yalis soak` — the
/// million-request throughput gate: a 10M-request diurnal day on a
/// 120-replica mixed Perlmutter+Vista fleet with contention priced.
pub const SOAK_REQUESTS: usize = 10_000_000;
pub const SOAK_REPLICAS: usize = 120;
pub const SOAK_SEED: u64 = 0x50AC;

/// The soak fleet: a mixed pool (3 Perlmutter A100 tp4 replicas to every
/// Vista GH200 tp4 replica), cost-aware routing, shared-fabric contention
/// on — every hot path the simulator has, in one configuration.
pub fn soak_fleet_config(replicas: usize) -> anyhow::Result<FleetConfig> {
    let perl = crate::calib::registry::resolve("perlmutter")?;
    let vista = crate::calib::registry::resolve("vista")?;
    let a = crate::serving::fig9_config_bundle(
        ParallelSpec::tp(4),
        AllReduceImpl::Nvrar,
        32,
        &perl,
        4,
    );
    let b = crate::serving::fig9_config_bundle(
        ParallelSpec::tp(4),
        AllReduceImpl::Nvrar,
        32,
        &vista,
        4,
    );
    let pool: Vec<_> =
        (0..replicas.max(1)).map(|i| if i % 4 == 3 { b.clone() } else { a.clone() }).collect();
    Ok(FleetConfig::heterogeneous(pool).with_contention(true))
}

/// One timed soak run: generate the diurnal trace (mean rate scaled to
/// ~5 req/s per replica so the sinusoid's peaks overload the pool and its
/// troughs drain it), run the fleet, and return the report plus the
/// wall-clock seconds the simulation loop took. Everything in the report
/// is deterministic in `(requests, replicas, seed)`; only the wall-clock
/// half varies.
pub fn soak_run(
    requests: usize,
    replicas: usize,
    seed: u64,
) -> anyhow::Result<(crate::fleet::FleetReport, f64)> {
    let mut spec = TraceSpec::soak(requests);
    spec.seed = seed;
    spec.rate = 5.0 * replicas.max(1) as f64;
    let reqs = spec.with_diurnal_cycles(2.0, 0.6).generate();
    let cfg = soak_fleet_config(replicas)?;
    let sw = crate::util::bench::Stopwatch::start();
    let rep = run_fleet(&cfg, &reqs);
    Ok((rep, sw.elapsed_secs()))
}

/// `yalis soak`: the simulator's own throughput benchmark. Simulated
/// requests per wall-second is the headline number `bench-suite` gates
/// (key `sim_throughput_rps`).
pub fn soak_experiment(requests: usize, replicas: usize, seed: u64) -> anyhow::Result<Table> {
    let (rep, wall) = soak_run(requests, replicas, seed)?;
    let mut t = Table::new(
        &format!("soak: {replicas}-replica mixed fleet, diurnal trace x{requests}"),
        &["metric", "value"],
    );
    t.meta("seed", &format!("{seed:#x}"));
    for (k, v) in [
        ("requests", requests.to_string()),
        ("replicas", replicas.to_string()),
        ("completed", rep.completed.to_string()),
        ("rejected", rep.rejected.to_string()),
        ("sim makespan (s)", format!("{:.1}", rep.makespan)),
        ("wall clock (s)", format!("{wall:.2}")),
        ("sim req/wall s", format!("{:.0}", requests as f64 / wall.max(1e-9))),
        ("tok/s", format!("{:.1}", rep.throughput)),
        ("goodput", format!("{:.1}", rep.goodput)),
        ("TTFT p50 (s)", format!("{:.3}", rep.ttft_p50)),
        ("TTFT p99 (s)", format!("{:.3}", rep.ttft_p99)),
        ("TPOT p50 (s)", format!("{:.4}", rep.tpot_p50)),
        ("SLO %", format!("{:.0}%", rep.slo_attainment * 100.0)),
        ("preemptions", rep.preemptions.to_string()),
        ("over-capacity routes", rep.over_capacity_routes.to_string()),
        ("NIC util", format!("{:.0}%", rep.net_util_inter * 100.0)),
    ] {
        t.row(&[k.to_string(), v]);
    }
    Ok(t)
}

/// `yalis profile`: one fully-traced fleet run built to light up every
/// event source at once — 3 replicas + contention-priced fabric + a
/// scripted mid-run drain (with KV migration). Writes the Chrome trace,
/// lifecycle CSV and windowed time-series under `trace_base`, then folds
/// the event stream back into per-replica Matmul/Other/Comm/Idle
/// breakdowns and reconciles them against the analytic accumulator — the
/// Pipit-style "analysis that closes the loop".
pub fn profile_experiment(trace_base: &str) -> Vec<Table> {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 300;
    spec.rate = 8.0;
    let reqs = spec.generate();
    let base = fig9_config(ParallelSpec::tp(16), AllReduceImpl::Nvrar, 64, "perlmutter", 16);
    let label = base.deployment_label();
    let sink = trace_sink(spec.seed, "perlmutter");
    let cfg = FleetConfig::new(base, 3)
        .with_contention(true)
        .with_migration(true)
        .with_drain_at(15.0, 2)
        .with_obs(sink.clone());
    let rep = run_fleet(&cfg, &reqs);
    write_trace(trace_base, &sink);

    let rec = sink.lock().expect("obs lock poisoned");
    let folded = fold::fold_breakdowns(&rec);
    let mk = rec.makespan();

    let mut summary = Table::new(
        &format!("profile: 3x{label} fleet + scripted drain, BurstGPT x{}", reqs.len()),
        &["metric", "value"],
    );
    summary.meta("seed", &format!("{:#x}", spec.seed));
    summary.meta("deployment", &label);
    summary.meta("trace", trace_base);
    for (k, v) in [
        ("completed", rep.completed.to_string()),
        ("tok/s", format!("{:.1}", rep.throughput)),
        ("goodput", format!("{:.1}", rep.goodput)),
        ("TTFT p50 (s)", format!("{:.3}", rep.ttft_p50)),
        ("TTFT p99 (s)", format!("{:.3}", rep.ttft_p99)),
        ("preemptions", rep.preemptions.to_string()),
        ("drains", rep.drains.to_string()),
        ("migrations", rep.migrations.to_string()),
        ("retunes", rep.retunes.to_string()),
        ("NIC util", format!("{:.0}%", rep.net_util_inter * 100.0)),
        ("events: spans", rec.spans().len().to_string()),
        ("events: instants", rec.instants().len().to_string()),
        ("makespan (s)", format!("{mk:.2}")),
    ] {
        summary.row(&[k.to_string(), v]);
    }

    let mut recon = Table::new(
        "profile: per-replica breakdown, event fold vs analytic (s)",
        &["replica", "matmul", "other", "comm", "idle", "total", "max drift"],
    );
    recon.meta("seed", &format!("{:#x}", spec.seed));
    recon.meta("deployment", &label);
    for (r, a) in rep.breakdowns.iter().enumerate() {
        let f = folded
            .get(&r)
            .copied()
            .unwrap_or(Breakdown { idle: mk, ..Breakdown::default() });
        let drift = [
            a.matmul - f.matmul,
            a.other_comp - f.other_comp,
            a.comm - f.comm,
            a.idle - f.idle,
        ]
        .iter()
        .fold(0.0f64, |w, d| w.max(d.abs()));
        let mut cells = vec![r.to_string()];
        cells.extend(a.row_cells());
        cells.push(format!("{drift:.1e}"));
        recon.row(&cells);
    }
    let worst = fold::reconcile(&rep.breakdowns, &folded, mk);
    recon.row(&[
        "worst".to_string(),
        "".to_string(),
        "".to_string(),
        "".to_string(),
        "".to_string(),
        "".to_string(),
        format!("{worst:.1e}"),
    ]);
    vec![summary, recon]
}

/// Figures 12/13 (Appendix B): sync-time hiding with interleaved matmul.
pub fn fig13_sync_hiding() -> Table {
    let topo = presets::perlmutter(4); // 16 GPUs
    let c = CommConfig::perlmutter();
    let bytes = 128 * 1024;
    // Representative interleaved matmul: one 70B decode layer's MLP GEMM.
    let g = GpuSpec::a100();
    let m70 = ModelConfig::llama31_70b();
    let gap = gemm_time(&g, 8, 2 * m70.ffn / 16, m70.d_model, 2);
    let mut t = Table::new(
        "Fig13 128KB all-reduce on 16 GPUs: sync hiding (us)",
        &["impl", "variant", "sync", "comm phases", "total"],
    );
    for (variant, gap_secs) in [("back-to-back", 0.0), ("w/ interleaved matmul", gap)] {
        let nv = sim::nvrar(&topo, &c, bytes, gap_secs);
        t.row(&[
            "NVRAR".to_string(),
            variant.to_string(),
            fmt_us(nv.phase_secs("sync")),
            fmt_us(nv.total - nv.phase_secs("sync")),
            fmt_us(nv.total),
        ]);
        let nccl = sim::nccl_auto(&topo, &c, bytes);
        t.row(&[
            "NCCL".to_string(),
            variant.to_string(),
            "0.0".to_string(),
            fmt_us(nccl.total),
            fmt_us(nccl.total),
        ]);
    }
    t
}

/// Figures 14/15 (Appendix C.3): Vista scaling, NCCL pinned algorithms,
/// and NCCL version comparison.
pub fn fig14_fig15_nccl_variants() -> Vec<Table> {
    let mut out = fig6_microbench("vista");

    // Fig 14 middle/right: speedup with NCCL pinned to Tree / Ring.
    let c = CommConfig::vista();
    let base = presets::vista(1);
    for (algo, name) in [(AllReduceImpl::NcclTree, "Tree"), (AllReduceImpl::NcclRing, "Ring")] {
        let mut t = Table::new(
            &format!("Fig14 NVRAR speedup vs NCCL pinned {name} (Vista)"),
            &["size", "g4", "g8", "g16", "g32"],
        );
        for kb in [64u64, 256, 1024] {
            let mut cells = vec![format!("{kb} KB")];
            for g in [4usize, 8, 16, 32] {
                let topo = base.with_gpus(g);
                let b = kb * 1024;
                let nccl = sim::allreduce(algo, &topo, &c, b, 0.0).total;
                let nv = sim::nvrar(&topo, &c, b, 0.0).total;
                cells.push(format!("{:.2}", nccl / nv));
            }
            t.row(&cells);
        }
        out.push(t);
    }

    // Fig 15: "NCCL 2.28.9" — modest transport improvements (bw +3%,
    // launch -0.5us), orthogonal to the heterogeneous-network path.
    let mut t = Table::new(
        "Fig15 NCCL versions vs NVRAR on Perlmutter (us)",
        &["gpus", "size", "NCCL 2.27.3", "NCCL 2.28.9", "NVRAR"],
    );
    let cp = CommConfig::perlmutter();
    let mut cp_new = cp;
    cp_new.launch_overhead = (cp.launch_overhead - 0.5e-6).max(0.0);
    cp_new.proxy_overhead *= 0.97;
    for g in [8usize, 16, 32, 64] {
        let topo = presets::perlmutter(1).with_gpus(g);
        for kb in [256u64, 1024] {
            let b = kb * 1024;
            t.row(&[
                g.to_string(),
                format!("{kb} KB"),
                fmt_us(sim::nccl_auto(&topo, &cp, b).total),
                fmt_us(sim::nccl_auto(&topo, &cp_new, b).total),
                fmt_us(sim::nvrar(&topo, &cp, b, 0.0).total),
            ]);
        }
    }
    out.push(t);
    out
}

/// Figure 17 + 18: trace distributions and decode-heavy serving.
pub fn fig17_fig18_traces() -> Vec<Table> {
    let buckets = [128usize, 256, 512, 1024, 2048, 4096, 8192];
    let mut t = Table::new(
        "Fig17 BurstGPT trace length distributions (1000 prompts)",
        &["bucket <=", "input count", "output count"],
    );
    let (hin, hout) = TraceSpec::burstgpt().length_histogram(&buckets);
    for (i, b) in buckets.iter().enumerate() {
        t.row(&[b.to_string(), hin[i].to_string(), hout[i].to_string()]);
    }
    t.row(&["more".to_string(), hin[buckets.len()].to_string(), hout[buckets.len()].to_string()]);
    vec![t, fig18_decode_trace_serving()]
}

/// Everything, in paper order (the `yalis all` command).
pub fn all_experiments() -> Vec<Table> {
    let mut out = Vec::new();
    out.extend(fig1_fig2_scaling("70b"));
    out.extend(fig1_fig2_scaling("405b"));
    out.push(fig3_breakdown());
    out.push(table4_gemm_model());
    out.push(fig4_nccl_vs_mpi());
    out.extend(fig6_microbench("perlmutter"));
    out.push(table5_hyperparams());
    out.push(fig7_e2e_speedup("70b", "perlmutter"));
    out.push(fig7_e2e_speedup("405b", "perlmutter"));
    out.push(fig8_phase_breakdown());
    out.push(fig9_trace_serving(0, None, OverlapSpec::none()));
    out.push(fig10_moe());
    out.push(fig13_sync_hiding());
    out.extend(fig14_fig15_nccl_variants());
    out.push(fig7_e2e_speedup("70b", "vista"));
    out.extend(fig17_fig18_traces());
    out.push(sweep_parallel("70b", "perlmutter", 16, OverlapSpec::none()));
    out.push(sweep_chunk("70b", "perlmutter", 16, None));
    out.push(sweep_session("70b", "perlmutter", 16, None));
    out.push(sweep_contention(16));
    out.push(sweep_overlap(16));
    out.push(fleet_experiment(AllReduceImpl::Nvrar, 0, None, OverlapSpec::none()));
    out.push(fleet_hetero_experiment(AllReduceImpl::Nvrar));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_shape_matches_paper() {
        let t = table4_gemm_model();
        let rows = t.rows();
        // Prefill: both halvings ~halve. Decode: only K/2 helps.
        let get = |r: usize, c: usize| rows[r][c].parse::<f64>().unwrap();
        assert!((get(0, 2) / get(0, 1) - 0.5).abs() < 0.06);
        assert!((get(0, 3) / get(0, 1) - 0.5).abs() < 0.06);
        assert!(get(1, 2) / get(1, 1) > 0.9);
        assert!(get(1, 3) / get(1, 1) < 0.65);
    }

    #[test]
    fn fig6_grid_positive_speedups_mid_range() {
        let tables = fig6_microbench("perlmutter");
        let grid = &tables[1];
        // 512 KB row, 32 GPUs column should show a speedup > 1.
        let row = grid.rows().iter().find(|r| r[0] == "512 KB").unwrap();
        let v: f64 = row[4].parse().unwrap();
        assert!(v > 1.0, "512KB@32gpus speedup {v}");
    }

    #[test]
    fn fig7_shows_speedups() {
        let t = fig7_e2e_speedup("70b", "perlmutter");
        assert!(!t.rows().is_empty());
        for row in t.rows() {
            let sp: f64 = row[6].trim_end_matches('x').parse().unwrap();
            assert!(sp > 0.9 && sp < 3.0, "speedup {sp} out of plausible range");
        }
    }

    #[test]
    fn fig13_sync_hidden_with_matmul() {
        let t = fig13_sync_hiding();
        let rows = t.rows();
        let sync_cold: f64 = rows[0][2].parse().unwrap();
        let sync_hot: f64 = rows[2][2].parse().unwrap();
        assert!(sync_cold > 0.0);
        assert!(sync_hot < sync_cold);
    }

    #[test]
    fn sweep_parallel_marks_a_nonempty_pareto_frontier() {
        let t = sweep_parallel("70b", "perlmutter", 8, OverlapSpec::none());
        let rows = t.rows();
        assert!(rows.len() >= 4, "grid should cover several specs");
        assert!(rows.iter().any(|r| r[3] == "*"), "at least one Pareto-optimal config");
        // Rows carry canonical ParallelSpec strings.
        assert!(rows.iter().any(|r| r[0] == "tp8/NVRAR"), "{:?}", rows[0]);
        assert!(rows.iter().any(|r| r[0] == "tp4-pp2/NCCL"));
    }

    #[test]
    fn sweep_chunk_shows_ttft_tail_win_without_tpot_regression() {
        // The chunked-vs-whole-prompt acceptance claim: at equal admission
        // budget, 2048-token chunks tighten the TTFT tail on the
        // long-prompt trace without regressing median TPOT by >5%.
        let t = sweep_chunk("70b", "perlmutter", 16, None);
        let rows = t.rows();
        let whole = rows.iter().find(|r| r[0] == "whole-prompt").expect("baseline row");
        let chunked = rows
            .iter()
            .find(|r| r[0] == "chunk 2048" && r[1] == whole[1])
            .expect("equal-budget chunked row");
        let p99 = |r: &[String]| r[4].parse::<f64>().unwrap();
        let tpot = |r: &[String]| r[5].parse::<f64>().unwrap();
        assert!(
            p99(chunked) < p99(whole),
            "chunked TTFT p99 {} must beat whole-prompt {}",
            p99(chunked),
            p99(whole)
        );
        assert!(
            tpot(chunked) < tpot(whole) * 1.05,
            "TPOT p50 must not regress >5%: {} vs {}",
            tpot(chunked),
            tpot(whole)
        );
        // The production shape (8192 budget, 4x-longer prompts) serves.
        assert!(rows.iter().any(|r| r[1] == "8192"));
    }

    #[test]
    fn sweep_session_affinity_wins_hits_on_multi_turn_rows() {
        let t = sweep_session("70b", "perlmutter", 8, None);
        let rows = t.rows();
        assert_eq!(rows.len(), 3 * 2 * 2, "turns x prefix x policy grid");
        let hit = |r: &[String]| r[6].trim_end_matches('%').parse::<f64>().unwrap();
        // Single-turn rows share nothing: both policies report 0% hits.
        for r in rows.iter().filter(|r| r[0] == "1") {
            assert_eq!(hit(r), 0.0, "{r:?}");
        }
        // On the 8-turn rows, session affinity's hit rate beats
        // least-outstanding's for every prefix length.
        for prefix in ["512", "2048"] {
            let sa = rows
                .iter()
                .find(|r| r[0] == "8" && r[1] == prefix && r[2] == "session-affinity")
                .unwrap();
            let lo = rows
                .iter()
                .find(|r| r[0] == "8" && r[1] == prefix && r[2] == "least-tokens")
                .unwrap();
            assert!(hit(sa) > 0.0, "{sa:?}");
            assert!(hit(sa) > hit(lo), "affinity {sa:?} vs least-tokens {lo:?}");
        }
    }

    #[test]
    fn sweep_contention_inflation_is_monotone_in_migration_rate() {
        let t = sweep_contention(16);
        let rows = t.rows();
        assert!(rows.iter().any(|r| r[0] == "perlmutter"));
        assert!(rows.iter().any(|r| r[0] == "vista"));
        let inflate = |r: &[String]| r[6].trim_end_matches('x').parse::<f64>().unwrap();
        for machine in ["perlmutter", "vista"] {
            for msg in ["128 KB", "512 KB", "2048 KB"] {
                let cells: Vec<f64> = rows
                    .iter()
                    .filter(|r| r[0] == machine && r[1] == msg)
                    .map(|r| inflate(r))
                    .collect();
                assert_eq!(cells.len(), 4, "{machine} {msg}: mig-rate sweep rows");
                // No background -> exactly the closed form.
                assert!((cells[0] - 1.0).abs() < 0.005, "{machine} {msg}: {cells:?}");
                // More concurrent migrations never deflate the all-reduce.
                for w in cells.windows(2) {
                    assert!(w[1] >= w[0] - 1e-9, "{machine} {msg}: {cells:?}");
                }
                // The heaviest rate visibly inflates it.
                assert!(cells[3] > 1.005, "{machine} {msg}: {cells:?}");
                assert!(cells[3] > cells[0], "{machine} {msg}: {cells:?}");
            }
        }
    }

    #[test]
    fn sweep_overlap_step_time_monotone_and_serial_baseline() {
        let t = sweep_overlap(8);
        let rows = t.rows();
        // 3 shapes (tp8, tp4-pp2, tp2-pp4) x 2 batch sizes x 5 fractions.
        assert_eq!(rows.len(), 3 * 2 * 5, "{rows:?}");
        let ms = |r: &[String], c: usize| r[c].parse::<f64>().unwrap();
        for chunk in rows.chunks(5) {
            // Overlap-0 row: everything exposed, nothing hidden, 1.00x.
            assert_eq!(chunk[0][2], "0.00");
            assert_eq!(chunk[0][6], "1.00x");
            assert_eq!(chunk[0][5], "0.000", "{:?}", chunk[0]);
            // Step time never grows as the fraction rises, and full
            // overlap hides a visible share of the comm.
            for w in chunk.windows(2) {
                assert!(ms(&w[1], 3) <= ms(&w[0], 3) + 1e-9, "{w:?}");
            }
            assert!(ms(&chunk[4], 5) > 0.0, "{:?}", chunk[4]);
            assert!(ms(&chunk[4], 3) < ms(&chunk[0], 3), "{chunk:?}");
        }
    }

    #[test]
    fn soak_run_is_deterministic_and_mixed() {
        // Scaled-down soak: the report must be bit-identical across runs
        // (wall-clock aside) and the pool must actually mix machines.
        let cfg = soak_fleet_config(8).unwrap();
        assert_eq!(cfg.replicas.len(), 8);
        let labels: std::collections::BTreeSet<String> =
            cfg.replicas.iter().map(|r| format!("{:?}", r.gpu)).collect();
        assert!(labels.len() >= 2, "pool must mix GPU kinds: {labels:?}");
        let (a, wa) = soak_run(2000, 8, SOAK_SEED).unwrap();
        let (b, _wb) = soak_run(2000, 8, SOAK_SEED).unwrap();
        assert!(wa >= 0.0);
        assert_eq!(a, b, "soak report must be deterministic");
        assert_eq!(a.completed as u64 + a.rejected, 2000);
        let (c, _) = soak_run(2000, 8, SOAK_SEED + 1).unwrap();
        assert_ne!(a.makespan.to_bits(), c.makespan.to_bits(), "seed must matter");
    }

    #[test]
    fn scaling_tables_have_oom_for_small_gpu_counts() {
        let tables = fig1_fig2_scaling("405b");
        // 405B on 16 GPUs fits, but nothing smaller is even listed.
        assert!(tables[0].rows()[0][1] == "-");
    }
}
