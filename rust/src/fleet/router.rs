//! Request router: pluggable placement policies over a replica pool, with
//! per-replica load and KV-commitment bookkeeping.
//!
//! The router is deliberately *stateful about its own decisions* only: it
//! tracks the predicted seconds and KV pages it has committed to each
//! replica (and releases them on completion), rather than peeking inside
//! replica internals on every arrival. That makes routing O(replicas) per
//! request, keeps the decision deterministic, and gives the KV-capacity
//! invariant a precise statement: under [`RoutePolicy::KvPressure`], the
//! router never commits more pages against a replica than its allocator
//! owns, as long as *some* replica can fit the request (otherwise the
//! pressure-relief path places it on the least-committed replica, where it
//! waits in the batcher queue — admission is still gated by the real
//! allocator, so the replica itself can never over-allocate).
//!
//! **Cost-awareness for heterogeneous fleets**: the caller prices each
//! request *per candidate replica* (the `costs` slice aligned with
//! `views`) through that replica's own [`crate::parallel::StepCost`]
//! model — for a chunked prefill that is remaining-chunk-count × the
//! replica's predicted chunk-step time, plus its predicted decode
//! seconds. `least-tokens` greedily minimizes *predicted completion
//! seconds* (outstanding + this request's cost on that replica), so a
//! TP16 replica absorbs proportionally more load than a TP8 one;
//! `kv-pressure` breaks page-fraction ties toward the faster replica.

use std::collections::BTreeMap;

/// Placement policy for new requests (and, in disaggregated mode, for
/// prefill→decode handoffs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through accepting replicas.
    RoundRobin,
    /// Fewest predicted outstanding-plus-marginal seconds (each request
    /// priced per replica through its own cost model).
    LeastOutstanding,
    /// Lowest committed-KV-pages fraction; never knowingly over-commits.
    KvPressure,
    /// Prefix-cache-aware affinity: the caller probes each replica's
    /// expected cached-prefix hit for the session (`hits`) and discounts
    /// the per-replica costs accordingly, so placement greedily minimizes
    /// *predicted completion seconds including the cache win* — the
    /// session re-lands where its KV lives unless that replica is
    /// overloaded. With no cache signal anywhere (first turn, evicted),
    /// falls back to a sticky session→replica pin so later turns still
    /// co-locate.
    SessionAffinity,
}

impl RoutePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastOutstanding => "least-tokens",
            RoutePolicy::KvPressure => "kv-pressure",
            RoutePolicy::SessionAffinity => "session-affinity",
        }
    }

    pub fn all() -> [RoutePolicy; 4] {
        [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastOutstanding,
            RoutePolicy::KvPressure,
            RoutePolicy::SessionAffinity,
        ]
    }

    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => RoutePolicy::RoundRobin,
            "least-tokens" | "least-outstanding" => RoutePolicy::LeastOutstanding,
            "kv-pressure" | "kv" => RoutePolicy::KvPressure,
            "session-affinity" | "session" => RoutePolicy::SessionAffinity,
            other => anyhow::bail!(
                "unknown routing policy '{other}' (expected round-robin, least-tokens, \
                 kv-pressure or session-affinity)"
            ),
        })
    }
}

/// What the router sees of one replica when placing a request.
#[derive(Clone, Copy, Debug)]
pub struct ReplicaView {
    pub id: usize,
    /// Replica accepts new work (alive, not draining).
    pub accepting: bool,
    /// KV pages its allocator owns in total.
    pub total_pages: usize,
    /// Predicted decode-step seconds of this replica's engine — the
    /// tie-break cost signal for heterogeneous fleets (lower = faster).
    pub pred_step: f64,
}

/// The stateful router.
#[derive(Clone, Debug, Default)]
pub struct Router {
    rr_next: usize,
    committed_pages: Vec<usize>,
    outstanding_secs: Vec<f64>,
    sessions: BTreeMap<u64, usize>,
    /// Candidate-pool scratch reused across [`Router::route`] calls so a
    /// placement allocates nothing: at 10M requests × 100+ replicas the
    /// per-call `Vec` churn of the old path dominated the routing profile.
    scratch: Vec<usize>,
    /// Placements made against each replica (observability for the
    /// heterogeneous-fleet tests and tables; a disaggregated request's
    /// prefill and decode legs count separately).
    pub routed: Vec<u64>,
    /// High-water mark of committed pages on any replica.
    pub max_committed_pages: usize,
    /// Placements that exceeded every accepting replica's capacity bound.
    pub over_capacity_routes: u64,
}

impl Router {
    pub fn new(replicas: usize) -> Self {
        Router {
            rr_next: 0,
            committed_pages: vec![0; replicas],
            outstanding_secs: vec![0.0; replicas],
            sessions: BTreeMap::new(),
            scratch: Vec::new(),
            routed: vec![0; replicas],
            max_committed_pages: 0,
            over_capacity_routes: 0,
        }
    }

    /// Extend bookkeeping when the autoscaler adds replicas.
    pub fn grow(&mut self, replicas: usize) {
        while self.committed_pages.len() < replicas {
            self.committed_pages.push(0);
            self.outstanding_secs.push(0.0);
            self.routed.push(0);
        }
    }

    pub fn committed_pages(&self, replica: usize) -> usize {
        self.committed_pages[replica]
    }

    pub fn outstanding_secs(&self, replica: usize) -> f64 {
        self.outstanding_secs[replica]
    }

    /// Place a request on one of `views` under `policy`, committing
    /// `pages` and `costs[chosen]` predicted seconds of load against the
    /// chosen replica until [`Router::complete`] releases them. `costs`
    /// is aligned with `views`: the request's predicted service seconds
    /// on each candidate (already discounted by `hits` — the expected
    /// cached-prefix tokens per candidate — for the session-affinity
    /// policy; zeros elsewhere). Panics if no view is accepting (the
    /// fleet always keeps ≥1 accepting replica per pool).
    ///
    /// Returns `(replica id, committed seconds)`.
    pub fn route(
        &mut self,
        policy: RoutePolicy,
        views: &[ReplicaView],
        session: u64,
        pages: usize,
        costs: &[f64],
        hits: &[usize],
    ) -> (usize, f64) {
        assert_eq!(views.len(), costs.len(), "one cost per candidate view");
        assert_eq!(views.len(), hits.len(), "one hit estimate per candidate view");
        // Candidate pool in the reusable scratch buffer (taken out of self
        // so the comparators below can still read the commitment tables):
        // accepting replicas that pass the capacity pre-filter — never
        // knowingly commit past a replica's KV allocator. If nothing fits,
        // fall back to every accepting replica (the request queues on the
        // least-committed one) and record the relief placement.
        let mut pool = std::mem::take(&mut self.scratch);
        pool.clear();
        for (i, v) in views.iter().enumerate() {
            if v.accepting && self.committed_pages[v.id] + pages <= v.total_pages {
                pool.push(i);
            }
        }
        if pool.is_empty() {
            pool.extend(views.iter().enumerate().filter(|(_, v)| v.accepting).map(|(i, _)| i));
            assert!(!pool.is_empty(), "router needs at least one accepting replica");
            self.over_capacity_routes += 1;
        }

        let chosen_idx = match policy {
            RoutePolicy::RoundRobin => {
                let idx = self.rr_next % pool.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                pool[idx]
            }
            RoutePolicy::LeastOutstanding => self.least_cost(views, costs, &pool),
            RoutePolicy::KvPressure => {
                // Lowest committed/total fraction, compared exactly via
                // cross-multiplication (deterministic, no float ties);
                // equal fractions go to the faster replica.
                first_min_by(&pool, |a, b| {
                    let (va, vb) = (&views[a], &views[b]);
                    let la = self.committed_pages[va.id] * vb.total_pages.max(1);
                    let lb = self.committed_pages[vb.id] * va.total_pages.max(1);
                    la.cmp(&lb)
                        .then(va.pred_step.total_cmp(&vb.pred_step))
                        .then(va.id.cmp(&vb.id))
                })
            }
            RoutePolicy::SessionAffinity => {
                let chosen = if hits.iter().any(|&h| h > 0) {
                    // Cost-aware: costs arrive hit-discounted, so greedy
                    // predicted-completion placement naturally re-lands
                    // the session where its cache lives — unless that
                    // replica is so loaded the recompute elsewhere is
                    // cheaper. Ties break toward the bigger hit.
                    first_min_by(&pool, |a, b| {
                        let la = self.outstanding_secs[views[a].id] + costs[a];
                        let lb = self.outstanding_secs[views[b].id] + costs[b];
                        la.total_cmp(&lb)
                            .then(hits[b].cmp(&hits[a]))
                            .then(views[a].id.cmp(&views[b].id))
                    })
                } else {
                    // No cache signal anywhere: sticky pin (the warm
                    // prior — the prior turn may still be in flight and
                    // will promote its pages there), else least-cost.
                    let pinned = self.sessions.get(&session).copied();
                    match pinned.and_then(|r| pool.iter().copied().find(|&i| views[i].id == r)) {
                        Some(i) => i,
                        None => self.least_cost(views, costs, &pool),
                    }
                };
                self.sessions.insert(session, views[chosen].id);
                chosen
            }
        };
        self.scratch = pool;

        let chosen = views[chosen_idx].id;
        let secs = costs[chosen_idx];
        self.committed_pages[chosen] += pages;
        self.outstanding_secs[chosen] += secs;
        self.routed[chosen] += 1;
        self.max_committed_pages = self.max_committed_pages.max(self.committed_pages[chosen]);
        (chosen, secs)
    }

    /// Greedy shortest-predicted-completion: outstanding committed seconds
    /// plus this request's own cost on that replica — so faster
    /// (bigger-TP) replicas absorb proportionally more of a heterogeneous
    /// fleet's load, and a replica whose chunked prefill would take many
    /// chunk-steps is priced accordingly.
    fn least_cost(&self, views: &[ReplicaView], costs: &[f64], pool: &[usize]) -> usize {
        first_min_by(pool, |a, b| {
            let la = self.outstanding_secs[views[a].id] + costs[a];
            let lb = self.outstanding_secs[views[b].id] + costs[b];
            la.total_cmp(&lb).then(views[a].id.cmp(&views[b].id))
        })
    }

    /// Release a prior commitment (request completed or handed off).
    pub fn complete(&mut self, replica: usize, pages: usize, secs: f64) {
        debug_assert!(self.committed_pages[replica] >= pages, "commitment underflow");
        self.committed_pages[replica] = self.committed_pages[replica].saturating_sub(pages);
        self.outstanding_secs[replica] = (self.outstanding_secs[replica] - secs).max(0.0);
    }

    /// Drop session stickiness to a retiring replica so future requests
    /// re-pin elsewhere.
    pub fn evict_replica_sessions(&mut self, replica: usize) {
        self.sessions.retain(|_, r| *r != replica);
    }
}

/// First minimal element of a non-empty candidate pool — the same element
/// `Iterator::min_by` returns (it keeps the earliest minimum), but over a
/// borrowed slice so the pool itself never has to be consumed or cloned.
/// Infallible by construction, which is what lets [`Router::route`] stay
/// free of `expect` on a pool it just asserted non-empty.
fn first_min_by(
    pool: &[usize],
    mut cmp: impl FnMut(usize, usize) -> std::cmp::Ordering,
) -> usize {
    let mut best = pool[0];
    for &i in &pool[1..] {
        if cmp(i, best) == std::cmp::Ordering::Less {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(n: usize, pages: usize) -> Vec<ReplicaView> {
        (0..n)
            .map(|id| ReplicaView { id, accepting: true, total_pages: pages, pred_step: 1.0 })
            .collect()
    }

    fn flat(n: usize, cost: f64) -> Vec<f64> {
        vec![cost; n]
    }

    fn no_hits(n: usize) -> Vec<usize> {
        vec![0; n]
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3);
        let v = views(3, 1000);
        let picks: Vec<usize> = (0..6)
            .map(|_| r.route(RoutePolicy::RoundRobin, &v, 0, 1, &flat(3, 1.0), &no_hits(3)).0)
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(r.routed, vec![2, 2, 2]);
    }

    #[test]
    fn least_outstanding_prefers_idle_replica() {
        let mut r = Router::new(2);
        let v = views(2, 1000);
        let (a, s) =
            r.route(RoutePolicy::LeastOutstanding, &v, 0, 1, &flat(2, 100.0), &no_hits(2));
        let (b, _) = r.route(RoutePolicy::LeastOutstanding, &v, 0, 1, &flat(2, 1.0), &no_hits(2));
        assert_eq!((a, b, s), (0, 1, 100.0));
        r.complete(0, 1, 100.0);
        assert_eq!(
            r.route(RoutePolicy::LeastOutstanding, &v, 0, 1, &flat(2, 1.0), &no_hits(2)).0,
            0
        );
        assert_eq!(r.outstanding_secs(0), 1.0);
    }

    #[test]
    fn least_outstanding_weighs_per_replica_cost() {
        // Replica 1 is twice as fast: the same request costs it half the
        // seconds, so greedy completion-time placement sends it more work.
        let mut r = Router::new(2);
        let v = views(2, 1000);
        let costs = [100.0, 50.0];
        let picks: Vec<usize> = (0..3)
            .map(|_| r.route(RoutePolicy::LeastOutstanding, &v, 0, 1, &costs, &no_hits(2)).0)
            .collect();
        // 1 (0+50 < 0+100), 0 (100 vs 50+50 tie -> lower id), 1 (200 vs 150).
        assert_eq!(picks, vec![1, 0, 1]);
    }

    #[test]
    fn kv_pressure_never_exceeds_capacity_when_any_fits() {
        let mut r = Router::new(2);
        let v = views(2, 10);
        for _ in 0..4 {
            r.route(RoutePolicy::KvPressure, &v, 0, 5, &flat(2, 10.0), &no_hits(2));
        }
        assert_eq!(r.committed_pages(0), 10);
        assert_eq!(r.committed_pages(1), 10);
        assert_eq!(r.over_capacity_routes, 0);
        assert_eq!(r.max_committed_pages, 10);
        // Fifth placement cannot fit anywhere: relief path, counted.
        r.route(RoutePolicy::KvPressure, &v, 0, 5, &flat(2, 10.0), &no_hits(2));
        assert_eq!(r.over_capacity_routes, 1);
    }

    #[test]
    fn kv_pressure_breaks_fraction_ties_toward_faster_replica() {
        let mut r = Router::new(2);
        let mut v = views(2, 10);
        v[1].pred_step = 0.5;
        assert_eq!(r.route(RoutePolicy::KvPressure, &v, 0, 2, &flat(2, 1.0), &no_hits(2)).0, 1);
    }

    #[test]
    fn session_affinity_sticks_and_evicts() {
        let mut r = Router::new(3);
        let v = views(3, 1000);
        let first =
            r.route(RoutePolicy::SessionAffinity, &v, 42, 1, &flat(3, 1000.0), &no_hits(3)).0;
        // Same session goes back despite the load imbalance (no cache
        // signal: the sticky pin is the only prior).
        let second =
            r.route(RoutePolicy::SessionAffinity, &v, 42, 1, &flat(3, 1000.0), &no_hits(3)).0;
        assert_eq!(first, second);
        // A different session balances away.
        let other = r.route(RoutePolicy::SessionAffinity, &v, 7, 1, &flat(3, 1.0), &no_hits(3)).0;
        assert_ne!(other, first);
        // After eviction the session re-pins.
        r.evict_replica_sessions(first);
        let mut v2 = v.clone();
        v2[first].accepting = false;
        let repinned =
            r.route(RoutePolicy::SessionAffinity, &v2, 42, 1, &flat(3, 1.0), &no_hits(3)).0;
        assert_ne!(repinned, first);
    }

    #[test]
    fn session_affinity_follows_the_cache_but_yields_under_load() {
        let mut r = Router::new(3);
        let v = views(3, 1000);
        // Replica 2 holds 900 cached tokens of this session's prefix: its
        // discounted cost wins even though the pin points at replica 0.
        let costs = [10.0, 10.0, 1.0];
        let hits = [0usize, 0, 900];
        let picked = r.route(RoutePolicy::SessionAffinity, &v, 42, 1, &costs, &hits).0;
        assert_eq!(picked, 2, "placement follows the cached prefix");
        r.complete(2, 1, 1.0);
        // Same session, but replica 2 is now drowning in outstanding work:
        // recomputing elsewhere is predicted faster, so affinity yields.
        for _ in 0..50 {
            r.route(RoutePolicy::LeastOutstanding, &v, 1, 1, &[100.0, 100.0, 1.0], &no_hits(3));
        }
        assert_eq!(r.outstanding_secs(2), 50.0);
        let picked = r.route(RoutePolicy::SessionAffinity, &v, 42, 1, &[3.0, 3.0, 1.0], &hits).0;
        assert_eq!(picked, 0, "overload beats the cache win");
        // And with the cache gone cold everywhere, the sticky pin (updated
        // to the last placement) takes over.
        let picked =
            r.route(RoutePolicy::SessionAffinity, &v, 42, 1, &flat(3, 1.0), &no_hits(3)).0;
        assert_eq!(picked, 0, "pin remembers the last placement");
    }

    #[test]
    fn draining_replicas_excluded() {
        let mut r = Router::new(2);
        let mut v = views(2, 100);
        v[0].accepting = false;
        for _ in 0..5 {
            assert_eq!(r.route(RoutePolicy::RoundRobin, &v, 0, 1, &flat(2, 1.0), &no_hits(2)).0, 1);
        }
    }

    /// Verbatim pre-optimization routing algorithm — three fresh `Vec`s
    /// and `min_by` per placement — kept as the oracle the zero-allocation
    /// scratch-buffer path must match byte for byte, state and all.
    fn route_reference(
        r: &mut Router,
        policy: RoutePolicy,
        views: &[ReplicaView],
        session: u64,
        pages: usize,
        costs: &[f64],
        hits: &[usize],
    ) -> (usize, f64) {
        assert_eq!(views.len(), costs.len(), "one cost per candidate view");
        assert_eq!(views.len(), hits.len(), "one hit estimate per candidate view");
        let accepting: Vec<usize> = (0..views.len()).filter(|&i| views[i].accepting).collect();
        assert!(!accepting.is_empty(), "router needs at least one accepting replica");
        let fits: Vec<usize> = accepting
            .iter()
            .copied()
            .filter(|&i| r.committed_pages[views[i].id] + pages <= views[i].total_pages)
            .collect();
        let pool: Vec<usize> = if fits.is_empty() {
            r.over_capacity_routes += 1;
            accepting
        } else {
            fits
        };
        let least_cost = |r: &Router, pool: &[usize]| -> usize {
            pool.iter()
                .copied()
                .min_by(|&a, &b| {
                    let la = r.outstanding_secs[views[a].id] + costs[a];
                    let lb = r.outstanding_secs[views[b].id] + costs[b];
                    la.total_cmp(&lb).then(views[a].id.cmp(&views[b].id))
                })
                .expect("non-empty pool")
        };
        let chosen_idx = match policy {
            RoutePolicy::RoundRobin => {
                let idx = r.rr_next % pool.len();
                r.rr_next = r.rr_next.wrapping_add(1);
                pool[idx]
            }
            RoutePolicy::LeastOutstanding => least_cost(r, &pool),
            RoutePolicy::KvPressure => pool
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    let (va, vb) = (&views[a], &views[b]);
                    let la = r.committed_pages[va.id] * vb.total_pages.max(1);
                    let lb = r.committed_pages[vb.id] * va.total_pages.max(1);
                    la.cmp(&lb)
                        .then(va.pred_step.total_cmp(&vb.pred_step))
                        .then(va.id.cmp(&vb.id))
                })
                .expect("non-empty pool"),
            RoutePolicy::SessionAffinity => {
                let chosen = if hits.iter().any(|&h| h > 0) {
                    pool.iter()
                        .copied()
                        .min_by(|&a, &b| {
                            let la = r.outstanding_secs[views[a].id] + costs[a];
                            let lb = r.outstanding_secs[views[b].id] + costs[b];
                            la.total_cmp(&lb)
                                .then(hits[b].cmp(&hits[a]))
                                .then(views[a].id.cmp(&views[b].id))
                        })
                        .expect("non-empty pool")
                } else {
                    let pinned = r.sessions.get(&session).copied();
                    match pinned.and_then(|p| pool.iter().copied().find(|&i| views[i].id == p)) {
                        Some(i) => i,
                        None => least_cost(r, &pool),
                    }
                };
                r.sessions.insert(session, views[chosen].id);
                chosen
            }
        };
        let chosen = views[chosen_idx].id;
        let secs = costs[chosen_idx];
        r.committed_pages[chosen] += pages;
        r.outstanding_secs[chosen] += secs;
        r.routed[chosen] += 1;
        r.max_committed_pages = r.max_committed_pages.max(r.committed_pages[chosen]);
        (chosen, secs)
    }

    fn assert_state_identical(opt: &Router, refr: &Router) {
        assert_eq!(opt.rr_next, refr.rr_next);
        assert_eq!(opt.committed_pages, refr.committed_pages);
        // Outstanding seconds compared bitwise: the scratch path must not
        // reorder a single float add.
        let ob: Vec<u64> = opt.outstanding_secs.iter().map(|x| x.to_bits()).collect();
        let rb: Vec<u64> = refr.outstanding_secs.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ob, rb);
        assert_eq!(opt.sessions, refr.sessions);
        assert_eq!(opt.routed, refr.routed);
        assert_eq!(opt.max_committed_pages, refr.max_committed_pages);
        assert_eq!(opt.over_capacity_routes, refr.over_capacity_routes);
    }

    #[test]
    fn scratch_path_is_byte_identical_to_reference() {
        use crate::util::prop::{check, Gen};
        check("router scratch path ≡ allocating reference", 60, |g: &mut Gen| {
            let n = g.usize(1, 6);
            let mut opt = Router::new(n);
            let mut refr = Router::new(n);
            let policies = RoutePolicy::all();
            let mut live: Vec<(usize, usize, f64)> = Vec::new();
            for _ in 0..g.usize(5, 40) {
                // Occasionally release a live commitment so the pool
                // drains and refills like a real fleet.
                if !live.is_empty() && g.bool() && g.bool() {
                    let k = g.usize(0, live.len() - 1);
                    let (rep, pages, secs) = live.swap_remove(k);
                    opt.complete(rep, pages, secs);
                    refr.complete(rep, pages, secs);
                    assert_state_identical(&opt, &refr);
                    continue;
                }
                let policy = *g.pick(&policies);
                let mut views: Vec<ReplicaView> = (0..n)
                    .map(|id| ReplicaView {
                        id,
                        accepting: g.bool(),
                        total_pages: g.usize(4, 40),
                        pred_step: g.f64(0.1, 2.0),
                    })
                    .collect();
                if !views.iter().any(|v| v.accepting) {
                    views[0].accepting = true;
                }
                let pages = g.usize(0, 12);
                let costs: Vec<f64> = (0..n).map(|_| g.f64(0.0, 50.0)).collect();
                let hits: Vec<usize> =
                    (0..n).map(|_| if g.bool() { 0 } else { g.usize(0, 900) }).collect();
                let session = g.u64(0, 5);
                let a = opt.route(policy, &views, session, pages, &costs, &hits);
                let b = route_reference(&mut refr, policy, &views, session, pages, &costs, &hits);
                assert_eq!(a.0, b.0, "placement diverged");
                assert_eq!(a.1.to_bits(), b.1.to_bits(), "committed seconds diverged");
                assert_state_identical(&opt, &refr);
                live.push((a.0, pages, a.1));
            }
        });
    }

    #[test]
    fn by_name_parses_and_rejects() {
        assert_eq!(RoutePolicy::by_name("kv").unwrap(), RoutePolicy::KvPressure);
        assert_eq!(RoutePolicy::by_name("RR").unwrap(), RoutePolicy::RoundRobin);
        assert!(RoutePolicy::by_name("random").is_err());
        for p in RoutePolicy::all() {
            assert_eq!(RoutePolicy::by_name(p.name()).unwrap(), p);
        }
    }
}
