//! SLO-driven autoscaler: adds replicas when recent tail latency breaches
//! the TTFT/TPOT targets, drains them when the fleet is comfortably under
//! target.
//!
//! Deliberately simple control: a periodic tick computes the p95 of a
//! sliding window of recently-completed requests and compares it against
//! the SLO with hysteresis (scale up above the target, scale down only
//! below `down_frac ×` target with a near-empty queue). One provisioning
//! action is in flight at a time, and new capacity arrives only after
//! `provision_delay` — the cold-start the fleet actually pays.
//!
//! Disaggregated fleets run **two symmetric loops** over the same window:
//! the prefill pool scales on p95 TTFT ([`Autoscaler::decide_prefill`] —
//! first tokens are the prefill pool's product) and the decode pool on p95
//! TPOT ([`Autoscaler::decide_decode`]), each with its own in-flight
//! provisioning flag. Monolithic fleets keep the combined
//! [`Autoscaler::decide`]. Both pools share `min_replicas`/`max_replicas`.

use super::metrics::SloTargets;
use std::collections::VecDeque;

/// Autoscaler tuning.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    /// Control-loop interval (s).
    pub tick: f64,
    /// Replica cold-start: decided → serving (s).
    pub provision_delay: f64,
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Sliding window of completed requests the controller looks at.
    pub window: usize,
    /// Scale down only when p95 TTFT < `down_frac × slo.ttft`.
    pub down_frac: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            tick: 10.0,
            provision_delay: 30.0,
            min_replicas: 1,
            max_replicas: 16,
            window: 128,
            down_frac: 0.25,
        }
    }
}

/// One control decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    Up,
    Down,
    Hold,
}

/// The controller.
#[derive(Clone, Debug)]
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    slo: SloTargets,
    recent_ttft: VecDeque<f64>,
    recent_tpot: VecDeque<f64>,
    /// A scale-up was decided but its replica has not come online yet.
    pub pending_up: bool,
    pub scale_ups: usize,
    pub scale_downs: usize,
    /// Prefill-pool twin of `pending_up` (disaggregated fleets only).
    pub pending_prefill_up: bool,
    pub prefill_scale_ups: usize,
    pub prefill_scale_downs: usize,
    /// Preemptions observed since the previous control tick: KV pressure.
    /// Non-zero vetoes decode/monolithic scale-down — draining capacity
    /// while sequences thrash in and out of KV would amplify the thrash.
    recent_preemptions: u64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig, slo: SloTargets) -> Self {
        Autoscaler {
            cfg,
            slo,
            recent_ttft: VecDeque::new(),
            recent_tpot: VecDeque::new(),
            pending_up: false,
            scale_ups: 0,
            scale_downs: 0,
            pending_prefill_up: false,
            prefill_scale_ups: 0,
            prefill_scale_downs: 0,
            recent_preemptions: 0,
        }
    }

    /// Report the preemptions that occurred since the last control tick
    /// (the fleet feeds the per-tick delta).
    pub fn observe_preemptions(&mut self, n: u64) {
        self.recent_preemptions = n;
    }

    /// Feed one completed request's latencies into the sliding window.
    pub fn observe(&mut self, ttft: f64, tpot: f64) {
        self.recent_ttft.push_back(ttft);
        self.recent_tpot.push_back(tpot);
        while self.recent_ttft.len() > self.cfg.window {
            self.recent_ttft.pop_front();
        }
        while self.recent_tpot.len() > self.cfg.window {
            self.recent_tpot.pop_front();
        }
    }

    fn p95(window: &VecDeque<f64>) -> f64 {
        if window.is_empty() {
            return 0.0;
        }
        let mut v: Vec<f64> = window.iter().copied().collect();
        // total_cmp: an observed NaN latency must not panic the control
        // loop (D02); it sorts last and shows up in the p95 instead.
        v.sort_by(f64::total_cmp);
        v[((v.len() - 1) as f64 * 0.95).round() as usize]
    }

    /// One control tick. `active` counts serving (non-draining) replicas of
    /// the scalable pool; `queued` is fleet-wide not-yet-completed work
    /// (waiting + pending handoffs), used to veto premature scale-down.
    pub fn decide(&mut self, active: usize, queued: usize) -> Decision {
        let ttft95 = Self::p95(&self.recent_ttft);
        let tpot95 = Self::p95(&self.recent_tpot);
        let breach = ttft95 > self.slo.ttft || tpot95 > self.slo.tpot;
        if breach && !self.pending_up && active < self.cfg.max_replicas {
            self.pending_up = true;
            self.scale_ups += 1;
            return Decision::Up;
        }
        let comfortable = !self.recent_ttft.is_empty()
            && ttft95 < self.cfg.down_frac * self.slo.ttft
            && tpot95 < self.slo.tpot
            && queued == 0
            && self.recent_preemptions == 0;
        // min is clamped to 1: draining the last replica would strand work.
        if comfortable && active > self.cfg.min_replicas.max(1) {
            self.scale_downs += 1;
            return Decision::Down;
        }
        Decision::Hold
    }

    /// The shared single-metric control law both per-pool loops apply:
    /// scale up on a windowed-p95 breach of `target` (one provisioning
    /// action in flight at a time), scale down with hysteresis when
    /// comfortably under `down_frac × target` with an empty queue, floored
    /// at `min_replicas` (clamped to 1).
    #[allow(clippy::too_many_arguments)]
    fn single_metric_loop(
        cfg: AutoscaleConfig,
        window: &VecDeque<f64>,
        target: f64,
        active: usize,
        queued: usize,
        pending: &mut bool,
        ups: &mut usize,
        downs: &mut usize,
    ) -> Decision {
        let p95 = Self::p95(window);
        if p95 > target && !*pending && active < cfg.max_replicas {
            *pending = true;
            *ups += 1;
            return Decision::Up;
        }
        let comfortable = !window.is_empty() && p95 < cfg.down_frac * target && queued == 0;
        if comfortable && active > cfg.min_replicas.max(1) {
            *downs += 1;
            return Decision::Down;
        }
        Decision::Hold
    }

    /// Decode-pool tick for disaggregated fleets: TPOT is the decode
    /// pool's product, so only it drives this loop (queueing in front of
    /// prefill replicas must not grow the decode pool).
    pub fn decide_decode(&mut self, active: usize, queued: usize) -> Decision {
        // KV-pressure preemptions veto the comfortable path exactly like a
        // non-empty queue: fold them into the queued signal.
        let queued = queued + self.recent_preemptions as usize;
        Self::single_metric_loop(
            self.cfg,
            &self.recent_tpot,
            self.slo.tpot,
            active,
            queued,
            &mut self.pending_up,
            &mut self.scale_ups,
            &mut self.scale_downs,
        )
    }

    /// Prefill-pool tick, symmetric with the decode loop: windowed p95
    /// TTFT against the SLO, hysteresis on the way down, one provisioning
    /// action in flight. `queued` counts prompts waiting at prefill
    /// replicas.
    pub fn decide_prefill(&mut self, active: usize, queued: usize) -> Decision {
        Self::single_metric_loop(
            self.cfg,
            &self.recent_ttft,
            self.slo.ttft,
            active,
            queued,
            &mut self.pending_prefill_up,
            &mut self.prefill_scale_ups,
            &mut self.prefill_scale_downs,
        )
    }

    /// The provisioned replica came online.
    pub fn replica_online(&mut self) {
        self.pending_up = false;
    }

    /// The provisioned prefill replica came online.
    pub fn prefill_online(&mut self) {
        self.pending_prefill_up = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler(slo_ttft: f64) -> Autoscaler {
        Autoscaler::new(
            AutoscaleConfig { window: 16, min_replicas: 1, max_replicas: 4, ..Default::default() },
            SloTargets { ttft: slo_ttft, tpot: 1.0 },
        )
    }

    #[test]
    fn breach_triggers_single_pending_up() {
        let mut a = scaler(1.0);
        for _ in 0..16 {
            a.observe(5.0, 0.01);
        }
        assert_eq!(a.decide(2, 10), Decision::Up);
        // Second tick while provisioning: no double-fire.
        assert_eq!(a.decide(2, 10), Decision::Hold);
        a.replica_online();
        assert_eq!(a.decide(3, 10), Decision::Up);
        assert_eq!(a.scale_ups, 2);
    }

    #[test]
    fn comfortable_and_idle_scales_down_with_hysteresis() {
        let mut a = scaler(10.0);
        for _ in 0..16 {
            a.observe(0.5, 0.01); // well under 0.25 * 10.0
        }
        assert_eq!(a.decide(3, 0), Decision::Down);
        // Queue pressure vetoes the down-scale.
        assert_eq!(a.decide(3, 50), Decision::Hold);
        // Floor respected.
        assert_eq!(a.decide(1, 0), Decision::Hold);
    }

    #[test]
    fn preemption_pressure_vetoes_scale_down() {
        let mut a = scaler(10.0);
        for _ in 0..16 {
            a.observe(0.5, 0.01); // comfortably under target
        }
        a.observe_preemptions(3);
        assert_eq!(a.decide(3, 0), Decision::Hold, "KV thrash must block drain");
        assert_eq!(a.decide_decode(3, 0), Decision::Hold);
        a.observe_preemptions(0);
        assert_eq!(a.decide(3, 0), Decision::Down);
    }

    #[test]
    fn mid_band_holds() {
        let mut a = scaler(10.0);
        for _ in 0..16 {
            a.observe(5.0, 0.01); // between 2.5 and 10.0
        }
        assert_eq!(a.decide(2, 0), Decision::Hold);
    }

    #[test]
    fn max_replicas_caps_upscale() {
        let mut a = scaler(1.0);
        for _ in 0..16 {
            a.observe(9.0, 0.01);
        }
        assert_eq!(a.decide(4, 10), Decision::Hold);
    }

    #[test]
    fn empty_window_never_scales_down() {
        let mut a = scaler(10.0);
        assert_eq!(a.decide(3, 0), Decision::Hold);
    }

    #[test]
    fn prefill_loop_scales_on_ttft_only() {
        let mut a = scaler(1.0);
        for _ in 0..16 {
            a.observe(5.0, 0.01); // TTFT breached, TPOT comfortable
        }
        assert_eq!(a.decide_prefill(1, 10), Decision::Up);
        assert_eq!(a.prefill_scale_ups, 1);
        // One provisioning action in flight at a time.
        assert_eq!(a.decide_prefill(1, 10), Decision::Hold);
        a.prefill_online();
        assert_eq!(a.decide_prefill(2, 10), Decision::Up);
        // The decode loop is independent: TPOT is fine, so it holds —
        // prefill queueing must not grow the decode pool.
        assert_eq!(a.decide_decode(2, 10), Decision::Hold);
    }

    #[test]
    fn prefill_loop_scales_down_with_floor() {
        let mut a = scaler(10.0);
        for _ in 0..16 {
            a.observe(0.5, 0.01); // well under 0.25 * 10.0
        }
        assert_eq!(a.decide_prefill(3, 0), Decision::Down);
        assert_eq!(a.prefill_scale_downs, 1);
        // Queue pressure vetoes; floor of 1 respected.
        assert_eq!(a.decide_prefill(3, 5), Decision::Hold);
        assert_eq!(a.decide_prefill(1, 0), Decision::Hold);
    }

    #[test]
    fn decode_loop_scales_on_tpot() {
        let mut a = scaler(10.0); // ttft SLO generous; tpot SLO is 1.0
        for _ in 0..16 {
            a.observe(0.5, 5.0); // TPOT breached
        }
        assert_eq!(a.decide_decode(2, 10), Decision::Up);
        a.replica_online();
        for _ in 0..16 {
            a.observe(0.5, 0.01); // comfortable again
        }
        assert_eq!(a.decide_decode(3, 0), Decision::Down);
    }
}
