//! Multi-replica SLO-aware serving fleet — the layer *above* the engine.
//!
//! The paper's serving experiments (Figs 9/17/18) stop at one engine
//! replica; production serving needs routing, load-balancing and scaling
//! across many. This module is a discrete-event fleet simulation over
//! [`crate::simnet::EventQueue`] in which every replica wraps the **real**
//! scheduling machinery — [`crate::engine::batcher::Batcher`] +
//! [`crate::engine::kv::PagedKv`] — and owns its *own*
//! [`crate::serving::ServeConfig`], i.e. its own
//! [`crate::parallel::ParallelSpec`] + [`crate::parallel::StepCost`] model
//! (perfmodel GEMMs + the chosen [`crate::collectives::AllReduceImpl`]).
//! Heterogeneous fleets — mixed TP8/TP16 replicas, or different machines'
//! pools — are just different per-replica configs side by side. Pieces:
//!
//! - [`router`] — pluggable placement policies (round-robin,
//!   least-outstanding-tokens, KV-pressure-aware, session-affinity) with
//!   per-replica KV-commitment bookkeeping, made **cost-aware** through
//!   each replica's predicted step time. Session affinity is
//!   **prefix-cache-aware**: arrivals probe each replica's shared-prefix
//!   KV cache ([`crate::engine::kv::PagedKv::lookup_prefix`]) and the
//!   expected hit discounts that replica's predicted cost, so sessions
//!   re-land where their KV lives — and measurably win TTFT on
//!   multi-turn [`crate::trace::SessionSpec`] workloads.
//! - **Disaggregated prefill/decode pools** — prefill replicas produce the
//!   first token, then the prompt's KV pages migrate to a decode replica
//!   as a real network transfer over [`crate::cluster::Topology`]'s
//!   inter-node link (FIFO-serialized per target NIC).
//! - **KV migration on drain** — a draining replica does not pin its
//!   hardware until its decodes finish: its waiting work re-routes, its
//!   partial prefills restart elsewhere, and its running decodes ship
//!   their accumulated KV context to peers over the same α-β-priced
//!   inter-node path the prefill→decode handoff uses, so the replica
//!   retires as soon as its current step completes.
//! - [`autoscaler`] — scales the decode/monolithic pool on p95 TTFT/TPOT
//!   breaches and (disaggregated) the prefill pool symmetrically on p95
//!   TTFT; drains replicas when comfortable. Pool resizes trigger the
//!   **NVRAR re-tune hook**: each surviving NVRAR replica rebuilds its
//!   [`crate::collectives::tuner::TunedTable`] and re-applies the B_s ×
//!   C_s entry for the new batch regime's all-reduce message size.
//! - [`metrics`] — p50/p95/p99 TTFT, TPOT, SLO attainment and goodput via
//!   [`crate::util::stats`], plus cache hit-rate, migration and re-tune
//!   counters.
//!
//! Invariants enforced at the end of every run (and property-tested):
//! every admitted request completes exactly once across the fleet, no
//! replica leaks KV pages, and the whole simulation is bit-deterministic
//! for a fixed trace seed.

pub mod autoscaler;
pub mod metrics;
pub mod router;

use crate::collectives::sim::CommConfig;
use crate::collectives::tuner::TunedTable;
use crate::collectives::AllReduceImpl;
use crate::engine::batcher::{Batcher, MigratedSeq, PrefillChunk, Request, StepBatch};
use crate::engine::kv::{KvError, PagedKv};
use crate::metrics::Breakdown;
use crate::obs::{ArgV, ObsSink, Track};
use crate::serving::{Fabric, ServeConfig};
use crate::simnet::{EventQueue, Interconnect, LinkId, LinkKind, Server};
use autoscaler::{AutoscaleConfig, Autoscaler, Decision};
use metrics::{FleetMetrics, FleetReport, SloTargets};
use router::{ReplicaView, RoutePolicy, Router};
use std::collections::VecDeque;

/// Which pool a replica serves in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    /// Full-lifecycle replica (prefill + decode on the same engine).
    Monolithic,
    /// Prefill-only replica: runs prompts, produces the first token, then
    /// hands the KV cache off.
    Prefill,
    /// Decode-only replica: receives prefilled KV and streams tokens.
    Decode,
}

/// Fleet deployment description: one [`ServeConfig`] per replica, so a
/// fleet can mix parallelism specs and GPU counts freely (all replicas
/// must serve the same model and share a KV page size).
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Replicas of the scalable pool (monolithic, or decode when
    /// disaggregated) — heterogeneous fleets list different configs here.
    /// The autoscaler provisions clones of `replicas[0]`.
    pub replicas: Vec<ServeConfig>,
    /// Prefill-pool replicas; empty = monolithic fleet. The prefill
    /// autoscaler provisions clones of `prefill[0]`.
    pub prefill: Vec<ServeConfig>,
    /// Routing policy for the monolithic pool (or, when disaggregated,
    /// for prefill→decode placement; prefill placement is
    /// least-outstanding, except under session affinity where the prefill
    /// pool is routed prefix-cache-aware too — that pool is where the
    /// cache pays).
    pub policy: RoutePolicy,
    pub slo: SloTargets,
    /// SLO-driven scaling; `None` = fixed fleet.
    pub autoscale: Option<AutoscaleConfig>,
    /// Migrate a draining replica's in-flight KV to peers instead of
    /// letting it decode to idle in place.
    pub migrate_on_drain: bool,
    /// Scripted drains `(time, replica index)` — exercises the drain /
    /// migration path deterministically without an autoscaler. A drain of
    /// the last accepting replica of a pool is skipped.
    pub drain_at: Vec<(f64, usize)>,
    /// Shared-interconnect contention (off by default, preserving every
    /// pre-contention fleet number bit for bit). When on, one
    /// [`Fabric`] spans the fleet — every replica books its collective
    /// bytes on its own per-node links, and KV handoffs / drain
    /// migrations book the source's **and** target's inter-node NICs
    /// instead of the standalone α-β path, so concurrent transfers and
    /// decode all-reduces inflate each other.
    pub contention: bool,
    /// Event recorder ([`crate::obs`]) shared by every replica: step spans
    /// per replica track, collective phases and KV transfers on link
    /// tracks, routing/scaling decisions on the control track. `None`
    /// (the default) disables tracing; recording never feeds back into
    /// any simulated quantity.
    pub obs: Option<ObsSink>,
}

impl FleetConfig {
    /// Homogeneous fleet: `n` replicas of `base`.
    pub fn new(base: ServeConfig, n: usize) -> Self {
        Self::heterogeneous(vec![base; n])
    }

    /// Fleet with explicit per-replica configs (mixed TP8/TP16 etc.).
    pub fn heterogeneous(replicas: Vec<ServeConfig>) -> Self {
        FleetConfig {
            replicas,
            prefill: Vec::new(),
            policy: RoutePolicy::LeastOutstanding,
            slo: SloTargets::default(),
            autoscale: None,
            migrate_on_drain: true,
            drain_at: Vec::new(),
            contention: false,
            obs: None,
        }
    }

    pub fn with_policy(mut self, policy: RoutePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Add `n` prefill-only replicas cloned from the first scalable
    /// replica's config; the existing `replicas` become decode-only.
    pub fn disaggregated(self, n: usize) -> Self {
        assert!(n >= 1, "disaggregation needs at least one prefill replica");
        assert!(!self.replicas.is_empty(), "need a replica to clone");
        let base = self.replicas[0].clone();
        self.with_prefill_pool(vec![base; n])
    }

    /// Explicit prefill-pool configs (may differ from the decode pool's).
    pub fn with_prefill_pool(mut self, prefill: Vec<ServeConfig>) -> Self {
        self.prefill = prefill;
        self
    }

    pub fn with_slo(mut self, slo: SloTargets) -> Self {
        self.slo = slo;
        self
    }

    pub fn with_autoscale(mut self, cfg: AutoscaleConfig) -> Self {
        self.autoscale = Some(cfg);
        self
    }

    /// Enable/disable KV migration on drain (on by default).
    pub fn with_migration(mut self, on: bool) -> Self {
        self.migrate_on_drain = on;
        self
    }

    /// Schedule a scripted drain of replica `replica` at time `t`.
    pub fn with_drain_at(mut self, t: f64, replica: usize) -> Self {
        self.drain_at.push((t, replica));
        self
    }

    /// Enable/disable shared-interconnect contention (off by default).
    pub fn with_contention(mut self, on: bool) -> Self {
        self.contention = on;
        self
    }

    /// Attach an event recorder — every replica, link booking, and fleet
    /// decision of the run records into it.
    pub fn with_obs(mut self, obs: ObsSink) -> Self {
        self.obs = Some(obs);
        self
    }

    fn disaggregated_mode(&self) -> bool {
        !self.prefill.is_empty()
    }

    fn scalable_kind(&self) -> PoolKind {
        if self.disaggregated_mode() {
            PoolKind::Decode
        } else {
            PoolKind::Monolithic
        }
    }
}

/// Run `reqs` (sorted by arrival) through the fleet; panics on any
/// conservation/allocator invariant violation, returns the metrics report.
pub fn run_fleet(cfg: &FleetConfig, reqs: &[Request]) -> FleetReport {
    assert!(!cfg.replicas.is_empty(), "need at least one serving replica");
    let page_tokens = cfg.replicas[0].kv_page_tokens.max(1);
    for c in cfg.replicas.iter().chain(cfg.prefill.iter()) {
        // Routing commits pages before a target is chosen, so page
        // geometry must be fleet-uniform (specs/GPU counts may differ).
        assert_eq!(
            c.kv_page_tokens.max(1),
            page_tokens,
            "fleet replicas must share one KV page size"
        );
        // Handoff sizing and admission math read replicas[0].model, so the
        // documented one-model-per-fleet constraint is enforced here too.
        assert_eq!(
            c.model.name, cfg.replicas[0].model.name,
            "fleet replicas must serve the same model"
        );
    }
    for (i, r) in reqs.iter().enumerate() {
        // The simulation indexes per-request state by id, so ids must be
        // the dense 0..n the trace generators produce.
        assert_eq!(r.id, i as u64, "request ids must be dense 0..n in arrival order");
    }
    Sim::new(cfg, reqs).run()
}

/// Can request `r` ever complete on every replica of this fleet? The
/// decode/monolithic pool must hold the full lifetime context (prompt +
/// decoded tokens); a prefill-only replica just the prompt. Routing can
/// place a request on *any* replica of a pool, so feasibility is required
/// against all of them (the autoscaler only clones existing templates).
/// Conservative under prefix sharing: a cached prefix would shrink the
/// real footprint, but cache contents are not admission guarantees.
fn feasible(cfg: &FleetConfig, page_tokens: usize, r: &Request) -> bool {
    let lifetime = (r.prompt_len + r.decode_len.saturating_sub(1)).max(1).div_ceil(page_tokens);
    let prompt = r.prompt_len.max(1).div_ceil(page_tokens);
    cfg.replicas.iter().all(|c| lifetime <= c.kv_pages)
        && cfg.prefill.iter().all(|c| prompt <= c.kv_pages)
}

// ---------------------------------------------------------------------
// Internals
// ---------------------------------------------------------------------

enum Ev {
    Arrival(usize),
    StepDone(usize),
    /// KV landed at `replica` — a prefill→decode handoff or a drain
    /// migration. `req` is the sequence to admit via the prefilled path
    /// (`prompt_len` = context tokens held in KV, `decode_len - 1` =
    /// tokens still to decode).
    Handoff { replica: usize, req: Request },
    ScaleTick,
    ReplicaUp(PoolKind),
    DrainAt(usize),
}

/// Load the router has committed for one request against one replica.
#[derive(Clone, Copy, Debug)]
struct Commit {
    replica: usize,
    pages: usize,
    secs: f64,
}

struct Replica {
    kind: PoolKind,
    /// This replica's own engine config (spec + cost model + KV sizing).
    cfg: ServeConfig,
    /// The comm config the replica was provisioned with — the base the
    /// NVRAR re-tune hook re-applies tuned parameters onto.
    base_comm: CommConfig,
    /// Predicted decode-step seconds (probe through the cost model) — the
    /// router's cost-awareness signal.
    pred_step: f64,
    /// Predicted seconds of one full prefill chunk step on this replica —
    /// with `pred_step`, prices a request as remaining-chunk cost.
    pred_chunk: f64,
    kv: PagedKv,
    batcher: Batcher,
    stepping: bool,
    current: Option<StepBatch>,
    draining: bool,
    /// When the drain decision was taken (drain-duration metric).
    drain_start: Option<f64>,
    retired: bool,
    /// Handed-off/migrated sequences waiting for concurrency/KV admission.
    pending: VecDeque<Request>,
    /// Ingress NIC serializing KV transfers into this replica.
    ingress: Server,
}

/// Probe the cost model with a canonical single-decode step: the relative
/// ordering across replicas is what routing needs.
fn predict_step(cfg: &ServeConfig) -> f64 {
    let probe = StepBatch { prefills: vec![], decodes: vec![0], decode_ctx: vec![1024] };
    cfg.step_time(&probe)
}

/// Probe the cost model with one full prefill chunk: the unit of the
/// router's remaining-chunk prefill cost.
fn predict_chunk(cfg: &ServeConfig) -> f64 {
    let chunk = cfg.effective_chunk().max(1);
    let probe = StepBatch {
        prefills: vec![PrefillChunk { id: 0, tokens: chunk, ctx: chunk, last: true }],
        decodes: vec![],
        decode_ctx: vec![],
    };
    cfg.step_time(&probe)
}

struct Sim<'a> {
    cfg: &'a FleetConfig,
    reqs: &'a [Request],
    page_tokens: usize,
    q: EventQueue<Ev>,
    replicas: Vec<Replica>,
    router: Router,
    autoscaler: Option<Autoscaler>,
    metrics: FleetMetrics,
    /// First-token timestamp per request (`None` until the last prefill
    /// chunk completes).
    first_token: Vec<Option<f64>>,
    /// Tokens actually produced per request (prefill's first token + one
    /// per decode-step participation).
    produced: Vec<u32>,
    done: Vec<bool>,
    commit_prefill: Vec<Option<Commit>>,
    commit_main: Vec<Option<Commit>>,
    last_done: f64,
    peak_replicas: usize,
    peak_prefill: usize,
    handoffs: u64,
    handoff_bytes: u64,
    /// In-flight sequences shipped off draining replicas.
    migrations: u64,
    migration_bytes: u64,
    drains: u64,
    drain_secs: f64,
    retunes: u64,
    /// Requests dropped up front because their KV footprint can never fit.
    rejected: u64,
    /// Fleet-wide preemption count at the last autoscaler tick.
    preempt_snapshot: u64,
    /// Shared interconnect (contention mode); every replica's scope is its
    /// index, registered at push time.
    fabric: Option<Fabric>,
    /// Analytic per-replica breakdown accumulators (tracing only; one per
    /// pushed replica, parallel to `replicas`).
    bd: Vec<Breakdown>,
    /// Fleet-wide exposed/hidden collective seconds and booked fabric
    /// bytes, accumulated from every step's [`crate::parallel::StepTiming`]
    /// (the exposed/hidden legs are 0.0 on the fast path, like `bd`).
    comm_exposed: f64,
    comm_hidden: f64,
    booked_bytes: f64,
    /// Routing scratch reused across placement decisions — the candidate
    /// views, per-candidate costs and prefix-hit estimates were three
    /// fresh `Vec`s per request in the old path, which at 10M requests ×
    /// 100+ replicas dominated the fleet loop's allocation profile.
    scratch_views: Vec<ReplicaView>,
    scratch_costs: Vec<f64>,
    scratch_hits: Vec<usize>,
}

impl<'a> Sim<'a> {
    fn new(cfg: &'a FleetConfig, reqs: &'a [Request]) -> Self {
        let mut sim = Sim {
            cfg,
            reqs,
            page_tokens: cfg.replicas[0].kv_page_tokens.max(1),
            q: EventQueue::new(),
            replicas: Vec::new(),
            router: Router::new(0),
            autoscaler: cfg.autoscale.map(|a| Autoscaler::new(a, cfg.slo)),
            metrics: FleetMetrics::new(),
            first_token: vec![None; reqs.len()],
            produced: vec![0; reqs.len()],
            done: vec![false; reqs.len()],
            commit_prefill: vec![None; reqs.len()],
            commit_main: vec![None; reqs.len()],
            last_done: 0.0,
            peak_replicas: 0,
            peak_prefill: 0,
            handoffs: 0,
            handoff_bytes: 0,
            migrations: 0,
            migration_bytes: 0,
            drains: 0,
            drain_secs: 0.0,
            retunes: 0,
            rejected: 0,
            preempt_snapshot: 0,
            fabric: if cfg.contention {
                Some(std::sync::Arc::new(std::sync::Mutex::new(Interconnect::new())))
            } else {
                None
            },
            bd: Vec::new(),
            comm_exposed: 0.0,
            comm_hidden: 0.0,
            booked_bytes: 0.0,
            scratch_views: Vec::new(),
            scratch_costs: Vec::new(),
            scratch_hits: Vec::new(),
        };
        let scalable = cfg.scalable_kind();
        for c in &cfg.replicas {
            sim.push_replica(scalable, c.clone());
        }
        for c in &cfg.prefill {
            sim.push_replica(PoolKind::Prefill, c.clone());
        }
        for (i, r) in reqs.iter().enumerate() {
            if !feasible(cfg, sim.page_tokens, r) {
                // Structured rejection instead of a trace-wide panic: the
                // request is counted and skipped, the rest of the trace
                // serves normally.
                sim.rejected += 1;
                sim.done[i] = true;
                continue;
            }
            sim.q.push(r.arrival, Ev::Arrival(i));
        }
        if let Some(a) = &sim.autoscaler {
            sim.q.push(a.cfg.tick, Ev::ScaleTick);
        }
        for &(t, r) in &cfg.drain_at {
            sim.q.push(t, Ev::DrainAt(r));
        }
        sim
    }

    fn run(mut self) -> FleetReport {
        while let Some((now, ev)) = self.q.pop() {
            match ev {
                Ev::Arrival(i) => self.on_arrival(i),
                Ev::StepDone(r) => self.on_step_done(r, now),
                Ev::Handoff { replica, req } => self.on_handoff(replica, req),
                Ev::ScaleTick => self.on_scale_tick(),
                Ev::ReplicaUp(kind) => self.on_replica_up(kind),
                Ev::DrainAt(r) => self.on_drain_at(r),
            }
        }
        // Conservation + allocator cleanliness: the fleet's contract —
        // every admitted request completes, every rejection is counted.
        assert_eq!(
            self.metrics.completed() as u64 + self.rejected,
            self.reqs.len() as u64,
            "request conservation violated"
        );
        for (i, d) in self.done.iter().enumerate() {
            assert!(*d, "request {i} never completed");
        }
        for rep in &self.replicas {
            assert_eq!(rep.kv.used_pages(), 0, "replica leaked KV pages");
            rep.kv.check_invariants();
        }
        let mut report = self.metrics.report(self.last_done);
        if let Some(a) = &self.autoscaler {
            report.scale_ups = a.scale_ups;
            report.scale_downs = a.scale_downs;
            report.prefill_scale_ups = a.prefill_scale_ups;
            report.prefill_scale_downs = a.prefill_scale_downs;
        }
        report.peak_replicas = self.peak_replicas;
        report.peak_prefill = self.peak_prefill;
        report.handoffs = self.handoffs;
        report.handoff_gb = self.handoff_bytes as f64 / (1u64 << 30) as f64;
        report.migrations = self.migrations;
        report.migration_gb = self.migration_bytes as f64 / (1u64 << 30) as f64;
        report.drains = self.drains;
        report.drain_secs = self.drain_secs;
        report.retunes = self.retunes;
        report.max_committed_pages = self.router.max_committed_pages;
        report.over_capacity_routes = self.router.over_capacity_routes;
        report.routed = self.router.routed.clone();
        report.rejected = self.rejected;
        report.preemptions = self.replicas.iter().map(|r| r.batcher.preemptions()).sum();
        if let Some(fab) = &self.fabric {
            let net = fab.lock().unwrap_or_else(|e| e.into_inner());
            report.net_util_intra = net.utilization(LinkKind::Intra, self.last_done);
            report.net_util_inter = net.utilization(LinkKind::Inter, self.last_done);
            report.congestion = net.stats().clone();
        }
        report.comm_exposed = self.comm_exposed;
        report.comm_hidden = self.comm_hidden;
        report.booked_gb = self.booked_bytes / 1e9;
        let (hit, prompt) = self.replicas.iter().fold((0u64, 0u64), |(h, p), r| {
            let s = r.kv.stats();
            (h + s.hit_tokens, p + s.prompt_tokens)
        });
        report.cached_tokens = hit;
        report.cache_hit_rate = if prompt == 0 { 0.0 } else { hit as f64 / prompt as f64 };
        if let Some(sink) = &self.cfg.obs {
            let mut rec = sink.lock().unwrap_or_else(|e| e.into_inner());
            rec.set_makespan(self.last_done);
            if rec.meta.label.is_empty() {
                rec.meta.label =
                    format!("fleet x{} {}", self.replicas.len(), self.cfg.replicas[0].deployment_label());
            }
            if rec.meta.model.is_empty() {
                rec.meta.model = self.cfg.replicas[0].model.name.to_string();
            }
            // Per-replica analytic breakdowns, idle-filled to the makespan
            // — the reference the event-stream fold is reconciled against.
            report.breakdowns = self
                .bd
                .iter()
                .map(|b| {
                    let mut b = *b;
                    b.idle += (self.last_done - b.total()).max(0.0);
                    b
                })
                .collect();
        }
        report
    }

    // -- event handlers ------------------------------------------------

    /// Predicted service seconds of one routing leg on replica `r`:
    /// remaining prefill chunks × the replica's chunk-step probe, plus
    /// decode tokens × its decode-step probe.
    fn leg_cost(&self, r: usize, prompt: usize, decode: usize) -> f64 {
        let rep = &self.replicas[r];
        let chunk = rep.cfg.effective_chunk().max(1);
        prompt.div_ceil(chunk) as f64 * rep.pred_chunk + decode as f64 * rep.pred_step
    }

    /// Expected cached-prefix tokens per candidate replica — the router's
    /// prefix-affinity signal. Only the session-affinity policy probes the
    /// allocators; every other policy stays content-blind (and with solo
    /// sessions the probe returns zeros anyway).
    fn fill_hits(&self, views: &[ReplicaView], req: &Request, out: &mut Vec<usize>) {
        out.clear();
        if self.cfg.policy != RoutePolicy::SessionAffinity {
            out.resize(views.len(), 0);
            return;
        }
        out.extend(
            views.iter().map(|v| self.replicas[v.id].kv.lookup_prefix(req.session, req.prompt_len)),
        );
    }

    fn on_arrival(&mut self, i: usize) {
        let req = self.reqs[i];
        if let Some(sink) = &self.cfg.obs {
            sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                Track::Control,
                "arrival",
                req.arrival,
                vec![
                    ("req", ArgV::U(req.id)),
                    ("prompt", ArgV::U(req.prompt_len as u64)),
                    ("decode", ArgV::U(req.decode_len as u64)),
                ],
            );
        }
        if self.cfg.disaggregated_mode() {
            // The prefill replica's product is exactly the first token:
            // submit with a single-token decode so the sequence retires at
            // last-chunk completion and its KV is freed for the handoff.
            self.route_queued(PoolKind::Prefill, Request { decode_len: 1, ..req });
        } else {
            self.route_queued(PoolKind::Monolithic, req);
        }
    }

    /// Place (or re-place, after a drain) a request that holds no KV yet:
    /// prefill legs commit against `commit_prefill`, full-lifecycle legs
    /// against `commit_main`. Session-affinity placements are discounted
    /// by each candidate's expected prefix-cache hit.
    fn route_queued(&mut self, kind: PoolKind, req: Request) {
        let i = req.id as usize;
        let mut views = std::mem::take(&mut self.scratch_views);
        self.fill_views(kind, &mut views);
        let mut hits = std::mem::take(&mut self.scratch_hits);
        self.fill_hits(&views, &req, &mut hits);
        let mut costs = std::mem::take(&mut self.scratch_costs);
        costs.clear();
        let (pages, policy) = match kind {
            PoolKind::Prefill => {
                costs.extend(
                    views
                        .iter()
                        .zip(&hits)
                        .map(|(v, &h)| self.leg_cost(v.id, req.prompt_len - h, 0)),
                );
                // Prefill placement is least-outstanding, except under
                // session affinity: the prefill pool is where the prefix
                // cache actually pays.
                let policy = if self.cfg.policy == RoutePolicy::SessionAffinity {
                    RoutePolicy::SessionAffinity
                } else {
                    RoutePolicy::LeastOutstanding
                };
                (self.pages_for(req.prompt_len), policy)
            }
            PoolKind::Monolithic | PoolKind::Decode => {
                costs.extend(
                    views
                        .iter()
                        .zip(&hits)
                        .map(|(v, &h)| self.leg_cost(v.id, req.prompt_len - h, req.decode_len)),
                );
                (self.pages_for(req.prompt_len + req.decode_len), self.cfg.policy)
            }
        };
        let old = match kind {
            PoolKind::Prefill => self.commit_prefill[i].take(),
            _ => self.commit_main[i].take(),
        };
        if let Some(c) = old {
            self.router.complete(c.replica, c.pages, c.secs);
        }
        let (target, secs) = self.router.route(policy, &views, req.session, pages, &costs, &hits);
        self.scratch_views = views;
        self.scratch_costs = costs;
        self.scratch_hits = hits;
        if let Some(sink) = &self.cfg.obs {
            sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                Track::Control,
                "route",
                self.q.now(),
                vec![
                    ("req", ArgV::U(req.id)),
                    ("replica", ArgV::U(target as u64)),
                    ("pages", ArgV::U(pages as u64)),
                ],
            );
        }
        let commit = Some(Commit { replica: target, pages, secs });
        match kind {
            PoolKind::Prefill => self.commit_prefill[i] = commit,
            _ => self.commit_main[i] = commit,
        }
        self.replicas[target].batcher.submit(req);
        self.try_start(target);
    }

    fn on_step_done(&mut self, r: usize, now: f64) {
        let rep = &mut self.replicas[r];
        rep.stepping = false;
        let kind = rep.kind;
        let Some(step) = rep.current.take() else {
            debug_assert!(false, "StepDone for replica {r} with no step in flight");
            return;
        };
        let (outcome, finished) = {
            let rep = &mut self.replicas[r];
            let outcome = rep.batcher.complete_step(&step, &mut rep.kv);
            (outcome, rep.batcher.take_finished())
        };
        // A *last chunk's* completion IS the first token, in every pool
        // kind — earlier chunks only build context. A preempted-and-
        // resumed sequence re-runs its prefill, but its first token
        // already happened: keep the original timestamp.
        for c in &step.prefills {
            if c.last {
                let i = c.id as usize;
                if self.first_token[i].is_none() {
                    self.first_token[i] = Some(now);
                    if let Some(sink) = &self.cfg.obs {
                        sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                            Track::Replica(r),
                            "first_token",
                            now,
                            vec![("req", ArgV::U(c.id))],
                        );
                    }
                }
                self.produced[i] += 1;
            }
        }
        for id in &step.decodes {
            self.produced[*id as usize] += 1;
        }
        for id in &outcome.preempted {
            // The preempted row's pending token was discarded; the resumed
            // prefill re-produces it, so conservation holds.
            self.produced[*id as usize] -= 1;
        }
        if let Some(sink) = &self.cfg.obs {
            let mut rec = sink.lock().unwrap_or_else(|e| e.into_inner());
            for id in &outcome.preempted {
                rec.instant(Track::Replica(r), "preempt", now, vec![("req", ArgV::U(*id))]);
            }
            rec.instant(
                Track::Replica(r),
                "toks",
                now,
                vec![("n", ArgV::U(outcome.new_tokens as u64))],
            );
            let kv = &self.replicas[r].kv;
            let frac = kv.used_pages() as f64 / kv.total_pages().max(1) as f64;
            rec.instant(Track::Replica(r), "kv", now, vec![("frac", ArgV::F(frac))]);
        }
        let reqs = self.reqs;
        for id in finished {
            let i = id as usize;
            match kind {
                PoolKind::Prefill => {
                    if let Some(c) = self.commit_prefill[i].take() {
                        self.router.complete(c.replica, c.pages, c.secs);
                    }
                    if reqs[i].decode_len <= 1 {
                        self.complete_request(i, now);
                    } else {
                        self.start_handoff(i, r, now);
                    }
                }
                PoolKind::Monolithic | PoolKind::Decode => {
                    if let Some(c) = self.commit_main[i].take() {
                        self.router.complete(c.replica, c.pages, c.secs);
                    }
                    self.complete_request(i, now);
                }
            }
        }
        self.replicas[r].batcher.recycle(step);
        if self.replicas[r].draining && self.cfg.migrate_on_drain {
            // The step that was in flight at drain time has completed:
            // everything left (including rows it just decoded) migrates
            // now instead of starting another step.
            self.try_migrate(r, now);
        } else {
            self.try_start(r);
        }
        self.maybe_retire(r, now);
    }

    /// Ship `bytes` of KV context from replica `from` into replica `to`
    /// starting at `now`; returns the landing time (link α included).
    /// Under contention the transfer books the source's and the target's
    /// node-0 inter-node NICs on the shared fabric — the same links the
    /// decode all-reduces occupy, so each slows the other; otherwise it
    /// takes the pre-contention path (target ingress [`Server`] at full
    /// β), preserving those runs bit for bit.
    fn kv_transfer(&mut self, from: usize, to: usize, bytes: u64, now: f64) -> f64 {
        let link = self.cfg.replicas[0].topo.inter;
        let landed = if let Some(fab) = &self.fabric {
            let mut net = fab.lock().unwrap_or_else(|e| e.into_inner());
            net.advance(now);
            let eg =
                net.book(LinkId { scope: from, node: 0, kind: LinkKind::Inter }, now, bytes as f64);
            let ing =
                net.book(LinkId { scope: to, node: 0, kind: LinkKind::Inter }, now, bytes as f64);
            eg.end.max(ing.end) + link.alpha
        } else {
            let (_start, end) = self.replicas[to].ingress.book(now, bytes as f64 / link.beta);
            end + link.alpha
        };
        if let Some(sink) = &self.cfg.obs {
            // The transfer occupies the target's ingress NIC: one span on
            // its inter-node link track.
            sink.lock().unwrap_or_else(|e| e.into_inner()).span(
                Track::Link { scope: to, kind: LinkKind::Inter },
                "xfer",
                now,
                landed - now,
                vec![
                    ("bytes", ArgV::U(bytes)),
                    ("from", ArgV::U(from as u64)),
                    ("to", ArgV::U(to as u64)),
                ],
            );
        }
        landed
    }

    /// Ship request `i`'s prompt KV from its prefill replica `from` to a
    /// decode replica chosen by the configured policy (priced by its
    /// remaining decode cost — the prefill leg is already done).
    fn start_handoff(&mut self, i: usize, from: usize, now: f64) {
        let req = self.reqs[i];
        let mut views = std::mem::take(&mut self.scratch_views);
        self.fill_views(PoolKind::Decode, &mut views);
        let mut costs = std::mem::take(&mut self.scratch_costs);
        costs.clear();
        costs.extend(views.iter().map(|v| self.leg_cost(v.id, 0, req.decode_len)));
        let mut no_hits = std::mem::take(&mut self.scratch_hits);
        no_hits.clear();
        no_hits.resize(views.len(), 0);
        let pages = self.pages_for(req.prompt_len + req.decode_len);
        let (target, secs) =
            self.router.route(self.cfg.policy, &views, req.session, pages, &costs, &no_hits);
        self.scratch_views = views;
        self.scratch_costs = costs;
        self.scratch_hits = no_hits;
        self.commit_main[i] = Some(Commit { replica: target, pages, secs });
        let bytes = self.kv_context_bytes(req.prompt_len);
        let landed = self.kv_transfer(from, target, bytes, now);
        self.handoffs += 1;
        self.handoff_bytes += bytes;
        if let Some(sink) = &self.cfg.obs {
            sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                Track::Control,
                "handoff",
                now,
                vec![
                    ("req", ArgV::U(req.id)),
                    ("from", ArgV::U(from as u64)),
                    ("to", ArgV::U(target as u64)),
                    ("bytes", ArgV::U(bytes)),
                ],
            );
        }
        self.q.push(landed, Ev::Handoff { replica: target, req });
    }

    /// Price and ship one migrating sequence's KV context from replica
    /// `from` to a peer of `pool`: the router commitment moves to the
    /// target, the bytes flow over the inter-node path (the same one a
    /// prefill→decode handoff takes — under contention, the shared
    /// fabric's NICs), and the sequence resumes through the
    /// prefilled-admission path when the transfer lands.
    fn ship_migration(&mut self, pool: PoolKind, from: usize, m: MigratedSeq, now: f64) {
        let i = m.id as usize;
        if let Some(c) = self.commit_main[i].take() {
            self.router.complete(c.replica, c.pages, c.secs);
        }
        let mut views = std::mem::take(&mut self.scratch_views);
        self.fill_views(pool, &mut views);
        let mut costs = std::mem::take(&mut self.scratch_costs);
        costs.clear();
        costs.extend(views.iter().map(|v| self.leg_cost(v.id, 0, m.remaining_decode)));
        let mut no_hits = std::mem::take(&mut self.scratch_hits);
        no_hits.clear();
        no_hits.resize(views.len(), 0);
        let pages = self.pages_for(m.ctx + m.remaining_decode);
        let (target, secs) =
            self.router.route(self.cfg.policy, &views, m.session, pages, &costs, &no_hits);
        self.scratch_views = views;
        self.scratch_costs = costs;
        self.scratch_hits = no_hits;
        self.commit_main[i] = Some(Commit { replica: target, pages, secs });
        let bytes = self.kv_context_bytes(m.ctx);
        let landed = self.kv_transfer(from, target, bytes, now);
        self.migrations += 1;
        self.migration_bytes += bytes;
        if let Some(sink) = &self.cfg.obs {
            sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                Track::Control,
                "migrate",
                now,
                vec![
                    ("req", ArgV::U(m.id)),
                    ("from", ArgV::U(from as u64)),
                    ("to", ArgV::U(target as u64)),
                    ("bytes", ArgV::U(bytes)),
                ],
            );
        }
        let synthetic = Request {
            id: m.id,
            prompt_len: m.ctx,
            decode_len: m.remaining_decode + 1,
            arrival: self.reqs[i].arrival,
            session: m.session,
        };
        self.q.push(landed, Ev::Handoff { replica: target, req: synthetic });
    }

    /// Move a draining replica's work to peers. Waiting and restarted
    /// prompts re-route (nothing to ship); running decodes and parked
    /// handoffs ship their KV context. Defers while a step is in flight —
    /// `on_step_done` calls back.
    fn try_migrate(&mut self, victim: usize, now: f64) {
        if self.replicas[victim].stepping {
            return;
        }
        let kind = self.replicas[victim].kind;
        let parked: Vec<Request> =
            std::mem::take(&mut self.replicas[victim].pending).into_iter().collect();
        let work = {
            let rep = &mut self.replicas[victim];
            rep.batcher.drain_for_migration(&mut rep.kv)
        };
        for req in work.waiting.into_iter().chain(work.restarts) {
            self.route_queued(kind, req);
        }
        for m in work.migrations {
            self.ship_migration(kind, victim, m, now);
        }
        for req in parked {
            // Already-shipped KV that was never admitted: ship it again.
            let m = MigratedSeq {
                id: req.id,
                ctx: req.prompt_len,
                remaining_decode: req.decode_len.saturating_sub(1),
                session: req.session,
            };
            self.ship_migration(kind, victim, m, now);
        }
    }

    fn on_handoff(&mut self, replica: usize, req: Request) {
        // The transfer raced a scale-down: if the target retired (or is
        // itself drain-migrating) while the KV was in flight, re-ship to
        // a live peer (the pool always keeps ≥1 accepting).
        let reship = {
            let r = &self.replicas[replica];
            r.retired || (r.draining && self.cfg.migrate_on_drain)
        };
        if reship {
            let now = self.q.now();
            if self.cfg.migrate_on_drain {
                let kind = self.replicas[replica].kind;
                let m = MigratedSeq {
                    id: req.id,
                    ctx: req.prompt_len,
                    remaining_decode: req.decode_len.saturating_sub(1),
                    session: req.session,
                };
                self.ship_migration(kind, replica, m, now);
            } else {
                // Migration disabled: the target retired while the KV was
                // in flight. Release the stale commitment and re-ship the
                // original handoff — counted as handoff traffic, so
                // `migrations` stays 0 when the feature is off.
                if let Some(c) = self.commit_main[req.id as usize].take() {
                    self.router.complete(c.replica, c.pages, c.secs);
                }
                self.start_handoff(req.id as usize, replica, now);
            }
            return;
        }
        let rep = &mut self.replicas[replica];
        let cap = rep.cfg.max_concurrency;
        if rep.batcher.running_len() < cap {
            match rep.batcher.submit_prefilled(req, &mut rep.kv) {
                Ok(()) => {}
                Err(KvError::OutOfPages) => rep.pending.push_back(req),
                Err(e) => {
                    // Any other admission failure is an invariant breach;
                    // park the request so a release build degrades to a
                    // retry through try_admit_pending instead of aborting.
                    debug_assert!(false, "handoff admission failed: {e:?}");
                    rep.pending.push_back(req);
                }
            }
        } else {
            rep.pending.push_back(req);
        }
        self.try_start(replica);
    }

    fn on_scale_tick(&mut self) {
        if self.metrics.completed() as u64 + self.rejected >= self.reqs.len() as u64 {
            return; // fleet drained; stop the control loop
        }
        if self.autoscaler.is_some() {
            // Preemptions since the last tick signal KV pressure: the
            // controller must not drain capacity while work is thrashing.
            let total: u64 = self.replicas.iter().map(|r| r.batcher.preemptions()).sum();
            let delta = total - self.preempt_snapshot;
            self.preempt_snapshot = total;
            if let Some(a) = self.autoscaler.as_mut() {
                a.observe_preemptions(delta);
            }
            self.scale_pool(self.cfg.scalable_kind());
            if self.cfg.disaggregated_mode() {
                self.scale_pool(PoolKind::Prefill);
            }
        }
        let tick = self.autoscaler.as_ref().map(|a| a.cfg.tick).unwrap_or(0.0);
        if tick > 0.0 {
            self.q.push_in(tick, Ev::ScaleTick);
        }
    }

    /// One control decision for one pool: the decode/monolithic pool runs
    /// the combined (or TPOT-only) loop, the prefill pool its symmetric
    /// TTFT-driven twin.
    fn scale_pool(&mut self, kind: PoolKind) {
        let active = self
            .replicas
            .iter()
            .filter(|r| r.kind == kind && !r.retired && !r.draining)
            .count();
        let queued: usize = self
            .replicas
            .iter()
            .filter(|r| r.kind == kind)
            .map(|r| r.batcher.waiting_len() + r.pending.len())
            .sum();
        let (decision, delay) = {
            let Some(a) = self.autoscaler.as_mut() else { return };
            let d = match kind {
                PoolKind::Prefill => a.decide_prefill(active, queued),
                PoolKind::Decode => a.decide_decode(active, queued),
                PoolKind::Monolithic => a.decide(active, queued),
            };
            (d, a.cfg.provision_delay)
        };
        match decision {
            Decision::Up => {
                self.q.push_in(delay, Ev::ReplicaUp(kind));
            }
            Decision::Down => {
                // Drain the highest-indexed active replica of this pool:
                // no new routes; with migration, its work leaves now.
                if let Some(victim) = (0..self.replicas.len()).rev().find(|&i| {
                    let r = &self.replicas[i];
                    r.kind == kind && !r.retired && !r.draining
                }) {
                    self.drain_replica(victim);
                }
            }
            Decision::Hold => {}
        }
    }

    /// Start draining `victim`: no new routes; with migration enabled its
    /// queued and in-flight work moves to peers immediately (so it retires
    /// as soon as its current step completes), otherwise it serves its
    /// in-flight sequences to completion in place. Either way the pool
    /// shrank for the survivors: re-tune their NVRAR tables.
    fn drain_replica(&mut self, victim: usize) {
        if self.replicas[victim].retired || self.replicas[victim].draining {
            return;
        }
        let now = self.q.now();
        let kind = self.replicas[victim].kind;
        self.replicas[victim].draining = true;
        self.replicas[victim].drain_start = Some(now);
        self.drains += 1;
        if let Some(sink) = &self.cfg.obs {
            sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                Track::Control,
                "drain",
                now,
                vec![("replica", ArgV::U(victim as u64))],
            );
        }
        self.router.evict_replica_sessions(victim);
        self.retune_pool(kind);
        if self.cfg.migrate_on_drain {
            self.try_migrate(victim, now);
        }
        self.maybe_retire(victim, now);
    }

    fn on_drain_at(&mut self, r: usize) {
        if r >= self.replicas.len() {
            return;
        }
        let kind = self.replicas[r].kind;
        let peers = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(i, p)| *i != r && p.kind == kind && !p.retired && !p.draining)
            .count();
        if peers == 0 {
            return; // never drain the last accepting replica of a pool
        }
        self.drain_replica(r);
    }

    fn on_replica_up(&mut self, kind: PoolKind) {
        if let Some(a) = self.autoscaler.as_mut() {
            match kind {
                PoolKind::Prefill => a.prefill_online(),
                _ => a.replica_online(),
            }
        }
        if self.metrics.completed() as u64 + self.rejected >= self.reqs.len() as u64 {
            return; // capacity arrived after the rush ended
        }
        let template = match kind {
            PoolKind::Prefill => self.cfg.prefill[0].clone(),
            _ => self.cfg.replicas[0].clone(),
        };
        self.push_replica(kind, template);
        self.retune_pool(kind);
    }

    // -- mechanics -----------------------------------------------------

    fn push_replica(&mut self, kind: PoolKind, mut cfg: ServeConfig) {
        if let Some(fab) = &self.fabric {
            // One link scope per replica (its index, stable for life);
            // collective bytes book here, transfers book inter links of
            // the source's and target's scopes.
            let scope = self.replicas.len();
            fab.lock()
                .unwrap_or_else(|e| e.into_inner())
                .add_scope(scope, cfg.topo.nodes, cfg.topo.intra.beta, cfg.topo.inter.beta);
            cfg.net = Some(fab.clone());
            cfg.net_scope = scope;
        }
        // The replica's own config carries the sink so its fabric bookings
        // (collective phase spans) record under its link scope.
        cfg.obs = self.cfg.obs.clone();
        self.bd.push(Breakdown::default());
        if let Some(sink) = &self.cfg.obs {
            sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                Track::Control,
                "replica_up",
                self.q.now(),
                vec![
                    ("replica", ArgV::U(self.replicas.len() as u64)),
                    ("pool", ArgV::S(format!("{kind:?}"))),
                ],
            );
        }
        let pred_step = predict_step(&cfg);
        let pred_chunk = predict_chunk(&cfg);
        let base_comm = cfg.comm;
        self.replicas.push(Replica {
            kind,
            kv: PagedKv::new(cfg.kv_pages, cfg.kv_page_tokens),
            batcher: cfg.build_batcher(),
            cfg,
            base_comm,
            pred_step,
            pred_chunk,
            stepping: false,
            current: None,
            draining: false,
            drain_start: None,
            retired: false,
            pending: VecDeque::new(),
            ingress: Server::new(),
        });
        self.router.grow(self.replicas.len());
        let live = self.replicas.iter().filter(|r| !r.retired).count();
        self.peak_replicas = self.peak_replicas.max(live);
        let live_prefill = self
            .replicas
            .iter()
            .filter(|r| r.kind == PoolKind::Prefill && !r.retired)
            .count();
        self.peak_prefill = self.peak_prefill.max(live_prefill);
    }

    /// Fleet-level NVRAR re-tune hook (ROADMAP): when a pool resizes, each
    /// surviving NVRAR replica's share of the load — and so its decode
    /// batch, and so its all-reduce message size — changes regime. Rebuild
    /// the tuned B_s × C_s table against the replica's TP-group topology
    /// and re-apply the entry for the new regime's message size; the
    /// routing probes refresh with it.
    fn retune_pool(&mut self, kind: PoolKind) {
        let members: Vec<usize> = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| {
                r.kind == kind
                    && !r.retired
                    && !r.draining
                    && r.cfg.cost.ar() == AllReduceImpl::Nvrar
            })
            .map(|(i, _)| i)
            .collect();
        if members.is_empty() {
            return;
        }
        let active = members.len();
        let load: usize = self
            .replicas
            .iter()
            .filter(|r| r.kind == kind && !r.retired)
            .map(|r| {
                r.batcher.running_len()
                    + r.batcher.prefilling_len()
                    + r.batcher.waiting_len()
                    + r.pending.len()
            })
            .sum();
        for i in members {
            let rep = &mut self.replicas[i];
            let rows = (load / active).clamp(1, rep.cfg.max_concurrency);
            let msg = (rows * rep.cfg.model.d_model * rep.cfg.model.dtype_bytes) as u64;
            let tp_topo = rep.cfg.cost.spec().tp_topology(&rep.cfg.topo);
            let table = TunedTable::build(&tp_topo, &rep.base_comm);
            rep.cfg.comm = table.apply(&rep.base_comm, msg);
            rep.pred_step = predict_step(&rep.cfg);
            rep.pred_chunk = predict_chunk(&rep.cfg);
            self.retunes += 1;
            if let Some(sink) = &self.cfg.obs {
                sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                    Track::Control,
                    "retune",
                    self.q.now(),
                    vec![("replica", ArgV::U(i as u64)), ("msg", ArgV::U(msg))],
                );
            }
        }
    }

    /// Admit pending handoffs, then launch the next engine step if idle.
    fn try_start(&mut self, r: usize) {
        self.try_admit_pending(r);
        let now = self.q.now();
        if let Some(fab) = &self.fabric {
            // Event time is monotone and every booking lands at or after
            // it, so advancing the shared fabric's watermark here lets
            // `book` prune expired intervals — without this a transfer-free
            // contention run grows every link's active list without bound
            // and each step's booking sweep degrades to O(run length).
            fab.lock().unwrap_or_else(|e| e.into_inner()).advance(now);
        }
        let rep = &mut self.replicas[r];
        if rep.stepping {
            return;
        }
        let step = rep.batcher.next_step(&mut rep.kv);
        // The fleet pre-rejects anything whose lifetime footprint cannot
        // fit, so replica-level admission must never reject.
        assert!(
            rep.batcher.take_rejected().is_empty(),
            "feasibility pre-check missed an infeasible request"
        );
        if step.is_empty() {
            rep.batcher.recycle(step);
            return;
        }
        // Each replica prices the step with its own cost model; under
        // contention the booking inflates it when its links are busy.
        let timing = rep.cfg.step_timing_at(&step, now);
        let dur = timing.dur;
        self.comm_exposed += timing.comm_exposed;
        self.comm_hidden += timing.comm_hidden;
        self.booked_bytes += timing.booked_bytes;
        let rep = &mut self.replicas[r];
        if let Some(sink) = &self.cfg.obs {
            // Same contract as the single-replica loop: the span carries
            // the buckets the analytic accumulator sums (fabric queueing
            // delay folded into Comm), so the event fold reconciles.
            let delay = (dur - timing.base).max(0.0);
            let mut b = rep.cfg.step_breakdown(&step);
            b.comm += delay;
            let mut rec = sink.lock().unwrap_or_else(|e| e.into_inner());
            for c in &step.prefills {
                rec.instant(
                    Track::Replica(r),
                    "chunk",
                    now,
                    vec![
                        ("req", ArgV::U(c.id)),
                        ("tokens", ArgV::U(c.tokens as u64)),
                        ("ctx", ArgV::U(c.ctx as u64)),
                        ("last", ArgV::U(c.last as u64)),
                    ],
                );
            }
            rec.span(
                Track::Replica(r),
                "step",
                now,
                dur,
                vec![
                    ("matmul", ArgV::F(b.matmul)),
                    ("other", ArgV::F(b.other_comp)),
                    ("comm", ArgV::F(b.comm)),
                    ("idle", ArgV::F(b.idle)),
                    ("rows", ArgV::U(step.token_rows() as u64)),
                    ("seqs", ArgV::U(step.seqs() as u64)),
                    ("hidden", ArgV::F(timing.comm_hidden)),
                    ("booked", ArgV::F(timing.booked_bytes)),
                ],
            );
            drop(rec);
            self.bd[r].add(&b);
        }
        rep.current = Some(step);
        rep.stepping = true;
        self.q.push_in(dur, Ev::StepDone(r));
    }

    fn try_admit_pending(&mut self, r: usize) {
        let rep = &mut self.replicas[r];
        let cap = rep.cfg.max_concurrency;
        while let Some(&req) = rep.pending.front() {
            if rep.batcher.running_len() >= cap
                || rep.batcher.submit_prefilled(req, &mut rep.kv).is_err()
            {
                break;
            }
            rep.pending.pop_front();
        }
    }

    fn maybe_retire(&mut self, r: usize, now: f64) {
        let rep = &mut self.replicas[r];
        if rep.draining
            && !rep.retired
            && !rep.stepping
            && rep.batcher.idle()
            && rep.pending.is_empty()
        {
            rep.retired = true;
            if let Some(t0) = rep.drain_start.take() {
                self.drain_secs += now - t0;
            }
            if let Some(sink) = &self.cfg.obs {
                sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                    Track::Control,
                    "retire",
                    now,
                    vec![("replica", ArgV::U(r as u64))],
                );
            }
        }
    }

    fn complete_request(&mut self, i: usize, now: f64) {
        assert!(!self.done[i], "request {i} completed twice");
        self.done[i] = true;
        let r = &self.reqs[i];
        debug_assert!(
            self.first_token[i].is_some(),
            "request {i} finished without a first token"
        );
        let ft = self.first_token[i].unwrap_or(r.arrival);
        let ttft = ft - r.arrival;
        // Credit only tokens that were actually produced: a KV-exhaustion
        // truncation must not inflate throughput or deflate TPOT.
        let toks = self.produced[i].max(1);
        let tpot = if toks > 1 { (now - ft) / (toks - 1) as f64 } else { 0.0 };
        if let Some(sink) = &self.cfg.obs {
            sink.lock().unwrap_or_else(|e| e.into_inner()).instant(
                Track::Control,
                "finish",
                now,
                vec![("req", ArgV::U(i as u64)), ("out", ArgV::U(toks as u64))],
            );
        }
        self.metrics.record(ttft, tpot, toks as u64, &self.cfg.slo);
        if let Some(a) = self.autoscaler.as_mut() {
            a.observe(ttft, tpot);
        }
        self.last_done = now;
    }

    /// Rebuild the candidate views of `kind`'s pool into `out` (a reused
    /// scratch buffer — same contents the old allocating path produced).
    fn fill_views(&self, kind: PoolKind, out: &mut Vec<ReplicaView>) {
        out.clear();
        out.extend(
            self.replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| r.kind == kind && !r.retired)
                .map(|(id, r)| ReplicaView {
                    id,
                    accepting: !r.draining,
                    total_pages: r.cfg.kv_pages,
                    pred_step: r.pred_step,
                }),
        );
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.max(1).div_ceil(self.page_tokens)
    }

    /// KV bytes that migrate when `tokens` of context move between
    /// replicas (prefill→decode handoff, or drain migration): the full
    /// cache across all layers (the TP shards move in parallel over the
    /// per-node NICs; the aggregate bytes are what the fabric carries).
    fn kv_context_bytes(&self, tokens: usize) -> u64 {
        let model = &self.cfg.replicas[0].model;
        (tokens * model.n_layers) as u64 * model.kv_bytes_per_token_layer()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::AllReduceImpl;
    use crate::parallel::ParallelSpec;
    use crate::serving::fig9_config;
    use crate::trace::{LenDist, RateShape, SessionSpec, TraceSpec};
    use crate::util::prop::{check, Gen};

    fn small_spec(n: usize, rate: f64, seed: u64) -> TraceSpec {
        TraceSpec {
            num_prompts: n,
            rate,
            burstiness: 2.0,
            shape: RateShape::Flat,
            input: LenDist { median: 96.0, sigma: 0.8, min: 8, max: 512 },
            output: LenDist { median: 48.0, sigma: 0.6, min: 1, max: 256 },
            seed,
        }
    }

    fn base_cfg(concurrency: usize) -> ServeConfig {
        let mut cfg = fig9_config(
            ParallelSpec::tp(16),
            AllReduceImpl::NcclAuto,
            concurrency,
            "perlmutter",
            16,
        );
        cfg.kv_pages = 4096; // small enough that KV pressure is reachable
        cfg
    }

    fn tp8_cfg(concurrency: usize) -> ServeConfig {
        let mut cfg = fig9_config(
            ParallelSpec::tp(8),
            AllReduceImpl::NcclAuto,
            concurrency,
            "perlmutter",
            8,
        );
        cfg.kv_pages = 4096;
        cfg
    }

    #[test]
    fn fleet_conserves_requests_all_policies_and_modes() {
        let reqs = small_spec(60, 4.0, 11).generate();
        for policy in RoutePolicy::all() {
            for prefill in [0usize, 1] {
                let mut cfg = FleetConfig::new(base_cfg(32), 3).with_policy(policy);
                if prefill > 0 {
                    cfg = cfg.disaggregated(prefill);
                }
                // run_fleet asserts conservation + KV cleanliness itself.
                let rep = run_fleet(&cfg, &reqs);
                assert_eq!(rep.completed, 60, "{policy:?} prefill={prefill}");
                assert!(rep.throughput > 0.0 && rep.makespan > 0.0);
                if prefill > 0 {
                    let multi_tok =
                        reqs.iter().filter(|r| r.decode_len > 1).count() as u64;
                    assert_eq!(rep.handoffs, multi_tok);
                    assert!(rep.handoff_gb > 0.0);
                }
            }
        }
    }

    #[test]
    fn fleet_deterministic_for_fixed_seed() {
        let reqs = small_spec(50, 5.0, 23).generate();
        let cfg = FleetConfig::new(base_cfg(32), 4).with_policy(RoutePolicy::KvPressure);
        let a = run_fleet(&cfg, &reqs);
        let b = run_fleet(&cfg, &reqs);
        assert_eq!(a, b, "fleet must be bit-deterministic");
    }

    #[test]
    fn kv_pressure_routing_respects_capacity() {
        // Tight KV: each replica fits only a handful of requests' worth of
        // pages. Worst-case commitment per request is ceil(900/16) = 57
        // pages, so 16 outstanding requests (≤ 912 pages) always fit the
        // 4×256-page fleet: the KV-aware router must keep every per-replica
        // commitment within capacity without ever taking the relief path.
        let mut spec = small_spec(16, 20.0, 31);
        spec.input = LenDist { median: 400.0, sigma: 0.3, min: 64, max: 600 };
        spec.output = LenDist { median: 200.0, sigma: 0.3, min: 16, max: 300 };
        let reqs = spec.generate();
        let mut base = base_cfg(16);
        base.kv_pages = 256; // 4096 tokens per replica
        let cfg = FleetConfig::new(base, 4).with_policy(RoutePolicy::KvPressure);
        let rep = run_fleet(&cfg, &reqs);
        assert!(
            rep.max_committed_pages <= 256,
            "router over-committed: {} pages",
            rep.max_committed_pages
        );
        assert_eq!(rep.over_capacity_routes, 0);
        assert_eq!(rep.completed, 16);
    }

    #[test]
    fn heterogeneous_fleet_prefers_faster_replicas() {
        // 1×TP16 + 1×TP8: cost-aware least-tokens must send the TP16
        // replica (lower predicted step time) more requests.
        let reqs = small_spec(60, 8.0, 17).generate();
        let cfg = FleetConfig::heterogeneous(vec![base_cfg(32), tp8_cfg(32)])
            .with_policy(RoutePolicy::LeastOutstanding);
        let rep = run_fleet(&cfg, &reqs);
        assert_eq!(rep.completed, 60);
        assert_eq!(rep.routed.len(), 2);
        assert!(
            rep.routed[0] > rep.routed[1],
            "TP16 should absorb more load: {:?}",
            rep.routed
        );
    }

    #[test]
    fn property_fleet_conservation_random_configs() {
        check("fleet conserves requests", 12, |g: &mut Gen| {
            let n = g.usize(5, 40);
            let reqs = small_spec(n, g.f64(1.0, 12.0), g.u64(1, 1 << 20)).generate();
            let policy = *g.pick(&RoutePolicy::all());
            let replicas = g.usize(1, 5);
            let prefill = if g.bool() { g.usize(1, 2) } else { 0 };
            let conc = g.pow2(2, 6);
            // Mix TP16 and TP8 replicas at random: the invariants must
            // hold for heterogeneous fleets too.
            let pool: Vec<ServeConfig> = (0..replicas)
                .map(|_| if g.bool() { base_cfg(conc) } else { tp8_cfg(conc) })
                .collect();
            let mut cfg = FleetConfig::heterogeneous(pool).with_policy(policy);
            if prefill > 0 {
                cfg = cfg.disaggregated(prefill);
            }
            // Random scripted drains stress the migration path; the guard
            // keeps the last replica of a pool serving.
            if g.bool() {
                cfg = cfg.with_drain_at(g.f64(0.5, 10.0), g.usize(0, replicas - 1));
            }
            cfg.migrate_on_drain = g.bool();
            // Conservation/KV invariants must also hold with the shared
            // fabric slowing steps and transfers.
            cfg.contention = g.bool();
            let rep = run_fleet(&cfg, &reqs);
            assert_eq!(rep.completed, n);
        });
    }

    #[test]
    fn property_fleet_conserves_session_traces() {
        check("fleet conserves session traces", 8, |g: &mut Gen| {
            let mut sspec = SessionSpec::standard();
            sspec.sessions = g.usize(3, 12);
            sspec.turns = g.usize(2, 5);
            sspec.think = g.f64(1.0, 20.0);
            sspec.seed = g.u64(1, 1 << 20);
            sspec.first_prompt = LenDist { median: 300.0, sigma: 0.5, min: 32, max: 1024 };
            let reqs = sspec.generate();
            let n = reqs.len();
            let policy = *g.pick(&RoutePolicy::all());
            let cfg = FleetConfig::new(base_cfg(32), g.usize(2, 4)).with_policy(policy);
            let rep = run_fleet(&cfg, &reqs);
            assert_eq!(rep.completed, n, "{policy:?}");
            assert!(rep.cache_hit_rate >= 0.0 && rep.cache_hit_rate <= 1.0);
        });
    }

    #[test]
    fn disaggregation_cuts_ttft_on_decode_heavy_load() {
        // Decode-heavy requests occupy monolithic replicas for their whole
        // lifetime, so waiting prompts queue behind slots held by long
        // decodes; a dedicated prefill pool answers first tokens while the
        // decode pool streams. Same total replica count (4) both ways.
        // ~5 req/s × ~7 s/request ≈ 35 concurrent > 4×8 slots: saturated.
        let mut spec = small_spec(60, 5.0, 7);
        spec.output = LenDist { median: 600.0, sigma: 0.2, min: 256, max: 1024 };
        let reqs = spec.generate();
        let mono = run_fleet(&FleetConfig::new(base_cfg(8), 4), &reqs);
        let disagg = run_fleet(&FleetConfig::new(base_cfg(8), 3).disaggregated(1), &reqs);
        assert!(
            disagg.ttft_p99 < mono.ttft_p99,
            "disaggregated TTFT p99 {} should beat monolithic {}",
            disagg.ttft_p99,
            mono.ttft_p99
        );
    }

    #[test]
    fn autoscaler_reacts_to_ramp() {
        let mut spec = small_spec(120, 3.0, 5);
        spec.shape = RateShape::Ramp { from: 0.3, to: 6.0 };
        let reqs = spec.generate();
        let slo = SloTargets { ttft: 0.5, tpot: 0.2 };
        let auto = AutoscaleConfig {
            tick: 2.0,
            provision_delay: 4.0,
            min_replicas: 1,
            max_replicas: 8,
            window: 32,
            down_frac: 0.25,
        };
        let cfg = FleetConfig::new(base_cfg(8), 1).with_slo(slo).with_autoscale(auto);
        let rep = run_fleet(&cfg, &reqs);
        assert!(rep.scale_ups > 0, "ramp load must trigger scale-up");
        assert!(rep.peak_replicas > 1);
        assert_eq!(rep.completed, 120);
    }

    #[test]
    fn prefill_bound_ramp_scales_the_prefill_pool() {
        // Long prompts, near-single-token outputs: the prefill pool is the
        // bottleneck, so TTFT breaches must grow *it*, not the decode pool.
        let mut spec = small_spec(80, 4.0, 19);
        spec.shape = RateShape::Ramp { from: 0.3, to: 5.0 };
        spec.input = LenDist { median: 900.0, sigma: 0.3, min: 256, max: 2048 };
        spec.output = LenDist { median: 2.0, sigma: 0.4, min: 2, max: 6 };
        let reqs = spec.generate();
        let slo = SloTargets { ttft: 0.4, tpot: 5.0 }; // TPOT never breaches
        let auto = AutoscaleConfig {
            tick: 2.0,
            provision_delay: 4.0,
            min_replicas: 1,
            max_replicas: 6,
            window: 24,
            down_frac: 0.25,
        };
        let cfg = FleetConfig::new(base_cfg(8), 2)
            .disaggregated(1)
            .with_slo(slo)
            .with_autoscale(auto);
        let rep = run_fleet(&cfg, &reqs);
        assert_eq!(rep.completed, 80);
        assert!(rep.prefill_scale_ups > 0, "prefill-bound ramp must grow the prefill pool");
        assert!(rep.peak_prefill > 1, "prefill pool must actually grow");
        assert_eq!(rep.scale_ups, 0, "comfortable TPOT must not grow the decode pool");
    }

    #[test]
    fn session_affinity_concentrates_cache_hits() {
        // Multi-turn sessions across a 4-replica fleet: affinity routing
        // lands turns where their prefix cache lives, so its fleet-wide
        // hit rate beats content-blind least-outstanding's.
        let mut sspec = SessionSpec::standard();
        sspec.sessions = 40;
        sspec.turns = 4;
        sspec.rate = 4.0; // enough overlap that blind routing scatters turns
        let reqs = sspec.generate();
        let n = reqs.len();
        let lo = run_fleet(
            &FleetConfig::new(base_cfg(32), 4).with_policy(RoutePolicy::LeastOutstanding),
            &reqs,
        );
        let sa = run_fleet(
            &FleetConfig::new(base_cfg(32), 4).with_policy(RoutePolicy::SessionAffinity),
            &reqs,
        );
        assert_eq!((lo.completed, sa.completed), (n, n));
        assert!(sa.cache_hit_rate > 0.0, "affinity must produce hits");
        assert!(
            sa.cache_hit_rate > lo.cache_hit_rate,
            "affinity hit rate {} must beat least-outstanding's {}",
            sa.cache_hit_rate,
            lo.cache_hit_rate
        );
        assert!(sa.cached_tokens > 0);
    }

    #[test]
    fn scripted_drain_migrates_and_retires_early() {
        // Long decodes in flight when replica 2 drains: with migration the
        // replica retires after its current step; without, it must stream
        // every remaining token first.
        let mut spec = small_spec(40, 6.0, 41);
        spec.output = LenDist { median: 400.0, sigma: 0.2, min: 128, max: 800 };
        let reqs = spec.generate();
        let base = FleetConfig::new(base_cfg(16), 3).with_drain_at(5.0, 2);
        let with = run_fleet(&base.clone().with_migration(true), &reqs);
        let without = run_fleet(&base.with_migration(false), &reqs);
        assert_eq!((with.completed, without.completed), (40, 40));
        assert_eq!((with.drains, without.drains), (1, 1));
        assert!(with.migrations > 0, "in-flight decodes must migrate");
        assert!(with.migration_gb > 0.0);
        assert_eq!(without.migrations, 0);
        assert!(
            with.drain_secs < without.drain_secs,
            "migration must retire the replica earlier: {} vs {}",
            with.drain_secs,
            without.drain_secs
        );
    }

    #[test]
    fn nvrar_pool_resize_retunes_tables() {
        // An autoscaling NVRAR fleet: every pool resize re-tunes the
        // surviving replicas' B_s × C_s tables.
        let mut spec = small_spec(100, 3.0, 29);
        spec.shape = RateShape::Ramp { from: 0.3, to: 5.0 };
        let reqs = spec.generate();
        let mut base = fig9_config(
            ParallelSpec::tp(16),
            AllReduceImpl::Nvrar,
            8,
            "perlmutter",
            16,
        );
        base.kv_pages = 4096;
        let auto = AutoscaleConfig {
            tick: 2.0,
            provision_delay: 4.0,
            min_replicas: 1,
            max_replicas: 6,
            window: 32,
            down_frac: 0.25,
        };
        let cfg = FleetConfig::new(base, 1)
            .with_slo(SloTargets { ttft: 0.5, tpot: 0.2 })
            .with_autoscale(auto);
        let rep = run_fleet(&cfg, &reqs);
        assert_eq!(rep.completed, 100);
        assert!(rep.scale_ups > 0);
        assert!(rep.retunes > 0, "pool resizes must re-tune NVRAR tables");
        // An NCCL fleet on the same trace never re-tunes.
        let nccl = FleetConfig::new(base_cfg(8), 1)
            .with_slo(SloTargets { ttft: 0.5, tpot: 0.2 })
            .with_autoscale(auto);
        let rep = run_fleet(&nccl, &reqs);
        assert_eq!(rep.retunes, 0);
    }
}
