//! Fleet-level serving metrics: per-request TTFT/TPOT percentiles and
//! SLO-conditioned goodput, built on [`crate::util::stats::Summary`].
//!
//! Definitions follow the serving literature the fleet layer targets:
//!
//! - **TTFT** (time to first token): arrival → completion of the request's
//!   prefill (wherever that prefill ran).
//! - **TPOT** (time per output token): (completion − first token) /
//!   (output tokens − 1); zero for single-token outputs.
//! - **SLO attainment**: fraction of completed requests meeting *both*
//!   targets; **goodput**: output tokens of SLO-meeting requests per
//!   second of makespan — the "useful" half of raw throughput.

use crate::metrics::Breakdown;
use crate::simnet::CongestionStats;
use crate::util::stats::Summary;

/// Latency targets a request must meet to count toward goodput.
#[derive(Clone, Copy, Debug)]
pub struct SloTargets {
    /// Max acceptable time-to-first-token (s).
    pub ttft: f64,
    /// Max acceptable time-per-output-token (s).
    pub tpot: f64,
}

impl Default for SloTargets {
    fn default() -> Self {
        // Interactive-serving ballpark: sub-5s first token, ≥5 tok/s decode.
        SloTargets { ttft: 5.0, tpot: 0.2 }
    }
}

/// Streaming per-request accumulator the fleet simulation feeds.
#[derive(Clone, Debug, Default)]
pub struct FleetMetrics {
    ttft: Summary,
    tpot: Summary,
    completed: usize,
    good_requests: usize,
    good_tokens: u64,
    output_tokens: u64,
}

impl FleetMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed request.
    pub fn record(&mut self, ttft: f64, tpot: f64, out_tokens: u64, slo: &SloTargets) {
        self.ttft.add(ttft);
        self.tpot.add(tpot);
        self.completed += 1;
        self.output_tokens += out_tokens;
        if ttft <= slo.ttft && tpot <= slo.tpot {
            self.good_requests += 1;
            self.good_tokens += out_tokens;
        }
    }

    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Freeze into a report. `makespan` is the time of the last completion.
    pub fn report(&self, makespan: f64) -> FleetReport {
        let pct = |s: &Summary, q: f64| if s.n() == 0 { 0.0 } else { s.percentile(q) };
        let span = makespan.max(1e-9);
        FleetReport {
            completed: self.completed,
            output_tokens: self.output_tokens,
            makespan,
            throughput: self.output_tokens as f64 / span,
            ttft_p50: pct(&self.ttft, 50.0),
            ttft_p95: pct(&self.ttft, 95.0),
            ttft_p99: pct(&self.ttft, 99.0),
            ttft_mean: if self.ttft.n() == 0 { 0.0 } else { self.ttft.mean() },
            tpot_p50: pct(&self.tpot, 50.0),
            tpot_p95: pct(&self.tpot, 95.0),
            tpot_p99: pct(&self.tpot, 99.0),
            slo_attainment: if self.completed == 0 {
                0.0
            } else {
                self.good_requests as f64 / self.completed as f64
            },
            goodput: self.good_tokens as f64 / span,
            scale_ups: 0,
            scale_downs: 0,
            prefill_scale_ups: 0,
            prefill_scale_downs: 0,
            peak_replicas: 0,
            peak_prefill: 0,
            handoffs: 0,
            handoff_gb: 0.0,
            max_committed_pages: 0,
            over_capacity_routes: 0,
            routed: Vec::new(),
            preemptions: 0,
            rejected: 0,
            cache_hit_rate: 0.0,
            cached_tokens: 0,
            migrations: 0,
            migration_gb: 0.0,
            drains: 0,
            drain_secs: 0.0,
            retunes: 0,
            net_util_intra: 0.0,
            net_util_inter: 0.0,
            congestion: CongestionStats::default(),
            breakdowns: Vec::new(),
            comm_exposed: 0.0,
            comm_hidden: 0.0,
            booked_gb: 0.0,
        }
    }
}

/// Outcome of one fleet run — everything the tables, benches and tests
/// consume. Scale/handoff/router fields are filled in by the simulation
/// after [`FleetMetrics::report`].
#[derive(Clone, Debug, PartialEq)]
pub struct FleetReport {
    pub completed: usize,
    pub output_tokens: u64,
    /// Time of the last request completion (s).
    pub makespan: f64,
    /// Raw output tokens/s over the makespan.
    pub throughput: f64,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
    pub ttft_p99: f64,
    pub ttft_mean: f64,
    pub tpot_p50: f64,
    pub tpot_p95: f64,
    pub tpot_p99: f64,
    /// Fraction of requests meeting both SLO targets.
    pub slo_attainment: f64,
    /// Output tokens/s counting only SLO-meeting requests.
    pub goodput: f64,
    pub scale_ups: usize,
    pub scale_downs: usize,
    /// Prefill-pool scaling actions (disaggregated fleets; the decode
    /// pool's actions are `scale_ups`/`scale_downs`).
    pub prefill_scale_ups: usize,
    pub prefill_scale_downs: usize,
    pub peak_replicas: usize,
    /// Peak live prefill replicas (disaggregated mode).
    pub peak_prefill: usize,
    /// Prefill→decode KV transfers performed (disaggregated mode).
    pub handoffs: u64,
    /// Total KV bytes moved by handoffs, in GB.
    pub handoff_gb: f64,
    /// Max pages the router ever had committed against one replica.
    pub max_committed_pages: usize,
    /// Times the router had to place a request past every replica's
    /// KV-capacity bound (pressure-relief path; 0 under KV-aware routing
    /// with adequate capacity).
    pub over_capacity_routes: u64,
    /// Router *placements* per replica index (heterogeneous-fleet
    /// observability; includes retired replicas). A monolithic request is
    /// one placement; a disaggregated request counts its prefill placement
    /// and its decode handoff separately, so the sum can exceed
    /// `completed`.
    pub routed: Vec<u64>,
    /// Sequences preempted (KV exhaustion) and re-queued across all
    /// replicas. Preemption re-produces work; it never drops tokens.
    pub preemptions: u64,
    /// Requests rejected up front because their lifetime KV footprint can
    /// never fit a replica (`completed + rejected == trace length`).
    pub rejected: u64,
    /// Fleet-wide fraction of admitted prompt tokens served from the
    /// shared-prefix KV caches (0 on workloads without sessions).
    pub cache_hit_rate: f64,
    /// Fleet-wide prompt tokens the prefix caches saved.
    pub cached_tokens: u64,
    /// In-flight sequences whose KV migrated off a draining replica.
    pub migrations: u64,
    /// Total KV bytes moved by drain migrations, in GB.
    pub migration_gb: f64,
    /// Replicas that entered draining (autoscaler or scripted).
    pub drains: u64,
    /// Total seconds from drain decision to retirement, summed over
    /// drains that completed (migration shrinks this).
    pub drain_secs: f64,
    /// NVRAR tuned-table rebuilds triggered by pool resizes (the
    /// fleet-level re-tune hook; 0 for non-NVRAR replicas).
    pub retunes: u64,
    /// Mean intra-node link utilization of the shared fabric over the
    /// makespan (0 with contention disabled — `FleetConfig::contention`).
    pub net_util_intra: f64,
    /// Mean inter-node link (NIC) utilization of the shared fabric.
    pub net_util_inter: f64,
    /// Congestion-delay accounting across every fabric booking —
    /// collective flows, KV handoffs, drain migrations (all-zero with
    /// contention disabled).
    pub congestion: CongestionStats,
    /// Per-replica analytic Matmul/Other/Comm/Idle breakdowns, each
    /// idle-filled to the makespan (empty unless tracing was enabled via
    /// `FleetConfig::obs` — so tracing-off reports compare bit-for-bit).
    pub breakdowns: Vec<Breakdown>,
    /// Exposed collective seconds summed over every step of every replica
    /// (closed-form exposed comm plus unabsorbed fabric delay). Only
    /// accumulated when overlap or tracing is on — 0.0 on the fast path,
    /// like `breakdowns`.
    pub comm_exposed: f64,
    /// Hidden collective seconds summed over every step of every replica
    /// (priced behind compute; their bytes still occupied the fabric).
    /// 0.0 on the fast path.
    pub comm_hidden: f64,
    /// Collective gigabytes booked on the shared fabric — the *full*
    /// volume, hidden bytes included (0.0 with contention disabled).
    pub booked_gb: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn goodput_counts_only_slo_meeting_requests() {
        let slo = SloTargets { ttft: 1.0, tpot: 0.1 };
        let mut m = FleetMetrics::new();
        m.record(0.5, 0.05, 100, &slo); // good
        m.record(2.0, 0.05, 100, &slo); // ttft violation
        m.record(0.5, 0.50, 100, &slo); // tpot violation
        let r = m.report(10.0);
        assert_eq!(r.completed, 3);
        assert_eq!(r.output_tokens, 300);
        assert!((r.throughput - 30.0).abs() < 1e-9);
        assert!((r.goodput - 10.0).abs() < 1e-9);
        assert!((r.slo_attainment - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_report_is_all_zero() {
        let r = FleetMetrics::new().report(0.0);
        assert_eq!(r.completed, 0);
        assert_eq!(r.ttft_p99, 0.0);
        assert_eq!(r.slo_attainment, 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let slo = SloTargets::default();
        let mut m = FleetMetrics::new();
        for i in 1..=100 {
            m.record(i as f64 * 0.01, i as f64 * 0.001, 10, &slo);
        }
        let r = m.report(1.0);
        assert!(r.ttft_p50 <= r.ttft_p95 && r.ttft_p95 <= r.ttft_p99);
        assert!(r.tpot_p50 <= r.tpot_p95 && r.tpot_p95 <= r.tpot_p99);
    }
}
