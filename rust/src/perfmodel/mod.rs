//! Analytic GPU performance model: tile-quantized GEMM roofline + memory-
//! bandwidth-bound ops.
//!
//! This is the compute half of the substitution for the paper's A100/GH200
//! testbed. The key mechanism is **tile quantization** (§3.4 / Table 4):
//! GEMM kernels tile M and N to fixed CTA tiles, so shrinking M below the
//! M-tile (decode GEMMs: M = batch) does not shrink the work — which is
//! exactly why pipeline-parallel micro-batching fails to cut decode matmul
//! time while TP's K-split succeeds.

use crate::models::ModelConfig;

/// GPU compute/memory capability.
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Dense bf16 tensor-core throughput, FLOP/s.
    pub flops: f64,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Device memory, bytes.
    pub mem_bytes: u64,
    /// GEMM CTA tile sizes the kernels quantize to.
    pub tile_m: usize,
    pub tile_n: usize,
    /// Minimum kernel time (launch + epilogue floor).
    pub kernel_floor: f64,
    /// Achievable fraction of peak FLOPs for large GEMMs.
    pub mxu_efficiency: f64,
}

impl GpuSpec {
    /// NVIDIA A100-80GB (Perlmutter).
    pub fn a100() -> Self {
        GpuSpec {
            name: "A100-80GB",
            flops: 312.0e12,
            mem_bw: 2.0e12,
            mem_bytes: 80 * (1 << 30),
            tile_m: 128,
            tile_n: 128,
            kernel_floor: 4.0e-6,
            mxu_efficiency: 0.85,
        }
    }

    /// NVIDIA A100-40GB (Perlmutter 40 GB partition, Fig 4 runs).
    pub fn a100_40g() -> Self {
        GpuSpec { mem_bytes: 40 * (1 << 30), name: "A100-40GB", ..Self::a100() }
    }

    /// GH200 (Vista).
    pub fn gh200() -> Self {
        GpuSpec {
            name: "GH200-96GB",
            flops: 990.0e12,
            mem_bw: 4.0e12,
            mem_bytes: 96 * (1 << 30),
            tile_m: 128,
            tile_n: 128,
            kernel_floor: 3.0e-6,
            mxu_efficiency: 0.85,
        }
    }

    /// GPU spec for a machine name or bundle file path, resolved through
    /// [`crate::calib::registry`] so it always pairs with the same
    /// bundle's comm constants. Unknown names are an error, not a silent
    /// A100 fallback.
    pub fn for_machine(name: &str) -> anyhow::Result<Self> {
        Ok(crate::calib::registry::resolve(name)?.gpu)
    }
}

/// Time for a bf16 GEMM of logical shape (M, N, K) with `dtype` bytes/elem.
///
/// compute: 2·⌈M/tm⌉tm·⌈N/tn⌉tn·K / (peak·eff) — the tile-quantized FLOPs;
/// memory: (MK + KN + MN)·dtype / bw; result: max(compute, memory, floor).
pub fn gemm_time(g: &GpuSpec, m: usize, n: usize, k: usize, dtype: usize) -> f64 {
    if m == 0 || n == 0 || k == 0 {
        return 0.0;
    }
    let mq = m.div_ceil(g.tile_m) * g.tile_m;
    let nq = n.div_ceil(g.tile_n) * g.tile_n;
    let flops = 2.0 * mq as f64 * nq as f64 * k as f64;
    let compute = flops / (g.flops * g.mxu_efficiency);
    let bytes = ((m * k + k * n + m * n) * dtype) as f64;
    let memory = bytes / g.mem_bw;
    compute.max(memory).max(g.kernel_floor)
}

/// Time for a memory-bandwidth-bound elementwise/reduction op over `bytes`.
pub fn membound_time(g: &GpuSpec, bytes: u64) -> f64 {
    (bytes as f64 / g.mem_bw).max(g.kernel_floor)
}

/// Per-layer, per-GPU times for one transformer layer under TP degree `tp`.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerTimes {
    /// Time in matmul kernels (the Fig 3 "Matmul" bucket).
    pub matmul: f64,
    /// Non-GEMM compute: attention softmax/AV, norms, rope, KV IO
    /// (Fig 3 "Other Comp.").
    pub other: f64,
}

impl LayerTimes {
    pub fn total(&self) -> f64 {
        self.matmul + self.other
    }
}

/// One transformer layer (attention + MLP) on a single GPU of a TP group.
/// `m_tokens` = rows fed to the GEMMs (batch × seqlen for prefill, batch
/// for decode); `kv_tokens` = mean KV-cache length read by attention —
/// f64 so a mixed batch's fractional mean (see
/// [`crate::engine::batcher::StepBatch::mean_ctx`]) is not truncated down
/// a token bucket.
pub fn layer_times(
    g: &GpuSpec,
    cfg: &ModelConfig,
    tp: usize,
    m_tokens: usize,
    kv_tokens: f64,
    batch: usize,
) -> LayerTimes {
    let d = cfg.d_model;
    let dt = cfg.dtype_bytes;
    let qd = cfg.q_dim() / tp;
    let kvd = (cfg.kv_dim() / tp).max(cfg.head_dim); // kv heads replicate past tp > n_kv
    let mut matmul = 0.0;
    // QKV projection (fused): N = (q + 2kv)/tp.
    matmul += gemm_time(g, m_tokens, qd + 2 * kvd, d, dt);
    // Output projection: K = q/tp.
    matmul += gemm_time(g, m_tokens, d, qd, dt);
    // MLP: gate+up then down — dense or MoE active experts.
    match cfg.moe {
        None => {
            let f = cfg.ffn / tp;
            matmul += gemm_time(g, m_tokens, 2 * f, d, dt);
            matmul += gemm_time(g, m_tokens, d, f, dt);
        }
        Some(moe) => {
            // Tokens spread across experts; each active expert GEMM sees
            // roughly m·active/experts rows, floored by the tile.
            let f = moe.expert_ffn;
            let routed = m_tokens * moe.active_experts;
            let n_gemms = moe.n_experts.min(routed).max(1);
            let rows = routed.div_ceil(n_gemms).max(1);
            matmul += n_gemms as f64
                * (gemm_time(g, rows, 2 * f / tp.min(f), d, dt)
                    + gemm_time(g, rows, d, f / tp.min(f), dt));
        }
    }

    // Attention score/AV compute + KV-cache traffic: memory-bound in
    // decode; flash-style compute in prefill.
    let kv_heads_here = (cfg.n_kv_heads / tp).max(1);
    let kv_bytes = batch as f64 * kv_tokens * (kv_heads_here * cfg.head_dim * 2 * dt) as f64;
    let attn_flops = 4.0
        * (m_tokens as f64)
        * kv_tokens
        * (cfg.n_heads / tp) as f64
        * cfg.head_dim as f64;
    let attn_time = (attn_flops / (g.flops * g.mxu_efficiency * 0.5))
        .max(kv_bytes / g.mem_bw)
        .max(g.kernel_floor);
    // Norms/rope/residuals: stream the activations a few times.
    let act_bytes = (6 * m_tokens * d * dt) as u64;
    let other = attn_time + membound_time(g, act_bytes);

    LayerTimes { matmul, other }
}

/// Memory footprint per GPU: weight shard + KV cache shard + workspace.
pub fn memory_per_gpu(
    cfg: &ModelConfig,
    tp: usize,
    stages: usize,
    batch: usize,
    seq_len: usize,
) -> u64 {
    let layers_here = cfg.n_layers.div_ceil(stages);
    let weight_share = cfg.param_bytes() / (tp as u64 * stages as u64);
    let kv = (layers_here * batch * seq_len) as u64 * cfg.kv_bytes_per_token_layer()
        / tp as u64;
    let workspace = 2 * (1u64 << 30);
    weight_share + kv + workspace
}

/// Does this deployment fit device memory? (Missing points in Figs 1–2.)
pub fn fits_memory(
    g: &GpuSpec,
    cfg: &ModelConfig,
    tp: usize,
    stages: usize,
    batch: usize,
    seq_len: usize,
) -> bool {
    memory_per_gpu(cfg, tp, stages, batch, seq_len) <= g.mem_bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 4 reproduction at paper scale (A100 numbers).
    #[test]
    fn table4_prefill_gemm_halves_both_ways() {
        let g = GpuSpec::a100();
        let (m, n, k) = (32768, 8192, 57344);
        let base = gemm_time(&g, m, n, k, 2);
        let mhalf = gemm_time(&g, m / 2, n, k, 2);
        let khalf = gemm_time(&g, m, n, k / 2, 2);
        // Paper: 108 ms -> 53.8 / 53.9 ms. Compute-bound: both halve.
        assert!((mhalf / base - 0.5).abs() < 0.05, "mhalf ratio {}", mhalf / base);
        assert!((khalf / base - 0.5).abs() < 0.05, "khalf ratio {}", khalf / base);
        // Absolute magnitude: ~100 ms at 85% efficiency.
        assert!(base > 0.08 && base < 0.15, "base {base}");
    }

    #[test]
    fn table4_decode_gemm_m_floor() {
        let g = GpuSpec::a100();
        let (m, n, k) = (32, 8192, 57344);
        let base = gemm_time(&g, m, n, k, 2);
        let mhalf = gemm_time(&g, m / 2, n, k, 2);
        let khalf = gemm_time(&g, m, n, k / 2, 2);
        // Paper: 0.614 -> 0.574 (marginal) / 0.359 ms (substantial).
        assert!(mhalf / base > 0.90, "M/2 should barely help: {}", mhalf / base);
        assert!(khalf / base < 0.65, "K/2 should nearly halve: {}", khalf / base);
        assert!(base > 3.0e-4 && base < 8.0e-4, "base {base}");
    }

    #[test]
    fn memory_bound_vs_compute_bound() {
        let g = GpuSpec::a100();
        // Decode GEMM is memory bound: time ≈ weight bytes / bw.
        let t = gemm_time(&g, 32, 8192, 57344, 2);
        let wbytes = (8192 * 57344 * 2) as f64;
        assert!((t - wbytes / g.mem_bw).abs() / t < 0.05);
    }

    #[test]
    fn kernel_floor_applies() {
        let g = GpuSpec::a100();
        assert_eq!(gemm_time(&g, 1, 1, 1, 2), g.kernel_floor);
        assert_eq!(membound_time(&g, 1), g.kernel_floor);
    }

    #[test]
    fn layer_times_decode_vs_prefill() {
        let g = GpuSpec::a100();
        let cfg = crate::models::ModelConfig::llama31_70b();
        let prefill = layer_times(&g, &cfg, 8, 8 * 2363, 2363.0, 8);
        let decode = layer_times(&g, &cfg, 8, 8, 1426.0, 8);
        assert!(prefill.matmul > 50.0 * decode.matmul);
    }

    #[test]
    fn tp_reduces_decode_matmul() {
        let g = GpuSpec::a100();
        let cfg = crate::models::ModelConfig::llama31_70b();
        let t4 = layer_times(&g, &cfg, 4, 8, 1426.0, 8);
        let t16 = layer_times(&g, &cfg, 16, 8, 1426.0, 8);
        // K-split: decode matmul keeps scaling with TP (Observation 2).
        assert!(t16.matmul < 0.5 * t4.matmul, "{} vs {}", t16.matmul, t4.matmul);
    }

    #[test]
    fn oom_detection_matches_paper_minimums() {
        let a100 = GpuSpec::a100();
        let m70 = crate::models::ModelConfig::llama31_70b();
        let m405 = crate::models::ModelConfig::llama31_405b();
        // 70B needs >= 4 GPUs (a single Perlmutter node); 405B >= 16.
        assert!(!fits_memory(&a100, &m70, 1, 1, 8, 4498));
        assert!(fits_memory(&a100, &m70, 4, 1, 8, 4498));
        assert!(!fits_memory(&a100, &m405, 4, 1, 8, 4498));
        assert!(fits_memory(&a100, &m405, 16, 1, 8, 4498));
    }

    #[test]
    fn moe_layer_cheaper_than_dense_equivalent() {
        let g = GpuSpec::a100();
        let qwen = crate::models::ModelConfig::qwen3_235b_a22b();
        let t = layer_times(&g, &qwen, 4, 8, 1024.0, 8);
        assert!(t.matmul > 0.0 && t.matmul < 0.01);
    }
}
