//! MoE step-cost model (Fig 10): expert-parallel configurations for
//! Qwen3-235B-A22B on 16 GPUs, combining EP for the MoE layers with
//! TP × DP (or PP) for the non-MoE layers, under NCCL or NVRAR.
//!
//! [`MoeCost`] is the [`StepCost`] implementation a `ep > 1`
//! [`ParallelSpec`] dispatches to (via [`crate::parallel::cost_for`]).
//! NVRAR targets the TP all-reduce, which remains on the critical path of
//! the attention (non-MoE) part of every layer — so it composes with EP
//! (the paper's §5.2.4 point) and the EP all-to-alls are untouched.

use crate::cluster::Topology;
use crate::collectives::sim::{allreduce, CommConfig};
use crate::collectives::AllReduceImpl;
use crate::engine::batcher::StepBatch;
use crate::metrics::Breakdown;
use crate::parallel::{CommSplit, ParallelSpec, StepCost};
use crate::perfmodel;
use crate::serving::ServeConfig;

/// The four Fig-10 deployments on 16 GPUs, as `(spec, all-reduce)` pairs —
/// canonical labels `tp16-ep16/NCCL`, `tp8-dp2-ep16/NCCL`,
/// `tp4-pp4-ep4/NCCL`, `tp16-ep16/NVRAR`.
pub fn fig10_specs() -> Vec<(ParallelSpec, AllReduceImpl)> {
    vec![
        (ParallelSpec::moe(16, 1, 16), AllReduceImpl::NcclAuto),
        (ParallelSpec::moe(8, 2, 16), AllReduceImpl::NcclAuto),
        (ParallelSpec { tp: 4, pp: 4, dp: 1, ep: 4 }, AllReduceImpl::NcclAuto),
        (ParallelSpec::moe(16, 1, 16), AllReduceImpl::Nvrar),
    ]
}

/// All-to-all dispatch/combine time for routing `rows` token embeddings
/// across `ep` GPUs (two a2a per MoE layer: dispatch + combine). An EP
/// group that fits inside one node runs on NVLink.
pub fn all_to_all_time(topo: &Topology, comm: &CommConfig, rows: usize, d: usize, dtype: usize, ep: usize) -> f64 {
    if ep <= 1 {
        return 0.0;
    }
    let link = if ep <= topo.gpus_per_node { topo.intra } else { topo.inter };
    // Each GPU exchanges (ep-1)/ep of its rows with peers; NIC serialized.
    let bytes = (rows * d * dtype) as f64 * (ep - 1) as f64 / ep as f64;
    let alpha = link.alpha + comm.proxy_overhead;
    alpha + bytes / link.beta
}

/// Per-step cost of a MoE model under an EP deployment (decode-dominated
/// serving step). Requires the [`ServeConfig`]'s model to be MoE.
#[derive(Clone, Copy, Debug)]
pub struct MoeCost {
    spec: ParallelSpec,
    ar: AllReduceImpl,
}

impl MoeCost {
    pub fn new(spec: ParallelSpec, ar: AllReduceImpl) -> Self {
        assert!(spec.ep > 1, "MoeCost needs an expert-parallel spec");
        MoeCost { spec, ar }
    }
}

impl StepCost for MoeCost {
    fn step_time(&self, cfg: &ServeConfig, step: &StepBatch) -> f64 {
        let s = self.spec;
        let model = &cfg.model;
        let moe = model.moe.expect("MoE model required");
        let rows_total = step.token_rows().max(1);
        // DP splits the batch across replicas. PP does NOT divide the work:
        // one batch in flight traverses all stages (same no-interleave
        // semantics as the dense serving path), so a PP deployment pays
        // full-model depth at the smaller intra-stage TP degree.
        let rows = rows_total.div_ceil(s.dp).max(1);
        let d = model.d_model;
        let dt = model.dtype_bytes;
        let kv_len = step.mean_ctx();

        // Attention part under TP (same as dense path, zero-FFN model).
        let mut dense = model.clone();
        dense.moe = None;
        dense.ffn = 0;
        let tp_topo = s.tp_topology(&cfg.topo);
        // Attention KV reads scale with *sequences* (one context per seq),
        // not token rows — a prefill chunk's rows all share one prefix.
        let batch = step.seqs().div_ceil(s.dp).max(1);
        let lt_attn = perfmodel::layer_times(&cfg.gpu, &dense, s.tp, rows, kv_len, batch);
        let ar_msg = (rows * d * dt) as u64;
        let ar_t = if s.tp > 1 {
            allreduce(self.ar, &tp_topo, &cfg.comm, ar_msg, lt_attn.total() / 2.0).total
        } else {
            0.0
        };

        // MoE part under EP: each GPU hosts n_experts/ep whole experts and
        // runs one (gate+up, down) GEMM pair per resident expert over its
        // routed token share. Lower EP means more experts (more weight bytes
        // and more kernel floors) per GPU per layer — the mechanism that makes
        // the tp4-pp4-ep4 configuration stream 4x the expert weights per
        // wall-clock step.
        let experts_per_gpu = (moe.n_experts / s.ep).max(1);
        let routed = (rows * moe.active_experts).div_ceil(s.ep).max(1);
        let rows_e = routed.div_ceil(experts_per_gpu).max(1);
        let expert_gemm = experts_per_gpu as f64
            * (perfmodel::gemm_time(&cfg.gpu, rows_e, 2 * moe.expert_ffn, d, dt)
                + perfmodel::gemm_time(&cfg.gpu, rows_e, d, moe.expert_ffn, dt));
        let a2a = 2.0 * all_to_all_time(&cfg.topo, &cfg.comm, rows, d, dt, s.ep);

        // Overlap: the attention all-reduce pair ducks behind the
        // attention compute; the a2a dispatch/combine pair interleaves
        // with the expert GEMMs it feeds (each capped by that compute).
        let attn_comp = lt_attn.total() / cfg.persona.compute_efficiency;
        let hidden_ar = (cfg.overlap.tp_ar * (2.0 * ar_t)).min(attn_comp).max(0.0);
        let hidden_a2a = (cfg.overlap.ep_a2a * a2a).min(expert_gemm).max(0.0);
        let mut per_layer =
            attn_comp + (2.0 * ar_t - hidden_ar) + expert_gemm + (a2a - hidden_a2a);
        // DP replicas batch independently but the EP all-to-all is a global
        // rendezvous across the whole EP group: every MoE layer the replicas
        // lock-step, and composition imbalance (plus vLLM's dummy-batch
        // padding when a replica is idle) exposes straggler time. Modelled as
        // a fractional penalty on the layer's critical path.
        if s.dp > 1 {
            per_layer *= 1.0 + 0.45 * (1.0 - 1.0 / s.dp as f64) * 2.0;
        }
        let p2p = if s.pp > 1 {
            s.stage_link(&cfg.topo).xfer_time((rows * d * dt) as u64) + cfg.persona.p2p_overhead
        } else {
            0.0
        };
        model.n_layers as f64 * per_layer + s.pp as f64 * p2p + cfg.persona.step_overhead
    }

    fn step_breakdown(&self, cfg: &ServeConfig, step: &StepBatch) -> Breakdown {
        // Mirrors `step_time` exactly; buckets sum back to it. The DP
        // straggler penalty is *exposed waiting* at the all-to-all
        // rendezvous, so its inflation lands in Idle, not in the buckets
        // of the work it stretches.
        let s = self.spec;
        let model = &cfg.model;
        let moe = model.moe.expect("MoE model required");
        let rows_total = step.token_rows().max(1);
        let rows = rows_total.div_ceil(s.dp).max(1);
        let d = model.d_model;
        let dt = model.dtype_bytes;
        let kv_len = step.mean_ctx();

        let mut dense = model.clone();
        dense.moe = None;
        dense.ffn = 0;
        let tp_topo = s.tp_topology(&cfg.topo);
        let batch = step.seqs().div_ceil(s.dp).max(1);
        let lt_attn = perfmodel::layer_times(&cfg.gpu, &dense, s.tp, rows, kv_len, batch);
        let ar_msg = (rows * d * dt) as u64;
        let ar_t = if s.tp > 1 {
            allreduce(self.ar, &tp_topo, &cfg.comm, ar_msg, lt_attn.total() / 2.0).total
        } else {
            0.0
        };

        let experts_per_gpu = (moe.n_experts / s.ep).max(1);
        let routed = (rows * moe.active_experts).div_ceil(s.ep).max(1);
        let rows_e = routed.div_ceil(experts_per_gpu).max(1);
        let expert_gemm = experts_per_gpu as f64
            * (perfmodel::gemm_time(&cfg.gpu, rows_e, 2 * moe.expert_ffn, d, dt)
                + perfmodel::gemm_time(&cfg.gpu, rows_e, d, moe.expert_ffn, dt));
        let a2a = 2.0 * all_to_all_time(&cfg.topo, &cfg.comm, rows, d, dt, s.ep);

        let eff = cfg.persona.compute_efficiency;
        let attn_comp = lt_attn.total() / eff;
        let hidden_ar = (cfg.overlap.tp_ar * (2.0 * ar_t)).min(attn_comp).max(0.0);
        let hidden_a2a = (cfg.overlap.ep_a2a * a2a).min(expert_gemm).max(0.0);
        let per_layer_base =
            attn_comp + (2.0 * ar_t - hidden_ar) + expert_gemm + (a2a - hidden_a2a);
        let straggle = if s.dp > 1 { 0.45 * (1.0 - 1.0 / s.dp as f64) * 2.0 } else { 0.0 };
        let p2p = if s.pp > 1 {
            s.stage_link(&cfg.topo).xfer_time((rows * d * dt) as u64) + cfg.persona.p2p_overhead
        } else {
            0.0
        };
        let layers = model.n_layers as f64;
        Breakdown {
            matmul: layers * (lt_attn.matmul / eff + expert_gemm),
            other_comp: layers * (lt_attn.other / eff) + cfg.persona.step_overhead,
            comm: layers * ((2.0 * ar_t - hidden_ar) + (a2a - hidden_a2a)) + s.pp as f64 * p2p,
            idle: layers * (straggle * per_layer_base),
        }
    }

    // Same preamble as `step_breakdown`, so `exposed` is bit-for-bit the
    // breakdown's Comm bucket.
    fn step_comm(&self, cfg: &ServeConfig, step: &StepBatch) -> CommSplit {
        let s = self.spec;
        let model = &cfg.model;
        let Some(moe) = model.moe else {
            debug_assert!(false, "MoE model required");
            return CommSplit::default();
        };
        let rows_total = step.token_rows().max(1);
        let rows = rows_total.div_ceil(s.dp).max(1);
        let d = model.d_model;
        let dt = model.dtype_bytes;
        let kv_len = step.mean_ctx();

        let mut dense = model.clone();
        dense.moe = None;
        dense.ffn = 0;
        let tp_topo = s.tp_topology(&cfg.topo);
        let batch = step.seqs().div_ceil(s.dp).max(1);
        let lt_attn = perfmodel::layer_times(&cfg.gpu, &dense, s.tp, rows, kv_len, batch);
        let ar_msg = (rows * d * dt) as u64;
        let ar_t = if s.tp > 1 {
            allreduce(self.ar, &tp_topo, &cfg.comm, ar_msg, lt_attn.total() / 2.0).total
        } else {
            0.0
        };

        let experts_per_gpu = (moe.n_experts / s.ep).max(1);
        let routed = (rows * moe.active_experts).div_ceil(s.ep).max(1);
        let rows_e = routed.div_ceil(experts_per_gpu).max(1);
        let expert_gemm = experts_per_gpu as f64
            * (perfmodel::gemm_time(&cfg.gpu, rows_e, 2 * moe.expert_ffn, d, dt)
                + perfmodel::gemm_time(&cfg.gpu, rows_e, d, moe.expert_ffn, dt));
        let a2a = 2.0 * all_to_all_time(&cfg.topo, &cfg.comm, rows, d, dt, s.ep);

        let attn_comp = lt_attn.total() / cfg.persona.compute_efficiency;
        let hidden_ar = (cfg.overlap.tp_ar * (2.0 * ar_t)).min(attn_comp).max(0.0);
        let hidden_a2a = (cfg.overlap.ep_a2a * a2a).min(expert_gemm).max(0.0);
        let p2p = if s.pp > 1 {
            s.stage_link(&cfg.topo).xfer_time((rows * d * dt) as u64) + cfg.persona.p2p_overhead
        } else {
            0.0
        };
        let layers = model.n_layers as f64;
        let hidden = layers * (hidden_ar + hidden_a2a);
        CommSplit {
            exposed: layers * ((2.0 * ar_t - hidden_ar) + (a2a - hidden_a2a))
                + s.pp as f64 * p2p,
            hidden,
            slack: (layers * (attn_comp + expert_gemm) - hidden).max(0.0),
        }
    }

    fn step_collective_bytes(&self, cfg: &ServeConfig, step: &StepBatch) -> (u64, f64) {
        // The TP all-reduces of the attention part are what share the
        // fabric; EP all-to-alls stay un-booked for now (they are mostly
        // intra-node for the Fig-10 shapes — a noted follow-on).
        let rows = step.token_rows().max(1).div_ceil(self.spec.dp).max(1);
        let msg = (rows * cfg.model.d_model * cfg.model.dtype_bytes) as u64;
        (msg, 2.0 * cfg.model.n_layers as f64)
    }

    fn spec(&self) -> ParallelSpec {
        self.spec
    }

    fn ar(&self) -> AllReduceImpl {
        self.ar
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelConfig;
    use crate::serving::fig9_config;

    fn qwen_cfg(spec: ParallelSpec, ar: AllReduceImpl) -> ServeConfig {
        let mut cfg = fig9_config(spec, ar, 32, "perlmutter", 16);
        cfg.model = ModelConfig::qwen3_235b_a22b();
        cfg
    }

    fn step(rows: usize) -> StepBatch {
        StepBatch {
            prefills: vec![],
            decodes: (0..rows as u64).collect(),
            decode_ctx: vec![1024; rows],
        }
    }

    #[test]
    fn fig10_specs_all_16_gpus() {
        for (s, _) in fig10_specs() {
            assert_eq!(s.gpus(), 16, "{s}");
            assert!(s.validate(&crate::cluster::presets::perlmutter(4)).is_ok(), "{s}");
        }
    }

    #[test]
    fn nvrar_fastest_among_fig10() {
        // §5.2.4: TP16-EP16 with NVRAR achieves the highest throughput.
        let times: Vec<(String, f64)> = fig10_specs()
            .into_iter()
            .map(|(s, ar)| {
                let cfg = qwen_cfg(s, ar);
                (cfg.deployment_label(), cfg.step_time(&step(64)))
            })
            .collect();
        let nvrar = times.iter().find(|(l, _)| l.contains("NVRAR")).unwrap().1;
        for (l, tm) in &times {
            if !l.contains("NVRAR") {
                assert!(nvrar < *tm, "NVRAR {nvrar} should beat {l} {tm}");
            }
        }
    }

    #[test]
    fn a2a_zero_for_single_gpu_ep() {
        let t = crate::cluster::presets::perlmutter(4);
        let c = CommConfig::perlmutter();
        assert_eq!(all_to_all_time(&t, &c, 64, 4096, 2, 1), 0.0);
        assert!(all_to_all_time(&t, &c, 64, 4096, 2, 16) > 0.0);
    }

    #[test]
    fn dp_reduces_per_replica_rows() {
        let tp16 = qwen_cfg(ParallelSpec::moe(16, 1, 16), AllReduceImpl::NcclAuto);
        let tp8dp2 = qwen_cfg(ParallelSpec::moe(8, 2, 16), AllReduceImpl::NcclAuto);
        let t16 = tp16.step_time(&step(256));
        let t8 = tp8dp2.step_time(&step(256));
        // Both should be the same order of magnitude; DP halves rows but
        // TP halves; crossover depends on comm. Just require sane values.
        assert!(t16 > 0.0 && t8 > 0.0 && t16.is_finite() && t8.is_finite());
    }

    #[test]
    #[should_panic(expected = "expert-parallel")]
    fn moe_cost_rejects_dense_spec() {
        let _ = MoeCost::new(ParallelSpec::tp(16), AllReduceImpl::NcclAuto);
    }

    #[test]
    fn moe_breakdown_sums_to_step_time_and_charges_dp_straggle_to_idle() {
        for (s, ar) in fig10_specs() {
            let cfg = qwen_cfg(s, ar);
            let batch = step(128);
            let t = cfg.step_time(&batch);
            let bd = cfg.step_breakdown(&batch);
            assert!(
                (bd.total() - t).abs() <= 1e-9 * t,
                "{}: {} vs {t}",
                cfg.deployment_label(),
                bd.total()
            );
            assert!(bd.matmul > 0.0 && bd.comm > 0.0);
            // Only the DP deployment has a rendezvous straggler bucket.
            assert_eq!(bd.idle > 0.0, s.dp > 1, "{}", cfg.deployment_label());
        }
    }
}
