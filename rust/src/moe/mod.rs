//! MoE deployments (Fig 10): expert-parallel configurations for
//! Qwen3-235B-A22B on 16 GPUs, combining EP for the MoE layers with
//! TP × DP (or PP) for the non-MoE layers, under NCCL or NVRAR.
//!
//! NVRAR targets the TP all-reduce, which remains on the critical path of
//! the attention (non-MoE) part of every layer — so it composes with EP
//! (the paper's §5.2.4 point) and the EP all-to-alls are untouched.

use crate::cluster::Topology;
use crate::collectives::sim::{allreduce, CommConfig};
use crate::collectives::AllReduceImpl;
use crate::engine::batcher::StepBatch;
use crate::engine::persona::Persona;
use crate::models::ModelConfig;
use crate::perfmodel::{self, GpuSpec};

/// One Fig-10 deployment configuration.
#[derive(Clone, Copy, Debug)]
pub struct MoeDeployment {
    pub label: &'static str,
    /// TP degree of the non-MoE (attention) layers.
    pub tp: usize,
    /// Data-parallel replicas of the attention layers.
    pub dp: usize,
    /// Pipeline stages (1 = no PP).
    pub pp: usize,
    /// EP degree of the MoE layers (experts spread over this many GPUs).
    pub ep: usize,
    /// All-reduce implementation for the TP groups.
    pub ar: AllReduceImpl,
}

impl MoeDeployment {
    /// The four Fig-10 configurations on 16 GPUs.
    pub fn fig10() -> Vec<MoeDeployment> {
        vec![
            MoeDeployment { label: "TP16-EP16 (NCCL)", tp: 16, dp: 1, pp: 1, ep: 16, ar: AllReduceImpl::NcclAuto },
            MoeDeployment { label: "TP8xDP2-EP16 (NCCL)", tp: 8, dp: 2, pp: 1, ep: 16, ar: AllReduceImpl::NcclAuto },
            MoeDeployment { label: "PP4xTP4 (NCCL)", tp: 4, dp: 1, pp: 4, ep: 4, ar: AllReduceImpl::NcclAuto },
            MoeDeployment { label: "TP16-EP16 (NVRAR)", tp: 16, dp: 1, pp: 1, ep: 16, ar: AllReduceImpl::Nvrar },
        ]
    }

    pub fn gpus(&self) -> usize {
        self.tp * self.dp * self.pp
    }
}

/// All-to-all dispatch/combine time for routing `rows` token embeddings
/// across `ep` GPUs (two a2a per MoE layer: dispatch + combine). An EP
/// group that fits inside one node runs on NVLink.
pub fn all_to_all_time(topo: &Topology, comm: &CommConfig, rows: usize, d: usize, dtype: usize, ep: usize) -> f64 {
    if ep <= 1 {
        return 0.0;
    }
    let link = if ep <= topo.gpus_per_node { topo.intra } else { topo.inter };
    // Each GPU exchanges (ep-1)/ep of its rows with peers; NIC serialized.
    let bytes = (rows * d * dtype) as f64 * (ep - 1) as f64 / ep as f64;
    let alpha = link.alpha + comm.proxy_overhead;
    alpha + bytes / link.beta
}

/// Per-step time of a MoE model under a deployment (decode-dominated
/// serving step of `rows` token rows).
pub fn moe_step_time(
    model: &ModelConfig,
    topo: &Topology,
    gpu: &GpuSpec,
    comm: &CommConfig,
    persona: &Persona,
    dep: &MoeDeployment,
    step: &StepBatch,
) -> f64 {
    let moe = model.moe.expect("MoE model required");
    let rows_total = step.token_rows().max(1);
    // DP splits the batch across replicas. PP does NOT divide the work:
    // one batch in flight traverses all stages (same no-interleave
    // semantics as the dense serving path), so a PP deployment pays
    // full-model depth at the smaller intra-stage TP degree.
    let rows = rows_total.div_ceil(dep.dp).max(1);
    let d = model.d_model;
    let dt = model.dtype_bytes;

    // Attention part under TP (same as dense path, zero-FFN model).
    let mut dense = model.clone();
    dense.moe = None;
    dense.ffn = 0;
    let tp_topo = topo.with_gpus(dep.tp);
    let lt_attn = perfmodel::layer_times(gpu, &dense, dep.tp, rows, 1024, rows);
    let ar_msg = (rows * d * dt) as u64;
    let ar_t = if dep.tp > 1 {
        allreduce(dep.ar, &tp_topo, comm, ar_msg, lt_attn.total() / 2.0).total
    } else {
        0.0
    };

    // MoE part under EP: each GPU hosts n_experts/ep whole experts and
    // runs one (gate+up, down) GEMM pair per resident expert over its
    // routed token share. Lower EP means more experts (more weight bytes
    // and more kernel floors) per GPU per layer — the mechanism that makes
    // the PP4xTP4 configuration stream 4x the expert weights per wall-
    // clock step.
    let experts_per_gpu = (moe.n_experts / dep.ep).max(1);
    let routed = (rows * moe.active_experts).div_ceil(dep.ep).max(1);
    let rows_e = routed.div_ceil(experts_per_gpu).max(1);
    let expert_gemm = experts_per_gpu as f64
        * (perfmodel::gemm_time(gpu, rows_e, 2 * moe.expert_ffn, d, dt)
            + perfmodel::gemm_time(gpu, rows_e, d, moe.expert_ffn, dt));
    let a2a = 2.0 * all_to_all_time(topo, comm, rows, d, dt, dep.ep);

    let mut per_layer = lt_attn.total() / persona.compute_efficiency + 2.0 * ar_t + expert_gemm + a2a;
    // DP replicas batch independently but the EP all-to-all is a global
    // rendezvous across the whole EP group: every MoE layer the replicas
    // lock-step, and composition imbalance (plus vLLM's dummy-batch
    // padding when a replica is idle) exposes straggler time. Modelled as
    // a fractional penalty on the layer's critical path.
    if dep.dp > 1 {
        per_layer *= 1.0 + 0.45 * (1.0 - 1.0 / dep.dp as f64) * 2.0;
    }
    let p2p = if dep.pp > 1 {
        topo.inter.xfer_time((rows * d * dt) as u64) + persona.p2p_overhead
    } else {
        0.0
    };
    model.n_layers as f64 * per_layer + dep.pp as f64 * p2p + persona.step_overhead
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;

    fn setup() -> (ModelConfig, Topology, GpuSpec, CommConfig, Persona) {
        (
            ModelConfig::qwen3_235b_a22b(),
            presets::perlmutter(4),
            GpuSpec::a100(),
            CommConfig::perlmutter(),
            Persona::vllm_v1(),
        )
    }

    fn step(rows: usize) -> StepBatch {
        StepBatch { prefills: vec![], decodes: (0..rows as u64).collect() }
    }

    #[test]
    fn fig10_configs_all_16_gpus() {
        for d in MoeDeployment::fig10() {
            assert_eq!(d.gpus(), 16, "{}", d.label);
        }
    }

    #[test]
    fn nvrar_fastest_among_fig10() {
        // §5.2.4: TP16-EP16 with NVRAR achieves the highest throughput.
        let (m, t, g, c, p) = setup();
        let times: Vec<(String, f64)> = MoeDeployment::fig10()
            .iter()
            .map(|d| (d.label.to_string(), moe_step_time(&m, &t, &g, &c, &p, d, &step(64))))
            .collect();
        let nvrar = times.iter().find(|(l, _)| l.contains("NVRAR")).unwrap().1;
        for (l, tm) in &times {
            if !l.contains("NVRAR") {
                assert!(nvrar < *tm, "NVRAR {nvrar} should beat {l} {tm}");
            }
        }
    }

    #[test]
    fn a2a_zero_for_single_gpu_ep() {
        let (_, t, _, c, _) = setup();
        assert_eq!(all_to_all_time(&t, &c, 64, 4096, 2, 1), 0.0);
        assert!(all_to_all_time(&t, &c, 64, 4096, 2, 16) > 0.0);
    }

    #[test]
    fn dp_reduces_per_replica_rows() {
        let (m, t, g, c, p) = setup();
        let tp16 = MoeDeployment::fig10()[0];
        let tp8dp2 = MoeDeployment::fig10()[1];
        let t16 = moe_step_time(&m, &t, &g, &c, &p, &tp16, &step(256));
        let t8 = moe_step_time(&m, &t, &g, &c, &p, &tp8dp2, &step(256));
        // Both should be the same order of magnitude; DP halves rows but
        // TP halves; crossover depends on comm. Just require sane values.
        assert!(t16 > 0.0 && t8 > 0.0 && t16.is_finite() && t8.is_finite());
    }
}
