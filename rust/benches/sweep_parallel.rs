//! Regenerates the `yalis sweep-parallel` grid: every valid ParallelSpec ×
//! {NCCL, NVRAR} for 70B on Perlmutter-16, with the Pareto frontier of
//! throughput vs mean TTFT marked.
use yalis::coordinator::experiments::sweep_parallel;
use yalis::parallel::OverlapSpec;

fn main() {
    let t = sweep_parallel("70b", "perlmutter", 16, OverlapSpec::none());
    t.print();
    t.write_csv("results/sweep_parallel.csv").unwrap();
}
