//! Regenerates Figure 17 (BurstGPT trace length distributions) and Figure
//! 18 (decode-heavy trace serving throughput).
use yalis::coordinator::experiments::fig17_fig18_traces;

fn main() {
    for (i, t) in fig17_fig18_traces().iter().enumerate() {
        t.print();
        t.write_csv(&format!("results/fig17_fig18_{i}.csv")).unwrap();
    }
}
