//! Regenerates the `yalis sweep-chunk` table: chunked vs whole-prompt
//! prefill on the long-prompt-heavy trace (70B on Perlmutter-16) — TTFT
//! p50/p99 tails, median TPOT and preemption counts per chunk size, with
//! the whole-prompt monolithic-step admission as the baseline.
use yalis::coordinator::experiments::sweep_chunk;

fn main() {
    let t = sweep_chunk("70b", "perlmutter", 16, None);
    t.print();
    t.write_csv("results/sweep_chunk.csv").unwrap();
}
