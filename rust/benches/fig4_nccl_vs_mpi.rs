//! Regenerates Figure 4: NCCL vs GPU-aware MPI all-reduce scaling across
//! message sizes and GPU counts (Perlmutter 40 GB partition).
use yalis::coordinator::experiments::fig4_nccl_vs_mpi;

fn main() {
    let t = fig4_nccl_vs_mpi();
    t.print();
    t.write_csv("results/fig4_nccl_vs_mpi.csv").unwrap();
}
