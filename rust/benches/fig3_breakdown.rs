//! Regenerates Figure 3: per-GPU time breakdown (Matmul / Other / Comm /
//! Idle) for YALIS (TP) and vLLM (HP) on 8 and 16 GPUs.
use yalis::coordinator::experiments::fig3_breakdown;

fn main() {
    let t = fig3_breakdown();
    t.print();
    t.write_csv("results/fig3_breakdown.csv").unwrap();
}
