//! Regenerates Figure 7: end-to-end decode-heavy batch-latency speedup of
//! NVRAR over NCCL for YALIS (TP) and vLLM (TP), 70B and 405B.
use yalis::coordinator::experiments::fig7_e2e_speedup;

fn main() {
    for model in ["70b", "405b"] {
        let t = fig7_e2e_speedup(model, "perlmutter");
        t.print();
        t.write_csv(&format!("results/fig7_{model}.csv")).unwrap();
    }
}
