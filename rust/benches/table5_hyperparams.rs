//! Regenerates Table 5: NVRAR B_s x C_s hyperparameter sensitivity for a
//! 1024 KB all-reduce on 16 GPUs.
use yalis::cluster::presets;
use yalis::collectives::sim::{nvrar, CommConfig};
use yalis::collectives::tuner;
use yalis::coordinator::experiments::table5_hyperparams;
use yalis::util::tables::Table;

fn main() {
    let t = table5_hyperparams();
    t.print();
    t.write_csv("results/table5_hyperparams.csv").unwrap();

    // Ablation (paper future work): the B_s x C_s auto-tuner vs the fixed
    // default configuration across message sizes.
    let topo = presets::perlmutter(4);
    let base = CommConfig::perlmutter();
    let table = tuner::TunedTable::build(&topo, &base);
    let mut ab = Table::new(
        "Table5-ext auto-tuned B_s/C_s vs default (16 GPUs, ms)",
        &["size", "default", "tuned", "B_s", "C_s", "gain"],
    );
    for kb in [64u64, 256, 1024, 4096] {
        let bytes = kb * 1024;
        let d = nvrar(&topo, &base, bytes, 0.0).total;
        let cfg = table.apply(&base, bytes);
        let tt = nvrar(&topo, &cfg, bytes, 0.0).total;
        let picked = table.lookup(bytes);
        ab.row(&[
            format!("{kb} KB"),
            format!("{:.4}", d * 1e3),
            format!("{:.4}", tt * 1e3),
            picked.block_count.to_string(),
            picked.chunk_bytes.to_string(),
            format!("{:.1}%", (1.0 - tt / d) * 100.0),
        ]);
    }
    ab.print();
    ab.write_csv("results/table5_autotuner.csv").unwrap();
}
