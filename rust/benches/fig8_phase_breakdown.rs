//! Regenerates Figure 8: per-phase breakdown of YALIS (TP) under NVRAR vs
//! NCCL all-reduce on 16 GPUs (decode-heavy, #P in {8, 32}).
use yalis::coordinator::experiments::fig8_phase_breakdown;

fn main() {
    let t = fig8_phase_breakdown();
    t.print();
    t.write_csv("results/fig8_phase_breakdown.csv").unwrap();
}
