//! Regenerates Table 4 twice: (a) the analytic tile-quantized GEMM model at
//! the paper's exact A100 shapes, and (b) REAL wall-clock PJRT executions of
//! the CPU-scaled GEMM artifacts (M/2 vs K/2), proving the tile-floor effect
//! on real hardware too (XLA CPU also tiles).

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::coordinator::experiments::table4_gemm_model;
use yalis::runtime::{lit_f32, Runtime};
use yalis::util::bench::Bencher;
use yalis::util::rng::Rng;
use yalis::util::tables::Table;

fn main() -> anyhow::Result<()> {
    let t = table4_gemm_model();
    t.print();
    t.write_csv("results/table4_model.csv").unwrap();

    if !std::path::Path::new("artifacts/gemm_decode_base.hlo.txt").exists() {
        println!("(artifacts not built; skipping real-GEMM half — run `make artifacts`)");
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let manifest = yalis::runtime::manifest::Manifest::load("artifacts")?;
    let mut table = Table::new(
        "Table4 real PJRT GEMMs (CPU-scaled shapes, ms)",
        &["workload", "variant", "M,N,K", "time (ms)"],
    );
    let b = Bencher::quick();
    let mut rng = Rng::new(11);
    for kind in ["prefill", "decode"] {
        for var in ["base", "mhalf", "khalf"] {
            let name = format!("gemm_{kind}_{var}");
            let exe = rt.load("artifacts", &name)?;
            let mnk = manifest.get(&format!("gemm.{kind}.{var}.mnk"))?;
            let dims: Vec<usize> = mnk.split(',').map(|s| s.parse().unwrap()).collect();
            let (m, n, k) = (dims[0], dims[1], dims[2]);
            let x: Vec<f32> = (0..m * k).map(|_| rng.f32()).collect();
            let y: Vec<f32> = (0..k * n).map(|_| rng.f32()).collect();
            let xl = lit_f32(&x, &[m, k])?;
            let yl = lit_f32(&y, &[k, n])?;
            let meas = b.run(&name, || {
                let _ = exe.run_lits(&[xl.clone(), yl.clone()]).unwrap();
            });
            table.row(&[
                kind.to_string(),
                var.to_string(),
                mnk.to_string(),
                format!("{:.3}", meas.mean() * 1e3),
            ]);
        }
    }
    table.print();
    table.write_csv("results/table4_real.csv").unwrap();
    Ok(())
}
