//! Regenerates Figures 1, 2 and 11: strong scaling of TP/HP across engines
//! for Llama 3.1 70B and 405B (Table 2 workloads). `cargo bench` prints the
//! same series the paper plots and writes CSVs under results/.
use yalis::coordinator::experiments::fig1_fig2_scaling;

fn main() {
    for model in ["70b", "405b"] {
        for (i, t) in fig1_fig2_scaling(model).iter().enumerate() {
            t.print();
            t.write_csv(&format!("results/fig1_fig2_{model}_{i}.csv")).unwrap();
        }
    }
}
