//! Regenerates Figure 10: Qwen3-235B-A22B MoE deployments (EP / TPxDP / PP,
//! NCCL vs NVRAR) on 16 GPUs serving the BurstGPT trace.
use yalis::coordinator::experiments::fig10_moe;

fn main() {
    let t = fig10_moe();
    t.print();
    t.write_csv("results/fig10_moe.csv").unwrap();
}
