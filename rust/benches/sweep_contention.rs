//! `cargo bench --bench sweep_contention` — shared-interconnect
//! contention: concurrent drain-migration-sized transfers × all-reduce
//! message size × fabric (Slingshot vs InfiniBand), showing decode
//! all-reduce inflation the closed-form α-β models cannot represent.
//! CSV into results/.

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::coordinator::experiments;

fn main() {
    let t = experiments::sweep_contention(16);
    t.print();
    t.write_csv("results/sweep_contention.csv").unwrap();
    println!("-> results/sweep_contention.csv");
}
