//! Wall-clock benchmark of the REAL shared-memory collectives (this host):
//! NVRAR (Algorithm 1) vs flat ring, flat recursive doubling, and the
//! central-reduce yardstick, across message sizes, world shapes and chunk
//! sizes. This is the L3 hot path the perf pass optimizes (EXPERIMENTS.md
//! §Perf); correctness is asserted on every measured run.
use yalis::collectives::real::{serial_sum, Algo, Harness};
use yalis::util::bench::Bencher;
use yalis::util::rng::Rng;
use yalis::util::tables::Table;

fn main() {
    let b = Bencher { target_secs: 0.3, warmup: 1, max_iters: 50, min_iters: 3 };
    let mut table = Table::new(
        "real shmem all-reduce wall-clock (this host)",
        &["algo", "world", "elems", "chunk", "mean (ms)", "p99 (ms)"],
    );
    for (nodes, g) in [(2usize, 2usize), (4, 2), (8, 1)] {
        for n_elems in [4_096usize, 65_536] {
            for algo in Algo::all() {
                if matches!(algo, Algo::RdFlat | Algo::Rabenseifner)
                    && !(nodes * g).is_power_of_two()
                {
                    continue;
                }
                let h = Harness { nodes, gpus_per_node: g, n_elems, chunk_words: 2048, algo };
                let mut rng = Rng::new(42);
                let inputs: Vec<Vec<f32>> = (0..h.pes())
                    .map(|_| (0..n_elems).map(|_| rng.f32() - 0.5).collect())
                    .collect();
                let want = serial_sum(&inputs);
                let m = b.run(&format!("{}-{}x{}-{}", algo.name(), nodes, g, n_elems), || {
                    let out = h.run_once(|pe| inputs[pe].clone());
                    // Correctness asserted inside the timed region is
                    // cheap relative to the collective itself.
                    assert!(out[0]
                        .iter()
                        .zip(&want)
                        .all(|(a, w)| (a - w).abs() <= 1e-3 * (1.0 + w.abs())));
                });
                table.row(&[
                    algo.name().to_string(),
                    format!("{nodes}x{g}"),
                    n_elems.to_string(),
                    "2048".to_string(),
                    format!("{:.3}", m.mean() * 1e3),
                    format!("{:.3}", m.summary.percentile(99.0) * 1e3),
                ]);
            }
        }
    }
    // Chunk-size ablation on NVRAR (Table 5's C_s knob, real substrate).
    for chunk in [64usize, 512, 4096, 65_536] {
        let h = Harness { nodes: 4, gpus_per_node: 2, n_elems: 65_536, chunk_words: chunk, algo: Algo::Nvrar };
        let mut rng = Rng::new(1);
        let inputs: Vec<Vec<f32>> =
            (0..8).map(|_| (0..65_536).map(|_| rng.f32()).collect()).collect();
        let m = b.run(&format!("nvrar-chunk-{chunk}"), || {
            let _ = h.run_once(|pe| inputs[pe].clone());
        });
        table.row(&[
            "nvrar".into(),
            "4x2".into(),
            "65536".into(),
            chunk.to_string(),
            format!("{:.3}", m.mean() * 1e3),
            format!("{:.3}", m.summary.percentile(99.0) * 1e3),
        ]);
    }
    table.print();
    table.write_csv("results/real_allreduce_hotpath.csv").unwrap();
}
