//! Regenerates Figures 14 and 15 (Appendix C.3): Vista scaling curves,
//! NVRAR speedups with NCCL pinned to Tree/Ring, and the NCCL 2.27 vs 2.28
//! version comparison.
use yalis::coordinator::experiments::fig14_fig15_nccl_variants;

fn main() {
    for (i, t) in fig14_fig15_nccl_variants().iter().enumerate() {
        t.print();
        t.write_csv(&format!("results/fig14_fig15_{i}.csv")).unwrap();
    }
}
