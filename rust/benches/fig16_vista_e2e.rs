//! Regenerates Figure 16 (Appendix C.4.1): end-to-end decode-heavy NVRAR
//! speedup on Vista (InfiniBand, 1 GPU/node).
use yalis::coordinator::experiments::fig7_e2e_speedup;

fn main() {
    let t = fig7_e2e_speedup("70b", "vista");
    t.print();
    t.write_csv("results/fig16_vista.csv").unwrap();
}
