//! Regenerates Figures 12/13 (Appendix B): NVRAR's deferred sequence-number
//! synchronization is exposed in back-to-back microbenchmarks but hidden by
//! interleaved matmul compute.
use yalis::coordinator::experiments::fig13_sync_hiding;

fn main() {
    let t = fig13_sync_hiding();
    t.print();
    t.write_csv("results/fig13_sync_hiding.csv").unwrap();
}
