//! Fleet scaling sweep: replicas × routing policy × all-reduce impl on a
//! scaled BurstGPT trace. Shows (a) near-linear goodput scaling while the
//! fleet is the bottleneck, (b) the policy spread at high load, and (c)
//! that the per-replica NVRAR gain survives aggregation — the fleet-level
//! answer to the paper's single-replica Fig 9. Deployments are named by
//! their canonical `ParallelSpec` string (`tp16/NCCL`, `tp16/NVRAR`).
use yalis::collectives::AllReduceImpl;
use yalis::fleet::router::RoutePolicy;
use yalis::fleet::{run_fleet, FleetConfig};
use yalis::parallel::ParallelSpec;
use yalis::serving::fig9_config;
use yalis::trace::TraceSpec;
use yalis::util::tables::Table;

fn main() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 600;
    spec.rate = 20.0;
    let reqs = spec.generate();

    let mut t = Table::new(
        "fleet scaling: BurstGPT x600 @ 20 req/s, 70B tp16 per replica",
        &["replicas", "policy", "deployment", "tok/s", "goodput", "TTFT p99", "TPOT p99", "SLO %"],
    );
    for replicas in [2usize, 4, 8] {
        for policy in [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastOutstanding,
            RoutePolicy::KvPressure,
        ] {
            for ar in [AllReduceImpl::NcclAuto, AllReduceImpl::Nvrar] {
                let base = fig9_config(ParallelSpec::tp(16), ar, 64, "perlmutter", 16);
                let label = base.deployment_label();
                let cfg = FleetConfig::new(base, replicas).with_policy(policy);
                let rep = run_fleet(&cfg, &reqs);
                t.row(&[
                    replicas.to_string(),
                    policy.name().to_string(),
                    label,
                    format!("{:.1}", rep.throughput),
                    format!("{:.1}", rep.goodput),
                    format!("{:.2}", rep.ttft_p99),
                    format!("{:.3}", rep.tpot_p99),
                    format!("{:.0}%", rep.slo_attainment * 100.0),
                ]);
            }
        }
    }
    t.print();
    t.write_csv("results/fleet_scaling.csv").unwrap();
}
