//! `cargo bench --bench sweep_overlap` — comm/compute overlap
//! sensitivity: deployment shape × decode batch size × overlap fraction,
//! with the exposed/hidden collective split and the step-time speedup
//! over the serial (overlap 0) pricing. CSV into results/.

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::coordinator::experiments;

fn main() {
    let t = experiments::sweep_overlap(16);
    t.print();
    t.write_csv("results/sweep_overlap.csv").unwrap();
    println!("-> results/sweep_overlap.csv");
}
