//! `cargo bench --bench sweep_session` — multi-turn session serving:
//! turns × shared-prefix length × routing policy on a 3-replica fleet,
//! showing where prefix-cache-aware session affinity wins TTFT and hit
//! rate over content-blind least-outstanding. CSV into results/.

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::coordinator::experiments;

fn main() {
    let t = experiments::sweep_session("70b", "perlmutter", 16, None);
    t.print();
    t.write_csv("results/sweep_session.csv").unwrap();
    println!("-> results/sweep_session.csv");
}
