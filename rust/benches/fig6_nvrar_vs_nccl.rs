//! Regenerates Figure 6: NVRAR vs NCCL all-reduce scaling curves (left) and
//! the speedup-by-size-and-GPU-count grids for Perlmutter and Vista.
use yalis::coordinator::experiments::fig6_microbench;

fn main() {
    for machine in ["perlmutter", "vista"] {
        for (i, t) in fig6_microbench(machine).iter().enumerate() {
            t.print();
            t.write_csv(&format!("results/fig6_{machine}_{i}.csv")).unwrap();
        }
    }
}
