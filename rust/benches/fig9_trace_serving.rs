//! Regenerates Figure 9: output throughput serving the BurstGPT trace with
//! NCCL-TP, NVRAR-TP and HP at C in {32, 256}.
use yalis::coordinator::experiments::fig9_trace_serving;
use yalis::parallel::OverlapSpec;

fn main() {
    let t = fig9_trace_serving(0, None, OverlapSpec::none());
    t.print();
    t.write_csv("results/fig9_trace_serving.csv").unwrap();
}
