//! Offline stub of the PJRT/XLA bindings the `yalis` runtime compiles
//! against.
//!
//! The real PJRT path needs the upstream `xla` bindings plus a built
//! `artifacts/` directory (`make artifacts`); this stub keeps the crate —
//! and every simulation/fleet/collective code path, which never touches
//! PJRT — fully functional in environments without either. Every entry
//! point that would actually execute XLA returns [`Error::Unsupported`];
//! the runtime integration tests and examples already skip or fail
//! gracefully when artifacts are absent.
//!
//! The API surface mirrors exactly what `yalis::runtime` uses: nothing
//! more, nothing less.

use std::fmt;

/// Error type of the stubbed bindings.
#[derive(Clone, Debug)]
pub enum Error {
    /// Operation requires the real PJRT bindings.
    Unsupported(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unsupported(what) => write!(
                f,
                "{what}: built with the vendored `xla` stub — real PJRT execution is \
                 unavailable (swap rust/vendor/xla for the real bindings and rebuild)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the runtime uploads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// A host literal: shape + raw little-endian bytes. Constructible so that
/// pure host-side code paths keep working; device/dehosting operations are
/// stubbed.
#[derive(Clone, Debug)]
pub struct Literal {
    pub element_type: ElementType,
    pub dims: Vec<usize>,
    pub raw: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        element_type: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal { element_type, dims: dims.to_vec(), raw: data.to_vec() })
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unsupported("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unsupported("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub: never constructible from a file offline).
#[derive(Clone, Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unsupported("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Clone, Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// A PJRT device buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unsupported("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled + loaded executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unsupported("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unsupported("PjRtLoadedExecutable::execute_b"))
    }
}

/// A PJRT client (stub: construction fails with a clear message).
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unsupported("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unsupported("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unsupported("PjRtClient::buffer_from_host_literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_host_side() {
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &[0u8; 16],
        )
        .unwrap();
        assert_eq!(lit.dims, vec![2, 2]);
        assert_eq!(lit.raw.len(), 16);
    }

    #[test]
    fn device_paths_report_unsupported() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("stub"), "{err}");
    }
}
