//! Integration: the fleet layer's acceptance contract end-to-end — real
//! paper traces through multi-replica fleets, disaggregation beating the
//! monolithic pool on decode-heavy TTFT tails, NVRAR's per-replica gain
//! surviving aggregation, determinism, autoscaling under a ramp, and a
//! heterogeneous TP8/TP16 fleet routed cost-aware through the unified
//! `ParallelSpec` + `StepCost` API.

use yalis::collectives::AllReduceImpl;
use yalis::fleet::autoscaler::AutoscaleConfig;
use yalis::fleet::metrics::SloTargets;
use yalis::fleet::router::RoutePolicy;
use yalis::fleet::{run_fleet, FleetConfig};
use yalis::parallel::ParallelSpec;
use yalis::serving::{fig9_config, ServeConfig};
use yalis::trace::{LenDist, RateShape, SessionSpec, TraceSpec};

fn replica_70b(ar: AllReduceImpl, concurrency: usize) -> ServeConfig {
    fig9_config(ParallelSpec::tp(16), ar, concurrency, "perlmutter", 16)
}

fn replica_70b_tp8(ar: AllReduceImpl, concurrency: usize) -> ServeConfig {
    fig9_config(ParallelSpec::tp(8), ar, concurrency, "perlmutter", 8)
}

/// The acceptance-criterion configuration: on the paper's decode-heavy
/// trace (Appendix C.4.3, scaled), splitting the same 4-replica fleet into
/// 3 decode + 1 prefill beats 4 monolithic replicas on TTFT p99 — long
/// decodes hold monolithic slots for minutes while prompts queue.
#[test]
fn disaggregated_beats_monolithic_ttft_p99_decode_heavy() {
    let mut spec = TraceSpec::decode_heavy();
    spec.num_prompts = 100;
    spec.rate = 3.0; // ~3 req/s × ~50 s/request ≫ 4×16 slots: saturated
    let reqs = spec.generate();
    let base = replica_70b(AllReduceImpl::Nvrar, 16);
    let mono = run_fleet(
        &FleetConfig::new(base.clone(), 4).with_policy(RoutePolicy::LeastOutstanding),
        &reqs,
    );
    let disagg = run_fleet(
        &FleetConfig::new(base, 3).with_policy(RoutePolicy::LeastOutstanding).disaggregated(1),
        &reqs,
    );
    assert_eq!(mono.completed, 100);
    assert_eq!(disagg.completed, 100);
    assert!(
        disagg.ttft_p99 < mono.ttft_p99,
        "disaggregated p99 TTFT {:.2}s must beat monolithic {:.2}s",
        disagg.ttft_p99,
        mono.ttft_p99
    );
    // The handoff traffic is real: every multi-token request moved its KV.
    assert_eq!(disagg.handoffs as usize, reqs.iter().filter(|r| r.decode_len > 1).count());
    assert!(disagg.handoff_gb > 0.0);
}

/// NVRAR's per-replica speedup (Fig 9's mechanism) survives fleet-level
/// aggregation: under saturating load, the NVRAR fleet clears the same
/// trace faster than the NCCL fleet.
#[test]
fn nvrar_fleet_outperforms_nccl_fleet_under_saturation() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 300;
    spec.rate = 50.0; // demand above the 3-replica service rate
    let reqs = spec.generate();
    let nccl = run_fleet(&FleetConfig::new(replica_70b(AllReduceImpl::NcclAuto, 64), 3), &reqs);
    let nvrar = run_fleet(&FleetConfig::new(replica_70b(AllReduceImpl::Nvrar, 64), 3), &reqs);
    assert!(
        nvrar.throughput > nccl.throughput,
        "NVRAR fleet {:.1} tok/s should beat NCCL {:.1} tok/s",
        nvrar.throughput,
        nccl.throughput
    );
    assert!(nvrar.makespan < nccl.makespan);
}

/// The acceptance criterion of the ParallelSpec redesign: a mixed
/// TP8/TP16 fleet (heterogeneous replica sizes, the ROADMAP item) runs
/// through the same API, the cost-aware router sends the faster TP16
/// replicas more work, and every invariant — request conservation (and
/// KV-page leak freedom, asserted inside `run_fleet`) plus bit-determinism
/// — holds.
#[test]
fn heterogeneous_tp8_tp16_fleet_routes_cost_aware_with_invariants() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 200;
    spec.rate = 25.0;
    let reqs = spec.generate();
    let pool = vec![
        replica_70b(AllReduceImpl::Nvrar, 64),
        replica_70b(AllReduceImpl::Nvrar, 64),
        replica_70b_tp8(AllReduceImpl::Nvrar, 64),
        replica_70b_tp8(AllReduceImpl::Nvrar, 64),
    ];
    let cfg = FleetConfig::heterogeneous(pool).with_policy(RoutePolicy::LeastOutstanding);
    let a = run_fleet(&cfg, &reqs);
    assert_eq!(a.completed, 200);
    // Cost-aware routing: the two TP16 replicas absorb more requests than
    // the two TP8 ones.
    assert_eq!(a.routed.len(), 4);
    let tp16_load = a.routed[0] + a.routed[1];
    let tp8_load = a.routed[2] + a.routed[3];
    assert!(
        tp16_load > tp8_load,
        "TP16 replicas should absorb more load: {:?}",
        a.routed
    );
    assert!(tp8_load > 0, "slower replicas must still serve: {:?}", a.routed);
    // Bit-deterministic across runs.
    let b = run_fleet(&cfg, &reqs);
    assert_eq!(a, b, "heterogeneous fleet must be bit-deterministic");
    // And the mixed fleet also works disaggregated, with kv-pressure
    // routing, conserving the whole trace.
    let disagg = FleetConfig::heterogeneous(vec![
        replica_70b(AllReduceImpl::Nvrar, 64),
        replica_70b_tp8(AllReduceImpl::Nvrar, 64),
    ])
    .with_policy(RoutePolicy::KvPressure)
    .disaggregated(1);
    let c = run_fleet(&disagg, &reqs);
    assert_eq!(c.completed, 200);
}

/// The chunked-prefill acceptance criterion at fleet level: a decode-heavy
/// trace whose prompts reach 4x the per-step token budget completes under
/// both pool modes with zero lost tokens — the configuration the fleet
/// used to reject outright with a `prompt_len <= max_step_tokens` assert.
#[test]
fn long_prompts_complete_across_the_fleet_with_zero_lost_tokens() {
    let mut spec = TraceSpec::decode_heavy();
    spec.num_prompts = 60;
    spec.rate = 6.0;
    spec.input = LenDist { median: 4000.0, sigma: 1.0, min: 256, max: 32_768 };
    let mut reqs = spec.generate();
    let budget = replica_70b(AllReduceImpl::Nvrar, 32).max_step_tokens;
    // Pin prompts at 4x and 2x the budget so the chunked path is
    // exercised regardless of what the log-normal tail sampled.
    reqs[4].prompt_len = 4 * budget;
    reqs[23].prompt_len = 2 * budget;
    let expected_check: usize = reqs.iter().filter(|r| r.prompt_len > budget).count();
    assert!(expected_check >= 2);
    let expected: u64 = reqs.iter().map(|r| r.decode_len as u64).sum();
    for prefill in [0usize, 1] {
        let mut cfg = FleetConfig::new(replica_70b(AllReduceImpl::Nvrar, 32), 3)
            .with_policy(RoutePolicy::LeastOutstanding);
        if prefill > 0 {
            cfg = cfg.disaggregated(prefill);
        }
        let rep = run_fleet(&cfg, &reqs);
        assert_eq!(rep.completed, 60, "prefill={prefill}");
        assert_eq!(rep.rejected, 0);
        assert_eq!(rep.output_tokens, expected, "zero lost tokens (prefill={prefill})");
    }
}

/// A request whose lifetime KV footprint can never fit any replica is
/// rejected with a counter — not a panic, and not a silent stall — while
/// the rest of the trace serves normally.
#[test]
fn infeasible_requests_are_counted_not_fatal() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 30;
    spec.rate = 10.0;
    // Keep every organic request's lifetime footprint well under the
    // shrunken KV so exactly the two doctored ones are infeasible.
    spec.input = LenDist { median: 400.0, sigma: 0.6, min: 32, max: 2048 };
    spec.output = LenDist { median: 100.0, sigma: 0.5, min: 8, max: 512 };
    let mut reqs = spec.generate();
    let mut base = replica_70b(AllReduceImpl::Nvrar, 32);
    base.kv_pages = 512; // 8192 tokens of KV per replica
    reqs[5].prompt_len = 9000; // lifetime footprint > 8192 tokens
    reqs[17].decode_len = 9000;
    let rep = run_fleet(&FleetConfig::new(base, 2), &reqs);
    assert_eq!(rep.rejected, 2);
    assert_eq!(rep.completed, 28);
}

/// Bit-identical results for a fixed seed, including the stateful paths
/// (disaggregation + autoscaling + session affinity).
#[test]
fn fleet_results_deterministic_across_runs() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 150;
    spec.rate = 25.0;
    spec.shape = RateShape::Ramp { from: 0.5, to: 2.0 };
    let reqs = spec.generate();
    let cfg = FleetConfig::new(replica_70b(AllReduceImpl::Nvrar, 32), 2)
        .with_policy(RoutePolicy::SessionAffinity)
        .disaggregated(1)
        .with_slo(SloTargets { ttft: 2.0, tpot: 0.1 })
        .with_autoscale(AutoscaleConfig {
            tick: 5.0,
            provision_delay: 10.0,
            min_replicas: 1,
            max_replicas: 6,
            window: 64,
            down_frac: 0.25,
        });
    let a = run_fleet(&cfg, &reqs);
    let b = run_fleet(&cfg, &reqs);
    assert_eq!(a, b, "fleet runs with a fixed seed must be bit-identical");
    // Regenerating the trace reproduces the same arrivals too.
    let reqs2 = spec.generate();
    let c = run_fleet(&cfg, &reqs2);
    assert_eq!(a, c);
}

/// A ramping trace drives the autoscaler: capacity grows under the rush
/// and every request still completes exactly once.
#[test]
fn autoscaler_grows_fleet_under_ramping_load() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 250;
    spec.rate = 10.0;
    spec.shape = RateShape::Ramp { from: 0.2, to: 4.0 };
    let reqs = spec.generate();
    let cfg = FleetConfig::new(replica_70b(AllReduceImpl::Nvrar, 32), 1)
        .with_slo(SloTargets { ttft: 1.0, tpot: 0.2 })
        .with_autoscale(AutoscaleConfig {
            tick: 3.0,
            provision_delay: 6.0,
            min_replicas: 1,
            max_replicas: 8,
            window: 48,
            down_frac: 0.2,
        });
    let rep = run_fleet(&cfg, &reqs);
    assert_eq!(rep.completed, 250);
    assert!(rep.scale_ups > 0, "ramp must trigger scale-ups");
    assert!(rep.peak_replicas > 1, "fleet must actually grow");
}

/// The shared-prefix acceptance criterion: on a multi-turn `SessionSpec`
/// trace, prefix-cache-aware `session-affinity` routing beats
/// content-blind `least-outstanding` on TTFT p50 with a nonzero reported
/// cache hit rate — the policy finally *wins* something (ROADMAP:
/// "Prefix-cache hit modeling for session affinity").
#[test]
fn session_affinity_beats_least_outstanding_on_session_trace() {
    let mut sspec = SessionSpec::standard();
    sspec.sessions = 60;
    sspec.turns = 5;
    sspec.rate = 3.0;
    let reqs = sspec.generate();
    let n = reqs.len();
    let base = replica_70b(AllReduceImpl::Nvrar, 32);
    let lo = run_fleet(
        &FleetConfig::new(base.clone(), 4).with_policy(RoutePolicy::LeastOutstanding),
        &reqs,
    );
    let sa = run_fleet(
        &FleetConfig::new(base, 4).with_policy(RoutePolicy::SessionAffinity),
        &reqs,
    );
    assert_eq!((lo.completed, sa.completed), (n, n));
    assert!(sa.cache_hit_rate > 0.0, "affinity must report a nonzero hit rate");
    assert!(sa.cached_tokens > 0);
    assert!(
        sa.cache_hit_rate > lo.cache_hit_rate,
        "affinity must concentrate hits: {} vs {}",
        sa.cache_hit_rate,
        lo.cache_hit_rate
    );
    assert!(
        sa.ttft_p50 < lo.ttft_p50,
        "session-affinity TTFT p50 {:.3}s must beat least-outstanding {:.3}s",
        sa.ttft_p50,
        lo.ttft_p50
    );
    // Output tokens agree: sharing changes work done, never tokens owed.
    assert_eq!(sa.output_tokens, lo.output_tokens);
}

/// The drain-migration acceptance criterion: a drained replica retires
/// strictly earlier with KV migration than without (ROADMAP: "KV
/// migration on drain"), with the migrated bytes priced over the
/// inter-node link, and the workload conserved either way.
#[test]
fn drained_replica_retires_strictly_earlier_with_kv_migration() {
    let mut spec = TraceSpec::decode_heavy();
    spec.num_prompts = 60;
    spec.rate = 4.0;
    let reqs = spec.generate();
    let base = FleetConfig::new(replica_70b(AllReduceImpl::Nvrar, 16), 3)
        .with_policy(RoutePolicy::LeastOutstanding)
        .with_drain_at(20.0, 2);
    let with = run_fleet(&base.clone().with_migration(true), &reqs);
    let without = run_fleet(&base.with_migration(false), &reqs);
    assert_eq!((with.completed, without.completed), (60, 60));
    assert_eq!((with.drains, without.drains), (1, 1), "both runs drained replica 2");
    assert!(with.migrations > 0, "in-flight decodes must migrate");
    assert!(with.migration_gb > 0.0, "migrated KV bytes are real traffic");
    assert_eq!(without.migrations, 0);
    assert!(
        with.drain_secs < without.drain_secs,
        "migration must retire the drained replica strictly earlier: {:.2}s vs {:.2}s",
        with.drain_secs,
        without.drain_secs
    );
    let expected: u64 = reqs.iter().map(|r| r.decode_len as u64).sum();
    assert_eq!(with.output_tokens, expected, "migration loses no tokens");
    assert_eq!(without.output_tokens, expected);
}

/// Zero-sharing contract at fleet level: on a single-shot trace the
/// shared-prefix allocator changes nothing observable — hit rate is zero
/// and throughput metrics stay deterministic.
#[test]
fn single_shot_traces_report_zero_cache_hits() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 80;
    spec.rate = 20.0;
    let reqs = spec.generate();
    let rep = run_fleet(&FleetConfig::new(replica_70b(AllReduceImpl::Nvrar, 32), 2), &reqs);
    assert_eq!(rep.completed, 80);
    assert_eq!(rep.cache_hit_rate, 0.0);
    assert_eq!(rep.cached_tokens, 0);
    assert_eq!(rep.migrations, 0);
    assert_eq!(rep.drains, 0);
}

/// Routing-policy sweep over the same trace: every policy conserves the
/// workload, and the load-aware policies do not lose to round-robin on
/// TTFT tails by more than noise (they place against load, not blindly).
#[test]
fn policy_sweep_conserves_and_reports_sane_metrics() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 200;
    spec.rate = 30.0;
    let reqs = spec.generate();
    let mut reports = Vec::new();
    for policy in RoutePolicy::all() {
        let cfg = FleetConfig::new(replica_70b(AllReduceImpl::Nvrar, 64), 4).with_policy(policy);
        let rep = run_fleet(&cfg, &reqs);
        assert_eq!(rep.completed, 200, "{policy:?}");
        assert!(rep.ttft_p50 <= rep.ttft_p95 && rep.ttft_p95 <= rep.ttft_p99);
        assert!(rep.throughput > 0.0);
        assert!(rep.slo_attainment >= 0.0 && rep.slo_attainment <= 1.0);
        reports.push((policy, rep));
    }
    // All policies saw identical work: output token totals must agree.
    let tokens: Vec<u64> = reports.iter().map(|(_, r)| r.output_tokens).collect();
    assert!(tokens.windows(2).all(|w| w[0] == w[1]), "{tokens:?}");
}
