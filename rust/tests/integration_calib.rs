//! Integration: the calibration loop end-to-end — bundles round-trip
//! through files, the registry rejects unknowns usably, `validate`'s claim
//! suite passes on built-ins and catches perturbed constants, and `fit`
//! recovers known α/β whose output bundle resolves via `--machine <path>`.

use yalis::calib::{claims, fit, registry, MachineBundle};
use yalis::cluster::presets;
use yalis::collectives::sim::CommConfig;
use yalis::perfmodel::GpuSpec;

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("yalis_integration_calib");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_str().unwrap().to_string()
}

/// The registry's built-ins are byte-for-byte the legacy preset constants:
/// refactoring resolution through `calib` changed no simulated number.
#[test]
fn builtin_bundles_match_legacy_presets() {
    for (name, comm, gpu, topo) in [
        ("perlmutter", CommConfig::perlmutter(), GpuSpec::a100(), presets::perlmutter(1)),
        ("vista", CommConfig::vista(), GpuSpec::gh200(), presets::vista(1)),
        ("generic_ib", CommConfig::generic_ib(), GpuSpec::a100(), presets::generic_ib(1)),
    ] {
        let b = registry::resolve(name).unwrap();
        assert_eq!(b.comm.eta, comm.eta, "{name}");
        assert_eq!(b.comm.proxy_overhead, comm.proxy_overhead, "{name}");
        assert_eq!(b.comm.sync_cost, comm.sync_cost, "{name}");
        assert_eq!(b.gpu.name, gpu.name, "{name}");
        assert_eq!(b.gpu.flops, gpu.flops, "{name}");
        assert_eq!(b.topo.gpus_per_node, topo.gpus_per_node, "{name}");
        assert_eq!(b.topo.inter.alpha, topo.inter.alpha, "{name}");
        assert_eq!(b.topo.inter.beta, topo.inter.beta, "{name}");
        // ...and the fallible machine-wide accessors agree with the bundle.
        assert_eq!(CommConfig::for_machine(name).unwrap().reduce_bw, b.comm.reduce_bw);
        assert_eq!(GpuSpec::for_machine(name).unwrap().mem_bw, b.gpu.mem_bw);
        assert_eq!(presets::by_name(name, 4).unwrap().nodes, 4);
    }
}

#[test]
fn unknown_names_error_with_valid_name_list() {
    for err in [
        CommConfig::for_machine("frontier").unwrap_err().to_string(),
        GpuSpec::for_machine("frontier").unwrap_err().to_string(),
        presets::by_name("frontier", 2).unwrap_err().to_string(),
    ] {
        assert!(err.contains("unknown machine 'frontier'"), "{err}");
        assert!(err.contains("perlmutter") && err.contains("generic_ib"), "{err}");
    }
}

#[test]
fn bundle_file_round_trip_preserves_every_constant() {
    let path = tmp("roundtrip.json");
    let b = registry::resolve("vista").unwrap();
    b.save(&path).unwrap();
    let back = MachineBundle::load(&path).unwrap();
    assert_eq!(back.label(), "vista@1");
    assert_eq!(back.comm.proxy_overhead, b.comm.proxy_overhead);
    assert_eq!(back.comm.chunk_bytes, b.comm.chunk_bytes);
    assert_eq!(back.gpu.flops, b.gpu.flops);
    assert_eq!(back.gpu.mem_bytes, b.gpu.mem_bytes);
    assert_eq!(back.topo.inter.beta, b.topo.inter.beta);
    // A loaded bundle is a first-class --machine value everywhere.
    assert_eq!(
        CommConfig::for_machine(&path).unwrap().proxy_overhead,
        b.comm.proxy_overhead
    );
    assert_eq!(presets::by_name(&path, 8).unwrap().gpus_per_node, 1);
}

#[test]
fn validate_passes_builtins_and_fails_perturbed_bundle() {
    let (table, ok) = claims::run(None).unwrap();
    assert!(ok, "built-in claim drift:\n{}", table.render());
    assert!(!table.rows().is_empty());

    // Perturb one comm constant: NVRAR pays 5 ms per inter-node put — the
    // speedup claims must leave their bands and the run must fail, which
    // is what gives `yalis validate` its non-zero exit in CI.
    let mut bad = registry::resolve("perlmutter").unwrap();
    bad.comm.nvshmem_overhead = 5.0e-3;
    let (table, ok) = claims::run(Some(&bad)).unwrap();
    assert!(!ok, "perturbation undetected:\n{}", table.render());
    assert!(table.render().contains("FAIL"));
}

#[test]
fn fit_recovers_known_constants_and_output_bundle_resolves() {
    // The committed CI fixture: closed-form latencies generated at the
    // perlmutter bundle's exact α/β.
    let csv = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../bench/fit_smoke.csv"
    ))
    .expect("bench/fit_smoke.csv fixture");
    let rows = fit::parse_csv(&csv).unwrap();
    assert_eq!(rows.len(), 48);
    let base = registry::resolve("perlmutter").unwrap();
    let rep = fit::fit_alpha_beta(&base, &rows).unwrap();
    assert!(rep.rms < 1e-6, "rms {}", rep.rms);
    let t = &rep.bundle.topo;
    for (got, want) in [
        (t.intra.alpha, base.topo.intra.alpha),
        (t.intra.beta, base.topo.intra.beta),
        (t.inter.alpha, base.topo.inter.alpha),
        (t.inter.beta, base.topo.inter.beta),
    ] {
        assert!((got - want).abs() / want < 1e-6, "{got} vs {want}");
    }

    // The emitted bundle loads via the --machine path route and, being the
    // same constants at version 2, still passes the perlmutter claims.
    let out = tmp("fitted.json");
    rep.bundle.save(&out).unwrap();
    let loaded = registry::resolve(&out).unwrap();
    assert_eq!(loaded.label(), "perlmutter@2");
    let (table, ok) = claims::run(Some(&loaded)).unwrap();
    assert!(ok, "fitted bundle drifted:\n{}", table.render());
}
