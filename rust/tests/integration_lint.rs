//! Integration: `yalis lint` over self-contained fixture trees — the
//! scanner, waiver grammar, and ratchet composing through the same
//! [`yalis::lint::run_cli`] entry the CI gate calls. Fixtures live in
//! per-test temp directories so these tests never depend on the state of
//! the real repo (that gate is the `simlint` CI job itself).

use std::path::PathBuf;
use yalis::lint;

/// Build a fixture repo: a temp root with the given (rel_path, contents)
/// files. Directory names are unique per (process, test) so parallel
/// test binaries never collide.
fn fixture(name: &str, files: &[(&str, &str)]) -> PathBuf {
    let root = std::env::temp_dir().join(format!("yalis_lint_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    for (rel, text) in files {
        let p = root.join(rel);
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(&p, text).unwrap();
    }
    root
}

const CLEAN: &str = "//! Fixture.\npub fn add(a: u64, b: u64) -> u64 { a + b }\n";

#[test]
fn seeded_violation_fails_clean_tree_passes() {
    let bad = fixture(
        "seeded",
        &[(
            "rust/src/foo.rs",
            "pub fn worst(v: &[f64]) -> f64 {\n\
             \x20   *v.iter().max_by(|a, b| a.partial_cmp(b).unwrap()).unwrap()\n\
             }\n",
        )],
    );
    let report = lint::run(&bad, &bad.join(lint::DEFAULT_BASELINE)).unwrap();
    assert!(!report.ok(), "seeded .partial_cmp().unwrap() must be new debt");
    assert!(report.new_debt.iter().any(|d| d.rule == "D02" && d.file == "rust/src/foo.rs"));
    // The same line is also a P01 (unwrap in library code).
    assert!(report.new_debt.iter().any(|d| d.rule == "P01"));
    std::fs::remove_dir_all(&bad).unwrap();

    let good = fixture("clean", &[("rust/src/foo.rs", CLEAN)]);
    let report = lint::run(&good, &good.join(lint::DEFAULT_BASELINE)).unwrap();
    assert!(report.ok());
    assert_eq!(report.files_scanned, 1);
    assert_eq!(report.baselined + report.waived, 0);
    std::fs::remove_dir_all(&good).unwrap();
}

#[test]
fn waiver_suppresses_and_malformed_waiver_fails() {
    let root = fixture(
        "waiver",
        &[(
            "rust/src/foo.rs",
            "use std::collections::HashMap; // lint: allow(D01) fixture justification\n\
             pub fn f() -> HashMap<u32, u32> { HashMap::new() } // lint: allow(D01) ditto\n",
        )],
    );
    let report = lint::run(&root, &root.join(lint::DEFAULT_BASELINE)).unwrap();
    assert!(report.ok(), "waived hits are not debt");
    assert_eq!(report.waived, 2);
    std::fs::remove_dir_all(&root).unwrap();

    // Missing reason → hard error even though the rule id is valid.
    let root = fixture(
        "badwaiver",
        &[("rust/src/foo.rs", "use std::collections::HashMap; // lint: allow(D01)\n")],
    );
    let report = lint::run(&root, &root.join(lint::DEFAULT_BASELINE)).unwrap();
    assert!(!report.ok());
    assert_eq!(report.waiver_errors.len(), 1);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn cfg_test_and_test_tree_exemptions() {
    // P01 in a #[cfg(test)] module and in rust/tests/ is exempt; the same
    // pattern in library code is not.
    let root = fixture(
        "exempt",
        &[
            (
                "rust/src/foo.rs",
                "pub fn f(v: &[u64]) -> u64 { *v.first().unwrap() }\n\
                 #[cfg(test)]\n\
                 mod tests {\n\
                 \x20   #[test]\n\
                 \x20   fn t() { assert_eq!(super::f(&[1]), 1); Some(1).unwrap(); }\n\
                 }\n",
            ),
            ("rust/tests/itest.rs", "#[test]\nfn t() { Some(1).unwrap(); }\n"),
        ],
    );
    let report = lint::run(&root, &root.join(lint::DEFAULT_BASELINE)).unwrap();
    let p01: Vec<_> = report.new_debt.iter().filter(|d| d.rule == "P01").collect();
    assert_eq!(p01.len(), 1, "only the library-path unwrap counts: {p01:?}");
    assert_eq!(p01[0].file, "rust/src/foo.rs");
    assert_eq!(p01[0].hits.len(), 1);
    assert_eq!(p01[0].hits[0].0, 1, "the cfg(test) unwraps are exempt");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn ratchet_increase_fails_decrease_tightens_on_disk() {
    let two_unwraps = "pub fn f(v: &[u64]) -> u64 { *v.first().unwrap() }\n\
                       pub fn g(v: &[u64]) -> u64 { *v.last().unwrap() }\n";
    let baseline_one = "{\n  \"schema\": 1,\n  \"counts\": {\n    \"rust/src/foo.rs\": { \"P01\": 1 }\n  }\n}\n";

    // 2 current vs 1 baselined → new debt, and the baseline is NOT rewritten.
    let root = fixture(
        "ratchet_up",
        &[("rust/src/foo.rs", two_unwraps), (lint::DEFAULT_BASELINE, baseline_one)],
    );
    let before = std::fs::read_to_string(root.join(lint::DEFAULT_BASELINE)).unwrap();
    let ok = lint::run_cli(root.to_str().unwrap(), lint::DEFAULT_BASELINE, true, "").unwrap();
    assert!(!ok, "count above baseline must fail");
    let after = std::fs::read_to_string(root.join(lint::DEFAULT_BASELINE)).unwrap();
    assert_eq!(before, after, "a failing run must not touch the baseline");
    std::fs::remove_dir_all(&root).unwrap();

    // 1 current vs 2 baselined → passes AND auto-tightens the file to 1.
    let baseline_two = baseline_one.replace("\"P01\": 1", "\"P01\": 2");
    let root = fixture(
        "ratchet_down",
        &[
            ("rust/src/foo.rs", "pub fn f(v: &[u64]) -> u64 { *v.first().unwrap() }\n"),
            (lint::DEFAULT_BASELINE, &baseline_two),
        ],
    );
    let ok = lint::run_cli(root.to_str().unwrap(), lint::DEFAULT_BASELINE, true, "").unwrap();
    assert!(ok);
    let tightened = lint::ratchet::load(&root.join(lint::DEFAULT_BASELINE)).unwrap();
    assert_eq!(tightened.get("rust/src/foo.rs").and_then(|m| m.get("P01")), Some(&1));
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn json_report_lands_at_out_path() {
    let root = fixture("jsonout", &[("rust/src/foo.rs", CLEAN)]);
    let out = root.join("results/lint.json");
    let ok =
        lint::run_cli(root.to_str().unwrap(), lint::DEFAULT_BASELINE, true, out.to_str().unwrap())
            .unwrap();
    assert!(ok);
    let v = yalis::obs::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    assert_eq!(v.get("ok"), Some(&yalis::obs::json::Value::Bool(true)));
    assert_eq!(v.get("files_scanned").and_then(|x| x.as_f64()), Some(1.0));
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn missing_root_is_a_usage_error() {
    let root = fixture("noroot", &[("README.md", "not a rust tree\n")]);
    let err = lint::run_cli(root.to_str().unwrap(), lint::DEFAULT_BASELINE, true, "");
    assert!(err.is_err(), "a root without rust/src must be exit-2 (Err), not a pass");
    std::fs::remove_dir_all(&root).unwrap();
}
